"""L2: the blocked model-quality evaluator, built on the L1 kernels.

The paper's entire evaluation is "training log-likelihood vs time/cores".
The LL of the collapsed model is

    log p(w, z) = log p(z)   = I*(lgG(T a) - T lgG(a))
                               + sum_d [ sum_t lgG(n_td + a) - lgG(n_d + T a) ]
                + log p(w|z) = T*(lgG(J b) - J lgG(b))
                               + sum_t [ sum_w lgG(n_wt + b) - lgG(n_t + J b) ]

Both double sums are evaluated **blockwise** with fixed AOT shapes: the Rust
coordinator streams (BLOCK_ROWS, T) count blocks (zero-padded) through the
``ll_block`` artifact and accumulates in f64, applying the closed-form
padding correction ``pad_rows * T * lgamma(c)`` itself.  The 1-D terms
(``lgG(n_d + T a)``, ``lgG(n_t + J b)``) go through the ``ll_vec`` artifact
the same way.

Every function here is shape-monomorphic per (BLOCK_ROWS, T) pair; aot.py
lowers one artifact per configured pair.  Python never runs at training
time — this module exists only for `make artifacts` and pytest.
"""

import jax
import jax.numpy as jnp

from .kernels import dense_prob, lgamma_block_sum

# Block geometry shared with the Rust runtime (rust/src/runtime/artifacts.rs
# mirrors these constants; the artifact *names* carry them too, so a mismatch
# fails loudly at load time rather than silently).
BLOCK_ROWS = 256
VEC_LEN = 1024
PROB_BATCH = 64
TOPIC_SIZES = (128, 1024)


def ll_block(block, c):
    """sum(lgamma(block + c)) for one zero-padded (BLOCK_ROWS, T) block.

    Returned as a 1-tuple (AOT lowers with return_tuple=True).
    """
    return (lgamma_block_sum(block, c),)


def ll_vec(v, c):
    """sum(lgamma(v + c)) for one zero-padded (VEC_LEN,) vector.

    Small and latency-bound, so plain jnp (XLA fuses it into two ops); the
    blocked 2-D sums are where the Pallas kernel earns its keep.
    """
    return (jnp.sum(jax.lax.lgamma(v.astype(jnp.float32) + c)),)


def prob_batch(ntd, ntw, nt, scal):
    """Dense CGS conditionals for a (PROB_BATCH, T) token batch.

    scal = [alpha, beta, betabar].  Returns (p, norm).
    """
    p, norm = dense_prob(ntd, ntw, nt, scal[0], scal[1], scal[2])
    return (p, norm)


def specs(t):
    """Example-argument specs for each exported function at topic count t."""
    f32 = jnp.float32
    return {
        f"ll_block_b{BLOCK_ROWS}_t{t}": (
            ll_block,
            (jax.ShapeDtypeStruct((BLOCK_ROWS, t), f32), jax.ShapeDtypeStruct((), f32)),
        ),
        f"prob_b{PROB_BATCH}_t{t}": (
            prob_batch,
            (
                jax.ShapeDtypeStruct((PROB_BATCH, t), f32),
                jax.ShapeDtypeStruct((PROB_BATCH, t), f32),
                jax.ShapeDtypeStruct((t,), f32),
                jax.ShapeDtypeStruct((3,), f32),
            ),
        ),
    }


def all_specs():
    """name -> (fn, example_args) for every artifact we ship."""
    out = {
        f"ll_vec_n{VEC_LEN}": (
            ll_vec,
            (jax.ShapeDtypeStruct((VEC_LEN,), jnp.float32), jax.ShapeDtypeStruct((), jnp.float32)),
        )
    }
    for t in TOPIC_SIZES:
        out.update(specs(t))
    return out
