"""AOT-lower the L2 evaluator functions to HLO **text** artifacts.

Interchange format is HLO text, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly.  (See /opt/xla-example.)

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Emits one ``<name>.hlo.txt`` per entry of ``model.all_specs()`` plus a
``manifest.txt`` (name, nargs, shapes, outputs) the Rust loader
cross-checks at startup.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_str(s) -> str:
    return f"{s.dtype}[{','.join(str(d) for d in s.shape)}]"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = []
    for name, (fn, example_args) in sorted(model.all_specs().items()):
        if only is not None and name not in only:
            continue
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        arg_sig = ";".join(_spec_str(s) for s in example_args)
        manifest.append(f"{name}\t{len(example_args)}\t{arg_sig}")
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {os.path.join(args.out_dir, 'manifest.txt')} ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
