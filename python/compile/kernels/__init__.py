"""L1: Pallas kernels for the LL / sampler-oracle hot spots.

``lgamma``  — blocked sum(lgamma(x + c)) reduction (the LL hot spot).
``densep``  — dense CGS conditional probabilities (sampler oracle).
``ref``     — pure-jnp oracles for both, plus whole-model LL references.
"""

from . import ref  # noqa: F401
from .densep import dense_prob  # noqa: F401
from .lgamma import lgamma_block_sum, vmem_bytes  # noqa: F401
