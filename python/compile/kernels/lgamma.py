"""L1 Pallas kernel: blocked ``sum(lgamma(x + c))`` reduction.

This is the compute hot-spot of the model-quality (log-likelihood) path:
every convergence-curve point in the paper's figures requires summing
``lgamma`` over the full doc-topic and topic-word count matrices — millions
of transcendental evaluations per evaluation point.

TPU shaping (see DESIGN.md §Hardware-Adaptation):
  * the (B, T) input block is tiled into (ROW_TILE, T) VMEM-resident tiles
    via ``BlockSpec`` — T is the lane dimension and is kept a multiple of
    128 by the callers in model.py;
  * the scalar accumulator output uses the revisit pattern (every grid step
    maps to the same (1, 1) output block and accumulates) instead of
    atomics — the sequential TPU grid makes this race-free;
  * the smoother ``c`` rides in a (1, 1) block so the same compiled kernel
    serves both the alpha (doc) and beta (word) sides.

On this CPU-only session the kernel must run with ``interpret=True`` —
real TPU lowering emits a Mosaic custom-call the CPU PJRT plugin cannot
execute.  The structure above is what a TPU build would compile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROW_TILE = 64


def _lgamma_sum_kernel(c_ref, x_ref, o_ref):
    """One grid step: o += sum(lgamma(x_tile + c)); o is revisited."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[0, 0] = jnp.float32(0.0)

    tile = x_ref[...].astype(jnp.float32) + c_ref[0, 0]
    o_ref[0, 0] += jnp.sum(jax.lax.lgamma(tile))


@functools.partial(jax.jit, static_argnames=("row_tile", "interpret"))
def lgamma_block_sum(block, c, *, row_tile=DEFAULT_ROW_TILE, interpret=True):
    """sum(lgamma(block + c)) over a (B, T) block -> f32 scalar.

    ``B`` must be divisible by ``row_tile``; callers pad with zeros and
    correct by ``pad_rows * T * lgamma(c)`` on the Rust side.
    """
    b, t = block.shape
    if b % row_tile != 0:
        raise ValueError(f"block rows {b} not divisible by row_tile {row_tile}")
    c_arr = jnp.asarray(c, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        _lgamma_sum_kernel,
        grid=(b // row_tile,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # smoother, broadcast
            pl.BlockSpec((row_tile, t), lambda i: (i, 0)),  # row tile
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),  # revisited scalar
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(c_arr, block)
    return out[0, 0]


def vmem_bytes(row_tile, t):
    """Estimated VMEM working set of one grid step (for DESIGN.md §Perf).

    One f32 input tile + the (1,1) smoother + the (1,1) accumulator; the
    lgamma is elementwise so no extra materialisation beyond the tile.
    """
    return 4 * (row_tile * t + 2)
