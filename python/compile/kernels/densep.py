"""L1 Pallas kernel: dense CGS conditional probabilities for a token batch.

Computes, for each token b in a batch with document row ``ntd[b]`` and word
row ``ntw[b]``:

    p[b, t] = (ntd[b, t] + alpha) * (ntw[b, t] + beta) / (nt[t] + betabar)
    norm[b] = sum_t p[b, t]

i.e. the unnormalised multinomial of eq. (2) in the paper.  The Rust test
suite uses the AOT artifact of this kernel as an *independent oracle* for
the sampler implementations: every CGS variant (plain, sparse, alias,
F+LDA doc/word) must target exactly this distribution.

TPU shaping: the batch is tiled (ROW_TILE, T) with the shared (1, T) ``nt``
row and the (1, 2) scalar pair resident across the grid; the row-normaliser
falls out of the same pass (fused), so the kernel is a single VMEM-bound
sweep.  interpret=True on this CPU session.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROW_TILE = 16


def _dense_prob_kernel(scal_ref, nt_ref, ntd_ref, ntw_ref, p_ref, norm_ref):
    alpha = scal_ref[0, 0]
    beta = scal_ref[0, 1]
    betabar = scal_ref[0, 2]
    denom = nt_ref[0, :] + betabar
    p = (ntd_ref[...] + alpha) * (ntw_ref[...] + beta) / denom[None, :]
    p_ref[...] = p
    norm_ref[...] = jnp.sum(p, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("row_tile", "interpret"))
def dense_prob(ntd, ntw, nt, alpha, beta, betabar, *, row_tile=DEFAULT_ROW_TILE, interpret=True):
    """Batched dense CGS conditionals -> (p (B, T) f32, norm (B,) f32)."""
    b, t = ntd.shape
    if ntw.shape != (b, t) or nt.shape != (t,):
        raise ValueError(f"shape mismatch: ntd {ntd.shape} ntw {ntw.shape} nt {nt.shape}")
    if b % row_tile != 0:
        raise ValueError(f"batch {b} not divisible by row_tile {row_tile}")
    scal = jnp.stack([
        jnp.asarray(alpha, jnp.float32),
        jnp.asarray(beta, jnp.float32),
        jnp.asarray(betabar, jnp.float32),
    ]).reshape(1, 3)
    p, norm = pl.pallas_call(
        _dense_prob_kernel,
        grid=(b // row_tile,),
        in_specs=[
            pl.BlockSpec((1, 3), lambda i: (0, 0)),      # alpha/beta/betabar
            pl.BlockSpec((1, t), lambda i: (0, 0)),      # shared topic totals
            pl.BlockSpec((row_tile, t), lambda i: (i, 0)),
            pl.BlockSpec((row_tile, t), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((row_tile, t), lambda i: (i, 0)),
            pl.BlockSpec((row_tile, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t), jnp.float32),
            jax.ShapeDtypeStruct((b, 1), jnp.float32),
        ],
        interpret=interpret,
    )(scal, nt.reshape(1, t).astype(jnp.float32), ntd.astype(jnp.float32), ntw.astype(jnp.float32))
    return p, norm[:, 0]
