"""Pure-jnp correctness oracles for the Pallas kernels (L1).

Every kernel in this package has a reference implementation here written
with plain ``jax.numpy`` ops and no Pallas.  ``python/tests`` asserts
``assert_allclose(kernel(...), ref(...))`` over a hypothesis-driven sweep of
shapes and dtypes — this is the core correctness signal for Layer 1.
"""

import jax
import jax.numpy as jnp

__all__ = [
    "lgamma_block_sum_ref",
    "lgamma_vec_sum_ref",
    "dense_prob_ref",
    "doc_ll_ref",
    "word_ll_ref",
    "full_ll_ref",
]


def lgamma_block_sum_ref(block, c):
    """sum(lgamma(block + c)) over the whole (B, T) block -> f32 scalar.

    ``c`` is the Dirichlet smoother (alpha for doc-topic blocks, beta for
    topic-word blocks), passed as a scalar.
    """
    return jnp.sum(jax.lax.lgamma(block.astype(jnp.float32) + c))


def lgamma_vec_sum_ref(v, c):
    """sum(lgamma(v + c)) over a vector -> f32 scalar."""
    return jnp.sum(jax.lax.lgamma(v.astype(jnp.float32) + c))


def dense_prob_ref(ntd, ntw, nt, alpha, beta, betabar):
    """Dense CGS conditional for a batch of tokens (eq. (2) of the paper).

    p[b, t] = (ntd[b, t] + alpha) * (ntw[b, t] + beta) / (nt[t] + betabar)

    Returns (p, norm) where norm[b] = sum_t p[b, t].
    """
    p = (ntd + alpha) * (ntw + beta) / (nt + betabar)[None, :]
    return p, jnp.sum(p, axis=1)


# ---------------------------------------------------------------------------
# Whole-model references (L2): the collapsed joint log-likelihood
# log p(w, z) = log p(w|z) + log p(z)  (Griffiths & Steyvers; the quantity
# Yahoo! LDA's eq. (2) tracks).  These are the oracles for model.py and,
# transitively, for the Rust-side evaluator via golden files.
# ---------------------------------------------------------------------------


def doc_ll_ref(ntd, lens, alpha):
    """log p(z) for a dense doc-topic count matrix ``ntd`` of shape (D, T).

    lens[d] = n_d (token count of doc d);  includes the per-document
    constant I*(lgamma(T*alpha) - T*lgamma(alpha)).
    """
    D, T = ntd.shape
    lg = jnp.sum(jax.lax.lgamma(ntd.astype(jnp.float32) + alpha))
    lg -= jnp.sum(jax.lax.lgamma(lens.astype(jnp.float32) + T * alpha))
    lg += D * (jax.lax.lgamma(jnp.float32(T * alpha)) - T * jax.lax.lgamma(jnp.float32(alpha)))
    return lg


def word_ll_ref(nwt, nt, beta):
    """log p(w|z) for a dense word-topic count matrix ``nwt`` of shape (J, T).

    nt[t] = n_t (total tokens in topic t); includes the constant
    T*(lgamma(J*beta) - J*lgamma(beta)).
    """
    J, T = nwt.shape
    lg = jnp.sum(jax.lax.lgamma(nwt.astype(jnp.float32) + beta))
    lg -= jnp.sum(jax.lax.lgamma(nt.astype(jnp.float32) + J * beta))
    lg += T * (jax.lax.lgamma(jnp.float32(J * beta)) - J * jax.lax.lgamma(jnp.float32(beta)))
    return lg


def full_ll_ref(ntd, lens, nwt, nt, alpha, beta):
    """The full collapsed joint LL that every paper figure plots."""
    return doc_ll_ref(ntd, lens, alpha) + word_ll_ref(nwt, nt, beta)
