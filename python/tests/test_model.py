"""L2 tests: the blocked evaluator composes to the exact whole-matrix LL.

Simulates exactly what the Rust coordinator does at a convergence-curve
point — stream zero-padded blocks through ``model.ll_block``/``ll_vec`` and
apply the closed-form padding corrections — and checks the result equals
the one-shot whole-matrix oracle ``ref.full_ll_ref``.
"""

import math

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _pad_rows(a, rows):
    pad = (-a.shape[0]) % rows
    if pad:
        a = np.concatenate([a, np.zeros((pad,) + a.shape[1:], a.dtype)], axis=0)
    return a, pad


def blocked_doc_ll(ntd, lens, alpha, t):
    """Rust-side algorithm, in numpy, over the L2 functions."""
    rows = model.BLOCK_ROWS
    total = 0.0
    padded, pad = _pad_rows(ntd.astype(np.float32), rows)
    for i in range(0, padded.shape[0], rows):
        total += float(model.ll_block(jnp.asarray(padded[i : i + rows]), jnp.float32(alpha))[0])
    total -= pad * t * math.lgamma(alpha)  # padding rows are all-zero

    vlen = model.VEC_LEN
    vpadded, vpad = _pad_rows(lens.astype(np.float32), vlen)
    for i in range(0, vpadded.shape[0], vlen):
        total -= float(model.ll_vec(jnp.asarray(vpadded[i : i + vlen]), jnp.float32(t * alpha))[0])
    total += vpad * math.lgamma(t * alpha)

    d = ntd.shape[0]
    total += d * (math.lgamma(t * alpha) - t * math.lgamma(alpha))
    return total


def blocked_word_ll(nwt, nt, beta, t):
    rows = model.BLOCK_ROWS
    j = nwt.shape[0]
    total = 0.0
    padded, pad = _pad_rows(nwt.astype(np.float32), rows)
    for i in range(0, padded.shape[0], rows):
        total += float(model.ll_block(jnp.asarray(padded[i : i + rows]), jnp.float32(beta))[0])
    total -= pad * t * math.lgamma(beta)

    vlen = model.VEC_LEN
    vpadded, vpad = _pad_rows(nt.astype(np.float32), vlen)
    for i in range(0, vpadded.shape[0], vlen):
        total -= float(model.ll_vec(jnp.asarray(vpadded[i : i + vlen]), jnp.float32(j * beta))[0])
    total += vpad * math.lgamma(j * beta)

    total += t * (math.lgamma(j * beta) - j * math.lgamma(beta))
    return total


def random_counts(seed, d, j, t, avg_len=40):
    """Counts with LDA's structural invariants (rowsums consistent)."""
    rng = np.random.default_rng(seed)
    lens = rng.poisson(avg_len, size=d) + 1
    ntd = np.zeros((d, t), np.float32)
    nwt = np.zeros((j, t), np.float32)
    nt = np.zeros(t, np.float32)
    for di in range(d):
        topics = rng.integers(0, t, size=lens[di])
        words = rng.integers(0, j, size=lens[di])
        for z, w in zip(topics, words):
            ntd[di, z] += 1
            nwt[w, z] += 1
            nt[z] += 1
    return ntd, lens.astype(np.float32), nwt, nt


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    d=st.sampled_from([3, 40, 300]),
    t=st.sampled_from([128]),
)
def test_blocked_ll_equals_whole_matrix_oracle(seed, d, t):
    j = 97  # deliberately not a multiple of anything
    ntd, lens, nwt, nt = random_counts(seed, d, j, t)
    alpha, beta = 50.0 / t, 0.01
    got = blocked_doc_ll(ntd, lens, alpha, t) + blocked_word_ll(nwt, nt, beta, t)
    want = float(ref.full_ll_ref(
        jnp.asarray(ntd), jnp.asarray(lens), jnp.asarray(nwt), jnp.asarray(nt), alpha, beta
    ))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2.0)


def test_ll_decreases_with_random_vs_structured_assignment():
    """Sanity: concentrated topic assignments score higher than uniform."""
    t, j, d = 128, 97, 60
    ntd_r, lens, nwt_r, nt_r = random_counts(3, d, j, t)
    # structured: every doc uses one topic, every word one topic
    rng = np.random.default_rng(4)
    ntd_s = np.zeros((d, t), np.float32)
    nwt_s = np.zeros((j, t), np.float32)
    nt_s = np.zeros(t, np.float32)
    for di in range(d):
        z = di % 8
        n = lens[di]
        ntd_s[di, z] = n
        words = rng.integers(0, j, size=int(n))
        for w in words:
            nwt_s[w, z] += 1
        nt_s[z] += n
    alpha, beta = 50.0 / t, 0.01
    ll_r = float(ref.full_ll_ref(jnp.asarray(ntd_r), jnp.asarray(lens), jnp.asarray(nwt_r), jnp.asarray(nt_r), alpha, beta))
    ll_s = float(ref.full_ll_ref(jnp.asarray(ntd_s), jnp.asarray(lens), jnp.asarray(nwt_s), jnp.asarray(nt_s), alpha, beta))
    assert ll_s > ll_r


def test_all_specs_cover_configured_topics():
    names = set(model.all_specs())
    for t in model.TOPIC_SIZES:
        assert f"ll_block_b{model.BLOCK_ROWS}_t{t}" in names
        assert f"prob_b{model.PROB_BATCH}_t{t}" in names
    assert f"ll_vec_n{model.VEC_LEN}" in names
