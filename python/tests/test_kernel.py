"""Kernel-vs-oracle: the CORE L1 correctness signal.

Hypothesis sweeps the Pallas kernels' shapes/dtypes/values and asserts
allclose against the pure-jnp oracles in compile.kernels.ref.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import densep, lgamma, ref

jax.config.update("jax_platform_name", "cpu")


def counts_array(rng, shape, dtype, max_count):
    """Random nonnegative count-like array (LDA counts are integers >= 0)."""
    a = rng.integers(0, max_count, size=shape).astype(dtype)
    return jnp.asarray(a)


# ----------------------------------------------------------------------- #
# lgamma_block_sum                                                         #
# ----------------------------------------------------------------------- #


@settings(max_examples=25, deadline=None)
@given(
    rows_tiles=st.integers(1, 4),
    row_tile=st.sampled_from([8, 16, 64]),
    t=st.sampled_from([8, 128, 256]),
    c=st.sampled_from([0.01, 0.048828125, 0.5, 50.0 / 1024.0]),
    seed=st.integers(0, 2**31 - 1),
    max_count=st.sampled_from([1, 5, 1000, 10_000_000]),
)
def test_lgamma_block_sum_matches_ref(rows_tiles, row_tile, t, c, seed, max_count):
    rng = np.random.default_rng(seed)
    b = rows_tiles * row_tile
    block = counts_array(rng, (b, t), np.float32, max_count)
    got = lgamma.lgamma_block_sum(block, c, row_tile=row_tile)
    want = ref.lgamma_block_sum_ref(block, c)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [np.float32, np.int32, np.int64])
def test_lgamma_block_sum_dtypes(dtype):
    rng = np.random.default_rng(0)
    block = counts_array(rng, (64, 128), dtype, 100).astype(jnp.float32)
    got = lgamma.lgamma_block_sum(block, 0.01)
    want = ref.lgamma_block_sum_ref(block, 0.01)
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_lgamma_block_sum_zero_block_closed_form():
    """All-zero (padding) block == B * T * lgamma(c): the Rust-side
    padding-correction identity."""
    b, t, c = 128, 128, 0.01
    block = jnp.zeros((b, t), jnp.float32)
    got = float(lgamma.lgamma_block_sum(block, c))
    import math

    want = b * t * math.lgamma(c)
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_lgamma_block_sum_rejects_ragged():
    with pytest.raises(ValueError):
        lgamma.lgamma_block_sum(jnp.zeros((65, 128)), 0.1, row_tile=64)


def test_vmem_budget():
    """Default tiling keeps a grid step's VMEM under 16 MB at T=1024."""
    assert lgamma.vmem_bytes(lgamma.DEFAULT_ROW_TILE, 1024) < 16 * 2**20


# ----------------------------------------------------------------------- #
# dense_prob                                                               #
# ----------------------------------------------------------------------- #


@settings(max_examples=25, deadline=None)
@given(
    tiles=st.integers(1, 3),
    t=st.sampled_from([8, 128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_prob_matches_ref(tiles, t, seed):
    rng = np.random.default_rng(seed)
    b = tiles * densep.DEFAULT_ROW_TILE
    ntd = counts_array(rng, (b, t), np.float32, 50)
    ntw = counts_array(rng, (b, t), np.float32, 5000)
    nt = counts_array(rng, (t,), np.float32, 500_000)
    alpha, beta = 50.0 / t, 0.01
    betabar = beta * 7000
    p, norm = densep.dense_prob(ntd, ntw, nt, alpha, beta, betabar)
    p_ref, norm_ref = ref.dense_prob_ref(ntd, ntw, nt, alpha, beta, betabar)
    np.testing.assert_allclose(p, p_ref, rtol=1e-5)
    np.testing.assert_allclose(norm, norm_ref, rtol=1e-5)


def test_dense_prob_is_valid_distribution():
    rng = np.random.default_rng(1)
    t = 128
    ntd = counts_array(rng, (16, t), np.float32, 10)
    ntw = counts_array(rng, (16, t), np.float32, 100)
    nt = counts_array(rng, (t,), np.float32, 10_000) + 1
    p, norm = densep.dense_prob(ntd, ntw, nt, 0.1, 0.01, 0.01 * 500)
    assert bool(jnp.all(p >= 0))
    np.testing.assert_allclose(jnp.sum(p, axis=1), norm, rtol=1e-6)
    assert bool(jnp.all(norm > 0))


def test_dense_prob_shape_mismatch():
    with pytest.raises(ValueError):
        densep.dense_prob(
            jnp.zeros((16, 8)), jnp.zeros((16, 8)), jnp.zeros((9,)), 0.1, 0.01, 1.0
        )
