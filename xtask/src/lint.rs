//! `cargo xtask lint-invariants`: a line-level static pass over
//! `rust/src` enforcing the repo's determinism and concurrency-hygiene
//! invariants — the ones the compiler cannot see and code review keeps
//! re-litigating.
//!
//! Rules (each violation names its rule):
//!
//! * **Determinism scope** (`rust/src/sampler/`, `rust/src/lda/`,
//!   `rust/src/nomad/worker.rs`, `rust/src/ps/worker.rs` — the code whose
//!   output must be bit-identical across runs, thread counts, and
//!   machines):
//!   - `no-hash-collections`: no `HashMap`/`HashSet` — their iteration
//!     order is randomized per process, a classic nondeterminism leak.
//!     Sorted `Vec`s and `BTreeMap` are the house idiom.
//!   - `no-wall-clock`: no `Instant::now`/`SystemTime::now` — timing must
//!     never influence sampling decisions (it belongs in `util::bench` /
//!     `util::metrics`, outside this scope).
//!   - `no-ambient-rng`: no `thread_rng`/`rand::` — all randomness flows
//!     from explicitly seeded `util::rng` streams.
//!   - `no-float-trunc-cast`: no `f32/f64 -> integer` `as` casts in the
//!     recognizable spellings (`.floor() as`, `x_f64 as usize`, ...) —
//!     `as` rounds toward zero and silently saturates; truncation points
//!     must be deliberate and named (see `lint-allow.txt` for the one
//!     audited case the lexical pass cannot see).
//! * **Shim scope** (the modules migrated onto `util::sync` so the loom
//!   suite models the real code):
//!   - `no-raw-std-sync`: no `std::sync::` primitives except
//!     `std::sync::Arc` (the shim deliberately re-exports std's) and
//!     `std::sync::mpsc` (single-consumer rendezvous channels, outside
//!     the modeled protocols).  Everything else must come through
//!     `crate::util::sync`, or loom silently stops seeing it.
//! * **Library scope** (`rust/src/**` minus `main.rs` and
//!   `obs/event.rs`, with `#[cfg(test)]` modules exempt):
//!   - `no-raw-print`: no `println!`/`print!`/`eprintln!`/`eprint!` —
//!     library narration goes through `log_event!` (leveled, filterable,
//!     machine-readable), stdout contracts live in `main.rs`, and the
//!     one deliberate stdout renderer (`util::bench`) is allowlisted.
//! * **Everywhere** (`rust/src/**`):
//!   - `relaxed-needs-justification`: every `Ordering::Relaxed` must be
//!     covered by a `// relaxed:` comment — on the same line or earlier
//!     in the same blank-line-delimited block — saying why no ordering is
//!     needed.  Relaxed is the one memory ordering whose misuse does not
//!     fail loudly; the comment is the reviewable proof obligation.
//!
//! Pattern matching is lexical, over comment-stripped lines — cheap,
//! zero-dependency, and deliberately dumb: anything it cannot prove
//! harmless it flags, and `xtask/lint-allow.txt` (`rule path-suffix
//! line-substring`, `#` comments) is the audited escape hatch.  Unused
//! allowlist entries are themselves errors, so the file can only shrink
//! stale.

use std::fmt;
use std::path::{Path, PathBuf};

/// Directories / files whose code must be bit-deterministic.
const DETERMINISM_SCOPE: &[&str] = &[
    "rust/src/sampler/",
    "rust/src/lda/",
    "rust/src/nomad/worker.rs",
    "rust/src/ps/worker.rs",
];

/// Files migrated onto the `util::sync` shim: raw `std::sync` here would
/// silently escape the loom models.
const SHIM_SCOPE: &[&str] = &[
    "rust/src/infer/batch.rs",
    "rust/src/infer/server.rs",
    "rust/src/infer/stats.rs",
    "rust/src/resilience/writer.rs",
    "rust/src/corpus/disk.rs",
];

/// `(rule, patterns)` applied to comment-stripped lines in the
/// determinism scope.
const DETERMINISM_RULES: &[(&str, &[&str])] = &[
    ("no-hash-collections", &["HashMap", "HashSet"]),
    ("no-wall-clock", &["Instant::now", "SystemTime::now"]),
    ("no-ambient-rng", &["thread_rng", "rand::"]),
    (
        "no-float-trunc-cast",
        &[
            "f32 as u",
            "f32 as i",
            "f64 as u",
            "f64 as i",
            ".floor() as",
            ".ceil() as",
            ".round() as",
            ".fract() as",
            "next_f64() as",
            "next_f32() as",
        ],
    ),
];

#[derive(Debug, PartialEq, Eq)]
pub struct Violation {
    pub rule: &'static str,
    pub file: String,
    pub line_no: usize,
    pub line: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file,
            self.line_no,
            self.rule,
            self.line.trim()
        )
    }
}

pub struct Report {
    pub violations: Vec<Violation>,
    pub files_scanned: usize,
    pub allowlisted: usize,
}

/// Lint the tree under `root` (the repo root: `rust/src` below it is
/// scanned, `xtask/lint-allow.txt` below it is honored).
pub fn check_tree(root: &Path) -> Result<Report, String> {
    let src = root.join("rust/src");
    let mut files = Vec::new();
    collect_rs_files(&src, &mut files)
        .map_err(|e| format!("walking {}: {e}", src.display()))?;
    files.sort();

    let mut raw = Vec::new();
    for path in &files {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        let rel = path
            .strip_prefix(root)
            .map_err(|_| format!("{} escapes the repo root", path.display()))?
            .to_string_lossy()
            .replace('\\', "/");
        scan_file(&rel, &text, &mut raw);
    }

    let allow = load_allowlist(&root.join("xtask/lint-allow.txt"))?;
    let mut used = vec![false; allow.len()];
    let mut violations = Vec::new();
    let mut allowlisted = 0;
    for v in raw {
        let hit = allow.iter().position(|a| a.matches(&v));
        match hit {
            Some(i) => {
                used[i] = true;
                allowlisted += 1;
            }
            None => violations.push(v),
        }
    }
    for (i, entry) in allow.iter().enumerate() {
        if !used[i] {
            return Err(format!(
                "unused allowlist entry (line {}): '{} {} {}' — the code it \
                 excused is gone; delete the entry",
                entry.source_line, entry.rule, entry.path_suffix, entry.substring
            ));
        }
    }
    Ok(Report { violations, files_scanned: files.len(), allowlisted })
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if entry.file_type()?.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn scan_file(rel: &str, text: &str, out: &mut Vec<Violation>) {
    let in_determinism = DETERMINISM_SCOPE.iter().any(|p| rel.starts_with(p));
    let in_shim = SHIM_SCOPE.contains(&rel);
    // `main.rs` owns the stdout contracts (banner lines, report
    // rendering); `obs/event.rs` is the one sanctioned emitter.
    let print_exempt = rel == "rust/src/main.rs" || rel == "rust/src/obs/event.rs";
    // Once a file enters its test module, raw printing is test-debug
    // output, not library narration.  Lexical, like everything here:
    // the house style keeps `#[cfg(test)]` last in the file.
    let mut seen_cfg_test = false;
    let lines: Vec<&str> = text.lines().collect();
    let mut in_block_comment = false;
    for (i, raw_line) in lines.iter().enumerate() {
        let code = strip_comments(raw_line, &mut in_block_comment);
        if code.contains("#[cfg(test)]") {
            seen_cfg_test = true;
        }
        let mut push = |rule: &'static str| {
            out.push(Violation {
                rule,
                file: rel.to_string(),
                line_no: i + 1,
                line: (*raw_line).to_string(),
            });
        };
        if in_determinism {
            for (rule, patterns) in DETERMINISM_RULES {
                if patterns.iter().any(|p| code.contains(p)) {
                    push(rule);
                }
            }
        }
        if in_shim && raw_std_sync(&code) {
            push("no-raw-std-sync");
        }
        // "println!" is a substring of "eprintln!" and "print!(" of
        // "eprint!(": two patterns cover all four macros
        if !print_exempt
            && !seen_cfg_test
            && (code.contains("println!") || code.contains("print!("))
        {
            push("no-raw-print");
        }
        // checked on the *raw* line: the justification is a comment, and
        // `Ordering::Relaxed` inside a comment is not an atomic access
        if code.contains("Ordering::Relaxed") && !relaxed_justified(&lines, i) {
            push("relaxed-needs-justification");
        }
    }
}

/// `std::sync::` minus the two sanctioned escapes (`Arc` is std under
/// both cfgs by shim design; `mpsc` is single-consumer plumbing outside
/// the modeled protocols).
fn raw_std_sync(code: &str) -> bool {
    code.replace("std::sync::Arc", "")
        .replace("std::sync::mpsc", "")
        .contains("std::sync::")
}

/// A `// relaxed:` marker on the line itself, or on any earlier line of
/// the same blank-line-delimited block, justifies the access: one comment
/// may cover a whole block of same-protocol accesses (the snapshot loads
/// in `infer::stats::ServerStats::report` are the canonical case).
fn relaxed_justified(lines: &[&str], i: usize) -> bool {
    let mut j = i;
    loop {
        let line = lines[j];
        if line.trim().is_empty() {
            return false;
        }
        if line.contains("// relaxed:") {
            return true;
        }
        if j == 0 {
            return false;
        }
        j -= 1;
    }
}

/// Drop `//` line comments and `/* ... */` block comments (tracking block
/// state across lines).  String literals are *not* parsed: a `//` inside
/// a string truncates the scanned line, which can only under-report —
/// and none of the linted patterns hide in strings today.
fn strip_comments(line: &str, in_block: &mut bool) -> String {
    let mut out = String::new();
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        if *in_block {
            if c == '*' && chars.peek() == Some(&'/') {
                chars.next();
                *in_block = false;
            }
        } else if c == '/' && chars.peek() == Some(&'/') {
            break;
        } else if c == '/' && chars.peek() == Some(&'*') {
            chars.next();
            *in_block = true;
        } else {
            out.push(c);
        }
    }
    out
}

// ------------------------------------------------------------- allowlist

struct AllowEntry {
    rule: String,
    path_suffix: String,
    substring: String,
    source_line: usize,
}

impl AllowEntry {
    fn matches(&self, v: &Violation) -> bool {
        v.rule == self.rule
            && v.file.ends_with(&self.path_suffix)
            && v.line.contains(&self.substring)
    }
}

/// Format: `rule path-suffix line-substring...` per line (the substring
/// keeps any internal spaces); `#` comments and blank lines are skipped.
/// A missing file means an empty allowlist.
fn load_allowlist(path: &Path) -> Result<Vec<AllowEntry>, String> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(format!("reading {}: {e}", path.display())),
    };
    let mut entries = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.splitn(3, char::is_whitespace);
        let (rule, suffix, substring) = (parts.next(), parts.next(), parts.next());
        match (rule, suffix, substring) {
            (Some(r), Some(p), Some(s)) => entries.push(AllowEntry {
                rule: r.to_string(),
                path_suffix: p.to_string(),
                substring: s.trim().to_string(),
                source_line: i + 1,
            }),
            _ => {
                return Err(format!(
                    "{}:{}: malformed allowlist entry (want: rule path-suffix \
                     line-substring): '{line}'",
                    path.display(),
                    i + 1
                ))
            }
        }
    }
    Ok(entries)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    /// A scratch repo-shaped tree under the OS temp dir.
    struct Tree {
        root: PathBuf,
    }

    impl Tree {
        fn new(name: &str) -> Tree {
            let root = std::env::temp_dir()
                .join(format!("xtask_lint_tests_{}", std::process::id()))
                .join(name);
            let _ = std::fs::remove_dir_all(&root);
            std::fs::create_dir_all(&root).unwrap();
            Tree { root }
        }

        fn write(&self, rel: &str, content: &str) {
            let path = self.root.join(rel);
            std::fs::create_dir_all(path.parent().unwrap()).unwrap();
            std::fs::write(path, content).unwrap();
        }
    }

    impl Drop for Tree {
        fn drop(&mut self) {
            let _ = std::fs::remove_dir_all(&self.root);
        }
    }

    fn rules_of(report: &Report) -> Vec<&'static str> {
        report.violations.iter().map(|v| v.rule).collect()
    }

    #[test]
    fn seeded_determinism_violations_fail_the_lint() {
        let t = Tree::new("seeded");
        t.write(
            "rust/src/sampler/bad.rs",
            "use std::collections::HashMap;\n\
             fn f() {\n\
                 let t0 = std::time::Instant::now();\n\
                 let x = 0.5f64;\n\
                 let k = x.floor() as usize;\n\
             }\n",
        );
        let report = check_tree(&t.root).unwrap();
        let rules = rules_of(&report);
        assert!(rules.contains(&"no-hash-collections"), "got {rules:?}");
        assert!(rules.contains(&"no-wall-clock"), "got {rules:?}");
        assert!(rules.contains(&"no-float-trunc-cast"), "got {rules:?}");
    }

    #[test]
    fn commented_out_code_does_not_trip_the_determinism_rules() {
        let t = Tree::new("comments");
        t.write(
            "rust/src/lda/ok.rs",
            "// a HashMap would be nondeterministic here, so we don't\n\
             /* Instant::now() is likewise banned */\n\
             fn f() {}\n",
        );
        let report = check_tree(&t.root).unwrap();
        assert!(report.violations.is_empty(), "{:?}", report.violations);
    }

    #[test]
    fn raw_std_sync_in_shim_scope_fails_but_arc_and_mpsc_pass() {
        let t = Tree::new("shim");
        t.write(
            "rust/src/infer/batch.rs",
            "use std::sync::Arc;\n\
             use std::sync::mpsc;\n\
             use std::sync::Mutex;\n",
        );
        let report = check_tree(&t.root).unwrap();
        assert_eq!(rules_of(&report), vec!["no-raw-std-sync"]);
        assert_eq!(report.violations[0].line_no, 3);
    }

    #[test]
    fn relaxed_needs_a_justifying_comment_in_its_block() {
        let t = Tree::new("relaxed");
        t.write(
            "rust/src/util/counters.rs",
            "fn ok(c: &AtomicU64) {\n\
                 // relaxed: independent tally, nothing ordered under it\n\
                 c.fetch_add(1, Ordering::Relaxed);\n\
                 c.load(Ordering::Relaxed);\n\
             }\n\
             \n\
             fn bad(c: &AtomicU64) {\n\
                 c.fetch_add(1, Ordering::Relaxed);\n\
             }\n",
        );
        let report = check_tree(&t.root).unwrap();
        assert_eq!(rules_of(&report), vec!["relaxed-needs-justification"]);
        assert_eq!(report.violations[0].line_no, 8, "{:?}", report.violations);
    }

    #[test]
    fn raw_prints_fail_in_library_scope_but_not_main_tests_or_emitter() {
        let t = Tree::new("prints");
        t.write(
            "rust/src/nomad/noisy.rs",
            "fn f() { eprintln!(\"chatty\"); }\n\
             fn g() { print!(\"chattier\"); }\n\
             // println! in a comment is fine\n\
             #[cfg(test)]\n\
             mod tests {\n\
                 fn t() { println!(\"test debug output\"); }\n\
             }\n",
        );
        t.write("rust/src/main.rs", "fn main() { println!(\"banner\"); }\n");
        t.write(
            "rust/src/obs/event.rs",
            "pub fn emit(line: &str) { eprintln!(\"{line}\"); }\n",
        );
        let report = check_tree(&t.root).unwrap();
        assert_eq!(rules_of(&report), vec!["no-raw-print", "no-raw-print"]);
        assert_eq!(report.violations[0].line_no, 1);
        assert_eq!(report.violations[1].line_no, 2);
    }

    #[test]
    fn allowlist_suppresses_matches_and_rejects_unused_entries() {
        let t = Tree::new("allow");
        t.write(
            "rust/src/sampler/bad.rs",
            "fn f() { let t0 = std::time::Instant::now(); }\n",
        );
        t.write(
            "xtask/lint-allow.txt",
            "# one live entry\n\
             no-wall-clock sampler/bad.rs Instant::now\n",
        );
        let report = check_tree(&t.root).unwrap();
        assert!(report.violations.is_empty(), "{:?}", report.violations);
        assert_eq!(report.allowlisted, 1);

        t.write(
            "xtask/lint-allow.txt",
            "no-wall-clock sampler/bad.rs Instant::now\n\
             no-wall-clock sampler/gone.rs Instant::now\n",
        );
        let err = check_tree(&t.root).unwrap_err();
        assert!(err.contains("unused allowlist entry"), "unhelpful: {err}");
    }

    #[test]
    fn block_comment_state_carries_across_lines() {
        let mut in_block = false;
        assert_eq!(strip_comments("code /* open", &mut in_block), "code ");
        assert!(in_block);
        assert_eq!(strip_comments("still hidden", &mut in_block), "");
        assert_eq!(strip_comments("end */ visible", &mut in_block), " visible");
        assert!(!in_block);
    }

    /// The live gate: the repo's own tree must stay clean (everything
    /// intentional is either compliant or explicitly allowlisted).
    #[test]
    fn the_real_tree_passes_the_lint() {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .unwrap()
            .to_path_buf();
        let report = check_tree(&root).unwrap();
        assert!(
            report.violations.is_empty(),
            "the tree regressed:\n{}",
            report
                .violations
                .iter()
                .map(|v| v.to_string())
                .collect::<Vec<_>>()
                .join("\n")
        );
        assert!(report.files_scanned > 50, "suspiciously few files scanned");
    }
}
