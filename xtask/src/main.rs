//! Repo tooling, invoked as `cargo xtask <command>` (the `xtask` alias
//! lives in `.cargo/config.toml`).
//!
//! One command so far: `lint-invariants`, the determinism/concurrency
//! static pass over `rust/src` — see [`lint`] for the rules and
//! `xtask/lint-allow.txt` for the escape hatch.

mod lint;

use std::path::PathBuf;
use std::process::ExitCode;

fn repo_root() -> PathBuf {
    // xtask/ sits directly under the repo root
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask has a parent directory")
        .to_path_buf()
}

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    match args.next().as_deref() {
        Some("lint-invariants") => match lint::check_tree(&repo_root()) {
            Ok(report) => {
                if report.violations.is_empty() {
                    eprintln!(
                        "lint-invariants: OK ({} files, {} allowlisted)",
                        report.files_scanned, report.allowlisted
                    );
                    ExitCode::SUCCESS
                } else {
                    for v in &report.violations {
                        eprintln!("{v}");
                    }
                    eprintln!(
                        "lint-invariants: {} violation(s) — fix, or add a justified entry \
                         to xtask/lint-allow.txt",
                        report.violations.len()
                    );
                    ExitCode::FAILURE
                }
            }
            Err(e) => {
                eprintln!("lint-invariants: {e}");
                ExitCode::FAILURE
            }
        },
        other => {
            let got = other.unwrap_or("<none>");
            eprintln!("unknown xtask command '{got}'; available: lint-invariants");
            ExitCode::FAILURE
        }
    }
}
