//! Serial sampler shoot-out on a NyTimes-shaped corpus — the workload of
//! the paper's Fig. 4 at example scale: all five CGS variants on the same
//! corpus, reporting per-iteration time and LL so the F+LDA advantage and
//! the word-vs-doc ordering are visible.
//!
//!     cargo run --release --example train_nytimes_style [iters] [topics]

use fnomad_lda::corpus::preset;
use fnomad_lda::lda::state::{Hyper, LdaState};
use fnomad_lda::lda::{self, log_likelihood};
use fnomad_lda::util::bench::{fmt_ns, Table};
use fnomad_lda::util::rng::Pcg32;

fn main() -> Result<(), String> {
    let args: Vec<String> = std::env::args().collect();
    let iters: usize = args.get(1).map(|s| s.parse().unwrap()).unwrap_or(3);
    let topics: usize = args.get(2).map(|s| s.parse().unwrap()).unwrap_or(256);

    let corpus = preset("nytimes-sim")?;
    println!(
        "nytimes-sim: {} docs, {} vocab, {} tokens, T={topics}\n",
        corpus.num_docs(),
        corpus.vocab(),
        corpus.num_tokens()
    );

    let mut table = Table::new(
        "serial samplers (Fig. 4 workload)",
        &["sampler", "ns/token", "tokens/s", "final LL"],
    );
    for name in lda::VARIANTS {
        let mut rng = Pcg32::seeded(1234);
        let mut state = LdaState::init_random(&corpus, Hyper::paper_default(topics), &mut rng);
        let mut sampler = lda::by_name(name, &state, &corpus)?;
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            sampler.sweep(&mut state, &corpus, &mut rng);
        }
        let ns = t0.elapsed().as_nanos() as f64 / (iters * corpus.num_tokens()) as f64;
        state.check_consistency(&corpus)?;
        table.row(vec![
            name.to_string(),
            fmt_ns(ns),
            format!("{:.0}", 1e9 / ns),
            format!("{:.4e}", log_likelihood(&state)),
        ]);
        eprintln!("  {name} done");
    }
    table.print();
    println!(
        "\nExpected shape: flda-* fastest; flda-word >= flda-doc at this doc count;\n\
         exact samplers (all but alias) at comparable LL after equal iterations."
    );
    Ok(())
}
