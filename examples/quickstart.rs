//! Quickstart: train F+LDA (word-by-word, the paper's fastest serial
//! sampler) on the bundled tiny corpus and inspect the topics.
//!
//!     cargo run --release --example quickstart

use fnomad_lda::coordinator::{train, EvalPolicy, RuntimeKind, TrainConfig};
use fnomad_lda::corpus::preset;
use fnomad_lda::lda::state::{Hyper, LdaState};
use fnomad_lda::lda::{log_likelihood, topics, FLdaWord, Sweep};
use fnomad_lda::util::rng::Pcg32;

fn main() -> Result<(), String> {
    // 1. a corpus: synthetic preset here; swap for corpus::bow::load(...)
    //    to read a real UCI docword file
    let corpus = preset("tiny")?;
    println!(
        "corpus: {} docs, {} vocab, {} tokens",
        corpus.num_docs(),
        corpus.vocab(),
        corpus.num_tokens()
    );

    // 2. hyperparameters: the paper's α = 50/T, β = 0.01
    let hyper = Hyper::paper_default(16);

    // 3. random init + the F+tree-backed word-by-word Gibbs sampler
    let mut rng = Pcg32::seeded(42);
    let mut state = LdaState::init_random(&corpus, hyper, &mut rng);
    let mut sampler = FLdaWord::new(&state, &corpus);

    println!("initial LL = {:.4e}", log_likelihood(&state));
    for iter in 1..=30 {
        sampler.sweep(&mut state, &corpus, &mut rng);
        if iter % 10 == 0 {
            println!("iter {iter:3}: LL = {:.4e}", log_likelihood(&state));
        }
    }

    // 4. inspect: top words per topic (ids only — synthetic corpus)
    print!("{}", topics::render_topics(&state, corpus.vocab_words(), 6));

    // 5. invariants held throughout
    state.check_consistency(&corpus)?;

    // 6. the same experiment through the coordinator: pick a runtime with
    //    the typed builder and let the driver loop handle eval + series
    let cfg = TrainConfig::preset("tiny")
        .runtime(RuntimeKind::NomadSim)
        .topics(16)
        .iters(5)
        .eval(EvalPolicy::Rust)
        .quiet(true);
    let res = train(&cfg)?;
    println!(
        "nomad-sim (simulated cluster): final LL = {:.4e}, {:.0} virtual tokens/s",
        res.ll_vs_iter.last_y().unwrap(),
        res.tokens_per_sec
    );
    println!("quickstart OK");
    Ok(())
}
