//! Standalone perf probe: timed sweeps of flda-word on enron-sim at T=1024.
//!
//!     cargo run --release --example perf_probe

use fnomad_lda::corpus::preset;
use fnomad_lda::lda::state::{Hyper, LdaState};
use fnomad_lda::lda::{FLdaWord, Sweep};
use fnomad_lda::util::rng::Pcg32;

fn main() {
    let corpus = preset("enron-sim").unwrap();
    let mut rng = Pcg32::seeded(9);
    let mut state = LdaState::init_random(&corpus, Hyper::paper_default(1024), &mut rng);
    let mut s = FLdaWord::new(&state, &corpus);
    // burn-in
    for _ in 0..2 {
        s.sweep(&mut state, &corpus, &mut rng);
    }
    let t0 = std::time::Instant::now();
    for _ in 0..3 {
        s.sweep(&mut state, &corpus, &mut rng);
    }
    let ns = t0.elapsed().as_nanos() as f64 / (3 * corpus.num_tokens()) as f64;
    println!("flda-word: {ns:.1} ns/token");
}
