//! END-TO-END driver (EXPERIMENTS.md §E2E): train a full topic model on
//! the Enron-shaped corpus at the paper's T=1024 with the complete
//! three-layer stack —
//!
//!   L3  Rust F+LDA(word) Gibbs sampling (F+tree, Θ(|T_d| + log T)/token)
//!   L2  blocked log-likelihood evaluator AOT-compiled from JAX
//!   L1  Pallas lgamma-reduction kernel inside that artifact, executed
//!       through PJRT from Rust at every evaluation point
//!
//! and log the convergence curve to results/e2e_train.csv.
//!
//!     cargo run --release --example e2e_train [iters] [preset] [topics]
//!
//! Requires `make artifacts` (falls back to the Rust evaluator with a
//! warning if they are missing, so the example always runs).

use fnomad_lda::coordinator::{train, EvalPolicy, Evaluator, SamplerKind, TrainConfig};
use fnomad_lda::runtime::{artifacts_available, default_artifact_dir};

fn main() -> Result<(), String> {
    let args: Vec<String> = std::env::args().collect();
    let iters: usize = args.get(1).map(|s| s.parse().unwrap()).unwrap_or(40);
    let preset = args.get(2).cloned().unwrap_or_else(|| "enron-sim".into());
    let topics: usize = args.get(3).map(|s| s.parse().unwrap()).unwrap_or(1024);

    if !artifacts_available(&default_artifact_dir()) {
        eprintln!(
            "WARNING: artifacts/ missing — run `make artifacts` for the full\n\
             three-layer path; continuing with the Rust evaluator."
        );
    }

    let cfg = TrainConfig::preset(&preset)
        .topics(topics)
        .sampler(SamplerKind::FLdaWord)
        .iters(iters)
        .seed(2015) // WWW'15
        .eval(EvalPolicy::Auto)
        .eval_every(1)
        .out("results/e2e_train.csv");
    // surface which evaluator resolved (xla = full stack)
    let eval = Evaluator::resolve(cfg.eval, cfg.topics)?;
    eprintln!("[e2e] evaluator: {}", eval.name());
    drop(eval);

    let res = train(&cfg)?;

    println!("\n=== e2e summary ===");
    println!("points on the loss curve : {}", res.ll_vs_iter.points.len());
    println!(
        "LL: initial {:.5e} -> final {:.5e}",
        res.ll_vs_iter.points.first().unwrap().1,
        res.ll_vs_iter.last_y().unwrap()
    );
    println!("sampler throughput        : {:.0} tokens/s", res.tokens_per_sec);
    println!("curve written to          : results/e2e_train.csv");

    // hard success criteria so CI/EXPERIMENTS can trust this run
    let first = res.ll_vs_iter.points.first().unwrap().1;
    let last = res.ll_vs_iter.last_y().unwrap();
    if last <= first {
        return Err("LL did not improve over training".into());
    }
    res.final_state
        .check_consistency(&fnomad_lda::corpus::preset(&cfg.preset)?)?;
    println!("e2e_train OK");
    Ok(())
}
