//! Distributed shoot-out under virtual time — the Fig. 5/6 workload at
//! example scale: F+Nomad LDA vs the parameter server (memory and disk
//! flavors) on a simulated 20-core node, plus nomad core-scaling.
//!
//! Virtual time comes from a cost model calibrated against the real serial
//! sampler; the Gibbs math is executed for real, so LL curves are genuine
//! (see DESIGN.md §Hardware-Adaptation).
//!
//!     cargo run --release --example distributed_sim [epochs]

use fnomad_lda::corpus::preset;
use fnomad_lda::lda::log_likelihood;
use fnomad_lda::lda::state::Hyper;
use fnomad_lda::simnet::nomad_sim::{NomadSim, NomadSimConfig};
use fnomad_lda::simnet::ps_sim::{PsSim, PsSimConfig};
use fnomad_lda::simnet::{ClusterSpec, CostModel};
use fnomad_lda::util::bench::Table;

fn main() -> Result<(), String> {
    let epochs: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse().unwrap())
        .unwrap_or(4);
    let topics = 64;
    let corpus = preset("tiny")?;
    let hyper = Hyper::paper_default(topics);
    let cost = CostModel::calibrate(&corpus, hyper, 1);
    println!(
        "corpus: {} docs / {} tokens, T={topics}, calibrated token_ns={:.0}\n",
        corpus.num_docs(),
        corpus.num_tokens(),
        cost.token_ns
    );

    // --- Fig. 5a/b shape: 20 cores, nomad vs PS(M) vs PS(D) ---
    let cluster = ClusterSpec::multicore(20);
    let mut table = Table::new(
        "20-core node (virtual time)",
        &["system", "epoch", "vtime(s)", "LL"],
    );
    {
        let mut cfg = NomadSimConfig::new(cluster, topics);
        cfg.cost = cost;
        let mut sim = NomadSim::new(&corpus, hyper, cfg);
        for e in 1..=epochs {
            sim.run_epoch();
            table.row(vec![
                "F+Nomad".into(),
                e.to_string(),
                format!("{:.4}", sim.vtime_secs()),
                format!("{:.4e}", log_likelihood(&sim.gather_state(&corpus))),
            ]);
        }
    }
    for disk in [false, true] {
        let mut cfg = PsSimConfig::new(cluster, topics);
        cfg.cost = cost;
        cfg.disk = disk;
        let mut sim = PsSim::new(&corpus, hyper, cfg);
        let label = if disk { "Yahoo!LDA(D)" } else { "Yahoo!LDA(M)" };
        for e in 1..=epochs {
            sim.run_epoch();
            table.row(vec![
                label.into(),
                e.to_string(),
                format!("{:.4}", sim.vtime_secs()),
                format!("{:.4e}", log_likelihood(&sim.gather_state(&corpus))),
            ]);
        }
    }
    table.print();

    // --- Fig. 5c shape: nomad scaling with cores ---
    let mut scaling = Table::new(
        "nomad core scaling (one epoch)",
        &["cores", "vtime(s)", "speedup"],
    );
    let mut base = None;
    for cores in [1usize, 2, 4, 8, 16, 20] {
        let mut cfg = NomadSimConfig::new(ClusterSpec::multicore(cores), topics);
        cfg.cost = cost;
        let mut sim = NomadSim::new(&corpus, hyper, cfg);
        sim.run_epoch();
        let t = sim.vtime_secs();
        let b = *base.get_or_insert(t);
        scaling.row(vec![
            cores.to_string(),
            format!("{t:.4}"),
            format!("{:.2}x", b / t),
        ]);
    }
    scaling.print();
    println!(
        "\nExpected shape: F+Nomad reaches a given LL in less virtual time than\n\
         both PS flavors; PS(D) trails PS(M); nomad speedup grows with cores."
    );
    Ok(())
}
