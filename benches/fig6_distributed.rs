//! Regenerates **Figure 6**: F+Nomad LDA vs Yahoo!LDA (memory/disk) on a
//! simulated 32-machine × 20-core cluster, Amazon- and UMBC-shaped
//! corpora — LL vs virtual wall clock.
//!
//! Expected shape: the gap between F+Nomad and the parameter server
//! *widens* relative to the single-node case — 640 clients queueing on
//! the sharded server vs the nomad ring whose cross-machine hops are
//! 1-in-20 — and PS(disk) trails everything.
//!
//! Writes results/fig6_distributed.csv.
//!
//!     cargo bench --bench fig6_distributed

use fnomad_lda::corpus::preset;
use fnomad_lda::lda::log_likelihood;
use fnomad_lda::lda::state::Hyper;
use fnomad_lda::simnet::nomad_sim::{NomadSim, NomadSimConfig};
use fnomad_lda::simnet::ps_sim::{PsSim, PsSimConfig};
use fnomad_lda::simnet::{ClusterSpec, CostModel};
use fnomad_lda::util::bench::Table;
use fnomad_lda::util::metrics::{write_csv, Series};

fn main() {
    let topics = 256;
    let epochs = 3;
    let machines = 32;
    let cluster = ClusterSpec::cluster(machines);
    let calib = preset("tiny").unwrap();
    let cost = CostModel::calibrate(&calib, Hyper::paper_default(topics), 1);
    eprintln!(
        "cluster: {machines} machines x {} cores = {} workers; token_ns={:.0}",
        cluster.cores_per_machine,
        cluster.total_workers(),
        cost.token_ns
    );

    let mut all_series = Vec::new();
    for preset_name in ["amazon-sim", "umbc-sim"] {
        let corpus = preset(preset_name).unwrap();
        let hyper = Hyper::paper_default(topics);
        eprintln!(
            "{preset_name}: {} docs / {} tokens",
            corpus.num_docs(),
            corpus.num_tokens()
        );

        {
            let mut cfg = NomadSimConfig::new(cluster, topics);
            cfg.cost = cost;
            let mut sim = NomadSim::new(&corpus, hyper, cfg);
            let mut s = Series::new(format!("fig6:{preset_name}:nomad"));
            s.push(0.0, log_likelihood(&sim.gather_state(&corpus)));
            for _ in 0..epochs {
                sim.run_epoch();
                s.push(sim.vtime_secs(), log_likelihood(&sim.gather_state(&corpus)));
            }
            eprintln!("  nomad: {:.3}s vtime, LL {:.4e}", sim.vtime_secs(), s.last_y().unwrap());
            all_series.push(s);
        }
        for disk in [false, true] {
            let mut cfg = PsSimConfig::new(cluster, topics);
            cfg.cost = cost;
            cfg.disk = disk;
            let mut sim = PsSim::new(&corpus, hyper, cfg);
            let label = if disk { "ps-disk" } else { "ps-mem" };
            let mut s = Series::new(format!("fig6:{preset_name}:{label}"));
            s.push(0.0, log_likelihood(&sim.gather_state(&corpus)));
            for _ in 0..epochs {
                sim.run_epoch();
                s.push(sim.vtime_secs(), log_likelihood(&sim.gather_state(&corpus)));
            }
            eprintln!("  {label}: {:.3}s vtime, LL {:.4e}", sim.vtime_secs(), s.last_y().unwrap());
            all_series.push(s);
        }
    }

    let mut table = Table::new(
        "Fig 6 — 32x20 cluster: virtual time to PS-mem final LL",
        &["corpus", "system", "vtime-to-target(s)", "vs nomad"],
    );
    for preset_name in ["amazon-sim", "umbc-sim"] {
        let target = all_series
            .iter()
            .find(|s| s.name == format!("fig6:{preset_name}:ps-mem"))
            .and_then(|s| s.last_y())
            .unwrap();
        let nomad_t = all_series
            .iter()
            .find(|s| s.name == format!("fig6:{preset_name}:nomad"))
            .and_then(|s| s.time_to_reach(target));
        for sys in ["nomad", "ps-mem", "ps-disk"] {
            let t = all_series
                .iter()
                .find(|s| s.name == format!("fig6:{preset_name}:{sys}"))
                .and_then(|s| s.time_to_reach(target));
            table.row(vec![
                preset_name.into(),
                sys.into(),
                t.map(|x| format!("{x:.3}")).unwrap_or("n/a".into()),
                match (t, nomad_t) {
                    (Some(a), Some(b)) if b > 0.0 => format!("{:.1}x", a / b),
                    _ => "n/a".into(),
                },
            ]);
        }
    }
    table.print();
    write_csv(std::path::Path::new("results/fig6_distributed.csv"), &all_series).unwrap();
    println!("\nwrote results/fig6_distributed.csv");
    println!(
        "Shape check: nomad dramatically ahead of both PS flavors at 640 workers; \
         disk flavor slowest (paper Fig. 6)."
    );
}
