//! Regenerates **Figure 5**: (a,b) F+Nomad LDA vs the parameter server
//! (memory + disk flavors) on a simulated 20-core node — LL vs virtual
//! time; (c) nomad convergence speed as cores scale.
//!
//! Virtual time: calibrated cost model + network model; Gibbs math is
//! executed for real (DESIGN.md §Hardware-Adaptation).  Expected shape:
//! nomad reaches a given LL several times faster than the PS; PS(disk)
//! trails PS(memory); more cores converge faster.
//!
//! Writes results/fig5_multicore.csv.
//!
//!     cargo bench --bench fig5_multicore

use fnomad_lda::corpus::preset;
use fnomad_lda::lda::log_likelihood;
use fnomad_lda::lda::state::Hyper;
use fnomad_lda::simnet::nomad_sim::{NomadSim, NomadSimConfig};
use fnomad_lda::simnet::ps_sim::{PsSim, PsSimConfig};
use fnomad_lda::simnet::{ClusterSpec, CostModel};
use fnomad_lda::util::bench::Table;
use fnomad_lda::util::metrics::{write_csv, Series};

fn main() {
    let topics = 256;
    let epochs = 5;
    let cores = 20;
    let mut all_series = Vec::new();

    // calibrate once on a slice of the target workload
    let calib = preset("tiny").unwrap();
    let cost = CostModel::calibrate(&calib, Hyper::paper_default(topics), 1);
    eprintln!("calibrated token_ns = {:.0}", cost.token_ns);

    for preset_name in ["pubmed-sim", "amazon-sim"] {
        let corpus = preset(preset_name).unwrap();
        let hyper = Hyper::paper_default(topics);
        eprintln!(
            "{preset_name}: {} docs / {} tokens on {cores} simulated cores",
            corpus.num_docs(),
            corpus.num_tokens()
        );

        // F+Nomad
        {
            let mut cfg = NomadSimConfig::new(ClusterSpec::multicore(cores), topics);
            cfg.cost = cost;
            let mut sim = NomadSim::new(&corpus, hyper, cfg);
            let mut s = Series::new(format!("fig5:{preset_name}:nomad"));
            s.push(0.0, log_likelihood(&sim.gather_state(&corpus)));
            for _ in 0..epochs {
                sim.run_epoch();
                s.push(sim.vtime_secs(), log_likelihood(&sim.gather_state(&corpus)));
            }
            eprintln!("  nomad: {:.2}s vtime, LL {:.4e}", sim.vtime_secs(), s.last_y().unwrap());
            all_series.push(s);
        }
        // PS memory + disk
        for disk in [false, true] {
            let mut cfg = PsSimConfig::new(ClusterSpec::multicore(cores), topics);
            cfg.cost = cost;
            cfg.disk = disk;
            let mut sim = PsSim::new(&corpus, hyper, cfg);
            let label = if disk { "ps-disk" } else { "ps-mem" };
            let mut s = Series::new(format!("fig5:{preset_name}:{label}"));
            s.push(0.0, log_likelihood(&sim.gather_state(&corpus)));
            for _ in 0..epochs {
                sim.run_epoch();
                s.push(sim.vtime_secs(), log_likelihood(&sim.gather_state(&corpus)));
            }
            eprintln!("  {label}: {:.2}s vtime, LL {:.4e}", sim.vtime_secs(), s.last_y().unwrap());
            all_series.push(s);
        }
    }

    // Fig 5c: nomad scaling on amazon-sim
    let corpus = preset("amazon-sim").unwrap();
    let hyper = Hyper::paper_default(topics);
    let mut scaling = Table::new(
        "Fig 5(c) — nomad scaling with cores (amazon-sim, 1 epoch)",
        &["cores", "vtime(s)", "speedup", "efficiency"],
    );
    let mut base = None;
    let mut scaling_series = Series::new("fig5c:amazon-sim:speedup".to_string());
    for c in [1usize, 2, 4, 8, 16, 20] {
        let mut cfg = NomadSimConfig::new(ClusterSpec::multicore(c), topics);
        cfg.cost = cost;
        let mut sim = NomadSim::new(&corpus, hyper, cfg);
        sim.run_epoch();
        let t = sim.vtime_secs();
        let b = *base.get_or_insert(t);
        scaling.row(vec![
            c.to_string(),
            format!("{t:.2}"),
            format!("{:.2}x", b / t),
            format!("{:.0}%", 100.0 * b / t / c as f64),
        ]);
        scaling_series.push(c as f64, b / t);
        eprintln!("  {c} cores: {t:.2}s");
    }
    all_series.push(scaling_series);

    // time-to-LL summary (the Fig-5a/b headline: "~4x faster")
    let mut headline = Table::new(
        "Fig 5(a,b) — virtual time to final-PS-quality LL",
        &["corpus", "system", "time-to-target (s)", "vs nomad"],
    );
    for preset_name in ["pubmed-sim", "amazon-sim"] {
        let target = all_series
            .iter()
            .find(|s| s.name == format!("fig5:{preset_name}:ps-mem"))
            .and_then(|s| s.last_y())
            .unwrap();
        let nomad_t = all_series
            .iter()
            .find(|s| s.name == format!("fig5:{preset_name}:nomad"))
            .and_then(|s| s.time_to_reach(target));
        for sys in ["nomad", "ps-mem", "ps-disk"] {
            let t = all_series
                .iter()
                .find(|s| s.name == format!("fig5:{preset_name}:{sys}"))
                .and_then(|s| s.time_to_reach(target));
            headline.row(vec![
                preset_name.into(),
                sys.into(),
                t.map(|x| format!("{x:.2}")).unwrap_or("n/a".into()),
                match (t, nomad_t) {
                    (Some(a), Some(b)) if b > 0.0 => format!("{:.1}x", a / b),
                    _ => "n/a".into(),
                },
            ]);
        }
    }
    headline.print();
    scaling.print();
    write_csv(std::path::Path::new("results/fig5_multicore.csv"), &all_series).unwrap();
    println!("\nwrote results/fig5_multicore.csv");
}
