//! Regenerates **Table 1**: measured init / generation / update cost of
//! the four multinomial samplers as T grows.
//!
//! Paper's asymptotics (what the shape must show):
//!   LSearch  : init Θ(T)   gen Θ(T)      update Θ(1)
//!   BSearch  : init Θ(T)   gen Θ(log T)  update Θ(T)
//!   Alias    : init Θ(T)   gen Θ(1)      update Θ(T)
//!   F+tree   : init Θ(T)   gen Θ(log T)  update Θ(log T)
//!
//!     cargo bench --bench table1_samplers

use fnomad_lda::sampler::{Alias, BSearch, DiscreteSampler, FTree, LSearch};
use fnomad_lda::util::bench::{fmt_ns, measure_ret, BenchOpts, Table};
use fnomad_lda::util::rng::Pcg32;
use std::hint::black_box;

fn params(t: usize, rng: &mut Pcg32) -> Vec<f64> {
    (0..t).map(|_| rng.next_f64() + 1e-3).collect()
}

fn bench_sampler<S: DiscreteSampler>(
    name: &str,
    t: usize,
    opts: BenchOpts,
    table: &mut Table,
) {
    let mut rng = Pcg32::seeded(t as u64);
    let p = params(t, &mut rng);

    let init = measure_ret(&format!("{name}/init"), opts, || S::build(&p));

    let s = S::build(&p);
    let mut gen_rng = Pcg32::seeded(1);
    let gen = measure_ret(&format!("{name}/gen"), opts, || {
        s.sample(gen_rng.uniform(s.total()))
    });

    let mut s = S::build(&p);
    let mut up_rng = Pcg32::seeded(2);
    let upd = measure_ret(&format!("{name}/update"), opts, || {
        let idx = up_rng.below(t);
        // alternate sign to keep parameters bounded
        let delta = if up_rng.next_f64() < 0.5 { 1e-4 } else { -1e-4 };
        s.update(idx, delta);
        black_box(s.total());
    });

    table.row(vec![
        name.to_string(),
        t.to_string(),
        fmt_ns(init.ns_per_op),
        fmt_ns(gen.ns_per_op),
        fmt_ns(upd.ns_per_op),
    ]);
}

fn main() {
    let opts = BenchOpts::default();
    let mut table = Table::new(
        "Table 1 — sampler cost vs T (measured)",
        &["sampler", "T", "init", "generate", "update"],
    );
    for &t in &[64usize, 256, 1024, 4096, 16384] {
        bench_sampler::<LSearch>("LSearch", t, opts, &mut table);
        bench_sampler::<BSearch>("BSearch", t, opts, &mut table);
        bench_sampler::<Alias>("Alias", t, opts, &mut table);
        bench_sampler::<FTree>("F+tree", t, opts, &mut table);
        eprintln!("  T={t} done");
    }
    table.print();
    println!(
        "\nShape check (paper Table 1): LSearch gen grows ~linearly in T while \
         F+tree/BSearch gen grow ~log T;\nAlias gen is ~flat; F+tree is the only \
         sampler whose UPDATE also stays ~log T (LSearch O(1), others O(T))."
    );
}
