//! Ablations over the design choices DESIGN.md calls out (not a paper
//! figure — the "what if we built it differently" sweeps):
//!
//!  A1  τ_s circulation count — the s-staleness knob of §4.1: fewer
//!      circulations = staler totals; quality should be flat (the paper's
//!      "this dependency is very weak" claim) while virtual time drops.
//!  A2  PS pull/push batch size — staleness vs server-pressure trade.
//!  A3  F+tree REBUILD_EVERY drift control — total drift after a long
//!      update stream, with and without periodic rebuilds.
//!  A4  partition balance — token-balanced vs naive doc-count split:
//!      last-reducer exposure of the bulk-sync baseline.
//!  A5  Minka hyperparameter optimization on/off (extension feature).
//!
//!     cargo bench --bench ablations

use fnomad_lda::corpus::presets::preset;
use fnomad_lda::corpus::Partition;
use fnomad_lda::lda::state::{Hyper, LdaState};
use fnomad_lda::lda::{hyper_opt, log_likelihood, FLdaWord, Sweep};
use fnomad_lda::sampler::{DiscreteSampler, FTree};
use fnomad_lda::simnet::nomad_sim::{NomadSim, NomadSimConfig};
use fnomad_lda::simnet::ps_sim::{PsSim, PsSimConfig};
use fnomad_lda::simnet::{ClusterSpec, CostModel};
use fnomad_lda::util::bench::Table;
use fnomad_lda::util::rng::Pcg32;

fn main() {
    let corpus = preset("tiny").unwrap();
    let hyper = Hyper::paper_default(16);
    let cost = CostModel::calibrate(&corpus, hyper, 1);

    // A1: τ_s circulations
    let mut a1 = Table::new(
        "A1 — τ_s circulations per epoch (nomad-sim, 8 cores, 4 epochs)",
        &["circulations", "vtime(s)", "final LL"],
    );
    for circ in [1u32, 2, 4, 8] {
        let mut cfg = NomadSimConfig::new(ClusterSpec::multicore(8), hyper.t);
        cfg.cost = cost;
        cfg.s_circulations = circ;
        cfg.seed = 7;
        let mut sim = NomadSim::new(&corpus, hyper, cfg);
        for _ in 0..4 {
            sim.run_epoch();
        }
        a1.row(vec![
            circ.to_string(),
            format!("{:.5}", sim.vtime_secs()),
            format!("{:.4e}", log_likelihood(&sim.gather_state(&corpus))),
        ]);
    }
    a1.print();

    // A2: PS batch size (staleness knob)
    let mut a2 = Table::new(
        "A2 — PS pull/push batch (docs) (ps-sim, 8 cores, 4 epochs)",
        &["batch_docs", "vtime(s)", "final LL"],
    );
    for batch in [1usize, 4, 16, 64] {
        let mut cfg = PsSimConfig::new(ClusterSpec::multicore(8), hyper.t);
        cfg.cost = cost;
        cfg.batch_docs = batch;
        cfg.seed = 7;
        let mut sim = PsSim::new(&corpus, hyper, cfg);
        for _ in 0..4 {
            sim.run_epoch();
        }
        a2.row(vec![
            batch.to_string(),
            format!("{:.5}", sim.vtime_secs()),
            format!("{:.4e}", log_likelihood(&sim.gather_state(&corpus))),
        ]);
    }
    a2.print();

    // A3: F+tree drift with vs without rebuild
    let mut a3 = Table::new(
        "A3 — F+tree drift after 10M cancelling updates (T=1024)",
        &["policy", "abs drift", "rel drift"],
    );
    for rebuild in [false, true] {
        let n = 1024;
        let p: Vec<f64> = (0..n).map(|i| 0.001 + (i % 17) as f64 * 0.01).collect();
        let mut tree = FTree::build(&p);
        let mut rng = Pcg32::seeded(1);
        for i in 0..10_000_000u64 {
            let idx = rng.below(n);
            tree.add(idx, 1e-7);
            tree.add(idx, -1e-7);
            if rebuild && i % 1_000_000 == 0 {
                tree.rebuild();
            }
        }
        if rebuild {
            tree.rebuild();
        }
        let drift = (tree.total() - tree.exact_total()).abs();
        a3.row(vec![
            if rebuild { "rebuild every 1M".into() } else { "never rebuild".to_string() },
            format!("{drift:.3e}"),
            format!("{:.3e}", drift / tree.exact_total()),
        ]);
    }
    a3.print();

    // A4: partition balance
    let mut a4 = Table::new(
        "A4 — partition balance (pubmed-sim, 20 workers)",
        &["policy", "max/mean token load", "last-reducer overhang"],
    );
    {
        let big = preset("pubmed-sim").unwrap();
        let balanced = Partition::by_tokens(&big, 20);
        let loads = balanced.loads(&big);
        let mean = loads.iter().sum::<usize>() as f64 / loads.len() as f64;
        let max = *loads.iter().max().unwrap() as f64;
        a4.row(vec![
            "token-balanced (ours)".into(),
            format!("{:.3}", max / mean),
            format!("{:.1}%", 100.0 * (max / mean - 1.0)),
        ]);
        // naive: equal doc counts
        let n = big.num_docs();
        let naive: Vec<(usize, usize)> =
            (0..20).map(|l| (l * n / 20, (l + 1) * n / 20)).collect();
        let naive_loads: Vec<usize> = naive
            .iter()
            .map(|&(s, e)| big.offsets()[e] - big.offsets()[s])
            .collect();
        let mean = naive_loads.iter().sum::<usize>() as f64 / naive_loads.len() as f64;
        let max = *naive_loads.iter().max().unwrap() as f64;
        a4.row(vec![
            "doc-count split".into(),
            format!("{:.3}", max / mean),
            format!("{:.1}%", 100.0 * (max / mean - 1.0)),
        ]);
    }
    a4.print();

    // A5: hyperparameter optimization
    let mut a5 = Table::new(
        "A5 — Minka hyperparameter optimization (tiny, T=16, 30 sweeps)",
        &["policy", "alpha", "beta", "final LL"],
    );
    for optimize in [false, true] {
        let mut rng = Pcg32::seeded(2);
        let mut state = LdaState::init_random(&corpus, hyper, &mut rng);
        let mut sampler = FLdaWord::new(&state, &corpus);
        for it in 0..30 {
            sampler.sweep(&mut state, &corpus, &mut rng);
            if optimize && it >= 10 && it % 5 == 0 {
                hyper_opt::optimize(&mut state, 3);
            }
        }
        a5.row(vec![
            if optimize { "optimized".into() } else { "paper-fixed".to_string() },
            format!("{:.4}", state.hyper.alpha),
            format!("{:.4}", state.hyper.beta),
            format!("{:.4e}", log_likelihood(&state)),
        ]);
    }
    a5.print();
    println!(
        "\nExpected: A1 quality flat across circulations (weak s-dependence, §4.1);\n\
         A2 larger batches slightly staler but cheaper; A3 rebuilds bound drift;\n\
         A4 token balancing flattens the last reducer; A5 moves (alpha, beta) off\n\
         the paper default (joint-LL values at different hyperparameters are not\n\
         directly comparable — the evidence objective is what the update ascends)."
    );
}
