//! Regenerates **Table 2** (measured): amortized per-token CGS cost of the
//! five LDA sampling strategies on Enron- and NyTimes-shaped corpora at
//! the paper's T=1024.
//!
//! Expected shape: flda-word ≈ Θ(|T_d| + log T) cheapest on the larger
//! corpus; flda-doc ≈ Θ(|T_w| + log T); sparse ≈ Θ(|T_w| + |T_d|);
//! alias ≈ Θ(|T_d| + #MH) with a large alias-rebuild constant; plain = Θ(T).
//!
//!     cargo bench --bench table2_lda_step

use fnomad_lda::corpus::preset;
use fnomad_lda::lda;
use fnomad_lda::lda::state::{Hyper, LdaState};
use fnomad_lda::util::bench::{fmt_ns, Table};
use fnomad_lda::util::rng::Pcg32;

fn main() {
    let topics = 1024;
    let mut table = Table::new(
        "Table 2 — amortized ns/token at T=1024 (measured, post-burn-in sweep)",
        &["corpus", "sampler", "ns/token", "tokens/s", "vs plain"],
    );
    for preset_name in ["enron-sim", "nytimes-sim"] {
        let corpus = preset(preset_name).unwrap();
        eprintln!(
            "{preset_name}: {} docs / {} tokens",
            corpus.num_docs(),
            corpus.num_tokens()
        );
        // shared burn-in: converge the state with the fast sampler so every
        // variant is measured at the SAME realistic |T_d|/|T_w| sparsity
        // (the paper measures post-burn-in iterations too)
        let burned = {
            let mut rng = Pcg32::seeded(2015);
            let mut state =
                LdaState::init_random(&corpus, Hyper::paper_default(topics), &mut rng);
            let mut s = lda::FLdaWord::new(&state, &corpus);
            for _ in 0..5 {
                lda::Sweep::sweep(&mut s, &mut state, &corpus, &mut rng);
            }
            state
        };
        let mut plain_ns = None;
        for name in lda::VARIANTS {
            let mut rng = Pcg32::seeded(2016);
            let mut state = burned.clone();
            let mut sampler = lda::by_name(name, &state, &corpus).unwrap();
            let t0 = std::time::Instant::now();
            sampler.sweep(&mut state, &corpus, &mut rng);
            let ns = t0.elapsed().as_nanos() as f64 / corpus.num_tokens() as f64;
            if *name == "plain" {
                plain_ns = Some(ns);
            }
            let speedup = plain_ns.map(|p| format!("{:.1}x", p / ns)).unwrap_or_default();
            table.row(vec![
                preset_name.to_string(),
                name.to_string(),
                fmt_ns(ns),
                format!("{:.0}", 1e9 / ns),
                speedup,
            ]);
            eprintln!("  {name}: {}", fmt_ns(ns));
        }
    }
    table.print();
    println!(
        "\nShape check (paper Table 2 / Fig. 4c-d): every sparse strategy beats \
         plain O(T) by ~an order of magnitude at T=1024;\nflda-word is the \
         fastest on the larger (nytimes-shaped) corpus."
    );
}
