//! Regenerates **Figure 4**: (a,b) training LL vs iteration for the five
//! serial samplers on Enron- and NyTimes-shaped corpora; (c,d) sampling
//! speedup over plain O(T) LDA per iteration.
//!
//! Expected shape: all exact samplers share one convergence curve per
//! iteration (AliasLDA trails slightly — it is an MH approximation);
//! F+LDA(doc) beats Sparse/Alias in speed, and F+LDA(word) beats
//! F+LDA(doc) on the corpus with more documents.
//!
//! Writes results/fig4_convergence.csv (long format: series,x,y).
//!
//!     cargo bench --bench fig4_serial_convergence

use fnomad_lda::corpus::preset;
use fnomad_lda::coordinator::{EvalPolicy, Evaluator};
use fnomad_lda::lda;
use fnomad_lda::lda::state::{Hyper, LdaState};
use fnomad_lda::util::bench::Table;
use fnomad_lda::util::metrics::{write_csv, Series};
use fnomad_lda::util::rng::Pcg32;

fn main() {
    let topics = 1024;
    let runs = [("enron-sim", 12usize), ("nytimes-sim", 4usize)];
    let mut all_series: Vec<Series> = Vec::new();
    let mut speed = Table::new(
        "Fig 4(c,d) — per-iteration sampling speedup over plain O(T) LDA",
        &["corpus", "sampler", "sec/iter", "speedup"],
    );

    for (preset_name, iters) in runs {
        let corpus = preset(preset_name).unwrap();
        let mut eval = Evaluator::resolve(EvalPolicy::Auto, topics).unwrap();
        eprintln!(
            "{preset_name}: {} docs / {} tokens, T={topics}, eval={}",
            corpus.num_docs(),
            corpus.num_tokens(),
            eval.name()
        );
        let mut plain_secs = None;
        for name in lda::VARIANTS {
            let mut rng = Pcg32::seeded(41);
            let mut state =
                LdaState::init_random(&corpus, Hyper::paper_default(topics), &mut rng);
            let mut sampler = lda::by_name(name, &state, &corpus).unwrap();
            let mut series = Series::new(format!("fig4:{preset_name}:{name}"));
            series.push(0.0, eval.log_likelihood(&state).unwrap());
            let mut secs = 0.0;
            for it in 1..=iters {
                let t0 = std::time::Instant::now();
                sampler.sweep(&mut state, &corpus, &mut rng);
                secs += t0.elapsed().as_secs_f64();
                series.push(it as f64, eval.log_likelihood(&state).unwrap());
            }
            let per_iter = secs / iters as f64;
            if *name == "plain" {
                plain_secs = Some(per_iter);
            }
            speed.row(vec![
                preset_name.into(),
                name.to_string(),
                format!("{per_iter:.3}"),
                plain_secs
                    .map(|p| format!("{:.1}x", p / per_iter))
                    .unwrap_or_default(),
            ]);
            eprintln!("  {name}: {per_iter:.3}s/iter, final LL {:.4e}", series.last_y().unwrap());
            all_series.push(series);
        }
    }

    // Fig 4(a,b): the convergence table, one row per (corpus, sampler)
    let mut conv = Table::new(
        "Fig 4(a,b) — LL by iteration (first/mid/final)",
        &["series", "iter0", "mid", "final"],
    );
    for s in &all_series {
        let mid = s.points[s.points.len() / 2];
        conv.row(vec![
            s.name.clone(),
            format!("{:.4e}", s.points[0].1),
            format!("{:.4e}", mid.1),
            format!("{:.4e}", s.last_y().unwrap()),
        ]);
    }
    conv.print();
    speed.print();
    write_csv(std::path::Path::new("results/fig4_convergence.csv"), &all_series).unwrap();
    println!("\nwrote results/fig4_convergence.csv");
    println!(
        "Shape check: exact samplers within a hair of each other per iteration, \
         alias slightly behind;\nF+LDA variants fastest; flda-word > flda-doc on \
         nytimes-sim (more docs)."
    );
}
