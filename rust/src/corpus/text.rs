//! Text preprocessing pipeline: tokenizer, stop-word filter, and the
//! Porter stemmer [Porter 1980] — the same preprocessing the paper applies
//! to the Amazon and UMBC corpora ("split the text into words, removed
//! stop words, and using Porter stemming", §5), plus the rare-term
//! thresholds ("discarded words that appear fewer than 5 times or in 5
//! reviews").

use std::collections::HashMap;
use std::io::{BufRead, BufReader};
use std::path::Path;

use super::{disk::FncorpusSummary, Corpus, FncorpusWriter};

/// Lowercasing alphabetic tokenizer: maximal runs of ASCII letters.
pub fn tokenize(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    for ch in text.chars() {
        if ch.is_ascii_alphabetic() {
            cur.push(ch.to_ascii_lowercase());
        } else if !cur.is_empty() {
            out.push(std::mem::take(&mut cur));
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

/// SMART-style English stop list (the high-frequency core).
pub const STOP_WORDS: &[&str] = &[
    "a", "about", "above", "after", "again", "against", "all", "am", "an", "and", "any", "are",
    "as", "at", "be", "because", "been", "before", "being", "below", "between", "both", "but",
    "by", "can", "cannot", "could", "did", "do", "does", "doing", "down", "during", "each",
    "few", "for", "from", "further", "had", "has", "have", "having", "he", "her", "here",
    "hers", "herself", "him", "himself", "his", "how", "i", "if", "in", "into", "is", "it",
    "its", "itself", "just", "me", "more", "most", "my", "myself", "no", "nor", "not", "now",
    "of", "off", "on", "once", "only", "or", "other", "our", "ours", "ourselves", "out",
    "over", "own", "same", "she", "should", "so", "some", "such", "than", "that", "the",
    "their", "theirs", "them", "themselves", "then", "there", "these", "they", "this",
    "those", "through", "to", "too", "under", "until", "up", "very", "was", "we", "were",
    "what", "when", "where", "which", "while", "who", "whom", "why", "will", "with", "would",
    "you", "your", "yours", "yourself", "yourselves",
];

pub fn is_stop_word(w: &str) -> bool {
    STOP_WORDS.binary_search(&w).is_ok()
}

// ---------------------------------------------------------------------- //
// Porter stemmer (Porter 1980, "An algorithm for suffix stripping")       //
// ---------------------------------------------------------------------- //

/// Stem a lowercase ASCII word with the classic Porter algorithm.
pub fn porter_stem(word: &str) -> String {
    let mut b: Vec<u8> = word.bytes().collect();
    if b.len() <= 2 {
        return word.to_string();
    }
    step1a(&mut b);
    step1b(&mut b);
    step1c(&mut b);
    step2(&mut b);
    step3(&mut b);
    step4(&mut b);
    step5a(&mut b);
    step5b(&mut b);
    String::from_utf8(b).unwrap()
}

/// Is b[i] a consonant under Porter's definition?
fn is_cons(b: &[u8], i: usize) -> bool {
    match b[i] {
        b'a' | b'e' | b'i' | b'o' | b'u' => false,
        b'y' => i == 0 || !is_cons(b, i - 1),
        _ => true,
    }
}

/// Porter's measure m of b[..len]: number of VC sequences.
fn measure(b: &[u8], len: usize) -> usize {
    let mut m = 0;
    let mut i = 0;
    // skip initial consonants
    while i < len && is_cons(b, i) {
        i += 1;
    }
    loop {
        // skip vowels
        let mut saw_v = false;
        while i < len && !is_cons(b, i) {
            i += 1;
            saw_v = true;
        }
        if !saw_v || i >= len {
            return m;
        }
        // skip consonants -> one VC
        while i < len && is_cons(b, i) {
            i += 1;
        }
        m += 1;
    }
}

fn has_vowel(b: &[u8], len: usize) -> bool {
    (0..len).any(|i| !is_cons(b, i))
}

/// stem ends with double consonant
fn double_cons(b: &[u8]) -> bool {
    let n = b.len();
    n >= 2 && b[n - 1] == b[n - 2] && is_cons(b, n - 1)
}

/// consonant-vowel-consonant ending, final consonant not w, x, y
fn cvc(b: &[u8], len: usize) -> bool {
    if len < 3 {
        return false;
    }
    let (i, j, k) = (len - 3, len - 2, len - 1);
    is_cons(b, i)
        && !is_cons(b, j)
        && is_cons(b, k)
        && !matches!(b[k], b'w' | b'x' | b'y')
}

fn ends_with(b: &[u8], suf: &str) -> bool {
    b.len() >= suf.len() && &b[b.len() - suf.len()..] == suf.as_bytes()
}

/// If word ends with `suf` and measure(stem) > m_min, replace with `rep`.
fn replace_if_m(b: &mut Vec<u8>, suf: &str, rep: &str, m_min: usize) -> bool {
    if ends_with(b, suf) {
        let stem_len = b.len() - suf.len();
        if measure(b, stem_len) > m_min {
            b.truncate(stem_len);
            b.extend_from_slice(rep.as_bytes());
            return true;
        }
    }
    false
}

fn step1a(b: &mut Vec<u8>) {
    if ends_with(b, "sses") || ends_with(b, "ies") {
        b.truncate(b.len() - 2);
    } else if ends_with(b, "ss") {
        // keep
    } else if ends_with(b, "s") {
        b.truncate(b.len() - 1);
    }
}

fn step1b(b: &mut Vec<u8>) {
    let mut cleanup = false;
    if ends_with(b, "eed") {
        if measure(b, b.len() - 3) > 0 {
            b.truncate(b.len() - 1);
        }
    } else if ends_with(b, "ed") && has_vowel(b, b.len() - 2) {
        b.truncate(b.len() - 2);
        cleanup = true;
    } else if ends_with(b, "ing") && has_vowel(b, b.len() - 3) {
        b.truncate(b.len() - 3);
        cleanup = true;
    }
    if cleanup {
        if ends_with(b, "at") || ends_with(b, "bl") || ends_with(b, "iz") {
            b.push(b'e');
        } else if double_cons(b) && !matches!(b[b.len() - 1], b'l' | b's' | b'z') {
            b.truncate(b.len() - 1);
        } else if measure(b, b.len()) == 1 && cvc(b, b.len()) {
            b.push(b'e');
        }
    }
}

fn step1c(b: &mut Vec<u8>) {
    if ends_with(b, "y") && has_vowel(b, b.len() - 1) {
        let n = b.len();
        b[n - 1] = b'i';
    }
}

fn step2(b: &mut Vec<u8>) {
    const RULES: &[(&str, &str)] = &[
        ("ational", "ate"),
        ("tional", "tion"),
        ("enci", "ence"),
        ("anci", "ance"),
        ("izer", "ize"),
        ("abli", "able"),
        ("alli", "al"),
        ("entli", "ent"),
        ("eli", "e"),
        ("ousli", "ous"),
        ("ization", "ize"),
        ("ation", "ate"),
        ("ator", "ate"),
        ("alism", "al"),
        ("iveness", "ive"),
        ("fulness", "ful"),
        ("ousness", "ous"),
        ("aliti", "al"),
        ("iviti", "ive"),
        ("biliti", "ble"),
    ];
    for (suf, rep) in RULES {
        if ends_with(b, suf) {
            replace_if_m(b, suf, rep, 0);
            return;
        }
    }
}

fn step3(b: &mut Vec<u8>) {
    const RULES: &[(&str, &str)] = &[
        ("icate", "ic"),
        ("ative", ""),
        ("alize", "al"),
        ("iciti", "ic"),
        ("ical", "ic"),
        ("ful", ""),
        ("ness", ""),
    ];
    for (suf, rep) in RULES {
        if ends_with(b, suf) {
            replace_if_m(b, suf, rep, 0);
            return;
        }
    }
}

fn step4(b: &mut Vec<u8>) {
    const SUFFIXES: &[&str] = &[
        "al", "ance", "ence", "er", "ic", "able", "ible", "ant", "ement", "ment", "ent",
        "ou", "ism", "ate", "iti", "ous", "ive", "ize",
    ];
    // special-case "ion": requires stem ending s or t
    if ends_with(b, "ion") {
        let stem_len = b.len() - 3;
        if stem_len > 0 && matches!(b[stem_len - 1], b's' | b't') && measure(b, stem_len) > 1 {
            b.truncate(stem_len);
        }
        return;
    }
    for suf in SUFFIXES {
        if ends_with(b, suf) {
            replace_if_m(b, suf, "", 1);
            return;
        }
    }
}

fn step5a(b: &mut Vec<u8>) {
    if ends_with(b, "e") {
        let stem_len = b.len() - 1;
        let m = measure(b, stem_len);
        if m > 1 || (m == 1 && !cvc(b, stem_len)) {
            b.truncate(stem_len);
        }
    }
}

fn step5b(b: &mut Vec<u8>) {
    if measure(b, b.len()) > 1 && double_cons(b) && b[b.len() - 1] == b'l' {
        b.truncate(b.len() - 1);
    }
}

// ---------------------------------------------------------------------- //
// Whole-pipeline corpus builder                                           //
// ---------------------------------------------------------------------- //

/// Pipeline configuration mirroring the paper's Amazon preprocessing.
#[derive(Clone, Debug)]
pub struct PipelineOpts {
    pub stem: bool,
    pub remove_stop_words: bool,
    /// drop words occurring fewer than this many times in total
    pub min_count: usize,
    /// drop words occurring in fewer than this many documents
    pub min_docs: usize,
}

impl Default for PipelineOpts {
    fn default() -> Self {
        PipelineOpts { stem: true, remove_stop_words: true, min_count: 5, min_docs: 5 }
    }
}

/// One document's tokens after normalization (tokenize, stop-word
/// filter, stemming, short-token drop) — the shared front half of the
/// in-RAM and streaming builders.
fn normalize(text: &str, opts: &PipelineOpts) -> Vec<String> {
    let mut toks = Vec::new();
    for tok in tokenize(text) {
        if opts.remove_stop_words && is_stop_word(&tok) {
            continue;
        }
        let tok = if opts.stem { porter_stem(&tok) } else { tok };
        if tok.len() < 2 {
            continue;
        }
        toks.push(tok);
    }
    toks
}

/// Update term/document frequency maps with one normalized document.
fn count_terms(
    toks: &[String],
    total_count: &mut HashMap<String, usize>,
    doc_count: &mut HashMap<String, usize>,
) {
    let mut uniq: Vec<&String> = toks.iter().collect();
    uniq.sort_unstable();
    uniq.dedup();
    for w in uniq {
        *doc_count.entry(w.clone()).or_insert(0) += 1;
    }
    for w in toks {
        *total_count.entry(w.clone()).or_insert(0) += 1;
    }
}

/// The vocabulary surviving the frequency thresholds, sorted.
fn surviving_vocab(
    total_count: &HashMap<String, usize>,
    doc_count: &HashMap<String, usize>,
    opts: &PipelineOpts,
) -> Vec<String> {
    let mut vocab_words: Vec<String> = total_count
        .iter()
        .filter(|(w, &c)| c >= opts.min_count && doc_count[*w] >= opts.min_docs)
        .map(|(w, _)| w.clone())
        .collect();
    vocab_words.sort_unstable();
    vocab_words
}

/// Build a [`Corpus`] from raw document texts.  Documents left empty after
/// preprocessing are discarded (as the paper does).
pub fn build_corpus(texts: &[String], opts: &PipelineOpts, name: &str) -> Corpus {
    // pass 1: tokenize + normalize, count frequencies
    let mut processed: Vec<Vec<String>> = Vec::with_capacity(texts.len());
    let mut total_count: HashMap<String, usize> = HashMap::new();
    let mut doc_count: HashMap<String, usize> = HashMap::new();
    for text in texts {
        let toks = normalize(text, opts);
        count_terms(&toks, &mut total_count, &mut doc_count);
        processed.push(toks);
    }
    // pass 2: build vocab over surviving words, then map docs to ids
    let vocab_words = surviving_vocab(&total_count, &doc_count, opts);
    let index: HashMap<&String, u32> =
        vocab_words.iter().enumerate().map(|(i, w)| (w, i as u32)).collect();
    let mut corpus =
        Corpus::with_meta(vocab_words.len(), Vec::new(), name.to_string());
    for toks in &processed {
        let ids: Vec<u32> = toks.iter().filter_map(|w| index.get(w).copied()).collect();
        if !ids.is_empty() {
            corpus.push_doc(&ids);
        }
    }
    corpus.vocab_words = vocab_words;
    corpus
}

/// Stream a newline-delimited text file (one document per line) into an
/// `FNCP0001` corpus with bounded memory: pass 1 scans the file to count
/// term/document frequencies (`O(vocab)` RAM), pass 2 re-normalizes each
/// line and appends its ids straight to the streaming writer — no
/// in-RAM token array at any point.  Returns the write summary and the
/// number of documents dropped for being empty after preprocessing.
pub fn stream_lines_to_fncorpus(
    input: &Path,
    opts: &PipelineOpts,
    name: &str,
    dest: &Path,
) -> Result<(FncorpusSummary, usize), String> {
    let open = || -> Result<BufReader<std::fs::File>, String> {
        std::fs::File::open(input)
            .map(BufReader::new)
            .map_err(|e| format!("{}: {e}", input.display()))
    };
    let mut total_count: HashMap<String, usize> = HashMap::new();
    let mut doc_count: HashMap<String, usize> = HashMap::new();
    for line in open()?.lines() {
        let line = line.map_err(|e| format!("{}: {e}", input.display()))?;
        let toks = normalize(&line, opts);
        count_terms(&toks, &mut total_count, &mut doc_count);
    }
    let vocab_words = surviving_vocab(&total_count, &doc_count, opts);
    let index: HashMap<String, u32> = vocab_words
        .iter()
        .enumerate()
        .map(|(i, w)| (w.clone(), i as u32))
        .collect();
    let mut writer = FncorpusWriter::create(dest, index.len(), vocab_words, name)?;
    let mut skipped = 0usize;
    for line in open()?.lines() {
        let line = line.map_err(|e| format!("{}: {e}", input.display()))?;
        let ids: Vec<u32> = normalize(&line, opts)
            .iter()
            .filter_map(|w| index.get(w).copied())
            .collect();
        if ids.is_empty() {
            skipped += 1;
        } else {
            writer.push_doc(&ids)?;
        }
    }
    let summary = writer.finish()?;
    Ok((summary, skipped))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenizer_splits_and_lowercases() {
        assert_eq!(tokenize("Hello, WORLD!  42 foo-bar"), vec!["hello", "world", "foo", "bar"]);
        assert!(tokenize("123 !!").is_empty());
    }

    #[test]
    fn stop_words_sorted_for_binary_search() {
        let mut sorted = STOP_WORDS.to_vec();
        sorted.sort_unstable();
        assert_eq!(sorted, STOP_WORDS, "STOP_WORDS must stay sorted");
        assert!(is_stop_word("the"));
        assert!(!is_stop_word("topic"));
    }

    #[test]
    fn porter_reference_pairs() {
        // Canonical examples from Porter's paper + the standard test vocab.
        for (w, want) in [
            ("caresses", "caress"),
            ("ponies", "poni"),
            ("ties", "ti"),
            ("caress", "caress"),
            ("cats", "cat"),
            ("feed", "feed"),
            ("agreed", "agre"),
            ("plastered", "plaster"),
            ("bled", "bled"),
            ("motoring", "motor"),
            ("sing", "sing"),
            ("conflated", "conflat"),
            ("troubled", "troubl"),
            ("sized", "size"),
            ("hopping", "hop"),
            ("tanned", "tan"),
            ("falling", "fall"),
            ("hissing", "hiss"),
            ("fizzed", "fizz"),
            ("failing", "fail"),
            ("filing", "file"),
            ("happy", "happi"),
            ("sky", "sky"),
            ("relational", "relat"),
            ("conditional", "condit"),
            ("rational", "ration"),
            ("valenci", "valenc"),
            ("digitizer", "digit"),
            ("conformabli", "conform"),
            ("radicalli", "radic"),
            ("differentli", "differ"),
            ("vileli", "vile"),
            ("analogousli", "analog"),
            ("vietnamization", "vietnam"),
            ("predication", "predic"),
            ("operator", "oper"),
            ("feudalism", "feudal"),
            ("decisiveness", "decis"),
            ("hopefulness", "hope"),
            ("callousness", "callous"),
            ("formaliti", "formal"),
            ("sensitiviti", "sensit"),
            ("sensibiliti", "sensibl"),
            ("triplicate", "triplic"),
            ("formative", "form"),
            ("formalize", "formal"),
            ("electriciti", "electr"),
            ("electrical", "electr"),
            ("hopeful", "hope"),
            ("goodness", "good"),
            ("revival", "reviv"),
            ("allowance", "allow"),
            ("inference", "infer"),
            ("airliner", "airlin"),
            ("gyroscopic", "gyroscop"),
            ("adjustable", "adjust"),
            ("defensible", "defens"),
            ("irritant", "irrit"),
            ("replacement", "replac"),
            ("adjustment", "adjust"),
            ("dependent", "depend"),
            ("adoption", "adopt"),
            ("homologou", "homolog"),
            ("communism", "commun"),
            ("activate", "activ"),
            ("angulariti", "angular"),
            ("homologous", "homolog"),
            ("effective", "effect"),
            ("bowdlerize", "bowdler"),
            ("probate", "probat"),
            ("rate", "rate"),
            ("cease", "ceas"),
            ("controll", "control"),
            ("roll", "roll"),
        ] {
            assert_eq!(porter_stem(w), want, "stem({w})");
        }
    }

    #[test]
    fn stemming_is_idempotent_on_stems() {
        for w in ["topic", "model", "comput", "scalabl"] {
            assert_eq!(porter_stem(&porter_stem(w)), porter_stem(w));
        }
    }

    #[test]
    fn pipeline_end_to_end() {
        let texts = vec![
            "The quick brown foxes are running and jumping over the lazy dogs".to_string(),
            "Foxes run. Dogs jump. Foxes and dogs are animals.".to_string(),
            "Running dogs chase jumping foxes in the park".to_string(),
            "dogs dogs dogs foxes foxes running".to_string(),
            "a fox and a dog run in the park".to_string(),
        ];
        let opts = PipelineOpts { min_count: 2, min_docs: 2, ..Default::default() };
        let c = build_corpus(&texts, &opts, "pipe");
        c.validate().unwrap();
        assert!(c.vocab > 0);
        // 'fox'/'dog' stems survive the frequency thresholds
        assert!(c.vocab_words.iter().any(|w| w == "fox"));
        assert!(c.vocab_words.iter().any(|w| w == "dog"));
        // stop words are gone
        assert!(!c.vocab_words.iter().any(|w| w == "the"));
    }

    #[test]
    fn pipeline_drops_empty_docs() {
        let texts = vec!["rare".to_string(), "common common common common common".to_string()];
        let opts = PipelineOpts { min_count: 3, min_docs: 1, ..Default::default() };
        let c = build_corpus(&texts, &opts, "drop");
        assert_eq!(c.num_docs(), 1);
    }

    #[test]
    fn streamed_pipeline_matches_in_ram_builder() {
        let texts = vec![
            "The quick brown foxes are running and jumping over the lazy dogs".to_string(),
            "Foxes run. Dogs jump. Foxes and dogs are animals.".to_string(),
            "Running dogs chase jumping foxes in the park".to_string(),
            "dogs dogs dogs foxes foxes running".to_string(),
            "only rare words here".to_string(),
            "a fox and a dog run in the park".to_string(),
        ];
        let opts = PipelineOpts { min_count: 2, min_docs: 2, ..Default::default() };
        let in_ram = build_corpus(&texts, &opts, "pipe");

        let dir = std::env::temp_dir().join("fnomad_text_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join(format!("lines-{}.txt", std::process::id()));
        let dest = dir.join(format!("lines-{}.fncorpus", std::process::id()));
        std::fs::write(&input, texts.join("\n")).unwrap();

        let (summary, skipped) =
            stream_lines_to_fncorpus(&input, &opts, "pipe", &dest).unwrap();
        assert_eq!(summary.num_docs, in_ram.num_docs());
        assert_eq!(summary.num_tokens, in_ram.num_tokens());
        // "only rare words here" normalizes to terms below the thresholds
        assert_eq!(skipped, texts.len() - in_ram.num_docs());

        let streamed = Corpus::load_fncorpus_ram(&dest).unwrap();
        assert_eq!(streamed.tokens_vec(), in_ram.tokens_vec());
        assert_eq!(streamed.offsets(), in_ram.offsets());
        assert_eq!(streamed.vocab(), in_ram.vocab());
        assert_eq!(streamed.vocab_words(), in_ram.vocab_words());
        let _ = std::fs::remove_file(&input);
        let _ = std::fs::remove_file(&dest);
    }
}
