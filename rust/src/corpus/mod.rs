//! Corpus substrate: document storage, UCI bag-of-words IO, text
//! preprocessing (tokenizer + stop words + Porter stemmer), synthetic
//! corpus generation, dataset presets and worker partitioning.
//!
//! # Memory layout (CSR)
//!
//! The canonical in-memory form is a token-expanded **flat CSR** layout:
//! one contiguous `tokens` array holding the word id of every occurrence,
//! documents back to back, plus a `doc_offsets` prefix-sum array so that
//! document `i` is the slice `tokens[doc_offsets[i]..doc_offsets[i + 1]]`.
//! The latent-variable array `z` ([`crate::lda::LdaState`]) is a flat
//! `Vec<u16>` sharing the *same* offsets, so `(doc, pos)` maps to the one
//! flat index `doc_offsets[doc] + pos` on both sides.
//!
//! Invariants (checked by [`Corpus::validate`]):
//!
//! * `doc_offsets.len() == num_docs() + 1`, `doc_offsets[0] == 0`,
//!   `doc_offsets` is strictly increasing (no empty documents), and
//!   `*doc_offsets.last() == tokens.len()`;
//! * every entry of `tokens` is `< vocab`.
//!
//! Why flat: at the paper's scale (millions of documents, billions of
//! tokens) a `Vec<Vec<u32>>` costs one heap allocation plus 24 bytes of
//! `Vec` header per document and pointer-chases on every sweep; the CSR
//! form is two allocations total, iterates at memcpy speed, and lets
//! workers copy their document range with a single `extend_from_slice`.
//!
//! Word-major access for word-by-word sampling (F+LDA(word), Nomad
//! subtasks `t_j`) goes through [`WordIndex`], which is CSR over the same
//! `tokens` payload sorted by word id.

pub mod bow;
pub mod partition;
pub mod presets;
pub mod stats;
pub mod synthetic;
pub mod text;

pub use partition::Partition;
pub use presets::preset;
pub use stats::CorpusStats;

/// A token-expanded bag-of-words corpus in flat CSR form (see the module
/// docs for the layout and its invariants).
#[derive(Clone, Debug)]
pub struct Corpus {
    /// vocabulary id of every occurrence, documents back to back
    pub tokens: Vec<u32>,
    /// `doc_offsets[i]..doc_offsets[i+1]` is document i's slice
    pub doc_offsets: Vec<usize>,
    /// vocabulary size J (ids are `0..vocab`)
    pub vocab: usize,
    /// optional vocabulary strings (empty when synthetic/anonymous)
    pub vocab_words: Vec<String>,
    /// dataset label for logging
    pub name: String,
}

impl Default for Corpus {
    fn default() -> Self {
        Corpus {
            tokens: Vec::new(),
            doc_offsets: vec![0],
            vocab: 0,
            vocab_words: Vec::new(),
            name: String::new(),
        }
    }
}

impl Corpus {
    /// Empty corpus with metadata only (documents appended via
    /// [`Self::push_doc`]).
    pub fn with_meta(vocab: usize, vocab_words: Vec<String>, name: String) -> Self {
        Corpus { tokens: Vec::new(), doc_offsets: vec![0], vocab, vocab_words, name }
    }

    /// Flatten nested per-document token lists into the CSR layout.
    pub fn from_docs(
        docs: Vec<Vec<u32>>,
        vocab: usize,
        vocab_words: Vec<String>,
        name: String,
    ) -> Self {
        let mut c = Corpus::with_meta(vocab, vocab_words, name);
        c.tokens.reserve(docs.iter().map(|d| d.len()).sum());
        c.doc_offsets.reserve(docs.len());
        for d in &docs {
            c.push_doc(d);
        }
        c
    }

    /// Append one document (its word ids, in occurrence order).
    pub fn push_doc(&mut self, toks: &[u32]) {
        self.tokens.extend_from_slice(toks);
        self.doc_offsets.push(self.tokens.len());
    }

    /// Number of documents I.
    #[inline]
    pub fn num_docs(&self) -> usize {
        self.doc_offsets.len() - 1
    }

    /// Total token count Σ_i n_i (O(1) under CSR).
    #[inline]
    pub fn num_tokens(&self) -> usize {
        self.tokens.len()
    }

    /// Document i as a token slice.
    #[inline]
    pub fn doc(&self, i: usize) -> &[u32] {
        &self.tokens[self.doc_offsets[i]..self.doc_offsets[i + 1]]
    }

    /// Length of document i (O(1)).
    #[inline]
    pub fn doc_len(&self, i: usize) -> usize {
        self.doc_offsets[i + 1] - self.doc_offsets[i]
    }

    /// Iterate documents in order as token slices.
    #[inline]
    pub fn docs(&self) -> impl Iterator<Item = &[u32]> + '_ {
        self.doc_offsets.windows(2).map(move |w| &self.tokens[w[0]..w[1]])
    }

    /// Validate structural invariants (CSR shape, every id < vocab, no
    /// empty docs).
    pub fn validate(&self) -> Result<(), String> {
        if self.doc_offsets.first() != Some(&0) {
            return Err("doc_offsets must start at 0".into());
        }
        if *self.doc_offsets.last().unwrap() != self.tokens.len() {
            return Err(format!(
                "doc_offsets ends at {}, tokens.len() is {}",
                self.doc_offsets.last().unwrap(),
                self.tokens.len()
            ));
        }
        for (i, w) in self.doc_offsets.windows(2).enumerate() {
            if w[1] <= w[0] {
                return Err(format!("document {i} is empty"));
            }
        }
        for (at, &w) in self.tokens.iter().enumerate() {
            if w as usize >= self.vocab {
                let i = self.doc_of_token(at);
                return Err(format!("doc {i}: word id {w} >= vocab {}", self.vocab));
            }
        }
        if !self.vocab_words.is_empty() && self.vocab_words.len() != self.vocab {
            return Err(format!(
                "vocab_words len {} != vocab {}",
                self.vocab_words.len(),
                self.vocab
            ));
        }
        Ok(())
    }

    /// Which document the flat token index `at` belongs to (diagnostics).
    fn doc_of_token(&self, at: usize) -> usize {
        self.doc_offsets.partition_point(|&o| o <= at) - 1
    }

    /// Build the word-major occurrence index.
    pub fn word_index(&self) -> WordIndex {
        WordIndex::build(self)
    }
}

/// Word-major view: for each vocabulary id, the (doc, position) of every
/// occurrence.  This is the unit-subtask structure of the Nomad framework —
/// subtask `t_j` is exactly `occurrences(j)` restricted to a worker's
/// document partition.
#[derive(Clone, Debug, Default)]
pub struct WordIndex {
    /// CSR-style: occurrence array sorted by word id
    pub doc_of: Vec<u32>,
    pub pos_of: Vec<u32>,
    /// offsets[j]..offsets[j+1] is word j's slice
    pub offsets: Vec<usize>,
}

impl WordIndex {
    pub fn build(corpus: &Corpus) -> Self {
        let mut counts = vec![0usize; corpus.vocab + 1];
        for &w in &corpus.tokens {
            counts[w as usize + 1] += 1;
        }
        for j in 1..counts.len() {
            counts[j] += counts[j - 1];
        }
        let offsets = counts.clone();
        let total = *offsets.last().unwrap();
        let mut doc_of = vec![0u32; total];
        let mut pos_of = vec![0u32; total];
        let mut cursor = offsets.clone();
        for (i, d) in corpus.docs().enumerate() {
            for (p, &w) in d.iter().enumerate() {
                let at = cursor[w as usize];
                doc_of[at] = i as u32;
                pos_of[at] = p as u32;
                cursor[w as usize] += 1;
            }
        }
        WordIndex { doc_of, pos_of, offsets }
    }

    /// All occurrences of word j as parallel (doc, pos) slices.
    #[inline]
    pub fn occurrences(&self, j: usize) -> (&[u32], &[u32]) {
        let lo = self.offsets[j];
        let hi = self.offsets[j + 1];
        (&self.doc_of[lo..hi], &self.pos_of[lo..hi])
    }

    /// Occurrence count of word j.
    #[inline]
    pub fn count(&self, j: usize) -> usize {
        self.offsets[j + 1] - self.offsets[j]
    }

    pub fn num_words(&self) -> usize {
        self.offsets.len() - 1
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    pub(crate) fn tiny() -> Corpus {
        Corpus::from_docs(
            vec![vec![0, 1, 1, 2], vec![2, 2, 3], vec![0, 3]],
            4,
            vec![],
            "tiny".into(),
        )
    }

    #[test]
    fn counts() {
        let c = tiny();
        assert_eq!(c.num_docs(), 3);
        assert_eq!(c.num_tokens(), 9);
        c.validate().unwrap();
    }

    #[test]
    fn csr_layout_shape() {
        let c = tiny();
        assert_eq!(c.doc_offsets, vec![0, 4, 7, 9]);
        assert_eq!(c.tokens, vec![0, 1, 1, 2, 2, 2, 3, 0, 3]);
        assert_eq!(c.doc(0), &[0, 1, 1, 2]);
        assert_eq!(c.doc(1), &[2, 2, 3]);
        assert_eq!(c.doc(2), &[0, 3]);
        assert_eq!(c.doc_len(1), 3);
        let collected: Vec<&[u32]> = c.docs().collect();
        assert_eq!(collected.len(), 3);
        assert_eq!(collected[2], &[0, 3]);
    }

    #[test]
    fn validate_catches_out_of_range() {
        let mut c = tiny();
        c.vocab = 3;
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_catches_empty_doc() {
        let mut c = tiny();
        c.push_doc(&[]);
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_catches_broken_offsets() {
        let mut c = tiny();
        c.doc_offsets.pop();
        assert!(c.validate().is_err());
    }

    #[test]
    fn word_index_roundtrip() {
        let c = tiny();
        let idx = c.word_index();
        assert_eq!(idx.num_words(), 4);
        let mut seen = 0;
        for j in 0..4 {
            let (docs, poss) = idx.occurrences(j);
            assert_eq!(docs.len(), idx.count(j));
            for (&d, &p) in docs.iter().zip(poss) {
                assert_eq!(c.doc(d as usize)[p as usize], j as u32);
                seen += 1;
            }
        }
        assert_eq!(seen, c.num_tokens());
        assert_eq!(idx.count(1), 2);
        assert_eq!(idx.count(2), 3);
    }
}
