//! Corpus substrate: document storage, UCI bag-of-words IO, text
//! preprocessing (tokenizer + stop words + Porter stemmer), synthetic
//! corpus generation, dataset presets and worker partitioning.
//!
//! The canonical in-memory form is token-expanded ([`Corpus`]): `docs[i]`
//! lists the word id of every occurrence, mirroring the latent-variable
//! array `z` one-to-one.  Word-major access for word-by-word sampling
//! (F+LDA(word), Nomad subtasks `t_j`) goes through [`WordIndex`].

pub mod bow;
pub mod partition;
pub mod presets;
pub mod stats;
pub mod synthetic;
pub mod text;

pub use partition::Partition;
pub use presets::preset;
pub use stats::CorpusStats;

/// A token-expanded bag-of-words corpus.
#[derive(Clone, Debug, Default)]
pub struct Corpus {
    /// `docs[i][j]` = vocabulary id of the j-th occurrence in document i.
    pub docs: Vec<Vec<u32>>,
    /// vocabulary size J (ids are `0..vocab`)
    pub vocab: usize,
    /// optional vocabulary strings (empty when synthetic/anonymous)
    pub vocab_words: Vec<String>,
    /// dataset label for logging
    pub name: String,
}

impl Corpus {
    /// Number of documents I.
    pub fn num_docs(&self) -> usize {
        self.docs.len()
    }

    /// Total token count Σ_i n_i.
    pub fn num_tokens(&self) -> usize {
        self.docs.iter().map(|d| d.len()).sum()
    }

    /// Validate structural invariants (every id < vocab, no empty docs).
    pub fn validate(&self) -> Result<(), String> {
        for (i, d) in self.docs.iter().enumerate() {
            if d.is_empty() {
                return Err(format!("document {i} is empty"));
            }
            for &w in d {
                if w as usize >= self.vocab {
                    return Err(format!("doc {i}: word id {w} >= vocab {}", self.vocab));
                }
            }
        }
        if !self.vocab_words.is_empty() && self.vocab_words.len() != self.vocab {
            return Err(format!(
                "vocab_words len {} != vocab {}",
                self.vocab_words.len(),
                self.vocab
            ));
        }
        Ok(())
    }

    /// Build the word-major occurrence index.
    pub fn word_index(&self) -> WordIndex {
        WordIndex::build(self)
    }
}

/// Word-major view: for each vocabulary id, the (doc, position) of every
/// occurrence.  This is the unit-subtask structure of the Nomad framework —
/// subtask `t_j` is exactly `occurrences(j)` restricted to a worker's
/// document partition.
#[derive(Clone, Debug, Default)]
pub struct WordIndex {
    /// CSR-style: occurrence array sorted by word id
    pub doc_of: Vec<u32>,
    pub pos_of: Vec<u32>,
    /// offsets[j]..offsets[j+1] is word j's slice
    pub offsets: Vec<usize>,
}

impl WordIndex {
    pub fn build(corpus: &Corpus) -> Self {
        let mut counts = vec![0usize; corpus.vocab + 1];
        for d in &corpus.docs {
            for &w in d {
                counts[w as usize + 1] += 1;
            }
        }
        for j in 1..counts.len() {
            counts[j] += counts[j - 1];
        }
        let offsets = counts.clone();
        let total = *offsets.last().unwrap();
        let mut doc_of = vec![0u32; total];
        let mut pos_of = vec![0u32; total];
        let mut cursor = offsets.clone();
        for (i, d) in corpus.docs.iter().enumerate() {
            for (p, &w) in d.iter().enumerate() {
                let at = cursor[w as usize];
                doc_of[at] = i as u32;
                pos_of[at] = p as u32;
                cursor[w as usize] += 1;
            }
        }
        WordIndex { doc_of, pos_of, offsets }
    }

    /// All occurrences of word j as parallel (doc, pos) slices.
    #[inline]
    pub fn occurrences(&self, j: usize) -> (&[u32], &[u32]) {
        let lo = self.offsets[j];
        let hi = self.offsets[j + 1];
        (&self.doc_of[lo..hi], &self.pos_of[lo..hi])
    }

    /// Occurrence count of word j.
    #[inline]
    pub fn count(&self, j: usize) -> usize {
        self.offsets[j + 1] - self.offsets[j]
    }

    pub fn num_words(&self) -> usize {
        self.offsets.len() - 1
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    pub(crate) fn tiny() -> Corpus {
        Corpus {
            docs: vec![vec![0, 1, 1, 2], vec![2, 2, 3], vec![0, 3]],
            vocab: 4,
            vocab_words: vec![],
            name: "tiny".into(),
        }
    }

    #[test]
    fn counts() {
        let c = tiny();
        assert_eq!(c.num_docs(), 3);
        assert_eq!(c.num_tokens(), 9);
        c.validate().unwrap();
    }

    #[test]
    fn validate_catches_out_of_range() {
        let mut c = tiny();
        c.vocab = 3;
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_catches_empty_doc() {
        let mut c = tiny();
        c.docs.push(vec![]);
        assert!(c.validate().is_err());
    }

    #[test]
    fn word_index_roundtrip() {
        let c = tiny();
        let idx = c.word_index();
        assert_eq!(idx.num_words(), 4);
        let mut seen = 0;
        for j in 0..4 {
            let (docs, poss) = idx.occurrences(j);
            assert_eq!(docs.len(), idx.count(j));
            for (&d, &p) in docs.iter().zip(poss) {
                assert_eq!(c.docs[d as usize][p as usize], j as u32);
                seen += 1;
            }
        }
        assert_eq!(seen, c.num_tokens());
        assert_eq!(idx.count(1), 2);
        assert_eq!(idx.count(2), 3);
    }
}
