//! Corpus substrate: backend-abstracted document storage, UCI
//! bag-of-words IO, text preprocessing (tokenizer + stop words + Porter
//! stemmer), synthetic corpus generation, dataset presets and worker
//! partitioning.
//!
//! # Storage backends
//!
//! [`Corpus`] is an encapsulated handle over one of two stores:
//!
//! * **Ram** — the token payload is one contiguous `Vec<u32>`.  This is
//!   what presets, loaders and tests build, and every accessor compiles
//!   down to the same slice arithmetic as the old public-field layout.
//! * **DiskCsr** — the payload stays in an `FNCP0001` file (see
//!   [`disk`]) and is streamed through a bounded sliding read window of
//!   positioned `pread` calls, so training never materializes the full
//!   token array.  Only the `O(num_docs)` offset table and the vocab
//!   strings live in RAM.
//!
//! Both backends expose the same access API, and fixed-seed training is
//! bit-identical across them:
//!
//! * [`Corpus::doc`] — one document ([`DocRef`]: borrowed slice for Ram,
//!   a small owned read for Disk);
//! * [`Corpus::docs`] — iterate all documents (convenience; does one
//!   read per document on Disk);
//! * [`Corpus::docs_in`] — the sweep workhorse: a lending iterator over
//!   a document range that refills a read window of at most
//!   `window_tokens` tokens at a time (`while let Some((doc, toks)) =
//!   sweep.next_doc()`);
//! * [`Corpus::doc_range_into`] / [`Corpus::read_range`] — bulk-copy a
//!   doc range, the spawn path by which nomad/ps runtimes hand each
//!   worker a rebased [`CorpusSlice`] without the coordinator ever
//!   holding the whole payload.
//!
//! # Memory layout (CSR)
//!
//! The canonical form is a token-expanded **flat CSR** layout: one
//! contiguous `tokens` payload holding the word id of every occurrence,
//! documents back to back, plus a `doc_offsets` prefix-sum array so that
//! document `i` is the payload range `doc_offsets[i]..doc_offsets[i+1]`.
//! The latent-variable array `z` ([`crate::lda::LdaState`]) is a flat
//! `Vec<u16>` sharing the *same* offsets, so `(doc, pos)` maps to the one
//! flat index `doc_offsets[doc] + pos` on both sides.
//!
//! Invariants (enforced at insertion by [`Corpus::push_doc`] and the
//! `FNCP0001` writer, and re-checkable via [`Corpus::validate`]):
//!
//! * `doc_offsets.len() == num_docs() + 1`, `doc_offsets[0] == 0`,
//!   `doc_offsets` is strictly increasing (**no empty documents**), and
//!   `*doc_offsets.last() == num_tokens()`;
//! * every token id is `< vocab`.
//!
//! Why flat: at the paper's scale (millions of documents, billions of
//! tokens) a `Vec<Vec<u32>>` costs one heap allocation plus 24 bytes of
//! `Vec` header per document and pointer-chases on every sweep; the CSR
//! form iterates at memcpy speed, lets workers copy their document range
//! with a single bulk read, and is exactly the shape the on-disk format
//! stores, which is why the Disk backend can stream it.
//!
//! Word-major access for word-by-word sampling (F+LDA(word), Nomad
//! subtasks `t_j`) goes through [`WordIndex`], which is CSR over the same
//! occurrences sorted by word id.  The index itself is `O(num_tokens)`
//! RAM, so word-major sampling is inherently an in-RAM affair; use the
//! doc-major samplers for out-of-core corpora.

pub mod bow;
pub mod disk;
pub mod partition;
pub mod presets;
pub mod stats;
pub mod synthetic;
pub mod text;

pub use disk::{
    peak_resident_corpus_bytes, reset_peak_resident_corpus_bytes, resident_corpus_bytes,
    FncorpusSummary, FncorpusWriter,
};
pub use partition::Partition;
pub use presets::preset;
pub use stats::CorpusStats;

use std::ops::{Deref, Range};
use std::path::Path;

/// Default sliding read-window size for disk-backed sweeps, in tokens
/// (1 Mi tokens = 4 MiB resident).
pub const DEFAULT_WINDOW_TOKENS: usize = 1 << 20;

/// Where the token payload lives (see the module docs).
#[derive(Clone, Debug)]
enum Store {
    Ram(Vec<u32>),
    Disk(disk::DiskCsr),
}

/// A token-expanded bag-of-words corpus in flat CSR form over a Ram or
/// Disk payload store (see the module docs for layout and invariants).
///
/// Fields are private by design: everything outside `corpus/` goes
/// through the backend-neutral accessors, which is what lets the Disk
/// backend exist at all.
#[derive(Clone, Debug)]
pub struct Corpus {
    store: Store,
    /// `doc_offsets[i]..doc_offsets[i+1]` is document i's payload range.
    /// Always RAM-resident for both backends.
    doc_offsets: Vec<usize>,
    /// vocabulary size J (ids are `0..vocab`)
    vocab: usize,
    /// optional vocabulary strings (empty when synthetic/anonymous)
    vocab_words: Vec<String>,
    /// dataset label for logging
    name: String,
}

impl Default for Corpus {
    fn default() -> Self {
        Corpus {
            store: Store::Ram(Vec::new()),
            doc_offsets: vec![0],
            vocab: 0,
            vocab_words: Vec::new(),
            name: String::new(),
        }
    }
}

impl Corpus {
    /// Empty in-RAM corpus with metadata only (documents appended via
    /// [`Self::push_doc`]).
    pub fn with_meta(vocab: usize, vocab_words: Vec<String>, name: String) -> Self {
        Corpus {
            store: Store::Ram(Vec::new()),
            doc_offsets: vec![0],
            vocab,
            vocab_words,
            name,
        }
    }

    /// Flatten nested per-document token lists into the CSR layout.
    pub fn from_docs(
        docs: Vec<Vec<u32>>,
        vocab: usize,
        vocab_words: Vec<String>,
        name: String,
    ) -> Self {
        let mut c = Corpus::with_meta(vocab, vocab_words, name);
        c.reserve_tokens(docs.iter().map(|d| d.len()).sum());
        c.doc_offsets.reserve(docs.len());
        for d in &docs {
            c.push_doc(d);
        }
        c
    }

    /// Build an in-RAM corpus directly from CSR parts, validating the
    /// invariants.
    pub fn from_csr_parts(
        tokens: Vec<u32>,
        doc_offsets: Vec<usize>,
        vocab: usize,
        vocab_words: Vec<String>,
        name: String,
    ) -> Result<Self, String> {
        if doc_offsets.is_empty() {
            return Err("doc_offsets must hold at least the leading 0".into());
        }
        let c = Corpus { store: Store::Ram(tokens), doc_offsets, vocab, vocab_words, name };
        c.validate()?;
        Ok(c)
    }

    /// Append one document (its word ids, in occurrence order).
    ///
    /// # Panics
    ///
    /// On an empty document — the no-empty-docs invariant is enforced at
    /// insertion time, not just in the after-the-fact [`Self::validate`]
    /// — and on a disk-backed corpus, which is read-only (build new
    /// files through [`FncorpusWriter`]).
    pub fn push_doc(&mut self, toks: &[u32]) {
        assert!(
            !toks.is_empty(),
            "corpus invariant: empty document rejected at insertion (doc {})",
            self.num_docs()
        );
        match &mut self.store {
            Store::Ram(tokens) => {
                tokens.extend_from_slice(toks);
                self.doc_offsets.push(tokens.len());
            }
            Store::Disk(_) => panic!("cannot append documents to a disk-backed corpus"),
        }
    }

    /// Capacity hint for the Ram payload (no-op for Disk).
    pub fn reserve_tokens(&mut self, additional: usize) {
        if let Store::Ram(tokens) = &mut self.store {
            tokens.reserve(additional);
        }
    }

    /// Number of documents I.
    #[inline]
    pub fn num_docs(&self) -> usize {
        self.doc_offsets.len() - 1
    }

    /// Total token count Σ_i n_i (O(1) under CSR for both backends).
    #[inline]
    pub fn num_tokens(&self) -> usize {
        *self.doc_offsets.last().unwrap()
    }

    /// Vocabulary size J (ids are `0..vocab`).
    #[inline]
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Vocabulary strings (empty when synthetic/anonymous).
    #[inline]
    pub fn vocab_words(&self) -> &[String] {
        &self.vocab_words
    }

    /// Dataset label for logging.
    #[inline]
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The CSR doc-offset table (always RAM-resident; `offsets()[i]` is
    /// the flat token index where document i starts — the shared base
    /// for the `z` array).
    #[inline]
    pub fn offsets(&self) -> &[usize] {
        &self.doc_offsets
    }

    /// Whether the token payload streams from an `.fncorpus` file.
    pub fn is_on_disk(&self) -> bool {
        matches!(self.store, Store::Disk(_))
    }

    /// Document i's tokens: a borrowed slice for Ram, a small owned read
    /// for Disk.
    #[inline]
    pub fn doc(&self, i: usize) -> DocRef<'_> {
        let lo = self.doc_offsets[i];
        let hi = self.doc_offsets[i + 1];
        match &self.store {
            Store::Ram(tokens) => DocRef::Borrowed(&tokens[lo..hi]),
            Store::Disk(csr) => {
                let mut v = Vec::with_capacity(hi - lo);
                csr.try_read_tokens_into(lo, hi - lo, &mut v)
                    .unwrap_or_else(|e| panic!("{e}"));
                disk::note_transient(v.capacity() * 4);
                DocRef::Owned(v)
            }
        }
    }

    /// Length of document i (O(1)).
    #[inline]
    pub fn doc_len(&self, i: usize) -> usize {
        self.doc_offsets[i + 1] - self.doc_offsets[i]
    }

    /// Iterate documents in order.  Convenience for metadata-scale scans;
    /// on the Disk backend each document is its own read, so hot sweeps
    /// should use [`Self::docs_in`] instead.
    #[inline]
    pub fn docs(&self) -> Docs<'_> {
        Docs { corpus: self, next: 0 }
    }

    /// Sweep a document range through a bounded read window: the lending
    /// iterator yields `(doc_index, tokens)` pairs whose slices stay
    /// valid until the next call.
    ///
    /// Ram: zero-copy subslices, no buffering.  Disk: at most
    /// `window_tokens` tokens (as set by [`Self::open_fncorpus`]) are
    /// resident at once, except for single documents longer than the
    /// window, which are read whole.
    pub fn docs_in(&self, range: Range<usize>) -> DocSweep<'_> {
        assert!(
            range.start <= range.end && range.end <= self.num_docs(),
            "docs_in({}..{}) out of bounds for {} docs",
            range.start,
            range.end,
            self.num_docs()
        );
        DocSweep {
            corpus: self,
            next: range.start,
            end: range.end,
            window: disk::TrackedBuf::new(),
            window_base: 0,
            window_len: 0,
        }
    }

    /// Replace `out` with the concatenated tokens of documents
    /// `range.start..range.end` (one bulk read on Disk).
    pub fn doc_range_into(&self, range: Range<usize>, out: &mut Vec<u32>) {
        assert!(
            range.start <= range.end && range.end <= self.num_docs(),
            "doc_range_into({}..{}) out of bounds for {} docs",
            range.start,
            range.end,
            self.num_docs()
        );
        out.clear();
        let lo = self.doc_offsets[range.start];
        let hi = self.doc_offsets[range.end];
        match &self.store {
            Store::Ram(tokens) => out.extend_from_slice(&tokens[lo..hi]),
            Store::Disk(csr) => {
                csr.try_read_tokens_into(lo, hi - lo, out)
                    .unwrap_or_else(|e| panic!("{e}"));
                disk::note_transient(out.capacity() * 4);
            }
        }
    }

    /// Materialize documents `start..end` as a rebased [`CorpusSlice`] —
    /// the worker-spawn payload.  A coordinator streaming from Disk can
    /// feed remote workers shards of a corpus it never fully loads.
    pub fn read_range(&self, start: usize, end: usize) -> CorpusSlice {
        let base = self.doc_offsets[start];
        let offsets: Vec<usize> =
            self.doc_offsets[start..=end].iter().map(|&o| o - base).collect();
        let mut tokens = Vec::new();
        self.doc_range_into(start..end, &mut tokens);
        CorpusSlice { start_doc: start, offsets, tokens, vocab: self.vocab }
    }

    /// Materialize the whole token payload (tests and diagnostics; on
    /// Disk this reads the entire file).
    pub fn tokens_vec(&self) -> Vec<u32> {
        let mut v = Vec::new();
        self.doc_range_into(0..self.num_docs(), &mut v);
        v
    }

    /// Validate structural invariants (CSR shape, every id < vocab, no
    /// empty docs).  On Disk this streams the payload through the
    /// bounds-checked decoder window by window.
    pub fn validate(&self) -> Result<(), String> {
        if self.doc_offsets.first() != Some(&0) {
            return Err("doc_offsets must start at 0".into());
        }
        for (i, w) in self.doc_offsets.windows(2).enumerate() {
            if w[1] <= w[0] {
                return Err(format!("document {i} is empty"));
            }
        }
        match &self.store {
            Store::Ram(tokens) => {
                if *self.doc_offsets.last().unwrap() != tokens.len() {
                    return Err(format!(
                        "doc_offsets ends at {}, tokens.len() is {}",
                        self.doc_offsets.last().unwrap(),
                        tokens.len()
                    ));
                }
                for (at, &w) in tokens.iter().enumerate() {
                    if w as usize >= self.vocab {
                        let i = self.doc_of_token(at);
                        return Err(format!("doc {i}: word id {w} >= vocab {}", self.vocab));
                    }
                }
            }
            Store::Disk(csr) => {
                let total = self.num_tokens();
                let window = csr.window_tokens();
                let mut buf = Vec::new();
                let mut at = 0usize;
                while at < total {
                    let n = (total - at).min(window);
                    buf.clear();
                    csr.try_read_tokens_into(at, n, &mut buf)?;
                    at += n;
                }
            }
        }
        if !self.vocab_words.is_empty() && self.vocab_words.len() != self.vocab {
            return Err(format!(
                "vocab_words len {} != vocab {}",
                self.vocab_words.len(),
                self.vocab
            ));
        }
        Ok(())
    }

    /// Which document the flat token index `at` belongs to (diagnostics).
    fn doc_of_token(&self, at: usize) -> usize {
        self.doc_offsets.partition_point(|&o| o <= at) - 1
    }

    /// Build the word-major occurrence index (`O(num_tokens)` RAM even
    /// for disk-backed corpora — see the module docs).
    pub fn word_index(&self) -> WordIndex {
        WordIndex::build(self)
    }

    /// Write this corpus as an `FNCP0001` file (atomic, fingerprinted).
    pub fn write_fncorpus(&self, path: &Path) -> Result<FncorpusSummary, String> {
        let mut w =
            FncorpusWriter::create(path, self.vocab, self.vocab_words.clone(), &self.name)?;
        let mut sweep = self.docs_in(0..self.num_docs());
        while let Some((_, d)) = sweep.next_doc() {
            w.push_doc(d)?;
        }
        w.finish()
    }

    /// Open an `.fncorpus` file for out-of-core streaming access with
    /// the given read-window size (in tokens; see
    /// [`DEFAULT_WINDOW_TOKENS`]).
    pub fn open_fncorpus(path: &Path, window_tokens: usize) -> Result<Corpus, String> {
        let o = disk::open(path, window_tokens)?;
        Ok(Corpus {
            store: Store::Disk(o.csr),
            doc_offsets: o.doc_offsets,
            vocab: o.vocab,
            vocab_words: o.vocab_words,
            name: o.name,
        })
    }

    /// Load an `.fncorpus` file fully into RAM, verifying its trailer
    /// fingerprint first.
    pub fn load_fncorpus_ram(path: &Path) -> Result<Corpus, String> {
        let l = disk::load_ram(path)?;
        Ok(Corpus {
            store: Store::Ram(l.tokens),
            doc_offsets: l.doc_offsets,
            vocab: l.vocab,
            vocab_words: l.vocab_words,
            name: l.name,
        })
    }

    /// Path of the backing `.fncorpus` file, if disk-backed.
    pub fn disk_path(&self) -> Option<&Path> {
        match &self.store {
            Store::Ram(_) => None,
            Store::Disk(csr) => Some(csr.path()),
        }
    }
}

/// One document's tokens: borrowed straight out of the Ram payload, or
/// owned when they were read from Disk.  Derefs to `&[u32]`.
#[derive(Clone)]
pub enum DocRef<'a> {
    Borrowed(&'a [u32]),
    Owned(Vec<u32>),
}

impl Deref for DocRef<'_> {
    type Target = [u32];

    #[inline]
    fn deref(&self) -> &[u32] {
        match self {
            DocRef::Borrowed(s) => s,
            DocRef::Owned(v) => v,
        }
    }
}

impl std::fmt::Debug for DocRef<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        std::fmt::Debug::fmt(&**self, f)
    }
}

impl PartialEq for DocRef<'_> {
    fn eq(&self, other: &Self) -> bool {
        **self == **other
    }
}

impl Eq for DocRef<'_> {}

impl PartialEq<[u32]> for DocRef<'_> {
    fn eq(&self, other: &[u32]) -> bool {
        **self == *other
    }
}

impl PartialEq<&[u32]> for DocRef<'_> {
    fn eq(&self, other: &&[u32]) -> bool {
        **self == **other
    }
}

impl PartialEq<Vec<u32>> for DocRef<'_> {
    fn eq(&self, other: &Vec<u32>) -> bool {
        **self == other[..]
    }
}

impl<const N: usize> PartialEq<[u32; N]> for DocRef<'_> {
    fn eq(&self, other: &[u32; N]) -> bool {
        **self == other[..]
    }
}

impl<const N: usize> PartialEq<&[u32; N]> for DocRef<'_> {
    fn eq(&self, other: &&[u32; N]) -> bool {
        **self == other[..]
    }
}

/// In-order document iterator (see [`Corpus::docs`]).
pub struct Docs<'a> {
    corpus: &'a Corpus,
    next: usize,
}

impl<'a> Iterator for Docs<'a> {
    type Item = DocRef<'a>;

    fn next(&mut self) -> Option<DocRef<'a>> {
        if self.next >= self.corpus.num_docs() {
            return None;
        }
        let d = self.corpus.doc(self.next);
        self.next += 1;
        Some(d)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let n = self.corpus.num_docs() - self.next;
        (n, Some(n))
    }
}

impl ExactSizeIterator for Docs<'_> {}

/// Lending sweep over a document range (see [`Corpus::docs_in`]): call
/// [`next_doc`] in a `while let` loop.  Not a `std::iter::Iterator`
/// because the yielded slice borrows the internal read window.
///
/// [`next_doc`]: DocSweep::next_doc
pub struct DocSweep<'a> {
    corpus: &'a Corpus,
    next: usize,
    end: usize,
    window: disk::TrackedBuf,
    /// flat token index of `window[0]`
    window_base: usize,
    window_len: usize,
}

impl DocSweep<'_> {
    /// The next `(doc_index, tokens)` pair, or `None` past the range
    /// end.  The slice is valid until the next call.
    #[inline]
    pub fn next_doc(&mut self) -> Option<(usize, &[u32])> {
        if self.next >= self.end {
            return None;
        }
        let i = self.next;
        self.next += 1;
        // hoist the `&'a Corpus` so the store borrow is disjoint from
        // the `&mut self.window` the Disk arm needs
        let corpus = self.corpus;
        let lo = corpus.doc_offsets[i];
        let hi = corpus.doc_offsets[i + 1];
        match &corpus.store {
            Store::Ram(tokens) => Some((i, &tokens[lo..hi])),
            Store::Disk(csr) => {
                if lo < self.window_base || hi > self.window_base + self.window_len {
                    // slide the window: start at this doc, extend to the
                    // window budget (or this doc's end if it is longer),
                    // clipped to the sweep's final token
                    let span_end = corpus.doc_offsets[self.end];
                    let want = (lo + csr.window_tokens().max(hi - lo)).min(span_end);
                    self.window.fill(csr, lo, want - lo);
                    self.window_base = lo;
                    self.window_len = want - lo;
                }
                Some((i, &self.window.as_slice()[lo - self.window_base..hi - self.window_base]))
            }
        }
    }
}

/// A rebased, materialized shard of a corpus: documents
/// `start_doc..start_doc + num_docs()` with `offsets[0] == 0`.  This is
/// what worker constructors consume and what the wire-level `Init`
/// message carries — the worker side never sees a [`Corpus`].
#[derive(Clone, Debug)]
pub struct CorpusSlice {
    /// global index of the first document in the slice
    pub start_doc: usize,
    /// rebased CSR offsets: `offsets[i]..offsets[i+1]` indexes `tokens`
    pub offsets: Vec<usize>,
    /// the shard's token payload
    pub tokens: Vec<u32>,
    /// vocabulary size of the parent corpus
    pub vocab: usize,
}

impl CorpusSlice {
    /// Validate and assemble a slice from raw parts (the deserialization
    /// path for wire `Init` payloads).
    pub fn from_parts(
        start_doc: usize,
        offsets: Vec<usize>,
        tokens: Vec<u32>,
        vocab: usize,
    ) -> Result<CorpusSlice, String> {
        if offsets.is_empty() {
            return Err("doc_offsets must hold at least the leading 0".into());
        }
        if offsets[0] != 0 {
            return Err(format!("doc_offsets must start at 0 (got {})", offsets[0]));
        }
        for (i, w) in offsets.windows(2).enumerate() {
            if w[1] <= w[0] {
                return Err(format!("document {} is empty or offsets are unordered", start_doc + i));
            }
        }
        if *offsets.last().unwrap() != tokens.len() {
            return Err(format!(
                "doc_offsets ends at {}, tokens.len() is {}",
                offsets.last().unwrap(),
                tokens.len()
            ));
        }
        if let Some(&w) = tokens.iter().find(|&&w| w as usize >= vocab) {
            return Err(format!("word id {w} >= vocab {vocab}"));
        }
        Ok(CorpusSlice { start_doc, offsets, tokens, vocab })
    }

    /// Number of documents in the slice.
    #[inline]
    pub fn num_docs(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Token count of the slice.
    #[inline]
    pub fn num_tokens(&self) -> usize {
        self.tokens.len()
    }

    /// Local document `i` (0-based within the slice) as a token slice.
    #[inline]
    pub fn doc(&self, i: usize) -> &[u32] {
        &self.tokens[self.offsets[i]..self.offsets[i + 1]]
    }
}

/// Word-major view: for each vocabulary id, the (doc, position) of every
/// occurrence.  This is the unit-subtask structure of the Nomad framework —
/// subtask `t_j` is exactly `occurrences(j)` restricted to a worker's
/// document partition.
#[derive(Clone, Debug, Default)]
pub struct WordIndex {
    /// CSR-style: occurrence array sorted by word id
    pub doc_of: Vec<u32>,
    pub pos_of: Vec<u32>,
    /// offsets[j]..offsets[j+1] is word j's slice
    pub offsets: Vec<usize>,
}

impl WordIndex {
    pub fn build(corpus: &Corpus) -> Self {
        let mut counts = vec![0usize; corpus.vocab() + 1];
        let mut sweep = corpus.docs_in(0..corpus.num_docs());
        while let Some((_, d)) = sweep.next_doc() {
            for &w in d {
                counts[w as usize + 1] += 1;
            }
        }
        for j in 1..counts.len() {
            counts[j] += counts[j - 1];
        }
        let offsets = counts.clone();
        let total = *offsets.last().unwrap();
        let mut doc_of = vec![0u32; total];
        let mut pos_of = vec![0u32; total];
        let mut cursor = offsets.clone();
        let mut sweep = corpus.docs_in(0..corpus.num_docs());
        while let Some((i, d)) = sweep.next_doc() {
            for (p, &w) in d.iter().enumerate() {
                let at = cursor[w as usize];
                doc_of[at] = i as u32;
                pos_of[at] = p as u32;
                cursor[w as usize] += 1;
            }
        }
        WordIndex { doc_of, pos_of, offsets }
    }

    /// All occurrences of word j as parallel (doc, pos) slices.
    #[inline]
    pub fn occurrences(&self, j: usize) -> (&[u32], &[u32]) {
        let lo = self.offsets[j];
        let hi = self.offsets[j + 1];
        (&self.doc_of[lo..hi], &self.pos_of[lo..hi])
    }

    /// Occurrence count of word j.
    #[inline]
    pub fn count(&self, j: usize) -> usize {
        self.offsets[j + 1] - self.offsets[j]
    }

    pub fn num_words(&self) -> usize {
        self.offsets.len() - 1
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    pub(crate) fn tiny() -> Corpus {
        Corpus::from_docs(
            vec![vec![0, 1, 1, 2], vec![2, 2, 3], vec![0, 3]],
            4,
            vec![],
            "tiny".into(),
        )
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("fnomad_corpus_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn counts() {
        let c = tiny();
        assert_eq!(c.num_docs(), 3);
        assert_eq!(c.num_tokens(), 9);
        c.validate().unwrap();
    }

    #[test]
    fn csr_layout_shape() {
        let c = tiny();
        assert_eq!(c.offsets(), &[0, 4, 7, 9]);
        assert_eq!(c.tokens_vec(), vec![0, 1, 1, 2, 2, 2, 3, 0, 3]);
        assert_eq!(c.doc(0), &[0, 1, 1, 2]);
        assert_eq!(c.doc(1), &[2, 2, 3]);
        assert_eq!(c.doc(2), &[0, 3]);
        assert_eq!(c.doc_len(1), 3);
        let collected: Vec<DocRef<'_>> = c.docs().collect();
        assert_eq!(collected.len(), 3);
        assert_eq!(collected[2], &[0, 3]);
    }

    #[test]
    fn validate_catches_out_of_range() {
        let mut c = tiny();
        c.vocab = 3;
        assert!(c.validate().is_err());
    }

    #[test]
    #[should_panic(expected = "empty document rejected at insertion")]
    fn push_doc_rejects_empty_doc() {
        let mut c = tiny();
        c.push_doc(&[]);
    }

    #[test]
    fn validate_catches_broken_offsets() {
        let mut c = tiny();
        c.doc_offsets.pop();
        assert!(c.validate().is_err());
    }

    #[test]
    fn word_index_roundtrip() {
        let c = tiny();
        let idx = c.word_index();
        assert_eq!(idx.num_words(), 4);
        let mut seen = 0;
        for j in 0..4 {
            let (docs, poss) = idx.occurrences(j);
            assert_eq!(docs.len(), idx.count(j));
            for (&d, &p) in docs.iter().zip(poss) {
                assert_eq!(c.doc(d as usize)[p as usize], j as u32);
                seen += 1;
            }
        }
        assert_eq!(seen, c.num_tokens());
        assert_eq!(idx.count(1), 2);
        assert_eq!(idx.count(2), 3);
    }

    #[test]
    fn sweep_matches_docs_for_ram() {
        let c = tiny();
        let mut sweep = c.docs_in(0..c.num_docs());
        let mut seen = Vec::new();
        while let Some((i, d)) = sweep.next_doc() {
            seen.push((i, d.to_vec()));
        }
        assert_eq!(seen.len(), 3);
        assert_eq!(seen[0], (0, vec![0, 1, 1, 2]));
        assert_eq!(seen[2], (2, vec![0, 3]));
    }

    #[test]
    fn read_range_rebases_offsets() {
        let c = tiny();
        let s = c.read_range(1, 3);
        assert_eq!(s.start_doc, 1);
        assert_eq!(s.offsets, vec![0, 3, 5]);
        assert_eq!(s.tokens, vec![2, 2, 3, 0, 3]);
        assert_eq!(s.vocab, 4);
        assert_eq!(s.num_docs(), 2);
        assert_eq!(s.doc(1), &[0, 3]);
    }

    #[test]
    fn slice_from_parts_validates() {
        assert!(CorpusSlice::from_parts(0, vec![0, 2, 3], vec![0, 1, 2], 4).is_ok());
        let err = CorpusSlice::from_parts(0, vec![], vec![], 4).unwrap_err();
        assert!(err.contains("leading 0"), "{err}");
        let err = CorpusSlice::from_parts(0, vec![1, 2], vec![0, 1], 4).unwrap_err();
        assert!(err.contains("start at 0"), "{err}");
        let err = CorpusSlice::from_parts(5, vec![0, 1, 1], vec![0], 4).unwrap_err();
        assert!(err.contains("document 6 is empty"), "{err}");
        let err = CorpusSlice::from_parts(0, vec![0, 2], vec![0, 1, 2], 4).unwrap_err();
        assert!(err.contains("tokens.len()"), "{err}");
        let err = CorpusSlice::from_parts(0, vec![0, 2], vec![0, 9], 4).unwrap_err();
        assert!(err.contains(">= vocab"), "{err}");
    }

    #[test]
    fn disk_backend_matches_ram_accessors() {
        let path = tmp("accessors.fncorpus");
        let ram = tiny();
        ram.write_fncorpus(&path).unwrap();
        // window of 4 tokens forces the sweep to slide mid-corpus
        let dsk = Corpus::open_fncorpus(&path, 4).unwrap();
        assert!(dsk.is_on_disk());
        assert_eq!(dsk.disk_path(), Some(path.as_path()));
        assert_eq!(dsk.num_docs(), ram.num_docs());
        assert_eq!(dsk.num_tokens(), ram.num_tokens());
        assert_eq!(dsk.vocab(), ram.vocab());
        assert_eq!(dsk.name(), ram.name());
        assert_eq!(dsk.offsets(), ram.offsets());
        assert_eq!(dsk.tokens_vec(), ram.tokens_vec());
        for i in 0..ram.num_docs() {
            assert_eq!(dsk.doc(i), ram.doc(i));
        }
        let mut sweep = dsk.docs_in(0..dsk.num_docs());
        let mut flat = Vec::new();
        while let Some((_, d)) = sweep.next_doc() {
            flat.extend_from_slice(d);
        }
        assert_eq!(flat, ram.tokens_vec());
        let s_ram = ram.read_range(1, 3);
        let s_dsk = dsk.read_range(1, 3);
        assert_eq!(s_ram.offsets, s_dsk.offsets);
        assert_eq!(s_ram.tokens, s_dsk.tokens);
        dsk.validate().unwrap();
        let back = Corpus::load_fncorpus_ram(&path).unwrap();
        assert!(!back.is_on_disk());
        assert_eq!(back.tokens_vec(), ram.tokens_vec());
        let _ = std::fs::remove_file(&path);
    }
}
