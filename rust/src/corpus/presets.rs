//! Dataset presets: scaled synthetic stand-ins for the paper's Table 3
//! datasets, plus loading of real UCI dumps when present on disk.
//!
//! Scaling rule (DESIGN.md §Hardware-Adaptation): docs/vocab/tokens are
//! shrunk ~100–1000× from Table 3 while preserving the *ratios* that drive
//! the algorithms — tokens-per-doc (|T_d| pressure) and docs-per-word
//! (|T_w| pressure) — so per-step cost comparisons and convergence shapes
//! carry over.

use super::synthetic::{generate, SyntheticSpec};
use super::Corpus;

/// Table 3 reference statistics (the real datasets).
pub const PAPER_TABLE3: &[(&str, u64, u64, u64)] = &[
    // (name, docs I, vocab J, tokens)
    ("Enron", 37_861, 28_102, 6_238_796),
    ("NyTimes", 298_000, 102_660, 98_793_316),
    ("PubMed", 8_200_000, 141_043, 737_869_083),
    ("Amazon", 29_907_995, 1_682_527, 1_499_602_431),
    ("UMBC", 40_599_164, 2_881_476, 1_483_145_192),
];

/// Names of the simulated presets.
pub const PRESET_NAMES: &[&str] =
    &["enron-sim", "nytimes-sim", "pubmed-sim", "amazon-sim", "umbc-sim", "tiny", "bigzipf"];

/// Resolve a preset name to a generation spec.
///
/// avg_doc_len is Table 3 tokens/docs; docs and vocab are scaled down,
/// larger corpora more aggressively (they exist to stress doc *count*).
pub fn spec(name: &str) -> Option<SyntheticSpec> {
    let s = match name {
        // Enron: 165 tok/doc, dense vocabulary reuse
        "enron-sim" => SyntheticSpec {
            name: name.into(),
            num_docs: 3_800,
            vocab: 5_600,
            avg_doc_len: 165.0,
            true_topics: 50,
            seed: 101,
            ..Default::default()
        },
        // NyTimes: 331 tok/doc, many more docs than Enron (drives the
        // F+LDA(word) > F+LDA(doc) crossover of Fig. 4)
        "nytimes-sim" => SyntheticSpec {
            name: name.into(),
            num_docs: 15_000,
            vocab: 10_000,
            avg_doc_len: 331.0,
            true_topics: 100,
            seed: 102,
            ..Default::default()
        },
        // PubMed: short docs (90 tok/doc), huge doc count
        "pubmed-sim" => SyntheticSpec {
            name: name.into(),
            num_docs: 60_000,
            vocab: 14_000,
            avg_doc_len: 90.0,
            true_topics: 100,
            seed: 103,
            ..Default::default()
        },
        // Amazon: very short reviews (50 tok/doc), widest vocabulary
        "amazon-sim" => SyntheticSpec {
            name: name.into(),
            num_docs: 120_000,
            vocab: 40_000,
            avg_doc_len: 50.0,
            true_topics: 150,
            seed: 104,
            ..Default::default()
        },
        // UMBC: paragraph-sized (37 tok/doc), widest vocabulary of all
        "umbc-sim" => SyntheticSpec {
            name: name.into(),
            num_docs: 160_000,
            vocab: 56_000,
            avg_doc_len: 37.0,
            true_topics: 150,
            seed: 105,
            ..Default::default()
        },
        // CI-scale smoke corpus
        "tiny" => SyntheticSpec {
            name: name.into(),
            num_docs: 120,
            vocab: 300,
            avg_doc_len: 30.0,
            true_topics: 8,
            seed: 7,
            ..Default::default()
        },
        // Billion-token-class Zipfian workload for the out-of-core path:
        // ~1.02e9 tokens at full size, meant to be *streamed* to disk via
        // `prepare-corpus --preset bigzipf` (the `--docs N` override cuts
        // it down for smoke runs), then trained with `train --corpus`.
        // Materializing it through `train --preset` would need the whole
        // payload in RAM — that being unreasonable is the point.
        "bigzipf" => SyntheticSpec {
            name: name.into(),
            num_docs: 12_000_000,
            vocab: 300_000,
            avg_doc_len: 85.0,
            true_topics: 64,
            seed: 106,
            ..Default::default()
        },
        _ => return None,
    };
    Some(s)
}

/// Materialize a preset corpus.  If `data/docword.<name>.txt` exists (e.g.
/// a real UCI dump saved under the preset name), it takes precedence over
/// generation.
pub fn preset(name: &str) -> Result<Corpus, String> {
    let disk = std::path::Path::new("data").join(format!("docword.{name}.txt"));
    if disk.exists() {
        let vocab = std::path::Path::new("data").join(format!("vocab.{name}.txt"));
        return super::bow::load(&disk, vocab.exists().then_some(vocab.as_path()), name);
    }
    let spec = spec(name).ok_or_else(|| {
        format!("unknown preset '{name}' (known: {})", PRESET_NAMES.join(", "))
    })?;
    Ok(generate(&spec))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_have_specs() {
        for name in PRESET_NAMES {
            assert!(spec(name).is_some(), "missing spec for {name}");
        }
        assert!(spec("nope").is_none());
    }

    #[test]
    fn tiny_preset_generates_and_validates() {
        let c = preset("tiny").unwrap();
        c.validate().unwrap();
        assert_eq!(c.num_docs(), 120);
    }

    #[test]
    fn unknown_preset_errors_with_catalog() {
        let err = preset("bogus").unwrap_err();
        assert!(err.contains("enron-sim"));
    }

    #[test]
    fn scaled_ratios_track_table3() {
        // tokens-per-doc of each sim preset within 15% of the real dataset
        for (real, sim) in PAPER_TABLE3.iter().zip(
            ["enron-sim", "nytimes-sim", "pubmed-sim", "amazon-sim", "umbc-sim"].iter(),
        ) {
            let s = spec(sim).unwrap();
            let real_tpd = real.3 as f64 / real.1 as f64;
            assert!(
                (s.avg_doc_len - real_tpd).abs() / real_tpd < 0.15,
                "{sim}: avg_doc_len {} vs paper {real_tpd}",
                s.avg_doc_len
            );
        }
    }
}
