//! UCI "Bag of Words" file format (the Enron / NyTimes / PubMed format of
//! the paper's Table 3 — https://archive.ics.uci.edu/ml/datasets/Bag+of+Words).
//!
//! `docword.*.txt`:
//! ```text
//! D            # number of documents
//! W            # vocabulary size
//! NNZ          # number of (doc, word) pairs
//! docID wordID count     # 1-indexed, NNZ lines
//! ```
//! plus `vocab.*.txt` with one word per line.  Real UCI dumps drop into the
//! presets unchanged; the synthetic generators also serialize to this
//! format so every experiment input is inspectable on disk.
//!
//! The reader streams: lines are grouped by docID (UCI dumps are sorted),
//! so each document is flushed straight into the CSR under construction
//! the moment the docID advances — peak ingest memory is one document,
//! not a `vec![Vec::new(); D]` per-doc intermediate.  Documents left
//! empty by preprocessing are skipped and counted with a warning, never
//! inserted (the corpus enforces no-empty-docs at insertion time).

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::{disk::FncorpusSummary, Corpus, FncorpusWriter};

/// Streaming docword parser: reads the three headers up front, then
/// [`for_each_doc`] hands each completed document to a sink.
///
/// [`for_each_doc`]: DocwordParser::for_each_doc
pub struct DocwordParser<R: Read> {
    lines: std::io::Lines<BufReader<R>>,
    /// D header: documents the file claims to hold (empty ones included)
    pub num_docs: usize,
    /// W header: vocabulary size
    pub vocab: usize,
    /// NNZ header: number of (doc, word) entry lines
    pub nnz: usize,
}

/// What a full parse saw.
pub struct DocwordStats {
    /// documents actually emitted (non-empty)
    pub docs: usize,
    /// documents the D header promised but that held no tokens
    pub skipped_empty: usize,
}

impl<R: Read> DocwordParser<R> {
    pub fn new(r: R) -> Result<Self, String> {
        let mut lines = BufReader::new(r).lines();
        let mut header = |what: &str| -> Result<usize, String> {
            lines
                .next()
                .ok_or(format!("missing {what} header"))?
                .map_err(|e| e.to_string())?
                .trim()
                .parse::<usize>()
                .map_err(|e| format!("bad {what} header: {e}"))
        };
        let num_docs = header("D")?;
        let vocab = header("W")?;
        let nnz = header("NNZ")?;
        Ok(DocwordParser { lines, num_docs, vocab, nnz })
    }

    /// Stream every document to `sink` in docID order.  Requires the
    /// entry lines to be grouped by docID (as UCI dumps are); a docID
    /// regression is a named error.
    pub fn for_each_doc(
        self,
        mut sink: impl FnMut(&[u32]) -> Result<(), String>,
    ) -> Result<DocwordStats, String> {
        let (d, w, nnz) = (self.num_docs, self.vocab, self.nnz);
        let mut cur_doc = 0usize; // docIDs are 1-based; 0 = nothing seen
        let mut cur: Vec<u32> = Vec::new();
        let mut seen = 0usize;
        let mut docs = 0usize;
        for line in self.lines {
            let line = line.map_err(|e| e.to_string())?;
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut it = line.split_ascii_whitespace();
            let (di, wi, ci) = (
                it.next().ok_or("missing docID")?,
                it.next().ok_or("missing wordID")?,
                it.next().ok_or("missing count")?,
            );
            let di: usize = di.parse().map_err(|e| format!("docID: {e}"))?;
            let wi: usize = wi.parse().map_err(|e| format!("wordID: {e}"))?;
            let ci: usize = ci.parse().map_err(|e| format!("count: {e}"))?;
            if di == 0 || di > d {
                return Err(format!("docID {di} out of range 1..={d}"));
            }
            if wi == 0 || wi > w {
                return Err(format!("wordID {wi} out of range 1..={w}"));
            }
            if di < cur_doc {
                return Err(format!(
                    "docword lines must be grouped by docID (doc {di} after doc {cur_doc}); \
                     sort the file by its first column"
                ));
            }
            if di > cur_doc {
                if !cur.is_empty() {
                    sink(&cur)?;
                    docs += 1;
                    cur.clear();
                }
                cur_doc = di;
            }
            for _ in 0..ci {
                cur.push((wi - 1) as u32);
            }
            seen += 1;
        }
        if !cur.is_empty() {
            sink(&cur)?;
            docs += 1;
        }
        if seen != nnz {
            return Err(format!("NNZ header says {nnz}, saw {seen} entries"));
        }
        Ok(DocwordStats { docs, skipped_empty: d - docs })
    }
}

/// Parse a docword stream into an in-RAM corpus.  `vocab_words` may be
/// empty.
pub fn read_docword<R: Read>(r: R, vocab_words: Vec<String>, name: &str) -> Result<Corpus, String> {
    let parser = DocwordParser::new(r)?;
    let mut corpus = Corpus::with_meta(parser.vocab, vocab_words, name.to_string());
    let stats = parser.for_each_doc(|doc| {
        corpus.push_doc(doc);
        Ok(())
    })?;
    if stats.skipped_empty > 0 {
        // the paper drops e.g. Amazon reviews left empty by stemming
        crate::log_event!(
            Warn,
            "docword",
            { skipped = stats.skipped_empty },
            "warning: skipped {} empty documents in {name}",
            stats.skipped_empty
        );
    }
    corpus.validate()?;
    Ok(corpus)
}

fn read_vocab_words(p: &Path) -> Result<Vec<String>, String> {
    BufReader::new(std::fs::File::open(p).map_err(|e| format!("{}: {e}", p.display()))?)
        .lines()
        .collect::<Result<Vec<_>, _>>()
        .map_err(|e| e.to_string())
}

/// Load `docword` (+ optional `vocab`) files from disk.
pub fn load(docword: &Path, vocab: Option<&Path>, name: &str) -> Result<Corpus, String> {
    let vocab_words = match vocab {
        None => Vec::new(),
        Some(p) => read_vocab_words(p)?,
    };
    let f = std::fs::File::open(docword).map_err(|e| format!("{}: {e}", docword.display()))?;
    read_docword(f, vocab_words, name)
}

/// Convert docword (+ optional vocab) files straight into an `FNCP0001`
/// corpus with bounded memory: one document at a time flows from the
/// text file into the streaming writer.  Returns the write summary and
/// the number of empty documents skipped.
pub fn stream_to_fncorpus(
    docword: &Path,
    vocab: Option<&Path>,
    name: &str,
    dest: &Path,
) -> Result<(FncorpusSummary, usize), String> {
    let vocab_words = match vocab {
        None => Vec::new(),
        Some(p) => read_vocab_words(p)?,
    };
    let f = std::fs::File::open(docword).map_err(|e| format!("{}: {e}", docword.display()))?;
    let parser = DocwordParser::new(f)?;
    let mut w = FncorpusWriter::create(dest, parser.vocab, vocab_words, name)?;
    let stats = parser.for_each_doc(|doc| w.push_doc(doc))?;
    let summary = w.finish()?;
    Ok((summary, stats.skipped_empty))
}

/// Serialize to the docword format (dense per-doc word counts).
pub fn write_docword<W: Write>(corpus: &Corpus, w: W) -> std::io::Result<()> {
    let mut out = BufWriter::new(w);
    // count (doc, word) pairs
    let mut per_doc: Vec<Vec<(u32, u32)>> = Vec::with_capacity(corpus.num_docs());
    let mut nnz = 0usize;
    for d in corpus.docs() {
        let mut counts = std::collections::BTreeMap::new();
        for &wid in d.iter() {
            *counts.entry(wid).or_insert(0u32) += 1;
        }
        nnz += counts.len();
        per_doc.push(counts.into_iter().collect());
    }
    writeln!(out, "{}", corpus.num_docs())?;
    writeln!(out, "{}", corpus.vocab())?;
    writeln!(out, "{nnz}")?;
    for (i, counts) in per_doc.iter().enumerate() {
        for &(wid, c) in counts {
            writeln!(out, "{} {} {}", i + 1, wid + 1, c)?;
        }
    }
    out.flush()
}

/// Save corpus (+vocab if present) under `dir/docword.<name>.txt`.
pub fn save(corpus: &Corpus, dir: &Path) -> std::io::Result<()> {
    std::fs::create_dir_all(dir)?;
    let f = std::fs::File::create(dir.join(format!("docword.{}.txt", corpus.name())))?;
    write_docword(corpus, f)?;
    if !corpus.vocab_words().is_empty() {
        let mut vf = BufWriter::new(std::fs::File::create(
            dir.join(format!("vocab.{}.txt", corpus.name())),
        )?);
        for w in corpus.vocab_words() {
            writeln!(vf, "{w}")?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::tests::tiny;

    #[test]
    fn roundtrip() {
        let c = tiny();
        let mut buf = Vec::new();
        write_docword(&c, &mut buf).unwrap();
        let back = read_docword(&buf[..], vec![], "tiny").unwrap();
        assert_eq!(back.num_docs(), c.num_docs());
        assert_eq!(back.num_tokens(), c.num_tokens());
        assert_eq!(back.vocab(), c.vocab());
        // token multisets per doc match (order within doc may differ)
        for (a, b) in c.docs().zip(back.docs()) {
            let mut a = a.to_vec();
            let mut b = b.to_vec();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn parses_reference_format() {
        let text = "2\n3\n3\n1 1 2\n1 3 1\n2 2 5\n";
        let c = read_docword(text.as_bytes(), vec![], "t").unwrap();
        assert_eq!(c.num_docs(), 2);
        assert_eq!(c.doc(0), &[0, 0, 2]);
        assert_eq!(c.doc(1), &[1; 5][..]);
    }

    #[test]
    fn rejects_bad_nnz() {
        let text = "1\n2\n5\n1 1 1\n";
        assert!(read_docword(text.as_bytes(), vec![], "t").is_err());
    }

    #[test]
    fn rejects_out_of_range_ids() {
        let text = "1\n2\n1\n1 3 1\n";
        assert!(read_docword(text.as_bytes(), vec![], "t").is_err());
        let text = "1\n2\n1\n2 1 1\n";
        assert!(read_docword(text.as_bytes(), vec![], "t").is_err());
    }

    #[test]
    fn rejects_docid_regression() {
        let text = "2\n2\n3\n2 1 1\n1 1 1\n2 2 1\n";
        let err = read_docword(text.as_bytes(), vec![], "t").unwrap_err();
        assert!(err.contains("grouped by docID"), "unnamed error: {err}");
    }

    #[test]
    fn drops_empty_docs() {
        let text = "3\n2\n2\n1 1 1\n3 2 1\n";
        let c = read_docword(text.as_bytes(), vec![], "t").unwrap();
        assert_eq!(c.num_docs(), 2);
    }

    #[test]
    fn disk_roundtrip() {
        let dir = std::env::temp_dir().join("fnomad_bow_test");
        let mut c = tiny();
        c.vocab_words = vec!["a".into(), "b".into(), "c".into(), "d".into()];
        save(&c, &dir).unwrap();
        let back = load(
            &dir.join("docword.tiny.txt"),
            Some(&dir.join("vocab.tiny.txt")),
            "tiny",
        )
        .unwrap();
        assert_eq!(back.vocab_words(), c.vocab_words());
        assert_eq!(back.num_tokens(), c.num_tokens());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn streams_docword_to_fncorpus() {
        let dir = std::env::temp_dir().join("fnomad_bow_stream_test");
        std::fs::create_dir_all(&dir).unwrap();
        let c = tiny();
        save(&c, &dir).unwrap();
        let dest = dir.join("tiny.fncorpus");
        let (summary, skipped) =
            stream_to_fncorpus(&dir.join("docword.tiny.txt"), None, "tiny", &dest).unwrap();
        assert_eq!(skipped, 0);
        assert_eq!(summary.num_docs, c.num_docs());
        assert_eq!(summary.num_tokens, c.num_tokens());
        let back = Corpus::load_fncorpus_ram(&dest).unwrap();
        assert_eq!(back.num_tokens(), c.num_tokens());
        let _ = std::fs::remove_dir_all(dir);
    }
}
