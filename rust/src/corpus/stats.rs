//! Corpus statistics — regenerates Table 3 for our presets (and for real
//! UCI dumps dropped into `data/`).

use super::Corpus;

/// The Table 3 row for one corpus.
#[derive(Clone, Debug, PartialEq)]
pub struct CorpusStats {
    pub name: String,
    pub num_docs: usize,
    pub vocab: usize,
    /// vocabulary entries that actually occur
    pub vocab_used: usize,
    pub num_tokens: usize,
    pub avg_doc_len: f64,
    pub max_doc_len: usize,
    /// average distinct words per document (drives |T_d|)
    pub avg_distinct_per_doc: f64,
    /// average occurrences per used word (drives |T_w|)
    pub avg_occ_per_word: f64,
}

impl CorpusStats {
    pub fn compute(c: &Corpus) -> Self {
        let mut word_seen = vec![false; c.vocab()];
        let mut distinct_total = 0usize;
        let mut max_doc_len = 0usize;
        let mut scratch: Vec<u32> = Vec::new();
        let mut sweep = c.docs_in(0..c.num_docs());
        while let Some((_, d)) = sweep.next_doc() {
            max_doc_len = max_doc_len.max(d.len());
            scratch.clear();
            scratch.extend_from_slice(d);
            scratch.sort_unstable();
            scratch.dedup();
            distinct_total += scratch.len();
            for &w in &scratch {
                word_seen[w as usize] = true;
            }
        }
        let vocab_used = word_seen.iter().filter(|&&b| b).count();
        let num_tokens = c.num_tokens();
        let num_docs = c.num_docs();
        CorpusStats {
            name: c.name().to_string(),
            num_docs,
            vocab: c.vocab(),
            vocab_used,
            num_tokens,
            avg_doc_len: num_tokens as f64 / num_docs.max(1) as f64,
            max_doc_len,
            avg_distinct_per_doc: distinct_total as f64 / num_docs.max(1) as f64,
            avg_occ_per_word: num_tokens as f64 / vocab_used.max(1) as f64,
        }
    }

    /// Render one aligned row (header via [`header`]).
    pub fn row(&self) -> Vec<String> {
        vec![
            self.name.clone(),
            self.num_docs.to_string(),
            self.vocab.to_string(),
            self.num_tokens.to_string(),
            format!("{:.1}", self.avg_doc_len),
            format!("{:.1}", self.avg_distinct_per_doc),
            format!("{:.1}", self.avg_occ_per_word),
        ]
    }

    pub fn header() -> Vec<&'static str> {
        vec!["dataset", "docs(I)", "vocab(J)", "tokens", "tok/doc", "|T_d|~", "occ/word"]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::tests::tiny;

    #[test]
    fn stats_of_tiny() {
        let s = CorpusStats::compute(&tiny());
        assert_eq!(s.num_docs, 3);
        assert_eq!(s.num_tokens, 9);
        assert_eq!(s.vocab, 4);
        assert_eq!(s.vocab_used, 4);
        assert_eq!(s.max_doc_len, 4);
        assert!((s.avg_doc_len - 3.0).abs() < 1e-12);
        // distinct: doc0 {0,1,2}=3, doc1 {2,3}=2, doc2 {0,3}=2 → 7/3
        assert!((s.avg_distinct_per_doc - 7.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn unused_vocab_counted() {
        let mut c = tiny();
        c.vocab = 10;
        let s = CorpusStats::compute(&c);
        assert_eq!(s.vocab, 10);
        assert_eq!(s.vocab_used, 4);
    }
}
