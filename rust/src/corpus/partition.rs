//! Document partitioning across workers (§4.1 "Data Partition and Subtask
//! Split"): worker `l` owns document set `D_l`; partitions are balanced by
//! *token count* (not doc count) since per-doc work is proportional to
//! length — poor balance is exactly the "curse of the last reducer" the
//! asynchronous design avoids amplifying.

use super::Corpus;

/// A contiguous document partition for one worker.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Partition {
    /// half-open doc-id ranges [start, end) per worker
    pub ranges: Vec<(usize, usize)>,
}

impl Partition {
    /// Greedy contiguous split targeting equal token mass per worker.
    pub fn by_tokens(corpus: &Corpus, workers: usize) -> Partition {
        assert!(workers >= 1);
        let total: usize = corpus.num_tokens();
        let target = total as f64 / workers as f64;
        let mut ranges = Vec::with_capacity(workers);
        let mut start = 0usize;
        let mut acc = 0usize;
        let mut consumed = 0usize;
        // doc lengths come from the RAM-resident offset table, so
        // partitioning a disk-backed corpus touches no payload bytes
        for i in 0..corpus.num_docs() {
            acc += corpus.doc_len(i);
            // close the range when we pass the proportional boundary,
            // keeping enough docs for the remaining workers
            let boundary = (ranges.len() + 1) as f64 * target;
            let docs_left = corpus.num_docs() - (i + 1);
            let workers_left = workers - ranges.len() - 1;
            if ranges.len() < workers - 1
                && (consumed + acc) as f64 >= boundary
                && docs_left >= workers_left
            {
                ranges.push((start, i + 1));
                start = i + 1;
                consumed += acc;
                acc = 0;
            }
        }
        ranges.push((start, corpus.num_docs()));
        while ranges.len() < workers {
            // degenerate corpora (fewer docs than workers): empty ranges
            let end = corpus.num_docs();
            ranges.push((end, end));
        }
        Partition { ranges }
    }

    pub fn num_workers(&self) -> usize {
        self.ranges.len()
    }

    /// Which worker owns doc `i` — O(log workers) binary search over the
    /// sorted range starts.
    ///
    /// The ranges are contiguous and ordered, so the last range whose
    /// start is `<= doc` is the only candidate that can contain it (empty
    /// ranges share a start with their successor but contain nothing, and
    /// a doc they "start at" is always owned by a later non-empty range).
    pub fn owner_of(&self, doc: usize) -> usize {
        let idx = self
            .ranges
            .partition_point(|&(s, _)| s <= doc)
            .checked_sub(1)
            .expect("doc not covered by partition");
        let (s, e) = self.ranges[idx];
        assert!(doc >= s && doc < e, "doc not covered by partition");
        idx
    }

    /// Token mass per worker (O(1) per range under CSR).
    pub fn loads(&self, corpus: &Corpus) -> Vec<usize> {
        self.ranges
            .iter()
            .map(|&(s, e)| corpus.offsets()[e] - corpus.offsets()[s])
            .collect()
    }

    /// Verify coverage: ranges are disjoint, ordered, and cover all docs.
    pub fn validate(&self, corpus: &Corpus) -> Result<(), String> {
        let mut expect = 0usize;
        for &(s, e) in &self.ranges {
            if s != expect {
                return Err(format!("gap/overlap at doc {expect}: range starts {s}"));
            }
            if e < s {
                return Err(format!("inverted range ({s}, {e})"));
            }
            expect = e;
        }
        if expect != corpus.num_docs() {
            return Err(format!("covers {expect} of {} docs", corpus.num_docs()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, SyntheticSpec};
    use crate::util::quickcheck::check;

    fn corpus(n: usize, seed: u64) -> Corpus {
        generate(&SyntheticSpec {
            num_docs: n,
            vocab: 100,
            avg_doc_len: 25.0,
            true_topics: 4,
            seed,
            ..Default::default()
        })
    }

    #[test]
    fn covers_and_balances() {
        let c = corpus(500, 1);
        for workers in [1, 2, 3, 8, 20] {
            let p = Partition::by_tokens(&c, workers);
            p.validate(&c).unwrap();
            assert_eq!(p.num_workers(), workers);
            let loads = p.loads(&c);
            let total: usize = loads.iter().sum();
            assert_eq!(total, c.num_tokens());
            let target = total as f64 / workers as f64;
            for &l in &loads {
                assert!(
                    (l as f64) < 1.5 * target + 60.0,
                    "load {l} vs target {target} ({workers} workers)"
                );
            }
        }
    }

    #[test]
    fn owner_of_is_consistent() {
        let c = corpus(100, 2);
        let p = Partition::by_tokens(&c, 7);
        for doc in 0..c.num_docs() {
            let w = p.owner_of(doc);
            let (s, e) = p.ranges[w];
            assert!(doc >= s && doc < e);
        }
    }

    #[test]
    fn more_workers_than_docs() {
        let c = corpus(3, 3);
        let p = Partition::by_tokens(&c, 8);
        p.validate(&c).unwrap();
        assert_eq!(p.num_workers(), 8);
        let covered: usize = p.ranges.iter().map(|&(s, e)| e - s).sum();
        assert_eq!(covered, 3);
    }

    #[test]
    fn partition_property_random_worker_counts() {
        check("partition covers corpus for random worker counts", 24, |rng| {
            let n = 1 + rng.below(300);
            let workers = 1 + rng.below(24);
            let c = corpus(n, rng.next_u64());
            let p = Partition::by_tokens(&c, workers);
            p.validate(&c).map_err(|e| format!("n={n} w={workers}: {e}"))
        });
    }

    #[test]
    fn owner_of_matches_linear_scan() {
        // the binary search must agree with the O(workers) scan it
        // replaced for every doc, including partitions with empty
        // trailing ranges (more workers than docs)
        check("owner_of == linear scan", 24, |rng| {
            let n = 1 + rng.below(200);
            let workers = 1 + rng.below(24);
            let c = corpus(n, rng.next_u64());
            let p = Partition::by_tokens(&c, workers);
            for doc in 0..c.num_docs() {
                let linear = p
                    .ranges
                    .iter()
                    .position(|&(s, e)| doc >= s && doc < e)
                    .ok_or_else(|| format!("doc {doc} uncovered (n={n} w={workers})"))?;
                let fast = p.owner_of(doc);
                if fast != linear {
                    return Err(format!(
                        "doc {doc}: owner_of {fast} != linear {linear} \
                         (n={n} w={workers} ranges={:?})",
                        p.ranges
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "doc not covered by partition")]
    fn owner_of_panics_past_the_last_doc() {
        let c = corpus(10, 4);
        let p = Partition::by_tokens(&c, 3);
        let _ = p.owner_of(c.num_docs());
    }
}
