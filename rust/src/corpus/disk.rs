//! `FNCP0001`: the versioned on-disk CSR corpus format, plus the
//! windowed `pread` reader that lets training stream token payloads
//! without materializing them.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! offset 0   magic          8 bytes   "FNCP0001"
//! offset 8   num_docs       u64
//! offset 16  num_tokens     u64
//! offset 24  vocab          u64
//! offset 32  name_len       u32       followed by name_len UTF-8 bytes
//! ...        flags          u32       bit 0: vocab-strings section present
//! ...        offset table   (num_docs + 1) x u64   CSR doc boundaries
//! ...        token payload  num_tokens x u32
//! ...        vocab strings  vocab x (u32 len + UTF-8 bytes)   iff flags bit 0
//! last 8     fingerprint    u64       FNV-1a of every preceding byte
//! ```
//!
//! Files are written atomically through [`AtomicFile`] (temp sibling +
//! fsync + rename), so a crashed `prepare-corpus` never leaves a torn
//! `.fncorpus` behind.  The trailer fingerprint is computed over the
//! header, offset table, payload, and vocab section; [`load_ram`]
//! verifies it before trusting the bytes.  The streaming [`open`] path
//! validates everything *structural* (magic, section lengths against the
//! file length, offset-table monotonicity — which also proves no empty
//! documents) but deliberately does not hash the payload, because
//! hashing would read the whole file and defeat out-of-core startup;
//! token ids are instead bounds-checked against the vocab as each read
//! window is decoded.
//!
//! The offset table and vocab strings stay RAM-resident (they are
//! `O(num_docs)` / `O(vocab)`, small next to the payload); only token
//! bytes stream.  [`resident_corpus_bytes`] / [`peak_resident_corpus_bytes`]
//! account the token bytes currently buffered from disk-backed corpora,
//! which is what the out-of-core test caps.

use std::fs::File;
use std::io::{BufWriter, Read, Write};
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::Arc;

// `static_atomic`, not the swappable shim atomics: these counters live in
// `static` items (process-global accounting), and loom's atomics are not
// const-constructible.  The residency gauges are therefore std under
// every cfg and outside the loom models' scope — by design; their
// protocol is a plain monotone gauge with no cross-variable invariant.
use crate::util::sync::static_atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::util::fsio::{AtomicFile, Fnv1a};

/// Magic + version prefix of every `.fncorpus` file.
pub const FNCORPUS_MAGIC: &[u8; 8] = b"FNCP0001";

/// Fixed-size header prefix: magic + num_docs + num_tokens + vocab + name_len.
const FIXED_HEADER: u64 = 8 + 8 + 8 + 8 + 4;

/// IO chunk for payload copies and streamed hashing.
const IO_CHUNK: usize = 64 * 1024;

// ---------------------------------------------------------------------------
// resident-bytes accounting
// ---------------------------------------------------------------------------

// Ordering audit: SeqCst throughout, deliberately.  PEAK is derived from
// RESIDENT (a read of one feeds a write of the other), so this is a
// *two-variable* protocol — the one shape where `Relaxed` genuinely loses
// updates across threads and even Acquire/Release offers no single total
// order to reason about.  The peak is test-asserted (the out_of_core
// residency cap), so "approximately right" is not acceptable; these
// counters are touched once per read-window slide, where a SeqCst fence
// costs nothing measurable next to the pread it accounts for.
static RESIDENT: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

fn add_resident(n: usize) {
    let now = RESIDENT.fetch_add(n, Ordering::SeqCst) + n;
    PEAK.fetch_max(now, Ordering::SeqCst);
}

fn sub_resident(n: usize) {
    RESIDENT.fetch_sub(n, Ordering::SeqCst);
}

/// Record a short-lived buffer (e.g. a single [`Corpus::doc`] read) in the
/// peak without tracking its drop.
///
/// [`Corpus::doc`]: super::Corpus::doc
pub(crate) fn note_transient(bytes: usize) {
    PEAK.fetch_max(RESIDENT.load(Ordering::SeqCst) + bytes, Ordering::SeqCst);
}

/// Token bytes currently buffered in RAM from disk-backed corpora.
pub fn resident_corpus_bytes() -> usize {
    RESIDENT.load(Ordering::SeqCst)
}

/// High-water mark of [`resident_corpus_bytes`] since the last reset.
pub fn peak_resident_corpus_bytes() -> usize {
    PEAK.load(Ordering::SeqCst)
}

/// Reset the peak to the current residency (for before/after measurements).
pub fn reset_peak_resident_corpus_bytes() {
    PEAK.store(RESIDENT.load(Ordering::SeqCst), Ordering::SeqCst);
}

/// A token buffer whose capacity is charged against the resident-bytes
/// accounting for as long as it lives.  The sliding read window of a
/// disk-backed sweep is one of these.
#[derive(Debug, Default)]
pub(crate) struct TrackedBuf {
    data: Vec<u32>,
    accounted: usize,
}

impl TrackedBuf {
    pub(crate) fn new() -> TrackedBuf {
        TrackedBuf { data: Vec::new(), accounted: 0 }
    }

    /// Replace the contents with `count` tokens starting at flat token
    /// index `tok_start`.
    pub(crate) fn fill(&mut self, csr: &DiskCsr, tok_start: usize, count: usize) {
        self.data.clear();
        self.data.reserve(count);
        csr.try_read_tokens_into(tok_start, count, &mut self.data)
            .unwrap_or_else(|e| panic!("{e}"));
        let cap = self.data.capacity() * std::mem::size_of::<u32>();
        if cap > self.accounted {
            add_resident(cap - self.accounted);
        } else if cap < self.accounted {
            sub_resident(self.accounted - cap);
        }
        self.accounted = cap;
    }

    pub(crate) fn as_slice(&self) -> &[u32] {
        &self.data
    }
}

impl Drop for TrackedBuf {
    fn drop(&mut self) {
        sub_resident(self.accounted);
    }
}

// ---------------------------------------------------------------------------
// writer
// ---------------------------------------------------------------------------

/// What a completed write looked like, for logs and manifests.
#[derive(Debug, Clone, Copy)]
pub struct FncorpusSummary {
    pub num_docs: usize,
    pub num_tokens: usize,
    /// Total file size in bytes, trailer included.
    pub bytes: u64,
    /// FNV-1a fingerprint stored in the trailer.
    pub fingerprint: u64,
}

/// Discriminator for payload temp names (mirrors `fsio`'s temp scheme).
static PAYLOAD_SEQ: AtomicU64 = AtomicU64::new(0);

/// Streaming `FNCP0001` writer: documents go to a temp payload file one
/// at a time (bounded memory — only the offset table accumulates in
/// RAM), and [`finish`] assembles the final file atomically.
///
/// Empty documents and out-of-vocab token ids are rejected at
/// [`push_doc`] time, so a committed file can never violate the corpus
/// invariants.
///
/// [`push_doc`]: FncorpusWriter::push_doc
/// [`finish`]: FncorpusWriter::finish
pub struct FncorpusWriter {
    dest: PathBuf,
    tmp: PathBuf,
    payload: Option<BufWriter<File>>,
    offsets: Vec<u64>,
    vocab: usize,
    vocab_words: Vec<String>,
    name: String,
}

impl FncorpusWriter {
    /// Open a writer targeting `dest`.  `vocab_words` is either empty
    /// (no vocab-strings section) or exactly `vocab` entries.
    pub fn create(
        dest: &Path,
        vocab: usize,
        vocab_words: Vec<String>,
        name: &str,
    ) -> Result<FncorpusWriter, String> {
        if !vocab_words.is_empty() && vocab_words.len() != vocab {
            return Err(format!(
                "FNCP0001: vocab-strings section has {} entries but vocab is {vocab}",
                vocab_words.len()
            ));
        }
        if let Some(dir) = dest.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).map_err(|e| format!("create {}: {e}", dir.display()))?;
            }
        }
        // relaxed: only uniqueness matters, which atomicity alone gives —
        // no other memory is published under this counter
        let seq = PAYLOAD_SEQ.fetch_add(1, Ordering::Relaxed);
        let mut tmp_name = dest.as_os_str().to_os_string();
        tmp_name.push(format!(".payload-{}-{seq}", std::process::id()));
        let tmp = PathBuf::from(tmp_name);
        let file = File::create(&tmp).map_err(|e| format!("{}: {e}", tmp.display()))?;
        Ok(FncorpusWriter {
            dest: dest.to_path_buf(),
            tmp,
            payload: Some(BufWriter::new(file)),
            offsets: vec![0],
            vocab,
            vocab_words,
            name: name.to_string(),
        })
    }

    pub fn num_docs(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn num_tokens(&self) -> usize {
        *self.offsets.last().unwrap() as usize
    }

    /// Append one document.  Returns a named error (and writes nothing)
    /// for an empty document or a token id outside the vocab.
    pub fn push_doc(&mut self, tokens: &[u32]) -> Result<(), String> {
        let doc = self.num_docs();
        if tokens.is_empty() {
            return Err(format!(
                "FNCP0001: refusing to write empty document {doc} to {}",
                self.dest.display()
            ));
        }
        if let Some(&w) = tokens.iter().find(|&&w| w as usize >= self.vocab) {
            return Err(format!(
                "FNCP0001: document {doc} has token id {w} >= vocab {} in {}",
                self.vocab,
                self.dest.display()
            ));
        }
        let payload = self.payload.as_mut().expect("push_doc after finish");
        let mut buf = [0u8; 4 * 1024];
        for chunk in tokens.chunks(buf.len() / 4) {
            let mut n = 0;
            for &w in chunk {
                buf[n..n + 4].copy_from_slice(&w.to_le_bytes());
                n += 4;
            }
            payload
                .write_all(&buf[..n])
                .map_err(|e| format!("{}: {e}", self.tmp.display()))?;
        }
        let end = self.offsets.last().unwrap() + tokens.len() as u64;
        self.offsets.push(end);
        Ok(())
    }

    /// Assemble header + offsets + payload + vocab strings + trailer and
    /// atomically commit the destination file.
    pub fn finish(mut self) -> Result<FncorpusSummary, String> {
        let mut payload = self.payload.take().expect("finish called once");
        payload.flush().map_err(|e| format!("{}: {e}", self.tmp.display()))?;
        drop(payload);

        let num_docs = self.offsets.len() as u64 - 1;
        let num_tokens = *self.offsets.last().unwrap();

        let mut af = AtomicFile::create(&self.dest)?;
        // The trailer is the hash of everything before it, so it cannot
        // come from AtomicFile's own fingerprint (which would include the
        // trailer bytes themselves): mirror every section through a
        // second hasher and write its digest last.
        let mut mirror = Fnv1a::new();
        let dest = self.dest.clone();
        let emit = |af: &mut AtomicFile, mirror: &mut Fnv1a, bytes: &[u8]| -> Result<(), String> {
            af.write_all(bytes).map_err(|e| format!("{}: {e}", dest.display()))?;
            mirror.update(bytes);
            Ok(())
        };

        let mut header = Vec::with_capacity(FIXED_HEADER as usize + self.name.len() + 4);
        header.extend_from_slice(FNCORPUS_MAGIC);
        header.extend_from_slice(&num_docs.to_le_bytes());
        header.extend_from_slice(&num_tokens.to_le_bytes());
        header.extend_from_slice(&(self.vocab as u64).to_le_bytes());
        header.extend_from_slice(&(self.name.len() as u32).to_le_bytes());
        header.extend_from_slice(self.name.as_bytes());
        let flags: u32 = if self.vocab_words.is_empty() { 0 } else { 1 };
        header.extend_from_slice(&flags.to_le_bytes());
        emit(&mut af, &mut mirror, &header)?;
        let mut total = header.len() as u64;

        let mut buf = Vec::with_capacity(IO_CHUNK);
        for &o in &self.offsets {
            buf.extend_from_slice(&o.to_le_bytes());
            if buf.len() >= IO_CHUNK {
                emit(&mut af, &mut mirror, &buf)?;
                total += buf.len() as u64;
                buf.clear();
            }
        }
        emit(&mut af, &mut mirror, &buf)?;
        total += buf.len() as u64;

        let mut src = File::open(&self.tmp).map_err(|e| format!("{}: {e}", self.tmp.display()))?;
        let mut chunk = [0u8; IO_CHUNK];
        let mut copied = 0u64;
        loop {
            let n = src.read(&mut chunk).map_err(|e| format!("{}: {e}", self.tmp.display()))?;
            if n == 0 {
                break;
            }
            emit(&mut af, &mut mirror, &chunk[..n])?;
            copied += n as u64;
        }
        if copied != num_tokens * 4 {
            return Err(format!(
                "FNCP0001: payload temp holds {copied} bytes but the offset table expects {}",
                num_tokens * 4
            ));
        }
        total += copied;

        buf.clear();
        for w in &self.vocab_words {
            buf.extend_from_slice(&(w.len() as u32).to_le_bytes());
            buf.extend_from_slice(w.as_bytes());
            if buf.len() >= IO_CHUNK {
                emit(&mut af, &mut mirror, &buf)?;
                total += buf.len() as u64;
                buf.clear();
            }
        }
        emit(&mut af, &mut mirror, &buf)?;
        total += buf.len() as u64;

        let fingerprint = mirror.finish();
        af.write_all(&fingerprint.to_le_bytes())
            .map_err(|e| format!("{}: {e}", self.dest.display()))?;
        total += 8;
        af.commit()?;

        Ok(FncorpusSummary {
            num_docs: num_docs as usize,
            num_tokens: num_tokens as usize,
            bytes: total,
            fingerprint,
        })
    }
}

impl Drop for FncorpusWriter {
    fn drop(&mut self) {
        drop(self.payload.take());
        let _ = std::fs::remove_file(&self.tmp);
    }
}

// ---------------------------------------------------------------------------
// reader
// ---------------------------------------------------------------------------

/// Handle on the token payload of an open `.fncorpus` file.  Reads go
/// through positioned `pread` ([`FileExt::read_at`]) on a shared `File`,
/// so clones and concurrent sweeps never contend on a seek cursor.
#[derive(Debug, Clone)]
pub struct DiskCsr {
    file: Arc<File>,
    path: Arc<PathBuf>,
    payload_base: u64,
    num_tokens: usize,
    vocab: usize,
    window_tokens: usize,
}

impl DiskCsr {
    pub(crate) fn window_tokens(&self) -> usize {
        self.window_tokens
    }

    pub(crate) fn path(&self) -> &Path {
        &self.path
    }

    /// Decode `count` tokens starting at flat index `tok_start`,
    /// appending to `out`.  Token ids are bounds-checked against the
    /// vocab as they are decoded.
    pub(crate) fn try_read_tokens_into(
        &self,
        tok_start: usize,
        count: usize,
        out: &mut Vec<u32>,
    ) -> Result<(), String> {
        assert!(
            tok_start + count <= self.num_tokens,
            "token range {}..{} out of bounds for {} tokens",
            tok_start,
            tok_start + count,
            self.num_tokens
        );
        out.reserve(count);
        let mut raw = [0u8; IO_CHUNK];
        let mut off = self.payload_base + tok_start as u64 * 4;
        let mut remaining = count * 4;
        let mut tok_idx = tok_start;
        while remaining > 0 {
            let n = remaining.min(raw.len());
            self.file.read_exact_at(&mut raw[..n], off).map_err(|e| {
                format!("FNCP0001: read failed at byte {off} of {}: {e}", self.path.display())
            })?;
            for quad in raw[..n].chunks_exact(4) {
                let w = u32::from_le_bytes([quad[0], quad[1], quad[2], quad[3]]);
                if w as usize >= self.vocab {
                    return Err(format!(
                        "FNCP0001: token id {w} >= vocab {} at token {tok_idx} in {}",
                        self.vocab,
                        self.path.display()
                    ));
                }
                out.push(w);
                tok_idx += 1;
            }
            off += n as u64;
            remaining -= n;
        }
        Ok(())
    }
}

/// Everything [`open`] learns about a file: the payload handle plus the
/// RAM-resident metadata sections.
pub(crate) struct Opened {
    pub csr: DiskCsr,
    pub doc_offsets: Vec<usize>,
    pub vocab: usize,
    pub vocab_words: Vec<String>,
    pub name: String,
}

fn read_exact(file: &File, off: u64, len: usize, path: &Path) -> Result<Vec<u8>, String> {
    let mut buf = vec![0u8; len];
    file.read_exact_at(&mut buf, off)
        .map_err(|e| format!("FNCP0001: read failed at byte {off} of {}: {e}", path.display()))?;
    Ok(buf)
}

fn get_u64(b: &[u8], at: usize) -> u64 {
    u64::from_le_bytes(b[at..at + 8].try_into().unwrap())
}

fn get_u32(b: &[u8], at: usize) -> u32 {
    u32::from_le_bytes(b[at..at + 4].try_into().unwrap())
}

/// Open a `.fncorpus` for windowed streaming access.  Validates the
/// header, section lengths, and offset-table invariants; does *not*
/// read or hash the token payload (see the module docs).
pub(crate) fn open(path: &Path, window_tokens: usize) -> Result<Opened, String> {
    let file = File::open(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let flen = file
        .metadata()
        .map_err(|e| format!("{}: {e}", path.display()))?
        .len();
    if flen < FIXED_HEADER {
        return Err(format!(
            "FNCP0001: {} is truncated ({flen} bytes, header alone needs {FIXED_HEADER})",
            path.display()
        ));
    }
    let head = read_exact(&file, 0, FIXED_HEADER as usize, path)?;
    if &head[..8] != FNCORPUS_MAGIC {
        return Err(format!(
            "FNCP0001: bad magic in {} (not an .fncorpus file)",
            path.display()
        ));
    }
    let num_docs = get_u64(&head, 8);
    let num_tokens = get_u64(&head, 16);
    let vocab = get_u64(&head, 24);
    let name_len = get_u32(&head, 32) as u64;
    if name_len > 4096 {
        return Err(format!(
            "FNCP0001: unreasonable corpus-name length {name_len} in {}",
            path.display()
        ));
    }

    let offsets_bytes = num_docs
        .checked_add(1)
        .and_then(|n| n.checked_mul(8))
        .ok_or_else(|| format!("FNCP0001: absurd num_docs {num_docs} in {}", path.display()))?;
    let payload_bytes = num_tokens
        .checked_mul(4)
        .ok_or_else(|| format!("FNCP0001: absurd num_tokens {num_tokens} in {}", path.display()))?;
    let header_end = FIXED_HEADER + name_len + 4;
    let payload_base = header_end + offsets_bytes;
    let vocab_base = payload_base + payload_bytes;
    // trailer must fit even before we know the vocab section's size
    if vocab_base.checked_add(8).is_none() || vocab_base + 8 > flen {
        return Err(format!(
            "FNCP0001: {} is truncated ({flen} bytes, layout needs at least {})",
            path.display(),
            vocab_base.saturating_add(8)
        ));
    }

    let tail = read_exact(&file, header_end - name_len - 4, (name_len + 4) as usize, path)?;
    let name = String::from_utf8(tail[..name_len as usize].to_vec())
        .map_err(|_| format!("FNCP0001: corpus name is not UTF-8 in {}", path.display()))?;
    let flags = get_u32(&tail, name_len as usize);
    if flags & !1 != 0 {
        return Err(format!("FNCP0001: unknown flags {flags:#x} in {}", path.display()));
    }

    let raw_offsets = read_exact(&file, header_end, offsets_bytes as usize, path)?;
    let mut doc_offsets = Vec::with_capacity(num_docs as usize + 1);
    for quad in raw_offsets.chunks_exact(8) {
        doc_offsets.push(u64::from_le_bytes(quad.try_into().unwrap()) as usize);
    }
    if doc_offsets[0] != 0 {
        return Err(format!(
            "FNCP0001: offset table must start at 0 (got {}) in {}",
            doc_offsets[0],
            path.display()
        ));
    }
    for i in 1..doc_offsets.len() {
        if doc_offsets[i] <= doc_offsets[i - 1] {
            return Err(format!(
                "FNCP0001: document {} is empty or the offset table is unordered in {}",
                i - 1,
                path.display()
            ));
        }
    }
    if *doc_offsets.last().unwrap() as u64 != num_tokens {
        return Err(format!(
            "FNCP0001: offset table ends at {} but the header says {num_tokens} tokens in {}",
            doc_offsets.last().unwrap(),
            path.display()
        ));
    }

    let vocab_words = if flags & 1 == 1 {
        let region_len = (flen - 8 - vocab_base) as usize;
        let region = read_exact(&file, vocab_base, region_len, path)?;
        let mut words = Vec::with_capacity(vocab as usize);
        let mut at = 0usize;
        for _ in 0..vocab {
            if at + 4 > region.len() {
                return Err(format!(
                    "FNCP0001: vocab-strings section is truncated in {}",
                    path.display()
                ));
            }
            let wlen = get_u32(&region, at) as usize;
            at += 4;
            if at + wlen > region.len() {
                return Err(format!(
                    "FNCP0001: vocab-strings section is truncated in {}",
                    path.display()
                ));
            }
            let word = String::from_utf8(region[at..at + wlen].to_vec()).map_err(|_| {
                format!("FNCP0001: vocab word {} is not UTF-8 in {}", words.len(), path.display())
            })?;
            at += wlen;
            words.push(word);
        }
        if at != region.len() {
            return Err(format!(
                "FNCP0001: {} trailing bytes after the vocab-strings section in {}",
                region.len() - at,
                path.display()
            ));
        }
        words
    } else {
        if flen != vocab_base + 8 {
            return Err(format!(
                "FNCP0001: file length mismatch in {}: {flen} bytes but the layout ends at {}",
                path.display(),
                vocab_base + 8
            ));
        }
        Vec::new()
    };

    Ok(Opened {
        csr: DiskCsr {
            file: Arc::new(file),
            path: Arc::new(path.to_path_buf()),
            payload_base,
            num_tokens: num_tokens as usize,
            vocab: vocab as usize,
            window_tokens: window_tokens.max(1),
        },
        doc_offsets,
        vocab: vocab as usize,
        vocab_words,
        name,
    })
}

/// Fully-decoded corpus parts, for the explicit load-to-RAM path.
pub(crate) struct RamLoaded {
    pub tokens: Vec<u32>,
    pub doc_offsets: Vec<usize>,
    pub vocab: usize,
    pub vocab_words: Vec<String>,
    pub name: String,
}

/// Load a `.fncorpus` entirely into RAM, verifying the trailer
/// fingerprint over the whole file first.
pub(crate) fn load_ram(path: &Path) -> Result<RamLoaded, String> {
    let opened = open(path, 1)?;
    let flen = opened
        .csr
        .file
        .metadata()
        .map_err(|e| format!("{}: {e}", path.display()))?
        .len();
    let stored = {
        let t = read_exact(&opened.csr.file, flen - 8, 8, path)?;
        get_u64(&t, 0)
    };
    let mut hash = Fnv1a::new();
    let mut chunk = [0u8; IO_CHUNK];
    let mut off = 0u64;
    while off < flen - 8 {
        let n = ((flen - 8 - off) as usize).min(chunk.len());
        opened.csr.file.read_exact_at(&mut chunk[..n], off).map_err(|e| {
            format!("FNCP0001: read failed at byte {off} of {}: {e}", path.display())
        })?;
        hash.update(&chunk[..n]);
        off += n as u64;
    }
    let computed = hash.finish();
    if computed != stored {
        return Err(format!(
            "FNCP0001: fingerprint mismatch in {} (stored {stored:#018x}, computed {computed:#018x}) — file is corrupt",
            path.display()
        ));
    }
    let mut tokens = Vec::with_capacity(opened.csr.num_tokens);
    opened
        .csr
        .try_read_tokens_into(0, opened.csr.num_tokens, &mut tokens)?;
    Ok(RamLoaded {
        tokens,
        doc_offsets: opened.doc_offsets,
        vocab: opened.vocab,
        vocab_words: opened.vocab_words,
        name: opened.name,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("fnomad_fncp_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn write_tiny(path: &Path, vocab_words: Vec<String>) -> FncorpusSummary {
        let mut w = FncorpusWriter::create(path, 4, vocab_words, "tiny").unwrap();
        w.push_doc(&[0, 1, 1, 2]).unwrap();
        w.push_doc(&[2, 2, 3]).unwrap();
        w.push_doc(&[0, 3]).unwrap();
        w.finish().unwrap()
    }

    /// Pin the exact byte layout: a reference file is assembled by hand
    /// (the same convention as the FNLDA001 golden-bytes test) and must
    /// match the writer's output bit for bit.
    #[test]
    fn golden_bytes_layout_pin() {
        let path = tmp("golden.fncorpus");
        write_tiny(&path, Vec::new());
        let got = std::fs::read(&path).unwrap();

        let mut want: Vec<u8> = Vec::new();
        want.extend_from_slice(b"FNCP0001");
        want.extend_from_slice(&3u64.to_le_bytes()); // num_docs
        want.extend_from_slice(&9u64.to_le_bytes()); // num_tokens
        want.extend_from_slice(&4u64.to_le_bytes()); // vocab
        want.extend_from_slice(&4u32.to_le_bytes()); // name_len
        want.extend_from_slice(b"tiny");
        want.extend_from_slice(&0u32.to_le_bytes()); // flags: no vocab strings
        for o in [0u64, 4, 7, 9] {
            want.extend_from_slice(&o.to_le_bytes());
        }
        for t in [0u32, 1, 1, 2, 2, 2, 3, 0, 3] {
            want.extend_from_slice(&t.to_le_bytes());
        }
        let mut h = Fnv1a::new();
        h.update(&want);
        want.extend_from_slice(&h.finish().to_le_bytes());

        assert_eq!(got, want, "FNCP0001 byte layout drifted");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn roundtrip_with_vocab_strings() {
        let path = tmp("roundtrip.fncorpus");
        let words: Vec<String> = ["alpha", "beta", "gamma", "delta"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let summary = write_tiny(&path, words.clone());
        assert_eq!(summary.num_docs, 3);
        assert_eq!(summary.num_tokens, 9);
        assert_eq!(summary.bytes, std::fs::metadata(&path).unwrap().len());

        let opened = open(&path, 1 << 20).unwrap();
        assert_eq!(opened.doc_offsets, vec![0, 4, 7, 9]);
        assert_eq!(opened.vocab, 4);
        assert_eq!(opened.vocab_words, words);
        assert_eq!(opened.name, "tiny");
        let mut toks = Vec::new();
        opened.csr.try_read_tokens_into(0, 9, &mut toks).unwrap();
        assert_eq!(toks, vec![0, 1, 1, 2, 2, 2, 3, 0, 3]);
        // partial window read
        toks.clear();
        opened.csr.try_read_tokens_into(4, 3, &mut toks).unwrap();
        assert_eq!(toks, vec![2, 2, 3]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn roundtrip_empty_vocab_section_via_ram_load() {
        let path = tmp("novocab.fncorpus");
        write_tiny(&path, Vec::new());
        let loaded = load_ram(&path).unwrap();
        assert_eq!(loaded.tokens, vec![0, 1, 1, 2, 2, 2, 3, 0, 3]);
        assert_eq!(loaded.doc_offsets, vec![0, 4, 7, 9]);
        assert_eq!(loaded.vocab, 4);
        assert!(loaded.vocab_words.is_empty());
        assert_eq!(loaded.name, "tiny");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn roundtrip_single_doc() {
        let path = tmp("onedoc.fncorpus");
        let mut w = FncorpusWriter::create(&path, 2, Vec::new(), "one").unwrap();
        w.push_doc(&[1]).unwrap();
        let summary = w.finish().unwrap();
        assert_eq!(summary.num_docs, 1);
        assert_eq!(summary.num_tokens, 1);
        let loaded = load_ram(&path).unwrap();
        assert_eq!(loaded.tokens, vec![1]);
        assert_eq!(loaded.doc_offsets, vec![0, 1]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn writer_rejects_empty_doc() {
        let path = tmp("wempty.fncorpus");
        let mut w = FncorpusWriter::create(&path, 4, Vec::new(), "x").unwrap();
        let err = w.push_doc(&[]).unwrap_err();
        assert!(err.contains("empty document"), "unnamed error: {err}");
    }

    #[test]
    fn writer_rejects_out_of_vocab_token() {
        let path = tmp("wrange.fncorpus");
        let mut w = FncorpusWriter::create(&path, 4, Vec::new(), "x").unwrap();
        let err = w.push_doc(&[0, 4]).unwrap_err();
        assert!(err.contains(">= vocab"), "unnamed error: {err}");
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmp("badmagic.fncorpus");
        write_tiny(&path, Vec::new());
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] = b'X';
        std::fs::write(&path, &bytes).unwrap();
        let err = open(&path, 1).unwrap_err();
        assert!(err.contains("bad magic"), "unnamed error: {err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_truncated_file() {
        let path = tmp("trunc.fncorpus");
        write_tiny(&path, Vec::new());
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 12]).unwrap();
        let err = open(&path, 1).unwrap_err();
        assert!(
            err.contains("truncated") || err.contains("length mismatch"),
            "unnamed error: {err}"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_trailing_garbage() {
        let path = tmp("garbage.fncorpus");
        write_tiny(&path, Vec::new());
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(b"junk");
        std::fs::write(&path, &bytes).unwrap();
        let err = open(&path, 1).unwrap_err();
        assert!(err.contains("length mismatch"), "unnamed error: {err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_empty_doc_in_offset_table() {
        let path = tmp("emptydoc.fncorpus");
        write_tiny(&path, Vec::new());
        let mut bytes = std::fs::read(&path).unwrap();
        // offset table starts after the 44-byte header ("tiny" name);
        // overwrite entry 1 (value 4) with 0 to fake an empty doc 0
        let table = 44;
        bytes[table + 8..table + 16].copy_from_slice(&0u64.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = open(&path, 1).unwrap_err();
        assert!(err.contains("empty or the offset table"), "unnamed error: {err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_nonzero_first_offset() {
        let path = tmp("offstart.fncorpus");
        write_tiny(&path, Vec::new());
        let mut bytes = std::fs::read(&path).unwrap();
        let table = 44;
        bytes[table..table + 8].copy_from_slice(&1u64.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = open(&path, 1).unwrap_err();
        assert!(err.contains("must start at 0"), "unnamed error: {err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_ram_rejects_fingerprint_mismatch() {
        let path = tmp("corrupt.fncorpus");
        write_tiny(&path, Vec::new());
        let mut bytes = std::fs::read(&path).unwrap();
        // flip one payload byte: structure stays valid, hash does not
        let payload = 44 + 4 * 8;
        bytes[payload] ^= 1;
        std::fs::write(&path, &bytes).unwrap();
        assert!(open(&path, 1).is_ok(), "streaming open does not hash the payload");
        let err = load_ram(&path).unwrap_err();
        assert!(err.contains("fingerprint mismatch"), "unnamed error: {err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn streaming_read_names_out_of_vocab_tokens() {
        let path = tmp("badtok.fncorpus");
        write_tiny(&path, Vec::new());
        let mut bytes = std::fs::read(&path).unwrap();
        let payload = 44 + 4 * 8;
        bytes[payload..payload + 4].copy_from_slice(&99u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let opened = open(&path, 1).unwrap();
        let mut out = Vec::new();
        let err = opened.csr.try_read_tokens_into(0, 9, &mut out).unwrap_err();
        assert!(err.contains(">= vocab"), "unnamed error: {err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn tracked_buf_accounts_resident_bytes() {
        let path = tmp("tracked.fncorpus");
        write_tiny(&path, Vec::new());
        let opened = open(&path, 4).unwrap();
        let before = resident_corpus_bytes();
        {
            let mut buf = TrackedBuf::new();
            buf.fill(&opened.csr, 0, 4);
            assert_eq!(buf.as_slice(), &[0, 1, 1, 2]);
            assert!(
                resident_corpus_bytes() >= before + 16,
                "window bytes not accounted"
            );
        }
        assert_eq!(resident_corpus_bytes(), before, "drop did not release accounting");
        let _ = std::fs::remove_file(&path);
    }
}
