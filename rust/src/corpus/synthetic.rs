//! Synthetic corpus generation from the LDA generative process (§2 of the
//! paper), used as scaled stand-ins for the paper's five datasets (see
//! DESIGN.md §Hardware-Adaptation — the real billion-token crawls are a
//! data gate we substitute).
//!
//! Word frequencies follow a Zipfian base measure so topic-word draws show
//! realistic head/tail behavior, and document lengths are Poisson with a
//! preset mean, matching the docs/vocab/token *ratios* of Table 3.

use crate::util::rng::Pcg32;

use super::Corpus;

/// Generative-process parameters.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub name: String,
    pub num_docs: usize,
    pub vocab: usize,
    /// mean document length (Poisson)
    pub avg_doc_len: f64,
    /// number of *true* generating topics (independent of the T used for
    /// inference)
    pub true_topics: usize,
    /// Dirichlet document-topic concentration
    pub alpha: f64,
    /// Dirichlet topic-word concentration (per-coordinate, scaled by the
    /// Zipf base measure)
    pub beta: f64,
    /// Zipf exponent for the vocabulary base measure
    pub zipf_s: f64,
    pub seed: u64,
}

impl Default for SyntheticSpec {
    fn default() -> Self {
        SyntheticSpec {
            name: "synthetic".into(),
            num_docs: 1000,
            vocab: 2000,
            avg_doc_len: 100.0,
            true_topics: 20,
            alpha: 0.1,
            beta: 0.01,
            zipf_s: 1.07,
            seed: 0,
        }
    }
}

/// Draw a corpus from the LDA generative process, streaming each
/// document to `sink` as it is generated.
///
/// This is the bounded-memory path behind [`generate`]: nothing but the
/// per-topic CDF tables (`true_topics x vocab` f64s) and one document
/// live in RAM, so a billion-token preset can flow straight into an
/// `FNCP0001` writer.  The RNG consumption is identical to [`generate`],
/// so a streamed corpus is bit-identical to the in-RAM one for the same
/// spec.
///
/// Topics are sampled as sparse multinomials via a cumulative-search table
/// per topic; documents mix `true_topics` topics with Dirichlet(alpha)
/// weights.  Empty documents are re-drawn (the paper discards them; at
/// Poisson means ≥ 20 re-draws are vanishingly rare).
pub fn generate_with(
    spec: &SyntheticSpec,
    mut sink: impl FnMut(&[u32]) -> Result<(), String>,
) -> Result<(), String> {
    let mut rng = Pcg32::new(spec.seed, 0xC0FFEE);
    let k = spec.true_topics;
    let j = spec.vocab;

    // Zipfian base measure over words (shuffled so id != rank)
    let mut rank_of: Vec<usize> = (0..j).collect();
    rng.shuffle(&mut rank_of);

    // phi_k ~ Dirichlet(beta * base): approximate the sparse Dirichlet by
    // gamma draws on the Zipf-weighted base measure, stored as cumsum for
    // O(log J) inverse-CDF sampling.
    let mut topic_cdfs: Vec<Vec<f64>> = Vec::with_capacity(k);
    for _ in 0..k {
        let mut cdf = Vec::with_capacity(j);
        let mut acc = 0.0;
        for w in 0..j {
            // base measure proportional to Zipf pmf of the word's rank
            let base = 1.0 / ((rank_of[w] + 1) as f64).powf(spec.zipf_s);
            let g = rng.gamma(spec.beta + 50.0 * base);
            acc += g;
            cdf.push(acc);
        }
        topic_cdfs.push(cdf);
    }

    let mut theta = vec![0.0f64; k];
    let alpha_vec = vec![spec.alpha; k];
    let mut emitted = 0usize;
    let mut doc = Vec::new();
    while emitted < spec.num_docs {
        rng.dirichlet(&alpha_vec, &mut theta);
        let len = rng.poisson(spec.avg_doc_len) as usize;
        if len == 0 {
            continue;
        }
        doc.clear();
        // cumsum of theta for topic draws
        let mut theta_cdf = theta.clone();
        for i in 1..k {
            theta_cdf[i] += theta_cdf[i - 1];
        }
        let theta_total = theta_cdf[k - 1];
        for _ in 0..len {
            let u = rng.uniform(theta_total);
            let z = theta_cdf.partition_point(|&c| c <= u).min(k - 1);
            let cdf = &topic_cdfs[z];
            let total = *cdf.last().unwrap();
            let uw = rng.uniform(total);
            let w = cdf.partition_point(|&c| c <= uw).min(j - 1);
            doc.push(w as u32);
        }
        sink(&doc)?;
        emitted += 1;
    }

    Ok(())
}

/// Draw a corpus from the LDA generative process into RAM (see
/// [`generate_with`] for the streaming variant and the process itself).
pub fn generate(spec: &SyntheticSpec) -> Corpus {
    let mut corpus = Corpus::with_meta(spec.vocab, Vec::new(), spec.name.clone());
    corpus.reserve_tokens((spec.num_docs as f64 * spec.avg_doc_len) as usize);
    generate_with(spec, |d| {
        corpus.push_doc(d);
        Ok(())
    })
    .expect("in-RAM sink cannot fail");
    corpus
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> SyntheticSpec {
        SyntheticSpec {
            name: "test".into(),
            num_docs: 200,
            vocab: 500,
            avg_doc_len: 50.0,
            true_topics: 8,
            seed: 42,
            ..Default::default()
        }
    }

    #[test]
    fn respects_spec_shape() {
        let c = generate(&small_spec());
        assert_eq!(c.num_docs(), 200);
        assert_eq!(c.vocab(), 500);
        c.validate().unwrap();
        let avg = c.num_tokens() as f64 / c.num_docs() as f64;
        assert!((40.0..60.0).contains(&avg), "avg len {avg}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(&small_spec());
        let b = generate(&small_spec());
        assert_eq!(a.tokens_vec(), b.tokens_vec());
        assert_eq!(a.offsets(), b.offsets());
        let mut spec = small_spec();
        spec.seed = 43;
        let c = generate(&spec);
        assert_ne!(a.tokens_vec(), c.tokens_vec());
    }

    #[test]
    fn streamed_generation_matches_in_ram() {
        let a = generate(&small_spec());
        let mut flat = Vec::new();
        let mut lens = Vec::new();
        generate_with(&small_spec(), |d| {
            flat.extend_from_slice(d);
            lens.push(d.len());
            Ok(())
        })
        .unwrap();
        assert_eq!(flat, a.tokens_vec());
        assert_eq!(lens.len(), a.num_docs());
    }

    #[test]
    fn word_frequencies_are_skewed() {
        // Zipf base measure => head words much more frequent than tail
        let c = generate(&small_spec());
        let mut freq = vec![0usize; c.vocab()];
        for &w in &c.tokens_vec() {
            freq[w as usize] += 1;
        }
        freq.sort_unstable_by(|a, b| b.cmp(a));
        let head: usize = freq[..10].iter().sum();
        let total: usize = freq.iter().sum();
        assert!(
            head as f64 > 0.05 * total as f64,
            "top-10 words carry {head}/{total}"
        );
    }

    #[test]
    fn documents_have_topical_structure() {
        // with low alpha, a doc's tokens should concentrate on few topics'
        // vocabularies => mean per-doc distinct-word ratio noticeably below
        // an iid-over-vocab draw
        let c = generate(&small_spec());
        let mut distinct_ratio = 0.0;
        for d in c.docs() {
            let mut s: Vec<u32> = d.to_vec();
            s.sort_unstable();
            s.dedup();
            distinct_ratio += s.len() as f64 / d.len() as f64;
        }
        distinct_ratio /= c.num_docs() as f64;
        assert!(distinct_ratio < 0.97, "distinct ratio {distinct_ratio}");
    }
}
