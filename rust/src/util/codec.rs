//! Shared binary-codec substrate for the crate's wire and artifact
//! formats (`nomad/wire.rs`, `infer/wire.rs`, the `.fnmodel` artifact).
//!
//! Three layers, all little-endian / fixed-width (the FNLDA001 checkpoint
//! conventions):
//!
//! * `put_*` — appending writers over a `Vec<u8>` body;
//! * [`Cur`] — a bounds-checked reader that makes decoders *total*: every
//!   read is checked against the remaining buffer, element counts are
//!   pre-checked against the remaining bytes before any allocation
//!   ([`Cur::len`]), and [`Cur::finish`] turns trailing bytes into an
//!   error.  A malformed buffer is always an `Err(String)`, never a panic
//!   or an attempted multi-GB allocation;
//! * [`write_len_prefixed`] / [`read_len_prefixed`] — `u32 LE length |
//!   body` framing over any `Write`/`Read`, with a caller-supplied cap
//!   enforced on both sides so a garbage length field cannot OOM the
//!   process.

use std::io::{Read, Write};

// --------------------------------------------------------------- writers

pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

pub fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_i64(out: &mut Vec<u8>, v: i64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// `u32` length + raw bytes (the string/blob convention).
pub fn put_bytes(out: &mut Vec<u8>, bytes: &[u8]) {
    put_u32(out, bytes.len() as u32);
    out.extend_from_slice(bytes);
}

// ---------------------------------------------------------------- reader

/// Bounds-checked reader over a byte buffer.
pub struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Cur { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!(
                "truncated frame: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            ));
        }
        let slice = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(slice)
    }

    pub fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16, String> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn i64(&mut self) -> Result<i64, String> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read a `u32` element count and pre-check it against the remaining
    /// bytes so garbage lengths error instead of attempting a huge
    /// allocation.  `elem_bytes` is the *minimum* encoded size of one
    /// element (variable-width elements pass their floor).
    pub fn len(&mut self, elem_bytes: usize) -> Result<usize, String> {
        let n = self.u32()? as usize;
        if n.saturating_mul(elem_bytes) > self.remaining() {
            return Err(format!(
                "frame length {n} x {elem_bytes}B exceeds remaining {} bytes",
                self.remaining()
            ));
        }
        Ok(n)
    }

    /// `u32` length + UTF-8 bytes (the [`put_bytes`] convention).
    pub fn string(&mut self) -> Result<String, String> {
        let n = self.len(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|e| format!("invalid utf8 in frame: {e}"))
    }

    pub fn finish(self) -> Result<(), String> {
        if self.remaining() != 0 {
            return Err(format!("{} trailing bytes after frame", self.remaining()));
        }
        Ok(())
    }
}

// --------------------------------------------------------------- framing

/// True for the error kinds a socket read deadline produces (platforms
/// disagree: Unix reports `WouldBlock`, Windows `TimedOut`).
fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// Name a read failure, distinguishing a deadline expiry from every other
/// fault — callers (and their tests) must be able to tell "the peer went
/// silent past the configured deadline" apart from EOF or a reset.
fn read_err(ctx: &str, e: std::io::Error) -> String {
    if is_timeout(&e) {
        format!("read deadline exceeded ({ctx}): {e}")
    } else {
        format!("{ctx}: {e}")
    }
}

/// Write one `u32 LE length | body` frame and flush it.  Errors (instead
/// of truncating the `u32` prefix) on bodies above `cap` — oversized
/// payloads must fail loudly, not desync the stream.
pub fn write_len_prefixed<W: Write>(w: &mut W, body: &[u8], cap: usize) -> Result<(), String> {
    if body.len() > cap {
        return Err(format!("frame body of {} bytes exceeds the {cap}-byte cap", body.len()));
    }
    w.write_all(&(body.len() as u32).to_le_bytes())
        .and_then(|_| w.write_all(body))
        .and_then(|_| w.flush())
        .map_err(|e| format!("frame write failed: {e}"))
}

/// Read one `u32 LE length | body` frame.  Errors on EOF, short reads,
/// and a length above `cap` (checked *before* the body allocation).
pub fn read_len_prefixed<R: Read>(r: &mut R, cap: usize) -> Result<Vec<u8>, String> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4).map_err(|e| read_err("frame read failed", e))?;
    read_frame_body(r, len4, cap)
}

/// Like [`read_len_prefixed`], but an orderly end-of-stream *between*
/// frames (EOF before any prefix byte arrived) is `Ok(None)` instead of
/// an error — session loops use this to tell a clean close apart from
/// mid-frame truncation, a reset, or an idle timeout (all still `Err`).
pub fn read_len_prefixed_eof<R: Read>(
    r: &mut R,
    cap: usize,
) -> Result<Option<Vec<u8>>, String> {
    let mut len4 = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut len4[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(format!("truncated frame length prefix ({got} of 4 bytes)"))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(read_err("frame read failed", e)),
        }
    }
    read_frame_body(r, len4, cap).map(Some)
}

fn read_frame_body<R: Read>(r: &mut R, len4: [u8; 4], cap: usize) -> Result<Vec<u8>, String> {
    let len = u32::from_le_bytes(len4) as usize;
    if len > cap {
        return Err(format!("frame length {len} exceeds the {cap}-byte cap"));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).map_err(|e| read_err("frame body read failed", e))?;
    Ok(body)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_roundtrip() {
        let mut out = Vec::new();
        put_u8(&mut out, 7);
        put_u16(&mut out, 0xBEEF);
        put_u32(&mut out, 0xDEAD_BEEF);
        put_u64(&mut out, u64::MAX - 3);
        put_i64(&mut out, -42);
        put_f64(&mut out, -0.125);
        put_bytes(&mut out, b"topic");
        let mut cur = Cur::new(&out);
        assert_eq!(cur.u8().unwrap(), 7);
        assert_eq!(cur.u16().unwrap(), 0xBEEF);
        assert_eq!(cur.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(cur.u64().unwrap(), u64::MAX - 3);
        assert_eq!(cur.i64().unwrap(), -42);
        assert_eq!(cur.f64().unwrap(), -0.125);
        assert_eq!(cur.string().unwrap(), "topic");
        cur.finish().unwrap();
    }

    #[test]
    fn truncation_and_trailing_are_errors() {
        let mut out = Vec::new();
        put_u32(&mut out, 9);
        let mut cur = Cur::new(&out[..2]);
        assert!(cur.u32().unwrap_err().contains("truncated"));
        let mut cur = Cur::new(&out);
        let _ = cur.u16().unwrap();
        assert!(cur.finish().unwrap_err().contains("trailing"));
    }

    #[test]
    fn absurd_length_field_is_rejected_before_allocation() {
        let mut out = Vec::new();
        put_u32(&mut out, u32::MAX);
        let mut cur = Cur::new(&out);
        assert!(cur.len(8).unwrap_err().contains("exceeds"));
    }

    #[test]
    fn invalid_utf8_is_an_error() {
        let mut out = Vec::new();
        put_bytes(&mut out, &[0xFF, 0xFE]);
        let mut cur = Cur::new(&out);
        assert!(cur.string().unwrap_err().contains("utf8"));
    }

    #[test]
    fn len_prefixed_roundtrip_and_caps() {
        let mut buf = Vec::new();
        write_len_prefixed(&mut buf, b"hello", 64).unwrap();
        write_len_prefixed(&mut buf, b"", 64).unwrap();
        let mut r = &buf[..];
        assert_eq!(read_len_prefixed(&mut r, 64).unwrap(), b"hello");
        assert_eq!(read_len_prefixed(&mut r, 64).unwrap(), b"");
        assert!(read_len_prefixed(&mut r, 64).unwrap_err().contains("frame read failed"));
        // write-side cap
        let err = write_len_prefixed(&mut Vec::new(), &[0u8; 9], 8).unwrap_err();
        assert!(err.contains("cap"), "unhelpful error: {err}");
        // read-side cap, checked before allocation
        let mut big = Vec::new();
        big.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(read_len_prefixed(&mut &big[..], 1024).unwrap_err().contains("cap"));
    }

    #[test]
    fn eof_aware_reader_distinguishes_close_from_truncation() {
        // orderly close: EOF before any prefix byte
        assert_eq!(read_len_prefixed_eof(&mut &[][..], 64).unwrap(), None);
        // a full frame still arrives intact
        let mut buf = Vec::new();
        write_len_prefixed(&mut buf, b"hi", 64).unwrap();
        let mut r = &buf[..];
        assert_eq!(read_len_prefixed_eof(&mut r, 64).unwrap().as_deref(), Some(&b"hi"[..]));
        assert_eq!(read_len_prefixed_eof(&mut r, 64).unwrap(), None);
        // mid-prefix truncation is an error, not a clean close
        let err = read_len_prefixed_eof(&mut &buf[..2], 64).unwrap_err();
        assert!(err.contains("truncated frame length prefix"), "unhelpful: {err}");
        // mid-body truncation too
        let err = read_len_prefixed_eof(&mut &buf[..5], 64).unwrap_err();
        assert!(err.contains("body"), "unhelpful: {err}");
        // and the cap still applies
        let mut big = Vec::new();
        big.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(read_len_prefixed_eof(&mut &big[..], 64).unwrap_err().contains("cap"));
    }

    /// A socket whose read deadline fires surfaces as `WouldBlock` /
    /// `TimedOut` — both readers must name it as a deadline expiry, never
    /// as a generic read failure (tests and supervisors key on the name).
    #[test]
    fn deadline_expiry_is_a_named_error_distinct_from_eof() {
        struct TimesOut;
        impl Read for TimesOut {
            fn read(&mut self, _: &mut [u8]) -> std::io::Result<usize> {
                Err(std::io::Error::new(std::io::ErrorKind::WouldBlock, "timed out"))
            }
        }
        let err = read_len_prefixed_eof(&mut TimesOut, 64).unwrap_err();
        assert!(err.contains("read deadline exceeded"), "unhelpful: {err}");
        let err = read_len_prefixed(&mut TimesOut, 64).unwrap_err();
        assert!(err.contains("read deadline exceeded"), "unhelpful: {err}");
        // a clean EOF is still Ok(None), not a deadline error
        assert_eq!(read_len_prefixed_eof(&mut &[][..], 64).unwrap(), None);
    }
}
