//! Experiment metrics: convergence-series recording, CSV output, and the
//! single histogram implementation shared by benches and the server.
//!
//! Every figure in the paper is a set of (x, y) series (LL vs iteration,
//! LL vs seconds, speedup vs cores).  [`Series`] collects points with
//! labels; [`write_csv`] emits the long-format file the plotting harness /
//! EXPERIMENTS.md tables are produced from.
//!
//! The log₂ latency-bucket helpers ([`LATENCY_BUCKETS`], [`latency_bucket`],
//! [`bucket_percentile_us`]) live here so the ad-hoc [`Histogram`], the
//! serving stats counters, and the observability registry all share one
//! bucketing scheme; `util::bench` re-exports them for its callers.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;
use std::time::Instant;

/// One named curve: (x, y) points, e.g. ("nomad-8cores", iter, ll).
#[derive(Clone, Debug, Default)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: impl Into<String>) -> Self {
        Series { name: name.into(), points: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    /// First x where y >= threshold (for "time to reach LL" comparisons;
    /// LL is negative and increasing).
    pub fn time_to_reach(&self, threshold: f64) -> Option<f64> {
        self.points.iter().find(|(_, y)| *y >= threshold).map(|(x, _)| *x)
    }

    pub fn last_y(&self) -> Option<f64> {
        self.points.last().map(|&(_, y)| y)
    }
}

/// Long-format CSV: series,x,y
pub fn to_csv(series: &[Series]) -> String {
    let mut out = String::from("series,x,y\n");
    for s in series {
        for &(x, y) in &s.points {
            let _ = writeln!(out, "{},{x},{y}", s.name);
        }
    }
    out
}

pub fn write_csv(path: &Path, series: &[Series]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(to_csv(series).as_bytes())
}

/// Minimal wall-clock stopwatch: construction starts it, [`Self::secs`]
/// reads the elapsed seconds (per-epoch timing in the engines).
pub struct Stopwatch {
    start: Instant,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Simple fixed-bucket histogram for latency-style metrics.
#[derive(Clone, Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    pub total: u64,
    pub sum: f64,
    pub max: f64,
}

impl Histogram {
    /// Log-spaced buckets between lo and hi.
    pub fn log_spaced(lo: f64, hi: f64, n: usize) -> Self {
        assert!(lo > 0.0 && hi > lo && n >= 2);
        let ratio = (hi / lo).powf(1.0 / (n as f64 - 1.0));
        let bounds = (0..n).map(|i| lo * ratio.powi(i as i32)).collect();
        Histogram { bounds, counts: vec![0; n + 1], total: 0, sum: 0.0, max: f64::MIN }
    }

    pub fn record(&mut self, v: f64) {
        let idx = self.bounds.partition_point(|&b| b < v);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += v;
        self.max = self.max.max(v);
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Approximate quantile by bucket upper bound.  0.0 on an empty
    /// histogram (rather than leaking the `f64::MIN` max-tracker init).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.bounds.get(i).copied().unwrap_or(self.max);
            }
        }
        self.max
    }
}

/// Bucket count of the log₂ latency histograms ([`latency_bucket`]):
/// bucket b covers `[2^b, 2^(b+1))` nanoseconds, so 64 buckets span
/// everything a `u64` nanosecond count can hold.
pub const LATENCY_BUCKETS: usize = 64;

/// Histogram bucket for one latency measurement in nanoseconds:
/// `⌊log₂ ns⌋`, with 0 ns folded into bucket 0.  Constant-time, so a
/// server can record it behind a single relaxed atomic increment.
#[inline]
pub fn latency_bucket(ns: u64) -> usize {
    (63 - (ns | 1).leading_zeros()) as usize
}

/// Nearest-rank percentile over log₂ histogram bucket counts, reported
/// as the geometric midpoint `2^b·√2` of the winning bucket, in
/// **microseconds** (`p ∈ [0, 100]`).  NaN when the histogram is empty.
///
/// The bucketed estimate trades ≤ √2× value resolution for O(1) lock-free
/// recording — the right trade for always-on serving percentiles, where
/// the alternative is an unbounded sample vector behind a lock.
pub fn bucket_percentile_us(counts: &[u64], p: f64) -> f64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return f64::NAN;
    }
    debug_assert!((0.0..=100.0).contains(&p));
    let rank = (((p / 100.0) * total as f64).ceil() as u64).clamp(1, total);
    let mut seen = 0u64;
    for (b, &c) in counts.iter().enumerate() {
        seen += c;
        if seen >= rank {
            return 2f64.powi(b as i32) * std::f64::consts::SQRT_2 / 1e3;
        }
    }
    f64::NAN
}

/// The one log₂ nanosecond histogram: [`latency_bucket`] indexing,
/// [`bucket_percentile_us`] quantiles.  The lock-free variants (the
/// serving stats array, the observability registry) keep the same
/// `[u64; LATENCY_BUCKETS]` layout and snapshot into / report through
/// these same functions, so every latency percentile in the system is
/// computed by one implementation.
#[derive(Clone, Debug)]
pub struct Log2Histogram {
    pub counts: [u64; LATENCY_BUCKETS],
    pub total: u64,
    pub sum_ns: u64,
    pub max_ns: u64,
}

impl Default for Log2Histogram {
    fn default() -> Self {
        Log2Histogram { counts: [0; LATENCY_BUCKETS], total: 0, sum_ns: 0, max_ns: 0 }
    }
}

impl Log2Histogram {
    pub fn record_ns(&mut self, ns: u64) {
        self.counts[latency_bucket(ns)] += 1;
        self.total += 1;
        self.sum_ns = self.sum_ns.saturating_add(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    /// Percentile in microseconds; 0.0 (not NaN, not `f64::MIN`) when empty.
    pub fn percentile_us(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        bucket_percentile_us(&self.counts, p)
    }

    pub fn mean_us(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.total as f64 / 1e3
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_time_to_reach() {
        let mut s = Series::new("t");
        s.push(1.0, -100.0);
        s.push(2.0, -50.0);
        s.push(3.0, -40.0);
        assert_eq!(s.time_to_reach(-60.0), Some(2.0));
        assert_eq!(s.time_to_reach(-10.0), None);
    }

    #[test]
    fn csv_format() {
        let mut s = Series::new("a");
        s.push(0.0, 1.5);
        let csv = to_csv(&[s]);
        assert_eq!(csv, "series,x,y\na,0,1.5\n");
    }

    #[test]
    fn csv_roundtrip_to_disk() {
        let dir = std::env::temp_dir().join("fnomad_metrics_test");
        let path = dir.join("out.csv");
        let mut s = Series::new("x");
        s.push(1.0, 2.0);
        write_csv(&path, &[s]).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.contains("x,1,2"));
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::log_spaced(1.0, 1000.0, 16);
        for i in 1..=1000 {
            h.record(i as f64);
        }
        assert_eq!(h.total, 1000);
        let p50 = h.quantile(0.5);
        assert!((300.0..800.0).contains(&p50), "p50 {p50}");
        assert!(h.quantile(1.0) >= 999.0);
        assert!((h.mean() - 500.5).abs() < 1.0);
    }

    #[test]
    fn empty_histogram_quantile_is_zero() {
        let h = Histogram::log_spaced(1.0, 1000.0, 16);
        assert_eq!(h.total, 0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.quantile(1.0), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn log2_histogram_matches_bucket_functions() {
        let mut h = Log2Histogram::default();
        for _ in 0..90 {
            h.record_ns(1 << 9); // bucket 9, ≈ 0.72 µs midpoint
        }
        for _ in 0..10 {
            h.record_ns(1 << 19); // bucket 19, ≈ 741 µs midpoint
        }
        assert_eq!(h.total, 100);
        assert_eq!(h.counts[9], 90);
        assert_eq!(h.counts[19], 10);
        assert_eq!(h.percentile_us(50.0), bucket_percentile_us(&h.counts, 50.0));
        assert!((h.percentile_us(99.0) - 741.5).abs() < 1.0);
        assert_eq!(h.max_ns, 1 << 19);
        // empty: 0.0, not NaN and not f64::MIN
        assert_eq!(Log2Histogram::default().percentile_us(50.0), 0.0);
        assert_eq!(Log2Histogram::default().mean_us(), 0.0);
    }
}
