//! The concurrency shim: every hand-rolled lock/atomic construction in
//! this crate reaches `Mutex`/`Condvar`/atomics through here, so the same
//! source compiles against `std::sync` normally and against
//! [loom](https://docs.rs/loom)'s model-checked replacements under
//! `--cfg loom`.
//!
//! # Why a shim
//!
//! The crate contains several bespoke concurrent protocols — the bounded
//! MPMC [`BatchQueue`], the [`VersionedSlot`] hot-swap version hint, the
//! snapshot [`OfferQueue`] — whose correctness claims ("offer never
//! blocks", "every answer is labeled with an actually-leased version")
//! are exactly the kind that survive hammer tests and die in production.
//! `rust/tests/loom_models.rs` model-checks those protocols exhaustively;
//! for loom to intercept every lock acquisition and atomic access, the
//! production types must be built from loom's primitives when the model
//! runs.  The shim keeps that a pure build-time switch: zero cost and
//! zero `cfg` noise at the use sites.
//!
//! # Running the models locally
//!
//! The committed manifest is dependency-free (the default build is
//! hermetic/offline), so `loom` is appended by the CI job — or by hand:
//!
//! ```sh
//! printf '\n%s\n%s\n' "[target.'cfg(loom)'.dependencies]" 'loom = "0.7"' >> Cargo.toml
//! RUSTFLAGS="--cfg loom" LOOM_MAX_PREEMPTIONS=3 \
//!     cargo test --release --test loom_models
//! ```
//!
//! # What is (and is not) swapped
//!
//! * [`Mutex`], [`Condvar`], [`MutexGuard`], the [`atomic`] module, and
//!   [`thread`] are loom's under `cfg(loom)`.
//! * [`Arc`] stays `std::sync::Arc` under both configurations: it is a
//!   reference counter, not an ordering protocol — nothing here relies on
//!   `Arc` for synchronization beyond what its (library-guaranteed)
//!   clone/drop contract provides — and keeping it `std` keeps public
//!   signatures (`serve_model(_, Arc<ModelSlot>, _)`) identical across
//!   configurations, so unmigrated callers interoperate.
//! * [`static_atomic`] is *always* `std`: loom atomics are created per
//!   model execution and cannot live in `static` items.  Process-global
//!   counters (the disk-corpus residency gauges) use these and are out of
//!   loom's scope by design.
//! * Condvar waits go through [`wait_timeout`], which under loom degrades
//!   to an untimed `wait` (loom does not model the passage of time).
//!   Loom models must therefore be written so every wait is eventually
//!   satisfied by a notify, never by a timeout.
//!
//! # Poisoning policy
//!
//! Loom's `Mutex` never poisons, and the serving stack must not answer a
//! panic with a cascade of `unwrap()` panics (see the named-error
//! discipline in [`crate::infer::server`]).  The two lock helpers make the
//! policy explicit at each site:
//!
//! * [`lock_checked`] surfaces a poisoned lock as [`Poisoned`] so the
//!   caller converts it into a named "worker panicked" error;
//! * [`lock_recover`] takes the data anyway — only correct for structures
//!   whose invariants hold across a panic (single-assignment swaps,
//!   monotone counters), which the call site must justify.
//!
//! [`BatchQueue`]: crate::infer::batch::BatchQueue
//! [`VersionedSlot`]: crate::infer::server::VersionedSlot
//! [`OfferQueue`]: crate::resilience::writer::OfferQueue

use std::time::Duration;

#[cfg(not(loom))]
pub use std::sync::atomic;
#[cfg(not(loom))]
pub use std::sync::{Condvar, Mutex, MutexGuard};
#[cfg(not(loom))]
pub use std::thread;

#[cfg(loom)]
pub use loom::sync::atomic;
#[cfg(loom)]
pub use loom::sync::{Condvar, Mutex, MutexGuard};
#[cfg(loom)]
pub use loom::thread;

// deliberately std under both cfgs — see the module docs
pub use std::sync::Arc;

/// Atomics for `static` items: always `std`, because loom's atomics are
/// not const-constructible (they register with the active model
/// execution).  Use only for process-global counters whose protocol is a
/// plain monotone gauge, and justify the orderings at the site.
pub mod static_atomic {
    pub use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
}

/// A mutex was poisoned: some thread panicked while holding it.  Returned
/// by [`lock_checked`] / [`wait_timeout`] so callers can answer with a
/// named error instead of propagating the panic to every thread that
/// touches the lock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Poisoned;

/// Acquire `m`, reporting poisoning as [`Poisoned`] instead of panicking.
#[cfg(not(loom))]
pub fn lock_checked<'a, T>(m: &'a Mutex<T>) -> Result<MutexGuard<'a, T>, Poisoned> {
    m.lock().map_err(|_| Poisoned)
}

/// Acquire `m`, reporting poisoning as [`Poisoned`] instead of panicking.
/// (Loom mutexes never poison.)
#[cfg(loom)]
pub fn lock_checked<'a, T>(m: &'a Mutex<T>) -> Result<MutexGuard<'a, T>, Poisoned> {
    Ok(m.lock().unwrap())
}

/// Acquire `m`, recovering the data from a poisoned lock.  Only for
/// structures whose invariants hold across a panic — the caller must be
/// able to argue that every critical section is a single indivisible
/// assignment or a monotone update.
#[cfg(not(loom))]
pub fn lock_recover<'a, T>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Acquire `m`, recovering the data from a poisoned lock.  (Loom mutexes
/// never poison.)
#[cfg(loom)]
pub fn lock_recover<'a, T>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {
    m.lock().unwrap()
}

/// Wait on `cv` for at most `dur`, reporting poisoning as [`Poisoned`].
///
/// Callers own their deadline arithmetic (they re-check elapsed wall time
/// against the deadline on every wakeup), so the *timed-out* flag is not
/// returned: a spurious early wakeup and a timeout look the same, and
/// both are handled by the caller's loop condition.
#[cfg(not(loom))]
pub fn wait_timeout<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> Result<MutexGuard<'a, T>, Poisoned> {
    match cv.wait_timeout(guard, dur) {
        Ok((guard, _)) => Ok(guard),
        Err(_) => Err(Poisoned),
    }
}

/// Wait on `cv`, reporting poisoning as [`Poisoned`].  Loom does not
/// model the passage of time, so the duration is ignored and the wait
/// only ends on a notify — loom models must guarantee one arrives.
#[cfg(loom)]
pub fn wait_timeout<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    _dur: Duration,
) -> Result<MutexGuard<'a, T>, Poisoned> {
    Ok(cv.wait(guard).unwrap())
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn lock_checked_reports_poison_and_lock_recover_takes_the_data() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("deliberate poison");
        })
        .join();
        assert_eq!(lock_checked(&m).err(), Some(Poisoned));
        assert_eq!(*lock_recover(&m), 7, "the data survives the panic");
    }

    #[test]
    fn wait_timeout_returns_after_the_deadline() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let guard = m.lock().unwrap();
        let t0 = Instant::now();
        let _guard = wait_timeout(&cv, guard, Duration::from_millis(20)).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(10));
    }

    #[test]
    fn wait_timeout_reports_poison() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let pair2 = Arc::clone(&pair);
        let _ = std::thread::spawn(move || {
            let _guard = pair2.0.lock().unwrap();
            panic!("deliberate poison");
        })
        .join();
        let guard = lock_recover(&pair.0);
        let r = wait_timeout(&pair.1, guard, Duration::from_millis(1));
        assert_eq!(r.err(), Some(Poisoned));
    }
}
