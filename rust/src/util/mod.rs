//! Small self-contained substrates (offline build: no external crates).
//!
//! * [`rng`] — deterministic PCG32 with distribution helpers (replaces `rand`).
//! * [`math`] — Lanczos `lgamma` and friends (std has no lgamma).
//! * [`quickcheck`] — mini property-testing harness (replaces `proptest`).
//! * [`bench`] — wall-clock micro-bench harness (replaces `criterion`).
//! * [`cli`] — flag parser (replaces `clap`).
//! * [`codec`] — little-endian writers, the bounds-checked total-decoder
//!   reader, and length-prefixed frame IO shared by every wire format.
//! * [`metrics`] — timers + CSV series writers for the experiment curves.
//! * [`fsio`] — crash-safe atomic file writes with FNV-1a fingerprints.
//! * [`sync`] — the `std`-or-loom concurrency shim every hand-rolled
//!   lock/atomic construction is built on, plus the poisoning policy
//!   helpers (see its module docs for how the loom models run).

pub mod bench;
pub mod cli;
pub mod codec;
pub mod fsio;
pub mod math;
pub mod metrics;
pub mod quickcheck;
pub mod rng;
pub mod sync;
