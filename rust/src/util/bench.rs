//! Micro-benchmark harness (the vendored crate set has no `criterion`).
//!
//! Calibrates iteration counts to a target measuring window, reports
//! median-of-samples ns/op, and renders aligned tables — each `benches/*.rs`
//! is a plain `fn main` that uses this to regenerate one paper table/figure.

use std::fmt::Write as _;
use std::hint::black_box;
use std::time::{Duration, Instant};

/// One measured statistic.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// median nanoseconds per op
    pub ns_per_op: f64,
    /// median absolute deviation of the per-sample estimates
    pub mad_ns: f64,
    pub samples: usize,
}

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    pub warmup: Duration,
    pub sample_time: Duration,
    pub samples: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            warmup: Duration::from_millis(100),
            sample_time: Duration::from_millis(60),
            samples: 9,
        }
    }
}

/// Quick preset for expensive end-to-end benches.
pub fn fast_opts() -> BenchOpts {
    BenchOpts {
        warmup: Duration::from_millis(10),
        sample_time: Duration::from_millis(30),
        samples: 5,
    }
}

/// Measure `f`, auto-calibrating the batch size.  `f` should perform ONE op.
pub fn measure<F: FnMut()>(name: &str, opts: BenchOpts, mut f: F) -> Measurement {
    // warmup + calibration
    let start = Instant::now();
    let mut calib_iters = 0u64;
    while start.elapsed() < opts.warmup {
        f();
        calib_iters += 1;
    }
    let per = opts.warmup.as_nanos() as f64 / calib_iters.max(1) as f64;
    let batch = ((opts.sample_time.as_nanos() as f64 / per.max(1.0)).ceil() as u64).max(1);

    let mut estimates = Vec::with_capacity(opts.samples);
    for _ in 0..opts.samples {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        estimates.push(t0.elapsed().as_nanos() as f64 / batch as f64);
    }
    estimates.sort_by(|a, b| a.total_cmp(b));
    let median = estimates[estimates.len() / 2];
    let mut devs: Vec<f64> = estimates.iter().map(|e| (e - median).abs()).collect();
    devs.sort_by(|a, b| a.total_cmp(b));
    Measurement {
        name: name.to_string(),
        ns_per_op: median,
        mad_ns: devs[devs.len() / 2],
        samples: opts.samples,
    }
}

/// Measure a closure that returns a value (kept alive via black_box).
pub fn measure_ret<T, F: FnMut() -> T>(name: &str, opts: BenchOpts, mut f: F) -> Measurement {
    measure(name, opts, || {
        black_box(f());
    })
}

/// Aligned-table renderer for bench output (rows: name + columns).
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = format!("== {} ==\n", self.title);
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Nearest-rank percentile of an **ascending-sorted** slice
/// (`p ∈ [0, 100]`).  NaN on empty input.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    debug_assert!((0.0..=100.0).contains(&p));
    debug_assert!(sorted.windows(2).all(|w| w[0] <= w[1]), "input must be sorted");
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.saturating_sub(1).min(sorted.len() - 1)]
}

// The log₂ latency-bucket scheme lives with the other histogram code in
// `util::metrics` (one bucketing implementation for benches, serving
// stats, and the observability registry); re-exported here for the
// bench-side callers that historically imported it from this module.
pub use crate::util::metrics::{bucket_percentile_us, latency_bucket, LATENCY_BUCKETS};

/// One value of a machine-readable bench record.
#[derive(Clone, Debug)]
pub enum JsonVal {
    Num(f64),
    Int(u64),
    Str(String),
}

/// Render a flat JSON object from `(key, value)` pairs — the
/// `BENCH_*.json` emitter (no serde in the vendored crate set).  Strings
/// are escaped per RFC 8259; non-finite numbers become `null` (JSON has
/// no NaN/Inf).
pub fn json_object(fields: &[(&str, JsonVal)]) -> String {
    let mut out = String::from("{");
    for (i, (k, v)) in fields.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&json_string(k));
        out.push(':');
        match v {
            JsonVal::Num(x) if x.is_finite() => {
                let _ = write!(out, "{x}");
            }
            JsonVal::Num(_) => out.push_str("null"),
            JsonVal::Int(n) => {
                let _ = write!(out, "{n}");
            }
            JsonVal::Str(s) => out.push_str(&json_string(s)),
        }
    }
    out.push_str("}\n");
    out
}

/// RFC 8259 string escaping — shared with the structured-event emitter
/// (`obs::event`), which needs the same escapes for its JSONL mode.
pub fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Write a `BENCH_*.json` record, creating parent directories.
pub fn write_json(path: &std::path::Path, fields: &[(&str, JsonVal)]) -> Result<(), String> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
    }
    std::fs::write(path, json_object(fields)).map_err(|e| format!("{}: {e}", path.display()))
}

/// Human formatting for ns quantities.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_orders_cheap_vs_expensive() {
        let opts = BenchOpts {
            warmup: Duration::from_millis(5),
            sample_time: Duration::from_millis(5),
            samples: 3,
        };
        let cheap = measure("cheap", opts, || {
            black_box(1 + 1);
        });
        let costly = measure("costly", opts, || {
            let mut s = 0u64;
            for i in 0..2000 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        assert!(costly.ns_per_op > cheap.ns_per_op);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["name", "ns"]);
        t.row(vec!["a".into(), "1.0".into()]);
        t.row(vec!["long-name".into(), "123.4".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.lines().count() >= 4);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 95.0), 95.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&[7.0], 50.0), 7.0);
        assert!(percentile(&[], 50.0).is_nan());
        // nearest-rank on a short list: p95 of 3 samples is the max
        assert_eq!(percentile(&[1.0, 2.0, 3.0], 95.0), 3.0);
    }

    #[test]
    fn latency_buckets_follow_log2_boundaries() {
        assert_eq!(latency_bucket(0), 0);
        assert_eq!(latency_bucket(1), 0);
        assert_eq!(latency_bucket(2), 1);
        assert_eq!(latency_bucket(3), 1);
        assert_eq!(latency_bucket(1023), 9);
        assert_eq!(latency_bucket(1024), 10);
        assert_eq!(latency_bucket(u64::MAX), LATENCY_BUCKETS - 1);
    }

    #[test]
    fn bucket_percentiles_pick_the_right_bucket() {
        let mut counts = vec![0u64; LATENCY_BUCKETS];
        // 90 measurements around 1 µs (bucket 9), 10 around 1 ms (bucket 19)
        counts[9] = 90;
        counts[19] = 10;
        let p50 = bucket_percentile_us(&counts, 50.0);
        let p99 = bucket_percentile_us(&counts, 99.0);
        // geometric midpoints: 2^9·√2 ns ≈ 0.72 µs, 2^19·√2 ns ≈ 741 µs
        assert!((p50 - 0.724).abs() < 0.01, "p50 = {p50}");
        assert!((p99 - 741.5).abs() < 1.0, "p99 = {p99}");
        assert!(bucket_percentile_us(&counts, 0.0) <= p50);
        assert!(bucket_percentile_us(&[0; LATENCY_BUCKETS], 50.0).is_nan());
    }

    #[test]
    fn json_object_escapes_and_formats() {
        let s = json_object(&[
            ("name", JsonVal::Str("he said \"hi\"\n\\".into())),
            ("tokens_per_sec", JsonVal::Num(1234.5)),
            ("docs", JsonVal::Int(42)),
            ("bad", JsonVal::Num(f64::NAN)),
        ]);
        assert_eq!(
            s,
            "{\"name\":\"he said \\\"hi\\\"\\n\\\\\",\"tokens_per_sec\":1234.5,\
             \"docs\":42,\"bad\":null}\n"
        );
    }

    #[test]
    fn write_json_creates_dirs() {
        let path = std::env::temp_dir().join("fnomad_bench_tests").join("b.json");
        write_json(&path, &[("x", JsonVal::Int(1))]).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert_eq!(body, "{\"x\":1}\n");
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(5.0).ends_with("ns"));
        assert!(fmt_ns(5e4).ends_with("µs"));
        assert!(fmt_ns(5e7).ends_with("ms"));
        assert!(fmt_ns(5e10).ends_with("s"));
    }
}
