//! Minimal flag parser (the vendored crate set has no `clap`).
//!
//! Syntax: `binary <subcommand> --key value --flag`.  Typed getters with
//! defaults; unknown-flag detection; per-subcommand `--help` rendering
//! from registered [`CommandSpec`]s (see `main.rs` for the registry).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One `--flag` of a subcommand, for help rendering.
pub struct FlagSpec {
    /// flag name without the leading `--`
    pub flag: &'static str,
    /// value placeholder (`"N"`, `"NAME"`, …); empty for boolean flags
    pub value: &'static str,
    pub help: &'static str,
}

/// A subcommand's registered help: one-line summary plus its flags.
/// `binary <subcommand> --help` renders this.
pub struct CommandSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub flags: &'static [FlagSpec],
}

impl CommandSpec {
    /// Render the full `--help` text for this subcommand.
    pub fn render(&self, binary: &str) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{binary} {} — {}", self.name, self.about);
        let _ = writeln!(out, "\nUSAGE: {binary} {} [flags]", self.name);
        if self.flags.is_empty() {
            return out;
        }
        let _ = writeln!(out, "\nFLAGS:");
        let left: Vec<String> = self
            .flags
            .iter()
            .map(|f| {
                if f.value.is_empty() {
                    format!("--{}", f.flag)
                } else {
                    format!("--{} {}", f.flag, f.value)
                }
            })
            .collect();
        let width = left.iter().map(|s| s.len()).max().unwrap_or(0);
        for (l, f) in left.iter().zip(self.flags) {
            let _ = writeln!(out, "  {l:width$}  {}", f.help);
        }
        out
    }

    /// One-line summary for the top-level help index.
    pub fn summary_line(&self) -> String {
        format!("  {:16} {}", self.name, self.about)
    }
}

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    /// flags actually consumed by a getter — used for unknown-flag errors
    seen: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an explicit token list (tests) — first non-flag token is
    /// the subcommand.
    pub fn from_tokens(tokens: &[String]) -> Result<Args, String> {
        let mut subcommand = None;
        let mut flags = BTreeMap::new();
        let mut i = 0;
        while i < tokens.len() {
            let tok = &tokens[i];
            if let Some(name) = tok.strip_prefix("--") {
                let (key, val) = if let Some((k, v)) = name.split_once('=') {
                    (k.to_string(), v.to_string())
                } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    i += 1;
                    (name.to_string(), tokens[i].clone())
                } else {
                    (name.to_string(), "true".to_string())
                };
                if flags.insert(key.clone(), val).is_some() {
                    return Err(format!("duplicate flag --{key}"));
                }
            } else if tok == "-h" {
                // short help alias: `binary <subcommand> -h`
                if flags.insert("help".into(), "true".into()).is_some() {
                    return Err("duplicate flag --help".into());
                }
            } else if subcommand.is_none() {
                subcommand = Some(tok.clone());
            } else {
                return Err(format!("unexpected positional argument '{tok}'"));
            }
            i += 1;
        }
        Ok(Args { subcommand, flags, seen: Default::default() })
    }

    pub fn from_env() -> Result<Args, String> {
        let tokens: Vec<String> = std::env::args().skip(1).collect();
        Args::from_tokens(&tokens)
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().push(key.to_string());
    }

    pub fn str_opt(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.flags.get(key).cloned()
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or_else(|| default.to_string())
    }

    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: cannot parse '{v}'")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1") | Some("yes"))
    }

    /// True when the user asked for this subcommand's help (`--help` and
    /// `-h` both reach us as the flag `help`; also honor `--h`).
    pub fn help_requested(&self) -> bool {
        self.flag("help") || self.flag("h")
    }

    /// List of usize, e.g. `--cores 1,2,4,8`.
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Result<Vec<usize>, String> {
        match self.str_opt(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| s.trim().parse().map_err(|_| format!("--{key}: bad element '{s}'")))
                .collect(),
        }
    }

    /// After all getters ran, reject flags nobody consumed.
    pub fn reject_unknown(&self) -> Result<(), String> {
        let seen = self.seen.borrow();
        for key in self.flags.keys() {
            if !seen.iter().any(|s| s == key) {
                return Err(format!("unknown flag --{key}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a =
            Args::from_tokens(&toks("train --topics 1024 --preset enron-sim --verbose")).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.parse_or("topics", 0usize).unwrap(), 1024);
        assert_eq!(a.str_or("preset", ""), "enron-sim");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_syntax() {
        let a = Args::from_tokens(&toks("x --k=v")).unwrap();
        assert_eq!(a.str_or("k", ""), "v");
    }

    #[test]
    fn duplicate_flag_errors() {
        assert!(Args::from_tokens(&toks("x --a 1 --a 2")).is_err());
    }

    #[test]
    fn unknown_flag_detection() {
        let a = Args::from_tokens(&toks("x --known 1 --mystery 2")).unwrap();
        let _ = a.parse_or("known", 0u32).unwrap();
        assert!(a.reject_unknown().is_err());
        let _ = a.str_opt("mystery");
        assert!(a.reject_unknown().is_ok());
    }

    #[test]
    fn usize_list() {
        let a = Args::from_tokens(&toks("x --cores 1,2,20")).unwrap();
        assert_eq!(a.usize_list_or("cores", &[]).unwrap(), vec![1, 2, 20]);
        assert_eq!(a.usize_list_or("absent", &[4]).unwrap(), vec![4]);
    }

    #[test]
    fn defaults_when_missing() {
        let a = Args::from_tokens(&toks("x")).unwrap();
        assert_eq!(a.parse_or("n", 7i32).unwrap(), 7);
        assert_eq!(a.str_or("s", "d"), "d");
    }

    #[test]
    fn help_flag_detection() {
        let a = Args::from_tokens(&toks("train --help")).unwrap();
        assert!(a.help_requested());
        assert!(a.reject_unknown().is_ok());
        let b = Args::from_tokens(&toks("train --iters 3")).unwrap();
        assert!(!b.help_requested());
        let c = Args::from_tokens(&toks("train -h")).unwrap();
        assert_eq!(c.subcommand.as_deref(), Some("train"));
        assert!(c.help_requested());
    }

    #[test]
    fn command_spec_renders_name_flags_and_help() {
        const SPEC: CommandSpec = CommandSpec {
            name: "train",
            about: "train a topic model",
            flags: &[
                FlagSpec { flag: "preset", value: "NAME", help: "corpus preset" },
                FlagSpec { flag: "quiet", value: "", help: "suppress progress logs" },
            ],
        };
        let text = SPEC.render("fnomad-lda");
        assert!(text.contains("fnomad-lda train — train a topic model"));
        assert!(text.contains("USAGE: fnomad-lda train [flags]"));
        assert!(text.contains("--preset NAME"));
        assert!(text.contains("corpus preset"));
        assert!(text.contains("--quiet"));
        assert!(SPEC.summary_line().contains("train"));
    }
}
