//! Deterministic PCG32 RNG plus the distribution samplers the corpus
//! generator and the LDA initializers need (uniform, normal, gamma,
//! Dirichlet, Poisson, Zipf).
//!
//! PCG-XSH-RR 64/32 (O'Neill 2014). Deterministic across platforms, cheap
//! (one 64-bit multiply per draw), and supports independent streams — each
//! worker derives its own stream id so parallel runs are replayable.

/// PCG-XSH-RR 64/32 generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and a stream id.  Different stream
    /// ids yield independent sequences for the same seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor on stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive a child generator (used to give each worker its own stream).
    pub fn split(&mut self, stream: u64) -> Pcg32 {
        Pcg32::new(self.next_u64(), stream.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }

    /// Raw `(state, inc)` pair — the full generator state.  Shipping these
    /// two words to another process ([`Self::from_parts`]) continues the
    /// *identical* sequence, which is how remote nomad workers keep the
    /// same per-slot RNG streams as their in-process counterparts.
    pub fn to_parts(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from [`Self::to_parts`] output.
    pub fn from_parts(state: u64, inc: u64) -> Pcg32 {
        Pcg32 { state, inc }
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1) with 53 bits of entropy.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in [0, limit) — the `uniform(c_T)` of the paper.
    #[inline]
    pub fn uniform(&mut self, limit: f64) -> f64 {
        self.next_f64() * limit
    }

    /// Uniform usize in [0, n) via Lemire's multiply-shift (unbiased enough
    /// for n << 2^32; exact rejection loop for the tail).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0 && n <= u32::MAX as usize);
        let n = n as u32;
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut lo = m as u32;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                lo = m as u32;
            }
        }
        (m >> 32) as usize
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, s: &mut [T]) {
        for i in (1..s.len()).rev() {
            let j = self.below(i + 1);
            s.swap(i, j);
        }
    }

    /// Standard normal via Marsaglia's polar method.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang; boosted for shape < 1.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        debug_assert!(shape > 0.0);
        if shape < 1.0 {
            // Gamma(a) = Gamma(a + 1) * U^{1/a}
            let g = self.gamma(shape + 1.0);
            let u = loop {
                let u = self.next_f64();
                if u > 0.0 {
                    break u;
                }
            };
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u > 0.0 && u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Dirichlet draw with concentration `alpha[i]`, written into `out`
    /// (normalized gamma draws).
    pub fn dirichlet(&mut self, alpha: &[f64], out: &mut [f64]) {
        debug_assert_eq!(alpha.len(), out.len());
        let mut sum = 0.0;
        for (o, &a) in out.iter_mut().zip(alpha) {
            let g = self.gamma(a);
            *o = g;
            sum += g;
        }
        if sum <= 0.0 {
            // pathological underflow: fall back to uniform
            let u = 1.0 / out.len() as f64;
            out.iter_mut().for_each(|o| *o = u);
            return;
        }
        out.iter_mut().for_each(|o| *o /= sum);
    }

    /// Symmetric Dirichlet draw.
    pub fn dirichlet_sym(&mut self, alpha: f64, out: &mut [f64]) {
        let mut sum = 0.0;
        for o in out.iter_mut() {
            let g = self.gamma(alpha);
            *o = g;
            sum += g;
        }
        if sum <= 0.0 {
            let u = 1.0 / out.len() as f64;
            out.iter_mut().for_each(|o| *o = u);
            return;
        }
        out.iter_mut().for_each(|o| *o /= sum);
    }

    /// Poisson(lambda) — Knuth for small lambda, normal approx for large.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        debug_assert!(lambda >= 0.0);
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.next_f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = lambda + lambda.sqrt() * self.normal();
            if x < 0.0 {
                0
            } else {
                x.round() as u64
            }
        }
    }
}

/// Precomputed Zipf(s) sampler over {0, .., n-1} by inverse-CDF binary
/// search — used to give synthetic vocabularies realistic frequency decay.
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        cdf.iter_mut().for_each(|c| *c /= total);
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Pcg32) -> usize {
        let u = rng.next_f64();
        self.cdf.partition_point(|&c| c <= u).min(self.cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::new(42, 7);
        let mut b = Pcg32::new(42, 7);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn parts_roundtrip_continues_the_sequence() {
        let mut a = Pcg32::new(7, 3);
        for _ in 0..17 {
            a.next_u32();
        }
        let (state, inc) = a.to_parts();
        let mut b = Pcg32::from_parts(state, inc);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg32::seeded(1);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Pcg32::seeded(3);
        let n = 10;
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(n)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(4);
        let n = 200_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            m += x;
            v += x * x;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.03, "var {v}");
    }

    #[test]
    fn gamma_moments() {
        let mut r = Pcg32::seeded(5);
        for &shape in &[0.05, 0.5, 2.0, 17.3] {
            let n = 100_000;
            let mut m = 0.0;
            for _ in 0..n {
                m += r.gamma(shape);
            }
            m /= n as f64;
            assert!(
                (m - shape).abs() < 0.05 * shape.max(1.0),
                "gamma({shape}) mean {m}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Pcg32::seeded(6);
        let mut out = vec![0.0; 64];
        r.dirichlet_sym(0.1, &mut out);
        let s: f64 = out.iter().sum();
        assert!((s - 1.0).abs() < 1e-12);
        assert!(out.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn poisson_mean() {
        let mut r = Pcg32::seeded(7);
        for &lam in &[3.0, 80.0] {
            let n = 50_000;
            let mut m = 0.0;
            for _ in 0..n {
                m += r.poisson(lam) as f64;
            }
            m /= n as f64;
            assert!((m - lam).abs() < 0.05 * lam, "poisson({lam}) mean {m}");
        }
    }

    #[test]
    fn zipf_is_monotone_decreasing_in_rank() {
        let mut r = Pcg32::seeded(8);
        let z = Zipf::new(100, 1.07);
        let mut counts = vec![0usize; 100];
        for _ in 0..200_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[70]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(9);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
