//! Mini property-testing harness (the vendored crate set has no `proptest`).
//!
//! A property is a closure over a seeded [`Pcg32`]; the harness runs it for
//! `cases` independent seeds and reports the failing seed so a shrunk repro
//! is one `prop_case` call away.

use super::rng::Pcg32;

/// Run `prop` for `cases` seeds; panic with the failing seed + message.
///
/// ```
/// use fnomad_lda::util::quickcheck::check;
/// check("addition commutes", 64, |rng| {
///     let (a, b) = (rng.next_u32() as u64, rng.next_u32() as u64);
///     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
/// });
/// ```
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    // Miri executes ~1000x slower than native; a handful of seeds still
    // exercises the UB-sensitive paths (the CI Miri job runs the pure
    // wire/codec/cache properties), while native runs keep full coverage.
    let cases = if cfg!(miri) { cases.min(4) } else { cases };
    for seed in 0..cases {
        let mut rng = Pcg32::new(0xF00D + seed, seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at seed {seed}: {msg}");
        }
    }
}

/// Re-run a single failing case by seed (for debugging).
pub fn prop_case<F>(seed: u64, mut prop: F) -> Result<(), String>
where
    F: FnMut(&mut Pcg32) -> Result<(), String>,
{
    let mut rng = Pcg32::new(0xF00D + seed, seed);
    prop(&mut rng)
}

/// Assert two floats are close (relative + absolute tolerance), Err-style
/// for use inside properties.
pub fn close(got: f64, want: f64, rtol: f64, atol: f64) -> Result<(), String> {
    if (got - want).abs() <= atol + rtol * want.abs() {
        Ok(())
    } else {
        Err(format!("got {got}, want {want} (rtol {rtol}, atol {atol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("tautology", 16, |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed at seed 0")]
    fn failing_property_reports_seed() {
        check("always-fails", 4, |_| Err("nope".into()));
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6, 0.0).is_ok());
        assert!(close(1.0, 1.1, 1e-6, 0.0).is_err());
        assert!(close(0.0, 1e-9, 0.0, 1e-6).is_ok());
    }
}
