//! Special functions (std has no `lgamma`; the `libm`/`libc` crates are not
//! in the offline vendor set, so we carry a well-tested Lanczos
//! implementation).  Used by the Rust-side reference LL evaluator
//! (`lda::eval`) which cross-checks the blocked evaluator at test time.

// the published Lanczos coefficients and reference values carry more
// digits than f64 resolves; keep them verbatim for auditability
#![allow(clippy::excessive_precision)]

/// Lanczos approximation coefficients (g = 7, n = 9) — the classic
/// Godfrey/Pugh set; |rel err| < 1e-13 over the positive reals.
const LANCZOS_G: f64 = 7.0;
const LANCZOS: [f64; 9] = [
    0.99999999999980993,
    676.5203681218851,
    -1259.1392167224028,
    771.32342877765313,
    -176.61502916214059,
    12.507343278686905,
    -0.13857109526572012,
    9.9843695780195716e-6,
    1.5056327351493116e-7,
];

const LN_SQRT_2PI: f64 = 0.9189385332046727417803297; // ln(sqrt(2*pi))

/// Natural log of the Gamma function for x > 0.
///
/// Uses the reflection formula below 0.5 to keep the Lanczos series in its
/// accurate range.
pub fn lgamma(x: f64) -> f64 {
    debug_assert!(x > 0.0, "lgamma domain: x > 0, got {x}");
    if x < 0.5 {
        // reflection: lgamma(x) = ln(pi / sin(pi x)) - lgamma(1 - x)
        let s = (std::f64::consts::PI * x).sin();
        return (std::f64::consts::PI / s).ln() - lgamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = LANCZOS[0];
    let t = x + LANCZOS_G + 0.5;
    for (i, &c) in LANCZOS.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    LN_SQRT_2PI + (x + 0.5) * t.ln() - t + a.ln()
}

/// ln(Gamma(x + n) / Gamma(x)) as a sum of logs — cheaper and exacter than
/// two lgamma calls when n is a small integer (used per-document).
pub fn lgamma_ratio_int(x: f64, n: u32) -> f64 {
    if n < 16 {
        let mut acc = 0.0;
        for k in 0..n {
            acc += (x + k as f64).ln();
        }
        acc
    } else {
        lgamma(x + n as f64) - lgamma(x)
    }
}

/// Digamma (psi) function for x > 0; asymptotic series with recurrence
/// shift.  Used by the hyperparameter-estimation extension.
pub fn digamma(x: f64) -> f64 {
    debug_assert!(x > 0.0);
    let mut x = x;
    let mut result = 0.0;
    while x < 10.0 {
        result -= 1.0 / x;
        x += 1.0;
    }
    let inv = 1.0 / x;
    let inv2 = inv * inv;
    result + x.ln() - 0.5 * inv
        - inv2
            * (1.0 / 12.0
                - inv2 * (1.0 / 120.0 - inv2 * (1.0 / 252.0 - inv2 * (1.0 / 240.0))))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Reference values from scipy.special.gammaln / psi (float64).
    const CASES: &[(f64, f64)] = &[
        (0.01, 4.599479878042022),
        (0.048828125, 2.9931801925203874), // alpha = 50/1024
        (0.5, 0.5723649429247004),
        (1.0, 0.0),
        (2.0, 0.0),
        (3.0, 0.693147180559945),
        (10.0, 12.801827480081467),
        (128.5, 493.9784867952413),
        (1024.0, 6071.28041294445),
        (5_000_000.0, 72124735.5584562),
    ];

    #[test]
    fn lgamma_matches_scipy() {
        for &(x, want) in CASES {
            let got = lgamma(x);
            let tol = 1e-12 * want.abs().max(1.0);
            assert!(
                (got - want).abs() < tol,
                "lgamma({x}) = {got}, want {want}"
            );
        }
    }

    #[test]
    fn lgamma_recurrence_property() {
        // lgamma(x+1) = lgamma(x) + ln(x)
        let mut x = 0.07;
        while x < 2000.0 {
            let lhs = lgamma(x + 1.0);
            let rhs = lgamma(x) + x.ln();
            assert!(
                (lhs - rhs).abs() < 1e-10 * lhs.abs().max(1.0),
                "recurrence fails at {x}: {lhs} vs {rhs}"
            );
            x *= 1.7;
        }
    }

    #[test]
    fn lgamma_ratio_matches_difference() {
        for &(x, _) in CASES {
            for n in [0u32, 1, 3, 15, 16, 100] {
                let got = lgamma_ratio_int(x, n);
                let want = lgamma(x + n as f64) - lgamma(x);
                assert!(
                    (got - want).abs() < 1e-9 * want.abs().max(1.0),
                    "ratio({x}, {n}) = {got}, want {want}"
                );
            }
        }
    }

    #[test]
    fn digamma_matches_scipy() {
        for &(x, want) in &[
            (0.5, -1.9635100260214235),
            (1.0, -0.5772156649015329),
            (10.0, 2.251752589066721),
            (1000.0, 6.907255195648812),
        ] {
            let got = digamma(x);
            assert!(
                (got - want).abs() < 1e-10 * want.abs().max(1.0),
                "digamma({x}) = {got}, want {want}"
            );
        }
    }
}
