//! Crash-safe file IO: write-to-temp + fsync + rename, with a streaming
//! FNV-1a fingerprint of everything written.
//!
//! Every durable artifact in the repo (FNLDA001 checkpoints, the
//! resilience MANIFEST) goes through [`AtomicFile`]: readers of the
//! destination path see either the old complete file or the new complete
//! file, never a torn prefix, because the only mutation of the
//! destination is a same-directory `rename(2)`.  The fingerprint returned
//! by [`AtomicFile::commit`] is what the resilience manifest records to
//! detect corruption that happens *after* the atomic write (disk faults,
//! deliberate fault injection).

use std::fs::File;
use std::io::{self, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// 64-bit FNV-1a streaming hasher (the same scheme `infer::model` uses
/// for artifact fingerprints).
pub struct Fnv1a(u64);

impl Fnv1a {
    pub fn new() -> Fnv1a {
        Fnv1a(0xcbf2_9ce4_8422_2325)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv1a {
    fn default() -> Fnv1a {
        Fnv1a::new()
    }
}

/// FNV-1a fingerprint of a file's current bytes (the verification side of
/// [`AtomicFile::commit`]'s return value).
pub fn fnv1a_of_file(path: &Path) -> Result<u64, String> {
    let mut f = File::open(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let mut hash = Fnv1a::new();
    let mut buf = [0u8; 64 * 1024];
    loop {
        let n = f.read(&mut buf).map_err(|e| format!("{}: {e}", path.display()))?;
        if n == 0 {
            return Ok(hash.finish());
        }
        hash.update(&buf[..n]);
    }
}

/// Discriminator for temp names: two writers racing on the same
/// destination (e.g. the async checkpoint writer and a synchronous
/// epoch-0 baseline save) must not share a temp file.
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// A buffered writer whose content only reaches `dest` on [`commit`]:
/// bytes land in a `<dest>.tmp-<pid>-<seq>` sibling, `commit` flushes,
/// fsyncs, and renames it over `dest`, and dropping without committing
/// removes the temp file.  All written bytes stream through an FNV-1a
/// hash; `commit` returns the fingerprint.
///
/// [`commit`]: AtomicFile::commit
pub struct AtomicFile {
    dest: PathBuf,
    tmp: PathBuf,
    file: Option<BufWriter<File>>,
    hash: Fnv1a,
    committed: bool,
}

impl AtomicFile {
    /// Open a temp sibling of `dest` for writing, creating parent
    /// directories as needed.
    pub fn create(dest: &Path) -> Result<AtomicFile, String> {
        if let Some(dir) = dest.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).map_err(|e| format!("create {}: {e}", dir.display()))?;
            }
        }
        // relaxed: only uniqueness matters, which atomicity alone gives —
        // no other memory is published under this counter
        let seq = TMP_SEQ.fetch_add(1, Ordering::Relaxed);
        let mut tmp_name = dest.as_os_str().to_os_string();
        tmp_name.push(format!(".tmp-{}-{seq}", std::process::id()));
        let tmp = PathBuf::from(tmp_name);
        let file = File::create(&tmp).map_err(|e| format!("{}: {e}", tmp.display()))?;
        Ok(AtomicFile {
            dest: dest.to_path_buf(),
            tmp,
            file: Some(BufWriter::new(file)),
            hash: Fnv1a::new(),
            committed: false,
        })
    }

    /// Flush, fsync, and rename onto the destination.  Returns the
    /// FNV-1a fingerprint of the committed bytes.
    pub fn commit(mut self) -> Result<u64, String> {
        let err = |e: io::Error| format!("{}: {e}", self.tmp.display());
        let mut w = self.file.take().expect("commit called once");
        w.flush().map_err(err)?;
        let f = w.into_inner().map_err(|e| err(e.into_error()))?;
        // durability order matters: the data must be on disk before the
        // rename makes it reachable, or a crash could leave a complete-
        // looking name pointing at unwritten blocks
        f.sync_all().map_err(err)?;
        drop(f);
        std::fs::rename(&self.tmp, &self.dest)
            .map_err(|e| format!("rename {} -> {}: {e}", self.tmp.display(), self.dest.display()))?;
        // best-effort directory fsync: the rename itself is already
        // atomic for live readers; this only narrows the power-loss window
        if let Some(dir) = self.dest.parent() {
            if !dir.as_os_str().is_empty() {
                if let Ok(d) = File::open(dir) {
                    let _ = d.sync_all();
                }
            }
        }
        self.committed = true;
        Ok(self.hash.finish())
    }
}

impl Write for AtomicFile {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        let n = self.file.as_mut().expect("write before commit").write(buf)?;
        self.hash.update(&buf[..n]);
        Ok(n)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.file.as_mut().expect("flush before commit").flush()
    }
}

impl Drop for AtomicFile {
    fn drop(&mut self) {
        if !self.committed {
            drop(self.file.take());
            let _ = std::fs::remove_file(&self.tmp);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("fnomad_fsio_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn commit_replaces_dest_and_fingerprints() {
        let dest = tmp("commit.bin");
        std::fs::write(&dest, b"old contents").unwrap();
        let mut w = AtomicFile::create(&dest).unwrap();
        w.write_all(b"new contents").unwrap();
        let fp = w.commit().unwrap();
        assert_eq!(std::fs::read(&dest).unwrap(), b"new contents");
        assert_eq!(fp, fnv1a_of_file(&dest).unwrap());
        let _ = std::fs::remove_file(&dest);
    }

    #[test]
    fn drop_without_commit_leaves_dest_untouched() {
        let dest = tmp("abort.bin");
        std::fs::write(&dest, b"survives").unwrap();
        {
            let mut w = AtomicFile::create(&dest).unwrap();
            w.write_all(b"half-written garbage").unwrap();
            // dropped uncommitted: simulates a failure mid-write
        }
        assert_eq!(std::fs::read(&dest).unwrap(), b"survives");
        // and no temp litter remains next to it
        let dir = dest.parent().unwrap();
        let litter: Vec<_> = std::fs::read_dir(dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().starts_with("abort.bin.tmp-"))
            .collect();
        assert!(litter.is_empty(), "uncommitted temp files left behind");
        let _ = std::fs::remove_file(&dest);
    }

    #[test]
    fn fnv1a_matches_known_vector() {
        // standard FNV-1a test vector: "a" -> 0xaf63dc4c8601ec8c
        let mut h = Fnv1a::new();
        h.update(b"a");
        assert_eq!(h.finish(), 0xaf63_dc4c_8601_ec8c);
    }
}
