//! `train --metrics FILE.jsonl`: the per-epoch metrics exporter.
//!
//! [`MetricsWriter`] is a [`TrainObserver`] that appends one JSON object
//! per epoch: the [`EpochReport`] scalars, the [`RingTelemetry`]
//! breakdown when the engine provides one, the latest evaluation LL, and
//! a snapshot of the metrics registry.  One line per epoch, every line a
//! complete JSON object — the format `rust/tests/observability.rs` and
//! the CI smoke validate.
//!
//! Required keys on every line (the schema contract): `epoch`, `secs`,
//! `processed`, `processed_total`.  `epoch` and `processed_total` are
//! monotone non-decreasing across lines.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::PathBuf;

use crate::coordinator::engine::EpochReport;
use crate::coordinator::observer::{EvalPoint, TrainObserver};
use crate::obs::registry::Registry;
use crate::util::bench::json_string;

/// Appends one JSONL metrics line per epoch; see the module docs.
pub struct MetricsWriter {
    file: std::fs::File,
    path: PathBuf,
    epochs: u64,
    processed_total: u64,
    last_ll: Option<f64>,
    registry: &'static Registry,
}

impl MetricsWriter {
    /// Create/truncate `path` (parent directories included).  Snapshots
    /// come from the process-global registry.
    pub fn create(path: impl Into<PathBuf>) -> Result<Self, String> {
        Self::create_with(path, crate::obs::registry::global())
    }

    /// As [`Self::create`] with an explicit registry (tests).
    pub fn create_with(
        path: impl Into<PathBuf>,
        registry: &'static Registry,
    ) -> Result<Self, String> {
        let path = path.into();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
            }
        }
        let file = std::fs::File::create(&path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        Ok(MetricsWriter {
            file,
            path,
            epochs: 0,
            processed_total: 0,
            last_ll: None,
            registry,
        })
    }
}

fn push_num(out: &mut String, key: &str, v: f64) {
    out.push(',');
    out.push_str(&json_string(key));
    out.push(':');
    if v.is_finite() {
        let _ = write!(out, "{v}");
    } else {
        out.push_str("null");
    }
}

fn push_int(out: &mut String, key: &str, v: u64) {
    out.push(',');
    out.push_str(&json_string(key));
    out.push(':');
    let _ = write!(out, "{v}");
}

impl TrainObserver for MetricsWriter {
    fn on_epoch(&mut self, epoch: usize, report: &EpochReport) -> Result<(), String> {
        self.epochs += 1;
        self.processed_total += report.processed;
        let mut line = format!("{{\"epoch\":{epoch}");
        push_num(&mut line, "secs", report.secs);
        push_int(&mut line, "processed", report.processed);
        push_int(&mut line, "processed_total", self.processed_total);
        push_int(&mut line, "msgs", report.msgs);
        push_int(&mut line, "stale_reads", report.stale_reads);
        if let Some(ll) = self.last_ll {
            push_num(&mut line, "ll", ll);
        }
        if let Some(ring) = &report.ring {
            push_num(&mut line, "ring.inject_secs", ring.inject_secs);
            push_num(&mut line, "ring.circulate_secs", ring.circulate_secs);
            push_num(&mut line, "ring.fold_secs", ring.fold_secs);
            push_num(&mut line, "ring.set_secs", ring.set_secs);
            push_num(&mut line, "ring.hop_p50_us", ring.hop_p50_us);
            push_num(&mut line, "ring.hop_p95_us", ring.hop_p95_us);
            push_num(&mut line, "ring.hop_max_us", ring.hop_max_us);
            for s in &ring.slots {
                push_num(&mut line, &format!("slot.{}.sample_secs", s.slot), s.sample_secs);
                push_num(&mut line, &format!("slot.{}.wait_secs", s.slot), s.wait_secs);
                push_int(&mut line, &format!("slot.{}.processed", s.slot), s.processed);
            }
        }
        for (name, value) in self.registry.snapshot() {
            push_num(&mut line, &name, value);
        }
        line.push_str("}\n");
        self.file
            .write_all(line.as_bytes())
            .map_err(|e| format!("{}: {e}", self.path.display()))
    }

    fn on_eval(&mut self, point: &EvalPoint<'_>) -> Result<(), String> {
        self.last_ll = Some(point.ll);
        Ok(())
    }

    fn on_finish(
        &mut self,
        _result: &mut crate::coordinator::TrainResult,
    ) -> Result<(), String> {
        self.file
            .flush()
            .map_err(|e| format!("{}: {e}", self.path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{RingTelemetry, SlotTelemetry};

    #[test]
    fn lines_carry_the_required_schema() {
        let dir = std::env::temp_dir().join("fnomad_export_test");
        let path = dir.join("m.jsonl");
        // a leaked local registry keeps this test independent of global tallies
        let reg: &'static Registry = Box::leak(Box::new(Registry::new()));
        reg.counter("t.count").add(3);
        let mut w = MetricsWriter::create_with(&path, reg).unwrap();
        let mut rep = EpochReport {
            processed: 10,
            secs: 0.5,
            stale_reads: 0,
            msgs: 7,
            ring: None,
        };
        w.on_epoch(1, &rep).unwrap();
        rep.ring = Some(RingTelemetry {
            inject_secs: 0.01,
            slots: vec![SlotTelemetry {
                slot: 0,
                sample_secs: 0.4,
                wait_secs: 0.05,
                processed: 10,
            }],
            ..Default::default()
        });
        w.on_epoch(2, &rep).unwrap();
        drop(w); // File writes are unbuffered; on_finish only flushes
        let body = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = body.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in &lines {
            assert!(line.starts_with("{\"epoch\":"));
            assert!(line.ends_with('}'));
            for key in ["\"secs\":", "\"processed\":", "\"processed_total\":"] {
                assert!(line.contains(key), "{line} missing {key}");
            }
            assert!(line.contains("\"t.count\":3"));
        }
        assert!(lines[0].contains("\"processed_total\":10"));
        assert!(lines[1].contains("\"processed_total\":20"));
        assert!(lines[1].contains("\"ring.inject_secs\":0.01"));
        assert!(lines[1].contains("\"slot.0.sample_secs\":0.4"));
        let _ = std::fs::remove_dir_all(dir);
    }
}
