//! Chrome-trace-event recorder: Perfetto-loadable span timelines.
//!
//! `train --trace FILE.json` turns recording on; spans are buffered
//! in-process and written once at the end of the run as a
//! `{"traceEvents":[...]}` JSON file of complete `"X"` events
//! (<https://ui.perfetto.dev> opens it directly).  Recorded spans:
//!
//! | cat          | name                  | tid      |
//! |--------------|-----------------------|----------|
//! | `epoch`      | `epoch N`             | 0        |
//! | `slot`       | `slot S sample`       | S + 1    |
//! | `checkpoint` | `checkpoint epoch N`  | 100      |
//! | `recovery`   | `ring failure` / `reload checkpoint` / `respawn ring` | 0 |
//!
//! When recording is off (the default), every entry point is one relaxed
//! atomic load and no clock is read — [`start`] returns `None` and
//! [`complete`] drops it on the floor.  Timestamps are microseconds
//! since the first trace-system touch in this process, which is what the
//! trace-event format expects (`ts`/`dur` in µs).

use std::path::Path;
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::bench::json_string;
use crate::util::sync::lock_recover;
use crate::util::sync::static_atomic::{AtomicUsize, Ordering};

// Process-global on/off switch; 0 = off.  `static_atomic` (always std):
// a process-global mode flag, out of loom's scope by design.
static ENABLED: AtomicUsize = AtomicUsize::new(0);

/// Trace lane of the background checkpoint writer (see the module table).
pub const TID_CHECKPOINT: u64 = 100;

#[derive(Clone, Debug)]
struct TraceEvent {
    name: String,
    cat: &'static str,
    ts_us: u64,
    dur_us: u64,
    tid: u64,
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn buffer() -> &'static Mutex<Vec<TraceEvent>> {
    static BUF: OnceLock<Mutex<Vec<TraceEvent>>> = OnceLock::new();
    BUF.get_or_init(|| Mutex::new(Vec::new()))
}

/// Turn span recording on (idempotent; there is deliberately no `off` —
/// a run either traces or it doesn't).
pub fn enable() {
    epoch(); // pin t=0 at enable time, before any span starts
    // relaxed: independent mode switch; a racing recorder that misses the
    // flip records nothing, same as if it ran a moment earlier.
    ENABLED.store(1, Ordering::Relaxed);
}

pub fn enabled() -> bool {
    // relaxed: independent mode switch read; see `enable`.
    ENABLED.load(Ordering::Relaxed) == 1
}

/// Begin a span: the clock is only read when recording is on.
pub fn start() -> Option<Instant> {
    if enabled() {
        Some(Instant::now())
    } else {
        None
    }
}

/// Finish a span begun with [`start`] on the coordinator timeline
/// (tid 0).  No-op when `t0` is `None`.
pub fn complete(cat: &'static str, name: &str, t0: Option<Instant>) {
    complete_tid(cat, name, t0, 0);
}

/// [`complete`] on an explicit thread lane.
pub fn complete_tid(cat: &'static str, name: &str, t0: Option<Instant>, tid: u64) {
    let Some(t0) = t0 else { return };
    let dur_us = t0.elapsed().as_micros() as u64;
    let ts_us = t0.duration_since(epoch()).as_micros() as u64;
    push(TraceEvent { name: name.to_string(), cat, ts_us, dur_us, tid });
}

/// Record a span from externally measured times — used for per-slot ring
/// work, whose durations arrive in the `SyncS` fold rather than from a
/// local clock pair.  `ts_us` is microseconds on this process's trace
/// timeline (e.g. a span start captured with [`start`] and converted via
/// [`us_since_epoch`]).
pub fn span_at(cat: &'static str, name: &str, ts_us: u64, dur_us: u64, tid: u64) {
    if !enabled() {
        return;
    }
    push(TraceEvent { name: name.to_string(), cat, ts_us, dur_us, tid });
}

/// Microseconds of `t` on the trace timeline (0 for instants that race
/// the timeline's pinning).
pub fn us_since_epoch(t: Instant) -> u64 {
    t.checked_duration_since(epoch()).map(|d| d.as_micros() as u64).unwrap_or(0)
}

fn push(ev: TraceEvent) {
    lock_recover(buffer()).push(ev);
}

/// Drain the buffer and write the Perfetto-loadable JSON file.  Call
/// once, at the end of the run.
pub fn write(path: &Path) -> Result<(), String> {
    let events = std::mem::take(&mut *lock_recover(buffer()));
    let mut out = String::from("{\"traceEvents\":[");
    for (i, ev) in events.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"name\":{},\"cat\":{},\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":1,\"tid\":{}}}",
            json_string(&ev.name),
            json_string(ev.cat),
            ev.ts_us,
            ev.dur_us,
            ev.tid
        ));
    }
    out.push_str("]}\n");
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
        }
    }
    std::fs::write(path, out).map_err(|e| format!("{}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    // One test, not two: ENABLED and the buffer are process-global, and
    // the disabled-state assertions must run before anything enables.
    #[test]
    fn spans_round_trip_through_the_file() {
        assert_eq!(start(), None, "recording defaults to off");
        complete("epoch", "nothing", None); // must not record
        span_at("slot", "nothing", 0, 1, 1); // must not record when off
        enable();
        let t0 = start();
        assert!(t0.is_some());
        std::thread::sleep(std::time::Duration::from_millis(2));
        complete("epoch", "epoch 0", t0);
        span_at("slot", "slot 1 sample", 10, 20, 2);
        let path = std::env::temp_dir().join("fnomad_trace_test").join("t.json");
        write(&path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.starts_with("{\"traceEvents\":["));
        assert!(body.contains("\"ph\":\"X\""));
        assert!(body.contains("\"name\":\"epoch 0\""));
        assert!(body.contains("\"tid\":2"));
        // buffer drained: a second write is empty
        write(&path).unwrap();
        let body2 = std::fs::read_to_string(&path).unwrap();
        assert_eq!(body2, "{\"traceEvents\":[]}\n");
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }
}
