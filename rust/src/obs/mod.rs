//! Observability: the telemetry substrate for training and serving.
//!
//! The paper's central claim is a *throughput* claim — F+Nomad wins
//! because asynchronous ring circulation keeps every core sampling — so
//! the reproduction needs to show *where epoch time goes*, not just how
//! much of it there was.  This module is the substrate every perf PR
//! reports through:
//!
//! * [`registry`] — a process-global metrics registry: named counters,
//!   gauges, and log₂-bucket histograms behind lock-free atomics, with a
//!   deterministic (sorted) snapshot.
//! * [`event`] — structured leveled events: one stable
//!   `ts=… level=… target=… msg="…" k=v` line per event (or one JSON
//!   object in `--log-json` mode), filtered by `--log-level` /
//!   `FNOMAD_LOG`.  Replaces the library's ad-hoc `eprintln!` narration;
//!   the `no-raw-print` rule in `xtask lint-invariants` keeps it that way.
//! * [`trace`] — an in-process Chrome-trace-event recorder: complete
//!   `"X"` spans for epochs, per-slot ring work, checkpoint writes, and
//!   the supervisor's failure→reload→respawn recovery timeline, written
//!   as a Perfetto-loadable JSON file by `train --trace FILE.json`.
//! * [`export`] — the `--metrics FILE.jsonl` exporter: a
//!   [`TrainObserver`](crate::coordinator::observer::TrainObserver) that
//!   appends one JSON line per epoch (epoch scalars + `RingTelemetry`
//!   breakdown + a registry snapshot).
//!
//! # Cost discipline
//!
//! Everything here is opt-in and near-zero when off: trace recording is a
//! single relaxed load before any work happens, events early-out on a
//! relaxed level check, and the per-epoch ring telemetry is collected
//! from clocks already read at the engine/transport boundary — never
//! inside the samplers, so the `xtask lint-invariants` wall-clock ban in
//! sampler scope holds and fixed-seed LL trajectories are bit-identical
//! with and without `--metrics`/`--trace` (asserted by
//! `rust/tests/observability.rs`).

pub mod event;
pub mod export;
pub mod registry;
pub mod trace;
