//! Process-global metrics registry: named counters, gauges, and
//! log₂-bucket histograms.
//!
//! Instruments register by name (`registry::global().counter("train.x")`)
//! and get back a cheap cloneable handle; recording is a single relaxed
//! atomic op.  [`Registry::snapshot`] renders the whole registry as a
//! sorted `(name, value)` report — sorted so two snapshots of the same
//! state are byte-identical, which the JSONL metrics exporter and the
//! tests rely on.
//!
//! # Atomics and orderings
//!
//! Handles use `util::sync::static_atomic` (always `std`, never loom):
//! registry cells are process-global tallies that outlive any loom model
//! execution, exactly the class `static_atomic` exists for.  Every load
//! and store is `Relaxed` and justified at the site: each cell is an
//! independent monotone counter or last-write-wins gauge — no cell's
//! value is used to establish ordering with any other memory, and a
//! snapshot that observes a torn *cross-cell* state (counter A bumped,
//! counter B not yet) is an acceptable report of a moment that almost
//! existed.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::metrics::{Log2Histogram, LATENCY_BUCKETS};
use crate::util::sync::static_atomic::{AtomicU64, Ordering};
use crate::util::sync::lock_recover;

/// Monotone counter handle.  Clone freely; all clones share the cell.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        // relaxed: independent monotone tally; nothing orders against it.
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        // relaxed: single-cell read of a monotone tally.
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge handle (u64; scale fractions yourself, e.g.
/// permille, to stay integral).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: u64) {
        // relaxed: last-write-wins level; readers only want *a* recent value.
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        // relaxed: single-cell read of a last-write-wins level.
        self.0.load(Ordering::Relaxed)
    }
}

/// Lock-free log₂-bucket histogram handle (nanosecond values; same
/// bucket layout as [`crate::util::metrics::latency_bucket`]).
#[derive(Clone)]
pub struct Histo(Arc<HistoCell>);

pub struct HistoCell {
    buckets: [AtomicU64; LATENCY_BUCKETS],
}

impl Histo {
    pub fn record_ns(&self, ns: u64) {
        let b = crate::util::metrics::latency_bucket(ns);
        // relaxed: per-bucket monotone tally; a snapshot may see bucket
        // counts from slightly different instants, which only perturbs a
        // percentile estimate that is already ≤ √2× approximate.
        self.0.buckets[b].fetch_add(1, Ordering::Relaxed);
    }

    /// Copy the bucket counts into the shared single-threaded histogram
    /// type, through which all percentile math is done.
    pub fn snapshot(&self) -> Log2Histogram {
        let mut h = Log2Histogram::default();
        for (b, cell) in self.0.buckets.iter().enumerate() {
            // relaxed: see `record_ns` — torn cross-bucket reads are fine.
            let c = cell.load(Ordering::Relaxed);
            h.counts[b] = c;
            h.total += c;
        }
        h
    }
}

enum Cell {
    Counter(Arc<AtomicU64>),
    Gauge(Arc<AtomicU64>),
    Histo(Arc<HistoCell>),
}

/// The registry: a name → cell map.  Registration takes a lock (rare,
/// startup-time); recording through the returned handles never does.
///
/// Prefer [`global`] in production code.  Tests construct their own
/// `Registry::new()` so parallel tests never share tallies.
pub struct Registry {
    cells: Mutex<BTreeMap<String, Cell>>,
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

impl Registry {
    pub fn new() -> Self {
        Registry { cells: Mutex::new(BTreeMap::new()) }
    }

    /// Get-or-create the named counter.  Panics if `name` is already
    /// registered as a different kind — a naming bug worth failing loudly
    /// on.
    pub fn counter(&self, name: &str) -> Counter {
        let mut cells = lock_recover(&self.cells);
        let cell = cells
            .entry(name.to_string())
            .or_insert_with(|| Cell::Counter(Arc::new(AtomicU64::new(0))));
        match cell {
            Cell::Counter(a) => Counter(Arc::clone(a)),
            _ => panic!("metric {name:?} already registered with a different kind"),
        }
    }

    /// Get-or-create the named gauge.  Panics on kind mismatch.
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut cells = lock_recover(&self.cells);
        let cell = cells
            .entry(name.to_string())
            .or_insert_with(|| Cell::Gauge(Arc::new(AtomicU64::new(0))));
        match cell {
            Cell::Gauge(a) => Gauge(Arc::clone(a)),
            _ => panic!("metric {name:?} already registered with a different kind"),
        }
    }

    /// Get-or-create the named histogram.  Panics on kind mismatch.
    pub fn histogram(&self, name: &str) -> Histo {
        let mut cells = lock_recover(&self.cells);
        let cell = cells.entry(name.to_string()).or_insert_with(|| {
            Cell::Histo(Arc::new(HistoCell {
                buckets: [const { AtomicU64::new(0) }; LATENCY_BUCKETS],
            }))
        });
        match cell {
            Cell::Histo(h) => Histo(Arc::clone(h)),
            _ => panic!("metric {name:?} already registered with a different kind"),
        }
    }

    /// Render every cell as `(name, value)`, sorted by name (the
    /// `BTreeMap` order).  Histograms flatten to `name.count`,
    /// `name.p50_us`, `name.p99_us`, `name.max_bucket_us` — still sorted,
    /// because the suffixes sort within the name's range.
    pub fn snapshot(&self) -> Vec<(String, f64)> {
        let cells = lock_recover(&self.cells);
        let mut out = Vec::with_capacity(cells.len());
        for (name, cell) in cells.iter() {
            match cell {
                Cell::Counter(a) | Cell::Gauge(a) => {
                    // relaxed: single-cell read; see the handle docs.
                    out.push((name.clone(), a.load(Ordering::Relaxed) as f64));
                }
                Cell::Histo(h) => {
                    let snap = Histo(Arc::clone(h)).snapshot();
                    out.push((format!("{name}.count"), snap.total as f64));
                    out.push((format!("{name}.p50_us"), snap.percentile_us(50.0)));
                    out.push((format!("{name}.p99_us"), snap.percentile_us(99.0)));
                }
            }
        }
        out
    }
}

/// The process-global registry.  Library instruments record here; the
/// exporters ([`crate::obs::export`], the serve-model `Stats` reply)
/// read it.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_register_and_report() {
        let r = Registry::new();
        let c = r.counter("a.count");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = r.gauge("b.level");
        g.set(42);
        g.set(7);
        assert_eq!(g.get(), 7);
        // handles to the same name share the cell
        r.counter("a.count").add(5);
        assert_eq!(c.get(), 10);
        let snap = r.snapshot();
        assert_eq!(
            snap,
            vec![("a.count".to_string(), 10.0), ("b.level".to_string(), 7.0)]
        );
    }

    #[test]
    fn histogram_flattens_into_sorted_snapshot() {
        let r = Registry::new();
        let h = r.histogram("lat");
        for _ in 0..99 {
            h.record_ns(1 << 9);
        }
        h.record_ns(1 << 20);
        r.counter("zz").inc();
        let snap = r.snapshot();
        let names: Vec<&str> = snap.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["lat.count", "lat.p50_us", "lat.p99_us", "zz"]);
        assert_eq!(snap[0].1, 100.0);
        assert!(snap[1].1 > 0.0);
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted, "snapshot is sorted by name");
    }

    #[test]
    #[should_panic(expected = "different kind")]
    fn kind_mismatch_panics() {
        let r = Registry::new();
        let _ = r.counter("x");
        let _ = r.gauge("x");
    }

    #[test]
    fn empty_histogram_reports_zero_percentiles() {
        let r = Registry::new();
        let _ = r.histogram("lat");
        let snap = r.snapshot();
        assert_eq!(snap[1], ("lat.p50_us".to_string(), 0.0));
        assert_eq!(snap[2], ("lat.p99_us".to_string(), 0.0));
    }
}
