//! Structured leveled events: the library's one way to narrate itself.
//!
//! Every library-scope diagnostic goes through [`log_event!`], which
//! emits exactly one line per event on stderr:
//!
//! ```text
//! ts=12.345 level=warn target=resilience msg="ring failure: ..." restart=1 max=2
//! ```
//!
//! or, in JSON mode (`--log-json` / `FNOMAD_LOG_JSON=1`), one JSON
//! object per line with the same keys — machine-greppable either way.
//! `ts` is seconds since the first event-system touch in this process.
//!
//! Levels are `error < warn < info < debug`; the filter defaults to
//! `info` and is set by `--log-level` on the CLI or the `FNOMAD_LOG`
//! environment variable (CLI wins).  The level check is a single relaxed
//! atomic load, so disabled events cost one compare.
//!
//! Legacy text contracts (the `recovered: restarted from epoch E` line
//! grepped by CI and the resilience tests, the `rebind` narration, …)
//! survive conversion because the original text is carried verbatim in
//! `msg="..."` and consumers match on substrings.
//!
//! The `no-raw-print` rule in `xtask lint-invariants` bans
//! `eprintln!`/`println!` in library scope; this module holds the one
//! exempt `eprintln!` that actually writes the line.

use std::str::FromStr;
use std::sync::OnceLock;
use std::time::Instant;

use crate::util::bench::json_string;
use crate::util::sync::static_atomic::{AtomicUsize, Ordering};

/// Event severity, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

impl FromStr for Level {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Ok(Level::Error),
            "warn" | "warning" => Ok(Level::Warn),
            "info" => Ok(Level::Info),
            "debug" => Ok(Level::Debug),
            other => Err(format!(
                "unknown log level {other:?} (expected error|warn|info|debug)"
            )),
        }
    }
}

// Both statics are plain process-global switches read on every event —
// exactly the `static_atomic` (always-std, loom-exempt) use case.
// Encodings: LEVEL holds a `Level as usize`; JSON holds 0/1.
static LEVEL: AtomicUsize = AtomicUsize::new(Level::Info as usize);
static JSON: AtomicUsize = AtomicUsize::new(0);
static ENV_INIT: OnceLock<()> = OnceLock::new();

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Read `FNOMAD_LOG` / `FNOMAD_LOG_JSON` once.  Called lazily from
/// [`enabled`], so processes that never parse a CLI (tests, library
/// embedders) still honor the environment.
fn init_from_env() {
    ENV_INIT.get_or_init(|| {
        if let Ok(v) = std::env::var("FNOMAD_LOG") {
            if let Ok(l) = v.parse::<Level>() {
                // relaxed: independent mode switch; no ordering with event data.
                LEVEL.store(l as usize, Ordering::Relaxed);
            }
        }
        if std::env::var("FNOMAD_LOG_JSON").as_deref() == Ok("1") {
            // relaxed: independent mode switch.
            JSON.store(1, Ordering::Relaxed);
        }
    });
}

/// Set the level filter (CLI `--log-level`; overrides `FNOMAD_LOG`).
pub fn set_level(l: Level) {
    init_from_env();
    // relaxed: independent mode switch; the worst a racing reader sees is
    // one event filtered by the previous level.
    LEVEL.store(l as usize, Ordering::Relaxed);
}

/// Switch to JSONL output (CLI `--log-json`).
pub fn set_json(on: bool) {
    init_from_env();
    // relaxed: independent mode switch.
    JSON.store(on as usize, Ordering::Relaxed);
}

/// Would an event at `l` be emitted?  The macro's early-out; one relaxed
/// load when the event is filtered.
pub fn enabled(l: Level) -> bool {
    init_from_env();
    // relaxed: independent mode switch read; see `set_level`.
    (l as usize) <= LEVEL.load(Ordering::Relaxed)
}

/// Emit one event line.  Call through [`log_event!`], which does the
/// level check and field formatting.
pub fn emit(level: Level, target: &str, msg: &str, fields: &[(&str, String)]) {
    let ts = epoch().elapsed().as_secs_f64();
    // relaxed: independent mode switch read; see `set_json`.
    let line = if JSON.load(Ordering::Relaxed) == 1 {
        let mut out = format!(
            "{{\"ts\":{ts:.3},\"level\":{},\"target\":{},\"msg\":{}",
            json_string(level.name()),
            json_string(target),
            json_string(msg)
        );
        for (k, v) in fields {
            out.push(',');
            out.push_str(&json_string(k));
            out.push(':');
            out.push_str(&json_string(v));
        }
        out.push('}');
        out
    } else {
        let mut out = format!(
            "ts={ts:.3} level={} target={target} msg={}",
            level.name(),
            json_string(msg)
        );
        for (k, v) in fields {
            // values are quoted only when they need it, keeping k=v greppable
            if v.chars().all(|c| c.is_ascii_graphic() && c != '"') && !v.is_empty() {
                out.push_str(&format!(" {k}={v}"));
            } else {
                out.push_str(&format!(" {k}={}", json_string(v)));
            }
        }
        out
    };
    eprintln!("{line}");
}

/// Emit a structured event: `log_event!(Warn, "resilience", {restart = 1,
/// max = 2}, "ring failure: {why}")`.  The field block is optional.
/// Formatting (of the message *and* the fields) only happens when the
/// level passes the filter.
#[macro_export]
macro_rules! log_event {
    ($lvl:ident, $target:expr, { $($k:ident = $v:expr),* $(,)? }, $($fmt:tt)+) => {
        if $crate::obs::event::enabled($crate::obs::event::Level::$lvl) {
            $crate::obs::event::emit(
                $crate::obs::event::Level::$lvl,
                $target,
                &format!($($fmt)+),
                &[ $( (stringify!($k), format!("{}", $v)) ),* ],
            );
        }
    };
    ($lvl:ident, $target:expr, $($fmt:tt)+) => {
        $crate::log_event!($lvl, $target, {}, $($fmt)+)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_parse_and_order() {
        assert_eq!("warn".parse::<Level>().unwrap(), Level::Warn);
        assert_eq!("WARNING".parse::<Level>().unwrap(), Level::Warn);
        assert!("verbose".parse::<Level>().is_err());
        assert!(Level::Error < Level::Warn && Level::Warn < Level::Info);
    }

    #[test]
    fn level_filter_gates_enabled() {
        // Note: LEVEL is process-global; tests in this module run in one
        // process, so restore the default before returning.
        set_level(Level::Error);
        assert!(enabled(Level::Error));
        assert!(!enabled(Level::Warn));
        set_level(Level::Debug);
        assert!(enabled(Level::Debug));
        set_level(Level::Info);
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn emit_does_not_panic_with_odd_fields() {
        emit(
            Level::Info,
            "test",
            "msg with \"quotes\" and\nnewline",
            &[("k", "value with space".to_string()), ("n", "42".to_string())],
        );
    }
}
