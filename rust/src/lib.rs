//! # F+Nomad LDA
//!
//! A reproduction of *"A Scalable Asynchronous Distributed Algorithm for
//! Topic Modeling"* (Yu, Hsieh, Yun, Vishwanathan, Dhillon — WWW 2015) as a
//! three-layer Rust + JAX/Pallas + PJRT system:
//!
//! * **Corpus substrate** ([`corpus`]): flat CSR token storage — one
//!   `tokens` array plus `doc_offsets`, shared by the assignment array
//!   `z`, so millions of documents cost two allocations instead of one
//!   heap `Vec` per document (see the [`corpus`] module docs for the
//!   layout invariants).
//! * **F+tree sampling** ([`sampler::FTree`]): Θ(log T) multinomial
//!   sampling *and* Θ(log T) parameter maintenance, the data structure that
//!   makes per-token Gibbs updates cheap at thousands of topics.
//! * **F+LDA** ([`lda`]): collapsed Gibbs sampling in document-by-document
//!   and word-by-word order built on the q/r decompositions of §3.2, plus
//!   the SparseLDA / AliasLDA / plain-O(T) baselines.
//! * **Nomad runtime** ([`nomad`]): decentralized, asynchronous, lock-free
//!   parallel LDA via nomadic word tokens and a circulating global-count
//!   token (§4), with a parameter-server baseline ([`ps`]) and a bulk-sync
//!   baseline ([`adlda`]).  Ring communication sits behind a transport
//!   abstraction with in-process channels and a length-prefixed TCP
//!   backend ([`nomad::net`], `fnomad-lda serve-worker`), so rings can mix
//!   local threads with workers in other processes or machines.
//! * **Cluster simulator** ([`simnet`]): virtual-time discrete-event
//!   execution of the same runtime for the paper's 20-core / 32-node
//!   experiments on this single-core session (see DESIGN.md).
//! * **Model serving** ([`infer`]): the frozen [`infer::TopicModel`]
//!   artifact (`export-model` → `.fnmodel`, total bounds-checked
//!   decoder), an F+tree fold-in inference engine for unseen documents
//!   (Θ(|T̂_w| + log T) per token, deterministic across thread counts),
//!   and a TCP query server (`serve-model` / `infer --remote`) answering
//!   θ̂ / top-words / model-info queries from N handler threads.
//! * **Resilient training** ([`resilience`]): an async checkpoint service
//!   (background writer thread, fingerprinting manifest, keep-last-K
//!   retention) plus a supervisor that restarts the Nomad ring from the
//!   latest valid snapshot when a worker dies mid-epoch — `kill -9` a
//!   `serve-worker` and the run still completes (`train --checkpoint-dir
//!   DIR --max-restarts N`).
//! * **Observability** ([`obs`]): a process-global metrics registry
//!   (counters/gauges/log₂ histograms behind relaxed atomics), structured
//!   leveled events (`log_event!` → stable `ts level target key=value`
//!   lines or `--log-json` JSONL, filtered by `--log-level`/`FNOMAD_LOG`),
//!   per-epoch ring telemetry (sample-vs-wait per slot, hop latencies,
//!   fold/set phase times) on [`coordinator::EpochReport`], and exporters:
//!   `train --metrics FILE.jsonl` + `--trace FILE.json` (Perfetto-loadable
//!   Chrome trace events for epochs, slots, checkpoints, and recovery).
//! * **Evaluator backends** ([`runtime`]): the model-quality evaluator is
//!   a blocked `Σ lgamma` reduction with two interchangeable backends —
//!   with `--features pjrt`, a JAX + Pallas program AOT-lowered to HLO
//!   text and executed from Rust through the XLA PJRT C API (Python never
//!   runs at training time); by default, a pure-Rust port of the same
//!   blocked computation, so the crate builds and tests hermetically.
//!
//! ## Running an experiment
//!
//! Every runtime sits behind one typed API ([`coordinator`]): a
//! [`coordinator::TrainConfig`] built with the fluent builder selects the
//! corpus, sampler, and [`coordinator::RuntimeKind`]; the single driver
//! loop builds the matching [`coordinator::TrainEngine`] and streams
//! progress to [`coordinator::TrainObserver`]s:
//!
//! ```no_run
//! use fnomad_lda::coordinator::{train, EvalPolicy, RuntimeKind, TrainConfig};
//!
//! # fn main() -> Result<(), String> {
//! let cfg = TrainConfig::preset("tiny")
//!     .runtime(RuntimeKind::NomadSim)   // simulated 20-core nomad
//!     .topics(64)
//!     .iters(20)
//!     .eval(EvalPolicy::Rust)
//!     .checkpoint("results/tiny.ckpt")  // resumable via .resume(true)
//!     .out("results/tiny.csv");
//! let result = train(&cfg)?;
//! println!("final LL = {:?}", result.ll_vs_iter.last_y());
//! # Ok(())
//! # }
//! ```
//!
//! Custom instrumentation plugs in through
//! [`coordinator::train_with`] and the observer trait; new runtimes plug
//! in by implementing [`coordinator::TrainEngine`].
//!
//! See `examples/quickstart.rs` for the five-minute tour and DESIGN.md for
//! the full system inventory.

pub mod adlda;
pub mod coordinator;
pub mod corpus;
pub mod infer;
pub mod lda;
pub mod nomad;
pub mod obs;
pub mod ps;
pub mod resilience;
pub mod runtime;
pub mod sampler;
pub mod simnet;
pub mod util;
