//! Alias method (Walker 1977, Vose's linear-time construction).
//!
//! Θ(1) generation after a Θ(T) build, but any parameter change forces a
//! full rebuild — the trade AliasLDA accepts by sampling from *stale*
//! tables and correcting with Metropolis–Hastings (paper §3.3, Table 1).

use super::DiscreteSampler;

/// Vose alias table.
#[derive(Clone, Debug)]
pub struct Alias {
    /// acceptance threshold per bucket, scaled so `prob[i] ∈ [0, 1]`
    prob: Vec<f64>,
    alias: Vec<u32>,
    /// raw parameters retained for `update`-then-rebuild and `weight`
    p: Vec<f64>,
    total: f64,
}

impl Alias {
    fn rebuild(&mut self) {
        let n = self.p.len();
        self.total = self.p.iter().sum();
        self.prob.clear();
        self.alias.clear();
        self.prob.resize(n, 0.0);
        self.alias.resize(n, 0);
        if self.total <= 0.0 {
            // degenerate: treat as uniform so sample() stays total (callers
            // never draw from an all-zero distribution in LDA)
            self.prob.iter_mut().for_each(|x| *x = 1.0);
            for (i, a) in self.alias.iter_mut().enumerate() {
                *a = i as u32;
            }
            return;
        }
        let scale = n as f64 / self.total;
        // Vose's two worklists of scaled weights
        let mut scaled: Vec<f64> = self.p.iter().map(|&w| w * scale).collect();
        let mut small: Vec<u32> = Vec::with_capacity(n);
        let mut large: Vec<u32> = Vec::with_capacity(n);
        for (i, &s) in scaled.iter().enumerate() {
            if s < 1.0 {
                small.push(i as u32);
            } else {
                large.push(i as u32);
            }
        }
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            self.prob[s as usize] = scaled[s as usize];
            self.alias[s as usize] = l;
            scaled[l as usize] -= 1.0 - scaled[s as usize];
            if scaled[l as usize] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        for &l in &large {
            self.prob[l as usize] = 1.0;
            self.alias[l as usize] = l;
        }
        for &s in &small {
            // numerically stranded smalls: full bucket
            self.prob[s as usize] = 1.0;
            self.alias[s as usize] = s;
        }
    }
}

impl DiscreteSampler for Alias {
    fn build(p: &[f64]) -> Self {
        let mut a = Alias {
            prob: Vec::new(),
            alias: Vec::new(),
            p: p.to_vec(),
            total: 0.0,
        };
        a.rebuild();
        a
    }

    #[inline]
    fn total(&self) -> f64 {
        self.total
    }

    /// Alias generation from a single uniform: the integer part selects the
    /// bucket, the fractional part decides accept-vs-alias (paper §2.2).
    #[inline]
    fn sample(&self, u: f64) -> usize {
        let n = self.prob.len();
        // map u ∈ [0,total) onto [0,n)
        let x = (u / self.total * n as f64).clamp(0.0, n as f64 - 1e-9);
        let j = x as usize;
        let frac = x - j as f64;
        if frac < self.prob[j] {
            j
        } else {
            self.alias[j] as usize
        }
    }

    /// Θ(T): alias tables cannot be incrementally maintained.
    fn update(&mut self, t: usize, delta: f64) {
        self.p[t] += delta;
        self.rebuild();
    }

    fn weight(&self, t: usize) -> f64 {
        self.p[t]
    }

    fn len(&self) -> usize {
        self.p.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn buckets_partition_unit_mass() {
        let p = vec![0.3, 1.5, 0.4, 0.3];
        let a = Alias::build(&p);
        // total implied mass per outcome reconstructed from the table
        let n = p.len();
        let mut implied = vec![0.0; n];
        for j in 0..n {
            implied[j] += a.prob[j];
            implied[a.alias[j] as usize] += 1.0 - a.prob[j];
        }
        let scale = a.total() / n as f64;
        for (t, (&imp, &want)) in implied.iter().zip(&p).enumerate() {
            assert!(
                (imp * scale - want).abs() < 1e-9,
                "bucket mass mismatch at {t}: {} vs {want}",
                imp * scale
            );
        }
    }

    #[test]
    fn statistical_agreement_large_t() {
        let mut rng = Pcg32::seeded(11);
        let t = 1024;
        let p: Vec<f64> = (0..t).map(|_| rng.next_f64()).collect();
        let a = Alias::build(&p);
        let total: f64 = p.iter().sum();
        let draws = 400_000;
        let mut counts = vec![0usize; t];
        for _ in 0..draws {
            counts[a.sample(rng.uniform(a.total()))] += 1;
        }
        // chi-square-ish: aggregate relative error over all cells
        let mut chi2 = 0.0;
        for (c, &w) in counts.iter().zip(&p) {
            let e = w / total * draws as f64;
            if e > 5.0 {
                chi2 += (*c as f64 - e).powi(2) / e;
            }
        }
        // dof ≈ 1023; 5σ bound ≈ dof + 5*sqrt(2*dof) ≈ 1250
        assert!(chi2 < 1350.0, "chi2 {chi2}");
    }

    #[test]
    fn update_rebuilds() {
        let mut a = Alias::build(&[1.0, 1.0]);
        a.update(0, 3.0);
        assert!((a.total() - 5.0).abs() < 1e-12);
        assert!((a.weight(0) - 4.0).abs() < 1e-12);
        // dimension 0 now has 80% of the mass
        let mut rng = Pcg32::seeded(2);
        let hits = (0..10_000)
            .filter(|_| a.sample(rng.uniform(a.total())) == 0)
            .count();
        assert!((7_700..8_300).contains(&hits), "hits {hits}");
    }

    #[test]
    fn handles_zero_entries() {
        let a = Alias::build(&[0.0, 1.0, 0.0, 0.0]);
        let mut rng = Pcg32::seeded(3);
        for _ in 0..1000 {
            assert_eq!(a.sample(rng.uniform(a.total())), 1);
        }
    }
}
