//! F+tree (paper §3.1, Algorithms 1–2): the contribution data structure.
//!
//! A complete binary tree stored flat in an array `F[1..2T)`, leaves in
//! `F[T..2T)` (T padded to a power of two), every internal node the sum of
//! its children.  `F[1]` is the normalizer `c_T`, sampling is a top-down
//! descent (Algorithm 1), and a single-coordinate change is a bottom-up
//! delta walk (Algorithm 2) — both Θ(log T), the balance no other Table 1
//! sampler achieves.
//!
//! Floating-point hygiene: repeated ± deltas drift internal sums away from
//! the exact leaf sums.  Drift is second-order (each update touches log T
//! nodes with one rounding each) but unbounded over time, so the tree
//! transparently rebuilds every [`REBUILD_EVERY`] updates — Θ(T) amortized
//! over Θ(T) updates keeps the per-update cost Θ(log T).

use super::DiscreteSampler;

/// Rebuild cadence for drift control (amortized Θ(1) extra per update).
pub const REBUILD_EVERY: u64 = 1 << 20;

/// Flat-array F+tree.
#[derive(Clone, Debug)]
pub struct FTree {
    /// `f[0]` unused; root at 1; leaves at `size..size + len` (padding
    /// leaves hold 0 and are unreachable by sampling).
    f: Vec<f64>,
    /// number of real dimensions (≤ size)
    len: usize,
    /// padded power-of-two capacity
    size: usize,
    updates_since_rebuild: u64,
}

impl FTree {
    /// Build with a given capacity (≥ p.len()), e.g. to reserve for growth.
    pub fn with_capacity(p: &[f64], capacity: usize) -> Self {
        let size = capacity.max(p.len()).max(1).next_power_of_two();
        let mut t = FTree {
            f: vec![0.0; 2 * size],
            len: p.len(),
            size,
            updates_since_rebuild: 0,
        };
        t.refill(p);
        t
    }

    /// Θ(T) exact (re)initialization from raw parameters (eq. (3)).
    pub fn refill(&mut self, p: &[f64]) {
        assert!(p.len() <= self.size);
        self.len = p.len();
        self.f[self.size..self.size + p.len()].copy_from_slice(p);
        self.f[self.size + p.len()..].iter_mut().for_each(|x| *x = 0.0);
        for i in (1..self.size).rev() {
            self.f[i] = self.f[2 * i] + self.f[2 * i + 1];
        }
        self.updates_since_rebuild = 0;
    }

    /// Θ(T): recompute internal sums from the current leaves.
    pub fn rebuild(&mut self) {
        for i in (1..self.size).rev() {
            self.f[i] = self.f[2 * i] + self.f[2 * i + 1];
        }
        self.updates_since_rebuild = 0;
    }

    /// Set leaf `t` to an absolute value (the `F.update(t, δ)` with
    /// `δ = v − F[leaf(t)]` pattern of Algorithm 3, fused).
    #[inline]
    pub fn set(&mut self, t: usize, value: f64) {
        let delta = value - self.f[self.size + t];
        self.add(t, delta);
    }

    /// Algorithm 2: bottom-up delta propagation, Θ(log T).
    ///
    /// The bound check is a real `assert!`, not a `debug_assert!`: in a
    /// release build an out-of-range `t` in `self.len..self.size` would
    /// silently write mass into a padding leaf and corrupt the normalizer
    /// `F[1]` — and the Θ(log T) walk dwarfs one predictable branch.
    #[inline]
    pub fn add(&mut self, t: usize, delta: f64) {
        assert!(
            t < self.len,
            "FTree index {t} out of range (len {})",
            self.len
        );
        let mut i = self.size + t;
        while i >= 1 {
            self.f[i] += delta;
            if i == 1 {
                break;
            }
            i >>= 1;
        }
        self.updates_since_rebuild += 1;
        if self.updates_since_rebuild >= REBUILD_EVERY {
            self.rebuild();
        }
    }

    /// Leaf accessor (the `F[leaf(t)]` of Algorithm 3).
    #[inline]
    pub fn leaf(&self, t: usize) -> f64 {
        self.f[self.size + t]
    }

    /// Algorithm 1: top-down descent for `u ∈ [0, F[1])`, Θ(log T).
    #[inline]
    pub fn descend(&self, mut u: f64) -> usize {
        let mut i = 1usize;
        while i < self.size {
            let left = self.f[2 * i];
            if u >= left {
                u -= left;
                i = 2 * i + 1;
            } else {
                i = 2 * i;
            }
        }
        let mut t = i - self.size;
        // fp edge: u may have landed on a zero-mass (or padding) leaf when
        // it equals/exceeds the true total; walk back to real mass.
        if t >= self.len || (self.f[self.size + t] <= 0.0 && self.f[1] > 0.0) {
            t = self.last_positive_leaf();
        }
        t
    }

    fn last_positive_leaf(&self) -> usize {
        (0..self.len)
            .rev()
            .find(|&t| self.f[self.size + t] > 0.0)
            .unwrap_or(0)
    }

    /// Exact sum of leaves (test-time drift oracle; Θ(T)).
    pub fn exact_total(&self) -> f64 {
        self.f[self.size..self.size + self.len].iter().sum()
    }

    /// Padded capacity (for introspection / benches).
    pub fn capacity(&self) -> usize {
        self.size
    }
}

impl DiscreteSampler for FTree {
    fn build(p: &[f64]) -> Self {
        FTree::with_capacity(p, p.len())
    }

    #[inline]
    fn total(&self) -> f64 {
        self.f[1]
    }

    #[inline]
    fn sample(&self, u: f64) -> usize {
        self.descend(u)
    }

    #[inline]
    fn update(&mut self, t: usize, delta: f64) {
        self.add(t, delta);
    }

    #[inline]
    fn weight(&self, t: usize) -> f64 {
        self.leaf(t)
    }

    fn len(&self) -> usize {
        self.len
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::{BSearch, LSearch};
    use crate::util::quickcheck::{check, close};

    #[test]
    fn paper_figure1_example() {
        // p = [0.3, 1.5, 0.4, 0.3]; u = 2.1 must select t = 2 (0-based),
        // i.e. the third leaf, as in Figure 1b.
        let t = FTree::build(&[0.3, 1.5, 0.4, 0.3]);
        assert!((t.total() - 2.5).abs() < 1e-12);
        assert_eq!(t.sample(2.1), 2);
        // and the internal nodes are the pairwise sums of Figure 1a
        assert!((t.f[2] - 1.8).abs() < 1e-12);
        assert!((t.f[3] - 0.7).abs() < 1e-12);
    }

    #[test]
    fn paper_figure1c_update() {
        // Figure 1c: update t=3 (1-based; here index 2) by δ=+1.0
        let mut t = FTree::build(&[0.3, 1.5, 0.4, 0.3]);
        t.add(2, 1.0);
        assert!((t.leaf(2) - 1.4).abs() < 1e-12);
        assert!((t.f[3] - 1.7).abs() < 1e-12); // right internal node
        assert!((t.total() - 3.5).abs() < 1e-12); // root
    }

    #[test]
    fn set_is_absolute() {
        let mut t = FTree::build(&[1.0, 2.0, 3.0, 4.0]);
        t.set(1, 0.25);
        assert!((t.leaf(1) - 0.25).abs() < 1e-12);
        assert!((t.total() - 8.25).abs() < 1e-12);
    }

    #[test]
    fn internal_nodes_always_sum_children() {
        check("ftree invariant: parent == left + right", 32, |rng| {
            let n = 1 + rng.below(37);
            let p: Vec<f64> = (0..n).map(|_| rng.next_f64() * 5.0).collect();
            let mut t = FTree::build(&p);
            for _ in 0..200 {
                let idx = rng.below(n);
                let delta = rng.next_f64() - 0.4;
                if t.leaf(idx) + delta >= 0.0 {
                    t.add(idx, delta);
                }
            }
            for i in 1..t.size {
                close(t.f[i], t.f[2 * i] + t.f[2 * i + 1], 1e-9, 1e-9)
                    .map_err(|e| format!("node {i}: {e}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn drift_rebuild_restores_exactness() {
        let n = 64;
        let p: Vec<f64> = (0..n).map(|i| (i as f64).mul_add(0.1, 0.01)).collect();
        let mut t = FTree::build(&p);
        // hammer with tiny cancelling deltas to accumulate drift
        for i in 0..500_000u64 {
            let idx = (i % n as u64) as usize;
            t.add(idx, 1e-9);
            t.add(idx, -1e-9);
        }
        let drift = (t.total() - t.exact_total()).abs();
        t.rebuild();
        let after = (t.total() - t.exact_total()).abs();
        assert!(after <= drift);
        assert!(after < 1e-12, "post-rebuild drift {after}");
    }

    #[test]
    fn automatic_rebuild_counter() {
        let mut t = FTree::build(&[1.0; 8]);
        for _ in 0..REBUILD_EVERY + 5 {
            t.add(3, 0.0);
        }
        assert!(t.updates_since_rebuild < REBUILD_EVERY);
    }

    #[test]
    fn capacity_reserved_growth() {
        let mut t = FTree::with_capacity(&[1.0, 1.0], 16);
        assert_eq!(t.capacity(), 16);
        t.refill(&[1.0; 10]);
        assert_eq!(t.len(), 10);
        assert!((t.total() - 10.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_update_panics_in_release_too() {
        // len 3 pads to size 4: index 3 is a padding leaf — writing there
        // would corrupt F[1] if the guard were debug-only
        let mut t = FTree::build(&[1.0, 2.0, 3.0]);
        t.set(3, 0.5);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_add_panics() {
        let mut t = FTree::build(&[1.0, 2.0, 3.0]);
        t.add(7, 0.1);
    }

    #[test]
    fn single_leaf() {
        let t = FTree::build(&[7.0]);
        assert_eq!(t.sample(6.999), 0);
        assert_eq!(t.sample(0.0), 0);
    }

    /// Property: on random weight vectors (zeros included, random lengths,
    /// mixed magnitudes), the F+tree descent inverts the same CDF as the
    /// linear-scan and binary-search samplers for every shared `u` — both
    /// freshly built and after a stream of random updates.
    #[test]
    fn property_matches_cdf_inversion_samplers() {
        check("ftree == lsearch/bsearch CDF inversion", 48, |rng| {
            let n = 1 + rng.below(300);
            let mut p: Vec<f64> = (0..n)
                .map(|_| {
                    if rng.next_f64() < 0.4 {
                        0.0
                    } else {
                        rng.next_f64() * 10f64.powi(rng.below(5) as i32 - 2)
                    }
                })
                .collect();
            p[rng.below(n)] += 0.5; // at least one positive entry
            let mut ft = FTree::build(&p);
            let mut ls = LSearch::build(&p);
            let mut bs = BSearch::build(&p);
            for step in 0..120 {
                let u = rng.uniform(ft.total());
                let (a, b, c) = (ls.sample(u), bs.sample(u), ft.sample(u));
                if a != c || b != c {
                    return Err(format!("step {step} u={u}: lsearch {a} bsearch {b} ftree {c}"));
                }
                // random nonneg-preserving update keeps the three in lockstep
                let idx = rng.below(n);
                let delta = if ft.weight(idx) > 0.2 {
                    rng.next_f64() - 0.2
                } else {
                    rng.next_f64()
                };
                ft.update(idx, delta);
                ls.update(idx, delta);
                bs.update(idx, delta);
            }
            close(ft.total(), ls.total(), 1e-9, 1e-12)
        });
    }

    /// Property: internal sums stay consistent with the leaf sums across
    /// the automatic rebuild boundary — drive an update stream through
    /// REBUILD_EVERY and verify parent == left + right plus root == exact
    /// leaf total just before and just after the rebuild fires.
    #[test]
    fn property_internal_sums_consistent_around_rebuild_every() {
        check("ftree sums consistent across REBUILD_EVERY", 4, |rng| {
            let n = 2 + rng.below(64);
            let p: Vec<f64> = (0..n).map(|_| rng.next_f64() * 3.0 + 0.01).collect();
            let mut t = FTree::build(&p);
            let verify = |t: &FTree, when: &str| -> Result<(), String> {
                for i in 1..t.size {
                    close(t.f[i], t.f[2 * i] + t.f[2 * i + 1], 1e-6, 1e-9)
                        .map_err(|e| format!("{when}: node {i}: {e}"))?;
                }
                close(t.total(), t.exact_total(), 1e-6, 1e-9)
                    .map_err(|e| format!("{when}: root vs exact: {e}"))
            };
            // walk right up to the rebuild threshold...
            while t.updates_since_rebuild < REBUILD_EVERY - 1 {
                let idx = rng.below(n);
                let delta = 1e-4 * (rng.next_f64() - 0.5);
                if t.leaf(idx) + delta >= 0.0 {
                    t.add(idx, delta);
                } else {
                    t.add(idx, 0.0);
                }
            }
            verify(&t, "before rebuild")?;
            // ...then across it: the counter must reset and sums must be
            // freshly exact
            t.add(rng.below(n), 0.25);
            if t.updates_since_rebuild >= REBUILD_EVERY {
                return Err("automatic rebuild did not fire".into());
            }
            verify(&t, "after rebuild")?;
            close(t.total(), t.exact_total(), 1e-12, 1e-12)
                .map_err(|e| format!("post-rebuild exactness: {e}"))
        });
    }

    #[test]
    fn zero_mass_leaves_are_never_sampled() {
        check("ftree never returns zero-mass leaf", 32, |rng| {
            let n = 2 + rng.below(30);
            let mut p = vec![0.0; n];
            // one to three positive leaves
            for _ in 0..1 + rng.below(3) {
                p[rng.below(n)] = rng.next_f64() + 0.1;
            }
            let t = FTree::build(&p);
            for _ in 0..100 {
                let u = rng.uniform(t.total());
                let z = t.sample(u);
                if p[z] <= 0.0 {
                    return Err(format!("sampled zero-mass leaf {z}"));
                }
            }
            Ok(())
        });
    }
}
