//! BSearch (paper §2.2): binary search on the cumulative sums.
//!
//! Θ(log T) generation but Θ(T) rebuild on any parameter change.  F+LDA
//! uses it for the *sparse* `r` term, where the vector is rebuilt from
//! scratch for every token anyway — see [`SparseCumSum`], the |T_d|/|T_w|
//! variant used inside the LDA kernels.

use super::DiscreteSampler;

/// Dense cumulative-sum sampler.
#[derive(Clone, Debug)]
pub struct BSearch {
    /// cum[t] = Σ_{s ≤ t} p_s
    cum: Vec<f64>,
}

impl DiscreteSampler for BSearch {
    fn build(p: &[f64]) -> Self {
        let mut cum = Vec::with_capacity(p.len());
        let mut acc = 0.0;
        for &w in p {
            acc += w;
            cum.push(acc);
        }
        BSearch { cum }
    }

    #[inline]
    fn total(&self) -> f64 {
        *self.cum.last().unwrap_or(&0.0)
    }

    #[inline]
    fn sample(&self, u: f64) -> usize {
        // min{t : cum[t] > u}; clamp for fp drift at the top end.
        let idx = self.cum.partition_point(|&c| c <= u);
        if idx < self.cum.len() {
            idx
        } else {
            // u >= total due to rounding: last index with positive mass
            self.last_positive()
        }
    }

    /// Θ(T): suffix rebuild from the changed coordinate.
    fn update(&mut self, t: usize, delta: f64) {
        for c in &mut self.cum[t..] {
            *c += delta;
        }
    }

    fn weight(&self, t: usize) -> f64 {
        if t == 0 {
            self.cum[0]
        } else {
            self.cum[t] - self.cum[t - 1]
        }
    }

    fn len(&self) -> usize {
        self.cum.len()
    }
}

impl BSearch {
    fn last_positive(&self) -> usize {
        let total = self.total();
        (0..self.cum.len())
            .rev()
            .find(|&t| self.weight(t) > 0.0 || total == 0.0)
            .unwrap_or(0)
    }
}

/// Sparse cumulative-sum scratch used by the LDA inner loops for the `r`
/// term: holds (topic, cumsum) pairs over the nonzero support only and is
/// re-filled in Θ(|support|) per token without reallocating.
#[derive(Clone, Debug, Default)]
pub struct SparseCumSum {
    topics: Vec<u32>,
    cum: Vec<f64>,
}

impl SparseCumSum {
    pub fn with_capacity(cap: usize) -> Self {
        SparseCumSum { topics: Vec::with_capacity(cap), cum: Vec::with_capacity(cap) }
    }

    #[inline]
    pub fn clear(&mut self) {
        self.topics.clear();
        self.cum.clear();
    }

    /// Append the next nonzero (topic, weight) in increasing topic order.
    #[inline]
    pub fn push(&mut self, topic: u32, weight: f64) {
        debug_assert!(weight >= 0.0);
        let prev = *self.cum.last().unwrap_or(&0.0);
        self.topics.push(topic);
        self.cum.push(prev + weight);
    }

    #[inline]
    pub fn total(&self) -> f64 {
        *self.cum.last().unwrap_or(&0.0)
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.topics.is_empty()
    }

    /// Binary search for u ∈ [0, total); returns the stored topic id.
    #[inline]
    pub fn sample(&self, u: f64) -> u32 {
        debug_assert!(!self.is_empty());
        let idx = self.cum.partition_point(|&c| c <= u).min(self.cum.len() - 1);
        self.topics[idx]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cdf_semantics_match_paper_example() {
        let s = BSearch::build(&[0.3, 1.5, 0.4, 0.3]);
        assert_eq!(s.sample(2.1), 2);
        assert_eq!(s.sample(0.0), 0);
        assert_eq!(s.sample(0.3), 1);
        assert!((s.total() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn update_is_suffix_add() {
        let mut s = BSearch::build(&[1.0, 1.0, 1.0]);
        s.update(1, 2.0);
        assert!((s.weight(0) - 1.0).abs() < 1e-12);
        assert!((s.weight(1) - 3.0).abs() < 1e-12);
        assert!((s.weight(2) - 1.0).abs() < 1e-12);
        assert!((s.total() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn u_past_total_clamps() {
        let s = BSearch::build(&[1.0, 2.0, 0.0]);
        assert_eq!(s.sample(3.0 + 1e-15), 1);
    }

    #[test]
    fn sparse_cumsum_matches_dense() {
        let dense = [0.0, 2.0, 0.0, 0.0, 1.0, 0.5, 0.0];
        let bs = BSearch::build(&dense);
        let mut sc = SparseCumSum::with_capacity(4);
        for (t, &w) in dense.iter().enumerate() {
            if w > 0.0 {
                sc.push(t as u32, w);
            }
        }
        assert!((sc.total() - bs.total()).abs() < 1e-12);
        for u in [0.0, 1.9, 2.0, 2.99, 3.2, 3.49] {
            assert_eq!(sc.sample(u) as usize, bs.sample(u), "u={u}");
        }
    }

    #[test]
    fn sparse_cumsum_reuse_without_realloc() {
        let mut sc = SparseCumSum::with_capacity(8);
        sc.push(3, 1.0);
        sc.clear();
        assert!(sc.is_empty());
        sc.push(5, 2.0);
        assert_eq!(sc.sample(1.5), 5);
        assert!((sc.total() - 2.0).abs() < 1e-12);
    }
}
