//! Multinomial samplers over unnormalized parameters `p` (paper §2.2, §3,
//! Table 1).
//!
//! All four samplers draw `z` with `Pr(z = t) ∝ p_t` given `u ~
//! uniform[0, total)`:
//!
//! | sampler   | init  | generate   | single-param update |
//! |-----------|-------|------------|---------------------|
//! | [`LSearch`] | Θ(T) | Θ(T)       | Θ(1)                |
//! | [`BSearch`] | Θ(T) | Θ(log T)   | Θ(T)                |
//! | [`Alias`]   | Θ(T) | Θ(1)       | Θ(T)                |
//! | [`FTree`]   | Θ(T) | Θ(log T)   | **Θ(log T)**        |
//!
//! The F+tree's balanced generate/update cost is contribution #1 of the
//! paper; `benches/table1_samplers.rs` regenerates the measured version of
//! this table.
//!
//! The `u`-outside interface (caller supplies the uniform draw) keeps the
//! samplers RNG-agnostic and lets the two-level LDA decompositions reuse a
//! single uniform across the q/r split exactly as eq. (6) prescribes.

pub mod alias;
pub mod bsearch;
pub mod ftree;
pub mod lsearch;

pub use alias::Alias;
pub use bsearch::BSearch;
pub use ftree::FTree;
pub use lsearch::LSearch;

/// Common interface for the Table 1 samplers.
pub trait DiscreteSampler {
    /// Build from unnormalized nonnegative parameters.
    fn build(p: &[f64]) -> Self;

    /// The normalization constant `c_T = Σ_t p_t`.
    fn total(&self) -> f64;

    /// Draw `z = min{t : Σ_{s≤t} p_s > u}` for `u ∈ [0, total)`.
    /// (The Alias sampler ignores the CDF semantics but matches the
    /// distribution for uniform `u`.)
    fn sample(&self, u: f64) -> usize;

    /// Apply `p_t += delta` and restore the sampler's invariants.
    fn update(&mut self, t: usize, delta: f64);

    /// Current parameter value (for tests / debugging).
    fn weight(&self, t: usize) -> f64;

    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{check, close};
    use crate::util::rng::Pcg32;

    fn random_params(rng: &mut Pcg32, t: usize, sparse: bool) -> Vec<f64> {
        (0..t)
            .map(|_| {
                if sparse && rng.next_f64() < 0.6 {
                    0.0
                } else {
                    rng.next_f64() * 10.0
                }
            })
            .collect()
    }

    /// Empirical distribution of `sample` matches p for every sampler.
    fn frequencies<S: DiscreteSampler>(s: &S, rng: &mut Pcg32, draws: usize) -> Vec<f64> {
        let mut counts = vec![0usize; s.len()];
        for _ in 0..draws {
            let u = rng.uniform(s.total());
            counts[s.sample(u)] += 1;
        }
        counts.iter().map(|&c| c as f64 / draws as f64).collect()
    }

    fn assert_matches_distribution<S: DiscreteSampler>(name: &str) {
        check(&format!("{name} matches target distribution"), 8, |rng| {
            let t = 1 << (3 + rng.below(4)); // 8..64
            let sparse = rng.next_f64() < 0.5;
            let mut p = random_params(rng, t, sparse);
            // ensure at least one positive entry
            p[rng.below(t)] += 1.0;
            let total: f64 = p.iter().sum();
            let s = S::build(&p);
            close(s.total(), total, 1e-9, 1e-12)?;
            let draws = 60_000;
            let freq = frequencies(&s, rng, draws);
            for (t_i, (&f, &pi)) in freq.iter().zip(&p).enumerate() {
                let want = pi / total;
                let tol = 4.0 * (want.max(1e-4) / draws as f64).sqrt(); // ~4σ
                if (f - want).abs() > tol {
                    return Err(format!("dim {t_i}: freq {f} vs p {want} (tol {tol})"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn lsearch_distribution() {
        assert_matches_distribution::<LSearch>("LSearch");
    }

    #[test]
    fn bsearch_distribution() {
        assert_matches_distribution::<BSearch>("BSearch");
    }

    #[test]
    fn alias_distribution() {
        assert_matches_distribution::<Alias>("Alias");
    }

    #[test]
    fn ftree_distribution() {
        assert_matches_distribution::<FTree>("FTree");
    }

    /// The three CDF-semantics samplers agree *pointwise* on the same u
    /// (the alias method has different u-semantics by design).
    #[test]
    fn cdf_samplers_agree_pointwise() {
        check("LSearch/BSearch/FTree pointwise agreement", 32, |rng| {
            let t = 1 << (2 + rng.below(6));
            let mut p = random_params(rng, t, true);
            p[rng.below(t)] += 0.5;
            let ls = LSearch::build(&p);
            let bs = BSearch::build(&p);
            let ft = FTree::build(&p);
            for _ in 0..200 {
                let u = rng.uniform(ls.total());
                let (a, b, c) = (ls.sample(u), bs.sample(u), ft.sample(u));
                if a != b || b != c {
                    return Err(format!("u={u}: lsearch {a}, bsearch {b}, ftree {c}"));
                }
            }
            Ok(())
        });
    }

    /// Updates keep all samplers equivalent to a fresh rebuild.
    #[test]
    fn updates_equal_rebuild() {
        check("update == rebuild for all samplers", 16, |rng| {
            let t = 1 << (2 + rng.below(5));
            let mut p = random_params(rng, t, false);
            let mut ls = LSearch::build(&p);
            let mut bs = BSearch::build(&p);
            let mut al = Alias::build(&p);
            let mut ft = FTree::build(&p);
            for _ in 0..50 {
                let idx = rng.below(t);
                // keep parameters nonnegative
                let delta = if p[idx] > 0.5 { rng.next_f64() - 0.5 } else { rng.next_f64() };
                p[idx] += delta;
                ls.update(idx, delta);
                bs.update(idx, delta);
                al.update(idx, delta);
                ft.update(idx, delta);
            }
            let want: f64 = p.iter().sum();
            for (name, total) in [
                ("lsearch", ls.total()),
                ("bsearch", bs.total()),
                ("alias", al.total()),
                ("ftree", ft.total()),
            ] {
                close(total, want, 1e-7, 1e-9).map_err(|e| format!("{name}: {e}"))?;
            }
            // pointwise equivalence with a rebuilt BSearch on shared u
            let fresh = BSearch::build(&p);
            for _ in 0..100 {
                let u = rng.uniform(want * 0.999999);
                let w = fresh.sample(u);
                if ls.sample(u) != w || bs.sample(u) != w || ft.sample(u) != w {
                    return Err(format!("post-update divergence at u={u}"));
                }
            }
            Ok(())
        });
    }

    /// Degenerate shapes: single element, all-but-one zero, u at edges.
    #[test]
    fn edge_cases() {
        let p = vec![2.0];
        assert_eq!(LSearch::build(&p).sample(1.9), 0);
        assert_eq!(BSearch::build(&p).sample(0.0), 0);
        assert_eq!(FTree::build(&p).sample(1.9), 0);

        let p = vec![0.0, 0.0, 3.0, 0.0];
        for u in [0.0, 1.5, 2.999] {
            assert_eq!(LSearch::build(&p).sample(u), 2);
            assert_eq!(BSearch::build(&p).sample(u), 2);
            assert_eq!(FTree::build(&p).sample(u), 2);
            assert_eq!(Alias::build(&p).sample(u), 2);
        }
    }

    /// Non-power-of-two lengths work (FTree pads internally).
    #[test]
    fn non_power_of_two_lengths() {
        for t in [1usize, 3, 5, 7, 100, 1000, 1025] {
            let p: Vec<f64> = (0..t).map(|i| (i % 7) as f64 + 0.25).collect();
            let ft = FTree::build(&p);
            let bs = BSearch::build(&p);
            assert!((ft.total() - bs.total()).abs() < 1e-9);
            let mut rng = Pcg32::seeded(t as u64);
            for _ in 0..100 {
                let u = rng.uniform(ft.total());
                assert_eq!(ft.sample(u), bs.sample(u), "t={t} u={u}");
            }
        }
    }
}
