//! LSearch (paper §2.2): linear scan over the raw parameters.
//!
//! Θ(T) generation, Θ(1) update — the structure SparseLDA leans on for its
//! rarely-sampled dense/sparse bucket terms, and the "normal LDA" baseline
//! of Fig. 4(c,d) when used on the full dense conditional.

use super::DiscreteSampler;

/// Raw parameters plus a maintained normalization constant.
#[derive(Clone, Debug)]
pub struct LSearch {
    p: Vec<f64>,
    total: f64,
}

impl DiscreteSampler for LSearch {
    fn build(p: &[f64]) -> Self {
        LSearch { p: p.to_vec(), total: p.iter().sum() }
    }

    #[inline]
    fn total(&self) -> f64 {
        self.total
    }

    #[inline]
    fn sample(&self, mut u: f64) -> usize {
        // z = min{t : cumsum(p)_t > u}; fall back to the last positive
        // entry if floating-point drift pushes u past the true total.
        let mut last_pos = 0;
        for (t, &w) in self.p.iter().enumerate() {
            if w > 0.0 {
                if u < w {
                    return t;
                }
                last_pos = t;
            }
            u -= w;
        }
        last_pos
    }

    #[inline]
    fn update(&mut self, t: usize, delta: f64) {
        self.p[t] += delta;
        self.total += delta;
    }

    #[inline]
    fn weight(&self, t: usize) -> f64 {
        self.p[t]
    }

    fn len(&self) -> usize {
        self.p.len()
    }
}

impl LSearch {
    /// Recompute the normalizer exactly (drift control after very long
    /// update streams).
    pub fn renormalize(&mut self) {
        self.total = self.p.iter().sum();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_cdf_semantics() {
        let s = LSearch::build(&[0.3, 1.5, 0.4, 0.3]); // paper Fig. 1 example
        assert_eq!(s.sample(0.0), 0);
        assert_eq!(s.sample(0.29), 0);
        assert_eq!(s.sample(0.3), 1);
        assert_eq!(s.sample(1.79), 1);
        assert_eq!(s.sample(2.1), 2); // paper's Fig. 1b walk ends at t=3 (1-based)
        assert_eq!(s.sample(2.49), 3);
    }

    #[test]
    fn update_maintains_total_in_constant_time() {
        let mut s = LSearch::build(&[1.0, 2.0, 3.0]);
        s.update(1, -0.5);
        assert!((s.total() - 5.5).abs() < 1e-12);
        assert!((s.weight(1) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn u_at_total_falls_back_to_last_positive() {
        let s = LSearch::build(&[1.0, 2.0, 0.0]);
        assert_eq!(s.sample(3.0), 1);
    }

    #[test]
    fn renormalize_fixes_drift() {
        let mut s = LSearch::build(&[1.0; 100]);
        for i in 0..100 {
            s.update(i, 1e-13);
        }
        s.renormalize();
        let exact: f64 = (0..100).map(|_| 1.0 + 1e-13).sum();
        assert!((s.total() - exact).abs() < 1e-12);
    }
}
