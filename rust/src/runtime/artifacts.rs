//! Artifact loading: manifest validation + HLO-text compilation cache.
//!
//! `artifacts/manifest.txt` (written by python/compile/aot.py) lists every
//! artifact with its argument signature; we cross-check the shapes we are
//! about to feed so a Python/Rust geometry drift fails at load time with a
//! readable message instead of a PJRT shape error mid-training.
//!
//! Manifest parsing is always compiled (it is pure std and the drift check
//! is useful on its own); the PJRT compilation cache needs the vendored
//! `xla` crate and lives behind the `pjrt` feature.

use std::collections::HashMap;
use std::path::Path;

/// Parse manifest.txt into name -> arg-signature.
pub fn read_manifest(dir: &Path) -> Result<HashMap<String, String>, String> {
    let path = dir.join("manifest.txt");
    let body = std::fs::read_to_string(&path)
        .map_err(|e| format!("{}: {e} (run `make artifacts`)", path.display()))?;
    let mut out = HashMap::new();
    for line in body.lines() {
        let mut cols = line.split('\t');
        let name = cols.next().ok_or("empty manifest line")?;
        let _nargs = cols.next().ok_or("manifest missing nargs")?;
        let sig = cols.next().unwrap_or("");
        out.insert(name.to_string(), sig.to_string());
    }
    Ok(out)
}

/// One compiled artifact set for a given topic count.
#[cfg(feature = "pjrt")]
pub struct ArtifactSet {
    pub client: xla::PjRtClient,
    pub ll_block: xla::PjRtLoadedExecutable,
    pub ll_vec: xla::PjRtLoadedExecutable,
    pub prob: Option<xla::PjRtLoadedExecutable>,
    pub t: usize,
}

#[cfg(feature = "pjrt")]
fn compile(
    client: &xla::PjRtClient,
    dir: &Path,
    name: &str,
) -> Result<xla::PjRtLoadedExecutable, String> {
    let path = dir.join(format!("{name}.hlo.txt"));
    let proto = xla::HloModuleProto::from_text_file(path.to_str().ok_or("non-utf8 artifact path")?)
        .map_err(|e| format!("{}: {e}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).map_err(|e| format!("compile {name}: {e}"))
}

#[cfg(feature = "pjrt")]
impl ArtifactSet {
    /// Load + compile the T-specific artifacts from `dir`.
    pub fn load(dir: &Path, t: usize) -> Result<ArtifactSet, String> {
        let manifest = read_manifest(dir)?;
        let block_name = format!("ll_block_b{}_t{t}", super::BLOCK_ROWS);
        let vec_name = format!("ll_vec_n{}", super::VEC_LEN);
        let prob_name = format!("prob_b{}_t{t}", super::PROB_BATCH);

        // shape cross-check against the manifest
        let want_block = format!("float32[{},{t}];float32[]", super::BLOCK_ROWS);
        match manifest.get(&block_name) {
            None => {
                return Err(format!(
                    "artifact '{block_name}' not in manifest (have: {:?})",
                    manifest.keys().collect::<Vec<_>>()
                ))
            }
            Some(sig) if sig != &want_block => {
                return Err(format!(
                    "artifact '{block_name}' signature drift: manifest has {sig}, \
                     rust expects {want_block}"
                ))
            }
            _ => {}
        }

        let client = xla::PjRtClient::cpu().map_err(|e| e.to_string())?;
        let ll_block = compile(&client, dir, &block_name)?;
        let ll_vec = compile(&client, dir, &vec_name)?;
        let prob = if manifest.contains_key(&prob_name) {
            Some(compile(&client, dir, &prob_name)?)
        } else {
            None
        };
        Ok(ArtifactSet { client, ll_block, ll_vec, prob, t })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parse() {
        let dir = std::env::temp_dir().join("fnomad_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "a\t2\tfloat32[4];float32[]\nb\t1\tfloat32[2,2]\n",
        )
        .unwrap();
        let m = read_manifest(&dir).unwrap();
        assert_eq!(m["a"], "float32[4];float32[]");
        assert_eq!(m["b"], "float32[2,2]");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn missing_manifest_mentions_make() {
        let err = read_manifest(Path::new("/nonexistent-dir-xyz")).unwrap_err();
        assert!(err.contains("make artifacts"));
    }
}
