//! PJRT-backed evaluator (`--features pjrt`): execute the AOT-compiled
//! JAX/Pallas artifacts from the Rust hot path through the XLA PJRT C API.
//!
//! Text is the interchange format because jax ≥ 0.5 emits 64-bit
//! instruction ids that the crate's xla_extension 0.5.1 rejects in proto
//! form.
//!
//! * [`LlEvaluator`] — the model-quality evaluator: streams count blocks
//!   through the `ll_block`/`ll_vec` kernels (Pallas lgamma reduction
//!   inside) with closed-form padding corrections.
//! * [`ProbOracle`] — the `prob` artifact: dense CGS conditionals for a
//!   token batch; integration tests use it as an independent oracle for
//!   the Rust samplers.

use super::artifacts::ArtifactSet;
use super::{blocked_log_likelihood, LlKernels, BLOCK_ROWS, PROB_BATCH, TOPIC_SIZES, VEC_LEN};
use crate::lda::state::LdaState;

/// The blocked log-likelihood evaluator backed by PJRT executables.
pub struct LlEvaluator {
    arts: ArtifactSet,
    t: usize,
    /// reusable dense block buffer (BLOCK_ROWS × T)
    block: Vec<f32>,
    /// reusable vec buffer (VEC_LEN)
    vec: Vec<f32>,
}

struct PjrtKernels<'a> {
    arts: &'a mut ArtifactSet,
    t: usize,
}

impl LlKernels for PjrtKernels<'_> {
    /// sum(lgamma(block + c)) via the Pallas kernel executable.
    fn block_sum(&mut self, block: &[f32], c: f32) -> Result<f64, String> {
        let lit = xla::Literal::vec1(block)
            .reshape(&[BLOCK_ROWS as i64, self.t as i64])
            .map_err(|e| e.to_string())?;
        let out = self
            .arts
            .ll_block
            .execute::<xla::Literal>(&[lit, xla::Literal::from(c)])
            .map_err(|e| e.to_string())?[0][0]
            .to_literal_sync()
            .map_err(|e| e.to_string())?
            .to_tuple1()
            .map_err(|e| e.to_string())?;
        Ok(out.to_vec::<f32>().map_err(|e| e.to_string())?[0] as f64)
    }

    /// sum(lgamma(vec + c)) via the ll_vec executable.
    fn vec_sum(&mut self, vec: &[f32], c: f32) -> Result<f64, String> {
        let lit = xla::Literal::vec1(vec);
        let out = self
            .arts
            .ll_vec
            .execute::<xla::Literal>(&[lit, xla::Literal::from(c)])
            .map_err(|e| e.to_string())?[0][0]
            .to_literal_sync()
            .map_err(|e| e.to_string())?
            .to_tuple1()
            .map_err(|e| e.to_string())?;
        Ok(out.to_vec::<f32>().map_err(|e| e.to_string())?[0] as f64)
    }
}

impl LlEvaluator {
    /// Which backend this build's `LlEvaluator` is ("xla" here).
    pub const BACKEND: &str = "xla";

    /// Load the artifacts for topic count `t` from `dir`.
    pub fn new(dir: &std::path::Path, t: usize) -> Result<Self, String> {
        if !TOPIC_SIZES.contains(&t) {
            return Err(format!(
                "no artifacts for T={t} (built for {TOPIC_SIZES:?}); \
                 add T to python/compile/model.py TOPIC_SIZES and re-run make artifacts"
            ));
        }
        let arts = ArtifactSet::load(dir, t)?;
        Ok(LlEvaluator { arts, t, block: vec![0.0; BLOCK_ROWS * t], vec: vec![0.0; VEC_LEN] })
    }

    pub fn topics(&self) -> usize {
        self.t
    }

    /// The collapsed joint log-likelihood of `state` (same quantity as
    /// [`crate::lda::eval::log_likelihood`], computed on the XLA path).
    pub fn log_likelihood(&mut self, state: &LdaState) -> Result<f64, String> {
        let mut kern = PjrtKernels { arts: &mut self.arts, t: self.t };
        blocked_log_likelihood(&mut kern, state, self.t, &mut self.block, &mut self.vec)
    }
}

/// The dense CGS conditional oracle (the `prob` artifact).
pub struct ProbOracle {
    arts: ArtifactSet,
    t: usize,
}

impl ProbOracle {
    pub fn new(dir: &std::path::Path, t: usize) -> Result<Self, String> {
        Ok(ProbOracle { arts: ArtifactSet::load(dir, t)?, t })
    }

    /// p[b,t] and norms for a batch of PROB_BATCH tokens described by
    /// their dense (ntd, ntw) rows plus the totals.
    pub fn dense_prob(
        &self,
        ntd: &[f32],
        ntw: &[f32],
        nt: &[f32],
        alpha: f32,
        beta: f32,
        betabar: f32,
    ) -> Result<(Vec<f32>, Vec<f32>), String> {
        let b = PROB_BATCH;
        assert_eq!(ntd.len(), b * self.t);
        assert_eq!(ntw.len(), b * self.t);
        assert_eq!(nt.len(), self.t);
        let prob = self.arts.prob.as_ref().ok_or("prob artifact not loaded")?;
        let mk = |v: &[f32], dims: &[i64]| -> Result<xla::Literal, String> {
            xla::Literal::vec1(v).reshape(dims).map_err(|e| e.to_string())
        };
        let out = prob
            .execute::<xla::Literal>(&[
                mk(ntd, &[b as i64, self.t as i64])?,
                mk(ntw, &[b as i64, self.t as i64])?,
                xla::Literal::vec1(nt),
                xla::Literal::vec1(&[alpha, beta, betabar]),
            ])
            .map_err(|e| e.to_string())?[0][0]
            .to_literal_sync()
            .map_err(|e| e.to_string())?;
        let (p, norm) = out.to_tuple2().map_err(|e| e.to_string())?;
        Ok((
            p.to_vec::<f32>().map_err(|e| e.to_string())?,
            norm.to_vec::<f32>().map_err(|e| e.to_string())?,
        ))
    }
}
