//! PJRT runtime bridge: load the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, built once by `make artifacts`) and execute them
//! from the Rust hot path.  Python never runs at training time.
//!
//! Pattern (see /opt/xla-example): HLO **text** → `HloModuleProto::
//! from_text_file` → `XlaComputation::from_proto` → `PjRtClient::compile`
//! → `execute`.  Text is the interchange format because jax ≥ 0.5 emits
//! 64-bit instruction ids that the crate's xla_extension 0.5.1 rejects in
//! proto form.
//!
//! * [`artifacts`] — manifest parsing + executable cache.
//! * [`LlEvaluator`] — the model-quality evaluator: streams count blocks
//!   through the `ll_block`/`ll_vec` kernels (Pallas lgamma reduction
//!   inside) with closed-form padding corrections; every convergence curve
//!   in the figures is produced by this path.
//! * [`ProbOracle`] — the `prob` artifact: dense CGS conditionals for a
//!   token batch; integration tests use it as an independent oracle for
//!   the Rust samplers.

pub mod artifacts;

pub use artifacts::ArtifactSet;

use crate::lda::state::LdaState;
use crate::util::math::lgamma;

/// Block geometry — MUST mirror python/compile/model.py.
pub const BLOCK_ROWS: usize = 256;
pub const VEC_LEN: usize = 1024;
pub const PROB_BATCH: usize = 64;
pub const TOPIC_SIZES: &[usize] = &[128, 1024];

/// The blocked log-likelihood evaluator backed by PJRT executables.
pub struct LlEvaluator {
    arts: ArtifactSet,
    t: usize,
    /// reusable dense block buffer (BLOCK_ROWS × T)
    block: Vec<f32>,
    /// reusable vec buffer (VEC_LEN)
    vec: Vec<f32>,
}

impl LlEvaluator {
    /// Load the artifacts for topic count `t` from `dir`.
    pub fn new(dir: &std::path::Path, t: usize) -> Result<Self, String> {
        if !TOPIC_SIZES.contains(&t) {
            return Err(format!(
                "no artifacts for T={t} (built for {TOPIC_SIZES:?}); \
                 add T to python/compile/model.py TOPIC_SIZES and re-run make artifacts"
            ));
        }
        let arts = ArtifactSet::load(dir, t)?;
        Ok(LlEvaluator { arts, t, block: vec![0.0; BLOCK_ROWS * t], vec: vec![0.0; VEC_LEN] })
    }

    pub fn topics(&self) -> usize {
        self.t
    }

    /// sum(lgamma(block + c)) via the Pallas kernel executable.
    fn block_sum(&mut self, c: f32) -> Result<f64, String> {
        let lit = xla::Literal::vec1(&self.block)
            .reshape(&[BLOCK_ROWS as i64, self.t as i64])
            .map_err(|e| e.to_string())?;
        let out = self
            .arts
            .ll_block
            .execute::<xla::Literal>(&[lit, xla::Literal::from(c)])
            .map_err(|e| e.to_string())?[0][0]
            .to_literal_sync()
            .map_err(|e| e.to_string())?
            .to_tuple1()
            .map_err(|e| e.to_string())?;
        Ok(out.to_vec::<f32>().map_err(|e| e.to_string())?[0] as f64)
    }

    /// sum(lgamma(vec + c)) via the ll_vec executable.
    fn vec_sum(&mut self, c: f32) -> Result<f64, String> {
        let lit = xla::Literal::vec1(&self.vec);
        let out = self
            .arts
            .ll_vec
            .execute::<xla::Literal>(&[lit, xla::Literal::from(c)])
            .map_err(|e| e.to_string())?[0][0]
            .to_literal_sync()
            .map_err(|e| e.to_string())?
            .to_tuple1()
            .map_err(|e| e.to_string())?;
        Ok(out.to_vec::<f32>().map_err(|e| e.to_string())?[0] as f64)
    }

    /// The collapsed joint log-likelihood of `state` (same quantity as
    /// [`crate::lda::eval::log_likelihood`], computed on the XLA path).
    pub fn log_likelihood(&mut self, state: &LdaState) -> Result<f64, String> {
        if state.num_topics() != self.t {
            return Err(format!(
                "state has T={} but evaluator was built for T={}",
                state.num_topics(),
                self.t
            ));
        }
        let t = self.t;
        let alpha = state.hyper.alpha;
        let beta = state.hyper.beta;
        let d = state.ntd.len();
        let j = state.vocab;

        // ---- doc side: Σ lgamma(n_td + α) over D×T, blockwise ----
        let mut total = 0.0f64;
        let mut row_in_block = 0usize;
        self.block.iter_mut().for_each(|x| *x = 0.0);
        for counts in &state.ntd {
            for (topic, c) in counts.iter() {
                self.block[row_in_block * t + topic as usize] = c as f32;
            }
            row_in_block += 1;
            if row_in_block == BLOCK_ROWS {
                total += self.block_sum(alpha as f32)?;
                self.block.iter_mut().for_each(|x| *x = 0.0);
                row_in_block = 0;
            }
        }
        if row_in_block > 0 {
            let pad = BLOCK_ROWS - row_in_block;
            total += self.block_sum(alpha as f32)? - pad as f64 * t as f64 * lgamma(alpha);
        }
        // − Σ lgamma(n_d + Tα), vec-chunked
        let ta = (t as f64 * alpha) as f32;
        let mut idx = 0usize;
        self.vec.iter_mut().for_each(|x| *x = 0.0);
        for counts in &state.ntd {
            self.vec[idx] = counts.total() as f32;
            idx += 1;
            if idx == VEC_LEN {
                total -= self.vec_sum(ta)?;
                self.vec.iter_mut().for_each(|x| *x = 0.0);
                idx = 0;
            }
        }
        if idx > 0 {
            let pad = VEC_LEN - idx;
            total -= self.vec_sum(ta)? - pad as f64 * lgamma(ta as f64);
        }
        total += d as f64 * (lgamma(t as f64 * alpha) - t as f64 * lgamma(alpha));

        // ---- word side: Σ lgamma(n_wt + β) over J×T, blockwise ----
        let mut row_in_block = 0usize;
        self.block.iter_mut().for_each(|x| *x = 0.0);
        for counts in &state.nwt {
            for (topic, c) in counts.iter() {
                self.block[row_in_block * t + topic as usize] = c as f32;
            }
            row_in_block += 1;
            if row_in_block == BLOCK_ROWS {
                total += self.block_sum(beta as f32)?;
                self.block.iter_mut().for_each(|x| *x = 0.0);
                row_in_block = 0;
            }
        }
        if row_in_block > 0 {
            let pad = BLOCK_ROWS - row_in_block;
            total += self.block_sum(beta as f32)? - pad as f64 * t as f64 * lgamma(beta);
        }
        // − Σ lgamma(n_t + Jβ)
        let jb = (j as f64 * beta) as f32;
        let mut idx = 0usize;
        self.vec.iter_mut().for_each(|x| *x = 0.0);
        for &nt in &state.nt {
            self.vec[idx] = nt as f32;
            idx += 1;
            if idx == VEC_LEN {
                total -= self.vec_sum(jb)?;
                self.vec.iter_mut().for_each(|x| *x = 0.0);
                idx = 0;
            }
        }
        if idx > 0 {
            let pad = VEC_LEN - idx;
            total -= self.vec_sum(jb)? - pad as f64 * lgamma(jb as f64);
        }
        total += t as f64 * (lgamma(j as f64 * beta) - j as f64 * lgamma(beta));

        Ok(total)
    }
}

/// The dense CGS conditional oracle (the `prob` artifact).
pub struct ProbOracle {
    arts: ArtifactSet,
    t: usize,
}

impl ProbOracle {
    pub fn new(dir: &std::path::Path, t: usize) -> Result<Self, String> {
        Ok(ProbOracle { arts: ArtifactSet::load(dir, t)?, t })
    }

    /// p[b,t] and norms for a batch of PROB_BATCH tokens described by
    /// their dense (ntd, ntw) rows plus the totals.
    pub fn dense_prob(
        &self,
        ntd: &[f32],
        ntw: &[f32],
        nt: &[f32],
        alpha: f32,
        beta: f32,
        betabar: f32,
    ) -> Result<(Vec<f32>, Vec<f32>), String> {
        let b = PROB_BATCH;
        assert_eq!(ntd.len(), b * self.t);
        assert_eq!(ntw.len(), b * self.t);
        assert_eq!(nt.len(), self.t);
        let prob = self.arts.prob.as_ref().ok_or("prob artifact not loaded")?;
        let mk = |v: &[f32], dims: &[i64]| -> Result<xla::Literal, String> {
            xla::Literal::vec1(v).reshape(dims).map_err(|e| e.to_string())
        };
        let out = prob
            .execute::<xla::Literal>(&[
                mk(ntd, &[b as i64, self.t as i64])?,
                mk(ntw, &[b as i64, self.t as i64])?,
                xla::Literal::vec1(nt),
                xla::Literal::vec1(&[alpha, beta, betabar]),
            ])
            .map_err(|e| e.to_string())?[0][0]
            .to_literal_sync()
            .map_err(|e| e.to_string())?;
        let (p, norm) = out.to_tuple2().map_err(|e| e.to_string())?;
        Ok((
            p.to_vec::<f32>().map_err(|e| e.to_string())?,
            norm.to_vec::<f32>().map_err(|e| e.to_string())?,
        ))
    }
}

/// Default artifact directory (relative to the repo root).
pub fn default_artifact_dir() -> std::path::PathBuf {
    std::path::PathBuf::from("artifacts")
}

/// True when `make artifacts` has produced the manifest.
pub fn artifacts_available(dir: &std::path::Path) -> bool {
    dir.join("manifest.txt").exists()
}
