//! Model-quality evaluation backends.
//!
//! Two implementations of the same blocked evaluator API ([`LlEvaluator`],
//! [`ProbOracle`]) live behind the `pjrt` feature:
//!
//! * **`pjrt` on** (`pjrt.rs`): the AOT-compiled JAX/Pallas artifacts
//!   (`artifacts/*.hlo.txt`, built once by `make artifacts`) are loaded and
//!   executed through the XLA PJRT C API — Python never runs at training
//!   time.  Pattern (see /opt/xla-example): HLO **text** →
//!   `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//!   `PjRtClient::compile` → `execute`.  Requires the vendored `xla` crate.
//! * **`pjrt` off** ([`native`], the default): a pure-Rust port of the same
//!   blocked computation (identical f32 block geometry, identical padding
//!   corrections), so the default build and test run hermetically with no
//!   Python, JAX, or XLA artifacts installed.
//!
//! Both backends stream dense count blocks through `Σ lgamma(x + c)`
//! reductions with closed-form corrections for block padding; every
//! convergence curve in the figures is produced by this path.  The
//! [`artifacts`] module (manifest parsing + executable cache) is shared;
//! its PJRT compilation half is feature-gated.

pub mod artifacts;
#[cfg(not(feature = "pjrt"))]
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

#[cfg(feature = "pjrt")]
pub use artifacts::ArtifactSet;
#[cfg(not(feature = "pjrt"))]
pub use native::{LlEvaluator, ProbOracle};
#[cfg(feature = "pjrt")]
pub use pjrt::{LlEvaluator, ProbOracle};

use crate::lda::state::LdaState;
use crate::util::math::lgamma;

/// Block geometry — MUST mirror python/compile/model.py.
pub const BLOCK_ROWS: usize = 256;
pub const VEC_LEN: usize = 1024;
pub const PROB_BATCH: usize = 64;
pub const TOPIC_SIZES: &[usize] = &[128, 1024];

/// The two reductions a backend must provide.  `block` is a dense
/// `BLOCK_ROWS × T` row-major buffer, `vec` a `VEC_LEN` buffer; both sums
/// are `Σ lgamma(x + c)` over every element, padding included.
pub(crate) trait LlKernels {
    fn block_sum(&mut self, block: &[f32], c: f32) -> Result<f64, String>;
    fn vec_sum(&mut self, vec: &[f32], c: f32) -> Result<f64, String>;
}

/// The collapsed joint log-likelihood of `state` (same quantity as
/// [`crate::lda::eval::log_likelihood`]), computed blockwise through a
/// backend's kernels.  Shared by both backends so their numerics can only
/// differ inside the reductions themselves.
pub(crate) fn blocked_log_likelihood<K: LlKernels>(
    kern: &mut K,
    state: &LdaState,
    t: usize,
    block: &mut [f32],
    vec: &mut [f32],
) -> Result<f64, String> {
    assert_eq!(block.len(), BLOCK_ROWS * t);
    assert_eq!(vec.len(), VEC_LEN);
    if state.num_topics() != t {
        return Err(format!(
            "state has T={} but evaluator was built for T={}",
            state.num_topics(),
            t
        ));
    }
    let alpha = state.hyper.alpha;
    let beta = state.hyper.beta;
    let d = state.ntd.len();
    let j = state.vocab;

    // ---- doc side: Σ lgamma(n_td + α) over D×T, blockwise ----
    let mut total = 0.0f64;
    let mut row_in_block = 0usize;
    block.iter_mut().for_each(|x| *x = 0.0);
    for counts in &state.ntd {
        for (topic, c) in counts.iter() {
            block[row_in_block * t + topic as usize] = c as f32;
        }
        row_in_block += 1;
        if row_in_block == BLOCK_ROWS {
            total += kern.block_sum(block, alpha as f32)?;
            block.iter_mut().for_each(|x| *x = 0.0);
            row_in_block = 0;
        }
    }
    if row_in_block > 0 {
        let pad = BLOCK_ROWS - row_in_block;
        total += kern.block_sum(block, alpha as f32)? - pad as f64 * t as f64 * lgamma(alpha);
    }
    // − Σ lgamma(n_d + Tα), vec-chunked
    let ta = (t as f64 * alpha) as f32;
    let mut idx = 0usize;
    vec.iter_mut().for_each(|x| *x = 0.0);
    for counts in &state.ntd {
        vec[idx] = counts.total() as f32;
        idx += 1;
        if idx == VEC_LEN {
            total -= kern.vec_sum(vec, ta)?;
            vec.iter_mut().for_each(|x| *x = 0.0);
            idx = 0;
        }
    }
    if idx > 0 {
        let pad = VEC_LEN - idx;
        total -= kern.vec_sum(vec, ta)? - pad as f64 * lgamma(ta as f64);
    }
    total += d as f64 * (lgamma(t as f64 * alpha) - t as f64 * lgamma(alpha));

    // ---- word side: Σ lgamma(n_wt + β) over J×T, blockwise ----
    let mut row_in_block = 0usize;
    block.iter_mut().for_each(|x| *x = 0.0);
    for counts in &state.nwt {
        for (topic, c) in counts.iter() {
            block[row_in_block * t + topic as usize] = c as f32;
        }
        row_in_block += 1;
        if row_in_block == BLOCK_ROWS {
            total += kern.block_sum(block, beta as f32)?;
            block.iter_mut().for_each(|x| *x = 0.0);
            row_in_block = 0;
        }
    }
    if row_in_block > 0 {
        let pad = BLOCK_ROWS - row_in_block;
        total += kern.block_sum(block, beta as f32)? - pad as f64 * t as f64 * lgamma(beta);
    }
    // − Σ lgamma(n_t + Jβ)
    let jb = (j as f64 * beta) as f32;
    let mut idx = 0usize;
    vec.iter_mut().for_each(|x| *x = 0.0);
    for &nt in &state.nt {
        vec[idx] = nt as f32;
        idx += 1;
        if idx == VEC_LEN {
            total -= kern.vec_sum(vec, jb)?;
            vec.iter_mut().for_each(|x| *x = 0.0);
            idx = 0;
        }
    }
    if idx > 0 {
        let pad = VEC_LEN - idx;
        total -= kern.vec_sum(vec, jb)? - pad as f64 * lgamma(jb as f64);
    }
    total += t as f64 * (lgamma(j as f64 * beta) - j as f64 * lgamma(beta));

    Ok(total)
}

/// Default artifact directory (relative to the repo root).
pub fn default_artifact_dir() -> std::path::PathBuf {
    std::path::PathBuf::from("artifacts")
}

/// True when `make artifacts` has produced the manifest.
pub fn artifacts_available(dir: &std::path::Path) -> bool {
    dir.join("manifest.txt").exists()
}
