//! Pure-Rust evaluator backend (the default, `pjrt` feature off).
//!
//! Implements the same blocked API as the PJRT backend — identical f32
//! block geometry, identical padding corrections — with the reductions
//! computed by the in-crate Lanczos [`lgamma`] instead of an XLA
//! executable.  Counts are integers well below 2^24, so the f32 staging
//! loses nothing and the result agrees with the sparse reference evaluator
//! ([`crate::lda::eval`]) to f64 rounding.

use super::{blocked_log_likelihood, LlKernels, BLOCK_ROWS, PROB_BATCH, VEC_LEN};
use crate::lda::state::LdaState;
use crate::util::math::lgamma;

struct NativeKernels;

impl LlKernels for NativeKernels {
    fn block_sum(&mut self, block: &[f32], c: f32) -> Result<f64, String> {
        Ok(block.iter().map(|&x| lgamma((x + c) as f64)).sum())
    }

    fn vec_sum(&mut self, vec: &[f32], c: f32) -> Result<f64, String> {
        Ok(vec.iter().map(|&x| lgamma((x + c) as f64)).sum())
    }
}

/// The blocked log-likelihood evaluator, pure-Rust flavor.  `_dir` is
/// accepted (and ignored) so both backends expose one constructor shape.
pub struct LlEvaluator {
    t: usize,
    block: Vec<f32>,
    vec: Vec<f32>,
}

impl LlEvaluator {
    /// Which backend this build's `LlEvaluator` is ("blocked-rust" here).
    pub const BACKEND: &str = "blocked-rust";

    pub fn new(_dir: &std::path::Path, t: usize) -> Result<Self, String> {
        if t < 2 {
            return Err(format!("evaluator needs T >= 2, got {t}"));
        }
        Ok(LlEvaluator { t, block: vec![0.0; BLOCK_ROWS * t], vec: vec![0.0; VEC_LEN] })
    }

    pub fn topics(&self) -> usize {
        self.t
    }

    /// The collapsed joint log-likelihood of `state` (same quantity as
    /// [`crate::lda::eval::log_likelihood`], via the blocked path).
    pub fn log_likelihood(&mut self, state: &LdaState) -> Result<f64, String> {
        blocked_log_likelihood(&mut NativeKernels, state, self.t, &mut self.block, &mut self.vec)
    }
}

/// Dense CGS conditional oracle, pure-Rust flavor: evaluates eq. (2)
/// directly on the supplied dense rows.
pub struct ProbOracle {
    t: usize,
}

impl ProbOracle {
    pub fn new(_dir: &std::path::Path, t: usize) -> Result<Self, String> {
        if t < 2 {
            return Err(format!("oracle needs T >= 2, got {t}"));
        }
        Ok(ProbOracle { t })
    }

    /// p[b,t] and norms for a batch of PROB_BATCH tokens described by
    /// their dense (ntd, ntw) rows plus the totals.
    pub fn dense_prob(
        &self,
        ntd: &[f32],
        ntw: &[f32],
        nt: &[f32],
        alpha: f32,
        beta: f32,
        betabar: f32,
    ) -> Result<(Vec<f32>, Vec<f32>), String> {
        let (b, t) = (PROB_BATCH, self.t);
        assert_eq!(ntd.len(), b * t);
        assert_eq!(ntw.len(), b * t);
        assert_eq!(nt.len(), t);
        let mut p = vec![0.0f32; b * t];
        let mut norm = vec![0.0f32; b];
        for i in 0..b {
            let mut acc = 0.0f32;
            for k in 0..t {
                let v = (ntd[i * t + k] + alpha) * (ntw[i * t + k] + beta) / (nt[k] + betabar);
                p[i * t + k] = v;
                acc += v;
            }
            norm[i] = acc;
        }
        Ok((p, norm))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;
    use crate::lda;
    use crate::lda::state::{Hyper, LdaState};
    use crate::util::rng::Pcg32;

    fn dir() -> std::path::PathBuf {
        super::super::default_artifact_dir()
    }

    /// Blocked path == sparse reference, including both padding branches
    /// (tiny: D=120 < BLOCK_ROWS, vocab=300 > BLOCK_ROWS).
    #[test]
    fn blocked_ll_matches_sparse_reference() {
        let corpus = preset("tiny").unwrap();
        for t in [8usize, 128] {
            let mut rng = Pcg32::seeded(t as u64);
            let state = LdaState::init_random(&corpus, Hyper::paper_default(t), &mut rng);
            let reference = lda::log_likelihood(&state);
            let mut ev = LlEvaluator::new(&dir(), t).unwrap();
            let blocked = ev.log_likelihood(&state).unwrap();
            // β is staged through f32 (mirroring the kernel geometry), and
            // ψ(0.01) ≈ -100 amplifies that rounding across the zero cells,
            // so agreement is ~1e-8 relative, not f64-exact
            let rel = ((blocked - reference) / reference).abs();
            assert!(rel < 1e-6, "T={t}: blocked {blocked:.8e} vs reference {reference:.8e}");
        }
    }

    /// Exactly full blocks (row_in_block == 0 at the end) take the no-pad
    /// branch; build a corpus with D == BLOCK_ROWS to hit it.
    #[test]
    fn blocked_ll_full_block_boundary() {
        use crate::corpus::synthetic::{generate, SyntheticSpec};
        let corpus = generate(&SyntheticSpec {
            num_docs: super::BLOCK_ROWS,
            vocab: super::VEC_LEN,
            avg_doc_len: 20.0,
            true_topics: 4,
            seed: 3,
            ..Default::default()
        });
        let mut rng = Pcg32::seeded(4);
        let state = LdaState::init_random(&corpus, Hyper::paper_default(16), &mut rng);
        let reference = lda::log_likelihood(&state);
        let mut ev = LlEvaluator::new(&dir(), 16).unwrap();
        let blocked = ev.log_likelihood(&state).unwrap();
        let rel = ((blocked - reference) / reference).abs();
        assert!(rel < 1e-6, "blocked {blocked:.8e} vs reference {reference:.8e}");
    }

    #[test]
    fn evaluator_rejects_topic_mismatch() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(1);
        let state = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        let mut ev = LlEvaluator::new(&dir(), 16).unwrap();
        assert!(ev.log_likelihood(&state).is_err());
    }

    #[test]
    fn prob_oracle_matches_dense_conditional() {
        let t = 16usize;
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(77);
        let state = LdaState::init_random(&corpus, Hyper::paper_default(t), &mut rng);
        let oracle = ProbOracle::new(&dir(), t).unwrap();

        let mut ntd = vec![0f32; PROB_BATCH * t];
        let mut ntw = vec![0f32; PROB_BATCH * t];
        let mut sites = Vec::new();
        'outer: for (doc, tokens) in corpus.docs().enumerate() {
            for &w in tokens {
                let b = sites.len();
                for k in 0..t {
                    ntd[b * t + k] = state.ntd[doc].get(k as u16) as f32;
                    ntw[b * t + k] = state.nwt[w as usize].get(k as u16) as f32;
                }
                sites.push((doc, w as usize));
                if sites.len() == PROB_BATCH {
                    break 'outer;
                }
            }
        }
        let nt: Vec<f32> = state.nt.iter().map(|&v| v as f32).collect();
        let h = state.hyper;
        let bb = h.betabar(state.vocab) as f32;
        let (p, norm) =
            oracle.dense_prob(&ntd, &ntw, &nt, h.alpha as f32, h.beta as f32, bb).unwrap();
        for (b, &(doc, word)) in sites.iter().enumerate() {
            let want = state.dense_conditional(doc, word);
            let total: f64 = want.iter().sum();
            assert!(((norm[b] as f64 - total) / total).abs() < 1e-4, "site {b} norm");
            for k in 0..t {
                let rel = ((p[b * t + k] as f64 - want[k]) / want[k]).abs();
                assert!(rel < 1e-4, "site {b} topic {k}");
            }
        }
    }
}
