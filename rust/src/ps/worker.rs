//! Parameter-server worker: samples its document partition against cached
//! (stale) server state, batching pulls and pushes.
//!
//! The sampler is doc-major F+LDA (decomposition (4)) over the cached
//! counts — same per-token asymptotics as the nomad workers, so wall-clock
//! and simulated comparisons isolate the *coordination* difference, not a
//! sampler difference (the paper does the same by comparing against
//! SparseLDA-based Yahoo! LDA at matched sampling cost).

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

use crate::corpus::CorpusSlice;
use crate::lda::state::{local_rows, Hyper, SparseCounts};
use crate::sampler::bsearch::SparseCumSum;
use crate::sampler::ftree::FTree;
use crate::sampler::DiscreteSampler;
use crate::util::rng::Pcg32;

use super::server::PsServer;

/// Signed per-topic delta accumulator (sorted sparse).
#[derive(Clone, Debug, Default)]
pub struct SignedCounts {
    pairs: Vec<(u16, i32)>,
}

impl SignedCounts {
    #[inline]
    pub fn add(&mut self, topic: u16, delta: i32) {
        match self.pairs.binary_search_by_key(&topic, |&(t, _)| t) {
            Ok(i) => {
                self.pairs[i].1 += delta;
                if self.pairs[i].1 == 0 {
                    self.pairs.remove(i);
                }
            }
            Err(i) => self.pairs.insert(i, (topic, delta)),
        }
    }

    pub fn drain(&mut self) -> Vec<(u16, i32)> {
        std::mem::take(&mut self.pairs)
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }
}

#[derive(Debug)]
pub enum PsWorkerMsg {
    RunEpoch,
    ReportDocs,
    Stop,
}

#[derive(Debug)]
pub enum PsWorkerReply {
    EpochDone { worker: usize, processed: u64, server_ops: u64, pulls: u64 },
    /// flat CSR assignment payload for the worker's contiguous doc range
    Docs { worker: usize, start_doc: usize, ntd: Vec<SparseCounts>, z: Vec<u16> },
}

/// Worker-local state.  Documents and assignments are stored flat in the
/// corpus's CSR layout, rebased to local offsets (see [`crate::corpus`]).
pub struct PsWorkerState {
    pub id: usize,
    hyper: Hyper,
    vocab: usize,
    start_doc: usize,
    /// the worker's tokens, documents back to back (CSR payload)
    tokens: Vec<u32>,
    /// local doc d is `tokens[offsets[d]..offsets[d+1]]` (and same for z)
    offsets: Vec<usize>,
    z: Vec<u16>,
    ntd: Vec<SparseCounts>,
    batch_docs: usize,
    rng: Pcg32,
    tree: FTree,
    r: SparseCumSum,
}

impl PsWorkerState {
    pub fn new(
        id: usize,
        slice: CorpusSlice,
        hyper: Hyper,
        z: Vec<u16>,
        batch_docs: usize,
        rng: Pcg32,
    ) -> Self {
        let (offsets, ntd) = local_rows(&slice, &z, hyper.t);
        let t = hyper.t;
        PsWorkerState {
            id,
            hyper,
            vocab: slice.vocab,
            start_doc: slice.start_doc,
            tokens: slice.tokens,
            offsets,
            z,
            ntd,
            batch_docs: batch_docs.max(1),
            rng,
            tree: FTree::with_capacity(&vec![0.0; t], t),
            r: SparseCumSum::with_capacity(64),
        }
    }

    /// Doc-side state accessors (simulator gather path).
    pub fn ntd_rows(&self) -> &[SparseCounts] {
        &self.ntd
    }

    /// Flat assignment payload for the worker's contiguous doc range.
    pub fn z_flat(&self) -> &[u16] {
        &self.z
    }

    pub fn start_doc(&self) -> usize {
        self.start_doc
    }

    fn num_docs(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of pull/compute/push batches per epoch.
    pub fn num_batches(&self) -> usize {
        self.num_docs().div_ceil(self.batch_docs)
    }

    /// Doc range of batch `b`.
    fn batch_range(&self, b: usize) -> (usize, usize) {
        let start = b * self.batch_docs;
        (start, (start + self.batch_docs).min(self.num_docs()))
    }

    /// The sorted-unique word set of batch `b` (the PULL request).
    pub fn batch_words(&self, b: usize) -> Vec<u32> {
        let (start, end) = self.batch_range(b);
        // contiguous docs → one contiguous token slice
        let mut words: Vec<u32> =
            self.tokens[self.offsets[start]..self.offsets[end]].to_vec();
        words.sort_unstable();
        words.dedup();
        words
    }

    /// Tokens in batch `b` (simulator cost-model input; O(1) under CSR).
    pub fn batch_tokens(&self, b: usize) -> usize {
        let (start, end) = self.batch_range(b);
        self.offsets[end] - self.offsets[start]
    }

    /// One pass over the partition; returns tokens processed.
    pub fn run_epoch(&mut self, server: &PsServer) -> (u64, u64) {
        let ops_before = server.ops();
        let mut processed = 0u64;
        for b in 0..self.num_batches() {
            let words = self.batch_words(b);
            let (rows, nt_cache) = server.pull(&words);
            let out = self.process_batch(b, &words, rows, nt_cache);
            server.push(&out.pushes, &out.nt_delta);
            processed += out.processed;
        }
        (processed, server.ops() - ops_before)
    }

    /// Sample batch `b` against the supplied (stale) cache; returns the
    /// deltas to push.  Shared by the thread loop and the simulator.
    pub fn process_batch(
        &mut self,
        b: usize,
        words: &[u32],
        mut rows: Vec<SparseCounts>,
        mut nt_cache: Vec<i64>,
    ) -> BatchResult {
        let h = self.hyper;
        let bb = h.betabar(self.vocab);
        let (batch_start, batch_end) = self.batch_range(b);
        let mut processed = 0u64;
        let word_pos = |w: u32| words.binary_search(&w).expect("word in batch set");

        // deltas accumulated for the PUSH
        let mut word_deltas: Vec<SignedCounts> = vec![SignedCounts::default(); words.len()];
        let mut nt_delta = vec![0i64; h.t];

        // F+tree base over cached totals: q_t = α/(nt+β̄)
        let base: Vec<f64> = nt_cache
            .iter()
            .map(|&n| h.alpha / (n.max(0) as f64 + bb))
            .collect();
        self.tree.refill(&base);

        for doc in batch_start..batch_end {
            // enter doc
            let support: Vec<u16> = self.ntd[doc].iter().map(|(t, _)| t).collect();
            for &t in &support {
                let q = (self.ntd[doc].get(t) as f64 + h.alpha)
                    / (nt_cache[t as usize].max(0) as f64 + bb);
                self.tree.set(t as usize, q);
            }

            let row = self.offsets[doc];
            for pos in 0..self.offsets[doc + 1] - row {
                let word = self.tokens[row + pos];
                let wp = word_pos(word);
                let old = self.z[row + pos];

                // remove from cached view + record deltas
                self.ntd[doc].dec(old);
                if rows[wp].get(old) > 0 {
                    rows[wp].dec(old);
                }
                nt_cache[old as usize] -= 1;
                word_deltas[wp].add(old, -1);
                nt_delta[old as usize] -= 1;
                let q = (self.ntd[doc].get(old) as f64 + h.alpha)
                    / (nt_cache[old as usize].max(0) as f64 + bb);
                self.tree.set(old as usize, q);

                // r over the cached word row
                self.r.clear();
                for (t, c) in rows[wp].iter() {
                    self.r.push(t as u32, c as f64 * self.tree.leaf(t as usize));
                }
                let r_total = self.r.total();
                let u = self.rng.uniform(h.beta * self.tree.total() + r_total);
                let new = if u < r_total {
                    self.r.sample(u) as u16
                } else {
                    self.tree.sample((u - r_total) / h.beta) as u16
                };

                self.ntd[doc].inc(new);
                rows[wp].inc(new);
                nt_cache[new as usize] += 1;
                word_deltas[wp].add(new, 1);
                nt_delta[new as usize] += 1;
                let q = (self.ntd[doc].get(new) as f64 + h.alpha)
                    / (nt_cache[new as usize].max(0) as f64 + bb);
                self.tree.set(new as usize, q);
                self.z[row + pos] = new;
                processed += 1;
            }

            // leave doc
            let support: Vec<u16> = self.ntd[doc].iter().map(|(t, _)| t).collect();
            for &t in &support {
                self.tree
                    .set(t as usize, h.alpha / (nt_cache[t as usize].max(0) as f64 + bb));
            }
        }

        // deltas for the PUSH
        let pushes: Vec<(u32, Vec<(u16, i32)>)> = words
            .iter()
            .zip(word_deltas.iter_mut())
            .filter(|(_, d)| !d.is_empty())
            .map(|(&w, d)| (w, d.drain()))
            .collect();
        BatchResult { pushes, nt_delta, processed }
    }
}

/// Output of [`PsWorkerState::process_batch`].
pub struct BatchResult {
    pub pushes: Vec<(u32, Vec<(u16, i32)>)>,
    pub nt_delta: Vec<i64>,
    pub processed: u64,
}

/// Worker thread body.
pub fn worker_loop(
    mut state: PsWorkerState,
    server: Arc<PsServer>,
    rx: Receiver<PsWorkerMsg>,
    reply: Sender<PsWorkerReply>,
) {
    while let Ok(msg) = rx.recv() {
        match msg {
            PsWorkerMsg::RunEpoch => {
                let (processed, server_ops) = state.run_epoch(&server);
                let _ = reply.send(PsWorkerReply::EpochDone {
                    worker: state.id,
                    processed,
                    server_ops,
                    pulls: state.num_batches() as u64,
                });
            }
            PsWorkerMsg::ReportDocs => {
                let _ = reply.send(PsWorkerReply::Docs {
                    worker: state.id,
                    start_doc: state.start_doc,
                    ntd: state.ntd.clone(),
                    z: state.z.clone(),
                });
            }
            PsWorkerMsg::Stop => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn signed_counts_cancel() {
        let mut s = SignedCounts::default();
        s.add(3, 1);
        s.add(3, -1);
        assert!(s.is_empty());
        s.add(2, -1);
        s.add(5, 2);
        assert_eq!(s.drain(), vec![(2, -1), (5, 2)]);
        assert!(s.is_empty());
    }
}
