//! Parameter-server LDA — the Yahoo! LDA (Smola & Narayanamurthy, VLDB'10)
//! baseline of §4.2 and Figs. 5–6.
//!
//! Architecture being modeled: a central server holds the authoritative
//! `n_wt` and `n_t`; every worker keeps a *cached local copy* of the rows
//! it needs, samples its documents against the (possibly stale) cache,
//! and asynchronously pushes accumulated deltas / pulls fresh values.
//! Both the word counts *and* the totals used by the sampler can be stale
//! — the contrast the paper draws with Nomad, where `n_wt` is always
//! exact and only `n_t` is bounded-stale.
//!
//! * threads mode (this module): workers are real threads; pull/push
//!   granularity is [`PsConfig::batch_docs`] documents.  On this 1-core
//!   session it validates semantics; contention/latency effects are
//!   reproduced in [`crate::simnet`].
//! * "disk" flavor (Fig. 5/6's Yahoo!LDA(D)) exists only in the simulator,
//!   as a per-token streaming time surcharge.

pub mod server;
pub mod worker;

pub use server::PsServer;

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::coordinator::EpochReport;
use crate::corpus::{Corpus, Partition};
use crate::lda::state::{assemble_state, checked_totals, Hyper, LdaState};
use crate::util::rng::Pcg32;

use worker::{PsWorkerMsg, PsWorkerReply, PsWorkerState};

/// Parameter-server runtime configuration.
#[derive(Clone, Debug)]
pub struct PsConfig {
    pub workers: usize,
    pub seed: u64,
    /// pull/push cadence in documents (1 = chatty, large = very stale)
    pub batch_docs: usize,
}

impl Default for PsConfig {
    fn default() -> Self {
        PsConfig { workers: 2, seed: 0, batch_docs: 8 }
    }
}

/// Coordinator handle.
pub struct PsRuntime {
    server: Arc<PsServer>,
    senders: Vec<Sender<PsWorkerMsg>>,
    replies: Receiver<PsWorkerReply>,
    handles: Vec<JoinHandle<()>>,
    hyper: Hyper,
    cfg: PsConfig,
    pub epochs_run: usize,
}

impl PsRuntime {
    /// Build workers from a random initial state (see [`Self::from_state`]).
    pub fn new(corpus: &Corpus, hyper: Hyper, cfg: PsConfig) -> Self {
        let mut rng = Pcg32::new(cfg.seed, 0x9A9A);
        let state = LdaState::init_random(corpus, hyper, &mut rng);
        Self::from_state(corpus, &state, cfg)
    }

    /// Build workers from explicit initial assignments (the resume path);
    /// the server becomes authoritative for the given counts.
    pub fn from_state(corpus: &Corpus, init: &LdaState, cfg: PsConfig) -> Self {
        assert!(cfg.workers >= 1);
        // offsets equality (not just doc count) — see NomadRuntime::from_state
        assert_eq!(init.doc_offsets.as_slice(), corpus.offsets(), "init state / corpus mismatch");
        let hyper = init.hyper;
        let partition = Partition::by_tokens(corpus, cfg.workers);
        // worker streams derive from a different stream id than the init
        // draws (0x9A9A in `new`), so sampling never replays them
        let mut seed_rng = Pcg32::new(cfg.seed, 0xA9A9);

        let nt: Vec<i64> = init.nt.iter().map(|&v| v as i64).collect();
        let server = Arc::new(PsServer::new(init.nwt.clone(), nt));

        let (reply_tx, replies) = channel();
        let mut senders = Vec::new();
        let mut handles = Vec::new();
        for l in 0..cfg.workers {
            let (tx, rx) = channel();
            senders.push(tx);
            let (start, end) = partition.ranges[l];
            let state = PsWorkerState::new(
                l,
                corpus.read_range(start, end),
                hyper,
                init.z_range(start, end).to_vec(),
                cfg.batch_docs,
                seed_rng.split(l as u64 + 1),
            );
            let server = Arc::clone(&server);
            let reply = reply_tx.clone();
            handles.push(std::thread::spawn(move || {
                worker::worker_loop(state, server, rx, reply);
            }));
        }

        PsRuntime { server, senders, replies, handles, hyper, cfg, epochs_run: 0 }
    }

    /// One pass of every worker over its documents (concurrent).
    pub fn run_epoch(&mut self) -> EpochReport {
        let t0 = std::time::Instant::now();
        for tx in &self.senders {
            tx.send(PsWorkerMsg::RunEpoch).expect("ps worker hung up");
        }
        let mut processed = 0;
        let mut server_ops = 0;
        let mut pulls = 0;
        for _ in 0..self.cfg.workers {
            match self.replies.recv().expect("ps reply channel closed") {
                PsWorkerReply::EpochDone { processed: p, server_ops: o, pulls: pl, .. } => {
                    processed += p;
                    server_ops += o;
                    pulls += pl;
                }
                other => panic!("expected EpochDone, got {other:?}"),
            }
        }
        self.epochs_run += 1;
        EpochReport {
            processed,
            secs: t0.elapsed().as_secs_f64(),
            // every pull refreshes a cache that concurrent pushes have
            // already made stale — the contrast with nomad's exact rows
            stale_reads: pulls,
            msgs: server_ops,
            ring: None,
        }
    }

    pub fn run_epochs(&mut self, n: usize) -> Vec<EpochReport> {
        (0..n).map(|_| self.run_epoch()).collect()
    }

    /// Exact global state (between epochs the server is authoritative).
    ///
    /// Panics if the server totals contain a negative entry — that is
    /// count-state corruption, not a value to clamp away.
    pub fn gather_state(&mut self, corpus: &Corpus) -> LdaState {
        for tx in &self.senders {
            tx.send(PsWorkerMsg::ReportDocs).expect("ps worker hung up");
        }
        let mut parts = Vec::with_capacity(self.cfg.workers);
        for _ in 0..self.cfg.workers {
            match self.replies.recv().expect("ps reply channel closed") {
                PsWorkerReply::Docs { start_doc, ntd, z, .. } => {
                    parts.push((start_doc, ntd, z));
                }
                other => panic!("expected Docs, got {other:?}"),
            }
        }
        let (nwt, nt) = self.server.snapshot();
        assemble_state(
            corpus,
            self.hyper,
            parts.iter().map(|(s, n, z)| (*s, n.as_slice(), z.as_slice())),
            nwt,
            checked_totals(&nt),
        )
    }

    pub fn shutdown(&mut self) {
        for tx in &self.senders {
            let _ = tx.send(PsWorkerMsg::Stop);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for PsRuntime {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;
    use crate::lda::log_likelihood;

    #[test]
    fn ps_trains_and_stays_consistent() {
        let corpus = preset("tiny").unwrap();
        let mut rt = PsRuntime::new(&corpus, Hyper::paper_default(16), PsConfig {
            workers: 3,
            seed: 11,
            batch_docs: 4,
        });
        let ll0 = log_likelihood(&rt.gather_state(&corpus));
        let stats = rt.run_epochs(6);
        assert!(stats.iter().all(|s| s.processed as usize == corpus.num_tokens()));
        assert!(stats[0].msgs > 0);
        assert!(stats[0].stale_reads > 0);
        let state = rt.gather_state(&corpus);
        state.check_consistency(&corpus).unwrap();
        assert!(log_likelihood(&state) > ll0);
        rt.shutdown();
    }

    #[test]
    fn staleness_grows_with_batch_size_but_still_converges() {
        let corpus = preset("tiny").unwrap();
        for batch in [1usize, 64] {
            let mut rt = PsRuntime::new(&corpus, Hyper::paper_default(8), PsConfig {
                workers: 2,
                seed: 12,
                batch_docs: batch,
            });
            rt.run_epochs(10);
            let state = rt.gather_state(&corpus);
            state.check_consistency(&corpus).unwrap();
            rt.shutdown();
        }
    }
}
