//! The central parameter server: authoritative `n_wt` rows + `n_t` totals
//! behind striped locks (row stripes for the word matrix, one stripe for
//! the totals) — the coarse architecture of Yahoo! LDA's ICE store.

use std::sync::Mutex;

use crate::lda::state::SparseCounts;

/// Number of row stripes (locks) over the word-topic matrix.
pub const STRIPES: usize = 64;

/// Server-side count store.
pub struct PsServer {
    /// word-topic rows, striped by `word % STRIPES`
    rows: Vec<Mutex<Vec<SparseCounts>>>,
    /// stripe-to-word mapping: stripe s holds words {w : w % STRIPES == s},
    /// in increasing order; index within stripe = w / STRIPES
    vocab: usize,
    nt: Mutex<Vec<i64>>,
    /// push/pull counters (telemetry)
    ops: Mutex<u64>,
}

impl PsServer {
    pub fn new(nwt: Vec<SparseCounts>, nt: Vec<i64>) -> Self {
        let vocab = nwt.len();
        let mut stripes: Vec<Vec<SparseCounts>> = (0..STRIPES).map(|_| Vec::new()).collect();
        for (w, counts) in nwt.into_iter().enumerate() {
            stripes[w % STRIPES].push(counts);
        }
        PsServer {
            rows: stripes.into_iter().map(Mutex::new).collect(),
            vocab,
            nt: Mutex::new(nt),
            ops: Mutex::new(0),
        }
    }

    /// Pull fresh copies of the given rows (sorted word ids) + totals.
    pub fn pull(&self, words: &[u32]) -> (Vec<SparseCounts>, Vec<i64>) {
        let mut out = Vec::with_capacity(words.len());
        for &w in words {
            let stripe = self.rows[w as usize % STRIPES].lock().unwrap();
            out.push(stripe[w as usize / STRIPES].clone());
        }
        let nt = self.nt.lock().unwrap().clone();
        *self.ops.lock().unwrap() += 1;
        (out, nt)
    }

    /// Push per-word topic deltas and total deltas.
    pub fn push(&self, word_deltas: &[(u32, Vec<(u16, i32)>)], nt_delta: &[i64]) {
        for (w, deltas) in word_deltas {
            let mut stripe = self.rows[*w as usize % STRIPES].lock().unwrap();
            let row = &mut stripe[*w as usize / STRIPES];
            for &(t, d) in deltas {
                match d.cmp(&0) {
                    std::cmp::Ordering::Greater => {
                        for _ in 0..d {
                            row.inc(t);
                        }
                    }
                    std::cmp::Ordering::Less => {
                        for _ in 0..(-d) {
                            row.dec(t);
                        }
                    }
                    std::cmp::Ordering::Equal => {}
                }
            }
        }
        let mut nt = self.nt.lock().unwrap();
        for (acc, &d) in nt.iter_mut().zip(nt_delta) {
            *acc += d;
        }
        *self.ops.lock().unwrap() += 1;
    }

    /// Full snapshot (coordinator, between epochs).
    pub fn snapshot(&self) -> (Vec<SparseCounts>, Vec<i64>) {
        let mut nwt = vec![SparseCounts::default(); self.vocab];
        for (s, stripe) in self.rows.iter().enumerate() {
            let stripe = stripe.lock().unwrap();
            for (i, counts) in stripe.iter().enumerate() {
                nwt[i * STRIPES + s] = counts.clone();
            }
        }
        (nwt, self.nt.lock().unwrap().clone())
    }

    pub fn ops(&self) -> u64 {
        *self.ops.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn server(vocab: usize, t: usize) -> PsServer {
        PsServer::new(vec![SparseCounts::default(); vocab], vec![0; t])
    }

    #[test]
    fn push_pull_roundtrip() {
        let s = server(100, 8);
        s.push(&[(7, vec![(2, 3)]), (99, vec![(0, 1)])], &[1, 0, 3, 0, 0, 0, 0, 0]);
        let (rows, nt) = s.pull(&[7, 99, 50]);
        assert_eq!(rows[0].get(2), 3);
        assert_eq!(rows[1].get(0), 1);
        assert!(rows[2].is_empty());
        assert_eq!(nt, vec![1, 0, 3, 0, 0, 0, 0, 0]);
        assert_eq!(s.ops(), 2);
    }

    #[test]
    fn negative_deltas_remove() {
        let s = server(10, 4);
        s.push(&[(3, vec![(1, 2)])], &[0, 2, 0, 0]);
        s.push(&[(3, vec![(1, -1)])], &[0, -1, 0, 0]);
        let (rows, nt) = s.pull(&[3]);
        assert_eq!(rows[0].get(1), 1);
        assert_eq!(nt[1], 1);
    }

    #[test]
    fn snapshot_covers_all_words() {
        let s = server(130, 4); // > STRIPES, uneven
        s.push(&[(0, vec![(0, 1)]), (129, vec![(3, 2)])], &[1, 0, 0, 2]);
        let (nwt, nt) = s.snapshot();
        assert_eq!(nwt.len(), 130);
        assert_eq!(nwt[0].get(0), 1);
        assert_eq!(nwt[129].get(3), 2);
        assert_eq!(nt[3], 2);
    }
}
