//! The cross-connection batching queue between handler threads and
//! inference workers.
//!
//! Handler threads decode inference requests and [`BatchQueue::push`] a
//! job each; worker threads [`BatchQueue::pop_batch`] *everything queued
//! at once* (up to a cap, optionally lingering for a batching window) and
//! run the whole batch through one warm engine — the F+tree base build
//! and scratch buffers are paid per batch, not per query.
//! `std::sync::mpsc` is single-consumer, so the queue is a hand-rolled
//! bounded MPMC: a `Mutex<VecDeque>` with two condvars (`not_empty` for
//! workers, `not_full` for backpressure on handlers), built on the
//! [`crate::util::sync`] shim so `rust/tests/loom_models.rs` can
//! model-check the push/pop/backpressure/close-drain protocol
//! exhaustively.
//!
//! Backpressure is explicit: when the queue is full past a deadline the
//! push fails with a named "server overloaded" error that travels back to
//! the client as a `Response::Err` — bounded memory under overload, never
//! an unbounded backlog.  The failure discipline extends to panics: a
//! worker that dies poisons nothing visible — producers and consumers get
//! the named close reason (see [`BatchQueue::close_named`]) instead of a
//! cascading `unwrap()` panic.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::util::sync::{lock_checked, wait_timeout, Condvar, Mutex};

/// Close reason when a mutex is found poisoned: some thread panicked
/// *inside* a queue critical section, so the state may be mid-mutation
/// and the only safe answer is a named shutdown.
const POISONED: &str = "inference queue poisoned: a worker thread panicked; server shutting down";

struct QueueState<T> {
    jobs: VecDeque<T>,
    /// `Some(reason)` once closed; the reason travels to producers as
    /// their push error.  The first close wins — a later, more generic
    /// close must not mask a "worker panicked" diagnosis.
    closed: Option<String>,
}

/// Bounded multi-producer multi-consumer job queue.
pub struct BatchQueue<T> {
    state: Mutex<QueueState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

impl<T> BatchQueue<T> {
    pub fn new(cap: usize) -> BatchQueue<T> {
        assert!(cap >= 1, "queue depth must be >= 1");
        BatchQueue {
            state: Mutex::new(QueueState { jobs: VecDeque::new(), closed: None }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap,
        }
    }

    /// Jobs currently queued (racy by nature; for stats reporting).
    /// A poisoned queue reports 0 — it no longer accepts or serves work.
    pub fn len(&self) -> usize {
        match lock_checked(&self.state) {
            Ok(st) => st.jobs.len(),
            Err(_) => 0,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enqueue one job, blocking up to `deadline` for room.  Errors by
    /// name when the queue stays full past the deadline (overload
    /// backpressure), the server is shutting down, or a worker panicked
    /// inside the queue.
    pub fn push(&self, job: T, deadline: Duration) -> Result<(), String> {
        let overloaded = || {
            format!(
                "server overloaded: inference queue held {} jobs for {deadline:?}",
                self.cap
            )
        };
        let t0 = Instant::now();
        let mut st = lock_checked(&self.state).map_err(|_| POISONED.to_string())?;
        while st.jobs.len() >= self.cap && st.closed.is_none() {
            let left = match deadline.checked_sub(t0.elapsed()) {
                Some(left) if !left.is_zero() => left,
                _ => return Err(overloaded()),
            };
            st = wait_timeout(&self.not_full, st, left).map_err(|_| POISONED.to_string())?;
        }
        if let Some(reason) = &st.closed {
            return Err(reason.clone());
        }
        st.jobs.push_back(job);
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Take one batch: block up to `idle` for a first job, then drain
    /// whatever is queued — lingering up to `window` (if nonzero) while
    /// under `max` jobs, so concurrent connections pile into one batch.
    ///
    /// * `Some(jobs)` — a non-empty batch to run;
    /// * `Some(vec![])` — the idle timeout fired with nothing queued
    ///   (workers use this to re-check the model slot version);
    /// * `None` — the queue is closed *and* drained, or poisoned: the
    ///   worker exits.
    pub fn pop_batch(&self, max: usize, window: Duration, idle: Duration) -> Option<Vec<T>> {
        let max = max.max(1);
        let t0 = Instant::now();
        let mut st = lock_checked(&self.state).ok()?;
        while st.jobs.is_empty() {
            if st.closed.is_some() {
                return None;
            }
            let left = match idle.checked_sub(t0.elapsed()) {
                Some(left) if !left.is_zero() => left,
                _ => return Some(Vec::new()),
            };
            st = wait_timeout(&self.not_empty, st, left).ok()?;
        }
        let mut batch = Vec::with_capacity(st.jobs.len().min(max));
        let w0 = Instant::now();
        loop {
            while batch.len() < max {
                match st.jobs.pop_front() {
                    Some(job) => batch.push(job),
                    None => break,
                }
            }
            if batch.len() >= max || st.closed.is_some() {
                break;
            }
            let left = match window.checked_sub(w0.elapsed()) {
                Some(left) if !left.is_zero() => left,
                _ => break,
            };
            st = match wait_timeout(&self.not_empty, st, left) {
                Ok(st) => st,
                // poisoned mid-linger: hand back what was already drained
                // (each job's reply is still owed an answer), the *next*
                // pop observes the poison and exits
                Err(_) => return Some(batch),
            };
        }
        drop(st);
        // up to `max` slots just freed — wake every blocked producer
        self.not_full.notify_all();
        Some(batch)
    }

    /// Close the queue: producers fail fast, consumers drain what is
    /// left and then get `None`.
    pub fn close(&self) {
        self.close_named("server shutting down: inference queue closed");
    }

    /// Close with an explicit reason — e.g. "inference worker panicked" —
    /// that every subsequent and currently-blocked producer receives as
    /// its error.  The first reason sticks.
    pub fn close_named(&self, reason: &str) {
        if let Ok(mut st) = lock_checked(&self.state) {
            if st.closed.is_none() {
                st.closed = Some(reason.to_string());
            }
        }
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn push_then_pop_batches_everything_queued() {
        let q = BatchQueue::new(16);
        for i in 0..5u64 {
            q.push(i, Duration::from_secs(1)).unwrap();
        }
        assert_eq!(q.len(), 5);
        let batch = q.pop_batch(3, Duration::ZERO, Duration::from_secs(1)).unwrap();
        assert_eq!(batch.len(), 3, "batch respects the max");
        assert_eq!(batch[0], 0, "FIFO order");
        let batch = q.pop_batch(16, Duration::ZERO, Duration::from_secs(1)).unwrap();
        assert_eq!(batch.len(), 2);
        assert!(q.is_empty());
    }

    #[test]
    fn idle_timeout_returns_an_empty_batch_not_a_hang() {
        let q = BatchQueue::<u64>::new(4);
        let t0 = Instant::now();
        let batch = q.pop_batch(8, Duration::ZERO, Duration::from_millis(30)).unwrap();
        assert!(batch.is_empty());
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn full_queue_backpressure_is_a_named_error() {
        let q = BatchQueue::new(2);
        q.push(0u64, Duration::from_millis(10)).unwrap();
        q.push(1u64, Duration::from_millis(10)).unwrap();
        let err = q.push(2u64, Duration::from_millis(10)).unwrap_err();
        assert!(err.contains("overloaded"), "unhelpful: {err}");
        // a consumer frees room and a blocked push succeeds
        let q = Arc::new(q);
        let q2 = Arc::clone(&q);
        let popper = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            q2.pop_batch(1, Duration::ZERO, Duration::from_secs(1)).unwrap().len()
        });
        q.push(3u64, Duration::from_secs(2)).unwrap();
        assert_eq!(popper.join().unwrap(), 1);
    }

    #[test]
    fn batching_window_collects_late_arrivals() {
        let q = Arc::new(BatchQueue::new(16));
        q.push(0u64, Duration::from_secs(1)).unwrap();
        let q2 = Arc::clone(&q);
        let pusher = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            q2.push(1u64, Duration::from_secs(1)).unwrap();
        });
        let batch = q
            .pop_batch(8, Duration::from_millis(250), Duration::from_secs(1))
            .unwrap();
        assert_eq!(batch.len(), 2, "the window must catch the late push");
        pusher.join().unwrap();
    }

    #[test]
    fn close_drains_then_terminates_consumers_and_fails_producers() {
        let q = BatchQueue::new(4);
        q.push(0u64, Duration::from_secs(1)).unwrap();
        q.close();
        // queued work still drains
        let batch = q.pop_batch(4, Duration::ZERO, Duration::from_secs(1)).unwrap();
        assert_eq!(batch.len(), 1);
        // then consumers see the end, promptly even with a long idle
        let t0 = Instant::now();
        assert!(q.pop_batch(4, Duration::ZERO, Duration::from_secs(60)).is_none());
        assert!(t0.elapsed() < Duration::from_secs(5));
        // and producers fail by name
        let err = q.push(1u64, Duration::from_secs(1)).unwrap_err();
        assert!(err.contains("shutting down"), "unhelpful: {err}");
    }

    #[test]
    #[should_panic(expected = "queue depth must be >= 1")]
    fn zero_capacity_queues_are_rejected_at_construction() {
        let _ = BatchQueue::<u64>::new(0);
    }

    /// Mirror of the loom close-wakes-blocked-producer model: a producer
    /// parked on backpressure must be woken by `close` and fail with the
    /// close reason — promptly, not after its full deadline.
    #[test]
    fn close_while_full_wakes_the_blocked_producer_with_the_reason() {
        let q = Arc::new(BatchQueue::new(1));
        q.push(0u64, Duration::from_secs(1)).unwrap();
        let q2 = Arc::clone(&q);
        let closer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            q2.close_named("inference worker panicked; server shutting down");
        });
        let t0 = Instant::now();
        let err = q.push(1u64, Duration::from_secs(30)).unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(10), "close must wake the producer");
        assert!(err.contains("worker panicked"), "unhelpful: {err}");
        closer.join().unwrap();
        // the job queued before the close still drains, then the end
        assert_eq!(q.pop_batch(4, Duration::ZERO, Duration::ZERO).unwrap(), vec![0]);
        assert!(q.pop_batch(4, Duration::ZERO, Duration::from_secs(1)).is_none());
    }

    /// Mirror of the loom transfer model: pops racing a close never lose
    /// an accepted job and never duplicate one.
    #[test]
    fn pop_batch_racing_close_drains_accepted_jobs_exactly_once() {
        for _ in 0..50 {
            let q = Arc::new(BatchQueue::new(64));
            let q2 = Arc::clone(&q);
            let consumer = std::thread::spawn(move || {
                let mut got = Vec::new();
                loop {
                    match q2.pop_batch(8, Duration::ZERO, Duration::from_secs(5)) {
                        Some(batch) => got.extend(batch),
                        None => return got,
                    }
                }
            });
            let mut accepted = Vec::new();
            for i in 0..20u64 {
                if q.push(i, Duration::ZERO).is_ok() {
                    accepted.push(i);
                }
            }
            q.close();
            let got = consumer.join().unwrap();
            assert_eq!(got, accepted, "accepted jobs must drain exactly once, in order");
        }
    }

    /// A thread that panics while holding the queue mutex must not turn
    /// every other thread's `unwrap()` into a panic: producers get the
    /// named poison error, consumers exit.
    #[test]
    fn poisoned_queue_is_a_named_error_not_a_panic_cascade() {
        let q = Arc::new(BatchQueue::new(4));
        q.push(0u64, Duration::from_secs(1)).unwrap();
        let q2 = Arc::clone(&q);
        let _ = std::thread::spawn(move || {
            let _guard = q2.state.lock().unwrap();
            panic!("deliberate poison");
        })
        .join();
        let err = q.push(1u64, Duration::from_secs(1)).unwrap_err();
        assert!(err.contains("panicked"), "unhelpful: {err}");
        assert!(q.pop_batch(4, Duration::ZERO, Duration::ZERO).is_none());
        assert_eq!(q.len(), 0, "a poisoned queue serves nothing");
    }
}
