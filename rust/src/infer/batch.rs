//! The cross-connection batching queue between handler threads and
//! inference workers.
//!
//! Handler threads decode inference requests and [`BatchQueue::push`] a
//! [`Job`] each; worker threads [`BatchQueue::pop_batch`] *everything
//! queued at once* (up to a cap, optionally lingering for a batching
//! window) and run the whole batch through one warm engine — the F+tree
//! base build and scratch buffers are paid per batch, not per query.
//! `std::sync::mpsc` is single-consumer, so the queue is a hand-rolled
//! bounded MPMC: a `Mutex<VecDeque>` with two condvars (`not_empty` for
//! workers, `not_full` for backpressure on handlers).
//!
//! Backpressure is explicit: when the queue is full past a deadline the
//! push fails with a named "server overloaded" error that travels back to
//! the client as a `Response::Err` — bounded memory under overload, never
//! an unbounded backlog.

use std::collections::VecDeque;
use std::sync::mpsc::SyncSender;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::wire::Response;

/// One queued inference request: the resolved token ids plus the reply
/// channel of the handler thread that owns the connection.
pub struct Job {
    pub tokens: Vec<u32>,
    pub sweeps: u32,
    pub seed: u64,
    /// rendezvous back to the handler; a handler that gave up waiting has
    /// dropped the receiver, and the worker's send simply no-ops
    pub reply: SyncSender<Response>,
}

struct QueueState {
    jobs: VecDeque<Job>,
    closed: bool,
}

/// Bounded multi-producer multi-consumer job queue.
pub struct BatchQueue {
    state: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

impl BatchQueue {
    pub fn new(cap: usize) -> BatchQueue {
        assert!(cap >= 1, "queue depth must be >= 1");
        BatchQueue {
            state: Mutex::new(QueueState { jobs: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap,
        }
    }

    /// Jobs currently queued (racy by nature; for stats reporting).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enqueue one job, blocking up to `deadline` for room.  Errors by
    /// name when the queue stays full past the deadline (overload
    /// backpressure) or the server is shutting down.
    pub fn push(&self, job: Job, deadline: Duration) -> Result<(), String> {
        let overloaded = || {
            format!(
                "server overloaded: inference queue held {} jobs for {deadline:?}",
                self.cap
            )
        };
        let t0 = Instant::now();
        let mut st = self.state.lock().unwrap();
        while st.jobs.len() >= self.cap && !st.closed {
            let left = match deadline.checked_sub(t0.elapsed()) {
                Some(left) if !left.is_zero() => left,
                _ => return Err(overloaded()),
            };
            st = self.not_full.wait_timeout(st, left).unwrap().0;
        }
        if st.closed {
            return Err("server shutting down: inference queue closed".into());
        }
        st.jobs.push_back(job);
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Take one batch: block up to `idle` for a first job, then drain
    /// whatever is queued — lingering up to `window` (if nonzero) while
    /// under `max` jobs, so concurrent connections pile into one batch.
    ///
    /// * `Some(jobs)` — a non-empty batch to run;
    /// * `Some(vec![])` — the idle timeout fired with nothing queued
    ///   (workers use this to re-check the model slot version);
    /// * `None` — the queue is closed *and* drained: the worker exits.
    pub fn pop_batch(&self, max: usize, window: Duration, idle: Duration) -> Option<Vec<Job>> {
        let max = max.max(1);
        let t0 = Instant::now();
        let mut st = self.state.lock().unwrap();
        while st.jobs.is_empty() {
            if st.closed {
                return None;
            }
            let left = match idle.checked_sub(t0.elapsed()) {
                Some(left) if !left.is_zero() => left,
                _ => return Some(Vec::new()),
            };
            st = self.not_empty.wait_timeout(st, left).unwrap().0;
        }
        let mut batch = Vec::with_capacity(st.jobs.len().min(max));
        let w0 = Instant::now();
        loop {
            while batch.len() < max {
                match st.jobs.pop_front() {
                    Some(job) => batch.push(job),
                    None => break,
                }
            }
            if batch.len() >= max || st.closed {
                break;
            }
            let left = match window.checked_sub(w0.elapsed()) {
                Some(left) if !left.is_zero() => left,
                _ => break,
            };
            st = self.not_empty.wait_timeout(st, left).unwrap().0;
        }
        drop(st);
        // up to `max` slots just freed — wake every blocked producer
        self.not_full.notify_all();
        Some(batch)
    }

    /// Close the queue: producers fail fast, consumers drain what is
    /// left and then get `None`.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;
    use std::sync::Arc;

    fn job(seed: u64) -> (Job, std::sync::mpsc::Receiver<Response>) {
        let (reply, rx) = sync_channel(1);
        (Job { tokens: vec![1, 2, 3], sweeps: 5, seed, reply }, rx)
    }

    #[test]
    fn push_then_pop_batches_everything_queued() {
        let q = BatchQueue::new(16);
        for i in 0..5 {
            let (j, _rx) = job(i);
            q.push(j, Duration::from_secs(1)).unwrap();
        }
        assert_eq!(q.len(), 5);
        let batch = q.pop_batch(3, Duration::ZERO, Duration::from_secs(1)).unwrap();
        assert_eq!(batch.len(), 3, "batch respects the max");
        assert_eq!(batch[0].seed, 0, "FIFO order");
        let batch = q.pop_batch(16, Duration::ZERO, Duration::from_secs(1)).unwrap();
        assert_eq!(batch.len(), 2);
        assert!(q.is_empty());
    }

    #[test]
    fn idle_timeout_returns_an_empty_batch_not_a_hang() {
        let q = BatchQueue::new(4);
        let t0 = Instant::now();
        let batch = q.pop_batch(8, Duration::ZERO, Duration::from_millis(30)).unwrap();
        assert!(batch.is_empty());
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn full_queue_backpressure_is_a_named_error() {
        let q = BatchQueue::new(2);
        let (j0, _r0) = job(0);
        let (j1, _r1) = job(1);
        let (j2, _r2) = job(2);
        q.push(j0, Duration::from_millis(10)).unwrap();
        q.push(j1, Duration::from_millis(10)).unwrap();
        let err = q.push(j2, Duration::from_millis(10)).unwrap_err();
        assert!(err.contains("overloaded"), "unhelpful: {err}");
        // a consumer frees room and a blocked push succeeds
        let q = Arc::new(q);
        let q2 = Arc::clone(&q);
        let popper = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            q2.pop_batch(1, Duration::ZERO, Duration::from_secs(1)).unwrap().len()
        });
        let (j3, _r3) = job(3);
        q.push(j3, Duration::from_secs(2)).unwrap();
        assert_eq!(popper.join().unwrap(), 1);
    }

    #[test]
    fn batching_window_collects_late_arrivals() {
        let q = Arc::new(BatchQueue::new(16));
        let (j0, _r0) = job(0);
        q.push(j0, Duration::from_secs(1)).unwrap();
        let q2 = Arc::clone(&q);
        let pusher = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            let (j1, r1) = job(1);
            q2.push(j1, Duration::from_secs(1)).unwrap();
            // keep the receiver alive until the pop below finishes
            std::thread::sleep(Duration::from_millis(300));
            drop(r1);
        });
        let batch = q
            .pop_batch(8, Duration::from_millis(250), Duration::from_secs(1))
            .unwrap();
        assert_eq!(batch.len(), 2, "the window must catch the late push");
        pusher.join().unwrap();
    }

    #[test]
    fn close_drains_then_terminates_consumers_and_fails_producers() {
        let q = BatchQueue::new(4);
        let (j0, _r0) = job(0);
        q.push(j0, Duration::from_secs(1)).unwrap();
        q.close();
        // queued work still drains
        let batch = q.pop_batch(4, Duration::ZERO, Duration::from_secs(1)).unwrap();
        assert_eq!(batch.len(), 1);
        // then consumers see the end, promptly even with a long idle
        let t0 = Instant::now();
        assert!(q.pop_batch(4, Duration::ZERO, Duration::from_secs(60)).is_none());
        assert!(t0.elapsed() < Duration::from_secs(5));
        // and producers fail by name
        let (j1, _r1) = job(1);
        let err = q.push(j1, Duration::from_secs(1)).unwrap_err();
        assert!(err.contains("shutting down"), "unhelpful: {err}");
    }
}
