//! The model query server (`serve-model`) and its client
//! (`infer --remote`): length-prefixed [`super::wire`] frames over TCP,
//! answered by a shared batching core.
//!
//! # Topology
//!
//! ```text
//! N handler threads ──decode──▶ BatchQueue ──▶ M worker threads
//!   (per-connection IO,          (bounded,       (one warm Inferencer
//!    caps, cache, admin)          MPMC)           per model lease)
//! ```
//!
//! Handler threads own connections: they decode requests, answer the
//! cheap ones inline (`ModelInfo`, `TopWords`, `Stats`, `ReloadModel`,
//! cache hits), and enqueue inference work as [`Job`]s.  Worker threads
//! drain *everything queued at once* and run the whole batch through one
//! warm engine — the F+tree base build and scratch buffers are amortized
//! across concurrent connections, not rebuilt per request.
//!
//! # Hot swap
//!
//! The served model lives in a [`ModelSlot`]: an atomically replaceable
//! `Arc<VersionedModel>`.  A `ReloadModel` admin request loads and
//! validates the new artifact *before* swapping, so a bad file is a named
//! error and the old model keeps serving.  Workers lease the current
//! `Arc` for a batch run and label every answer with the lease's version;
//! in-flight queries finish on whichever model they started on and no
//! response ever mixes versions.  The answer cache embeds the model
//! version in every key, so stale entries become unaddressable the
//! instant the swap lands.
//!
//! # Failure discipline
//!
//! A malformed request *body* (bad magic, version skew, unknown tag,
//! truncation) gets a named [`Response::Err`] and the session continues —
//! the length-prefix framing is still intact.  A broken *frame* layer
//! (oversized length, mid-frame truncation, reset, read deadline) gets a
//! best-effort `Err` response and the connection is dropped, because the
//! stream can no longer be resynchronized.  A client that connects and
//! goes silent is cut off by the configurable per-connection read
//! deadline ([`ServeConfig::read_deadline`]) with a named timeout error;
//! a full queue is a named "server overloaded" error, never an unbounded
//! backlog.  The server never panics on client input: both decoders are
//! total.

use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::path::Path;
// mpsc stays std under every cfg: it is the single-consumer rendezvous
// back to one handler thread, not one of the model-checked protocols
// (the loom suite covers BatchQueue/VersionedSlot; see util/sync docs)
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::corpus::text::{porter_stem, tokenize};
use crate::util::codec::{read_len_prefixed, read_len_prefixed_eof, write_len_prefixed};
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{lock_checked, lock_recover, Arc, Mutex};

use super::batch::BatchQueue;
use super::cache::{CacheKey, LruCache};
use super::config::{ClientConfig, ServeConfig};
use super::engine::{InferJob, InferOpts, Inferencer};
use super::model::TopicModel;
use super::stats::ServerStats;
use super::wire::{
    decode_request, decode_response, encode_request, encode_response, Request, Response,
    TopWord, MAX_QUERY_FRAME,
};

/// Cap on the fold-in sweeps one query may request (a hostile
/// `sweeps = u32::MAX` must not pin a worker thread).  Exceeding it is a
/// named error, never a silent clamp.
pub const MAX_QUERY_SWEEPS: u32 = 1_000;

/// Cap on tokens per query document.
pub const MAX_QUERY_TOKENS: usize = 1 << 20;

/// Cap on the `k` of one top-words query: `k = u32::MAX` against a wide
/// vocabulary would clone vocabulary-sized string lists per topic and
/// overflow the frame cap — reject it by name instead.
pub const MAX_QUERY_TOP_WORDS: u32 = 1_000;

/// Budget on total `T × k` entries of one top-words answer: keeps the
/// response comfortably under [`MAX_QUERY_FRAME`] even for models at the
/// maximum topic count, where a legal per-topic `k` alone would not.
pub const MAX_TOP_WORDS_ENTRIES: u64 = 1 << 19;

/// How often an *idle* worker re-checks the model slot for a hot swap
/// (a busy worker re-checks after every batch).
const VERSION_POLL: Duration = Duration::from_millis(500);

/// The close reason a dying worker leaves on the queue: every blocked and
/// subsequent push fails with this instead of a timeout or a poisoned
/// `unwrap()` cascade.
const WORKER_PANICKED: &str = "inference worker panicked; server shutting down";

/// One queued inference request: the resolved token ids plus the reply
/// channel of the handler thread that owns the connection.
pub struct Job {
    pub tokens: Vec<u32>,
    pub sweeps: u32,
    pub seed: u64,
    /// rendezvous back to the handler; a handler that gave up waiting has
    /// dropped the receiver, and the worker's send simply no-ops
    pub reply: mpsc::SyncSender<Response>,
}

/// A loaded model plus the word → id index raw-text queries resolve
/// against.  Immutable after construction — safe to share via `Arc`.
pub struct ModelHost {
    model: TopicModel,
    word_ids: HashMap<String, u32>,
}

impl ModelHost {
    pub fn new(model: TopicModel) -> ModelHost {
        let word_ids = model
            .vocab_words()
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i as u32))
            .collect();
        ModelHost { model, word_ids }
    }

    pub fn model(&self) -> &TopicModel {
        &self.model
    }

    /// Tokenize raw text (lowercased alphabetic runs, as in training
    /// preprocessing) and resolve each token against the model
    /// vocabulary: the Porter stem first (the default `build_corpus`
    /// pipeline), then the raw token (corpora built with `stem: false`).
    /// Membership in the vocabulary is the only filter — stop words and
    /// out-of-vocabulary terms miss it and drop naturally, whatever
    /// `PipelineOpts` the corpus was built with.  Errors when the
    /// artifact was exported without vocabulary strings.
    pub fn tokenize_text(&self, text: &str) -> Result<Vec<u32>, String> {
        if self.word_ids.is_empty() {
            return Err(
                "model carries no vocabulary strings; send token ids instead".into()
            );
        }
        let mut ids = Vec::new();
        for tok in tokenize(text) {
            let id = self
                .word_ids
                .get(&porter_stem(&tok))
                .or_else(|| self.word_ids.get(&tok));
            if let Some(&id) = id {
                ids.push(id);
            }
        }
        Ok(ids)
    }

    /// The `ModelInfo` answer, stamped with the caller's serving identity
    /// (`version` 0 marks a local, unserved answer).
    pub fn model_info(&self, model_version: u64, model_id: &str) -> Response {
        Response::ModelInfo {
            topics: self.model.num_topics() as u32,
            vocab: self.model.vocab() as u64,
            alpha: self.model.hyper().alpha,
            beta: self.model.hyper().beta,
            total_tokens: self.model.total_tokens(),
            has_vocab: !self.word_ids.is_empty(),
            model_version,
            model_id: model_id.to_string(),
        }
    }

    /// The `TopWords` answer, with both per-topic and total-entry caps
    /// enforced by name.
    pub fn top_words_response(&self, k: u32) -> Response {
        if k > MAX_QUERY_TOP_WORDS {
            return Response::Err(format!(
                "top-words k {k} exceeds the {MAX_QUERY_TOP_WORDS}-word cap"
            ));
        }
        let entries = k as u64 * self.model.num_topics() as u64;
        if entries > MAX_TOP_WORDS_ENTRIES {
            return Response::Err(format!(
                "top-words k {k} x T {} exceeds the {MAX_TOP_WORDS_ENTRIES}-entry \
                 answer budget",
                self.model.num_topics()
            ));
        }
        let k = (k as usize).min(self.model.vocab());
        let topics = self
            .model
            .top_words(k)
            .into_iter()
            .map(|row| {
                row.into_iter()
                    .map(|(word, count)| TopWord {
                        word,
                        count,
                        text: self
                            .model
                            .vocab_words()
                            .get(word as usize)
                            .cloned()
                            .unwrap_or_default(),
                    })
                    .collect()
            })
            .collect();
        Response::TopWords { topics }
    }

    /// One fold-in inference with the caps enforced by name, labeled with
    /// the model version that computed it.
    pub fn infer_response(
        &self,
        inf: &mut Inferencer<'_>,
        tokens: &[u32],
        sweeps: u32,
        seed: u64,
        model_version: u64,
    ) -> Response {
        if tokens.len() > MAX_QUERY_TOKENS {
            return Response::Err(format!(
                "query document of {} tokens exceeds the {MAX_QUERY_TOKENS}-token cap",
                tokens.len()
            ));
        }
        if sweeps > MAX_QUERY_SWEEPS {
            return Response::Err(format!(
                "{sweeps} sweeps exceeds the {MAX_QUERY_SWEEPS}-sweep cap per query"
            ));
        }
        let opts = InferOpts { sweeps: sweeps as usize, seed };
        match inf.infer_doc(tokens, &opts) {
            Ok(res) => Response::Theta {
                theta: res.theta,
                used_tokens: tokens.len() as u32,
                model_version,
            },
            Err(e) => Response::Err(e),
        }
    }

    /// Answer one request with a caller-owned per-thread engine — the
    /// *local* (unserved) dispatch used by `infer` without `--remote`.
    /// Answers carry model version 0; the admin requests (`Stats`,
    /// `ReloadModel`) are server concepts and error by name here.
    pub fn answer_with(&self, inf: &mut Inferencer<'_>, req: Request) -> Response {
        match req {
            Request::ModelInfo => {
                self.model_info(0, &format!("local@{:016x}", self.model.fingerprint()))
            }
            Request::TopWords { k } => self.top_words_response(k),
            Request::InferTokens { tokens, sweeps, seed } => {
                self.infer_response(inf, &tokens, sweeps, seed, 0)
            }
            Request::InferText { text, sweeps, seed } => match self.tokenize_text(&text) {
                Ok(tokens) => self.infer_response(inf, &tokens, sweeps, seed, 0),
                Err(e) => Response::Err(e),
            },
            Request::Stats => Response::Err(
                "stats are serving counters; query a running serve-model process".into(),
            ),
            Request::ReloadModel { .. } => Response::Err(
                "reload is an admin request to a running serve-model process".into(),
            ),
        }
    }

    /// Convenience single-shot answer (builds a throwaway engine).
    pub fn answer(&self, req: Request) -> Response {
        let mut inf = Inferencer::new(&self.model);
        self.answer_with(&mut inf, req)
    }
}

/// Human-readable serving identity for an artifact: the file stem plus
/// the model's content fingerprint, `stem@0123456789abcdef`.
pub fn model_id_for(path: &Path, model: &TopicModel) -> String {
    let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("model");
    format!("{stem}@{:016x}", model.fingerprint())
}

/// One immutable generation of a swappable value (for serving: the
/// loaded model).
pub struct Versioned<T> {
    pub value: T,
    /// 1 for the initially loaded generation, bumped by every swap
    pub version: u64,
    /// `stem@fingerprint` identity of the artifact
    pub id: String,
}

/// One immutable generation of the served model.
pub type VersionedModel = Versioned<ModelHost>;

/// The atomically swappable holder — generic so the lease/re-lease
/// protocol is model-checked in `rust/tests/loom_models.rs` with a cheap
/// payload, served as [`ModelSlot`] in production.
///
/// `load` hands out a cheap `Arc` lease: readers keep whatever generation
/// they leased for as long as they hold it (in-flight queries finish on
/// the model they started on), while `swap` makes every *subsequent*
/// lease see the new generation.  The separate atomic `version` lets hot
/// paths ask "did anything change?" without touching the mutex.
///
/// The hint discipline: `swap` stores the hint *inside* the critical
/// section, after publishing the new `Arc`, so (a) hint values are
/// serialized by the lock and strictly monotone, and (b) a reader that
/// observes hint `v` and then takes the lock is guaranteed a lease with
/// `version >= v` — the hint never runs ahead of what `load` returns.
pub struct VersionedSlot<T> {
    current: Mutex<Arc<Versioned<T>>>,
    version_hint: AtomicU64,
}

/// The atomically swappable model holder (see [`VersionedSlot`]).
pub type ModelSlot = VersionedSlot<ModelHost>;

impl<T> VersionedSlot<T> {
    /// Wrap the initially loaded value as version 1.
    pub fn new(value: T, id: String) -> VersionedSlot<T> {
        VersionedSlot {
            current: Mutex::new(Arc::new(Versioned { value, version: 1, id })),
            version_hint: AtomicU64::new(1),
        }
    }

    /// Lease the current generation.
    ///
    /// Poison-tolerant by construction: both critical sections (here and
    /// in [`VersionedSlot::swap`]) are single indivisible assignments, so
    /// the guarded `Arc` is always a complete generation even if a thread
    /// panicked while holding the lock.
    pub fn load(&self) -> Arc<Versioned<T>> {
        Arc::clone(&lock_recover(&self.current))
    }

    /// The current generation number, lock-free.
    pub fn version(&self) -> u64 {
        // Acquire pairs with the Release store in `swap`: a reader that
        // sees version v also sees every write that preceded publishing
        // generation v, even on a path that never takes the lock
        self.version_hint.load(Ordering::Acquire)
    }

    /// Publish a new generation; returns its version number.  Existing
    /// leases are untouched — the old `Arc` frees when its last in-flight
    /// reader drops it.
    pub fn swap(&self, value: T, id: String) -> u64 {
        let mut cur = lock_recover(&self.current);
        let version = cur.version + 1;
        *cur = Arc::new(Versioned { value, version, id });
        // Release (paired with the Acquire in `version`), stored while
        // the lock is held — see the hint discipline in the type docs
        self.version_hint.store(version, Ordering::Release);
        version
    }
}

/// Everything the handler and worker threads share.
struct ServeCore {
    slot: Arc<ModelSlot>,
    cfg: ServeConfig,
    stats: ServerStats,
    queue: BatchQueue<Job>,
    /// `None` when `cache_capacity` is 0
    cache: Option<Mutex<LruCache<CacheKey, Response>>>,
}

impl ServeCore {
    fn new(slot: Arc<ModelSlot>, cfg: ServeConfig) -> ServeCore {
        let cache = (cfg.cache_capacity > 0)
            .then(|| Mutex::new(LruCache::new(cfg.cache_capacity)));
        let queue = BatchQueue::new(cfg.queue_depth);
        ServeCore { slot, cfg, stats: ServerStats::new(), queue, cache }
    }

    /// Cache lookup; records the hit/miss (only when the cache exists).
    /// A poisoned cache (a panic inside a lookup/insert) silently stops
    /// caching — inference still answers, just uncached.
    fn cache_get(&self, key: &CacheKey) -> Option<Response> {
        let cache = self.cache.as_ref()?;
        let mut cache = lock_checked(cache).ok()?;
        let hit = cache.get(key);
        drop(cache);
        self.stats.record_cache(hit.is_some());
        hit
    }

    fn cache_put(&self, key: CacheKey, resp: &Response) {
        if let Some(Ok(mut cache)) = self.cache.as_ref().map(lock_checked) {
            cache.insert(key, resp.clone());
        }
    }

    /// Dispatch one decoded request.
    fn answer_request(&self, req: Request) -> Response {
        match req {
            Request::ModelInfo => {
                let vm = self.slot.load();
                vm.value.model_info(vm.version, &vm.id)
            }
            Request::TopWords { k } => {
                let vm = self.slot.load();
                let key = CacheKey::TopWords { k, model_version: vm.version };
                if let Some(hit) = self.cache_get(&key) {
                    return hit;
                }
                let resp = vm.value.top_words_response(k);
                if !matches!(resp, Response::Err(_)) {
                    self.cache_put(key, &resp);
                }
                resp
            }
            Request::InferTokens { tokens, sweeps, seed } => {
                self.infer_via_queue(tokens, sweeps, seed)
            }
            Request::InferText { text, sweeps, seed } => {
                // tokenized against the generation current at decode time;
                // a swap racing this request resolves ids on the old vocab
                // and folds in on the new, exactly like any in-flight query
                match self.slot.load().value.tokenize_text(&text) {
                    Ok(tokens) => self.infer_via_queue(tokens, sweeps, seed),
                    Err(e) => Response::Err(e),
                }
            }
            Request::Stats => {
                let depth = self.queue.len() as u64;
                let report = self.stats.report(
                    depth,
                    self.cfg.queue_depth as u64,
                    self.cfg.max_batch as u64,
                    self.slot.version(),
                );
                let reg = crate::obs::registry::global();
                reg.gauge("serve.queue_depth").set(depth);
                reg.gauge("serve.queue_cap").set(self.cfg.queue_depth as u64);
                reg.gauge("serve.batch_fill_permille")
                    .set((report.batch_fill * 1000.0) as u64);
                Response::Stats(report)
            }
            Request::ReloadModel { path } => self.reload_model(&path),
        }
    }

    /// The inference path: caps → cache → queue → rendezvous.
    fn infer_via_queue(&self, tokens: Vec<u32>, sweeps: u32, seed: u64) -> Response {
        if tokens.len() > MAX_QUERY_TOKENS {
            return Response::Err(format!(
                "query document of {} tokens exceeds the {MAX_QUERY_TOKENS}-token cap",
                tokens.len()
            ));
        }
        if sweeps > MAX_QUERY_SWEEPS {
            return Response::Err(format!(
                "{sweeps} sweeps exceeds the {MAX_QUERY_SWEEPS}-sweep cap per query"
            ));
        }
        let key = CacheKey::theta(&tokens, sweeps, seed, self.slot.version());
        if let Some(hit) = self.cache_get(&key) {
            return hit;
        }
        let (reply, rx) = mpsc::sync_channel(1);
        if let Err(e) =
            self.queue.push(Job { tokens, sweeps, seed, reply }, self.cfg.answer_deadline)
        {
            return Response::Err(e);
        }
        let resp = match rx.recv_timeout(self.cfg.answer_deadline) {
            Ok(resp) => resp,
            Err(_) => {
                return Response::Err(format!(
                    "inference workers gave no answer within {:?}",
                    self.cfg.answer_deadline
                ))
            }
        };
        // cache under the version that *actually* answered (a swap may
        // have landed between the lookup above and the worker's run)
        if let Response::Theta { model_version, .. } = &resp {
            if let CacheKey::Theta { tokens, sweeps, seed, .. } = key {
                self.cache_put(
                    CacheKey::Theta { tokens, sweeps, seed, model_version: *model_version },
                    &resp,
                );
            }
        }
        resp
    }

    /// Load + validate the new artifact, then swap.  Failures leave the
    /// old model serving, by name.
    fn reload_model(&self, path: &str) -> Response {
        let path = Path::new(path);
        match TopicModel::load(path) {
            Ok(model) => {
                let id = model_id_for(path, &model);
                let topics = model.num_topics() as u32;
                let vocab = model.vocab() as u64;
                let model_version = self.slot.swap(ModelHost::new(model), id.clone());
                self.stats.record_swap();
                Response::Reloaded { model_version, model_id: id, topics, vocab }
            }
            Err(e) => Response::Err(format!("reload failed, serving unchanged: {e}")),
        }
    }
}

/// Armed for the lifetime of a worker: if the worker panics (a bug — the
/// decoders are total, so client input cannot get here), the queue is
/// closed by name so handlers get "worker panicked" errors instead of a
/// rendezvous that times out or a poisoned-mutex `unwrap()` cascade.
struct WorkerPanicGuard<'a>(&'a ServeCore);

impl Drop for WorkerPanicGuard<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.queue.close_named(WORKER_PANICKED);
        }
    }
}

/// One worker: lease the current model, drain batches through a warm
/// engine, re-lease when the slot version moves.  After a swap a worker
/// finishes at most the batch it already drained on the old lease (its
/// answers are labeled with that lease's version), then rebuilds.
fn worker_loop(core: &ServeCore) {
    let _guard = WorkerPanicGuard(core);
    loop {
        let vm = core.slot.load();
        let mut inf = Inferencer::new(vm.value.model());
        loop {
            let batch = match core.queue.pop_batch(
                core.cfg.max_batch,
                core.cfg.batch_window,
                VERSION_POLL,
            ) {
                None => return,
                Some(batch) => batch,
            };
            if batch.is_empty() {
                // idle poll tick: rebuild only if a swap landed
                if core.slot.version() != vm.version {
                    break;
                }
                continue;
            }
            core.stats.record_batch(batch.len() as u64);
            let mut replies = Vec::with_capacity(batch.len());
            let jobs: Vec<InferJob> = batch
                .into_iter()
                .map(|job| {
                    replies.push((job.reply, job.tokens.len() as u32));
                    InferJob {
                        tokens: job.tokens,
                        opts: InferOpts { sweeps: job.sweeps as usize, seed: job.seed },
                    }
                })
                .collect();
            let results = inf.infer_jobs(&jobs);
            for ((reply, used_tokens), res) in replies.into_iter().zip(results) {
                let resp = match res {
                    Ok(r) => Response::Theta {
                        theta: r.theta,
                        used_tokens,
                        model_version: vm.version,
                    },
                    Err(e) => Response::Err(e),
                };
                // a handler that gave up waiting dropped its receiver;
                // the answer is simply discarded
                let _ = reply.try_send(resp);
            }
            if core.slot.version() != vm.version {
                break;
            }
        }
    }
}

/// Consecutive `accept` failures after which a handler thread gives up
/// (a persistently broken listener, not load-induced churn).
const MAX_ACCEPT_FAILURES: u32 = 100;

/// Serve query traffic on `listener` from the model in `slot`.
///
/// With `cfg.once`, exactly one connection is handled on the calling
/// thread and its session error (if any) becomes this call's error — the
/// CLI/CI exit-code mode.  Otherwise `cfg.threads` handler threads accept
/// and serve connections until the process exits; session errors are
/// logged, never fatal, and transient `accept` failures (ECONNABORTED, fd
/// exhaustion under load) are backed off and retried rather than draining
/// handler capacity.  Only a persistently failing listener ends the call —
/// as an `Err`, so supervisors see a non-zero exit.  In both modes
/// `cfg.workers` inference workers drain the shared batch queue and are
/// joined before returning.
pub fn serve_model(
    listener: TcpListener,
    slot: Arc<ModelSlot>,
    cfg: &ServeConfig,
) -> Result<(), String> {
    cfg.validate()?;
    let core = Arc::new(ServeCore::new(slot, cfg.clone()));
    let mut workers = Vec::new();
    for _ in 0..cfg.workers {
        let core = Arc::clone(&core);
        workers.push(std::thread::spawn(move || worker_loop(&core)));
    }
    let result = serve_accept(listener, &core);
    core.queue.close();
    for w in workers {
        let _ = w.join();
    }
    result
}

fn serve_accept(listener: TcpListener, core: &Arc<ServeCore>) -> Result<(), String> {
    if core.cfg.once {
        let (stream, peer) = listener.accept().map_err(|e| format!("accept failed: {e}"))?;
        if !core.cfg.quiet {
            crate::log_event!(Info, "serve-model", "client connected from {peer}");
        }
        return handle_conn(stream, core);
    }
    let mut handles = Vec::new();
    for _ in 0..core.cfg.threads {
        let listener = listener.try_clone().map_err(|e| format!("listener clone failed: {e}"))?;
        let core = Arc::clone(core);
        handles.push(std::thread::spawn(move || -> Result<(), String> {
            let mut failures = 0u32;
            loop {
                match listener.accept() {
                    Ok((stream, peer)) => {
                        failures = 0;
                        if !core.cfg.quiet {
                            crate::log_event!(Info, "serve-model", "client connected from {peer}");
                        }
                        if let Err(e) = handle_conn(stream, &core) {
                            crate::log_event!(Warn, "serve-model", "session error: {e}");
                        }
                    }
                    Err(e) => {
                        failures += 1;
                        crate::log_event!(
                            Warn,
                            "serve-model",
                            { failures = failures },
                            "accept failed ({failures}): {e}"
                        );
                        if failures >= MAX_ACCEPT_FAILURES {
                            return Err(format!("accept failing persistently: {e}"));
                        }
                        std::thread::sleep(Duration::from_millis(50));
                    }
                }
            }
        }));
    }
    let mut first_err = None;
    for h in handles {
        match h.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => first_err = first_err.or(Some(e)),
            Err(_) => first_err = first_err.or(Some("handler thread panicked".to_string())),
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Serve one connection until the client closes it.
fn handle_conn(stream: TcpStream, core: &ServeCore) -> Result<(), String> {
    stream.set_nodelay(true).map_err(|e| e.to_string())?;
    // read deadline: a silent client must not pin this handler thread
    stream
        .set_read_timeout(Some(core.cfg.read_deadline))
        .map_err(|e| e.to_string())?;
    let mut reader =
        BufReader::new(stream.try_clone().map_err(|e| format!("socket clone failed: {e}"))?);
    let mut writer = BufWriter::new(stream);
    loop {
        let body = match read_len_prefixed_eof(&mut reader, MAX_QUERY_FRAME) {
            // orderly close between requests: the normal end of session
            Ok(None) => return Ok(()),
            Ok(Some(body)) => body,
            Err(e) => {
                // frame layer broken (oversized length, mid-frame
                // truncation, reset, read deadline): the stream cannot be
                // resynced — name the fault and drop the connection
                let _ = send_response(&mut writer, &Response::Err(e.clone()));
                return Err(e);
            }
        };
        let t0 = Instant::now();
        let (resp, is_infer) = match decode_request(&body) {
            Ok(req) => {
                let is_infer = matches!(
                    req,
                    Request::InferTokens { .. } | Request::InferText { .. }
                );
                (core.answer_request(req), is_infer)
            }
            // body-level malformation: framing is intact, so report the
            // named error and keep the session alive
            Err(e) => (Response::Err(format!("bad request: {e}")), false),
        };
        let is_err = matches!(resp, Response::Err(_));
        send_response(&mut writer, &resp)?;
        core.stats.record_request(t0.elapsed(), is_infer, is_err);
    }
}

fn send_response<W: Write>(w: &mut W, resp: &Response) -> Result<(), String> {
    write_len_prefixed(w, &encode_response(resp), MAX_QUERY_FRAME)
}

// ----------------------------------------------------------------- client

/// One client connection to a `serve-model` host; reusable for any number
/// of queries.  Build with [`Client::connect`] for the defaults or
/// [`ClientConfig::connect`] for tuned timeouts.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    /// Connect with the default [`ClientConfig`] knobs.
    pub fn connect(addr: &str) -> Result<Client, String> {
        Client::connect_with(&ClientConfig::new(addr))
    }

    /// Connect with explicit knobs (see [`ClientConfig`] for what each
    /// deadline protects against).
    pub fn connect_with(cfg: &ClientConfig) -> Result<Client, String> {
        let addr = cfg.addr.as_str();
        let sock = addr
            .to_socket_addrs()
            .map_err(|e| format!("resolve {addr}: {e}"))?
            .next()
            .ok_or_else(|| format!("resolve {addr}: no addresses"))?;
        let stream = TcpStream::connect_timeout(&sock, cfg.connect_timeout)
            .map_err(|e| format!("connect {addr}: {e}"))?;
        stream.set_nodelay(true).map_err(|e| e.to_string())?;
        stream.set_read_timeout(Some(cfg.answer_timeout)).map_err(|e| e.to_string())?;
        let reader =
            BufReader::new(stream.try_clone().map_err(|e| format!("socket clone failed: {e}"))?);
        Ok(Client { reader, writer: BufWriter::new(stream) })
    }

    /// Send one request and read its answer.
    pub fn query(&mut self, req: &Request) -> Result<Response, String> {
        write_len_prefixed(&mut self.writer, &encode_request(req), MAX_QUERY_FRAME)?;
        decode_response(&read_len_prefixed(&mut self.reader, MAX_QUERY_FRAME)?)
    }
}

/// One-shot convenience: connect, query, disconnect.
pub fn query_one(addr: &str, req: &Request) -> Result<Response, String> {
    Client::connect(addr)?.query(req)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::text::{build_corpus, PipelineOpts};
    use crate::lda::state::{Hyper, LdaState};
    use crate::lda::{FLdaWord, Sweep};
    use crate::util::rng::Pcg32;

    /// A tiny *textual* corpus so the vocab-strings path is real.
    fn text_model() -> TopicModel {
        let texts: Vec<String> = [
            "the cat sat on the mat and the cat purred",
            "dogs chase cats and cats chase mice in the yard",
            "stock markets rallied as traders bought shares",
            "the market fell while investors sold stock shares",
            "cats and dogs are pets while mice hide",
            "shares of the company rallied on strong markets",
            "a cat and a dog fought over the mat",
            "traders watch the stock market every day",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let corpus = build_corpus(
            &texts,
            &PipelineOpts { min_count: 2, min_docs: 2, ..Default::default() },
            "text-tiny",
        );
        let mut rng = Pcg32::seeded(5);
        let mut state = LdaState::init_random(&corpus, Hyper::paper_default(4), &mut rng);
        let mut sweeper = FLdaWord::new(&state, &corpus);
        for _ in 0..15 {
            sweeper.sweep(&mut state, &corpus, &mut rng);
        }
        TopicModel::from_state(&state, corpus.vocab_words().to_vec())
    }

    #[test]
    fn host_answers_every_request_kind() {
        let host = ModelHost::new(text_model());
        let t = host.model().num_topics();
        match host.answer(Request::ModelInfo) {
            Response::ModelInfo {
                topics,
                vocab,
                has_vocab,
                total_tokens,
                model_version,
                model_id,
                ..
            } => {
                assert_eq!(topics as usize, t);
                assert_eq!(vocab as usize, host.model().vocab());
                assert!(has_vocab);
                assert!(total_tokens > 0);
                assert_eq!(model_version, 0, "local answers carry version 0");
                assert!(model_id.starts_with("local@"), "odd local id: {model_id}");
            }
            other => panic!("wrong answer: {other:?}"),
        }
        match host.answer(Request::TopWords { k: 3 }) {
            Response::TopWords { topics } => {
                assert_eq!(topics.len(), t);
                for row in &topics {
                    assert!(row.len() <= 3);
                    for w in row {
                        assert!(!w.text.is_empty(), "vocab model must resolve strings");
                    }
                }
            }
            other => panic!("wrong answer: {other:?}"),
        }
        match host.answer(Request::InferText {
            text: "the cat sat with the dog".into(),
            sweeps: 10,
            seed: 1,
        }) {
            Response::Theta { theta, used_tokens, model_version } => {
                assert_eq!(theta.len(), t);
                assert!(used_tokens > 0, "every query word was dropped");
                assert_eq!(model_version, 0);
                let sum: f64 = theta.iter().sum();
                assert!((sum - 1.0).abs() < 1e-9);
            }
            other => panic!("wrong answer: {other:?}"),
        }
    }

    #[test]
    fn admin_requests_are_named_errors_locally() {
        let host = ModelHost::new(text_model());
        match host.answer(Request::Stats) {
            Response::Err(e) => assert!(e.contains("serve-model"), "unhelpful: {e}"),
            other => panic!("expected Err, got {other:?}"),
        }
        match host.answer(Request::ReloadModel { path: "/tmp/x.fnmodel".into() }) {
            Response::Err(e) => assert!(e.contains("admin"), "unhelpful: {e}"),
            other => panic!("expected Err, got {other:?}"),
        }
    }

    #[test]
    fn oov_and_bad_queries_are_named_errors() {
        let host = ModelHost::new(text_model());
        let vocab = host.model().vocab() as u32;
        match host.answer(Request::InferTokens { tokens: vec![0, vocab], sweeps: 5, seed: 0 }) {
            Response::Err(e) => assert!(e.contains("vocabulary"), "unhelpful: {e}"),
            other => panic!("expected Err, got {other:?}"),
        }
        // a model without vocab strings rejects text queries by name
        let corpus = crate::corpus::presets::preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(2);
        let state = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        let anon = ModelHost::new(TopicModel::from_state(&state, Vec::new()));
        match anon.answer(Request::InferText { text: "hello".into(), sweeps: 1, seed: 0 }) {
            Response::Err(e) => assert!(e.contains("vocabulary strings"), "unhelpful: {e}"),
            other => panic!("expected Err, got {other:?}"),
        }
    }

    #[test]
    fn tokenizer_matches_training_pipeline() {
        let host = ModelHost::new(text_model());
        // "cats" stems to "cat" — must resolve to the same id
        let a = host.tokenize_text("cats").unwrap();
        let b = host.tokenize_text("cat").unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 1);
        // stop words and OOV terms drop silently
        let ids = host.tokenize_text("the and zzzunknownzzz").unwrap();
        assert!(ids.is_empty());
    }

    #[test]
    fn hostile_top_words_requests_are_a_named_error() {
        let host = ModelHost::new(text_model());
        match host.answer(Request::TopWords { k: u32::MAX }) {
            Response::Err(e) => assert!(e.contains("cap"), "unhelpful: {e}"),
            other => panic!("expected Err, got {other:?}"),
        }
    }

    #[test]
    fn hostile_sweep_counts_are_a_named_error_not_a_stall() {
        let host = ModelHost::new(text_model());
        // must return promptly — and honestly — despite the absurd request
        match host.answer(Request::InferTokens { tokens: vec![0], sweeps: u32::MAX, seed: 0 }) {
            Response::Err(e) => assert!(e.contains("sweep cap"), "unhelpful: {e}"),
            other => panic!("expected Err, got {other:?}"),
        }
        // the cap itself is inclusive
        match host.answer(Request::InferTokens {
            tokens: vec![0],
            sweeps: MAX_QUERY_SWEEPS,
            seed: 0,
        }) {
            Response::Theta { .. } => {}
            other => panic!("expected Theta at the cap, got {other:?}"),
        }
    }

    #[test]
    fn model_slot_versions_and_leases() {
        let slot = ModelSlot::new(ModelHost::new(text_model()), "a@1".into());
        assert_eq!(slot.version(), 1);
        let lease = slot.load();
        assert_eq!(lease.version, 1);
        assert_eq!(lease.id, "a@1");
        let v2 = slot.swap(ModelHost::new(text_model()), "b@2".into());
        assert_eq!(v2, 2);
        assert_eq!(slot.version(), 2);
        // the old lease is untouched; a fresh one sees the new generation
        assert_eq!(lease.version, 1);
        assert_eq!(slot.load().version, 2);
        assert_eq!(slot.load().id, "b@2");
    }

    /// The batching core end to end, without TCP: handler-side dispatch
    /// into the queue, a real worker loop answering, cache hits on
    /// repeats, stats accumulating.
    #[test]
    fn batching_core_answers_and_caches() {
        let slot = Arc::new(ModelSlot::new(ModelHost::new(text_model()), "m@0".into()));
        let core = Arc::new(ServeCore::new(
            Arc::clone(&slot),
            ServeConfig::default().workers(1).cache_capacity(64),
        ));
        let worker = {
            let core = Arc::clone(&core);
            std::thread::spawn(move || worker_loop(&core))
        };
        let req = Request::InferTokens { tokens: vec![0, 1, 2, 1], sweeps: 8, seed: 3 };
        let a = core.answer_request(req.clone());
        let b = core.answer_request(req);
        match (&a, &b) {
            (
                Response::Theta { theta: ta, model_version: va, .. },
                Response::Theta { theta: tb, model_version: vb, .. },
            ) => {
                assert_eq!(ta, tb, "cache hit must replay the same answer");
                assert_eq!((*va, *vb), (1, 1));
            }
            other => panic!("expected two Thetas, got {other:?}"),
        }
        // a permutation of the same bag is the same cache entry
        let c = core.answer_request(Request::InferTokens {
            tokens: vec![1, 1, 2, 0],
            sweeps: 8,
            seed: 3,
        });
        assert_eq!(c, a, "multiset key must make permutations hit");
        let r = core.stats.report(
            core.queue.len() as u64,
            core.cfg.queue_depth as u64,
            core.cfg.max_batch as u64,
            slot.version(),
        );
        assert_eq!(r.cache_hits, 2);
        assert_eq!(r.cache_misses, 1);
        assert!(r.batches >= 1 && r.batched_docs >= 1);
        assert_eq!(r.queue_cap, core.cfg.queue_depth as u64);
        assert!(r.batch_fill > 0.0 && r.batch_fill <= 1.0, "batch_fill = {}", r.batch_fill);
        core.queue.close();
        worker.join().unwrap();
    }

    /// Regression for the lock-poisoning fragility: a worker that panics
    /// must convert into named "worker panicked" errors on the handler
    /// path — not a poisoned-mutex `unwrap()` cascade, not a silent
    /// rendezvous timeout.
    #[test]
    fn panicking_worker_yields_named_errors_not_a_panic_cascade() {
        let slot = Arc::new(ModelSlot::new(ModelHost::new(text_model()), "m@0".into()));
        let core = Arc::new(ServeCore::new(
            Arc::clone(&slot),
            ServeConfig::default().workers(1),
        ));
        let worker = {
            let core = Arc::clone(&core);
            std::thread::spawn(move || {
                let _guard = WorkerPanicGuard(&core);
                panic!("deliberate worker bug");
            })
        };
        assert!(worker.join().is_err(), "the worker must have panicked");
        // every subsequent inference is refused by name, promptly
        let t0 = Instant::now();
        let resp = core.answer_request(Request::InferTokens {
            tokens: vec![0, 1],
            sweeps: 2,
            seed: 0,
        });
        match resp {
            Response::Err(e) => assert!(e.contains("worker panicked"), "unhelpful: {e}"),
            other => panic!("expected a named error, got {other:?}"),
        }
        assert!(
            t0.elapsed() < core.cfg.answer_deadline,
            "the refusal must be fail-fast, not an answer-deadline timeout"
        );
        // cheap requests that bypass the queue still answer
        match core.answer_request(Request::ModelInfo) {
            Response::ModelInfo { model_version, .. } => assert_eq!(model_version, 1),
            other => panic!("expected ModelInfo, got {other:?}"),
        }
    }

    /// The slot's critical sections are single assignments, so a panic
    /// while the lock is held must not take down lease/swap.
    #[test]
    fn poisoned_slot_still_leases_and_swaps() {
        let slot = Arc::new(ModelSlot::new(ModelHost::new(text_model()), "a@1".into()));
        let s2 = Arc::clone(&slot);
        let _ = std::thread::spawn(move || {
            let _guard = s2.current.lock().unwrap();
            panic!("deliberate poison");
        })
        .join();
        assert_eq!(slot.load().version, 1, "the lease survives the poison");
        assert_eq!(slot.swap(ModelHost::new(text_model()), "b@2".into()), 2);
        assert_eq!(slot.load().version, 2);
        assert_eq!(slot.version(), 2);
    }

    /// A poisoned answer cache degrades to a cache-less server: queries
    /// still answer, nothing panics.
    #[test]
    fn poisoned_cache_degrades_to_uncached_answers() {
        let slot = Arc::new(ModelSlot::new(ModelHost::new(text_model()), "m@0".into()));
        let core = Arc::new(ServeCore::new(
            Arc::clone(&slot),
            ServeConfig::default().workers(1).cache_capacity(64),
        ));
        let c2 = Arc::clone(&core);
        let _ = std::thread::spawn(move || {
            let _guard = c2.cache.as_ref().unwrap().lock().unwrap();
            panic!("deliberate poison");
        })
        .join();
        let worker = {
            let core = Arc::clone(&core);
            std::thread::spawn(move || worker_loop(&core))
        };
        let req = Request::InferTokens { tokens: vec![0, 1, 2], sweeps: 4, seed: 9 };
        match core.answer_request(req) {
            Response::Theta { model_version, .. } => assert_eq!(model_version, 1),
            other => panic!("expected Theta despite the poisoned cache, got {other:?}"),
        }
        core.queue.close();
        worker.join().unwrap();
    }
}
