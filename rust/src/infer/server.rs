//! The model query server (`serve-model`) and its client
//! (`infer --remote`): length-prefixed [`super::wire`] frames over TCP,
//! answered by a shared, immutable [`ModelHost`].
//!
//! # Topology
//!
//! The model is loaded **once** and shared read-only across N handler
//! threads; each accepted connection is served by one thread with its own
//! per-thread [`Inferencer`] (the F+tree and scratch buffers are reused
//! across that connection's requests).  A connection carries any number
//! of request/response pairs until the client closes it.
//!
//! # Failure discipline
//!
//! A malformed request *body* (bad magic, version skew, unknown tag,
//! truncation) gets a named [`Response::Err`] and the session continues —
//! the length-prefix framing is still intact.  A broken *frame* layer
//! (oversized length, mid-frame truncation, reset, idle timeout) gets a
//! best-effort `Err` response and the connection is dropped, because the
//! stream can no longer be resynchronized.  A client that connects and
//! goes silent is cut off by a per-connection idle read deadline rather
//! than pinning a handler thread; oversized sweep/token requests are
//! named errors, never silent clamps.  The server never panics on client
//! input: both decoders are total.

use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::Arc;
use std::time::Duration;

use crate::corpus::text::{porter_stem, tokenize};
use crate::util::codec::{read_len_prefixed, read_len_prefixed_eof, write_len_prefixed};

use super::engine::{InferOpts, Inferencer};
use super::model::TopicModel;
use super::wire::{
    decode_request, decode_response, encode_request, encode_response, Request, Response,
    TopWord, MAX_QUERY_FRAME,
};

/// Cap on the fold-in sweeps one query may request (a hostile
/// `sweeps = u32::MAX` must not pin a handler thread).  Exceeding it is a
/// named error, never a silent clamp.
pub const MAX_QUERY_SWEEPS: u32 = 1_000;

/// Cap on tokens per query document.
pub const MAX_QUERY_TOKENS: usize = 1 << 20;

/// Cap on the `k` of one top-words query: `k = u32::MAX` against a wide
/// vocabulary would clone vocabulary-sized string lists per topic and
/// overflow the frame cap — reject it by name instead.
pub const MAX_QUERY_TOP_WORDS: u32 = 1_000;

/// Budget on total `T × k` entries of one top-words answer: keeps the
/// response comfortably under [`MAX_QUERY_FRAME`] even for models at the
/// maximum topic count, where a legal per-topic `k` alone would not.
pub const MAX_TOP_WORDS_ENTRIES: u64 = 1 << 19;

/// How long the client waits for a connection.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(30);

/// How long the client waits for an answer: sized for the slowest
/// *legal* request (a MAX_QUERY_TOKENS document at MAX_QUERY_SWEEPS), so
/// no within-cap query is un-servable through the bundled client.
const ANSWER_TIMEOUT: Duration = Duration::from_secs(600);

/// Server-side idle deadline per connection: a client that connects and
/// goes silent may not pin a handler thread forever.
const IDLE_TIMEOUT: Duration = Duration::from_secs(300);

/// A loaded model plus the word → id index raw-text queries resolve
/// against.  Immutable after construction — safe to share via `Arc`.
pub struct ModelHost {
    model: TopicModel,
    word_ids: HashMap<String, u32>,
}

impl ModelHost {
    pub fn new(model: TopicModel) -> ModelHost {
        let word_ids = model
            .vocab_words()
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i as u32))
            .collect();
        ModelHost { model, word_ids }
    }

    pub fn model(&self) -> &TopicModel {
        &self.model
    }

    /// Tokenize raw text (lowercased alphabetic runs, as in training
    /// preprocessing) and resolve each token against the model
    /// vocabulary: the Porter stem first (the default `build_corpus`
    /// pipeline), then the raw token (corpora built with `stem: false`).
    /// Membership in the vocabulary is the only filter — stop words and
    /// out-of-vocabulary terms miss it and drop naturally, whatever
    /// `PipelineOpts` the corpus was built with.  Errors when the
    /// artifact was exported without vocabulary strings.
    pub fn tokenize_text(&self, text: &str) -> Result<Vec<u32>, String> {
        if self.word_ids.is_empty() {
            return Err(
                "model carries no vocabulary strings; send token ids instead".into()
            );
        }
        let mut ids = Vec::new();
        for tok in tokenize(text) {
            let id = self
                .word_ids
                .get(&porter_stem(&tok))
                .or_else(|| self.word_ids.get(&tok));
            if let Some(&id) = id {
                ids.push(id);
            }
        }
        Ok(ids)
    }

    /// Answer one request with a caller-owned per-thread engine.  Pure
    /// compute — no IO, no panics on any input.
    pub fn answer_with(&self, inf: &mut Inferencer<'_>, req: Request) -> Response {
        match req {
            Request::ModelInfo => Response::ModelInfo {
                topics: self.model.num_topics() as u32,
                vocab: self.model.vocab() as u64,
                alpha: self.model.hyper().alpha,
                beta: self.model.hyper().beta,
                total_tokens: self.model.total_tokens(),
                has_vocab: !self.word_ids.is_empty(),
            },
            Request::TopWords { k } => {
                if k > MAX_QUERY_TOP_WORDS {
                    return Response::Err(format!(
                        "top-words k {k} exceeds the {MAX_QUERY_TOP_WORDS}-word cap"
                    ));
                }
                let entries = k as u64 * self.model.num_topics() as u64;
                if entries > MAX_TOP_WORDS_ENTRIES {
                    return Response::Err(format!(
                        "top-words k {k} x T {} exceeds the {MAX_TOP_WORDS_ENTRIES}-entry \
                         answer budget",
                        self.model.num_topics()
                    ));
                }
                let k = (k as usize).min(self.model.vocab());
                let topics = self
                    .model
                    .top_words(k)
                    .into_iter()
                    .map(|row| {
                        row.into_iter()
                            .map(|(word, count)| TopWord {
                                word,
                                count,
                                text: self
                                    .model
                                    .vocab_words()
                                    .get(word as usize)
                                    .cloned()
                                    .unwrap_or_default(),
                            })
                            .collect()
                    })
                    .collect();
                Response::TopWords { topics }
            }
            Request::InferTokens { tokens, sweeps, seed } => {
                self.infer(inf, &tokens, sweeps, seed)
            }
            Request::InferText { text, sweeps, seed } => match self.tokenize_text(&text) {
                Ok(tokens) => self.infer(inf, &tokens, sweeps, seed),
                Err(e) => Response::Err(e),
            },
        }
    }

    /// Convenience single-shot answer (builds a throwaway engine).
    pub fn answer(&self, req: Request) -> Response {
        let mut inf = Inferencer::new(&self.model);
        self.answer_with(&mut inf, req)
    }

    fn infer(&self, inf: &mut Inferencer<'_>, tokens: &[u32], sweeps: u32, seed: u64) -> Response {
        if tokens.len() > MAX_QUERY_TOKENS {
            return Response::Err(format!(
                "query document of {} tokens exceeds the {MAX_QUERY_TOKENS}-token cap",
                tokens.len()
            ));
        }
        if sweeps > MAX_QUERY_SWEEPS {
            return Response::Err(format!(
                "{sweeps} sweeps exceeds the {MAX_QUERY_SWEEPS}-sweep cap per query"
            ));
        }
        let opts = InferOpts { sweeps: sweeps as usize, seed };
        match inf.infer_doc(tokens, &opts) {
            Ok(res) => Response::Theta { theta: res.theta, used_tokens: tokens.len() as u32 },
            Err(e) => Response::Err(e),
        }
    }
}

/// `serve-model` options.
pub struct ServeModelOpts {
    /// handler threads (each owns a clone of the listener)
    pub threads: usize,
    /// serve a single connection on the calling thread, then return
    pub once: bool,
    /// suppress per-connection logging
    pub quiet: bool,
}

impl Default for ServeModelOpts {
    fn default() -> Self {
        ServeModelOpts { threads: 4, once: false, quiet: false }
    }
}

/// Consecutive `accept` failures after which a handler thread gives up
/// (a persistently broken listener, not load-induced churn).
const MAX_ACCEPT_FAILURES: u32 = 100;

/// Serve query traffic on `listener`.  With `once`, exactly one
/// connection is handled on the calling thread and its session error (if
/// any) becomes this call's error — the CLI/CI exit-code mode.  Otherwise
/// `threads` handler threads accept and serve connections until the
/// process exits; session errors are logged, never fatal, and transient
/// `accept` failures (ECONNABORTED, fd exhaustion under load) are backed
/// off and retried rather than draining handler capacity.  Only a
/// persistently failing listener ends the call — as an `Err`, so
/// supervisors see a non-zero exit.
pub fn serve_model(
    listener: TcpListener,
    host: Arc<ModelHost>,
    opts: &ServeModelOpts,
) -> Result<(), String> {
    if opts.once {
        let (stream, peer) = listener.accept().map_err(|e| format!("accept failed: {e}"))?;
        if !opts.quiet {
            eprintln!("[serve-model] client connected from {peer}");
        }
        return handle_conn(stream, &host);
    }
    let mut handles = Vec::new();
    for _ in 0..opts.threads.max(1) {
        let listener = listener.try_clone().map_err(|e| format!("listener clone failed: {e}"))?;
        let host = Arc::clone(&host);
        let quiet = opts.quiet;
        handles.push(std::thread::spawn(move || -> Result<(), String> {
            let mut failures = 0u32;
            loop {
                match listener.accept() {
                    Ok((stream, peer)) => {
                        failures = 0;
                        if !quiet {
                            eprintln!("[serve-model] client connected from {peer}");
                        }
                        if let Err(e) = handle_conn(stream, &host) {
                            eprintln!("[serve-model] session error: {e}");
                        }
                    }
                    Err(e) => {
                        failures += 1;
                        eprintln!("[serve-model] accept failed ({failures}): {e}");
                        if failures >= MAX_ACCEPT_FAILURES {
                            return Err(format!("accept failing persistently: {e}"));
                        }
                        std::thread::sleep(Duration::from_millis(50));
                    }
                }
            }
        }));
    }
    let mut first_err = None;
    for h in handles {
        match h.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => first_err = first_err.or(Some(e)),
            Err(_) => first_err = first_err.or(Some("handler thread panicked".to_string())),
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Serve one connection until the client closes it.  Exposed so tests
/// can host a session on their own listener.
pub fn handle_conn(stream: TcpStream, host: &ModelHost) -> Result<(), String> {
    stream.set_nodelay(true).map_err(|e| e.to_string())?;
    // idle deadline: a silent client must not pin this handler thread
    stream.set_read_timeout(Some(IDLE_TIMEOUT)).map_err(|e| e.to_string())?;
    let mut reader =
        BufReader::new(stream.try_clone().map_err(|e| format!("socket clone failed: {e}"))?);
    let mut writer = BufWriter::new(stream);
    let mut inf = Inferencer::new(host.model());
    loop {
        let body = match read_len_prefixed_eof(&mut reader, MAX_QUERY_FRAME) {
            // orderly close between requests: the normal end of session
            Ok(None) => return Ok(()),
            Ok(Some(body)) => body,
            Err(e) => {
                // frame layer broken (oversized length, mid-frame
                // truncation, reset, idle timeout): the stream cannot be
                // resynced — name the fault and drop the connection
                let _ = send_response(&mut writer, &Response::Err(e.clone()));
                return Err(e);
            }
        };
        let resp = match decode_request(&body) {
            Ok(req) => host.answer_with(&mut inf, req),
            // body-level malformation: framing is intact, so report the
            // named error and keep the session alive
            Err(e) => Response::Err(format!("bad request: {e}")),
        };
        send_response(&mut writer, &resp)?;
    }
}

fn send_response<W: Write>(w: &mut W, resp: &Response) -> Result<(), String> {
    write_len_prefixed(w, &encode_response(resp), MAX_QUERY_FRAME)
}

// ----------------------------------------------------------------- client

/// One client connection to a `serve-model` host; reusable for any number
/// of queries.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    /// Connect with a deadline (a black-holed address must be a prompt
    /// error, not an OS-default multi-minute hang).  The answer deadline
    /// is separate and much larger — a maximal legal query takes minutes.
    pub fn connect(addr: &str) -> Result<Client, String> {
        let sock = addr
            .to_socket_addrs()
            .map_err(|e| format!("resolve {addr}: {e}"))?
            .next()
            .ok_or_else(|| format!("resolve {addr}: no addresses"))?;
        let stream = TcpStream::connect_timeout(&sock, CONNECT_TIMEOUT)
            .map_err(|e| format!("connect {addr}: {e}"))?;
        stream.set_nodelay(true).map_err(|e| e.to_string())?;
        stream.set_read_timeout(Some(ANSWER_TIMEOUT)).map_err(|e| e.to_string())?;
        let reader =
            BufReader::new(stream.try_clone().map_err(|e| format!("socket clone failed: {e}"))?);
        Ok(Client { reader, writer: BufWriter::new(stream) })
    }

    /// Send one request and read its answer.
    pub fn query(&mut self, req: &Request) -> Result<Response, String> {
        write_len_prefixed(&mut self.writer, &encode_request(req), MAX_QUERY_FRAME)?;
        decode_response(&read_len_prefixed(&mut self.reader, MAX_QUERY_FRAME)?)
    }
}

/// One-shot convenience: connect, query, disconnect.
pub fn query_one(addr: &str, req: &Request) -> Result<Response, String> {
    Client::connect(addr)?.query(req)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::text::{build_corpus, PipelineOpts};
    use crate::lda::state::{Hyper, LdaState};
    use crate::lda::{FLdaWord, Sweep};
    use crate::util::rng::Pcg32;

    /// A tiny *textual* corpus so the vocab-strings path is real.
    fn text_model() -> TopicModel {
        let texts: Vec<String> = [
            "the cat sat on the mat and the cat purred",
            "dogs chase cats and cats chase mice in the yard",
            "stock markets rallied as traders bought shares",
            "the market fell while investors sold stock shares",
            "cats and dogs are pets while mice hide",
            "shares of the company rallied on strong markets",
            "a cat and a dog fought over the mat",
            "traders watch the stock market every day",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let corpus = build_corpus(
            &texts,
            &PipelineOpts { min_count: 2, min_docs: 2, ..Default::default() },
            "text-tiny",
        );
        let mut rng = Pcg32::seeded(5);
        let mut state = LdaState::init_random(&corpus, Hyper::paper_default(4), &mut rng);
        let mut sweeper = FLdaWord::new(&state, &corpus);
        for _ in 0..15 {
            sweeper.sweep(&mut state, &corpus, &mut rng);
        }
        TopicModel::from_state(&state, corpus.vocab_words.clone())
    }

    #[test]
    fn host_answers_every_request_kind() {
        let host = ModelHost::new(text_model());
        let t = host.model().num_topics();
        match host.answer(Request::ModelInfo) {
            Response::ModelInfo { topics, vocab, has_vocab, total_tokens, .. } => {
                assert_eq!(topics as usize, t);
                assert_eq!(vocab as usize, host.model().vocab());
                assert!(has_vocab);
                assert!(total_tokens > 0);
            }
            other => panic!("wrong answer: {other:?}"),
        }
        match host.answer(Request::TopWords { k: 3 }) {
            Response::TopWords { topics } => {
                assert_eq!(topics.len(), t);
                for row in &topics {
                    assert!(row.len() <= 3);
                    for w in row {
                        assert!(!w.text.is_empty(), "vocab model must resolve strings");
                    }
                }
            }
            other => panic!("wrong answer: {other:?}"),
        }
        match host.answer(Request::InferText {
            text: "the cat sat with the dog".into(),
            sweeps: 10,
            seed: 1,
        }) {
            Response::Theta { theta, used_tokens } => {
                assert_eq!(theta.len(), t);
                assert!(used_tokens > 0, "every query word was dropped");
                let sum: f64 = theta.iter().sum();
                assert!((sum - 1.0).abs() < 1e-9);
            }
            other => panic!("wrong answer: {other:?}"),
        }
    }

    #[test]
    fn oov_and_bad_queries_are_named_errors() {
        let host = ModelHost::new(text_model());
        let vocab = host.model().vocab() as u32;
        match host.answer(Request::InferTokens { tokens: vec![0, vocab], sweeps: 5, seed: 0 }) {
            Response::Err(e) => assert!(e.contains("vocabulary"), "unhelpful: {e}"),
            other => panic!("expected Err, got {other:?}"),
        }
        // a model without vocab strings rejects text queries by name
        let corpus = crate::corpus::presets::preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(2);
        let state = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        let anon = ModelHost::new(TopicModel::from_state(&state, Vec::new()));
        match anon.answer(Request::InferText { text: "hello".into(), sweeps: 1, seed: 0 }) {
            Response::Err(e) => assert!(e.contains("vocabulary strings"), "unhelpful: {e}"),
            other => panic!("expected Err, got {other:?}"),
        }
    }

    #[test]
    fn tokenizer_matches_training_pipeline() {
        let host = ModelHost::new(text_model());
        // "cats" stems to "cat" — must resolve to the same id
        let a = host.tokenize_text("cats").unwrap();
        let b = host.tokenize_text("cat").unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 1);
        // stop words and OOV terms drop silently
        let ids = host.tokenize_text("the and zzzunknownzzz").unwrap();
        assert!(ids.is_empty());
    }

    #[test]
    fn hostile_top_words_requests_are_a_named_error() {
        let host = ModelHost::new(text_model());
        match host.answer(Request::TopWords { k: u32::MAX }) {
            Response::Err(e) => assert!(e.contains("cap"), "unhelpful: {e}"),
            other => panic!("expected Err, got {other:?}"),
        }
    }

    #[test]
    fn hostile_sweep_counts_are_a_named_error_not_a_stall() {
        let host = ModelHost::new(text_model());
        // must return promptly — and honestly — despite the absurd request
        match host.answer(Request::InferTokens { tokens: vec![0], sweeps: u32::MAX, seed: 0 }) {
            Response::Err(e) => assert!(e.contains("sweep cap"), "unhelpful: {e}"),
            other => panic!("expected Err, got {other:?}"),
        }
        // the cap itself is inclusive
        match host.answer(Request::InferTokens {
            tokens: vec![0],
            sweeps: MAX_QUERY_SWEEPS,
            seed: 0,
        }) {
            Response::Theta { .. } => {}
            other => panic!("expected Theta at the cap, got {other:?}"),
        }
    }
}
