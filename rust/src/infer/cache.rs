//! Serving-side LRU answer cache.
//!
//! Real query streams are heavily skewed — the same documents and
//! top-word requests repeat — so the server keeps a bounded map from
//! [`CacheKey`] to the finished `Response`.  The cache is a classic
//! index-linked LRU: a `HashMap` into a slab of entries threaded on an
//! intrusive doubly-linked recency list, so `get`, `insert`, and eviction
//! are all O(1) with no per-operation allocation beyond the stored value.
//!
//! Hot-swap invalidation is by *construction*, not by flush: every key
//! embeds the model version it was answered under, so after a
//! `ReloadModel` the old entries simply stop being addressable and age
//! out of the LRU tail on their own.  There is no race window where a
//! flush and an in-flight insert could disagree about which model
//! answered.

use std::collections::HashMap;
use std::hash::Hash;

/// Sentinel link for "no neighbor" in the intrusive recency list.
const NIL: usize = usize::MAX;

struct Entry<K, V> {
    key: K,
    val: V,
    prev: usize,
    next: usize,
}

/// A fixed-capacity LRU map with O(1) get / insert / evict.
pub struct LruCache<K, V> {
    map: HashMap<K, usize>,
    entries: Vec<Entry<K, V>>,
    free: Vec<usize>,
    /// most recently used; NIL when empty
    head: usize,
    /// least recently used; NIL when empty
    tail: usize,
    cap: usize,
}

impl<K: Clone + Eq + Hash, V: Clone> LruCache<K, V> {
    /// `cap` must be ≥ 1 — "cache disabled" is expressed by not
    /// constructing a cache, not by a zero capacity.
    pub fn new(cap: usize) -> LruCache<K, V> {
        assert!(cap >= 1, "LruCache capacity must be >= 1");
        LruCache {
            map: HashMap::with_capacity(cap),
            entries: Vec::with_capacity(cap),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            cap,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Look up a key, promoting it to most-recently-used on a hit.
    /// Returns a clone so the caller holds no borrow into the cache
    /// (values are shared `Response`s, cloned anyway to answer).
    pub fn get(&mut self, key: &K) -> Option<V> {
        let i = *self.map.get(key)?;
        self.unlink(i);
        self.push_front(i);
        Some(self.entries[i].val.clone())
    }

    /// Insert or refresh a key at most-recently-used, evicting the LRU
    /// entry when full.
    pub fn insert(&mut self, key: K, val: V) {
        if let Some(&i) = self.map.get(&key) {
            self.entries[i].val = val;
            self.unlink(i);
            self.push_front(i);
            return;
        }
        if self.map.len() == self.cap {
            let lru = self.tail;
            self.unlink(lru);
            self.map.remove(&self.entries[lru].key);
            self.free.push(lru);
        }
        let entry = Entry { key: key.clone(), val, prev: NIL, next: NIL };
        let i = match self.free.pop() {
            Some(slot) => {
                self.entries[slot] = entry;
                slot
            }
            None => {
                self.entries.push(entry);
                self.entries.len() - 1
            }
        };
        self.map.insert(key, i);
        self.push_front(i);
    }

    fn unlink(&mut self, i: usize) {
        let (prev, next) = (self.entries[i].prev, self.entries[i].next);
        if prev != NIL {
            self.entries[prev].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.entries[next].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    fn push_front(&mut self, i: usize) {
        self.entries[i].prev = NIL;
        self.entries[i].next = self.head;
        if self.head != NIL {
            self.entries[self.head].prev = i;
        } else {
            self.tail = i;
        }
        self.head = i;
    }
}

/// What one cached serving answer is keyed on.
///
/// Theta entries key on the **sorted** token multiset.  LDA is a
/// bag-of-words model, so every ordering of the same bag is the same
/// query; fold-in Gibbs does consume RNG draws in token order, so
/// permutations are different (equally valid) θ̂ samples — the multiset
/// key pins the first one computed and serves it to all orderings, which
/// is what makes shuffled replays of a hot document cache hits.  Repeats
/// of the byte-identical request always get the byte-identical answer.
/// Every variant embeds `model_version`, which is what makes hot-swap
/// invalidation free.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum CacheKey {
    Theta { tokens: Vec<u32>, sweeps: u32, seed: u64, model_version: u64 },
    TopWords { k: u32, model_version: u64 },
}

impl CacheKey {
    /// Build a theta key, sorting the tokens into canonical multiset
    /// order.
    pub fn theta(tokens: &[u32], sweeps: u32, seed: u64, model_version: u64) -> CacheKey {
        let mut tokens = tokens.to_vec();
        tokens.sort_unstable();
        CacheKey::Theta { tokens, sweeps, seed, model_version }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_miss_and_lru_eviction_order() {
        let mut c: LruCache<u32, &str> = LruCache::new(2);
        assert!(c.is_empty());
        c.insert(1, "a");
        c.insert(2, "b");
        assert_eq!(c.get(&1), Some("a"));
        // 1 is now most recent; inserting 3 evicts 2
        c.insert(3, "c");
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(&2), None);
        assert_eq!(c.get(&1), Some("a"));
        assert_eq!(c.get(&3), Some("c"));
    }

    #[test]
    fn reinsert_refreshes_value_and_recency() {
        let mut c: LruCache<u32, &str> = LruCache::new(2);
        c.insert(1, "a");
        c.insert(2, "b");
        c.insert(1, "a2");
        // 2 is now the LRU entry
        c.insert(3, "c");
        assert_eq!(c.get(&1), Some("a2"));
        assert_eq!(c.get(&2), None);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn capacity_one_degenerates_gracefully() {
        let mut c: LruCache<u32, u32> = LruCache::new(1);
        for i in 0..10 {
            c.insert(i, i * i);
            assert_eq!(c.len(), 1);
            assert_eq!(c.get(&i), Some(i * i));
        }
        assert_eq!(c.get(&0), None);
    }

    #[test]
    fn evicted_slots_are_reused_not_leaked() {
        let mut c: LruCache<u32, u32> = LruCache::new(4);
        for i in 0..1000 {
            c.insert(i, i);
        }
        assert_eq!(c.len(), 4);
        // slab never grew past capacity: 4 live + at most 1 transient free
        assert!(c.entries.len() <= 5, "slab leaked to {}", c.entries.len());
        for i in 996..1000 {
            assert_eq!(c.get(&i), Some(i));
        }
    }

    #[test]
    fn theta_keys_are_multiset_canonical_and_version_scoped() {
        let a = CacheKey::theta(&[5, 1, 5, 2], 10, 7, 1);
        let b = CacheKey::theta(&[1, 2, 5, 5], 10, 7, 1);
        assert_eq!(a, b);
        // different multiset, sweeps, seed, or model version: distinct keys
        assert_ne!(a, CacheKey::theta(&[1, 2, 5], 10, 7, 1));
        assert_ne!(a, CacheKey::theta(&[5, 1, 5, 2], 11, 7, 1));
        assert_ne!(a, CacheKey::theta(&[5, 1, 5, 2], 10, 8, 1));
        assert_ne!(a, CacheKey::theta(&[5, 1, 5, 2], 10, 7, 2));
        assert_ne!(
            CacheKey::TopWords { k: 5, model_version: 1 },
            CacheKey::TopWords { k: 5, model_version: 2 }
        );
    }
}
