//! Typed configuration for the serving stack, mirroring the
//! [`crate::coordinator::TrainConfig`] builder idiom: public fields, a
//! chaining builder, and a [`ServeConfig::validate`] that names the
//! offending flag.  `main.rs` parses flag strings into these exactly
//! once at the edge; everything below the CLI is typed.

use std::time::Duration;

/// Everything `serve_model` needs beyond the listener and the model
/// slot.  Build with `ServeConfig::default()` plus the chaining setters.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// connection handler threads (each owns a clone of the listener)
    pub threads: usize,
    /// inference worker threads draining the batch queue
    pub workers: usize,
    /// how long a worker lingers for more jobs after the first of a
    /// batch; zero (the default) drains opportunistically — whatever is
    /// queued forms the batch, and an idle queue adds no latency
    pub batch_window: Duration,
    /// bounded depth of the handler → worker queue; a full queue is a
    /// named backpressure error, not an unbounded backlog
    pub queue_depth: usize,
    /// LRU answer-cache entries; 0 disables the cache
    pub cache_capacity: usize,
    /// per-connection read deadline: a client that connects and goes
    /// silent is cut off with a named timeout error, not held forever
    pub read_deadline: Duration,
    /// how long a handler waits for a worker to answer one job — sized
    /// for the slowest legal query, it only fires when workers are wedged
    pub answer_deadline: Duration,
    /// most documents one worker drains into a single batch
    pub max_batch: usize,
    /// serve a single connection on the calling thread, then return
    pub once: bool,
    /// suppress per-connection logging
    pub quiet: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            threads: 4,
            workers: 2,
            batch_window: Duration::ZERO,
            queue_depth: 256,
            cache_capacity: 1024,
            read_deadline: Duration::from_secs(300),
            answer_deadline: Duration::from_secs(600),
            max_batch: 64,
            once: false,
            quiet: false,
        }
    }
}

impl ServeConfig {
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    pub fn batch_window(mut self, window: Duration) -> Self {
        self.batch_window = window;
        self
    }

    pub fn queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = depth;
        self
    }

    pub fn cache_capacity(mut self, entries: usize) -> Self {
        self.cache_capacity = entries;
        self
    }

    pub fn read_deadline(mut self, deadline: Duration) -> Self {
        self.read_deadline = deadline;
        self
    }

    pub fn answer_deadline(mut self, deadline: Duration) -> Self {
        self.answer_deadline = deadline;
        self
    }

    pub fn max_batch(mut self, max: usize) -> Self {
        self.max_batch = max;
        self
    }

    pub fn once(mut self, once: bool) -> Self {
        self.once = once;
        self
    }

    pub fn quiet(mut self, quiet: bool) -> Self {
        self.quiet = quiet;
        self
    }

    /// Check every knob, naming the offending flag in the error.
    pub fn validate(&self) -> Result<(), String> {
        if self.threads == 0 {
            return Err("--threads must be >= 1".into());
        }
        if self.workers == 0 {
            return Err("--workers must be >= 1".into());
        }
        if self.queue_depth == 0 {
            return Err("--queue-depth must be >= 1".into());
        }
        if self.max_batch == 0 {
            return Err("--max-batch must be >= 1".into());
        }
        if self.read_deadline.is_zero() {
            return Err("--read-deadline-secs must be > 0".into());
        }
        if self.answer_deadline.is_zero() {
            return Err("the answer deadline must be > 0".into());
        }
        Ok(())
    }
}

/// Client-side connection knobs; `Client::connect(addr)` is shorthand
/// for the defaults.
#[derive(Clone, Debug)]
pub struct ClientConfig {
    pub addr: String,
    /// deadline for the TCP connect (a black-holed address must be a
    /// prompt error, not an OS-default multi-minute hang)
    pub connect_timeout: Duration,
    /// deadline per answer: sized for the slowest *legal* request (a
    /// max-token document at the sweep cap), so no within-cap query is
    /// un-servable through the bundled client
    pub answer_timeout: Duration,
}

impl ClientConfig {
    pub fn new(addr: impl Into<String>) -> ClientConfig {
        ClientConfig {
            addr: addr.into(),
            connect_timeout: Duration::from_secs(30),
            answer_timeout: Duration::from_secs(600),
        }
    }

    pub fn connect_timeout(mut self, deadline: Duration) -> Self {
        self.connect_timeout = deadline;
        self
    }

    pub fn answer_timeout(mut self, deadline: Duration) -> Self {
        self.answer_timeout = deadline;
        self
    }

    /// Open a connection with these knobs.
    pub fn connect(&self) -> Result<super::server::Client, String> {
        super::server::Client::connect_with(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chains_and_validates() {
        let cfg = ServeConfig::default()
            .threads(8)
            .workers(3)
            .batch_window(Duration::from_millis(2))
            .queue_depth(512)
            .cache_capacity(0)
            .read_deadline(Duration::from_secs(10))
            .answer_deadline(Duration::from_secs(20))
            .max_batch(32)
            .once(true)
            .quiet(true);
        assert_eq!(cfg.threads, 8);
        assert_eq!(cfg.workers, 3);
        assert_eq!(cfg.batch_window, Duration::from_millis(2));
        assert_eq!(cfg.queue_depth, 512);
        assert_eq!(cfg.cache_capacity, 0, "0 = cache disabled is legal");
        assert!(cfg.once && cfg.quiet);
        cfg.validate().unwrap();
        assert!(ServeConfig::default().validate().is_ok());
    }

    #[test]
    fn validation_names_the_offending_flag() {
        for (cfg, needle) in [
            (ServeConfig::default().threads(0), "--threads"),
            (ServeConfig::default().workers(0), "--workers"),
            (ServeConfig::default().queue_depth(0), "--queue-depth"),
            (ServeConfig::default().max_batch(0), "--max-batch"),
            (ServeConfig::default().read_deadline(Duration::ZERO), "--read-deadline"),
            (ServeConfig::default().answer_deadline(Duration::ZERO), "answer deadline"),
        ] {
            let err = cfg.validate().unwrap_err();
            assert!(err.contains(needle), "error {err:?} must name {needle}");
        }
    }

    #[test]
    fn client_config_builds_with_defaults() {
        let cfg = ClientConfig::new("127.0.0.1:7878")
            .connect_timeout(Duration::from_secs(1))
            .answer_timeout(Duration::from_secs(2));
        assert_eq!(cfg.addr, "127.0.0.1:7878");
        assert_eq!(cfg.connect_timeout, Duration::from_secs(1));
        assert_eq!(cfg.answer_timeout, Duration::from_secs(2));
        let d = ClientConfig::new("x");
        assert_eq!(d.connect_timeout, Duration::from_secs(30));
        assert_eq!(d.answer_timeout, Duration::from_secs(600));
    }
}
