//! Compact binary wire format for the model query protocol
//! (`serve-model` / `infer --remote`).
//!
//! Same design rules as the nomad ring format (`nomad/wire.rs`), built on
//! the shared `util::codec` substrate: little-endian fixed-width fields,
//! a **total** decoder (bounds-checked lengths before allocation,
//! trailing bytes are errors, malformed input is an `Err(String)` — never
//! a panic), and exact `decode(encode(x)) == x` roundtrips
//! (property-tested below).  The transport layer length-prefixes these
//! bodies with a [`MAX_QUERY_FRAME`] cap on both sides.
//!
//! Every *request* leads with a magic + version pair so a foreign or
//! version-skewed client is a named error instead of a confusing decode
//! failure; responses are only ever parsed by a client that already
//! passed that check.
//!
//! # Version negotiation
//!
//! The server rejects any request whose version field differs from
//! [`QUERY_VERSION`] with a named `unsupported query protocol version`
//! error *frame* — and that rejection is decodable by down-level
//! clients, because the `Err` response layout (tag 4, length-prefixed
//! UTF-8) is frozen across versions (pinned by test below).  v2 added
//! the `Stats` and `ReloadModel` admin requests, `model_version` /
//! `model_id` identity in `ModelInfo`, and a `model_version` label on
//! every `Theta` answer (which model a hot-swapping server used).

use crate::util::codec::{put_bytes, put_f64, put_u32, put_u64, put_u8, Cur};

use super::stats::StatsReport;

/// Magic at the head of every request body ("FNQY").
pub const QUERY_MAGIC: u32 = 0x464E_5159;

/// Query protocol version; bump on ANY layout or semantics change.
/// v1: ModelInfo/TopWords/InferTokens/InferText.
/// v2: + Stats, ReloadModel, model identity fields.
pub const QUERY_VERSION: u32 = 2;

/// Upper bound on one query frame body (64 MiB) — far above any real
/// query or answer, far below an attacker-controlled length field.
pub const MAX_QUERY_FRAME: usize = 64 << 20;

const REQ_MODEL_INFO: u8 = 1;
const REQ_TOP_WORDS: u8 = 2;
const REQ_INFER_TOKENS: u8 = 3;
const REQ_INFER_TEXT: u8 = 4;
const REQ_STATS: u8 = 5;
const REQ_RELOAD_MODEL: u8 = 6;

// RESP_ERR's tag and layout are frozen forever: it is the one frame a
// version-skewed client must still be able to decode (the negotiation
// rejection travels in it).
const RESP_MODEL_INFO: u8 = 1;
const RESP_TOP_WORDS: u8 = 2;
const RESP_THETA: u8 = 3;
const RESP_ERR: u8 = 4;
const RESP_STATS: u8 = 5;
const RESP_RELOADED: u8 = 6;

/// One client → server query.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// model shape + hyperparameters
    ModelInfo,
    /// top-k words per topic
    TopWords { k: u32 },
    /// fold-in inference over explicit token ids
    InferTokens { tokens: Vec<u32>, sweeps: u32, seed: u64 },
    /// fold-in inference over raw text, tokenized server-side against the
    /// model vocabulary (needs an artifact exported with vocab strings)
    InferText { text: String, sweeps: u32, seed: u64 },
    /// serving counters: QPS, latency percentiles, cache hit rate, …
    Stats,
    /// admin: atomically hot-swap the served model for the artifact at
    /// `path` (server-local path); in-flight queries finish on the old
    /// model, new ones see the new version
    ReloadModel { path: String },
}

/// One `(word, count)` entry of a topic's top-word list; `text` is empty
/// when the model carries no vocabulary strings.
#[derive(Clone, Debug, PartialEq)]
pub struct TopWord {
    pub word: u32,
    pub count: u32,
    pub text: String,
}

/// One server → client answer.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    ModelInfo {
        topics: u32,
        vocab: u64,
        alpha: f64,
        beta: f64,
        total_tokens: u64,
        has_vocab: bool,
        /// hot-swap counter: 1 for the initially loaded model, bumped by
        /// every `ReloadModel`; 0 marks a local (unserved) answer
        model_version: u64,
        /// human-readable identity, `stem@fingerprint`
        model_id: String,
    },
    TopWords {
        topics: Vec<Vec<TopWord>>,
    },
    Theta {
        /// dense θ̂ (length T, sums to 1)
        theta: Vec<f64>,
        /// tokens actually used (raw-text queries drop OOV terms)
        used_tokens: u32,
        /// which model version produced this answer (0 = local)
        model_version: u64,
    },
    /// snapshot of the serving counters
    Stats(StatsReport),
    /// acknowledgment of a completed hot-swap
    Reloaded {
        model_version: u64,
        model_id: String,
        topics: u32,
        vocab: u64,
    },
    Err(String),
}

// ---------------------------------------------------------------- encode

/// Serialize a request to its magic-led tagged body.
pub fn encode_request(req: &Request) -> Vec<u8> {
    let mut out = Vec::new();
    put_u32(&mut out, QUERY_MAGIC);
    put_u32(&mut out, QUERY_VERSION);
    match req {
        Request::ModelInfo => put_u8(&mut out, REQ_MODEL_INFO),
        Request::TopWords { k } => {
            put_u8(&mut out, REQ_TOP_WORDS);
            put_u32(&mut out, *k);
        }
        Request::InferTokens { tokens, sweeps, seed } => {
            put_u8(&mut out, REQ_INFER_TOKENS);
            put_u32(&mut out, *sweeps);
            put_u64(&mut out, *seed);
            put_u32(&mut out, tokens.len() as u32);
            for &w in tokens {
                put_u32(&mut out, w);
            }
        }
        Request::InferText { text, sweeps, seed } => {
            put_u8(&mut out, REQ_INFER_TEXT);
            put_u32(&mut out, *sweeps);
            put_u64(&mut out, *seed);
            put_bytes(&mut out, text.as_bytes());
        }
        Request::Stats => put_u8(&mut out, REQ_STATS),
        Request::ReloadModel { path } => {
            put_u8(&mut out, REQ_RELOAD_MODEL);
            put_bytes(&mut out, path.as_bytes());
        }
    }
    out
}

fn put_stats(out: &mut Vec<u8>, s: &StatsReport) {
    put_f64(out, s.uptime_secs);
    put_u64(out, s.total_requests);
    put_u64(out, s.infer_requests);
    put_u64(out, s.errors);
    put_f64(out, s.qps);
    put_u64(out, s.cache_hits);
    put_u64(out, s.cache_misses);
    put_f64(out, s.cache_hit_rate);
    put_f64(out, s.p50_us);
    put_f64(out, s.p95_us);
    put_f64(out, s.p99_us);
    put_u64(out, s.batches);
    put_u64(out, s.batched_docs);
    put_u64(out, s.max_batch);
    put_u64(out, s.queue_depth);
    put_u64(out, s.model_version);
    put_u64(out, s.model_swaps);
    // additive tail (shipped after v2): down-level decoders stop before
    // these bytes never existed for them, up-level decoders default the
    // fields to 0 when an old peer's reply ends here.  New fields go
    // after these, in order, same rule.
    put_u64(out, s.queue_cap);
    put_f64(out, s.batch_fill);
}

fn get_stats(cur: &mut Cur<'_>) -> Result<StatsReport, String> {
    let mut report = StatsReport {
        uptime_secs: cur.f64()?,
        total_requests: cur.u64()?,
        infer_requests: cur.u64()?,
        errors: cur.u64()?,
        qps: cur.f64()?,
        cache_hits: cur.u64()?,
        cache_misses: cur.u64()?,
        cache_hit_rate: cur.f64()?,
        p50_us: cur.f64()?,
        p95_us: cur.f64()?,
        p99_us: cur.f64()?,
        batches: cur.u64()?,
        batched_docs: cur.u64()?,
        max_batch: cur.u64()?,
        queue_depth: cur.u64()?,
        model_version: cur.u64()?,
        model_swaps: cur.u64()?,
        queue_cap: 0,
        batch_fill: 0.0,
    };
    // the additive tail: absent in replies from servers that predate it
    if cur.remaining() > 0 {
        report.queue_cap = cur.u64()?;
        report.batch_fill = cur.f64()?;
    }
    Ok(report)
}

/// Serialize a response to its tagged body.
pub fn encode_response(resp: &Response) -> Vec<u8> {
    let mut out = Vec::new();
    match resp {
        Response::ModelInfo {
            topics,
            vocab,
            alpha,
            beta,
            total_tokens,
            has_vocab,
            model_version,
            model_id,
        } => {
            put_u8(&mut out, RESP_MODEL_INFO);
            put_u32(&mut out, *topics);
            put_u64(&mut out, *vocab);
            put_f64(&mut out, *alpha);
            put_f64(&mut out, *beta);
            put_u64(&mut out, *total_tokens);
            put_u8(&mut out, *has_vocab as u8);
            put_u64(&mut out, *model_version);
            put_bytes(&mut out, model_id.as_bytes());
        }
        Response::TopWords { topics } => {
            put_u8(&mut out, RESP_TOP_WORDS);
            put_u32(&mut out, topics.len() as u32);
            for row in topics {
                put_u32(&mut out, row.len() as u32);
                for w in row {
                    put_u32(&mut out, w.word);
                    put_u32(&mut out, w.count);
                    put_bytes(&mut out, w.text.as_bytes());
                }
            }
        }
        Response::Theta { theta, used_tokens, model_version } => {
            put_u8(&mut out, RESP_THETA);
            put_u32(&mut out, *used_tokens);
            put_u32(&mut out, theta.len() as u32);
            for &x in theta {
                put_f64(&mut out, x);
            }
            put_u64(&mut out, *model_version);
        }
        Response::Stats(report) => {
            put_u8(&mut out, RESP_STATS);
            put_stats(&mut out, report);
        }
        Response::Reloaded { model_version, model_id, topics, vocab } => {
            put_u8(&mut out, RESP_RELOADED);
            put_u64(&mut out, *model_version);
            put_bytes(&mut out, model_id.as_bytes());
            put_u32(&mut out, *topics);
            put_u64(&mut out, *vocab);
        }
        Response::Err(msg) => {
            put_u8(&mut out, RESP_ERR);
            put_bytes(&mut out, msg.as_bytes());
        }
    }
    out
}

// ---------------------------------------------------------------- decode

/// Parse a request body.  Total; the magic/version check runs first so
/// foreign peers and binary skew are named errors.
pub fn decode_request(buf: &[u8]) -> Result<Request, String> {
    let mut cur = Cur::new(buf);
    let magic = cur.u32().map_err(|_| "empty request frame".to_string())?;
    if magic != QUERY_MAGIC {
        return Err(format!("bad query magic {magic:#010x}: not an fnomad query peer"));
    }
    let version = cur.u32()?;
    if version != QUERY_VERSION {
        return Err(format!(
            "unsupported query protocol version v{version}: this server speaks \
             v{QUERY_VERSION} — upgrade the client (or server) so both sides match"
        ));
    }
    let req = match cur.u8()? {
        REQ_MODEL_INFO => Request::ModelInfo,
        REQ_TOP_WORDS => Request::TopWords { k: cur.u32()? },
        REQ_INFER_TOKENS => {
            let sweeps = cur.u32()?;
            let seed = cur.u64()?;
            let n = cur.len(4)?;
            let tokens = (0..n).map(|_| cur.u32()).collect::<Result<_, _>>()?;
            Request::InferTokens { tokens, sweeps, seed }
        }
        REQ_INFER_TEXT => {
            let sweeps = cur.u32()?;
            let seed = cur.u64()?;
            let text = cur.string()?;
            Request::InferText { text, sweeps, seed }
        }
        REQ_STATS => Request::Stats,
        REQ_RELOAD_MODEL => Request::ReloadModel { path: cur.string()? },
        tag => return Err(format!("unknown request tag {tag}")),
    };
    cur.finish()?;
    Ok(req)
}

/// Parse a response body.  Total.
pub fn decode_response(buf: &[u8]) -> Result<Response, String> {
    let mut cur = Cur::new(buf);
    let resp = match cur.u8().map_err(|_| "empty response frame".to_string())? {
        RESP_MODEL_INFO => Response::ModelInfo {
            topics: cur.u32()?,
            vocab: cur.u64()?,
            alpha: cur.f64()?,
            beta: cur.f64()?,
            total_tokens: cur.u64()?,
            has_vocab: cur.u8()? != 0,
            model_version: cur.u64()?,
            model_id: cur.string()?,
        },
        RESP_TOP_WORDS => {
            // rows are variable-width; pre-check the 4-byte length floor
            let rows = cur.len(4)?;
            let mut topics = Vec::with_capacity(rows);
            for _ in 0..rows {
                let n = cur.len(12)?;
                let mut row = Vec::with_capacity(n);
                for _ in 0..n {
                    let word = cur.u32()?;
                    let count = cur.u32()?;
                    let text = cur.string()?;
                    row.push(TopWord { word, count, text });
                }
                topics.push(row);
            }
            Response::TopWords { topics }
        }
        RESP_THETA => {
            let used_tokens = cur.u32()?;
            let n = cur.len(8)?;
            let theta = (0..n).map(|_| cur.f64()).collect::<Result<_, _>>()?;
            Response::Theta { theta, used_tokens, model_version: cur.u64()? }
        }
        RESP_STATS => Response::Stats(get_stats(&mut cur)?),
        RESP_RELOADED => Response::Reloaded {
            model_version: cur.u64()?,
            model_id: cur.string()?,
            topics: cur.u32()?,
            vocab: cur.u64()?,
        },
        RESP_ERR => Response::Err(cur.string()?),
        tag => return Err(format!("unknown response tag {tag}")),
    };
    cur.finish()?;
    Ok(resp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::check;

    fn req_roundtrip(req: &Request) -> Request {
        decode_request(&encode_request(req)).expect("request roundtrip failed")
    }

    fn resp_roundtrip(resp: &Response) -> Response {
        decode_response(&encode_response(resp)).expect("response roundtrip failed")
    }

    #[test]
    fn every_request_variant_roundtrips() {
        for req in [
            Request::ModelInfo,
            Request::TopWords { k: 0 },
            Request::TopWords { k: 1000 },
            Request::InferTokens { tokens: vec![], sweeps: 0, seed: u64::MAX },
            Request::InferTokens { tokens: vec![0, 7, 299, u32::MAX], sweeps: 50, seed: 9 },
            Request::InferText { text: String::new(), sweeps: 1, seed: 0 },
            Request::InferText { text: "naïve quick fox — €".into(), sweeps: 3, seed: 4 },
            Request::Stats,
            Request::ReloadModel { path: String::new() },
            Request::ReloadModel { path: "/models/next — β.fnmodel".into() },
        ] {
            assert_eq!(req_roundtrip(&req), req);
        }
    }

    #[test]
    fn every_response_variant_roundtrips() {
        let top = TopWord { word: 3, count: 99, text: "topic".into() };
        let anon = TopWord { word: 4, count: 1, text: String::new() };
        for resp in [
            Response::ModelInfo {
                topics: 128,
                vocab: 7000,
                alpha: 50.0 / 128.0,
                beta: 0.01,
                total_tokens: u64::MAX / 7,
                has_vocab: true,
                model_version: 3,
                model_id: "news@deadbeefcafef00d".into(),
            },
            Response::TopWords { topics: vec![] },
            Response::TopWords { topics: vec![vec![top, anon], vec![]] },
            Response::Theta { theta: vec![], used_tokens: 0, model_version: 0 },
            Response::Theta {
                theta: vec![0.25, 0.75, f64::MIN_POSITIVE],
                used_tokens: 31,
                model_version: u64::MAX,
            },
            Response::Stats(StatsReport {
                uptime_secs: 12.5,
                total_requests: 9000,
                infer_requests: 8000,
                errors: 3,
                qps: 720.0,
                cache_hits: 4000,
                cache_misses: 4000,
                cache_hit_rate: 0.5,
                p50_us: 180.2,
                p95_us: 950.7,
                p99_us: 2048.0,
                batches: 1200,
                batched_docs: 8000,
                max_batch: 64,
                queue_depth: 7,
                queue_cap: 128,
                batch_fill: 0.104,
                model_version: 2,
                model_swaps: 1,
            }),
            Response::Reloaded {
                model_version: 2,
                model_id: "next@0123456789abcdef".into(),
                topics: 64,
                vocab: 12000,
            },
            Response::Err("model on fire".into()),
        ] {
            assert_eq!(resp_roundtrip(&resp), resp);
        }
    }

    #[test]
    fn random_token_queries_roundtrip() {
        check("InferTokens wire roundtrip", 32, |rng| {
            let n = rng.below(400);
            let tokens: Vec<u32> = (0..n).map(|_| rng.below(1 << 20) as u32).collect();
            let req = Request::InferTokens {
                tokens,
                sweeps: rng.below(100) as u32,
                seed: rng.next_u64(),
            };
            if req_roundtrip(&req) != req {
                return Err("request changed across the wire".into());
            }
            let t = 1 + rng.below(256);
            let theta: Vec<f64> = (0..t).map(|_| rng.next_f64()).collect();
            let resp = Response::Theta {
                theta,
                used_tokens: n as u32,
                model_version: rng.next_u64(),
            };
            if resp_roundtrip(&resp) != resp {
                return Err("response changed across the wire".into());
            }
            Ok(())
        });
    }

    #[test]
    fn magic_and_version_skew_are_named_errors() {
        let good = encode_request(&Request::ModelInfo);
        let mut bad_magic = good.clone();
        bad_magic[..4].copy_from_slice(&0u32.to_le_bytes());
        assert!(decode_request(&bad_magic).unwrap_err().contains("magic"));
        let mut bad_version = good.clone();
        bad_version[4..8].copy_from_slice(&(QUERY_VERSION + 1).to_le_bytes());
        let err = decode_request(&bad_version).unwrap_err();
        assert!(err.contains("unsupported query protocol version"), "unhelpful: {err}");
        decode_request(&good).unwrap();
    }

    #[test]
    fn v1_requests_are_rejected_by_version_number() {
        // a hand-built v1 ModelInfo frame, as an un-upgraded client sends it
        let mut v1 = Vec::new();
        put_u32(&mut v1, QUERY_MAGIC);
        put_u32(&mut v1, 1);
        put_u8(&mut v1, 1); // REQ_MODEL_INFO
        let err = decode_request(&v1).unwrap_err();
        assert!(err.contains("unsupported"), "unhelpful: {err}");
        assert!(err.contains("v1"), "must name the client's version: {err}");
        assert!(err.contains("v2"), "must name the server's version: {err}");
    }

    /// A Stats reply without the additive tail (`queue_cap`/`batch_fill`)
    /// — as a server that predates those fields sends it — must still
    /// decode, with the missing fields defaulted to zero.
    #[test]
    fn stats_reply_without_the_additive_tail_still_decodes() {
        let full = StatsReport {
            uptime_secs: 1.0,
            total_requests: 10,
            infer_requests: 8,
            errors: 0,
            qps: 10.0,
            cache_hits: 1,
            cache_misses: 2,
            cache_hit_rate: 1.0 / 3.0,
            p50_us: 100.0,
            p95_us: 200.0,
            p99_us: 300.0,
            batches: 4,
            batched_docs: 8,
            max_batch: 3,
            queue_depth: 2,
            queue_cap: 16,
            batch_fill: 0.25,
            model_version: 1,
            model_swaps: 0,
        };
        let mut enc = encode_response(&Response::Stats(full.clone()));
        enc.truncate(enc.len() - 16); // drop queue_cap (u64) + batch_fill (f64)
        match decode_response(&enc).expect("tail-less reply must decode") {
            Response::Stats(got) => {
                assert_eq!(got.queue_cap, 0, "absent field defaults to 0");
                assert_eq!(got.batch_fill, 0.0, "absent field defaults to 0");
                assert_eq!(
                    got,
                    StatsReport { queue_cap: 0, batch_fill: 0.0, ..full },
                    "every pre-tail field must survive"
                );
            }
            other => panic!("expected Stats, got {other:?}"),
        }
    }

    /// The `Err` response layout is the one frame every client version
    /// must decode (version-negotiation rejections travel in it), so its
    /// bytes are pinned: tag 4, then u32-LE length, then raw UTF-8.
    #[test]
    fn err_response_layout_is_frozen() {
        let enc = encode_response(&Response::Err("nope".into()));
        assert_eq!(enc[0], 4, "Err tag must stay 4 forever");
        assert_eq!(&enc[1..5], &4u32.to_le_bytes(), "length prefix must stay u32-LE");
        assert_eq!(&enc[5..], b"nope");
        assert_eq!(enc.len(), 9);
    }

    #[test]
    fn malformed_bodies_error_instead_of_panicking() {
        assert!(decode_request(&[]).unwrap_err().contains("empty"));
        assert!(decode_response(&[]).unwrap_err().contains("empty"));
        // unknown tags
        let mut buf = Vec::new();
        put_u32(&mut buf, QUERY_MAGIC);
        put_u32(&mut buf, QUERY_VERSION);
        put_u8(&mut buf, 99);
        assert!(decode_request(&buf).unwrap_err().contains("unknown request tag"));
        assert!(decode_response(&[99]).unwrap_err().contains("unknown response tag"));
        // truncated token list
        let mut buf = encode_request(&Request::InferTokens {
            tokens: vec![1, 2, 3],
            sweeps: 5,
            seed: 0,
        });
        buf.truncate(buf.len() - 2);
        assert!(decode_request(&buf).is_err());
        // trailing bytes
        let mut buf = encode_request(&Request::ModelInfo);
        buf.push(0);
        assert!(decode_request(&buf).unwrap_err().contains("trailing"));
        // absurd length field: error, not a 4 GiB allocation attempt
        let mut buf = Vec::new();
        put_u32(&mut buf, QUERY_MAGIC);
        put_u32(&mut buf, QUERY_VERSION);
        put_u8(&mut buf, 3); // REQ_INFER_TOKENS
        put_u32(&mut buf, 5);
        put_u64(&mut buf, 0);
        put_u32(&mut buf, u32::MAX);
        assert!(decode_request(&buf).unwrap_err().contains("exceeds"));
    }

    #[test]
    fn random_bytes_never_panic_the_decoders() {
        check("query decoders are total on garbage", 64, |rng| {
            let n = rng.below(200);
            let buf: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            let _ = decode_request(&buf);
            let _ = decode_response(&buf);
            Ok(())
        });
    }
}
