//! Model serving: the missing half of the system next to training.
//!
//! Training produces a mutable, corpus-bound [`crate::lda::LdaState`];
//! serving heavy query traffic wants a frozen, self-contained artifact
//! and an inference path whose per-token cost does not scale linearly in
//! the topic count.  Three layers, mirroring LightLDA-style
//! train/serve separation:
//!
//! * [`model`] — the immutable [`TopicModel`] (sparse topic–word counts,
//!   topic totals, hyperparameters, optional vocabulary strings) with its
//!   versioned `FNTM0001` binary format and a total, bounds-checked
//!   decoder.  `fnomad-lda export-model` freezes a training checkpoint
//!   into one.
//! * [`engine`] — fold-in Gibbs inference for unseen documents with φ̂
//!   frozen: a per-thread F+tree over the q term of
//!   `(n_td + α)·φ̂_t(w)` gives Θ(|T̂_w| + log T) per token (no O(T)
//!   scan), per-document RNG streams give bit-identical results across
//!   runs and thread counts, and `lda::perplexity` delegates its fold-in
//!   here.
//! * [`server`] + [`wire`] — a length-prefixed TCP query protocol
//!   (`fnomad-lda serve-model` / `fnomad-lda infer --remote`): the model
//!   loads once and N handler threads answer `InferDoc` / `TopWords` /
//!   `ModelInfo` queries, tokenizing raw-text requests with the training
//!   text pipeline.

pub mod engine;
pub mod model;
pub mod server;
pub mod wire;

pub use engine::{infer_batch, HeldOutScore, InferOpts, Inference, Inferencer};
pub use model::TopicModel;
pub use server::{query_one, serve_model, Client, ModelHost, ServeModelOpts};
pub use wire::{Request, Response};
