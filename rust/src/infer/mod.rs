//! Model serving: the missing half of the system next to training.
//!
//! Training produces a mutable, corpus-bound [`crate::lda::LdaState`];
//! serving heavy query traffic wants a frozen, self-contained artifact
//! and an inference path whose per-token cost does not scale linearly in
//! the topic count.  The layers, mirroring LightLDA-style train/serve
//! separation:
//!
//! * [`model`] — the immutable [`TopicModel`] (sparse topic–word counts,
//!   topic totals, hyperparameters, optional vocabulary strings) with its
//!   versioned `FNTM0001` binary format, a total, bounds-checked decoder,
//!   and a content fingerprint for serving identity.  `fnomad-lda
//!   export-model` freezes a training checkpoint into one.
//! * [`engine`] — fold-in Gibbs inference for unseen documents with φ̂
//!   frozen: a per-thread F+tree over the q term of
//!   `(n_td + α)·φ̂_t(w)` gives Θ(|T̂_w| + log T) per token (no O(T)
//!   scan), per-document RNG streams give bit-identical results across
//!   runs and thread counts, and `lda::perplexity` delegates its fold-in
//!   here.  [`engine::InferJob`] batches independent queries through one
//!   warm engine.
//! * [`server`] + [`wire`] — a length-prefixed TCP query protocol v2
//!   (`fnomad-lda serve-model` / `fnomad-lda infer --remote`): handler
//!   threads decode and answer cheap requests; inference fans through the
//!   shared [`batch`] queue into worker threads; [`cache`] holds an LRU
//!   of finished answers keyed on the token multiset; the served model
//!   sits in a [`server::ModelSlot`] so `ReloadModel` hot-swaps artifacts
//!   with zero dropped in-flight queries; [`stats`] counts QPS, latency
//!   percentiles, and cache hit rate for the `Stats` request.  Everything
//!   is configured through the typed [`ServeConfig`] / [`ClientConfig`]
//!   builders in [`config`].

pub mod batch;
pub mod cache;
pub mod config;
pub mod engine;
pub mod model;
pub mod server;
pub mod stats;
pub mod wire;

pub use config::{ClientConfig, ServeConfig};
pub use engine::{infer_batch, HeldOutScore, InferJob, InferOpts, Inference, Inferencer};
pub use model::TopicModel;
pub use server::{model_id_for, query_one, serve_model, Client, ModelHost, ModelSlot};
pub use stats::{ServerStats, StatsReport};
pub use wire::{Request, Response};
