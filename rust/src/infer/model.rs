//! The frozen serving artifact: an immutable [`TopicModel`] holding the
//! trained topic–word counts, and its versioned `FNTM0001` binary format.
//!
//! Training state ([`LdaState`]) is mutable and corpus-bound: resuming it
//! needs the full corpus to rederive counts, and every count can still
//! change.  Serving wants the opposite — a self-contained, *immutable*
//! point estimate φ̂ that loads without the corpus and is safe to share
//! across query threads.  `export-model` performs the freeze; this module
//! owns the artifact.
//!
//! # `FNTM0001` layout (little-endian, self-describing, no external crates)
//!
//! ```text
//! magic "FNTM0001"
//! T u32 | vocab u64 | alpha f64 | beta f64
//! nt: T × u32                                  (topic totals)
//! per word (vocab rows): SparseCounts row      (u32 support, (u16,u32)×)
//! has_vocab u8                                 (0 | 1)
//! if 1, per word: u32 len | utf8 bytes         (vocabulary strings)
//! ```
//!
//! The decoder is **total** in the style of `nomad/wire.rs`: every length
//! is bounds-checked against the remaining bytes before allocation,
//! sparse rows go through [`SparseCounts::from_sorted_pairs`], trailing
//! bytes are an error, and the decoded counts are cross-checked (`nt`
//! must equal the per-word column sums) so a corrupt or tampered file can
//! never produce an inconsistent model.  Version bumps change the magic
//! suffix (`FNTM0002`, …), so skew is a named error.

use std::path::Path;

use crate::lda::state::{Hyper, LdaState, SparseCounts};
use crate::lda::topics::top_words_rows;
use crate::util::codec::{put_bytes, put_f64, put_u32, put_u64, put_u8, Cur};

/// Magic + version at the head of every model artifact.
pub const MODEL_MAGIC: &[u8; 8] = b"FNTM0001";

/// A frozen, immutable topic model: the point estimate
/// `φ̂_t(w) = (n̂_wt + β) / (n̂_t + β̄)` plus the hyperparameters and the
/// optional vocabulary strings raw-text queries are resolved against.
///
/// Fields are private so every instance — constructed from a trained
/// state or decoded from disk — has passed the same consistency
/// validation and carries a correct cached `Σ_t 1/(n̂_t + β̄)`.
#[derive(Clone, Debug)]
pub struct TopicModel {
    hyper: Hyper,
    vocab: usize,
    /// frozen word-topic counts, one sparse row per word (`n̂_wt`)
    nwt: Vec<SparseCounts>,
    /// frozen topic totals (`n̂_t`)
    nt: Vec<u32>,
    /// vocabulary strings; empty when the corpus was synthetic/anonymous
    vocab_words: Vec<String>,
    /// cached `Σ_t 1/(n̂_t + β̄)` — the O(T) part of `Σ_t φ̂_t(w)`, paid
    /// once here so held-out scoring is O(|T̂_w|) per token
    inv_denom_sum: f64,
    /// FNV-1a over the statistical content (shape, hyperparameters,
    /// counts) — a stable identity for serving logs and hot-swap
    /// audit trails; derived, never serialized
    fingerprint: u64,
}

/// FNV-1a 64-bit, folded over little-endian field bytes.
struct Fnv1a(u64);

impl Fnv1a {
    fn new() -> Fnv1a {
        Fnv1a(0xcbf2_9ce4_8422_2325)
    }

    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100_0000_01b3);
        }
    }

    fn write_u64(&mut self, x: u64) {
        self.write(&x.to_le_bytes());
    }
}

impl TopicModel {
    /// Build a validated model.  Errors name the first violated
    /// invariant: shape mismatches, out-of-range topics, non-finite
    /// hyperparameters, or topic totals that disagree with the per-word
    /// column sums.
    pub fn new(
        hyper: Hyper,
        vocab: usize,
        nwt: Vec<SparseCounts>,
        nt: Vec<u32>,
        vocab_words: Vec<String>,
    ) -> Result<TopicModel, String> {
        let t = hyper.t;
        if !(2..=u16::MAX as usize + 1).contains(&t) {
            return Err(format!("topic count {t} out of range"));
        }
        if !(hyper.alpha.is_finite() && hyper.alpha > 0.0) {
            return Err(format!("alpha {} must be finite and positive", hyper.alpha));
        }
        if !(hyper.beta.is_finite() && hyper.beta > 0.0) {
            return Err(format!("beta {} must be finite and positive", hyper.beta));
        }
        if nt.len() != t {
            return Err(format!("topic totals length {} != T {t}", nt.len()));
        }
        if nwt.len() != vocab {
            return Err(format!("word rows {} != vocab {vocab}", nwt.len()));
        }
        if !vocab_words.is_empty() && vocab_words.len() != vocab {
            return Err(format!("vocab strings {} != vocab {vocab}", vocab_words.len()));
        }
        // cross-check: nt must be exactly the column sums of nwt — a
        // corrupt artifact cannot smuggle in inconsistent normalizers
        let mut col = vec![0u64; t];
        for (w, row) in nwt.iter().enumerate() {
            for (topic, c) in row.iter() {
                if topic as usize >= t {
                    return Err(format!("word {w}: topic {topic} >= T {t}"));
                }
                col[topic as usize] += c as u64;
            }
        }
        for (topic, (&have, &want)) in nt.iter().zip(&col).enumerate() {
            if have as u64 != want {
                return Err(format!(
                    "topic total nt[{topic}] = {have} but word rows sum to {want}: \
                     inconsistent model"
                ));
            }
        }
        let bb = hyper.betabar(vocab);
        let inv_denom_sum = nt.iter().map(|&n| 1.0 / (n as f64 + bb)).sum();
        let mut h = Fnv1a::new();
        h.write_u64(t as u64);
        h.write_u64(vocab as u64);
        h.write_u64(hyper.alpha.to_bits());
        h.write_u64(hyper.beta.to_bits());
        for &n in &nt {
            h.write(&n.to_le_bytes());
        }
        for row in &nwt {
            for (topic, c) in row.iter() {
                h.write(&topic.to_le_bytes());
                h.write(&c.to_le_bytes());
            }
        }
        let fingerprint = h.0;
        Ok(TopicModel { hyper, vocab, nwt, nt, vocab_words, inv_denom_sum, fingerprint })
    }

    /// Freeze a trained state into a serving model.  `vocab_words` comes
    /// from the corpus (pass an empty vec for synthetic vocabularies);
    /// panics only if the state violates its own invariants.
    pub fn from_state(state: &LdaState, vocab_words: Vec<String>) -> TopicModel {
        TopicModel::new(state.hyper, state.vocab, state.nwt.clone(), state.nt.clone(), vocab_words)
            .expect("trained state is internally consistent")
    }

    pub fn num_topics(&self) -> usize {
        self.hyper.t
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    pub fn hyper(&self) -> Hyper {
        self.hyper
    }

    pub fn betabar(&self) -> f64 {
        self.hyper.betabar(self.vocab)
    }

    /// Vocabulary strings (empty when the training corpus had none).
    pub fn vocab_words(&self) -> &[String] {
        &self.vocab_words
    }

    /// Stable identity hash over the statistical content (topics, vocab
    /// size, hyperparameters, all counts).  Two models answer queries
    /// identically iff their fingerprints match; vocabulary *strings* are
    /// presentation, not statistics, and are deliberately excluded.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Frozen sparse row `n̂_w·` for one word.
    #[inline]
    pub fn word_row(&self, word: usize) -> &SparseCounts {
        &self.nwt[word]
    }

    /// Frozen topic total `n̂_t`.
    #[inline]
    pub fn topic_total(&self, topic: usize) -> u32 {
        self.nt[topic]
    }

    /// Total training tokens Σ_t n̂_t.
    pub fn total_tokens(&self) -> u64 {
        self.nt.iter().map(|&c| c as u64).sum()
    }

    /// Point estimate `φ̂_t(w) = (n̂_wt + β) / (n̂_t + β̄)`.
    #[inline]
    pub fn phi(&self, topic: u16, word: usize) -> f64 {
        (self.nwt[word].get(topic) as f64 + self.hyper.beta)
            / (self.nt[topic as usize] as f64 + self.betabar())
    }

    /// `Σ_t φ̂_t(w)` in O(|T̂_w|) via the cached `Σ_t 1/(n̂_t + β̄)`.
    #[inline]
    pub fn phi_sum(&self, word: usize) -> f64 {
        let bb = self.betabar();
        let sparse: f64 = self.nwt[word]
            .iter()
            .map(|(t, c)| c as f64 / (self.nt[t as usize] as f64 + bb))
            .sum();
        sparse + self.hyper.beta * self.inv_denom_sum
    }

    /// Top-k `(word, count)` per topic (shared partial-selection kernel
    /// with the training-state inspector).
    pub fn top_words(&self, k: usize) -> Vec<Vec<(u32, u32)>> {
        top_words_rows(&self.nwt, self.hyper.t, k)
    }

    /// Predictive probability of one held-out word under a folded-in
    /// document: `p(w | d) = Σ_t θ̂_d(t) · φ̂_t(w)` with
    /// `θ̂_d(t) = (n_td + α) / (n_obs + Tα)`.
    ///
    /// Computed over the document support plus the word support —
    /// O(|T_d| + |T̂_w|) via [`Self::phi_sum`], never an O(T) scan:
    /// `Σ_t (n_td + α)·φ̂ = Σ_{t ∈ T_d} n_td·φ̂ + α·Σ_t φ̂`.
    pub fn predictive_prob(&self, counts: &SparseCounts, observed: usize, word: u32) -> f64 {
        let w = word as usize;
        let sparse: f64 = counts.iter().map(|(t, c)| c as f64 * self.phi(t, w)).sum();
        (sparse + self.hyper.alpha * self.phi_sum(w))
            / (observed as f64 + self.hyper.t as f64 * self.hyper.alpha)
    }

    // ----------------------------------------------------------- codec

    /// Serialize to the `FNTM0001` byte layout.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MODEL_MAGIC);
        put_u32(&mut out, self.hyper.t as u32);
        put_u64(&mut out, self.vocab as u64);
        put_f64(&mut out, self.hyper.alpha);
        put_f64(&mut out, self.hyper.beta);
        for &n in &self.nt {
            put_u32(&mut out, n);
        }
        for row in &self.nwt {
            row.encode(&mut out);
        }
        put_u8(&mut out, if self.vocab_words.is_empty() { 0 } else { 1 });
        for w in &self.vocab_words {
            put_bytes(&mut out, w.as_bytes());
        }
        out
    }

    /// Parse an `FNTM0001` buffer.  Total: every malformation — wrong
    /// magic, truncation, absurd lengths, invalid rows, trailing bytes,
    /// inconsistent totals — is a named `Err`, never a panic.
    pub fn decode(buf: &[u8]) -> Result<TopicModel, String> {
        let mut cur = Cur::new(buf);
        let magic = cur.take(8).map_err(|_| "not an FNTM model: shorter than the magic")?;
        if magic != MODEL_MAGIC {
            return Err(format!(
                "bad magic {:?}: not an FNTM0001 model artifact",
                String::from_utf8_lossy(magic)
            ));
        }
        let t = cur.u32()? as usize;
        if !(2..=u16::MAX as usize + 1).contains(&t) {
            return Err(format!("topic count {t} out of range"));
        }
        let vocab = cur.u64()? as usize;
        let alpha = cur.f64()?;
        let beta = cur.f64()?;
        if t.saturating_mul(4) > cur.remaining() {
            return Err(format!("topic totals ({t} x 4B) exceed the artifact size"));
        }
        let nt = (0..t).map(|_| cur.u32()).collect::<Result<Vec<_>, _>>()?;
        // each word row costs at least its 4-byte support field
        if vocab.saturating_mul(4) > cur.remaining() {
            return Err(format!("vocab {vocab} rows exceed the artifact size"));
        }
        let mut nwt = Vec::with_capacity(vocab);
        for w in 0..vocab {
            nwt.push(SparseCounts::decode(&mut cur).map_err(|e| format!("word {w}: {e}"))?);
        }
        let vocab_words = match cur.u8()? {
            0 => Vec::new(),
            1 => {
                if vocab.saturating_mul(4) > cur.remaining() {
                    return Err(format!("vocab {vocab} strings exceed the artifact size"));
                }
                (0..vocab).map(|_| cur.string()).collect::<Result<Vec<_>, _>>()?
            }
            v => return Err(format!("bad vocab-strings flag {v}")),
        };
        cur.finish()?;
        TopicModel::new(Hyper { t, alpha, beta }, vocab, nwt, nt, vocab_words)
    }

    /// Write the artifact; returns the byte size on disk.
    pub fn save(&self, path: &Path) -> Result<u64, String> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
        }
        let bytes = self.encode();
        std::fs::write(path, &bytes).map_err(|e| format!("{}: {e}", path.display()))?;
        Ok(bytes.len() as u64)
    }

    /// Load and fully validate an artifact.
    pub fn load(path: &Path) -> Result<TopicModel, String> {
        let bytes =
            std::fs::read(path).map_err(|e| format!("{}: {e}", path.display()))?;
        TopicModel::decode(&bytes).map_err(|e| format!("{}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;
    use crate::util::rng::Pcg32;

    fn trained_model(vocab_words: bool) -> TopicModel {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(41);
        let state = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        let words = if vocab_words {
            (0..corpus.vocab()).map(|w| format!("word{w}")).collect()
        } else {
            Vec::new()
        };
        TopicModel::from_state(&state, words)
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join("fnomad_model_tests").join(name)
    }

    #[test]
    fn phi_rows_are_distributions() {
        let m = trained_model(false);
        for t in 0..m.num_topics() {
            let sum: f64 = (0..m.vocab()).map(|w| m.phi(t as u16, w)).sum();
            assert!((sum - 1.0).abs() < 1e-9, "topic {t}: phi sums to {sum}");
        }
    }

    #[test]
    fn phi_sum_matches_dense_scan() {
        let m = trained_model(false);
        for w in [0usize, 7, 123, 299] {
            let dense: f64 = (0..m.num_topics()).map(|t| m.phi(t as u16, w)).sum();
            let sparse = m.phi_sum(w);
            assert!(
                (dense - sparse).abs() < 1e-9 * dense.max(1.0),
                "word {w}: dense {dense} vs cached {sparse}"
            );
        }
    }

    #[test]
    fn roundtrip_preserves_everything() {
        for with_words in [false, true] {
            let m = trained_model(with_words);
            let back = TopicModel::decode(&m.encode()).unwrap();
            assert_eq!(back.num_topics(), m.num_topics());
            assert_eq!(back.vocab(), m.vocab());
            assert_eq!(back.vocab_words(), m.vocab_words());
            assert_eq!(back.total_tokens(), m.total_tokens());
            for w in 0..m.vocab() {
                assert_eq!(back.word_row(w), m.word_row(w), "word {w}");
            }
            // and the cached sum was rebuilt identically
            assert!((back.phi_sum(0) - m.phi_sum(0)).abs() < 1e-12);
        }
    }

    #[test]
    fn save_load_roundtrip_on_disk() {
        let m = trained_model(true);
        let path = tmp("rt.fnmodel");
        let bytes = m.save(&path).unwrap();
        assert_eq!(bytes, m.encode().len() as u64);
        let back = TopicModel::load(&path).unwrap();
        assert_eq!(back.encode(), m.encode());
        let _ = std::fs::remove_file(path);
    }

    /// Golden oracle: the FNTM0001 byte stream is pinned field by field,
    /// so an accidental layout change fails loudly instead of silently
    /// orphaning every exported model.
    #[test]
    fn golden_bytes_match_the_documented_layout() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(41);
        let state = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        let m = TopicModel::from_state(&state, Vec::new());
        let mut want: Vec<u8> = Vec::new();
        want.extend_from_slice(b"FNTM0001");
        want.extend_from_slice(&(state.hyper.t as u32).to_le_bytes());
        want.extend_from_slice(&(state.vocab as u64).to_le_bytes());
        want.extend_from_slice(&state.hyper.alpha.to_le_bytes());
        want.extend_from_slice(&state.hyper.beta.to_le_bytes());
        for &n in &state.nt {
            want.extend_from_slice(&n.to_le_bytes());
        }
        for row in &state.nwt {
            want.extend_from_slice(&(row.support() as u32).to_le_bytes());
            for (t, c) in row.iter() {
                want.extend_from_slice(&t.to_le_bytes());
                want.extend_from_slice(&c.to_le_bytes());
            }
        }
        want.push(0);
        assert_eq!(m.encode(), want, "FNTM0001 byte format changed");
    }

    #[test]
    fn malformed_artifacts_error_instead_of_panicking() {
        let good = trained_model(true).encode();
        // empty / short
        assert!(TopicModel::decode(&[]).unwrap_err().contains("magic"));
        assert!(TopicModel::decode(&good[..4]).unwrap_err().contains("magic"));
        // wrong magic
        let mut bad = good.clone();
        bad[..8].copy_from_slice(b"FNLDA001");
        assert!(TopicModel::decode(&bad).unwrap_err().contains("magic"));
        // truncated body
        assert!(TopicModel::decode(&good[..good.len() - 3]).is_err());
        // trailing bytes
        let mut bad = good.clone();
        bad.push(0);
        assert!(TopicModel::decode(&bad).unwrap_err().contains("trailing"));
        // absurd topic count
        let mut bad = good.clone();
        bad[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(TopicModel::decode(&bad).unwrap_err().contains("out of range"));
        // absurd vocab: must error before attempting a giant allocation
        let mut bad = good.clone();
        bad[12..20].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(TopicModel::decode(&bad).unwrap_err().contains("exceed"));
    }

    #[test]
    fn tampered_counts_fail_the_consistency_check() {
        let m = trained_model(false);
        let bytes = m.encode();
        // nt starts at offset 8 (magic) + 4 (T) + 8 (vocab) + 16 (α, β)
        let nt0_at = 8 + 4 + 8 + 16;
        let mut bad = bytes.clone();
        let nt0 = u32::from_le_bytes(bad[nt0_at..nt0_at + 4].try_into().unwrap());
        bad[nt0_at..nt0_at + 4].copy_from_slice(&(nt0 + 1).to_le_bytes());
        let err = TopicModel::decode(&bad).unwrap_err();
        assert!(err.contains("inconsistent"), "unhelpful error: {err}");
    }

    #[test]
    fn constructor_rejects_bad_shapes() {
        let m = trained_model(false);
        let hyper = m.hyper();
        let nwt = m.nwt.clone();
        let nt = m.nt.clone();
        // wrong nt length
        let err =
            TopicModel::new(hyper, m.vocab(), nwt.clone(), nt[1..].to_vec(), Vec::new())
                .unwrap_err();
        assert!(err.contains("totals length"));
        // wrong vocab_words length
        let err =
            TopicModel::new(hyper, m.vocab(), nwt.clone(), nt.clone(), vec!["x".into()])
                .unwrap_err();
        assert!(err.contains("vocab strings"));
        // topic out of range in a row
        let mut bad_rows = nwt.clone();
        bad_rows[0] = SparseCounts::from_sorted_pairs(vec![(hyper.t as u16, 3)]).unwrap();
        let err = TopicModel::new(hyper, m.vocab(), bad_rows, nt, Vec::new()).unwrap_err();
        assert!(err.contains(">= T"));
    }

    /// `predictive_prob` (sparse, via the cached `phi_sum`) must equal
    /// the textbook dense `Σ_t θ̂(t)·φ̂_t(w)` scan.
    #[test]
    fn predictive_prob_matches_dense_reference() {
        let m = trained_model(false);
        let t = m.num_topics();
        let mut counts = SparseCounts::default();
        for topic in [0u16, 0, 3, 5, 5, 5] {
            counts.inc(topic);
        }
        let n_obs = counts.total() as usize;
        let h = m.hyper();
        for w in [0u32, 17, 299] {
            let theta = |k: usize| {
                (counts.get(k as u16) as f64 + h.alpha)
                    / (n_obs as f64 + t as f64 * h.alpha)
            };
            let dense: f64 = (0..t).map(|k| theta(k) * m.phi(k as u16, w as usize)).sum();
            let got = m.predictive_prob(&counts, n_obs, w);
            assert!(
                (dense - got).abs() < 1e-12 * dense.max(1e-12),
                "word {w}: dense {dense} vs sparse {got}"
            );
        }
    }

    #[test]
    fn fingerprint_is_stable_and_content_sensitive() {
        let m = trained_model(false);
        // deterministic: rebuilding from the same state reproduces it,
        // strings-only differences (presentation) do not perturb it
        assert_eq!(trained_model(false).fingerprint(), m.fingerprint());
        assert_eq!(trained_model(true).fingerprint(), m.fingerprint());
        // the decode path derives the identical identity
        let back = TopicModel::decode(&m.encode()).unwrap();
        assert_eq!(back.fingerprint(), m.fingerprint());
        // any statistical difference moves it
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(42);
        let other = TopicModel::from_state(
            &LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng),
            Vec::new(),
        );
        assert_ne!(other.fingerprint(), m.fingerprint());
    }

    #[test]
    fn top_words_match_state_inspector() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(41);
        let state = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        let m = TopicModel::from_state(&state, Vec::new());
        assert_eq!(m.top_words(5), crate::lda::topics::top_words(&state, 5));
    }
}
