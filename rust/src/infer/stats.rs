//! Serving instrumentation: lock-free counters plus a log₂ latency
//! histogram, snapshotted into a [`StatsReport`] for the `Stats` wire
//! request and the `BENCH_infer.json` recorder.
//!
//! Every handler thread records into the same [`ServerStats`] through
//! relaxed atomics — one increment per counter, one increment per
//! latency bucket ([`crate::util::bench::latency_bucket`]) — so
//! instrumentation never serializes the request path.  Percentiles are
//! read back as bucket geometric midpoints
//! ([`crate::util::bench::bucket_percentile_us`]): ≤ √2× value
//! resolution, O(1) recording, bounded memory.

// Counter protocol, and why every access is `Relaxed`: each counter is an
// independent monotone tally — no reader derives a cross-counter
// invariant that synchronization would have to protect (a report may see
// a request that its latency histogram does not, and vice versa; totals
// are exact once the recording threads are quiescent, e.g. after join).
// Relaxed atomics give per-counter exactness without ordering cost on the
// request path.
use std::time::{Duration, Instant};

use crate::util::sync::atomic::{AtomicU64, Ordering};

use crate::util::bench::{bucket_percentile_us, latency_bucket, LATENCY_BUCKETS};

/// Shared, lock-free serving counters.  One instance per server, shared
/// across handler and worker threads via `Arc`.
pub struct ServerStats {
    start: Instant,
    total_requests: AtomicU64,
    infer_requests: AtomicU64,
    errors: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    batches: AtomicU64,
    batched_docs: AtomicU64,
    max_batch: AtomicU64,
    model_swaps: AtomicU64,
    /// per-request wall time, log₂-bucketed nanoseconds
    lat_ns: [AtomicU64; LATENCY_BUCKETS],
}

impl ServerStats {
    pub fn new() -> ServerStats {
        ServerStats {
            start: Instant::now(),
            total_requests: AtomicU64::new(0),
            infer_requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_docs: AtomicU64::new(0),
            max_batch: AtomicU64::new(0),
            model_swaps: AtomicU64::new(0),
            lat_ns: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Record one finished request: its wall time, whether it was an
    /// inference (vs info/top-words/admin), and whether it answered with
    /// an `Err` response.
    pub fn record_request(&self, wall: Duration, is_infer: bool, is_err: bool) {
        // relaxed: independent monotone tallies, see the module protocol note
        self.total_requests.fetch_add(1, Ordering::Relaxed);
        if is_infer {
            // relaxed: independent monotone tally
            self.infer_requests.fetch_add(1, Ordering::Relaxed);
        }
        if is_err {
            // relaxed: independent monotone tally
            self.errors.fetch_add(1, Ordering::Relaxed);
        }
        let ns = wall.as_nanos().min(u64::MAX as u128) as u64;
        // relaxed: each bucket is its own tally; percentile readback
        // tolerates torn cross-bucket snapshots
        self.lat_ns[latency_bucket(ns)].fetch_add(1, Ordering::Relaxed);
    }

    /// Record one cache lookup outcome.
    pub fn record_cache(&self, hit: bool) {
        if hit {
            // relaxed: independent monotone tally
            self.cache_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            // relaxed: independent monotone tally
            self.cache_misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record one drained worker batch of `docs` documents.
    pub fn record_batch(&self, docs: u64) {
        // relaxed: independent monotone tallies
        self.batches.fetch_add(1, Ordering::Relaxed);
        // relaxed: independent monotone tally
        self.batched_docs.fetch_add(docs, Ordering::Relaxed);
        // Running max as a CAS loop rather than `fetch_max`: loom's
        // atomics do not model `fetch_max`, and the loop is equivalent —
        // retry while our value still exceeds the observed max.
        // relaxed: a monotone high-water mark; no other memory hangs off it
        let mut seen = self.max_batch.load(Ordering::Relaxed);
        while docs > seen {
            // relaxed: same monotone high-water mark
            match self.max_batch.compare_exchange_weak(
                seen,
                docs,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(now) => seen = now,
            }
        }
    }

    /// Record one completed model hot-swap.
    pub fn record_swap(&self) {
        // relaxed: independent monotone tally
        self.model_swaps.fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot everything into a wire-encodable report.  `queue_depth`,
    /// `queue_cap`, `batch_cap` and `model_version` are sampled by the
    /// caller (they live on the queue / config / model slot, not here).
    pub fn report(
        &self,
        queue_depth: u64,
        queue_cap: u64,
        batch_cap: u64,
        model_version: u64,
    ) -> StatsReport {
        let uptime_secs = self.start.elapsed().as_secs_f64().max(1e-9);
        // relaxed: snapshot loads of independent tallies; the report is
        // allowed to be a torn cross-counter snapshot (module note)
        let total_requests = self.total_requests.load(Ordering::Relaxed);
        let cache_hits = self.cache_hits.load(Ordering::Relaxed);
        let cache_misses = self.cache_misses.load(Ordering::Relaxed);
        let lookups = cache_hits + cache_misses;
        let counts: Vec<u64> =
            self.lat_ns.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        // NaN.max(0.0) is 0.0: an empty histogram reports zeroed
        // percentiles rather than poisoning the wire roundtrip / JSON
        let pct = |p: f64| bucket_percentile_us(&counts, p).max(0.0);
        // relaxed: snapshot loads, as above
        let batches = self.batches.load(Ordering::Relaxed);
        let batched_docs = self.batched_docs.load(Ordering::Relaxed);
        StatsReport {
            uptime_secs,
            total_requests,
            // relaxed: snapshot loads, as above
            infer_requests: self.infer_requests.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            qps: total_requests as f64 / uptime_secs,
            cache_hits,
            cache_misses,
            cache_hit_rate: if lookups == 0 {
                0.0
            } else {
                cache_hits as f64 / lookups as f64
            },
            p50_us: pct(50.0),
            p95_us: pct(95.0),
            p99_us: pct(99.0),
            batches,
            batched_docs,
            // relaxed: snapshot load, as above
            max_batch: self.max_batch.load(Ordering::Relaxed),
            queue_depth,
            queue_cap,
            batch_fill: if batches == 0 || batch_cap == 0 {
                0.0
            } else {
                batched_docs as f64 / (batches * batch_cap) as f64
            },
            model_version,
            // relaxed: snapshot load, as above
            model_swaps: self.model_swaps.load(Ordering::Relaxed),
        }
    }
}

impl Default for ServerStats {
    fn default() -> Self {
        ServerStats::new()
    }
}

/// One snapshot of the serving counters, as carried by the `Stats` wire
/// response and rendered by `infer --stats` / `bench`.
#[derive(Clone, Debug, PartialEq)]
pub struct StatsReport {
    pub uptime_secs: f64,
    pub total_requests: u64,
    pub infer_requests: u64,
    pub errors: u64,
    pub qps: f64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_hit_rate: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub batches: u64,
    pub batched_docs: u64,
    pub max_batch: u64,
    pub queue_depth: u64,
    /// configured queue capacity (`--queue-depth`); with `queue_depth`
    /// this makes live occupancy a ratio, not a bare number.
    /// Wire note: added after v2 shipped as a trailing additive field —
    /// decoders default it to 0 when an older peer's reply omits it.
    pub queue_cap: u64,
    /// mean drained-batch fill fraction of the configured `--max-batch`
    /// cap (0.0 with no batches).  Additive trailing field, like
    /// `queue_cap`.
    pub batch_fill: f64,
    pub model_version: u64,
    pub model_swaps: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_report() {
        let s = ServerStats::new();
        s.record_request(Duration::from_micros(100), true, false);
        s.record_request(Duration::from_micros(200), true, false);
        s.record_request(Duration::from_millis(5), false, true);
        s.record_cache(true);
        s.record_cache(true);
        s.record_cache(false);
        s.record_batch(2);
        s.record_batch(7);
        s.record_swap();
        let r = s.report(3, 16, 8, 2);
        assert_eq!(r.total_requests, 3);
        assert_eq!(r.infer_requests, 2);
        assert_eq!(r.errors, 1);
        assert_eq!(r.cache_hits, 2);
        assert_eq!(r.cache_misses, 1);
        assert!((r.cache_hit_rate - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(r.batches, 2);
        assert_eq!(r.batched_docs, 9);
        assert_eq!(r.max_batch, 7);
        assert_eq!(r.queue_depth, 3);
        assert_eq!(r.queue_cap, 16);
        // 9 docs over 2 batches against a cap of 8 → 9/16
        assert!((r.batch_fill - 9.0 / 16.0).abs() < 1e-12, "batch_fill = {}", r.batch_fill);
        assert_eq!(r.model_version, 2);
        assert_eq!(r.model_swaps, 1);
        assert!(r.qps > 0.0);
        assert!(r.uptime_secs > 0.0);
        // bucketed percentiles: ordered, positive, within √2 of the truth
        assert!(r.p50_us > 0.0);
        assert!(r.p50_us <= r.p95_us && r.p95_us <= r.p99_us);
        assert!(r.p50_us > 100.0 / 1.5 && r.p50_us < 200.0 * 1.5, "p50 = {}", r.p50_us);
        assert!(r.p99_us > 5_000.0 / 1.5, "p99 = {}", r.p99_us);
    }

    #[test]
    fn empty_stats_report_zeroed_not_nan() {
        let r = ServerStats::new().report(0, 16, 8, 1);
        assert_eq!(r.total_requests, 0);
        assert_eq!(r.cache_hit_rate, 0.0);
        assert_eq!(r.batch_fill, 0.0);
        assert_eq!(r.p50_us, 0.0);
        assert_eq!(r.p99_us, 0.0);
        assert!(r.qps == 0.0);
    }

    #[test]
    fn stats_are_safe_to_record_concurrently() {
        let s = std::sync::Arc::new(ServerStats::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let s = std::sync::Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    s.record_request(Duration::from_nanos(i), i % 2 == 0, false);
                    s.record_cache(i % 3 == 0);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let r = s.report(0, 16, 8, 1);
        assert_eq!(r.total_requests, 4000);
        assert_eq!(r.infer_requests, 2000);
        assert_eq!(r.cache_hits + r.cache_misses, 4000);
    }
}
