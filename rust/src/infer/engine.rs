//! Fold-in Gibbs inference for *unseen* documents against a frozen
//! [`TopicModel`].
//!
//! With φ̂ frozen, resampling token j of a query document targets
//!
//! ```text
//! p(z_j = t) ∝ (n_td + α) · φ̂_t(w_j)
//!            = β·q_t + n̂_wt·q_t,   q_t = (n_td + α)/(n̂_t + β̄)
//! ```
//!
//! — the doc-major q/r decomposition of paper §3.2 with the word side
//! constant.  `q` changes in O(1) coordinates per token, so it lives in a
//! per-thread [`FTree`] (Θ(log T) draw *and* Θ(log T) update); the `r`
//! term is |T̂_w|-sparse and is rebuilt per token as a sparse cumsum.
//! Per-token cost: Θ(|T̂_w| + log T).  The previous serving path (the
//! loop formerly inlined in `lda::perplexity`) scanned all T topics per
//! token; that loop now delegates here.
//!
//! Determinism: every document draws from its own PCG32 stream
//! `(seed, doc index)`, so [`infer_batch`] returns bit-identical θ̂ for
//! any thread count, and repeated calls replay exactly.

use crate::corpus::Corpus;
use crate::lda::state::SparseCounts;
use crate::sampler::bsearch::SparseCumSum;
use crate::sampler::ftree::FTree;
use crate::sampler::DiscreteSampler;
use crate::util::rng::Pcg32;

use super::model::TopicModel;

/// Inference knobs: fold-in Gibbs sweeps and the RNG seed.
#[derive(Clone, Copy, Debug)]
pub struct InferOpts {
    /// Gibbs sweeps over the query document with φ̂ frozen
    pub sweeps: usize,
    /// base seed; each document uses the stream `(seed, doc index)`
    pub seed: u64,
}

impl Default for InferOpts {
    fn default() -> Self {
        InferOpts { sweeps: 20, seed: 0 }
    }
}

/// One inferred document: the smoothed topic mixture θ̂ plus the raw
/// folded-in counts it came from.
#[derive(Clone, Debug)]
pub struct Inference {
    /// dense θ̂_d (length T, sums to 1)
    pub theta: Vec<f64>,
    /// folded-in `n_td`
    pub counts: SparseCounts,
    /// query document length
    pub tokens: usize,
}

impl Inference {
    fn from_counts(model: &TopicModel, counts: SparseCounts, tokens: usize) -> Inference {
        let h = model.hyper();
        let denom = tokens as f64 + h.t as f64 * h.alpha;
        let theta = (0..h.t)
            .map(|t| (counts.get(t as u16) as f64 + h.alpha) / denom)
            .collect();
        Inference { theta, counts, tokens }
    }

    /// The k largest θ̂ entries as `(topic, θ̂)`, mass descending with
    /// topic-id ascending as the deterministic tie-break.  (The order
    /// vector is usize: at the maximum legal T = 65536, a u16 range
    /// would wrap to empty.)
    pub fn top_topics(&self, k: usize) -> Vec<(u16, f64)> {
        let mut order: Vec<usize> = (0..self.theta.len()).collect();
        order.sort_unstable_by(|&a, &b| {
            self.theta[b].total_cmp(&self.theta[a]).then(a.cmp(&b))
        });
        order.truncate(k);
        order.into_iter().map(|t| (t as u16, self.theta[t])).collect()
    }
}

/// One independent unit of batched inference work: a document and its
/// own options (each concurrent client picks its own sweeps/seed).
#[derive(Clone, Debug)]
pub struct InferJob {
    pub tokens: Vec<u32>,
    pub opts: InferOpts,
}

/// Held-out score of one document (the second half, given the first).
#[derive(Clone, Copy, Debug)]
pub struct HeldOutScore {
    /// Σ log p(w | θ̂, φ̂) over the held-out tokens
    pub log_likelihood: f64,
    pub held_tokens: usize,
}

/// Per-thread fold-in sampler over one frozen model: the `q` F+tree, the
/// sparse `r` scratch, and the assignment scratch, all reused across
/// documents without reallocating.
pub struct Inferencer<'m> {
    model: &'m TopicModel,
    /// `n̂_t + β̄` per topic (frozen denominators)
    denom: Vec<f64>,
    /// `α/(n̂_t + β̄)` — the outside-document leaf value of the q tree
    base: Vec<f64>,
    tree: FTree,
    r: SparseCumSum,
    /// assignment scratch for the current document
    z: Vec<u16>,
}

impl<'m> Inferencer<'m> {
    pub fn new(model: &'m TopicModel) -> Inferencer<'m> {
        let h = model.hyper();
        let bb = model.betabar();
        let denom: Vec<f64> =
            (0..h.t).map(|t| model.topic_total(t) as f64 + bb).collect();
        let base: Vec<f64> = denom.iter().map(|&d| h.alpha / d).collect();
        let tree = FTree::with_capacity(&base, h.t);
        Inferencer {
            model,
            denom,
            base,
            tree,
            r: SparseCumSum::with_capacity(64),
            z: Vec::new(),
        }
    }

    pub fn model(&self) -> &'m TopicModel {
        self.model
    }

    /// The core fold-in loop: Gibbs over `tokens` with φ̂ frozen, starting
    /// from a uniform-random assignment drawn from `rng`.  Returns the
    /// final `n_td`.  Errors (without sampling) on token ids outside the
    /// model vocabulary.
    pub fn fold_in(
        &mut self,
        tokens: &[u32],
        sweeps: usize,
        rng: &mut Pcg32,
    ) -> Result<SparseCounts, String> {
        let model = self.model;
        let t = model.num_topics();
        let vocab = model.vocab();
        if let Some(&w) = tokens.iter().find(|&&w| w as usize >= vocab) {
            return Err(format!("token id {w} >= model vocabulary {vocab}"));
        }
        let h = model.hyper();
        let mut counts = SparseCounts::with_capacity(tokens.len().min(t));
        self.z.clear();
        for _ in tokens {
            let topic = rng.below(t) as u16;
            self.z.push(topic);
            counts.inc(topic);
        }
        // enter the document: raise the support leaves from base to q_t
        for (topic, c) in counts.iter() {
            let q = (c as f64 + h.alpha) / self.denom[topic as usize];
            self.tree.set(topic as usize, q);
        }
        for _ in 0..sweeps {
            for (j, &w) in tokens.iter().enumerate() {
                let old = self.z[j];
                counts.dec(old);
                let q_old = (counts.get(old) as f64 + h.alpha) / self.denom[old as usize];
                self.tree.set(old as usize, q_old);

                // r term over the frozen word support, using fresh q leaves
                self.r.clear();
                for (topic, c) in model.word_row(w as usize).iter() {
                    self.r.push(topic as u32, c as f64 * self.tree.leaf(topic as usize));
                }
                let r_total = self.r.total();

                let u = rng.uniform(h.beta * self.tree.total() + r_total);
                let new = if u < r_total {
                    self.r.sample(u) as u16
                } else {
                    self.tree.descend((u - r_total) / h.beta) as u16
                };

                counts.inc(new);
                self.z[j] = new;
                let q_new = (counts.get(new) as f64 + h.alpha) / self.denom[new as usize];
                self.tree.set(new as usize, q_new);
            }
        }
        // leave the document: lower the final support back to base (any
        // topic whose count hit zero mid-document already holds base —
        // q with n_td = 0 *is* the base formula)
        for (topic, _) in counts.iter() {
            let b = self.base[topic as usize];
            self.tree.set(topic as usize, b);
        }
        Ok(counts)
    }

    /// Infer θ̂ for one unseen document with the per-document RNG stream
    /// `(opts.seed, index)` — the determinism contract of [`infer_batch`].
    pub fn infer_doc_indexed(
        &mut self,
        tokens: &[u32],
        index: u64,
        opts: &InferOpts,
    ) -> Result<Inference, String> {
        let mut rng = Pcg32::new(opts.seed, index);
        let counts = self.fold_in(tokens, opts.sweeps, &mut rng)?;
        Ok(Inference::from_counts(self.model, counts, tokens.len()))
    }

    /// Infer θ̂ for one unseen document (document index 0's stream).
    pub fn infer_doc(&mut self, tokens: &[u32], opts: &InferOpts) -> Result<Inference, String> {
        self.infer_doc_indexed(tokens, 0, opts)
    }

    /// Run a whole batch of independent jobs through this one warm
    /// engine — the cross-connection batching entry point of the serving
    /// path.  The F+tree base build and all scratch buffers are paid once
    /// per engine, not once per job, and each job still draws from its
    /// own `(seed, 0)` stream, so every answer is bit-identical to a solo
    /// [`Self::infer_doc`] call with the same options (batch composition
    /// never leaks into results).  Per-job failures are per-slot `Err`s;
    /// one bad document never poisons its batch-mates.
    pub fn infer_jobs(&mut self, jobs: &[InferJob]) -> Vec<Result<Inference, String>> {
        jobs.iter().map(|job| self.infer_doc(&job.tokens, &job.opts)).collect()
    }

    /// Document-completion held-out score: fold in the first half of
    /// `tokens` using `rng`, then score the second half under the
    /// resulting θ̂ (see [`TopicModel::predictive_prob`]).
    pub fn score_doc_with(
        &mut self,
        tokens: &[u32],
        sweeps: usize,
        rng: &mut Pcg32,
    ) -> Result<HeldOutScore, String> {
        let half = tokens.len() / 2;
        let (observed, held) = tokens.split_at(half);
        if let Some(&w) = held.iter().find(|&&w| (w as usize) >= self.model.vocab()) {
            return Err(format!("token id {w} >= model vocabulary {}", self.model.vocab()));
        }
        let counts = self.fold_in(observed, sweeps, rng)?;
        let mut log_likelihood = 0.0f64;
        for &w in held {
            let pw = self.model.predictive_prob(&counts, half, w);
            log_likelihood += pw.max(1e-300).ln();
        }
        Ok(HeldOutScore { log_likelihood, held_tokens: held.len() })
    }

    /// [`Self::score_doc_with`] on the document's own seeded stream.
    pub fn score_doc(&mut self, tokens: &[u32], opts: &InferOpts) -> Result<HeldOutScore, String> {
        let mut rng = Pcg32::new(opts.seed, 0);
        self.score_doc_with(tokens, opts.sweeps, &mut rng)
    }
}

/// Infer every document of `corpus` against `model` on `threads` OS
/// threads.  Document i always uses the RNG stream `(opts.seed, i)`, so
/// the result is bit-identical across thread counts and runs.
pub fn infer_batch(
    model: &TopicModel,
    corpus: &Corpus,
    opts: &InferOpts,
    threads: usize,
) -> Result<Vec<Inference>, String> {
    if threads == 0 {
        return Err("infer_batch needs at least one thread".into());
    }
    if corpus.vocab() > model.vocab() {
        return Err(format!(
            "corpus vocabulary {} exceeds the model's {}",
            corpus.vocab(),
            model.vocab()
        ));
    }
    let n = corpus.num_docs();
    let mut out: Vec<Option<Inference>> = (0..n).map(|_| None).collect();
    if n == 0 {
        return Ok(Vec::new());
    }
    let chunk = n.div_ceil(threads);
    let result: Result<(), String> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (c, slots) in out.chunks_mut(chunk).enumerate() {
            handles.push(s.spawn(move || -> Result<(), String> {
                let mut inf = Inferencer::new(model);
                for (j, slot) in slots.iter_mut().enumerate() {
                    let doc = c * chunk + j;
                    *slot = Some(inf.infer_doc_indexed(&corpus.doc(doc), doc as u64, opts)?);
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join().map_err(|_| "inference thread panicked".to_string())??;
        }
        Ok(())
    });
    result?;
    Ok(out.into_iter().map(|o| o.expect("every doc inferred")).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;
    use crate::lda::state::{Hyper, LdaState};
    use crate::lda::{FLdaWord, Sweep};
    use crate::util::quickcheck::check;

    fn trained() -> (Corpus, TopicModel) {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(21);
        let mut state = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        let mut sweeper = FLdaWord::new(&state, &corpus);
        for _ in 0..10 {
            sweeper.sweep(&mut state, &corpus, &mut rng);
        }
        let model = TopicModel::from_state(&state, Vec::new());
        (corpus, model)
    }

    #[test]
    fn theta_is_a_distribution() {
        let (corpus, model) = trained();
        let mut inf = Inferencer::new(&model);
        let res = inf.infer_doc(&corpus.doc(0), &InferOpts::default()).unwrap();
        assert_eq!(res.theta.len(), model.num_topics());
        let sum: f64 = res.theta.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "theta sums to {sum}");
        assert_eq!(res.counts.total() as usize, corpus.doc(0).len());
        assert_eq!(res.tokens, corpus.doc(0).len());
        // top topics are sorted by mass and bounded
        let top = res.top_topics(3);
        assert_eq!(top.len(), 3);
        assert!(top[0].1 >= top[1].1 && top[1].1 >= top[2].1);
    }

    #[test]
    fn empty_doc_and_zero_sweeps_are_fine() {
        let (corpus, model) = trained();
        let mut inf = Inferencer::new(&model);
        let res = inf.infer_doc(&[], &InferOpts::default()).unwrap();
        // no evidence → the uniform prior mixture
        for &th in &res.theta {
            assert!((th - 1.0 / model.num_topics() as f64).abs() < 1e-12);
        }
        let res = inf
            .infer_doc(&corpus.doc(1), &InferOpts { sweeps: 0, seed: 5 })
            .unwrap();
        assert_eq!(res.counts.total() as usize, corpus.doc(1).len());
    }

    #[test]
    fn out_of_vocabulary_tokens_are_a_named_error() {
        let (_, model) = trained();
        let mut inf = Inferencer::new(&model);
        let bad = model.vocab() as u32;
        let err = inf.infer_doc(&[0, bad], &InferOpts::default()).unwrap_err();
        assert!(err.contains(&bad.to_string()), "error must name the token: {err}");
        let err = inf
            .score_doc(&[0, 1, bad, bad], &InferOpts::default())
            .unwrap_err();
        assert!(err.contains("vocabulary"), "unhelpful error: {err}");
    }

    /// Fixed seed ⇒ identical θ̂ across repeated calls and across fresh
    /// engines (the artifact determinism promise).
    #[test]
    fn fixed_seed_is_deterministic_across_runs() {
        let (corpus, model) = trained();
        let opts = InferOpts { sweeps: 7, seed: 99 };
        let mut a = Inferencer::new(&model);
        let mut b = Inferencer::new(&model);
        // warm engine `a` on other docs first: scratch reuse must not leak
        let _ = a.infer_doc(&corpus.doc(5), &opts).unwrap();
        let ra = a.infer_doc(&corpus.doc(0), &opts).unwrap();
        let rb = b.infer_doc(&corpus.doc(0), &opts).unwrap();
        assert_eq!(ra.theta, rb.theta);
        assert_eq!(ra.counts, rb.counts);
    }

    /// Thread counts must not change results: doc i's stream is
    /// `(seed, i)` regardless of which thread runs it.
    #[test]
    fn infer_batch_is_identical_across_thread_counts() {
        let (corpus, model) = trained();
        let opts = InferOpts { sweeps: 5, seed: 3 };
        let one = infer_batch(&model, &corpus, &opts, 1).unwrap();
        for threads in [2usize, 4, 7] {
            let many = infer_batch(&model, &corpus, &opts, threads).unwrap();
            assert_eq!(one.len(), many.len());
            for (i, (a, b)) in one.iter().zip(&many).enumerate() {
                assert_eq!(a.theta, b.theta, "doc {i} diverged at {threads} threads");
            }
        }
        // and doc 0 of the batch matches the single-doc entry point
        let mut inf = Inferencer::new(&model);
        let single = inf.infer_doc(&corpus.doc(0), &opts).unwrap();
        assert_eq!(single.theta, one[0].theta);
    }

    /// Batched jobs on a shared warm engine must answer exactly like solo
    /// calls on fresh engines — batch composition never leaks into θ̂, and
    /// a failing job leaves its batch-mates untouched.
    #[test]
    fn infer_jobs_match_solo_calls_and_isolate_failures() {
        let (corpus, model) = trained();
        let jobs: Vec<InferJob> = (0..6)
            .map(|d| InferJob {
                tokens: corpus.doc(d).to_vec(),
                opts: InferOpts { sweeps: 3 + d, seed: 100 + d as u64 },
            })
            .collect();
        let mut engine = Inferencer::new(&model);
        let batched = engine.infer_jobs(&jobs);
        assert_eq!(batched.len(), jobs.len());
        for (job, got) in jobs.iter().zip(&batched) {
            let mut solo = Inferencer::new(&model);
            let want = solo.infer_doc(&job.tokens, &job.opts).unwrap();
            assert_eq!(got.as_ref().unwrap().theta, want.theta);
        }
        // an OOV job fails alone; its neighbors still answer correctly
        let mixed = vec![
            jobs[0].clone(),
            InferJob { tokens: vec![model.vocab() as u32], opts: jobs[1].opts },
            jobs[2].clone(),
        ];
        let res = engine.infer_jobs(&mixed);
        assert!(res[0].is_ok() && res[2].is_ok());
        assert!(res[1].as_ref().unwrap_err().contains("vocabulary"));
        assert_eq!(res[0].as_ref().unwrap().theta, batched[0].as_ref().unwrap().theta);
    }

    /// After every document the q tree must be back at the base leaves —
    /// the enter/leave discipline that keeps per-doc cost at
    /// O(|T_d| log T) instead of a Θ(T) refill.
    #[test]
    fn tree_returns_to_base_after_each_doc() {
        let (corpus, model) = trained();
        let mut inf = Inferencer::new(&model);
        for d in 0..10 {
            let _ = inf.infer_doc(&corpus.doc(d), &InferOpts::default()).unwrap();
            for t in 0..model.num_topics() {
                let got = inf.tree.leaf(t);
                let want = inf.base[t];
                assert!(
                    (got - want).abs() < 1e-12 * want.max(1e-300),
                    "doc {d} leaf {t}: {got} vs base {want}"
                );
            }
        }
    }

    /// Single-site correctness: for a one-token document with one sweep,
    /// the resampled topic's distribution is exactly φ̂ normalized (the
    /// conditional with the token removed is (0 + α)·φ̂_t(w)).  This pins
    /// the q/r decomposition against the dense model estimate.
    #[test]
    fn single_token_fold_in_matches_dense_conditional() {
        let (_, model) = trained();
        check("fold-in single-site distribution == φ̂", 4, |rng| {
            let w = rng.below(model.vocab()) as u32;
            let t = model.num_topics();
            let p: Vec<f64> = (0..t).map(|k| model.phi(k as u16, w as usize)).collect();
            let total: f64 = p.iter().sum();
            let mut inf = Inferencer::new(&model);
            let draws = 30_000;
            let mut freq = vec![0usize; t];
            let mut doc_rng = Pcg32::new(rng.next_u64(), 17);
            for _ in 0..draws {
                let counts = inf.fold_in(&[w], 1, &mut doc_rng).unwrap();
                let (topic, c) = counts.iter().next().unwrap();
                assert_eq!(c, 1);
                freq[topic as usize] += 1;
            }
            for (k, (&f, &pk)) in freq.iter().zip(&p).enumerate() {
                let want = pk / total;
                let got = f as f64 / draws as f64;
                let tol = 4.5 * (want.max(1e-4) / draws as f64).sqrt();
                if (got - want).abs() > tol {
                    return Err(format!(
                        "word {w} topic {k}: freq {got} vs φ̂ {want} (tol {tol})"
                    ));
                }
            }
            Ok(())
        });
    }

    /// T = 65536 is legal (u16::MAX + 1 topics): top_topics must not
    /// wrap its index range to empty.
    #[test]
    fn top_topics_survive_the_maximum_topic_count() {
        let t = u16::MAX as usize + 1;
        let mut theta = vec![1.0 / t as f64; t];
        theta[65_535] = 0.5;
        theta[7] = 0.25;
        let inf = Inference { theta, counts: SparseCounts::default(), tokens: 0 };
        let top = inf.top_topics(2);
        assert_eq!(top.len(), 2);
        assert_eq!(top[0].0, u16::MAX);
        assert_eq!(top[1].0, 7);
    }

    #[test]
    fn score_doc_is_finite_and_negative() {
        let (corpus, model) = trained();
        let mut inf = Inferencer::new(&model);
        let score = inf.score_doc(&corpus.doc(2), &InferOpts::default()).unwrap();
        assert_eq!(score.held_tokens, corpus.doc(2).len() - corpus.doc(2).len() / 2);
        assert!(score.log_likelihood.is_finite());
        assert!(score.log_likelihood < 0.0);
        // better than the uniform-over-vocab baseline on in-domain text
        let uniform = -(model.vocab() as f64).ln() * score.held_tokens as f64;
        assert!(
            score.log_likelihood > uniform,
            "trained score {} not better than uniform {uniform}",
            score.log_likelihood
        );
    }

    #[test]
    fn infer_batch_rejects_mismatched_vocab_and_zero_threads() {
        let (corpus, model) = trained();
        assert!(infer_batch(&model, &corpus, &InferOpts::default(), 0)
            .unwrap_err()
            .contains("thread"));
        // same documents under a declared vocab one wider than the model's
        let mut wide =
            Corpus::with_meta(model.vocab() + 1, Vec::new(), "wide".to_string());
        for doc in corpus.docs() {
            wide.push_doc(&doc);
        }
        assert!(infer_batch(&model, &wide, &InferOpts::default(), 2)
            .unwrap_err()
            .contains("vocabulary"));
    }
}
