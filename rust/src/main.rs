//! `fnomad-lda` — the F+Nomad LDA launcher.
//!
//! Subcommands (each supports `--help` for its full flag list):
//!   train           train a topic model (any runtime/sampler)
//!   prepare-corpus  stream a text/bag-of-words/preset source into an .fncorpus file
//!   data-stats      print Table-3-style statistics for presets / UCI files
//!   calibrate       measure the per-token cost model for the simulator
//!   topics          train briefly and print the top words per topic
//!   check-artifacts cross-check the PJRT evaluator vs the Rust reference
//!   serve-worker    host a nomad ring worker over TCP for `train --remote`
//!   export-model    freeze a checkpoint into a `.fnmodel` serving artifact
//!   serve-model     host a model query server over TCP
//!   infer           fold-in inference for one document (local or remote)
//!   bench           train/infer micro-benchmarks → BENCH_*.json
//!   help            the top-level index
//!
//! Flag strings are parsed into the typed [`TrainConfig`] here and nowhere
//! else; the coordinator never sees a string it has to re-interpret.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use fnomad_lda::coordinator::{train, TrainConfig};
use fnomad_lda::corpus::presets::{preset, PAPER_TABLE3, PRESET_NAMES};
use fnomad_lda::corpus::{bow, presets, synthetic, text, CorpusStats, FncorpusWriter};
use fnomad_lda::infer::{
    infer_batch, model_id_for, query_one, serve_model, Client, InferOpts, Inferencer, ModelHost,
    ModelSlot, Request, Response, ServeConfig, TopicModel,
};
use fnomad_lda::lda::state::{Hyper, LdaState};
use fnomad_lda::lda::{self, topics as topics_mod};
use fnomad_lda::nomad::net::{serve, ServeOpts};
use fnomad_lda::runtime::{artifacts_available, default_artifact_dir, LlEvaluator};
use fnomad_lda::simnet::CostModel;
use fnomad_lda::util::bench::{percentile, write_json, JsonVal};
use fnomad_lda::util::cli::{Args, CommandSpec, FlagSpec};
use fnomad_lda::util::rng::Pcg32;

const BINARY: &str = "fnomad-lda";

const TRAIN_SPEC: CommandSpec = CommandSpec {
    name: "train",
    about: "train a topic model (any runtime/sampler)",
    flags: &[
        FlagSpec {
            flag: "preset",
            value: "NAME",
            help: "corpus: tiny|enron-sim|nytimes-sim|pubmed-sim|amazon-sim|umbc-sim",
        },
        FlagSpec {
            flag: "corpus",
            value: "PATH",
            help: "train from an .fncorpus file (see prepare-corpus) instead of a preset",
        },
        FlagSpec {
            flag: "in-ram",
            value: "",
            help: "load --corpus fully into RAM instead of streaming it",
        },
        FlagSpec {
            flag: "corpus-window",
            value: "TOKENS",
            help: "sliding read-window for streamed corpora (default 1048576 tokens)",
        },
        FlagSpec {
            flag: "topics",
            value: "N",
            help: "topic count T (default 128; artifacts exist for 128 and 1024)",
        },
        FlagSpec {
            flag: "runtime",
            value: "KIND",
            help: "serial|nomad|ps|adlda|nomad-sim|ps-sim",
        },
        FlagSpec {
            flag: "sampler",
            value: "KIND",
            help: "plain|sparse|alias|flda-doc|flda-word (serial runtime)",
        },
        FlagSpec { flag: "workers", value: "P", help: "worker threads / simulated cores" },
        FlagSpec {
            flag: "remote",
            value: "ADDRS",
            help: "comma-separated serve-worker host:port list joining the nomad ring",
        },
        FlagSpec {
            flag: "machines",
            value: "M",
            help: "simulated machines (sim runtimes; M machines x 20 cores)",
        },
        FlagSpec { flag: "iters", value: "N", help: "training epochs" },
        FlagSpec { flag: "seed", value: "S", help: "RNG seed" },
        FlagSpec { flag: "eval", value: "POLICY", help: "auto|xla|rust evaluator backend" },
        FlagSpec { flag: "eval-every", value: "K", help: "evaluate every K epochs" },
        FlagSpec { flag: "batch-docs", value: "B", help: "PS pull/push cadence in documents" },
        FlagSpec { flag: "disk", value: "", help: "PS disk flavor (sim only)" },
        FlagSpec { flag: "out", value: "PATH", help: "write the convergence series as CSV" },
        FlagSpec { flag: "checkpoint", value: "PATH", help: "checkpoint file (written at finish)" },
        FlagSpec {
            flag: "save-every",
            value: "N",
            help: "also checkpoint every N epochs (at evaluation points)",
        },
        FlagSpec { flag: "resume", value: "", help: "start from --checkpoint if it exists" },
        FlagSpec {
            flag: "checkpoint-dir",
            value: "DIR",
            help: "async checkpoint service: retained snapshots + MANIFEST in DIR",
        },
        FlagSpec {
            flag: "keep",
            value: "K",
            help: "snapshots retained under --checkpoint-dir (default 3)",
        },
        FlagSpec {
            flag: "max-restarts",
            value: "N",
            help: "nomad only: ring rebuilds from the latest snapshot before giving up",
        },
        FlagSpec {
            flag: "hyper-opt",
            value: "N",
            help: "N Minka fixed-point steps on the final state (0 = off)",
        },
        FlagSpec {
            flag: "metrics",
            value: "FILE",
            help: "append one JSON metrics object per epoch to FILE (JSONL)",
        },
        FlagSpec {
            flag: "trace",
            value: "FILE",
            help: "write a Chrome-trace-event JSON timeline to FILE (load in Perfetto)",
        },
        FlagSpec {
            flag: "log-level",
            value: "LEVEL",
            help: "event filter: error|warn|info|debug (default info)",
        },
        FlagSpec { flag: "log-json", value: "", help: "emit events as JSONL instead of text" },
        FlagSpec { flag: "quiet", value: "", help: "suppress progress logging" },
    ],
};

const PREPARE_CORPUS_SPEC: CommandSpec = CommandSpec {
    name: "prepare-corpus",
    about: "stream a text/bag-of-words/preset source into an .fncorpus file",
    flags: &[
        FlagSpec {
            flag: "text",
            value: "PATH",
            help: "newline-delimited raw text: tokenize/stem/prune, one doc per line",
        },
        FlagSpec {
            flag: "bow",
            value: "PATH",
            help: "UCI docword.txt bag-of-words file (sorted by docID)",
        },
        FlagSpec {
            flag: "vocab",
            value: "PATH",
            help: "UCI vocab.txt word list embedded alongside --bow",
        },
        FlagSpec {
            flag: "preset",
            value: "NAME",
            help: "stream a synthetic preset (e.g. bigzipf) without materializing it",
        },
        FlagSpec {
            flag: "docs",
            value: "N",
            help: "override the preset's document count (smoke-scale runs)",
        },
        FlagSpec { flag: "name", value: "NAME", help: "corpus name recorded in the header" },
        FlagSpec { flag: "out", value: "PATH", help: "output .fncorpus path (required)" },
    ],
};

const DATA_STATS_SPEC: CommandSpec = CommandSpec {
    name: "data-stats",
    about: "print Table 3 for our datasets",
    flags: &[FlagSpec { flag: "preset", value: "NAME|all", help: "which preset (default all)" }],
};

const CALIBRATE_SPEC: CommandSpec = CommandSpec {
    name: "calibrate",
    about: "measure ns/token -> simulator cost model",
    flags: &[
        FlagSpec { flag: "preset", value: "NAME", help: "corpus preset (default tiny)" },
        FlagSpec { flag: "topics", value: "N", help: "topic count (default 128)" },
        FlagSpec { flag: "sweeps", value: "N", help: "measurement sweeps (default 2)" },
    ],
};

const TOPICS_SPEC: CommandSpec = CommandSpec {
    name: "topics",
    about: "train briefly and print the top words per topic",
    flags: &[
        FlagSpec { flag: "preset", value: "NAME", help: "corpus preset (default tiny)" },
        FlagSpec { flag: "topics", value: "N", help: "topic count (default 16)" },
        FlagSpec { flag: "iters", value: "N", help: "training epochs (default 20)" },
        FlagSpec { flag: "top", value: "K", help: "words per topic (default 8)" },
    ],
};

const CHECK_ARTIFACTS_SPEC: CommandSpec = CommandSpec {
    name: "check-artifacts",
    about: "blocked evaluator (PJRT with --features pjrt, pure Rust otherwise) vs Rust reference",
    flags: &[FlagSpec { flag: "topics", value: "N", help: "topic count (default 128)" }],
};

const SERVE_WORKER_SPEC: CommandSpec = CommandSpec {
    name: "serve-worker",
    about: "host a nomad ring worker over TCP (the remote end of train --remote)",
    flags: &[
        FlagSpec {
            flag: "listen",
            value: "ADDR",
            help: "bind address (default 127.0.0.1:7777; port 0 picks a free port)",
        },
        FlagSpec { flag: "once", value: "", help: "serve one coordinator session, then exit" },
        FlagSpec { flag: "quiet", value: "", help: "suppress per-connection logging" },
        FlagSpec {
            flag: "log-level",
            value: "LEVEL",
            help: "event filter: error|warn|info|debug (default info)",
        },
        FlagSpec { flag: "log-json", value: "", help: "emit events as JSONL instead of text" },
    ],
};

const EXPORT_MODEL_SPEC: CommandSpec = CommandSpec {
    name: "export-model",
    about: "freeze a training checkpoint into a .fnmodel serving artifact",
    flags: &[
        FlagSpec {
            flag: "checkpoint",
            value: "PATH",
            help: "FNLDA001 checkpoint to freeze (required)",
        },
        FlagSpec {
            flag: "preset",
            value: "NAME",
            help: "corpus the checkpoint was trained on (default tiny)",
        },
        FlagSpec { flag: "out", value: "PATH", help: "output .fnmodel path (required)" },
        FlagSpec {
            flag: "no-vocab",
            value: "",
            help: "strip vocabulary strings (disables raw-text queries)",
        },
    ],
};

const SERVE_MODEL_SPEC: CommandSpec = CommandSpec {
    name: "serve-model",
    about: "host a model query server over TCP (the remote end of infer --remote)",
    flags: &[
        FlagSpec { flag: "model", value: "PATH", help: ".fnmodel artifact to serve (required)" },
        FlagSpec {
            flag: "listen",
            value: "ADDR",
            help: "bind address (default 127.0.0.1:7878; port 0 picks a free port)",
        },
        FlagSpec { flag: "threads", value: "N", help: "connection handler threads (default 4)" },
        FlagSpec { flag: "workers", value: "N", help: "inference worker threads (default 2)" },
        FlagSpec {
            flag: "batch-window-us",
            value: "US",
            help: "linger for more jobs per batch (default 0 = opportunistic drain)",
        },
        FlagSpec {
            flag: "queue-depth",
            value: "N",
            help: "bounded inference queue; full = named overload error (default 256)",
        },
        FlagSpec {
            flag: "cache",
            value: "N",
            help: "LRU answer-cache entries, 0 disables (default 1024)",
        },
        FlagSpec {
            flag: "read-deadline-secs",
            value: "S",
            help: "cut off silent connections after S seconds (default 300)",
        },
        FlagSpec { flag: "once", value: "", help: "serve one client connection, then exit" },
        FlagSpec { flag: "quiet", value: "", help: "suppress per-connection logging" },
        FlagSpec {
            flag: "log-level",
            value: "LEVEL",
            help: "event filter: error|warn|info|debug (default info)",
        },
        FlagSpec { flag: "log-json", value: "", help: "emit events as JSONL instead of text" },
    ],
};

const INFER_SPEC: CommandSpec = CommandSpec {
    name: "infer",
    about: "fold-in inference for one document, locally or against serve-model",
    flags: &[
        FlagSpec { flag: "remote", value: "ADDR", help: "query a serve-model host" },
        FlagSpec { flag: "model", value: "PATH", help: "infer locally from a .fnmodel" },
        FlagSpec { flag: "text", value: "STR", help: "raw text query (needs vocab strings)" },
        FlagSpec { flag: "tokens", value: "LIST", help: "comma-separated token ids, e.g. 3,17,42" },
        FlagSpec { flag: "sweeps", value: "N", help: "fold-in sweeps (default 20, max 1000)" },
        FlagSpec { flag: "seed", value: "S", help: "RNG seed (default 0)" },
        FlagSpec { flag: "top", value: "K", help: "topics on the theta_top line (default 10)" },
        FlagSpec { flag: "info", value: "", help: "print model shape + hyperparameters instead" },
        FlagSpec { flag: "top-words", value: "K", help: "print top-K words per topic instead" },
        FlagSpec { flag: "stats", value: "", help: "print the server's serving counters instead" },
        FlagSpec {
            flag: "reload",
            value: "PATH",
            help: "admin: hot-swap the server onto the artifact at PATH (server-local)",
        },
    ],
};

const BENCH_SPEC: CommandSpec = CommandSpec {
    name: "bench",
    about: "train + infer micro-benchmarks, emitting machine-readable BENCH_*.json",
    flags: &[
        FlagSpec { flag: "preset", value: "NAME", help: "corpus preset (default tiny)" },
        FlagSpec { flag: "topics", value: "N", help: "topic count (default 16)" },
        FlagSpec { flag: "iters", value: "N", help: "training epochs (default 3)" },
        FlagSpec { flag: "sweeps", value: "N", help: "fold-in sweeps per doc (default 10)" },
        FlagSpec { flag: "threads", value: "P", help: "inference threads (default 2)" },
        FlagSpec { flag: "out-dir", value: "PATH", help: "where BENCH_*.json land (default .)" },
    ],
};

const SPECS: &[&CommandSpec] = &[
    &TRAIN_SPEC,
    &PREPARE_CORPUS_SPEC,
    &DATA_STATS_SPEC,
    &CALIBRATE_SPEC,
    &TOPICS_SPEC,
    &CHECK_ARTIFACTS_SPEC,
    &SERVE_WORKER_SPEC,
    &EXPORT_MODEL_SPEC,
    &SERVE_MODEL_SPEC,
    &INFER_SPEC,
    &BENCH_SPEC,
];

fn top_level_help() -> String {
    let mut out = format!(
        "{BINARY} — F+Nomad LDA (WWW'15 reproduction)\n\nUSAGE: {BINARY} <subcommand> [--flags]\n\n"
    );
    for spec in SPECS {
        out.push_str(&spec.summary_line());
        out.push('\n');
    }
    out.push_str(&format!("\nRun `{BINARY} <subcommand> --help` for the full flag list.\n"));
    out
}

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{}", top_level_help());
            std::process::exit(2);
        }
    };
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    let code = match sub.as_str() {
        "train" => with_help(&args, &TRAIN_SPEC, cmd_train),
        "prepare-corpus" => with_help(&args, &PREPARE_CORPUS_SPEC, cmd_prepare_corpus),
        "data-stats" => with_help(&args, &DATA_STATS_SPEC, cmd_data_stats),
        "calibrate" => with_help(&args, &CALIBRATE_SPEC, cmd_calibrate),
        "topics" => with_help(&args, &TOPICS_SPEC, cmd_topics),
        "check-artifacts" => with_help(&args, &CHECK_ARTIFACTS_SPEC, cmd_check_artifacts),
        "serve-worker" => with_help(&args, &SERVE_WORKER_SPEC, cmd_serve_worker),
        "export-model" => with_help(&args, &EXPORT_MODEL_SPEC, cmd_export_model),
        "serve-model" => with_help(&args, &SERVE_MODEL_SPEC, cmd_serve_model),
        "infer" => with_help(&args, &INFER_SPEC, cmd_infer),
        "bench" => with_help(&args, &BENCH_SPEC, cmd_bench),
        "help" | "--help" | "-h" => {
            println!("{}", top_level_help());
            Ok(())
        }
        other => Err(format!("unknown subcommand '{other}'\n{}", top_level_help())),
    }
    .map(|_| 0)
    .unwrap_or_else(|e| {
        eprintln!("error: {e}");
        1
    });
    std::process::exit(code);
}

/// Render the subcommand's `--help` if asked, otherwise run it.
fn with_help(
    args: &Args,
    spec: &CommandSpec,
    cmd: fn(&Args) -> Result<(), String>,
) -> Result<(), String> {
    if args.help_requested() {
        println!("{}", spec.render(BINARY));
        Ok(())
    } else {
        cmd(args)
    }
}

/// Apply the shared `--log-level LEVEL` / `--log-json` event flags.
/// Process-global: every subcommand that emits structured events calls
/// this before its `reject_unknown`.
fn apply_log_flags(args: &Args) -> Result<(), String> {
    use fnomad_lda::obs::event;
    if let Some(v) = args.str_opt("log-level") {
        event::set_level(v.parse::<event::Level>()?);
    }
    if args.flag("log-json") {
        event::set_json(true);
    }
    Ok(())
}

/// The thin CLI → [`TrainConfig`] parse layer: every enum-valued flag goes
/// through `FromStr` exactly once, right here.
fn train_config(args: &Args) -> Result<TrainConfig, String> {
    let d = TrainConfig::default();
    let cfg = TrainConfig {
        preset: args.str_or("preset", &d.preset),
        corpus: args.str_opt("corpus").map(PathBuf::from),
        corpus_ram: args.flag("in-ram"),
        corpus_window: args.parse_or("corpus-window", d.corpus_window)?,
        topics: args.parse_or("topics", d.topics)?,
        sampler: args.str_or("sampler", &d.sampler.to_string()).parse()?,
        runtime: args.str_or("runtime", &d.runtime.to_string()).parse()?,
        workers: args.parse_or("workers", d.workers)?,
        remote: parse_remote(args)?,
        machines: args.parse_or("machines", d.machines)?,
        iters: args.parse_or("iters", d.iters)?,
        seed: args.parse_or("seed", d.seed)?,
        eval: args.str_or("eval", &d.eval.to_string()).parse()?,
        eval_every: args.parse_or("eval-every", d.eval_every)?,
        batch_docs: args.parse_or("batch-docs", d.batch_docs)?,
        disk: args.flag("disk"),
        out: args.str_opt("out").map(PathBuf::from),
        quiet: args.flag("quiet"),
        checkpoint: args.str_opt("checkpoint").map(PathBuf::from),
        save_every: args.parse_or("save-every", d.save_every)?,
        resume: args.flag("resume"),
        hyper_opt_steps: args.parse_or("hyper-opt", d.hyper_opt_steps)?,
        checkpoint_dir: args.str_opt("checkpoint-dir").map(PathBuf::from),
        keep: args.parse_or("keep", d.keep)?,
        max_restarts: args.parse_or("max-restarts", d.max_restarts)?,
        // fault injection is a library/test surface, never a CLI flag
        fault: d.fault,
        metrics: args.str_opt("metrics").map(PathBuf::from),
        trace: args.str_opt("trace").map(PathBuf::from),
    };
    apply_log_flags(args)?;
    args.reject_unknown()?;
    Ok(cfg)
}

/// `--remote host:port,host:port` → address list (empty when absent).
/// A present-but-empty value is an error: silently degrading a
/// distributed run to local-only would report success the user did not
/// ask for.
fn parse_remote(args: &Args) -> Result<Vec<String>, String> {
    match args.str_opt("remote") {
        None => Ok(Vec::new()),
        Some(v) => {
            let addrs: Vec<String> = v
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect();
            if addrs.is_empty() {
                return Err(format!("--remote '{v}' contains no worker addresses"));
            }
            Ok(addrs)
        }
    }
}

/// `prepare-corpus`: stream one of three sources into a versioned
/// `FNCP0001` file without ever holding the token payload in RAM.
fn cmd_prepare_corpus(args: &Args) -> Result<(), String> {
    let out = args.str_opt("out").ok_or_else(|| "--out PATH is required".to_string())?;
    let text_in = args.str_opt("text");
    let bow_in = args.str_opt("bow");
    let preset_in = args.str_opt("preset");
    let vocab_in = args.str_opt("vocab");
    let name_override = args.str_opt("name");
    let docs_override = match args.str_opt("docs") {
        None => None,
        Some(v) => {
            Some(v.parse::<usize>().map_err(|_| format!("--docs: cannot parse '{v}'"))?)
        }
    };
    args.reject_unknown()?;
    let sources =
        [&text_in, &bow_in, &preset_in].iter().filter(|s| s.is_some()).count();
    if sources != 1 {
        return Err("exactly one of --text, --bow, --preset selects the source".into());
    }
    if vocab_in.is_some() && bow_in.is_none() {
        return Err("--vocab only applies with --bow".into());
    }
    if docs_override.is_some() && preset_in.is_none() {
        return Err("--docs only applies with --preset".into());
    }
    let out_path = PathBuf::from(&out);
    let stem = |p: &Path| {
        p.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_else(|| "corpus".into())
    };

    let started = Instant::now();
    let (summary, skipped, name) = if let Some(path) = text_in {
        let p = PathBuf::from(&path);
        let name = name_override.unwrap_or_else(|| stem(&p));
        let (s, skipped) = text::stream_lines_to_fncorpus(
            &p,
            &text::PipelineOpts::default(),
            &name,
            &out_path,
        )?;
        (s, skipped, name)
    } else if let Some(path) = bow_in {
        let p = PathBuf::from(&path);
        let name = name_override.unwrap_or_else(|| stem(&p));
        let vocab = vocab_in.map(PathBuf::from);
        let (s, skipped) = bow::stream_to_fncorpus(&p, vocab.as_deref(), &name, &out_path)?;
        (s, skipped, name)
    } else {
        let pname = preset_in.expect("source checked above");
        let mut spec = presets::spec(&pname).ok_or_else(|| {
            format!("unknown preset '{pname}' (known: {})", PRESET_NAMES.join(", "))
        })?;
        if let Some(n) = docs_override {
            spec.num_docs = n;
        }
        if let Some(n) = name_override {
            spec.name = n;
        }
        let mut writer = FncorpusWriter::create(&out_path, spec.vocab, Vec::new(), &spec.name)?;
        synthetic::generate_with(&spec, |d| writer.push_doc(d))?;
        let s = writer.finish()?;
        (s, 0usize, spec.name)
    };
    println!(
        "wrote {out} (name={name}, docs={}, tokens={}, {} bytes, fingerprint {:016x}, \
         {skipped} empty docs skipped, {:.1}s)",
        summary.num_docs,
        summary.num_tokens,
        summary.bytes,
        summary.fingerprint,
        started.elapsed().as_secs_f64(),
    );
    Ok(())
}

fn cmd_serve_worker(args: &Args) -> Result<(), String> {
    use std::io::Write as _;

    let addr = args.str_or("listen", "127.0.0.1:7777");
    // --fail-after-epochs is deliberately absent from the help spec: it
    // exists so the resilience tests and CI chaos smoke can kill a real
    // worker process mid-epoch on a deterministic schedule
    let fail_after_epochs = match args.str_opt("fail-after-epochs") {
        None => None,
        Some(v) => Some(
            v.parse::<u32>().map_err(|_| format!("--fail-after-epochs: cannot parse '{v}'"))?,
        ),
    };
    let opts = ServeOpts { once: args.flag("once"), quiet: args.flag("quiet"), fail_after_epochs };
    apply_log_flags(args)?;
    args.reject_unknown()?;
    let listener = std::net::TcpListener::bind(&addr).map_err(|e| format!("bind {addr}: {e}"))?;
    let local = listener.local_addr().map_err(|e| e.to_string())?;
    // machine-readable line launch scripts / tests parse for the port
    println!("listening on {local}");
    std::io::stdout().flush().map_err(|e| e.to_string())?;
    serve(listener, &opts)
}

fn cmd_export_model(args: &Args) -> Result<(), String> {
    let ckpt = args
        .str_opt("checkpoint")
        .ok_or_else(|| "--checkpoint PATH is required".to_string())?;
    let preset_name = args.str_or("preset", "tiny");
    let out = args.str_opt("out").ok_or_else(|| "--out PATH is required".to_string())?;
    let no_vocab = args.flag("no-vocab");
    args.reject_unknown()?;
    let corpus = preset(&preset_name)?;
    let state = lda::checkpoint::load(Path::new(&ckpt), &corpus)?;
    let words = if no_vocab { Vec::new() } else { corpus.vocab_words().to_vec() };
    let model = TopicModel::from_state(&state, words);
    let bytes = model.save(Path::new(&out))?;
    println!(
        "exported {out} (T={}, vocab={}, tokens={}, vocab_strings={}, {bytes} bytes)",
        model.num_topics(),
        model.vocab(),
        model.total_tokens(),
        !model.vocab_words().is_empty(),
    );
    Ok(())
}

fn cmd_serve_model(args: &Args) -> Result<(), String> {
    use std::io::Write as _;

    let model_path =
        args.str_opt("model").ok_or_else(|| "--model PATH is required".to_string())?;
    let addr = args.str_or("listen", "127.0.0.1:7878");
    // the CLI → ServeConfig parse edge: flag strings become typed knobs
    // exactly once, mirroring train_config
    let cfg = ServeConfig::default()
        .threads(args.parse_or("threads", 4)?)
        .workers(args.parse_or("workers", 2)?)
        .batch_window(Duration::from_micros(args.parse_or("batch-window-us", 0u64)?))
        .queue_depth(args.parse_or("queue-depth", 256)?)
        .cache_capacity(args.parse_or("cache", 1024)?)
        .read_deadline(Duration::from_secs(args.parse_or("read-deadline-secs", 300u64)?))
        .once(args.flag("once"))
        .quiet(args.flag("quiet"));
    apply_log_flags(args)?;
    args.reject_unknown()?;
    cfg.validate()?;
    let model = TopicModel::load(Path::new(&model_path))?;
    let id = model_id_for(Path::new(&model_path), &model);
    if !cfg.quiet {
        eprintln!(
            "[serve-model] loaded {id}: T={} vocab={} tokens={}",
            model.num_topics(),
            model.vocab(),
            model.total_tokens(),
        );
    }
    let listener = std::net::TcpListener::bind(&addr).map_err(|e| format!("bind {addr}: {e}"))?;
    let local = listener.local_addr().map_err(|e| e.to_string())?;
    // machine-readable line launch scripts / tests parse for the port
    println!("listening on {local}");
    std::io::stdout().flush().map_err(|e| e.to_string())?;
    serve_model(listener, Arc::new(ModelSlot::new(ModelHost::new(model), id)), &cfg)
}

fn cmd_infer(args: &Args) -> Result<(), String> {
    let remote = args.str_opt("remote");
    let model_path = args.str_opt("model");
    let text = args.str_opt("text");
    let tokens_arg = args.str_opt("tokens");
    let sweeps: u32 = args.parse_or("sweeps", 20)?;
    let seed: u64 = args.parse_or("seed", 0)?;
    let top: usize = args.parse_or("top", 10)?;
    let info = args.flag("info");
    let top_words: u32 = args.parse_or("top-words", 0)?;
    let stats = args.flag("stats");
    let reload = args.str_opt("reload");
    args.reject_unknown()?;

    let req = if info {
        Request::ModelInfo
    } else if stats {
        Request::Stats
    } else if let Some(path) = reload {
        Request::ReloadModel { path }
    } else if top_words > 0 {
        Request::TopWords { k: top_words }
    } else if let Some(text) = text {
        Request::InferText { text, sweeps, seed }
    } else if let Some(list) = tokens_arg {
        let tokens = list
            .split(',')
            .map(|s| {
                s.trim().parse::<u32>().map_err(|_| format!("--tokens: bad token id '{s}'"))
            })
            .collect::<Result<Vec<_>, _>>()?;
        Request::InferTokens { tokens, sweeps, seed }
    } else {
        return Err(
            "one of --text, --tokens, --info, --top-words, --stats, or --reload is required"
                .into(),
        );
    };

    let resp = match (remote, model_path) {
        (Some(addr), None) => query_one(&addr, &req)?,
        (None, Some(path)) => ModelHost::new(TopicModel::load(Path::new(&path))?).answer(req),
        _ => return Err("exactly one of --remote ADDR or --model PATH is required".into()),
    };
    render_infer_response(resp, top)
}

/// Render a query answer; the `theta_top:` line is the machine-greppable
/// contract CI and scripts rely on (`topic:mass` pairs, mass descending).
fn render_infer_response(resp: Response, top: usize) -> Result<(), String> {
    match resp {
        Response::Theta { theta, used_tokens, model_version } => {
            let mut order: Vec<usize> = (0..theta.len()).collect();
            order.sort_unstable_by(|&a, &b| theta[b].total_cmp(&theta[a]).then(a.cmp(&b)));
            // the version goes on this line, never on theta_top: remote
            // (v >= 1) and local (v = 0) answers for the same query must
            // produce byte-identical theta_top lines
            println!(
                "used_tokens = {used_tokens}   T = {}   model_version = {model_version}",
                theta.len()
            );
            let mut line = String::from("theta_top:");
            for &t in order.iter().take(top.max(1)) {
                line.push_str(&format!(" {t}:{:.4}", theta[t]));
            }
            println!("{line}");
            Ok(())
        }
        Response::ModelInfo {
            topics,
            vocab,
            alpha,
            beta,
            total_tokens,
            has_vocab,
            model_version,
            model_id,
        } => {
            println!(
                "model: T={topics} vocab={vocab} alpha={alpha:.6} beta={beta:.6} \
                 tokens={total_tokens} vocab_strings={has_vocab} version={model_version} \
                 id={model_id}"
            );
            Ok(())
        }
        Response::Stats(s) => {
            println!(
                "serve_stats: qps={:.2} total={} infer={} errors={} cache_hit_rate={:.4} \
                 p50_us={:.1} p95_us={:.1} p99_us={:.1}",
                s.qps,
                s.total_requests,
                s.infer_requests,
                s.errors,
                s.cache_hit_rate,
                s.p50_us,
                s.p95_us,
                s.p99_us,
            );
            println!(
                "serve_state: uptime_s={:.1} queue_depth={} queue_cap={} batches={} \
                 batched_docs={} max_batch={} batch_fill={:.4} model_version={} swaps={}",
                s.uptime_secs,
                s.queue_depth,
                s.queue_cap,
                s.batches,
                s.batched_docs,
                s.max_batch,
                s.batch_fill,
                s.model_version,
                s.model_swaps,
            );
            Ok(())
        }
        Response::Reloaded { model_version, model_id, topics, vocab } => {
            println!("reloaded: version={model_version} id={model_id} T={topics} vocab={vocab}");
            Ok(())
        }
        Response::TopWords { topics } => {
            for (t, row) in topics.iter().enumerate() {
                let mut line = format!("topic {t:4}: ");
                for w in row {
                    if w.text.is_empty() {
                        line.push_str(&format!("w{}:{} ", w.word, w.count));
                    } else {
                        line.push_str(&format!("{}:{} ", w.text, w.count));
                    }
                }
                println!("{}", line.trim_end());
            }
            Ok(())
        }
        Response::Err(e) => Err(format!("server error: {e}")),
    }
}

fn cmd_bench(args: &Args) -> Result<(), String> {
    let preset_name = args.str_or("preset", "tiny");
    let topics: usize = args.parse_or("topics", 16)?;
    let iters: usize = args.parse_or("iters", 3)?;
    let sweeps: usize = args.parse_or("sweeps", 10)?;
    let threads: usize = args.parse_or("threads", 2)?;
    let out_dir = PathBuf::from(args.str_or("out-dir", "."));
    args.reject_unknown()?;

    let corpus = preset(&preset_name)?;
    let cfg = TrainConfig::preset(&preset_name)
        .topics(topics)
        .iters(iters)
        .eval(fnomad_lda::coordinator::EvalPolicy::Rust)
        .quiet(true);
    let res = train(&cfg)?;
    let train_path = out_dir.join("BENCH_train.json");
    write_json(
        &train_path,
        &[
            ("bench", JsonVal::Str("train".into())),
            ("label", JsonVal::Str(cfg.label())),
            ("preset", JsonVal::Str(preset_name.clone())),
            ("topics", JsonVal::Int(topics as u64)),
            ("iters", JsonVal::Int(iters as u64)),
            ("tokens", JsonVal::Int(corpus.num_tokens() as u64)),
            ("tokens_per_sec", JsonVal::Num(res.tokens_per_sec)),
            ("final_ll", JsonVal::Num(res.ll_vs_iter.last_y().unwrap_or(f64::NAN))),
        ],
    )?;

    let model = TopicModel::from_state(&res.final_state, Vec::new());
    let opts = InferOpts { sweeps, seed: 0 };
    // throughput: the multi-threaded batch path
    let t0 = Instant::now();
    infer_batch(&model, &corpus, &opts, threads.max(1))?;
    let batch_secs = t0.elapsed().as_secs_f64();
    // latency: single-threaded per-document timing for honest p50/p95
    let mut inf = Inferencer::new(&model);
    let mut lat_us: Vec<f64> = Vec::with_capacity(corpus.num_docs());
    for d in 0..corpus.num_docs() {
        let s = Instant::now();
        inf.infer_doc_indexed(&corpus.doc(d), d as u64, &opts)?;
        lat_us.push(s.elapsed().as_nanos() as f64 / 1e3);
    }
    lat_us.sort_by(|a, b| a.total_cmp(b));
    let p50 = percentile(&lat_us, 50.0);
    let p95 = percentile(&lat_us, 95.0);
    let p99 = percentile(&lat_us, 99.0);
    let infer_tps =
        if batch_secs > 0.0 { corpus.num_tokens() as f64 / batch_secs } else { 0.0 };

    // serving path: a loopback server with the full batching core, hit
    // with two passes over the same documents (pass two exercises the
    // answer cache), then its own Stats counters read back over the wire
    let listener =
        std::net::TcpListener::bind("127.0.0.1:0").map_err(|e| format!("bench bind: {e}"))?;
    let addr = listener.local_addr().map_err(|e| e.to_string())?.to_string();
    let serve_cfg = ServeConfig::default()
        .threads(threads.max(1))
        .workers(threads.max(1))
        .quiet(true);
    let slot = Arc::new(ModelSlot::new(
        ModelHost::new(model.clone()),
        format!("bench@{:016x}", model.fingerprint()),
    ));
    std::thread::spawn(move || {
        let _ = serve_model(listener, slot, &serve_cfg);
    });
    let mut client = Client::connect(&addr)?;
    let serve_docs = corpus.num_docs().min(200);
    for _pass in 0..2 {
        for d in 0..serve_docs {
            let req = Request::InferTokens {
                tokens: corpus.doc(d).to_vec(),
                sweeps: sweeps as u32,
                seed: 0,
            };
            if let Response::Err(e) = client.query(&req)? {
                return Err(format!("bench serving query failed: {e}"));
            }
        }
    }
    let stats = match client.query(&Request::Stats)? {
        Response::Stats(s) => s,
        other => return Err(format!("bench expected Stats, got {other:?}")),
    };
    drop(client);

    let infer_path = out_dir.join("BENCH_infer.json");
    write_json(
        &infer_path,
        &[
            ("bench", JsonVal::Str("infer".into())),
            ("preset", JsonVal::Str(preset_name.clone())),
            ("topics", JsonVal::Int(topics as u64)),
            ("sweeps", JsonVal::Int(sweeps as u64)),
            ("threads", JsonVal::Int(threads as u64)),
            ("docs", JsonVal::Int(corpus.num_docs() as u64)),
            ("tokens", JsonVal::Int(corpus.num_tokens() as u64)),
            ("tokens_per_sec", JsonVal::Num(infer_tps)),
            ("p50_us", JsonVal::Num(p50)),
            ("p95_us", JsonVal::Num(p95)),
            ("p99_us", JsonVal::Num(p99)),
            ("serve_docs", JsonVal::Int(2 * serve_docs as u64)),
            ("serve_qps", JsonVal::Num(stats.qps)),
            ("serve_p50_us", JsonVal::Num(stats.p50_us)),
            ("serve_p95_us", JsonVal::Num(stats.p95_us)),
            ("serve_p99_us", JsonVal::Num(stats.p99_us)),
            ("cache_hit_rate", JsonVal::Num(stats.cache_hit_rate)),
        ],
    )?;
    println!(
        "train: {:.0} tokens/s   infer: {:.0} tokens/s   p50 {p50:.1} µs/doc   \
         p95 {p95:.1} µs/doc",
        res.tokens_per_sec, infer_tps,
    );
    println!(
        "serve: {:.0} qps   p50 {:.1} µs   p95 {:.1} µs   p99 {:.1} µs   \
         cache hit rate {:.2}",
        stats.qps, stats.p50_us, stats.p95_us, stats.p99_us, stats.cache_hit_rate,
    );
    println!("wrote {} and {}", train_path.display(), infer_path.display());
    Ok(())
}

fn cmd_train(args: &Args) -> Result<(), String> {
    let cfg = train_config(args)?;
    let res = train(&cfg)?;
    println!(
        "final LL = {:.6e}   throughput = {:.0} tokens/s ({} runtime)",
        res.ll_vs_iter.last_y().unwrap_or(f64::NAN),
        res.tokens_per_sec,
        cfg.runtime,
    );
    Ok(())
}

fn cmd_data_stats(args: &Args) -> Result<(), String> {
    let which = args.str_or("preset", "all");
    args.reject_unknown()?;
    let names: Vec<String> = if which == "all" {
        PRESET_NAMES.iter().map(|s| s.to_string()).collect()
    } else {
        vec![which]
    };
    let mut table = fnomad_lda::util::bench::Table::new(
        "Table 3 (scaled presets; see DESIGN.md)",
        &CorpusStats::header(),
    );
    for name in &names {
        let corpus = preset(name)?;
        table.row(CorpusStats::compute(&corpus).row());
    }
    table.print();
    println!("\npaper's Table 3 (for reference):");
    let mut paper = fnomad_lda::util::bench::Table::new(
        "Table 3 (paper)",
        &["dataset", "docs(I)", "vocab(J)", "tokens"],
    );
    for &(name, i, j, w) in PAPER_TABLE3 {
        paper.row(vec![name.into(), i.to_string(), j.to_string(), w.to_string()]);
    }
    paper.print();
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<(), String> {
    let name = args.str_or("preset", "tiny");
    let topics: usize = args.parse_or("topics", 128)?;
    let sweeps: usize = args.parse_or("sweeps", 2)?;
    args.reject_unknown()?;
    let corpus = preset(&name)?;
    let model = CostModel::calibrate(&corpus, Hyper::paper_default(topics), sweeps);
    println!("calibrated on {name} (T={topics}): token_ns = {:.1}", model.token_ns);
    println!("{model:#?}");
    Ok(())
}

fn cmd_topics(args: &Args) -> Result<(), String> {
    let cfg = TrainConfig::preset(&args.str_or("preset", "tiny"))
        .topics(args.parse_or("topics", 16)?)
        .iters(args.parse_or("iters", 20)?)
        .eval(fnomad_lda::coordinator::EvalPolicy::Rust)
        .quiet(true);
    let top: usize = args.parse_or("top", 8)?;
    args.reject_unknown()?;
    let corpus = preset(&cfg.preset)?;
    let res = train(&cfg)?;
    print!("{}", topics_mod::render_topics(&res.final_state, corpus.vocab_words(), top));
    Ok(())
}

fn cmd_check_artifacts(args: &Args) -> Result<(), String> {
    let topics: usize = args.parse_or("topics", 128)?;
    args.reject_unknown()?;
    let dir = default_artifact_dir();
    // the pure-Rust blocked backend (pjrt feature off) needs no artifacts
    if cfg!(feature = "pjrt") && !artifacts_available(&dir) {
        return Err("artifacts missing — run `make artifacts` first".into());
    }
    let corpus = preset("tiny")?;
    let mut rng = Pcg32::seeded(0xA7);
    let state = LdaState::init_random(&corpus, Hyper::paper_default(topics), &mut rng);
    let rust_ll = lda::log_likelihood(&state);
    let mut evaluator = LlEvaluator::new(&dir, topics)?;
    let eval_ll = evaluator.log_likelihood(&state)?;
    let rel = ((eval_ll - rust_ll) / rust_ll).abs();
    let backend = LlEvaluator::BACKEND;
    println!("rust reference LL = {rust_ll:.6e}");
    println!("{backend} LL = {eval_ll:.6e}  (rel diff {rel:.3e})");
    if rel > 1e-4 {
        return Err(format!("{backend} and Rust evaluators disagree (rel {rel:.3e})"));
    }
    println!("check-artifacts OK ({backend} backend)");
    Ok(())
}
