//! `fnomad-lda` — the F+Nomad LDA launcher.
//!
//! Subcommands:
//!   train           train a topic model (any runtime/sampler; see --help)
//!   data-stats      print Table-3-style statistics for presets / UCI files
//!   calibrate       measure the per-token cost model for the simulator
//!   topics          train briefly and print the top words per topic
//!   check-artifacts cross-check the PJRT evaluator vs the Rust reference
//!   help            this text

use std::path::PathBuf;

use fnomad_lda::coordinator::{train, TrainOpts};
use fnomad_lda::corpus::presets::{preset, PAPER_TABLE3, PRESET_NAMES};
use fnomad_lda::corpus::CorpusStats;
use fnomad_lda::lda::state::{Hyper, LdaState};
use fnomad_lda::lda::{self, topics as topics_mod};
use fnomad_lda::runtime::{artifacts_available, default_artifact_dir, LlEvaluator};
use fnomad_lda::simnet::CostModel;
use fnomad_lda::util::bench::Table;
use fnomad_lda::util::cli::Args;
use fnomad_lda::util::rng::Pcg32;

const HELP: &str = "\
fnomad-lda — F+Nomad LDA (WWW'15 reproduction)

USAGE: fnomad-lda <subcommand> [--flags]

  train            --preset tiny|enron-sim|nytimes-sim|pubmed-sim|amazon-sim|umbc-sim
                   --topics N            (default 128; artifacts exist for 128 and 1024)
                   --sampler plain|sparse|alias|flda-doc|flda-word   (serial runtime)
                   --runtime serial|nomad|ps|adlda|nomad-sim|ps-sim
                   --workers P --machines M (sim cluster: M machines x 20 cores)
                   --iters N --seed S --eval auto|xla|rust --eval-every K
                   --batch-docs B --disk (ps flavors) --out results.csv --quiet
  data-stats       [--preset NAME|all] print Table 3 for our datasets
  calibrate        [--preset NAME] [--topics N] measure ns/token -> cost model
  topics           [--preset NAME] [--topics N] [--iters N] [--top K]
  check-artifacts  [--topics N] blocked evaluator (PJRT with --features pjrt,
                   pure Rust otherwise) vs Rust reference on random state
";

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{HELP}");
            std::process::exit(2);
        }
    };
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    let code = match sub.as_str() {
        "train" => cmd_train(&args),
        "data-stats" => cmd_data_stats(&args),
        "calibrate" => cmd_calibrate(&args),
        "topics" => cmd_topics(&args),
        "check-artifacts" => cmd_check_artifacts(&args),
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => Err(format!("unknown subcommand '{other}'\n{HELP}")),
    }
    .map(|_| 0)
    .unwrap_or_else(|e| {
        eprintln!("error: {e}");
        1
    });
    std::process::exit(code);
}

fn train_opts(args: &Args) -> Result<TrainOpts, String> {
    let d = TrainOpts::default();
    let opts = TrainOpts {
        preset: args.str_or("preset", &d.preset),
        topics: args.parse_or("topics", d.topics)?,
        sampler: args.str_or("sampler", &d.sampler),
        runtime: args.str_or("runtime", &d.runtime),
        workers: args.parse_or("workers", d.workers)?,
        machines: args.parse_or("machines", d.machines)?,
        iters: args.parse_or("iters", d.iters)?,
        seed: args.parse_or("seed", d.seed)?,
        eval: args.str_or("eval", &d.eval),
        eval_every: args.parse_or("eval-every", d.eval_every)?,
        batch_docs: args.parse_or("batch-docs", d.batch_docs)?,
        disk: args.flag("disk"),
        out: args.str_opt("out").map(PathBuf::from),
        quiet: args.flag("quiet"),
    };
    args.reject_unknown()?;
    Ok(opts)
}

fn cmd_train(args: &Args) -> Result<(), String> {
    let opts = train_opts(args)?;
    let res = train(&opts)?;
    println!(
        "final LL = {:.6e}   throughput = {:.0} tokens/s ({} runtime)",
        res.ll_vs_iter.last_y().unwrap_or(f64::NAN),
        res.tokens_per_sec,
        opts.runtime,
    );
    Ok(())
}

fn cmd_data_stats(args: &Args) -> Result<(), String> {
    let which = args.str_or("preset", "all");
    args.reject_unknown()?;
    let names: Vec<String> = if which == "all" {
        PRESET_NAMES.iter().map(|s| s.to_string()).collect()
    } else {
        vec![which]
    };
    let mut table = Table::new("Table 3 (scaled presets; see DESIGN.md)", &CorpusStats::header());
    for name in &names {
        let corpus = preset(name)?;
        table.row(CorpusStats::compute(&corpus).row());
    }
    table.print();
    println!("\npaper's Table 3 (for reference):");
    let mut paper = Table::new("Table 3 (paper)", &["dataset", "docs(I)", "vocab(J)", "tokens"]);
    for &(name, i, j, w) in PAPER_TABLE3 {
        paper.row(vec![name.into(), i.to_string(), j.to_string(), w.to_string()]);
    }
    paper.print();
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<(), String> {
    let name = args.str_or("preset", "tiny");
    let topics: usize = args.parse_or("topics", 128)?;
    let sweeps: usize = args.parse_or("sweeps", 2)?;
    args.reject_unknown()?;
    let corpus = preset(&name)?;
    let model = CostModel::calibrate(&corpus, Hyper::paper_default(topics), sweeps);
    println!("calibrated on {name} (T={topics}): token_ns = {:.1}", model.token_ns);
    println!("{model:#?}");
    Ok(())
}

fn cmd_topics(args: &Args) -> Result<(), String> {
    let opts = TrainOpts {
        preset: args.str_or("preset", "tiny"),
        topics: args.parse_or("topics", 16)?,
        iters: args.parse_or("iters", 20)?,
        eval: "rust".into(),
        quiet: true,
        ..Default::default()
    };
    let top: usize = args.parse_or("top", 8)?;
    args.reject_unknown()?;
    let corpus = preset(&opts.preset)?;
    let res = train(&opts)?;
    print!("{}", topics_mod::render_topics(&res.final_state, &corpus.vocab_words, top));
    Ok(())
}

fn cmd_check_artifacts(args: &Args) -> Result<(), String> {
    let topics: usize = args.parse_or("topics", 128)?;
    args.reject_unknown()?;
    let dir = default_artifact_dir();
    // the pure-Rust blocked backend (pjrt feature off) needs no artifacts
    if cfg!(feature = "pjrt") && !artifacts_available(&dir) {
        return Err("artifacts missing — run `make artifacts` first".into());
    }
    let corpus = preset("tiny")?;
    let mut rng = Pcg32::seeded(0xA7);
    let state = LdaState::init_random(&corpus, Hyper::paper_default(topics), &mut rng);
    let rust_ll = lda::log_likelihood(&state);
    let mut evaluator = LlEvaluator::new(&dir, topics)?;
    let eval_ll = evaluator.log_likelihood(&state)?;
    let rel = ((eval_ll - rust_ll) / rust_ll).abs();
    let backend = LlEvaluator::BACKEND;
    println!("rust reference LL = {rust_ll:.6e}");
    println!("{backend} LL = {eval_ll:.6e}  (rel diff {rel:.3e})");
    if rel > 1e-4 {
        return Err(format!("{backend} and Rust evaluators disagree (rel {rel:.3e})"));
    }
    println!("check-artifacts OK ({backend} backend)");
    Ok(())
}
