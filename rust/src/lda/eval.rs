//! Rust-side model-quality evaluation: the collapsed joint log-likelihood
//! log p(w, z) (Griffiths & Steyvers; the quantity of Yahoo! LDA's eq. (2)
//! that every figure in the paper plots).
//!
//! This is the *reference* evaluator, exploiting count sparsity
//! (`Σ_t lgamma(n+c)` = support terms + closed form for the zeros).  The
//! production path streams dense blocks through `runtime::LlEvaluator`
//! instead (AOT-compiled JAX/Pallas artifact with `--features pjrt`, the
//! pure-Rust blocked port by default); tests assert the two agree.

use crate::util::math::lgamma;

use super::state::LdaState;

/// Breakdown of the joint LL (useful for debugging convergence).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LlParts {
    /// log p(z)
    pub doc_side: f64,
    /// log p(w|z)
    pub word_side: f64,
}

impl LlParts {
    pub fn total(&self) -> f64 {
        self.doc_side + self.word_side
    }
}

/// Compute both sides from sparse counts.
///
/// doc side  = I·lgΓ(Tα) + Σ_d [ Σ_{t∈T_d} (lgΓ(n_td+α) − lgΓ(α)) ]
///             − Σ_d lgΓ(n_d + Tα)
/// word side = T·lgΓ(Jβ) + Σ_w [ Σ_{t∈T_w} (lgΓ(n_wt+β) − lgΓ(β)) ]
///             − Σ_t lgΓ(n_t + Jβ)
///
/// (the −T·lgΓ(α)·I and −J·lgΓ(β)·T constants fold into the support sums
/// via the zero-count closed form).
pub fn log_likelihood_parts(state: &LdaState) -> LlParts {
    let t = state.num_topics() as f64;
    let j = state.vocab as f64;
    let alpha = state.hyper.alpha;
    let beta = state.hyper.beta;
    let lga = lgamma(alpha);
    let lgb = lgamma(beta);

    let mut doc_side = state.ntd.len() as f64 * lgamma(t * alpha);
    for counts in &state.ntd {
        let mut nd = 0u64;
        for (_, c) in counts.iter() {
            doc_side += lgamma(c as f64 + alpha) - lga;
            nd += c as u64;
        }
        doc_side -= lgamma(nd as f64 + t * alpha);
    }

    let mut word_side = t * lgamma(j * beta);
    for counts in &state.nwt {
        for (_, c) in counts.iter() {
            word_side += lgamma(c as f64 + beta) - lgb;
        }
    }
    for &nt in &state.nt {
        word_side -= lgamma(nt as f64 + j * beta);
    }

    LlParts { doc_side, word_side }
}

/// The scalar every convergence curve plots.
pub fn log_likelihood(state: &LdaState) -> f64 {
    log_likelihood_parts(state).total()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;
    use crate::lda::state::Hyper;
    use crate::util::rng::Pcg32;

    /// Dense-formula oracle (direct transcription of the Griffiths &
    /// Steyvers expression, no sparsity tricks).
    fn dense_ll(state: &LdaState) -> f64 {
        let t = state.num_topics();
        let j = state.vocab;
        let (alpha, beta) = (state.hyper.alpha, state.hyper.beta);
        let mut ll = state.ntd.len() as f64
            * (lgamma(t as f64 * alpha) - t as f64 * lgamma(alpha));
        for counts in &state.ntd {
            let mut nd = 0u64;
            for k in 0..t {
                let c = counts.get(k as u16);
                ll += lgamma(c as f64 + alpha);
                nd += c as u64;
            }
            ll -= lgamma(nd as f64 + t as f64 * alpha);
        }
        ll += t as f64 * (lgamma(j as f64 * beta) - j as f64 * lgamma(beta));
        for k in 0..t {
            for w in 0..j {
                ll += lgamma(state.nwt[w].get(k as u16) as f64 + beta);
            }
            ll -= lgamma(state.nt[k] as f64 + j as f64 * beta);
        }
        // subtract the lgamma(beta) for every (w, t) cell added above that
        // the sparse version folds in: dense adds J*T lgamma(beta) worth of
        // zero cells; sparse formula is identical — both keep them, so no
        // correction needed here (the constant term already removed J of
        // them per topic).
        ll
    }

    #[test]
    fn sparse_ll_matches_dense_oracle() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(21);
        let state = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        let sparse = log_likelihood(&state);
        let dense = dense_ll(&state);
        assert!(
            (sparse - dense).abs() < 1e-6 * dense.abs(),
            "sparse {sparse} vs dense {dense}"
        );
    }

    #[test]
    fn ll_is_negative_and_finite() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(22);
        let state = LdaState::init_random(&corpus, Hyper::paper_default(16), &mut rng);
        let parts = log_likelihood_parts(&state);
        assert!(parts.doc_side.is_finite());
        assert!(parts.word_side.is_finite());
        assert!(parts.total() < 0.0);
    }

    #[test]
    fn concentrated_assignment_scores_higher() {
        // all tokens of a doc on one topic beats uniform-random assignment
        let corpus = preset("tiny").unwrap();
        let hyper = Hyper::paper_default(8);
        let mut rng = Pcg32::seeded(23);
        let random = LdaState::init_random(&corpus, hyper, &mut rng);

        let mut concentrated = random.clone();
        // rebuild with doc-major single-topic assignment
        let mut nwt = vec![super::super::SparseCounts::default(); corpus.vocab()];
        let mut nt = vec![0u32; hyper.t];
        for (i, doc) in corpus.docs().enumerate() {
            let topic = (i % hyper.t) as u16;
            let mut counts = super::super::SparseCounts::default();
            let base = corpus.offsets()[i];
            for (pos, &w) in doc.iter().enumerate() {
                concentrated.z[base + pos] = topic;
                counts.inc(topic);
                nwt[w as usize].inc(topic);
                nt[topic as usize] += 1;
            }
            concentrated.ntd[i] = counts;
        }
        concentrated.nwt = nwt;
        concentrated.nt = nt;
        concentrated.check_consistency(&corpus).unwrap();
        assert!(log_likelihood(&concentrated) > log_likelihood(&random));
    }
}
