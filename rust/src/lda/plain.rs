//! Plain O(T) collapsed Gibbs sampling — the "normal LDA implementation
//! which takes O(T) time to generate one sample" that Fig. 4(c,d) uses as
//! the speedup denominator.
//!
//! Per token: materialize the full dense conditional of eq. (2) and draw by
//! linear search.  No amortization tricks; this is the reference both for
//! correctness (it *is* eq. (2), verbatim) and for speedup measurement.

use crate::corpus::Corpus;
use crate::util::rng::Pcg32;

use super::state::LdaState;
use super::{add_token, remove_token, Sweep};

/// Dense CGS sweeper.
pub struct PlainLda {
    /// dense n_td of the current document (scattered/cleared per doc)
    doc_counts: Vec<u32>,
    /// dense n_wt row of the current token's word
    word_counts: Vec<u32>,
    /// dense conditional scratch
    p: Vec<f64>,
}

impl PlainLda {
    pub fn new(state: &LdaState) -> Self {
        let t = state.num_topics();
        PlainLda { doc_counts: vec![0; t], word_counts: vec![0; t], p: vec![0.0; t] }
    }
}

impl Sweep for PlainLda {
    fn sweep(&mut self, state: &mut LdaState, corpus: &Corpus, rng: &mut Pcg32) {
        let t = state.num_topics();
        let alpha = state.hyper.alpha;
        let beta = state.hyper.beta;
        let bb = state.hyper.betabar(state.vocab);
        let mut docs = corpus.docs_in(0..corpus.num_docs());
        while let Some((doc, toks)) = docs.next_doc() {
            // scatter the doc's sparse counts into dense scratch
            for (topic, c) in state.ntd[doc].iter() {
                self.doc_counts[topic as usize] = c;
            }
            let base = state.doc_offsets[doc];
            for (pos, &wtok) in toks.iter().enumerate() {
                let word = wtok as usize;
                let old = state.z[base + pos];
                remove_token(state, doc, word, old);
                self.doc_counts[old as usize] -= 1;

                // dense n_wt row for this word
                for (topic, c) in state.nwt[word].iter() {
                    self.word_counts[topic as usize] = c;
                }
                let mut total = 0.0;
                for k in 0..t {
                    let v = (self.doc_counts[k] as f64 + alpha)
                        * (self.word_counts[k] as f64 + beta)
                        / (state.nt[k] as f64 + bb);
                    self.p[k] = v;
                    total += v;
                }
                // clear word scratch (support only)
                for (topic, _) in state.nwt[word].iter() {
                    self.word_counts[topic as usize] = 0;
                }

                // linear search on the cdf
                let mut u = rng.uniform(total);
                let mut new = t - 1;
                for (k, &v) in self.p.iter().enumerate() {
                    if u < v {
                        new = k;
                        break;
                    }
                    u -= v;
                }
                let new = new as u16;

                add_token(state, doc, word, new);
                self.doc_counts[new as usize] += 1;
                state.z[base + pos] = new;
            }
            // clear doc scratch
            for (topic, _) in state.ntd[doc].iter() {
                self.doc_counts[topic as usize] = 0;
            }
            debug_assert!(self.doc_counts.iter().all(|&c| c == 0));
        }
    }

    fn name(&self) -> &'static str {
        "plain"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;
    use crate::lda::state::Hyper;

    #[test]
    fn sweep_preserves_token_count_and_consistency() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(5);
        let mut state = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        let tokens = state.total_tokens();
        let mut s = PlainLda::new(&state);
        s.sweep(&mut state, &corpus, &mut rng);
        assert_eq!(state.total_tokens(), tokens);
        state.check_consistency(&corpus).unwrap();
    }

    #[test]
    fn scratch_buffers_reset_between_docs() {
        // two sweeps must give the same result as two sweeps on a fresh
        // sampler (i.e. no scratch leakage across calls)
        let corpus = preset("tiny").unwrap();
        let mk = || {
            let mut rng = Pcg32::seeded(9);
            let state = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
            (state, rng)
        };
        let (mut s1, mut r1) = mk();
        let mut a = PlainLda::new(&s1);
        a.sweep(&mut s1, &corpus, &mut r1);
        a.sweep(&mut s1, &corpus, &mut r1);

        let (mut s2, mut r2) = mk();
        let mut b1 = PlainLda::new(&s2);
        b1.sweep(&mut s2, &corpus, &mut r2);
        let mut b2 = PlainLda::new(&s2);
        b2.sweep(&mut s2, &corpus, &mut r2);

        assert_eq!(s1.z, s2.z);
    }
}
