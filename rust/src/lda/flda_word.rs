//! F+LDA, word-by-word order (paper §3.2, decomposition (5), Algorithm 3):
//!
//! ```text
//! p_t = α·q_t + r_t,   q_t = (n_tw + β)/(n_t + β̄),   r_t = n_td · q_t
//! ```
//!
//! The F+tree tracks `q` for the *current word*; `r` is |T_d|-sparse and
//! rebuilt per occurrence.  Per-token cost Θ(|T_d| + log T) — |T_d| is
//! bounded by document length, so on large corpora this beats the
//! |T_w|-bound doc-major order (the Fig. 4 crossover).  This is also the
//! order the Nomad runtime uses: the unit subtask is "all occurrences of
//! word w in my documents", exactly one tree-raise/lower per subtask.

use crate::corpus::{Corpus, WordIndex};
use crate::sampler::bsearch::SparseCumSum;
use crate::sampler::ftree::FTree;
use crate::sampler::DiscreteSampler;
use crate::util::rng::Pcg32;

use super::state::LdaState;
use super::Sweep;

/// Word-major F+LDA sweeper.
pub struct FLdaWord {
    /// F+tree over q_t; outside the current word every leaf holds the base
    /// value β/(n_t + β̄)
    tree: FTree,
    r: SparseCumSum,
    /// word-major occurrence index (built once per corpus)
    index: WordIndex,
    /// reusable support-topic scratch (avoids a per-word allocation)
    support: Vec<u16>,
    /// dense scratch of the current word's count row (perf: per-occurrence
    /// O(1) access instead of sorted-vec binary search + memmove)
    wrow: Vec<u32>,
    /// topics whose wrow entry changed during the subtask (write-back set)
    touched: Vec<u16>,
    is_touched: Vec<bool>,
}

impl FLdaWord {
    pub fn new(state: &LdaState, corpus: &Corpus) -> Self {
        let t = state.num_topics();
        FLdaWord {
            tree: FTree::with_capacity(&vec![0.0; t], t),
            r: SparseCumSum::with_capacity(64),
            index: corpus.word_index(),
            support: Vec::with_capacity(t),
            wrow: vec![0; t],
            touched: Vec::with_capacity(t),
            is_touched: vec![false; t],
        }
    }

    fn rebuild_base(&mut self, state: &LdaState) {
        let bb = state.hyper.betabar(state.vocab);
        let beta = state.hyper.beta;
        let base: Vec<f64> = state.nt.iter().map(|&n| beta / (n as f64 + bb)).collect();
        self.tree.refill(&base);
    }

    /// Process every occurrence of `word` (the Nomad unit subtask, shared
    /// with the parallel runtimes via `pub(crate)`).
    pub(crate) fn process_word(
        &mut self,
        state: &mut LdaState,
        word: usize,
        docs: &[u32],
        poss: &[u32],
        rng: &mut Pcg32,
    ) {
        let alpha = state.hyper.alpha;
        let beta = state.hyper.beta;
        let bb = state.hyper.betabar(state.vocab);

        // raise: scatter the word row into the dense scratch and lift the
        // tree leaves on T_w to the word-specific value
        self.support.clear();
        for (t, c) in state.nwt[word].iter() {
            self.support.push(t);
            self.wrow[t as usize] = c;
        }
        for &topic in &self.support {
            let t = topic as usize;
            self.tree
                .set(t, (self.wrow[t] as f64 + beta) / (state.nt[t] as f64 + bb));
        }

        for (&doc, &pos) in docs.iter().zip(poss) {
            let (doc, pos) = (doc as usize, pos as usize);
            let zi = state.doc_offsets[doc] + pos;
            let old = state.z[zi];
            let old_t = old as usize;
            // remove: ntd (sparse), word row (dense scratch), totals
            state.ntd[doc].dec(old);
            self.wrow[old_t] -= 1;
            state.nt[old_t] -= 1;
            if !self.is_touched[old_t] {
                self.is_touched[old_t] = true;
                self.touched.push(old);
            }
            self.tree
                .set(old_t, (self.wrow[old_t] as f64 + beta) / (state.nt[old_t] as f64 + bb));

            // r over the document's support, fresh q from the tree leaves
            self.r.clear();
            for (t, c) in state.ntd[doc].iter() {
                self.r.push(t as u32, c as f64 * self.tree.leaf(t as usize));
            }
            let r_total = self.r.total();

            let u = rng.uniform(alpha * self.tree.total() + r_total);
            let new = if u < r_total {
                self.r.sample(u) as u16
            } else {
                self.tree.sample((u - r_total) / alpha) as u16
            };
            let new_t = new as usize;

            state.ntd[doc].inc(new);
            self.wrow[new_t] += 1;
            state.nt[new_t] += 1;
            if !self.is_touched[new_t] {
                self.is_touched[new_t] = true;
                self.touched.push(new);
            }
            self.tree
                .set(new_t, (self.wrow[new_t] as f64 + beta) / (state.nt[new_t] as f64 + bb));
            state.z[zi] = new;
        }

        // lower: write the touched scratch entries back into the sparse
        // row (one binary search per topic instead of per occurrence),
        // reset every lifted leaf to the base value, clear the scratch.
        for &topic in &self.touched {
            state.nwt[word].set_count(topic, self.wrow[topic as usize]);
            self.is_touched[topic as usize] = false;
        }
        self.touched.clear();
        self.support.clear();
        self.support.extend(state.nwt[word].iter().map(|(t, _)| t));
        for &topic in &self.support {
            let t = topic as usize;
            self.tree.set(t, beta / (state.nt[t] as f64 + bb));
            self.wrow[t] = 0;
        }
        debug_assert!(self.wrow.iter().all(|&c| c == 0));
    }
}

impl Sweep for FLdaWord {
    fn sweep(&mut self, state: &mut LdaState, corpus: &Corpus, rng: &mut Pcg32) {
        self.rebuild_base(state);
        // borrow-split: the index is immutable over the sweep, so move it
        // out instead of copying every occurrence slice (perf: saves a
        // full corpus copy per sweep)
        let index = std::mem::take(&mut self.index);
        for word in 0..corpus.vocab() {
            let (docs, poss) = index.occurrences(word);
            if docs.is_empty() {
                continue;
            }
            self.process_word(state, word, docs, poss, rng);
        }
        self.index = index;
    }

    fn name(&self) -> &'static str {
        "flda-word"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;
    use crate::lda::state::Hyper;

    #[test]
    fn sweep_is_consistent() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(41);
        let mut state = LdaState::init_random(&corpus, Hyper::paper_default(16), &mut rng);
        let mut s = FLdaWord::new(&state, &corpus);
        for _ in 0..3 {
            s.sweep(&mut state, &corpus, &mut rng);
        }
        state.check_consistency(&corpus).unwrap();
    }

    #[test]
    fn tree_returns_to_base_after_sweep() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(42);
        let mut state = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        let mut s = FLdaWord::new(&state, &corpus);
        s.sweep(&mut state, &corpus, &mut rng);
        let bb = state.hyper.betabar(state.vocab);
        for t in 0..8 {
            let want = state.hyper.beta / (state.nt[t] as f64 + bb);
            let got = s.tree.leaf(t);
            assert!(
                (got - want).abs() < 1e-12 * want.abs().max(1e-300),
                "leaf {t}: {got} vs base {want}"
            );
        }
    }

    #[test]
    fn every_token_is_resampled_once_per_sweep() {
        // token count conservation + consistency across several sweeps
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(43);
        let mut state = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        let before = state.total_tokens();
        let mut s = FLdaWord::new(&state, &corpus);
        s.sweep(&mut state, &corpus, &mut rng);
        assert_eq!(state.total_tokens(), before);
    }
}
