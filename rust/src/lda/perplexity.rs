//! Held-out evaluation: per-word predictive perplexity on a test split.
//!
//! The paper evaluates training log-likelihood (its figures' y-axis); a
//! production topic-modeling library also needs held-out perplexity.  We
//! implement the standard *document-completion* estimator: for each test
//! document, the first half of its tokens estimate θ̂_d against the
//! trained φ̂ (point estimates from the count state), the second half is
//! scored:
//!
//! ```text
//! ppl = exp( − Σ_held log Σ_t θ̂_d(t)·φ̂_t(w) / N_held )
//! ```

use crate::corpus::Corpus;
use crate::infer::{Inferencer, TopicModel};
use crate::util::rng::Pcg32;

use super::state::{Hyper, LdaState};

/// Minimum document length eligible for the test split: the
/// document-completion estimator needs both a non-trivial observed half
/// and at least one held-out token.
pub const MIN_TEST_DOC_LEN: usize = 4;

/// Deterministic train/test split by document id hash: doc `i` goes to
/// test iff `hash(seed, i)` falls below `test_fraction` — stable per
/// document, independent of iteration order.
///
/// Documents shorter than [`MIN_TEST_DOC_LEN`] always stay in train and
/// are excluded from the draw entirely, so the realized test fraction
/// among *eligible* documents is unbiased.  (The previous implementation
/// drew from a sequential RNG and silently dropped selected-but-short
/// docs back into train, biasing the realized fraction low on short-doc
/// corpora.)
pub fn split_corpus(corpus: &Corpus, test_fraction: f64, seed: u64) -> (Corpus, Corpus) {
    assert!((0.0..1.0).contains(&test_fraction));
    let mut train = corpus_meta(corpus, "train");
    let mut test = corpus_meta(corpus, "test");
    for (i, doc) in corpus.docs().enumerate() {
        if doc.len() >= MIN_TEST_DOC_LEN && doc_hash01(seed, i as u64) < test_fraction {
            test.push_doc(&doc);
        } else {
            train.push_doc(&doc);
        }
    }
    (train, test)
}

/// SplitMix64 finalizer over (seed, doc id), mapped to a uniform f64 in
/// [0, 1) with 53 bits of entropy.
fn doc_hash01(seed: u64, doc: u64) -> f64 {
    let mut x = seed.wrapping_add(doc.wrapping_mul(0x9E3779B97F4A7C15));
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58476D1CE4E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D049BB133111EB);
    x ^= x >> 31;
    (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

fn corpus_meta(c: &Corpus, suffix: &str) -> Corpus {
    Corpus::with_meta(c.vocab(), c.vocab_words().to_vec(), format!("{}-{suffix}", c.name()))
}

/// Document-completion perplexity of `state` (trained on the train split)
/// on `test`.  `fold_in_sweeps` Gibbs passes estimate θ̂ on the first half
/// of each test document with φ̂ frozen.
///
/// The fold-in and held-out scoring are the *serving* implementation
/// ([`crate::infer::Inferencer`]): the state is frozen into a
/// [`TopicModel`] point estimate and each document is Gibbs-folded with a
/// per-token cost of Θ(|T̂_w| + log T) via the q/r F+tree decomposition —
/// one inference implementation, not two.  (The pre-serving version of
/// this function carried its own O(T)-per-token linear-scan loop; the
/// parity test below keeps the reported numbers anchored to it.)
pub fn perplexity(
    state: &LdaState,
    test: &Corpus,
    fold_in_sweeps: usize,
    rng: &mut Pcg32,
) -> f64 {
    let model = TopicModel::from_state(state, Vec::new());
    let mut inf = Inferencer::new(&model);
    let mut log_sum = 0.0f64;
    let mut held_tokens = 0usize;
    for doc in test.docs() {
        let score = inf
            .score_doc_with(&doc, fold_in_sweeps, rng)
            .expect("test split tokens are inside the training vocabulary");
        log_sum += score.log_likelihood;
        held_tokens += score.held_tokens;
    }
    if held_tokens == 0 {
        return f64::NAN;
    }
    (-log_sum / held_tokens as f64).exp()
}

/// Convenience: uniform-model perplexity (the "random" baseline = J).
pub fn uniform_perplexity(vocab: usize) -> f64 {
    vocab as f64
}

/// Hyper re-export used by doc examples.
pub type _Hyper = Hyper;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;
    use crate::lda::state::SparseCounts;
    use crate::lda::{FLdaWord, Sweep};

    /// The pre-serving implementation, kept verbatim as the parity
    /// oracle: O(T)-per-token dense conditional with a linear-scan draw.
    fn linear_scan_perplexity(
        state: &LdaState,
        test: &Corpus,
        fold_in_sweeps: usize,
        rng: &mut Pcg32,
    ) -> f64 {
        let t = state.num_topics();
        let h = state.hyper;
        let bb = h.betabar(state.vocab);
        let phi = |topic: usize, w: usize| -> f64 {
            (state.nwt[w].get(topic as u16) as f64 + h.beta) / (state.nt[topic] as f64 + bb)
        };
        let mut log_sum = 0.0f64;
        let mut held_tokens = 0usize;
        let mut p = vec![0.0f64; t];
        for doc in test.docs() {
            let half = doc.len() / 2;
            let (observed, held) = doc.split_at(half);
            let mut counts = SparseCounts::default();
            let mut z: Vec<u16> = observed
                .iter()
                .map(|_| {
                    let topic = rng.below(t) as u16;
                    counts.inc(topic);
                    topic
                })
                .collect();
            for _ in 0..fold_in_sweeps {
                for (j, &w) in observed.iter().enumerate() {
                    let old = z[j];
                    counts.dec(old);
                    let mut total = 0.0;
                    for (k, pk) in p.iter_mut().enumerate() {
                        *pk = (counts.get(k as u16) as f64 + h.alpha) * phi(k, w as usize);
                        total += *pk;
                    }
                    let mut u = rng.uniform(total);
                    let mut new = t - 1;
                    for (k, &pk) in p.iter().enumerate() {
                        if u < pk {
                            new = k;
                            break;
                        }
                        u -= pk;
                    }
                    counts.inc(new as u16);
                    z[j] = new as u16;
                }
            }
            let nd = half as f64;
            let theta =
                |k: usize| (counts.get(k as u16) as f64 + h.alpha) / (nd + t as f64 * h.alpha);
            for &w in held {
                let mut pw = 0.0;
                for k in 0..t {
                    pw += theta(k) * phi(k, w as usize);
                }
                log_sum += pw.max(1e-300).ln();
                held_tokens += 1;
            }
        }
        if held_tokens == 0 {
            return f64::NAN;
        }
        (-log_sum / held_tokens as f64).exp()
    }

    /// Parity: the F+tree fold-in must report the same perplexity as the
    /// pre-PR linear-scan implementation up to Monte-Carlo noise.  Both
    /// target the identical conditional, so with a seeded corpus and a
    /// decent sweep budget the two estimates agree to a few percent;
    /// averaged over two seeds the tolerance below has wide margin.
    #[test]
    fn ftree_fold_in_matches_linear_scan_perplexity() {
        let corpus = preset("tiny").unwrap();
        let (train, test) = split_corpus(&corpus, 0.25, 2);
        let hyper = Hyper::paper_default(8);
        let mut rng = Pcg32::seeded(3);
        let mut state = LdaState::init_random(&train, hyper, &mut rng);
        let mut sampler = FLdaWord::new(&state, &train);
        for _ in 0..25 {
            sampler.sweep(&mut state, &train, &mut rng);
        }
        let avg = |f: &dyn Fn(&mut Pcg32) -> f64| {
            let mut sum = 0.0;
            for seed in [11u64, 12] {
                sum += f(&mut Pcg32::seeded(seed));
            }
            sum / 2.0
        };
        let old = avg(&|rng| linear_scan_perplexity(&state, &test, 15, rng));
        let new = avg(&|rng| perplexity(&state, &test, 15, rng));
        assert!(old.is_finite() && new.is_finite());
        let rel = (new - old).abs() / old;
        assert!(
            rel < 0.10,
            "fold-in parity broken: linear-scan ppl {old:.3} vs f+tree ppl {new:.3} \
             (rel {rel:.4})"
        );
        // both still beat the uniform baseline by a wide margin
        assert!(new < uniform_perplexity(corpus.vocab()));
        assert!(old < uniform_perplexity(corpus.vocab()));
    }

    #[test]
    fn split_partitions_docs() {
        let corpus = preset("tiny").unwrap();
        let (train, test) = split_corpus(&corpus, 0.3, 1);
        assert_eq!(train.num_docs() + test.num_docs(), corpus.num_docs());
        assert_eq!(train.num_tokens() + test.num_tokens(), corpus.num_tokens());
        assert!(test.num_docs() > 0 && train.num_docs() > 0);
        train.validate().unwrap();
        test.validate().unwrap();
        // deterministic
        let (train2, _) = split_corpus(&corpus, 0.3, 1);
        assert_eq!(train.tokens_vec(), train2.tokens_vec());
        assert_eq!(train.offsets(), train2.offsets());
    }

    #[test]
    fn short_doc_split_is_unbiased_among_eligible_docs() {
        use crate::corpus::synthetic::{generate, SyntheticSpec};
        // Poisson mean 3 → roughly half the docs are shorter than
        // MIN_TEST_DOC_LEN; under the old sequential-RNG draw those docs
        // consumed test picks and fell back to train, biasing the
        // realized fraction low.
        let corpus = generate(&SyntheticSpec {
            name: "shorty".into(),
            num_docs: 4000,
            vocab: 60,
            avg_doc_len: 3.0,
            true_topics: 4,
            seed: 11,
            ..Default::default()
        });
        let eligible =
            corpus.docs().filter(|d| d.len() >= MIN_TEST_DOC_LEN).count();
        let short = corpus.num_docs() - eligible;
        assert!(
            eligible > 800 && short > 800,
            "corpus not mixed enough to exercise the bias ({eligible} eligible, {short} short)"
        );
        let frac = 0.25;
        let (train, test) = split_corpus(&corpus, frac, 9);
        assert_eq!(train.num_docs() + test.num_docs(), corpus.num_docs());
        // short docs are never selected for test
        assert!(test.docs().all(|d| d.len() >= MIN_TEST_DOC_LEN));
        // realized fraction among eligible docs is unbiased: within 5
        // binomial standard deviations of the request
        let realized = test.num_docs() as f64 / eligible as f64;
        let sigma = (frac * (1.0 - frac) / eligible as f64).sqrt();
        assert!(
            (realized - frac).abs() < 5.0 * sigma,
            "realized test fraction {realized:.4} vs requested {frac} (sigma {sigma:.4})"
        );
    }

    #[test]
    fn split_is_per_doc_stable() {
        // the hash draw depends only on (seed, doc id): splitting a prefix
        // of the corpus assigns the shared docs identically
        let corpus = preset("tiny").unwrap();
        let (_, test_full) = split_corpus(&corpus, 0.4, 3);
        let mut prefix = crate::corpus::Corpus::with_meta(
            corpus.vocab(),
            vec![],
            "prefix".into(),
        );
        for doc in corpus.docs().take(corpus.num_docs() / 2) {
            prefix.push_doc(&doc);
        }
        let (_, test_prefix) = split_corpus(&prefix, 0.4, 3);
        // every prefix test doc appears in the full test split too
        let full_docs: Vec<Vec<u32>> = test_full.docs().map(|d| d.to_vec()).collect();
        for d in test_prefix.docs() {
            assert!(
                full_docs.iter().any(|f| f[..] == *d),
                "prefix split disagrees with full split"
            );
        }
    }

    #[test]
    fn trained_model_beats_uniform_perplexity() {
        let corpus = preset("tiny").unwrap();
        let (train, test) = split_corpus(&corpus, 0.25, 2);
        let hyper = Hyper::paper_default(8);
        let mut rng = Pcg32::seeded(3);
        let mut state = LdaState::init_random(&train, hyper, &mut rng);
        let mut sampler = FLdaWord::new(&state, &train);
        for _ in 0..25 {
            sampler.sweep(&mut state, &train, &mut rng);
        }
        let ppl = perplexity(&state, &test, 10, &mut rng);
        assert!(ppl.is_finite() && ppl > 1.0);
        assert!(
            ppl < uniform_perplexity(corpus.vocab()),
            "trained ppl {ppl} not better than uniform {}",
            corpus.vocab()
        );
    }

    #[test]
    fn more_training_does_not_hurt_much() {
        // ppl after 20 sweeps ≤ 1.2 × ppl after 2 sweeps (sanity, generous)
        let corpus = preset("tiny").unwrap();
        let (train, test) = split_corpus(&corpus, 0.25, 4);
        let hyper = Hyper::paper_default(8);
        let run = |sweeps: usize| {
            let mut rng = Pcg32::seeded(5);
            let mut state = LdaState::init_random(&train, hyper, &mut rng);
            let mut sampler = FLdaWord::new(&state, &train);
            for _ in 0..sweeps {
                sampler.sweep(&mut state, &train, &mut rng);
            }
            perplexity(&state, &test, 8, &mut rng)
        };
        let early = run(2);
        let late = run(20);
        assert!(late < early * 1.2, "early {early} late {late}");
    }
}
