//! Held-out evaluation: per-word predictive perplexity on a test split.
//!
//! The paper evaluates training log-likelihood (its figures' y-axis); a
//! production topic-modeling library also needs held-out perplexity.  We
//! implement the standard *document-completion* estimator: for each test
//! document, the first half of its tokens estimate θ̂_d against the
//! trained φ̂ (point estimates from the count state), the second half is
//! scored:
//!
//! ```text
//! ppl = exp( − Σ_held log Σ_t θ̂_d(t)·φ̂_t(w) / N_held )
//! ```

use crate::corpus::Corpus;
use crate::util::rng::Pcg32;

use super::state::{Hyper, LdaState, SparseCounts};

/// Deterministic train/test split by document id hash.
pub fn split_corpus(corpus: &Corpus, test_fraction: f64, seed: u64) -> (Corpus, Corpus) {
    assert!((0.0..1.0).contains(&test_fraction));
    let mut rng = Pcg32::new(seed, 0x5117);
    let mut train = Corpus { docs: vec![], ..corpus_meta(corpus, "train") };
    let mut test = Corpus { docs: vec![], ..corpus_meta(corpus, "test") };
    for doc in &corpus.docs {
        if rng.next_f64() < test_fraction && doc.len() >= 4 {
            test.docs.push(doc.clone());
        } else {
            train.docs.push(doc.clone());
        }
    }
    (train, test)
}

fn corpus_meta(c: &Corpus, suffix: &str) -> Corpus {
    Corpus {
        docs: vec![],
        vocab: c.vocab,
        vocab_words: c.vocab_words.clone(),
        name: format!("{}-{suffix}", c.name),
    }
}

/// Document-completion perplexity of `state` (trained on the train split)
/// on `test`.  `fold_in_sweeps` Gibbs passes estimate θ̂ on the first half
/// of each test document with φ̂ frozen.
pub fn perplexity(
    state: &LdaState,
    test: &Corpus,
    fold_in_sweeps: usize,
    rng: &mut Pcg32,
) -> f64 {
    let t = state.num_topics();
    let h = state.hyper;
    let bb = h.betabar(state.vocab);
    // frozen topic-word point estimate φ̂_t(w) accessor
    let phi = |topic: usize, w: usize| -> f64 {
        (state.nwt[w].get(topic as u16) as f64 + h.beta)
            / (state.nt[topic] as f64 + bb)
    };

    let mut log_sum = 0.0f64;
    let mut held_tokens = 0usize;
    let mut p = vec![0.0f64; t];
    for doc in &test.docs {
        let half = doc.len() / 2;
        let (observed, held) = doc.split_at(half);
        // fold-in: Gibbs on the observed half with φ̂ frozen
        let mut counts = SparseCounts::default();
        let mut z: Vec<u16> = observed
            .iter()
            .map(|_| {
                let topic = rng.below(t) as u16;
                counts.inc(topic);
                topic
            })
            .collect();
        for _ in 0..fold_in_sweeps {
            for (j, &w) in observed.iter().enumerate() {
                let old = z[j];
                counts.dec(old);
                let mut total = 0.0;
                for (k, pk) in p.iter_mut().enumerate() {
                    *pk = (counts.get(k as u16) as f64 + h.alpha) * phi(k, w as usize);
                    total += *pk;
                }
                let mut u = rng.uniform(total);
                let mut new = t - 1;
                for (k, &pk) in p.iter().enumerate() {
                    if u < pk {
                        new = k;
                        break;
                    }
                    u -= pk;
                }
                counts.inc(new as u16);
                z[j] = new as u16;
            }
        }
        // θ̂_d from the folded-in counts
        let nd = half as f64;
        let theta = |k: usize| (counts.get(k as u16) as f64 + h.alpha) / (nd + t as f64 * h.alpha);
        for &w in held {
            let mut pw = 0.0;
            for k in 0..t {
                pw += theta(k) * phi(k, w as usize);
            }
            log_sum += pw.max(1e-300).ln();
            held_tokens += 1;
        }
    }
    if held_tokens == 0 {
        return f64::NAN;
    }
    (-log_sum / held_tokens as f64).exp()
}

/// Convenience: uniform-model perplexity (the "random" baseline = J).
pub fn uniform_perplexity(vocab: usize) -> f64 {
    vocab as f64
}

/// Hyper re-export used by doc examples.
pub type _Hyper = Hyper;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;
    use crate::lda::{FLdaWord, Sweep};

    #[test]
    fn split_partitions_docs() {
        let corpus = preset("tiny").unwrap();
        let (train, test) = split_corpus(&corpus, 0.3, 1);
        assert_eq!(train.num_docs() + test.num_docs(), corpus.num_docs());
        assert!(test.num_docs() > 0 && train.num_docs() > 0);
        train.validate().unwrap();
        test.validate().unwrap();
        // deterministic
        let (train2, _) = split_corpus(&corpus, 0.3, 1);
        assert_eq!(train.docs, train2.docs);
    }

    #[test]
    fn trained_model_beats_uniform_perplexity() {
        let corpus = preset("tiny").unwrap();
        let (train, test) = split_corpus(&corpus, 0.25, 2);
        let hyper = Hyper::paper_default(8);
        let mut rng = Pcg32::seeded(3);
        let mut state = LdaState::init_random(&train, hyper, &mut rng);
        let mut sampler = FLdaWord::new(&state, &train);
        for _ in 0..25 {
            sampler.sweep(&mut state, &train, &mut rng);
        }
        let ppl = perplexity(&state, &test, 10, &mut rng);
        assert!(ppl.is_finite() && ppl > 1.0);
        assert!(
            ppl < uniform_perplexity(corpus.vocab),
            "trained ppl {ppl} not better than uniform {}",
            corpus.vocab
        );
    }

    #[test]
    fn more_training_does_not_hurt_much() {
        // ppl after 20 sweeps ≤ 1.2 × ppl after 2 sweeps (sanity, generous)
        let corpus = preset("tiny").unwrap();
        let (train, test) = split_corpus(&corpus, 0.25, 4);
        let hyper = Hyper::paper_default(8);
        let run = |sweeps: usize| {
            let mut rng = Pcg32::seeded(5);
            let mut state = LdaState::init_random(&train, hyper, &mut rng);
            let mut sampler = FLdaWord::new(&state, &train);
            for _ in 0..sweeps {
                sampler.sweep(&mut state, &train, &mut rng);
            }
            perplexity(&state, &test, 8, &mut rng)
        };
        let early = run(2);
        let late = run(20);
        assert!(late < early * 1.2, "early {early} late {late}");
    }
}
