//! F+LDA, document-by-document order (paper §3.2, decomposition (4)):
//!
//! ```text
//! p_t = β·q_t + r_t,   q_t = (n_td + α)/(n_t + β̄),   r_t = n_tw · q_t
//! ```
//!
//! `q` is dense but changes in O(1) coordinates per step → F+tree
//! (Θ(log T) sample + update).  `r` is |T_w|-sparse and fully changes on
//! every word switch → rebuilt per token as a sparse cumsum (Θ(|T_w|)
//! init, Θ(log |T_w|) sample).  Total: Θ(|T_w| + log T) per token, exact.

use crate::corpus::Corpus;
use crate::sampler::bsearch::SparseCumSum;
use crate::sampler::ftree::FTree;
use crate::sampler::DiscreteSampler;
use crate::util::rng::Pcg32;

use super::state::LdaState;
use super::{add_token, remove_token, Sweep};

/// Doc-major F+LDA sweeper.
pub struct FLdaDoc {
    /// F+tree over q_t; outside the current document every leaf holds the
    /// base value α/(n_t + β̄)
    tree: FTree,
    /// sparse cumsum scratch for the r term
    r: SparseCumSum,
}

impl FLdaDoc {
    pub fn new(state: &LdaState) -> Self {
        let t = state.num_topics();
        FLdaDoc {
            tree: FTree::with_capacity(&vec![0.0; t], t),
            r: SparseCumSum::with_capacity(64),
        }
    }

    /// Rebuild every leaf to the document-independent base value.
    fn rebuild_base(&mut self, state: &LdaState) {
        let bb = state.hyper.betabar(state.vocab);
        let alpha = state.hyper.alpha;
        let base: Vec<f64> = state
            .nt
            .iter()
            .map(|&n| alpha / (n as f64 + bb))
            .collect();
        self.tree.refill(&base);
    }

    #[inline]
    fn q_value(state: &LdaState, doc: usize, t: u16) -> f64 {
        let bb = state.hyper.betabar(state.vocab);
        (state.ntd[doc].get(t) as f64 + state.hyper.alpha)
            / (state.nt[t as usize] as f64 + bb)
    }
}

impl Sweep for FLdaDoc {
    fn sweep(&mut self, state: &mut LdaState, corpus: &Corpus, rng: &mut Pcg32) {
        let beta = state.hyper.beta;
        self.rebuild_base(state);
        let mut docs = corpus.docs_in(0..corpus.num_docs());
        while let Some((doc, toks)) = docs.next_doc() {
            // enter document: raise leaves on T_d to (n_td + α)/(n_t + β̄)
            // (two-pass over the sparse support; borrow discipline)
            let support: Vec<u16> = state.ntd[doc].iter().map(|(t, _)| t).collect();
            for &t in &support {
                self.tree.set(t as usize, Self::q_value(state, doc, t));
            }

            let base = state.doc_offsets[doc];
            for (pos, &wtok) in toks.iter().enumerate() {
                let word = wtok as usize;
                let old = state.z[base + pos];
                remove_token(state, doc, word, old);
                // n_td[old] and n_t[old] both changed → refresh that leaf
                self.tree.set(old as usize, Self::q_value(state, doc, old));

                // r term over the word's support, using fresh q leaves
                self.r.clear();
                for (t, c) in state.nwt[word].iter() {
                    self.r.push(t as u32, c as f64 * self.tree.leaf(t as usize));
                }
                let r_total = self.r.total();

                let u = rng.uniform(beta * self.tree.total() + r_total);
                let new = if u < r_total {
                    self.r.sample(u) as u16
                } else {
                    self.tree.sample((u - r_total) / beta) as u16
                };

                add_token(state, doc, word, new);
                self.tree.set(new as usize, Self::q_value(state, doc, new));
                state.z[base + pos] = new;
            }

            // leave document: lower the final support back to base; any
            // topic whose count hit zero mid-document already holds the
            // base value (set() with n_td = 0 is the base formula).
            let bb = state.hyper.betabar(state.vocab);
            let alpha = state.hyper.alpha;
            let support: Vec<u16> = state.ntd[doc].iter().map(|(t, _)| t).collect();
            for &t in &support {
                self.tree
                    .set(t as usize, alpha / (state.nt[t as usize] as f64 + bb));
            }
        }
    }

    fn name(&self) -> &'static str {
        "flda-doc"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;
    use crate::lda::state::Hyper;

    #[test]
    fn sweep_is_consistent() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(31);
        let mut state = LdaState::init_random(&corpus, Hyper::paper_default(16), &mut rng);
        let mut s = FLdaDoc::new(&state);
        for _ in 0..3 {
            s.sweep(&mut state, &corpus, &mut rng);
        }
        state.check_consistency(&corpus).unwrap();
    }

    #[test]
    fn tree_returns_to_base_after_each_doc() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(32);
        let mut state = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        let mut s = FLdaDoc::new(&state);
        s.sweep(&mut state, &corpus, &mut rng);
        // after the sweep every leaf must equal the base value under the
        // *current* n_t
        let bb = state.hyper.betabar(state.vocab);
        for t in 0..8 {
            let want = state.hyper.alpha / (state.nt[t] as f64 + bb);
            let got = s.tree.leaf(t);
            assert!(
                (got - want).abs() < 1e-12 * want.abs().max(1e-300),
                "leaf {t}: {got} vs base {want}"
            );
        }
    }
}
