//! The CGS count state (§2.1): topic assignments `z` plus the three count
//! aggregates `n_td`, `n_wt`, `n_t`.
//!
//! `z` is stored **flat** in the corpus's CSR layout (see
//! [`crate::corpus`]): one `Vec<u16>` with document i's assignments at
//! `doc_offsets[i]..doc_offsets[i + 1]`, mirroring the corpus token
//! payload one-to-one (the state keeps its own copy of the offset table;
//! `z` stays RAM-resident even when the corpus payload lives on disk).  Both the doc-topic and word-topic matrices are stored
//! *sparse* (sorted `(topic, count)` pairs) — at T in the thousands they
//! are overwhelmingly sparse (|T_d| is bounded by document length, |T_w|
//! by the word's corpus frequency), and every sampler in this crate
//! iterates nonzero support.  Samplers that need dense rows scatter into
//! reusable scratch buffers.

use crate::corpus::{Corpus, CorpusSlice};
use crate::util::codec::{put_u16, put_u32, Cur};
use crate::util::rng::Pcg32;

/// LDA hyperparameters (symmetric Dirichlet, the paper's setting).
#[derive(Clone, Copy, Debug)]
pub struct Hyper {
    /// number of topics T
    pub t: usize,
    /// document-topic smoother (paper default 50/T)
    pub alpha: f64,
    /// topic-word smoother (paper default 0.01)
    pub beta: f64,
}

impl Hyper {
    /// The paper's default setting: alpha = 50/T, beta = 0.01.
    pub fn paper_default(t: usize) -> Hyper {
        Hyper { t, alpha: 50.0 / t as f64, beta: 0.01 }
    }

    /// beta-bar = J * beta (the denominator smoother of eq. (2)).
    pub fn betabar(&self, vocab: usize) -> f64 {
        self.beta * vocab as f64
    }
}

/// Sorted sparse (topic -> count) map.  |support| stays small (≤ doc length
/// for `n_td`, ≤ word frequency for `n_wt`), so binary-search + memmove
/// beats hashing at these sizes.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SparseCounts {
    pairs: Vec<(u16, u32)>,
}

impl SparseCounts {
    pub fn with_capacity(cap: usize) -> Self {
        SparseCounts { pairs: Vec::with_capacity(cap) }
    }

    /// Build from already-sorted `(topic, count)` pairs — the wire-decode
    /// path.  Topics must be strictly increasing and counts nonzero (the
    /// invariants every other constructor maintains incrementally); a
    /// violating input is a decode error, never a silently-broken row.
    pub fn from_sorted_pairs(pairs: Vec<(u16, u32)>) -> Result<Self, String> {
        for w in pairs.windows(2) {
            if w[1].0 <= w[0].0 {
                return Err(format!(
                    "sparse row topics not strictly increasing: {} then {}",
                    w[0].0, w[1].0
                ));
            }
        }
        if let Some(&(t, _)) = pairs.iter().find(|&&(_, c)| c == 0) {
            return Err(format!("sparse row has a zero count at topic {t}"));
        }
        Ok(SparseCounts { pairs })
    }

    #[inline]
    pub fn get(&self, topic: u16) -> u32 {
        match self.pairs.binary_search_by_key(&topic, |&(t, _)| t) {
            Ok(i) => self.pairs[i].1,
            Err(_) => 0,
        }
    }

    /// Increment, inserting the topic if absent.
    #[inline]
    pub fn inc(&mut self, topic: u16) {
        match self.pairs.binary_search_by_key(&topic, |&(t, _)| t) {
            Ok(i) => self.pairs[i].1 += 1,
            Err(i) => self.pairs.insert(i, (topic, 1)),
        }
    }

    /// Decrement, removing the topic when it reaches zero.
    /// Panics in debug builds if the topic is absent (a state corruption).
    #[inline]
    pub fn dec(&mut self, topic: u16) {
        match self.pairs.binary_search_by_key(&topic, |&(t, _)| t) {
            Ok(i) => {
                self.pairs[i].1 -= 1;
                if self.pairs[i].1 == 0 {
                    self.pairs.remove(i);
                }
            }
            Err(_) => debug_assert!(false, "dec of absent topic {topic}"),
        }
    }

    /// Set a topic's count to an absolute value (0 removes it).  Used by
    /// the word-major hot path to write back a dense scratch row in one
    /// binary search per touched topic.
    #[inline]
    pub fn set_count(&mut self, topic: u16, count: u32) {
        match self.pairs.binary_search_by_key(&topic, |&(t, _)| t) {
            Ok(i) => {
                if count == 0 {
                    self.pairs.remove(i);
                } else {
                    self.pairs[i].1 = count;
                }
            }
            Err(i) => {
                if count > 0 {
                    self.pairs.insert(i, (topic, count));
                }
            }
        }
    }

    /// Nonzero support size (|T_d| / |T_w|).
    #[inline]
    pub fn support(&self) -> usize {
        self.pairs.len()
    }

    #[inline]
    pub fn iter(&self) -> impl Iterator<Item = (u16, u32)> + '_ {
        self.pairs.iter().copied()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }

    pub fn total(&self) -> u64 {
        self.pairs.iter().map(|&(_, c)| c as u64).sum()
    }

    /// Append the shared wire/artifact encoding of a sparse row: a `u32`
    /// support size followed by `(u16 topic, u32 count)` pairs in topic
    /// order — the layout both the nomad ring frames and the `.fnmodel`
    /// serving artifact use.
    pub fn encode(&self, out: &mut Vec<u8>) {
        put_u32(out, self.support() as u32);
        for &(t, n) in &self.pairs {
            put_u16(out, t);
            put_u32(out, n);
        }
    }

    /// Decode one [`Self::encode`]d row from a bounds-checked reader.
    /// Total: truncation, oversized lengths, unsorted topics and zero
    /// counts are all `Err`, never a panic.
    pub fn decode(cur: &mut Cur) -> Result<SparseCounts, String> {
        let n = cur.len(6)?;
        let mut pairs = Vec::with_capacity(n);
        for _ in 0..n {
            let t = cur.u16()?;
            let c = cur.u32()?;
            pairs.push((t, c));
        }
        SparseCounts::from_sorted_pairs(pairs)
    }
}

/// Convert signed global topic totals to the `u32` count vector, surfacing
/// a negative entry as a *loud* panic naming the offending topic.  A
/// negative total can only arise from count-state corruption (a lost or
/// double-applied delta); clamping it to zero would silently re-mask
/// exactly the class of bug the exact-fold protocol exists to rule out.
pub fn checked_totals(s: &[i64]) -> Vec<u32> {
    s.iter()
        .enumerate()
        .map(|(t, &v)| {
            u32::try_from(v).unwrap_or_else(|_| {
                panic!("global topic total s[{t}] = {v} out of u32 range: state corruption")
            })
        })
        .collect()
}

/// Rebuild the per-doc topic counts of a worker's [`CorpusSlice`] from
/// its flat `z` rows — the shared spawn-time setup of every partitioned
/// worker.  Returns the slice's (already zero-based) offset table and
/// the `n_td` rows.
pub fn local_rows(slice: &CorpusSlice, z: &[u16], t: usize) -> (Vec<usize>, Vec<SparseCounts>) {
    let offsets = slice.offsets.clone();
    assert_eq!(z.len(), *offsets.last().unwrap(), "z / doc range mismatch");
    let mut ntd = Vec::with_capacity(slice.num_docs());
    for w in offsets.windows(2) {
        let zs = &z[w[0]..w[1]];
        let mut counts = SparseCounts::with_capacity(zs.len().min(t));
        for &topic in zs {
            counts.inc(topic);
        }
        ntd.push(counts);
    }
    (offsets, ntd)
}

/// Assemble a full state from per-worker doc-range parts — the shared
/// epoch-boundary gather of every partitioned runtime.  Each part is
/// `(start_doc, ntd rows, flat z payload)` for one worker's contiguous
/// document range, borrowed so live workers (the simulators) contribute
/// without a transient copy of the multi-GB assignment array; the
/// word-side counts and globals come from wherever the runtime keeps
/// them authoritative (home tokens, server snapshot, exact fold).
pub fn assemble_state<'a>(
    corpus: &Corpus,
    hyper: Hyper,
    parts: impl IntoIterator<Item = (usize, &'a [SparseCounts], &'a [u16])>,
    nwt: Vec<SparseCounts>,
    nt: Vec<u32>,
) -> LdaState {
    let mut z = vec![0u16; corpus.num_tokens()];
    let mut ntd = vec![SparseCounts::default(); corpus.num_docs()];
    for (start_doc, worker_ntd, worker_z) in parts {
        let lo = corpus.offsets()[start_doc];
        z[lo..lo + worker_z.len()].copy_from_slice(worker_z);
        for (off, counts) in worker_ntd.iter().enumerate() {
            ntd[start_doc + off] = counts.clone();
        }
    }
    LdaState {
        hyper,
        vocab: corpus.vocab(),
        z,
        doc_offsets: corpus.offsets().to_vec(),
        ntd,
        nwt,
        nt,
    }
}

/// Full Gibbs state for one corpus.
#[derive(Clone, Debug)]
pub struct LdaState {
    pub hyper: Hyper,
    pub vocab: usize,
    /// flat CSR assignments: doc i's topics at
    /// `doc_offsets[i]..doc_offsets[i+1]`, mirroring the corpus tokens
    pub z: Vec<u16>,
    /// CSR row offsets, copied from the corpus at construction
    pub doc_offsets: Vec<usize>,
    /// n_td per document
    pub ntd: Vec<SparseCounts>,
    /// n_wt per word
    pub nwt: Vec<SparseCounts>,
    /// n_t global topic totals
    pub nt: Vec<u32>,
}

impl LdaState {
    /// Random initialization: every occurrence assigned a uniform topic
    /// (the standard CGS start).
    pub fn init_random(corpus: &Corpus, hyper: Hyper, rng: &mut Pcg32) -> LdaState {
        assert!(hyper.t >= 2 && hyper.t <= u16::MAX as usize + 1);
        let mut z = Vec::with_capacity(corpus.num_tokens());
        let mut ntd = Vec::with_capacity(corpus.num_docs());
        let mut nwt = vec![SparseCounts::default(); corpus.vocab()];
        let mut nt = vec![0u32; hyper.t];
        let mut sweep = corpus.docs_in(0..corpus.num_docs());
        while let Some((_, doc)) = sweep.next_doc() {
            let mut counts = SparseCounts::with_capacity(doc.len().min(hyper.t));
            for &w in doc {
                let topic = rng.below(hyper.t) as u16;
                z.push(topic);
                counts.inc(topic);
                nwt[w as usize].inc(topic);
                nt[topic as usize] += 1;
            }
            ntd.push(counts);
        }
        LdaState {
            hyper,
            vocab: corpus.vocab(),
            z,
            doc_offsets: corpus.offsets().to_vec(),
            ntd,
            nwt,
            nt,
        }
    }

    pub fn num_topics(&self) -> usize {
        self.hyper.t
    }

    /// Number of documents (CSR rows).
    #[inline]
    pub fn num_docs(&self) -> usize {
        self.doc_offsets.len() - 1
    }

    /// Document i's assignments as a slice.
    #[inline]
    pub fn z_doc(&self, i: usize) -> &[u16] {
        &self.z[self.doc_offsets[i]..self.doc_offsets[i + 1]]
    }

    /// Document i's assignments, mutable.
    #[inline]
    pub fn z_doc_mut(&mut self, i: usize) -> &mut [u16] {
        &mut self.z[self.doc_offsets[i]..self.doc_offsets[i + 1]]
    }

    /// The flat z payload of the contiguous doc range [start, end) — what
    /// a worker owning that range copies at spawn.
    #[inline]
    pub fn z_range(&self, start: usize, end: usize) -> &[u16] {
        &self.z[self.doc_offsets[start]..self.doc_offsets[end]]
    }

    pub fn total_tokens(&self) -> u64 {
        self.nt.iter().map(|&c| c as u64).sum()
    }

    /// Recompute all counts from `z` and compare — the state-integrity
    /// oracle used by tests and by the runtime's paranoid mode.
    pub fn check_consistency(&self, corpus: &Corpus) -> Result<(), String> {
        let mut ntd = vec![SparseCounts::default(); corpus.num_docs()];
        let mut nwt = vec![SparseCounts::default(); corpus.vocab()];
        let mut nt = vec![0u32; self.hyper.t];
        if self.num_docs() != corpus.num_docs() {
            return Err(format!(
                "z has {} docs, corpus {}",
                self.num_docs(),
                corpus.num_docs()
            ));
        }
        if self.doc_offsets.as_slice() != corpus.offsets() {
            return Err("state doc_offsets diverge from corpus doc_offsets".into());
        }
        if self.z.len() != corpus.num_tokens() {
            return Err(format!(
                "z has {} assignments, corpus {} tokens",
                self.z.len(),
                corpus.num_tokens()
            ));
        }
        let mut sweep = corpus.docs_in(0..corpus.num_docs());
        while let Some((i, doc)) = sweep.next_doc() {
            let zs = self.z_doc(i);
            for (&w, &topic) in doc.iter().zip(zs) {
                if topic as usize >= self.hyper.t {
                    return Err(format!("doc {i}: topic {topic} out of range"));
                }
                ntd[i].inc(topic);
                nwt[w as usize].inc(topic);
                nt[topic as usize] += 1;
            }
        }
        if ntd != self.ntd {
            let bad = ntd.iter().zip(&self.ntd).position(|(a, b)| a != b).unwrap();
            return Err(format!("ntd mismatch at doc {bad}"));
        }
        if nwt != self.nwt {
            let bad = nwt.iter().zip(&self.nwt).position(|(a, b)| a != b).unwrap();
            return Err(format!("nwt mismatch at word {bad}"));
        }
        if nt != self.nt {
            return Err("nt mismatch".into());
        }
        Ok(())
    }

    /// The dense conditional of eq. (2) for one (doc, word) pair with the
    /// token *removed* — the target distribution every sampler must match.
    /// Test/oracle use only (Θ(T)).
    pub fn dense_conditional(&self, doc: usize, word: usize) -> Vec<f64> {
        let bb = self.hyper.betabar(self.vocab);
        (0..self.hyper.t)
            .map(|t| {
                let ntd = self.ntd[doc].get(t as u16) as f64;
                let nwt = self.nwt[word].get(t as u16) as f64;
                (ntd + self.hyper.alpha) * (nwt + self.hyper.beta)
                    / (self.nt[t] as f64 + bb)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;
    use crate::util::quickcheck::check;

    #[test]
    fn sparse_counts_inc_dec() {
        let mut c = SparseCounts::default();
        assert_eq!(c.get(5), 0);
        c.inc(5);
        c.inc(5);
        c.inc(2);
        assert_eq!(c.get(5), 2);
        assert_eq!(c.get(2), 1);
        assert_eq!(c.support(), 2);
        c.dec(5);
        c.dec(5);
        assert_eq!(c.get(5), 0);
        assert_eq!(c.support(), 1);
        assert_eq!(c.total(), 1);
    }

    #[test]
    fn sparse_counts_from_sorted_pairs_validates() {
        let ok = SparseCounts::from_sorted_pairs(vec![(1, 2), (5, 1), (9, 3)]).unwrap();
        assert_eq!(ok.get(5), 1);
        assert_eq!(ok.total(), 6);
        assert!(SparseCounts::from_sorted_pairs(vec![]).unwrap().is_empty());
        assert!(SparseCounts::from_sorted_pairs(vec![(5, 1), (1, 2)]).is_err());
        assert!(SparseCounts::from_sorted_pairs(vec![(5, 1), (5, 2)]).is_err());
        assert!(SparseCounts::from_sorted_pairs(vec![(1, 0)]).is_err());
    }

    #[test]
    fn sparse_counts_iter_sorted() {
        let mut c = SparseCounts::default();
        for t in [9u16, 1, 5, 1, 9, 9] {
            c.inc(t);
        }
        let pairs: Vec<_> = c.iter().collect();
        assert_eq!(pairs, vec![(1, 2), (5, 1), (9, 3)]);
    }

    #[test]
    fn sparse_counts_random_against_dense_model() {
        check("SparseCounts == dense counter", 32, |rng| {
            let mut sparse = SparseCounts::default();
            let mut dense = vec![0i64; 16];
            for _ in 0..500 {
                let t = rng.below(16) as u16;
                if dense[t as usize] > 0 && rng.next_f64() < 0.45 {
                    sparse.dec(t);
                    dense[t as usize] -= 1;
                } else {
                    sparse.inc(t);
                    dense[t as usize] += 1;
                }
            }
            for (t, &d) in dense.iter().enumerate() {
                if sparse.get(t as u16) as i64 != d {
                    return Err(format!("topic {t}: sparse {} dense {d}", sparse.get(t as u16)));
                }
            }
            if sparse.support() != dense.iter().filter(|&&d| d > 0).count() {
                return Err("support mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn init_random_is_consistent() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(1);
        let state = LdaState::init_random(&corpus, Hyper::paper_default(16), &mut rng);
        state.check_consistency(&corpus).unwrap();
        assert_eq!(state.total_tokens() as usize, corpus.num_tokens());
        assert_eq!(state.z.len(), corpus.num_tokens());
        assert_eq!(state.doc_offsets.as_slice(), corpus.offsets());
    }

    #[test]
    fn z_doc_rows_mirror_corpus_rows() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(4);
        let state = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        for i in 0..corpus.num_docs() {
            assert_eq!(state.z_doc(i).len(), corpus.doc_len(i));
        }
    }

    #[test]
    fn consistency_detects_corruption() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(2);
        let mut state = LdaState::init_random(&corpus, Hyper::paper_default(16), &mut rng);
        state.nt[0] += 1;
        assert!(state.check_consistency(&corpus).is_err());
    }

    #[test]
    fn dense_conditional_is_positive_and_finite() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(3);
        let state = LdaState::init_random(&corpus, Hyper::paper_default(16), &mut rng);
        let p = state.dense_conditional(0, corpus.doc(0)[0] as usize);
        assert_eq!(p.len(), 16);
        assert!(p.iter().all(|&x| x > 0.0 && x.is_finite()));
    }

    #[test]
    fn checked_totals_roundtrips_nonnegative() {
        assert_eq!(checked_totals(&[0, 3, 7]), vec![0u32, 3, 7]);
    }

    #[test]
    #[should_panic(expected = "state corruption")]
    fn checked_totals_panics_on_negative() {
        let _ = checked_totals(&[4, -1, 2]);
    }

    #[test]
    fn paper_default_hyper() {
        let h = Hyper::paper_default(1024);
        assert!((h.alpha - 50.0 / 1024.0).abs() < 1e-12);
        assert!((h.beta - 0.01).abs() < 1e-12);
        assert!((h.betabar(7000) - 70.0).abs() < 1e-9);
    }
}
