//! CVB0 — collapsed variational Bayes with zeroth-order approximation
//! (Asuncion et al., UAI'09).  The paper's conclusion names CVB0 as the
//! scheme its framework should transfer to; this module provides the
//! serial reference implementation so that transfer is testable.
//!
//! Instead of a hard assignment z_ij, each token keeps a variational
//! distribution γ_ij over topics, and the "counts" become expectations:
//!
//! ```text
//! γ_ij(t) ∝ (Ê[n_td]^{-ij} + α)(Ê[n_tw]^{-ij} + β) / (Ê[n_t]^{-ij} + β̄)
//! ```
//!
//! Memory is Θ(tokens × T) for γ, so this is intended for moderate T /
//! corpus sizes (the constructor returns an error above a budget instead
//! of silently allocating tens of GB).

use crate::corpus::Corpus;

use super::state::Hyper;

/// Soft-assignment trainer state.
pub struct Cvb0 {
    pub hyper: Hyper,
    pub vocab: usize,
    /// γ[d][j*T + t]: variational responsibility of topic t for token j
    gamma: Vec<Vec<f32>>,
    /// expected counts
    e_ntd: Vec<Vec<f64>>,
    e_nwt: Vec<f64>,
    e_nt: Vec<f64>,
}

/// Refuse to allocate more than this many γ entries (~4 GB of f32).
pub const MAX_GAMMA_ENTRIES: usize = 1 << 30;

impl Cvb0 {
    /// Uniform-initialize γ (the standard CVB0 start) with a tiny
    /// deterministic perturbation to break symmetry.
    pub fn new(corpus: &Corpus, hyper: Hyper) -> Result<Cvb0, String> {
        let t = hyper.t;
        let entries: usize = corpus.num_tokens() * t;
        if entries > MAX_GAMMA_ENTRIES {
            return Err(format!(
                "CVB0 needs tokens×T = {entries} γ entries (> {MAX_GAMMA_ENTRIES}); \
                 use collapsed Gibbs (flda-*) at this scale"
            ));
        }
        let mut gamma = Vec::with_capacity(corpus.num_docs());
        let mut e_ntd = Vec::with_capacity(corpus.num_docs());
        let mut e_nwt = vec![0.0; corpus.vocab() * t];
        let mut e_nt = vec![0.0; t];
        for (d, doc) in corpus.docs().enumerate() {
            let mut g = vec![0.0f32; doc.len() * t];
            let mut nd = vec![0.0f64; t];
            for (j, &w) in doc.iter().enumerate() {
                // symmetry-breaking: deterministic ramp by (d, j, t)
                let mut sum = 0.0f32;
                for k in 0..t {
                    let v = 1.0 + 0.01 * (((d + 3 * j + 7 * k) % 13) as f32 / 13.0);
                    g[j * t + k] = v;
                    sum += v;
                }
                for k in 0..t {
                    g[j * t + k] /= sum;
                    let v = g[j * t + k] as f64;
                    nd[k] += v;
                    e_nwt[w as usize * t + k] += v;
                    e_nt[k] += v;
                }
            }
            gamma.push(g);
            e_ntd.push(nd);
        }
        Ok(Cvb0 { hyper, vocab: corpus.vocab(), gamma, e_ntd, e_nwt, e_nt })
    }

    /// One full CVB0 sweep (doc-by-doc, token-by-token).
    pub fn sweep(&mut self, corpus: &Corpus) {
        let t = self.hyper.t;
        let alpha = self.hyper.alpha;
        let beta = self.hyper.beta;
        let bb = self.hyper.betabar(self.vocab);
        let mut fresh = vec![0.0f64; t];
        for (d, doc) in corpus.docs().enumerate() {
            for (j, &w) in doc.iter().enumerate() {
                let w = w as usize;
                let g = &mut self.gamma[d][j * t..(j + 1) * t];
                // remove this token's expectation, compute the update,
                // add the fresh expectation back
                let mut sum = 0.0;
                for k in 0..t {
                    let old = g[k] as f64;
                    let ntd = self.e_ntd[d][k] - old;
                    let nwt = self.e_nwt[w * t + k] - old;
                    let nt = self.e_nt[k] - old;
                    let v = (ntd + alpha) * (nwt + beta) / (nt + bb);
                    fresh[k] = v.max(0.0);
                    sum += fresh[k];
                }
                for k in 0..t {
                    let new = fresh[k] / sum;
                    let old = g[k] as f64;
                    let delta = new - old;
                    g[k] = new as f32;
                    self.e_ntd[d][k] += delta;
                    self.e_nwt[w * t + k] += delta;
                    self.e_nt[k] += delta;
                }
            }
        }
    }

    /// Expected-count "pseudo log-likelihood": the CGS LL formula over the
    /// expected counts — comparable across CVB0 iterations (not directly
    /// to CGS LL, which uses integer counts).
    pub fn pseudo_ll(&self) -> f64 {
        use crate::util::math::lgamma;
        let t = self.hyper.t as f64;
        let j = self.vocab as f64;
        let alpha = self.hyper.alpha;
        let beta = self.hyper.beta;
        let mut ll = self.e_ntd.len() as f64 * lgamma(t * alpha);
        for nd in &self.e_ntd {
            let mut total = 0.0;
            for &c in nd {
                if c > 1e-9 {
                    ll += lgamma(c + alpha) - lgamma(alpha);
                }
                total += c;
            }
            ll -= lgamma(total + t * alpha);
        }
        ll += t * lgamma(j * beta);
        for &c in &self.e_nwt {
            if c > 1e-9 {
                ll += lgamma(c + beta) - lgamma(beta);
            }
        }
        for &nt in &self.e_nt {
            ll -= lgamma(nt + j * beta);
        }
        ll
    }

    /// Invariant check: expectations sum to token counts.
    pub fn check_consistency(&self, corpus: &Corpus) -> Result<(), String> {
        let total: f64 = self.e_nt.iter().sum();
        let want = corpus.num_tokens() as f64;
        if (total - want).abs() > 1e-4 * want.max(1.0) {
            return Err(format!("e_nt sums to {total}, expected {want}"));
        }
        for (d, g) in self.gamma.iter().enumerate() {
            let t = self.hyper.t;
            for j in 0..g.len() / t {
                let s: f32 = g[j * t..(j + 1) * t].iter().sum();
                if (s - 1.0).abs() > 1e-3 {
                    return Err(format!("gamma[{d}][{j}] sums to {s}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;

    #[test]
    fn sweep_preserves_expectation_mass() {
        let corpus = preset("tiny").unwrap();
        let mut cvb = Cvb0::new(&corpus, Hyper::paper_default(8)).unwrap();
        cvb.check_consistency(&corpus).unwrap();
        for _ in 0..3 {
            cvb.sweep(&corpus);
        }
        cvb.check_consistency(&corpus).unwrap();
    }

    #[test]
    fn pseudo_ll_improves() {
        let corpus = preset("tiny").unwrap();
        let mut cvb = Cvb0::new(&corpus, Hyper::paper_default(8)).unwrap();
        let ll0 = cvb.pseudo_ll();
        for _ in 0..10 {
            cvb.sweep(&corpus);
        }
        let ll = cvb.pseudo_ll();
        assert!(ll > ll0, "CVB0 did not improve: {ll0} -> {ll}");
    }

    #[test]
    fn memory_budget_enforced() {
        let corpus = preset("tiny").unwrap();
        let big = Hyper { t: 1 << 20, alpha: 0.1, beta: 0.01 };
        assert!(Cvb0::new(&corpus, big).is_err());
    }
}
