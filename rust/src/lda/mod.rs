//! LDA inference: collapsed Gibbs sampling in five flavors (paper Table 2).
//!
//! | variant | order | exact? | per-token cost |
//! |---------|-------|--------|----------------|
//! | [`PlainLda`]  | doc-by-doc  | yes | Θ(T) |
//! | [`SparseLda`] | doc-by-doc  | yes | Θ(\|T_w\| + \|T_d\|) amortized |
//! | [`AliasLda`]  | doc-by-doc  | no (MH) | Θ(\|T_d\| + #MH) amortized |
//! | [`FLdaDoc`]   | doc-by-doc  | yes | Θ(\|T_w\| + log T) |
//! | [`FLdaWord`]  | word-by-word| yes | Θ(\|T_d\| + log T) |
//!
//! All five target the same conditional (eq. (2)); `rust/tests` verifies
//! each against the dense oracle by single-site distribution tests, and
//! `benches/fig4_serial_convergence.rs` reproduces the convergence and
//! speed figures.

pub mod alias_lda;
pub mod checkpoint;
pub mod cvb0;
pub mod eval;
pub mod flda_doc;
pub mod flda_word;
pub mod hyper_opt;
pub mod perplexity;
pub mod plain;
pub mod sparse;
pub mod state;
pub mod topics;

pub use alias_lda::AliasLda;
pub use eval::log_likelihood;
pub use flda_doc::FLdaDoc;
pub use flda_word::FLdaWord;
pub use plain::PlainLda;
pub use sparse::SparseLda;
pub use state::{Hyper, LdaState, SparseCounts};

use crate::corpus::Corpus;
use crate::util::rng::Pcg32;

/// One full Gibbs sweep over every token of the corpus.
pub trait Sweep {
    /// Resample every `z_{ij}` once, updating `state` in place.
    fn sweep(&mut self, state: &mut LdaState, corpus: &Corpus, rng: &mut Pcg32);

    /// Human-readable variant name (figure labels).
    fn name(&self) -> &'static str;
}

/// Sampler variants by CLI name.
pub const VARIANTS: &[&str] = &["plain", "sparse", "alias", "flda-doc", "flda-word"];

/// Construct a sweeper by name for a given problem shape.
pub fn by_name(
    name: &str,
    state: &LdaState,
    corpus: &Corpus,
) -> Result<Box<dyn Sweep>, String> {
    Ok(match name {
        "plain" => Box::new(PlainLda::new(state)),
        "sparse" => Box::new(SparseLda::new(state)),
        "alias" => Box::new(AliasLda::new(state)),
        "flda-doc" => Box::new(FLdaDoc::new(state)),
        "flda-word" => Box::new(FLdaWord::new(state, corpus)),
        _ => {
            return Err(format!(
                "unknown sampler '{name}' (known: {})",
                VARIANTS.join(", ")
            ))
        }
    })
}

/// Remove one token's assignment from all three aggregates.
#[inline]
pub(crate) fn remove_token(state: &mut LdaState, doc: usize, word: usize, topic: u16) {
    state.ntd[doc].dec(topic);
    state.nwt[word].dec(topic);
    state.nt[topic as usize] -= 1;
}

/// Add one token's assignment to all three aggregates.
#[inline]
pub(crate) fn add_token(state: &mut LdaState, doc: usize, word: usize, topic: u16) {
    state.ntd[doc].inc(topic);
    state.nwt[word].inc(topic);
    state.nt[topic as usize] += 1;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;

    /// Every variant preserves count-state integrity across sweeps and
    /// improves the joint LL from random init.
    #[test]
    fn all_variants_sweep_consistently_and_improve_ll() {
        let corpus = preset("tiny").unwrap();
        for name in VARIANTS {
            let mut rng = Pcg32::seeded(0xBEEF);
            let mut state = LdaState::init_random(&corpus, Hyper::paper_default(16), &mut rng);
            let ll0 = log_likelihood(&state);
            let mut sampler = by_name(name, &state, &corpus).unwrap();
            for _ in 0..5 {
                sampler.sweep(&mut state, &corpus, &mut rng);
            }
            state
                .check_consistency(&corpus)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            let ll5 = log_likelihood(&state);
            assert!(
                ll5 > ll0,
                "{name}: LL did not improve ({ll0} -> {ll5})"
            );
        }
    }

    /// Exact samplers end up at statistically similar LL after burn-in.
    #[test]
    fn exact_variants_reach_similar_ll() {
        let corpus = preset("tiny").unwrap();
        let mut lls = Vec::new();
        for name in ["plain", "sparse", "flda-doc", "flda-word"] {
            let mut rng = Pcg32::seeded(7);
            let mut state = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
            let mut sampler = by_name(name, &state, &corpus).unwrap();
            for _ in 0..30 {
                sampler.sweep(&mut state, &corpus, &mut rng);
            }
            lls.push((name, log_likelihood(&state)));
        }
        let max = lls.iter().map(|&(_, l)| l).fold(f64::MIN, f64::max);
        let min = lls.iter().map(|&(_, l)| l).fold(f64::MAX, f64::min);
        // same target distribution => within a few percent of each other
        assert!(
            (max - min).abs() / max.abs() < 0.03,
            "LL spread too wide: {lls:?}"
        );
    }

    #[test]
    fn by_name_rejects_unknown() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(1);
        let state = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        assert!(by_name("bogus", &state, &corpus).is_err());
    }
}
