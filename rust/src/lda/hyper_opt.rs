//! Hyperparameter estimation: Minka's fixed-point update for the symmetric
//! Dirichlet concentrations (the standard Mallet `--optimize-interval`
//! feature; the paper fixes α = 50/T, β = 0.01, so this ships as an
//! extension, exercised by the ablation bench).
//!
//! For a symmetric Dirichlet α over T outcomes observed through count
//! vectors {n_dt} with totals {n_d}:
//!
//! ```text
//! α ← α · Σ_d Σ_t [Ψ(n_dt + α) − Ψ(α)] / (T · Σ_d [Ψ(n_d + Tα) − Ψ(Tα)])
//! ```
//!
//! (Minka 2000, "Estimating a Dirichlet distribution", fixed-point iteration.)

use crate::util::math::digamma;

use super::state::LdaState;

/// One Minka fixed-point step for the document-topic α.
pub fn alpha_step(state: &LdaState) -> f64 {
    let t = state.num_topics() as f64;
    let alpha = state.hyper.alpha;
    let mut num = 0.0;
    let mut den = 0.0;
    let psi_a = digamma(alpha);
    for counts in &state.ntd {
        let mut nd = 0u64;
        for (_, c) in counts.iter() {
            num += digamma(c as f64 + alpha) - psi_a;
            nd += c as u64;
        }
        den += digamma(nd as f64 + t * alpha) - digamma(t * alpha);
    }
    if den <= 0.0 || num <= 0.0 {
        return alpha;
    }
    (alpha * num / (t * den)).clamp(1e-6, 1e3)
}

/// One Minka fixed-point step for the topic-word β.
pub fn beta_step(state: &LdaState) -> f64 {
    let j = state.vocab as f64;
    let beta = state.hyper.beta;
    let psi_b = digamma(beta);
    let mut num = 0.0;
    for counts in &state.nwt {
        for (_, c) in counts.iter() {
            num += digamma(c as f64 + beta) - psi_b;
        }
    }
    let mut den = 0.0;
    for &nt in &state.nt {
        den += digamma(nt as f64 + j * beta) - digamma(j * beta);
    }
    if den <= 0.0 || num <= 0.0 {
        return beta;
    }
    (beta * num / (j * den)).clamp(1e-6, 1e3)
}

/// Run `steps` alternating fixed-point updates, mutating the state's
/// hyperparameters.  Returns (α, β).
pub fn optimize(state: &mut LdaState, steps: usize) -> (f64, f64) {
    for _ in 0..steps {
        state.hyper.alpha = alpha_step(state);
        state.hyper.beta = beta_step(state);
    }
    (state.hyper.alpha, state.hyper.beta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;
    use crate::lda::state::Hyper;
    use crate::lda::{log_likelihood, FLdaWord, Sweep};
    use crate::util::rng::Pcg32;

    fn trained_state(t: usize, sweeps: usize) -> (crate::corpus::Corpus, LdaState) {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(13);
        let mut state = LdaState::init_random(&corpus, Hyper::paper_default(t), &mut rng);
        let mut s = FLdaWord::new(&state, &corpus);
        for _ in 0..sweeps {
            s.sweep(&mut state, &corpus, &mut rng);
        }
        (corpus, state)
    }

    #[test]
    fn steps_stay_positive_and_bounded() {
        let (_, state) = trained_state(8, 10);
        let a = alpha_step(&state);
        let b = beta_step(&state);
        assert!(a > 0.0 && a < 1e3, "alpha {a}");
        assert!(b > 0.0 && b < 1e3, "beta {b}");
    }

    #[test]
    fn optimize_improves_or_preserves_ll() {
        let (_, mut state) = trained_state(8, 20);
        let before = log_likelihood(&state);
        optimize(&mut state, 8);
        let after = log_likelihood(&state);
        // Minka's update ascends the evidence of the Dirichlet given the
        // counts; allow a little slack for fixed-point overshoot
        assert!(
            after > before - 0.002 * before.abs(),
            "LL degraded: {before} -> {after}"
        );
    }

    #[test]
    fn fixed_point_converges() {
        let (_, mut state) = trained_state(8, 20);
        optimize(&mut state, 30);
        let a1 = state.hyper.alpha;
        optimize(&mut state, 1);
        let a2 = state.hyper.alpha;
        assert!(
            (a1 - a2).abs() < 0.05 * a1.max(1e-6),
            "not converged: {a1} vs {a2}"
        );
    }
}
