//! Topic inspection: top-k words per topic and point estimates of the
//! topic-word (φ) and doc-topic (θ) distributions from the count state.

use super::state::LdaState;

/// Top-k (word, count) per topic.
pub fn top_words(state: &LdaState, k: usize) -> Vec<Vec<(u32, u32)>> {
    let t = state.num_topics();
    let mut per_topic: Vec<Vec<(u32, u32)>> = vec![Vec::new(); t];
    for (w, counts) in state.nwt.iter().enumerate() {
        for (topic, c) in counts.iter() {
            per_topic[topic as usize].push((w as u32, c));
        }
    }
    for list in &mut per_topic {
        list.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        list.truncate(k);
    }
    per_topic
}

/// Render the topics with vocabulary strings when available.
pub fn render_topics(state: &LdaState, vocab_words: &[String], k: usize) -> String {
    let mut out = String::new();
    for (topic, words) in top_words(state, k).iter().enumerate() {
        out.push_str(&format!("topic {topic:4}  (n_t={:8}): ", state.nt[topic]));
        for (w, c) in words {
            if (*w as usize) < vocab_words.len() {
                out.push_str(&format!("{}:{c} ", vocab_words[*w as usize]));
            } else {
                out.push_str(&format!("w{w}:{c} "));
            }
        }
        out.push('\n');
    }
    out
}

/// Point estimate φ_t(w) = (n_wt + β)/(n_t + Jβ) for one topic (dense row).
pub fn phi_row(state: &LdaState, topic: u16) -> Vec<f64> {
    let bb = state.hyper.betabar(state.vocab);
    let denom = state.nt[topic as usize] as f64 + bb;
    (0..state.vocab)
        .map(|w| (state.nwt[w].get(topic) as f64 + state.hyper.beta) / denom)
        .collect()
}

/// Point estimate θ_d(t) = (n_td + α)/(n_d + Tα) for one document.
pub fn theta_row(state: &LdaState, doc: usize) -> Vec<f64> {
    let t = state.num_topics();
    let nd = state.ntd[doc].total() as f64;
    let denom = nd + t as f64 * state.hyper.alpha;
    (0..t)
        .map(|k| (state.ntd[doc].get(k as u16) as f64 + state.hyper.alpha) / denom)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;
    use crate::lda::state::Hyper;
    use crate::util::rng::Pcg32;

    fn state() -> (crate::corpus::Corpus, LdaState) {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(71);
        let s = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        (corpus, s)
    }

    #[test]
    fn top_words_sorted_and_bounded() {
        let (_, s) = state();
        let tops = top_words(&s, 5);
        assert_eq!(tops.len(), 8);
        for list in &tops {
            assert!(list.len() <= 5);
            for pair in list.windows(2) {
                assert!(pair[0].1 >= pair[1].1);
            }
        }
    }

    #[test]
    fn phi_theta_are_distributions() {
        let (_, s) = state();
        let phi: f64 = phi_row(&s, 0).iter().sum();
        assert!((phi - 1.0).abs() < 1e-9, "phi sums to {phi}");
        let theta: f64 = theta_row(&s, 0).iter().sum();
        assert!((theta - 1.0).abs() < 1e-9, "theta sums to {theta}");
    }

    #[test]
    fn render_includes_counts() {
        let (_, s) = state();
        let txt = render_topics(&s, &[], 3);
        assert!(txt.contains("topic"));
        assert!(txt.lines().count() == 8);
    }
}
