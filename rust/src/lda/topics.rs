//! Topic inspection: top-k words per topic and point estimates of the
//! topic-word (φ) and doc-topic (θ) distributions from the count state.

use std::cmp::Ordering;

use super::state::{LdaState, SparseCounts};

/// Deterministic top-word ordering: count descending, word id ascending
/// as the tie-break.
fn by_count_desc(a: &(u32, u32), b: &(u32, u32)) -> Ordering {
    b.1.cmp(&a.1).then(a.0.cmp(&b.0))
}

/// Top-k (word, count) per topic from a word-major count matrix — shared
/// by the live training state ([`top_words`]) and the frozen serving
/// artifact ([`crate::infer::TopicModel::top_words`]).
///
/// Uses `select_nth_unstable_by` to partition each topic's support around
/// the k-th order statistic in O(support) before sorting only the k
/// survivors, instead of fully sorting the (potentially vocabulary-sized)
/// list and truncating.
pub fn top_words_rows(nwt: &[SparseCounts], t: usize, k: usize) -> Vec<Vec<(u32, u32)>> {
    let mut per_topic: Vec<Vec<(u32, u32)>> = vec![Vec::new(); t];
    for (w, counts) in nwt.iter().enumerate() {
        for (topic, c) in counts.iter() {
            per_topic[topic as usize].push((w as u32, c));
        }
    }
    for list in &mut per_topic {
        if k == 0 {
            list.clear();
            continue;
        }
        if list.len() > k {
            // everything at index > k-1 compares ≥ the pivot under the
            // total order above, so dropping it preserves the exact top-k
            // set *and* the deterministic tie-break
            list.select_nth_unstable_by(k - 1, by_count_desc);
            list.truncate(k);
        }
        list.sort_unstable_by(by_count_desc);
    }
    per_topic
}

/// Top-k (word, count) per topic.
pub fn top_words(state: &LdaState, k: usize) -> Vec<Vec<(u32, u32)>> {
    top_words_rows(&state.nwt, state.num_topics(), k)
}

/// Render the topics with vocabulary strings when available.
pub fn render_topics(state: &LdaState, vocab_words: &[String], k: usize) -> String {
    let mut out = String::new();
    for (topic, words) in top_words(state, k).iter().enumerate() {
        out.push_str(&format!("topic {topic:4}  (n_t={:8}): ", state.nt[topic]));
        for (w, c) in words {
            if (*w as usize) < vocab_words.len() {
                out.push_str(&format!("{}:{c} ", vocab_words[*w as usize]));
            } else {
                out.push_str(&format!("w{w}:{c} "));
            }
        }
        out.push('\n');
    }
    out
}

/// Point estimate φ_t(w) = (n_wt + β)/(n_t + Jβ) for one topic (dense row).
pub fn phi_row(state: &LdaState, topic: u16) -> Vec<f64> {
    let bb = state.hyper.betabar(state.vocab);
    let denom = state.nt[topic as usize] as f64 + bb;
    (0..state.vocab)
        .map(|w| (state.nwt[w].get(topic) as f64 + state.hyper.beta) / denom)
        .collect()
}

/// Point estimate θ_d(t) = (n_td + α)/(n_d + Tα) for one document.
pub fn theta_row(state: &LdaState, doc: usize) -> Vec<f64> {
    let t = state.num_topics();
    let nd = state.ntd[doc].total() as f64;
    let denom = nd + t as f64 * state.hyper.alpha;
    (0..t)
        .map(|k| (state.ntd[doc].get(k as u16) as f64 + state.hyper.alpha) / denom)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;
    use crate::lda::state::Hyper;
    use crate::util::rng::Pcg32;

    fn state() -> (crate::corpus::Corpus, LdaState) {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(71);
        let s = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        (corpus, s)
    }

    #[test]
    fn top_words_sorted_and_bounded() {
        let (_, s) = state();
        let tops = top_words(&s, 5);
        assert_eq!(tops.len(), 8);
        for list in &tops {
            assert!(list.len() <= 5);
            for pair in list.windows(2) {
                assert!(pair[0].1 >= pair[1].1);
            }
        }
    }

    /// Oracle: partial selection returns exactly what a full sort +
    /// truncate returns, ties included (count desc, word asc).  The tiny
    /// preset's random init is saturated with count ties, which is
    /// precisely where a sloppy partition would reorder results.
    #[test]
    fn partial_selection_matches_full_sort_reference() {
        let (_, s) = state();
        for k in [0usize, 1, 3, 5, 64, 10_000] {
            let got = top_words(&s, k);
            // reference: the pre-optimization implementation
            let t = s.num_topics();
            let mut want: Vec<Vec<(u32, u32)>> = vec![Vec::new(); t];
            for (w, counts) in s.nwt.iter().enumerate() {
                for (topic, c) in counts.iter() {
                    want[topic as usize].push((w as u32, c));
                }
            }
            for list in &mut want {
                list.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
                list.truncate(k);
            }
            assert_eq!(got, want, "k={k}");
        }
    }

    #[test]
    fn phi_theta_are_distributions() {
        let (_, s) = state();
        let phi: f64 = phi_row(&s, 0).iter().sum();
        assert!((phi - 1.0).abs() < 1e-9, "phi sums to {phi}");
        let theta: f64 = theta_row(&s, 0).iter().sum();
        assert!((theta - 1.0).abs() < 1e-9, "theta sums to {theta}");
    }

    #[test]
    fn render_includes_counts() {
        let (_, s) = state();
        let txt = render_topics(&s, &[], 3);
        assert!(txt.contains("topic"));
        assert!(txt.lines().count() == 8);
    }
}
