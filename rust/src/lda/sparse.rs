//! SparseLDA (Yao, Mimno & McCallum, KDD'09) — the sampler inside Yahoo!
//! LDA and Mallet, the paper's §3.3 first baseline.  Three-term
//! decomposition of eq. (2):
//!
//! ```text
//!     p_t = αβ/(n_t+β̄)  +  β·n_td/(n_t+β̄)  +  n_tw·(n_td+α)/(n_t+β̄)
//!           \_ "s" dense _/  \_ "r" |T_d|-sparse _/ \_ "q" |T_w|-sparse _/
//! ```
//!
//! Bucket masses are maintained incrementally (the n_t/n_td terms change
//! in O(1) coordinates per step); each draw picks a bucket by mass and
//! linear-searches inside it (LSearch — most of the mass is in `q`, whose
//! support is |T_w|).  Amortized Θ(|T_w| + |T_d|) per token, exact.

use crate::corpus::Corpus;
use crate::util::rng::Pcg32;

use super::state::LdaState;
use super::{add_token, remove_token, Sweep};

/// SparseLDA sweeper.
pub struct SparseLda {
    /// Σ_t αβ/(n_t+β̄), maintained incrementally
    s_sum: f64,
    /// Σ_{t∈T_d} β·n_td/(n_t+β̄) for the current doc
    r_sum: f64,
    /// dense coefficient cache: coeff[t] = (n_td + α)/(n_t + β̄)
    coeff: Vec<f64>,
}

impl SparseLda {
    pub fn new(state: &LdaState) -> Self {
        SparseLda { s_sum: 0.0, r_sum: 0.0, coeff: vec![0.0; state.num_topics()] }
    }

    /// coeff base for topics outside the current doc's support.
    #[inline]
    fn base_coeff(state: &LdaState, t: usize) -> f64 {
        state.hyper.alpha / (state.nt[t] as f64 + state.hyper.betabar(state.vocab))
    }

    #[inline]
    fn doc_coeff(state: &LdaState, doc: usize, t: u16) -> f64 {
        (state.ntd[doc].get(t) as f64 + state.hyper.alpha)
            / (state.nt[t as usize] as f64 + state.hyper.betabar(state.vocab))
    }

    /// Recompute the dense smoothing mass exactly.
    fn rebuild_s(&mut self, state: &LdaState) {
        let ab = state.hyper.alpha * state.hyper.beta;
        let bb = state.hyper.betabar(state.vocab);
        self.s_sum = state.nt.iter().map(|&n| ab / (n as f64 + bb)).sum();
    }

    /// Recompute the doc-bucket mass for the current doc exactly.
    fn rebuild_r(&mut self, state: &LdaState, doc: usize) {
        let beta = state.hyper.beta;
        let bb = state.hyper.betabar(state.vocab);
        self.r_sum = state.ntd[doc]
            .iter()
            .map(|(t, c)| beta * c as f64 / (state.nt[t as usize] as f64 + bb))
            .sum();
    }

    /// Incremental bucket/coefficient maintenance after n_t/n_td of topic
    /// `t` changed (called once for the decremented and once for the
    /// incremented topic).
    #[inline]
    fn refresh_topic(&mut self, state: &LdaState, doc: usize, t: u16, old_nt: u32, old_ntd: u32) {
        let h = state.hyper;
        let bb = h.betabar(state.vocab);
        let old_denom = old_nt as f64 + bb;
        let new_denom = state.nt[t as usize] as f64 + bb;
        let new_ntd = state.ntd[doc].get(t) as f64;
        self.s_sum += h.alpha * h.beta * (1.0 / new_denom - 1.0 / old_denom);
        self.r_sum += h.beta * (new_ntd / new_denom - old_ntd as f64 / old_denom);
        self.coeff[t as usize] = (new_ntd + h.alpha) / new_denom;
    }
}

impl Sweep for SparseLda {
    fn sweep(&mut self, state: &mut LdaState, corpus: &Corpus, rng: &mut Pcg32) {
        let h = state.hyper;
        let bb = h.betabar(state.vocab);
        // dense coeff cache starts at the base value for every topic
        for t in 0..state.num_topics() {
            self.coeff[t] = Self::base_coeff(state, t);
        }
        self.rebuild_s(state);

        let mut docs = corpus.docs_in(0..corpus.num_docs());
        while let Some((doc, toks)) = docs.next_doc() {
            // enter doc: raise coeff on T_d, compute r mass
            let support: Vec<u16> = state.ntd[doc].iter().map(|(t, _)| t).collect();
            for &t in &support {
                self.coeff[t as usize] = Self::doc_coeff(state, doc, t);
            }
            self.rebuild_r(state, doc);

            let base = state.doc_offsets[doc];
            for (pos, &wtok) in toks.iter().enumerate() {
                let word = wtok as usize;
                let old = state.z[base + pos];
                let (old_nt, old_ntd) = (state.nt[old as usize], state.ntd[doc].get(old));
                remove_token(state, doc, word, old);
                self.refresh_topic(state, doc, old, old_nt, old_ntd);

                // q bucket: Σ_{t∈T_w} n_tw · coeff[t]
                let mut q_sum = 0.0;
                for (t, c) in state.nwt[word].iter() {
                    q_sum += c as f64 * self.coeff[t as usize];
                }

                let total = q_sum + self.r_sum + self.s_sum;
                let mut u = rng.uniform(total);
                let new: u16;
                if u < q_sum {
                    // topic-word bucket (most mass): LSearch over T_w
                    let mut chosen = None;
                    let mut last = 0;
                    for (t, c) in state.nwt[word].iter() {
                        let w = c as f64 * self.coeff[t as usize];
                        if u < w {
                            chosen = Some(t);
                            break;
                        }
                        u -= w;
                        last = t;
                    }
                    new = chosen.unwrap_or(last);
                } else if u < q_sum + self.r_sum {
                    // doc bucket: LSearch over T_d
                    u -= q_sum;
                    let mut chosen = None;
                    let mut last = 0;
                    for (t, c) in state.ntd[doc].iter() {
                        let w = h.beta * c as f64 / (state.nt[t as usize] as f64 + bb);
                        if u < w {
                            chosen = Some(t);
                            break;
                        }
                        u -= w;
                        last = t;
                    }
                    new = chosen.unwrap_or(last);
                } else {
                    // smoothing bucket: LSearch over all T (rare)
                    u -= q_sum + self.r_sum;
                    let ab = h.alpha * h.beta;
                    let mut chosen = state.num_topics() - 1;
                    for t in 0..state.num_topics() {
                        let w = ab / (state.nt[t] as f64 + bb);
                        if u < w {
                            chosen = t;
                            break;
                        }
                        u -= w;
                    }
                    new = chosen as u16;
                }

                let (new_nt, new_ntd) = (state.nt[new as usize], state.ntd[doc].get(new));
                add_token(state, doc, word, new);
                self.refresh_topic(state, doc, new, new_nt, new_ntd);
                state.z[base + pos] = new;
            }

            // leave doc: lower coeff back to base on the final support
            let support: Vec<u16> = state.ntd[doc].iter().map(|(t, _)| t).collect();
            for &t in &support {
                self.coeff[t as usize] = Self::base_coeff(state, t as usize);
            }
            // drift control: r is rebuilt on doc entry anyway; s refreshed
            // here keeps the error independent of corpus length
            self.rebuild_s(state);
        }
    }

    fn name(&self) -> &'static str {
        "sparse"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;
    use crate::lda::state::Hyper;

    #[test]
    fn sweep_is_consistent() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(51);
        let mut state = LdaState::init_random(&corpus, Hyper::paper_default(16), &mut rng);
        let mut s = SparseLda::new(&state);
        for _ in 0..3 {
            s.sweep(&mut state, &corpus, &mut rng);
        }
        state.check_consistency(&corpus).unwrap();
    }

    #[test]
    fn bucket_masses_match_direct_computation() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(52);
        let mut state = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        let mut s = SparseLda::new(&state);
        s.sweep(&mut state, &corpus, &mut rng);
        // after a sweep the incremental s_sum must equal a fresh rebuild
        let incremental = s.s_sum;
        s.rebuild_s(&state);
        assert!(
            (incremental - s.s_sum).abs() < 1e-9 * s.s_sum,
            "s_sum drifted: {incremental} vs {}",
            s.s_sum
        );
    }
}
