//! Checkpointing: save/restore a full Gibbs state to disk.
//!
//! Production trainers checkpoint; the format here is a versioned,
//! self-describing binary layout (little-endian, no external crates):
//!
//! ```text
//! magic "FNLDA001" | T u32 | vocab u32 | D u32 | alpha f64 | beta f64
//! per doc: len u32, then len × u16 topic ids          (z; counts derived)
//! ```
//!
//! Counts are *rederived* on load and cross-checked, so a corrupt file
//! cannot produce an inconsistent state.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::corpus::Corpus;
use crate::util::rng::Pcg32;

use super::state::{Hyper, LdaState, SparseCounts};

const MAGIC: &[u8; 8] = b"FNLDA001";

/// Serialize the state (assignments + hyperparameters).
pub fn save(state: &LdaState, path: &Path) -> Result<(), String> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
    }
    let f = std::fs::File::create(path).map_err(|e| e.to_string())?;
    let mut w = BufWriter::new(f);
    let io = |e: std::io::Error| e.to_string();
    w.write_all(MAGIC).map_err(io)?;
    w.write_all(&(state.hyper.t as u32).to_le_bytes()).map_err(io)?;
    w.write_all(&(state.vocab as u32).to_le_bytes()).map_err(io)?;
    w.write_all(&(state.z.len() as u32).to_le_bytes()).map_err(io)?;
    w.write_all(&state.hyper.alpha.to_le_bytes()).map_err(io)?;
    w.write_all(&state.hyper.beta.to_le_bytes()).map_err(io)?;
    for zs in &state.z {
        w.write_all(&(zs.len() as u32).to_le_bytes()).map_err(io)?;
        for &z in zs {
            w.write_all(&z.to_le_bytes()).map_err(io)?;
        }
    }
    w.flush().map_err(io)
}

/// Load a checkpoint and rebuild the counts against `corpus`.
pub fn load(path: &Path, corpus: &Corpus) -> Result<LdaState, String> {
    let f = std::fs::File::open(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let mut r = BufReader::new(f);
    let io = |e: std::io::Error| e.to_string();

    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).map_err(io)?;
    if &magic != MAGIC {
        return Err("bad magic: not an FNLDA001 checkpoint".into());
    }
    let mut b4 = [0u8; 4];
    let mut b8 = [0u8; 8];
    let mut read_u32 = |r: &mut BufReader<std::fs::File>| -> Result<u32, String> {
        r.read_exact(&mut b4).map_err(io)?;
        Ok(u32::from_le_bytes(b4))
    };
    let t = read_u32(&mut r)? as usize;
    let vocab = read_u32(&mut r)? as usize;
    let d = read_u32(&mut r)? as usize;
    r.read_exact(&mut b8).map_err(io)?;
    let alpha = f64::from_le_bytes(b8);
    r.read_exact(&mut b8).map_err(io)?;
    let beta = f64::from_le_bytes(b8);

    if vocab != corpus.vocab {
        return Err(format!("checkpoint vocab {vocab} != corpus vocab {}", corpus.vocab));
    }
    if d != corpus.num_docs() {
        return Err(format!("checkpoint has {d} docs, corpus {}", corpus.num_docs()));
    }

    let hyper = Hyper { t, alpha, beta };
    let mut z: Vec<Vec<u16>> = Vec::with_capacity(d);
    let mut ntd = Vec::with_capacity(d);
    let mut nwt = vec![SparseCounts::default(); vocab];
    let mut nt = vec![0u32; t];
    let mut b2 = [0u8; 2];
    for doc in 0..d {
        let len = {
            let mut b4 = [0u8; 4];
            r.read_exact(&mut b4).map_err(io)?;
            u32::from_le_bytes(b4) as usize
        };
        if len != corpus.docs[doc].len() {
            return Err(format!(
                "doc {doc}: checkpoint has {len} tokens, corpus {}",
                corpus.docs[doc].len()
            ));
        }
        let mut zs = Vec::with_capacity(len);
        let mut counts = SparseCounts::default();
        for pos in 0..len {
            r.read_exact(&mut b2).map_err(io)?;
            let topic = u16::from_le_bytes(b2);
            if topic as usize >= t {
                return Err(format!("doc {doc} pos {pos}: topic {topic} >= T {t}"));
            }
            zs.push(topic);
            counts.inc(topic);
            nwt[corpus.docs[doc][pos] as usize].inc(topic);
            nt[topic as usize] += 1;
        }
        z.push(zs);
        ntd.push(counts);
    }
    let state = LdaState { hyper, vocab, z, ntd, nwt, nt };
    state.check_consistency(corpus)?;
    Ok(state)
}

/// Round-trip helper used by the CLI: save, reload, verify, return bytes.
pub fn verify_roundtrip(state: &LdaState, corpus: &Corpus, path: &Path) -> Result<u64, String> {
    save(state, path)?;
    let back = load(path, corpus)?;
    if back.z != state.z {
        return Err("roundtrip mismatch in assignments".into());
    }
    Ok(std::fs::metadata(path).map_err(|e| e.to_string())?.len())
}

/// Deterministic fresh state helper mirroring init_random (exposed here so
/// the CLI resume path shares one entry point).
pub fn init_or_load(
    path: Option<&Path>,
    corpus: &Corpus,
    hyper: Hyper,
    seed: u64,
) -> Result<LdaState, String> {
    match path {
        Some(p) if p.exists() => load(p, corpus),
        _ => {
            let mut rng = Pcg32::seeded(seed);
            Ok(LdaState::init_random(corpus, hyper, &mut rng))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;
    use crate::lda::{FLdaWord, Sweep};

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join("fnomad_ckpt_tests").join(name)
    }

    #[test]
    fn roundtrip_preserves_state() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(8);
        let mut state = LdaState::init_random(&corpus, Hyper::paper_default(16), &mut rng);
        let mut s = FLdaWord::new(&state, &corpus);
        for _ in 0..3 {
            s.sweep(&mut state, &corpus, &mut rng);
        }
        let path = tmp("rt.ckpt");
        let bytes = verify_roundtrip(&state, &corpus, &path).unwrap();
        assert!(bytes > 8);
        let back = load(&path, &corpus).unwrap();
        assert_eq!(back.z, state.z);
        assert_eq!(back.nt, state.nt);
        assert!((back.hyper.alpha - state.hyper.alpha).abs() < 1e-15);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn rejects_wrong_corpus() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(9);
        let state = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        let path = tmp("wrong.ckpt");
        save(&state, &path).unwrap();
        let mut other = corpus.clone();
        other.docs.pop();
        assert!(load(&path, &other).is_err());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("garbage.ckpt");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, b"not a checkpoint").unwrap();
        let corpus = preset("tiny").unwrap();
        let err = load(&path, &corpus).unwrap_err();
        assert!(err.contains("magic"));
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn init_or_load_falls_back() {
        let corpus = preset("tiny").unwrap();
        let state =
            init_or_load(None, &corpus, Hyper::paper_default(8), 1).unwrap();
        state.check_consistency(&corpus).unwrap();
    }
}
