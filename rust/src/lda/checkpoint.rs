//! Checkpointing: save/restore a full Gibbs state to disk.
//!
//! Production trainers checkpoint; the format here is a versioned,
//! self-describing binary layout (little-endian, no external crates):
//!
//! ```text
//! magic "FNLDA001" | T u32 | vocab u32 | D u32 | alpha f64 | beta f64
//! per doc: len u32, then len × u16 topic ids          (z; counts derived)
//! ```
//!
//! Counts are *rederived* on load and cross-checked, so a corrupt file
//! cannot produce an inconsistent state.

use std::io::{BufReader, Read, Write};
use std::path::{Path, PathBuf};

use crate::corpus::Corpus;
use crate::util::fsio::AtomicFile;
use crate::util::rng::Pcg32;

use super::state::{Hyper, LdaState, SparseCounts};

const MAGIC: &[u8; 8] = b"FNLDA001";

/// Serialize the state (assignments + hyperparameters).
///
/// The byte format is exactly FNLDA001 (see the module docs); with the
/// flat CSR `z` each document row goes out as one bulk `write_all`
/// instead of one 2-byte write per token — roughly an order of magnitude
/// on the billion-token target, with no transient copy of the assignment
/// array.  The write is atomic ([`AtomicFile`]): a crash mid-save leaves
/// the previous complete file at `path`, never a torn prefix.
pub fn save(state: &LdaState, path: &Path) -> Result<(), String> {
    save_fingerprinted(state, path).map(|_| ())
}

/// [`save`] that also returns the FNV-1a fingerprint of the written
/// bytes — the resilience manifest records it so recovery can detect a
/// checkpoint corrupted *after* the atomic rename.
pub fn save_fingerprinted(state: &LdaState, path: &Path) -> Result<u64, String> {
    let mut w = AtomicFile::create(path)?;
    let io = |e: std::io::Error| e.to_string();
    w.write_all(MAGIC).map_err(io)?;
    w.write_all(&(state.hyper.t as u32).to_le_bytes()).map_err(io)?;
    w.write_all(&(state.vocab as u32).to_le_bytes()).map_err(io)?;
    w.write_all(&(state.num_docs() as u32).to_le_bytes()).map_err(io)?;
    w.write_all(&state.hyper.alpha.to_le_bytes()).map_err(io)?;
    w.write_all(&state.hyper.beta.to_le_bytes()).map_err(io)?;
    for d in 0..state.num_docs() {
        let row = state.z_doc(d);
        w.write_all(&(row.len() as u32).to_le_bytes()).map_err(io)?;
        write_z_row(&mut w, row).map_err(io)?;
    }
    w.commit()
}

/// Sibling path holding the previously retained generation of a
/// single-file checkpoint (`<path>.prev`).
pub fn prev_path(path: &Path) -> PathBuf {
    let mut name = path.as_os_str().to_os_string();
    name.push(".prev");
    PathBuf::from(name)
}

/// Atomic save that first retains the existing file as `<path>.prev`
/// (a hard link, so retention is O(1) regardless of checkpoint size).
/// This is the `Checkpointer` observer's save path: even if this whole
/// *generation* turns out bad — disk corruption after the rename —
/// [`init_or_load`] can still fall back to the previous one.
pub fn save_with_retention(state: &LdaState, path: &Path) -> Result<(), String> {
    if path.exists() {
        let prev = prev_path(path);
        let _ = std::fs::remove_file(&prev);
        if let Err(e) = std::fs::hard_link(path, &prev) {
            crate::log_event!(
                Warn,
                "checkpoint",
                "warning: could not retain {} as {}: {e}",
                path.display(),
                prev.display()
            );
        }
    }
    save(state, path)
}

/// Write a z row as little-endian u16 bytes.
#[cfg(target_endian = "little")]
fn write_z_row<W: Write>(w: &mut W, row: &[u16]) -> std::io::Result<()> {
    // on a little-endian target the in-memory u16 bytes ARE the wire
    // format, so the whole row is one write
    let bytes =
        unsafe { std::slice::from_raw_parts(row.as_ptr().cast::<u8>(), row.len() * 2) };
    w.write_all(bytes)
}

#[cfg(target_endian = "big")]
fn write_z_row<W: Write>(w: &mut W, row: &[u16]) -> std::io::Result<()> {
    for &z in row {
        w.write_all(&z.to_le_bytes())?;
    }
    Ok(())
}

/// The fixed 36-byte FNLDA001 header.
struct Header {
    hyper: Hyper,
    vocab: usize,
    num_docs: usize,
}

fn read_header<R: Read>(r: &mut R) -> Result<Header, String> {
    let io = |e: std::io::Error| e.to_string();
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).map_err(io)?;
    if &magic != MAGIC {
        return Err("bad magic: not an FNLDA001 checkpoint".into());
    }
    let mut b4 = [0u8; 4];
    let mut b8 = [0u8; 8];
    let mut read_u32 = |r: &mut R| -> Result<u32, String> {
        r.read_exact(&mut b4).map_err(io)?;
        Ok(u32::from_le_bytes(b4))
    };
    let t = read_u32(r)? as usize;
    let vocab = read_u32(r)? as usize;
    let num_docs = read_u32(r)? as usize;
    r.read_exact(&mut b8).map_err(io)?;
    let alpha = f64::from_le_bytes(b8);
    r.read_exact(&mut b8).map_err(io)?;
    let beta = f64::from_le_bytes(b8);
    Ok(Header { hyper: Hyper { t, alpha, beta }, vocab, num_docs })
}

/// Read only the header's hyperparameters — cheap shape validation
/// without touching the (potentially multi-GB) body.
pub fn peek_hyper(path: &Path) -> Result<Hyper, String> {
    let f = std::fs::File::open(path).map_err(|e| format!("{}: {e}", path.display()))?;
    Ok(read_header(&mut BufReader::new(f))?.hyper)
}

/// Load a checkpoint and rebuild the counts against `corpus`.
pub fn load(path: &Path, corpus: &Corpus) -> Result<LdaState, String> {
    let f = std::fs::File::open(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let mut r = BufReader::new(f);
    let io = |e: std::io::Error| e.to_string();

    let Header { hyper, vocab, num_docs: d } = read_header(&mut r)?;
    let t = hyper.t;

    if vocab != corpus.vocab() {
        return Err(format!("checkpoint vocab {vocab} != corpus vocab {}", corpus.vocab()));
    }
    if d != corpus.num_docs() {
        return Err(format!("checkpoint has {d} docs, corpus {}", corpus.num_docs()));
    }
    let mut z: Vec<u16> = Vec::with_capacity(corpus.num_tokens());
    let mut ntd = Vec::with_capacity(d);
    let mut nwt = vec![SparseCounts::default(); vocab];
    let mut nt = vec![0u32; t];
    let mut row_bytes: Vec<u8> = Vec::new();
    for doc in 0..d {
        let len = {
            let mut b4 = [0u8; 4];
            r.read_exact(&mut b4).map_err(io)?;
            u32::from_le_bytes(b4) as usize
        };
        if len != corpus.doc_len(doc) {
            return Err(format!(
                "doc {doc}: checkpoint has {len} tokens, corpus {}",
                corpus.doc_len(doc)
            ));
        }
        // one bulk read per doc row instead of one 2-byte read per token
        row_bytes.resize(2 * len, 0);
        r.read_exact(&mut row_bytes).map_err(io)?;
        let words = corpus.doc(doc);
        let mut counts = SparseCounts::default();
        for pos in 0..len {
            let topic = u16::from_le_bytes([row_bytes[2 * pos], row_bytes[2 * pos + 1]]);
            if topic as usize >= t {
                return Err(format!("doc {doc} pos {pos}: topic {topic} >= T {t}"));
            }
            z.push(topic);
            counts.inc(topic);
            nwt[words[pos] as usize].inc(topic);
            nt[topic as usize] += 1;
        }
        ntd.push(counts);
    }
    let state = LdaState {
        hyper,
        vocab,
        z,
        doc_offsets: corpus.offsets().to_vec(),
        ntd,
        nwt,
        nt,
    };
    state.check_consistency(corpus)?;
    Ok(state)
}

/// Round-trip helper used by the CLI: save, reload, verify, return bytes.
pub fn verify_roundtrip(state: &LdaState, corpus: &Corpus, path: &Path) -> Result<u64, String> {
    save(state, path)?;
    let back = load(path, corpus)?;
    if back.z != state.z {
        return Err("roundtrip mismatch in assignments".into());
    }
    Ok(std::fs::metadata(path).map_err(|e| e.to_string())?.len())
}

/// How a checkpoint refused to load: a deliberate shape [`Mismatch`]
/// (wrong `--topics` — actionable, must stay a hard error) versus file
/// [`Corruption`] (torn bytes, bad magic — recoverable by falling back
/// to an older generation).
///
/// [`Mismatch`]: LoadFailure::Mismatch
/// [`Corruption`]: LoadFailure::Corruption
enum LoadFailure {
    Mismatch(String),
    Corruption(String),
}

/// Header-check + load + consistency, classifying the failure mode.
fn try_load_validated(
    p: &Path,
    corpus: &Corpus,
    hyper: Hyper,
    quiet: bool,
) -> Result<LdaState, LoadFailure> {
    // header-only validation first: a multi-GB body should not be read
    // and count-rebuilt just to discover a T mismatch
    let ckpt = peek_hyper(p).map_err(LoadFailure::Corruption)?;
    if ckpt.t != hyper.t {
        return Err(LoadFailure::Mismatch(format!(
            "checkpoint {} has T={} but T={} was requested; pass --topics {} \
             to resume it (or point --checkpoint elsewhere)",
            p.display(),
            ckpt.t,
            hyper.t,
            ckpt.t
        )));
    }
    if !quiet
        && ((ckpt.alpha - hyper.alpha).abs() > 1e-12 || (ckpt.beta - hyper.beta).abs() > 1e-12)
    {
        crate::log_event!(
            Warn,
            "checkpoint",
            "warning: resuming with checkpoint hyperparameters \
             alpha={:.6} beta={:.6} (requested alpha={:.6} beta={:.6})",
            ckpt.alpha,
            ckpt.beta,
            hyper.alpha,
            hyper.beta
        );
    }
    load(p, corpus).map_err(LoadFailure::Corruption)
}

/// Deterministic fresh state helper mirroring init_random (exposed here so
/// the CLI resume path shares one entry point).
///
/// When a checkpoint exists, the *requested* hyperparameters are
/// validated against it instead of being silently discarded: a topic
/// count mismatch is an error (T is baked into every count row — resuming
/// a T=1024 checkpoint as T=512 cannot work), while an alpha/beta
/// mismatch warns (suppressed by `quiet`, like every other emitter) and
/// proceeds with the checkpoint values (they are smoothers, legitimately
/// retuned by `--hyper-opt`).
///
/// A truncated or corrupt file is *not* fatal: the loader falls back to
/// the `<path>.prev` generation retained by [`save_with_retention`] (and
/// to a fresh random init if that is unusable too), warning either way —
/// a crashed run should resume from the best surviving state, not refuse
/// to start.
pub fn init_or_load(
    path: Option<&Path>,
    corpus: &Corpus,
    hyper: Hyper,
    seed: u64,
    quiet: bool,
) -> Result<LdaState, String> {
    let random = |seed: u64| {
        let mut rng = Pcg32::seeded(seed);
        LdaState::init_random(corpus, hyper, &mut rng)
    };
    match path {
        Some(p) if p.exists() => match try_load_validated(p, corpus, hyper, quiet) {
            Ok(state) => Ok(state),
            Err(LoadFailure::Mismatch(e)) => Err(e),
            Err(LoadFailure::Corruption(why)) => {
                crate::log_event!(
                    Warn,
                    "checkpoint",
                    "warning: {} is truncated or corrupt ({why}); \
                     trying the previous retained generation",
                    p.display()
                );
                let prev = prev_path(p);
                if prev.exists() {
                    match try_load_validated(&prev, corpus, hyper, quiet) {
                        Ok(state) => {
                            crate::log_event!(
                                Info,
                                "checkpoint",
                                "recovered from {}",
                                prev.display()
                            );
                            Ok(state)
                        }
                        Err(LoadFailure::Mismatch(e)) => Err(e),
                        Err(LoadFailure::Corruption(why)) => {
                            crate::log_event!(
                                Warn,
                                "checkpoint",
                                "warning: {} is also unusable ({why}); \
                                 starting from a fresh random init",
                                prev.display()
                            );
                            Ok(random(seed))
                        }
                    }
                } else {
                    crate::log_event!(
                        Warn,
                        "checkpoint",
                        "warning: no {} fallback; starting from a fresh \
                         random init",
                        prev.display()
                    );
                    Ok(random(seed))
                }
            }
        },
        _ => Ok(random(seed)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;
    use crate::lda::{FLdaWord, Sweep};

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join("fnomad_ckpt_tests").join(name)
    }

    #[test]
    fn roundtrip_preserves_state() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(8);
        let mut state = LdaState::init_random(&corpus, Hyper::paper_default(16), &mut rng);
        let mut s = FLdaWord::new(&state, &corpus);
        for _ in 0..3 {
            s.sweep(&mut state, &corpus, &mut rng);
        }
        let path = tmp("rt.ckpt");
        let bytes = verify_roundtrip(&state, &corpus, &path).unwrap();
        assert!(bytes > 8);
        let back = load(&path, &corpus).unwrap();
        assert_eq!(back.z, state.z);
        assert_eq!(back.nt, state.nt);
        assert!((back.hyper.alpha - state.hyper.alpha).abs() < 1e-15);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn rejects_wrong_corpus() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(9);
        let state = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        let path = tmp("wrong.ckpt");
        save(&state, &path).unwrap();
        // rebuild the corpus without its last document
        let mut other = crate::corpus::Corpus::with_meta(
            corpus.vocab(),
            corpus.vocab_words().to_vec(),
            corpus.name().to_string(),
        );
        for doc in corpus.docs().take(corpus.num_docs() - 1) {
            other.push_doc(&doc);
        }
        assert!(load(&path, &other).is_err());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn save_bytes_match_the_original_per_token_writer() {
        // golden oracle: the pre-CSR writer emitted the header followed by
        // one `len` u32 and one 2-byte little-endian write per token; the
        // bulk writer must keep the FNLDA001 stream byte-identical
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(12);
        let state = LdaState::init_random(&corpus, Hyper::paper_default(16), &mut rng);
        let mut want: Vec<u8> = Vec::new();
        want.extend_from_slice(MAGIC);
        want.extend_from_slice(&(state.hyper.t as u32).to_le_bytes());
        want.extend_from_slice(&(state.vocab as u32).to_le_bytes());
        want.extend_from_slice(&(state.num_docs() as u32).to_le_bytes());
        want.extend_from_slice(&state.hyper.alpha.to_le_bytes());
        want.extend_from_slice(&state.hyper.beta.to_le_bytes());
        for d in 0..state.num_docs() {
            let row = state.z_doc(d);
            want.extend_from_slice(&(row.len() as u32).to_le_bytes());
            for &zv in row {
                want.extend_from_slice(&zv.to_le_bytes());
            }
        }
        let path = tmp("golden.ckpt");
        save(&state, &path).unwrap();
        let got = std::fs::read(&path).unwrap();
        assert_eq!(got, want, "FNLDA001 byte format changed");
        // and the old-format bytes load back to the same state
        let back = load(&path, &corpus).unwrap();
        assert_eq!(back.z, state.z);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn init_or_load_rejects_topic_mismatch() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(13);
        let state = LdaState::init_random(&corpus, Hyper::paper_default(16), &mut rng);
        let path = tmp("tmismatch.ckpt");
        save(&state, &path).unwrap();
        let err = init_or_load(Some(&path), &corpus, Hyper::paper_default(8), 1, true)
            .unwrap_err();
        assert!(err.contains("T=16"), "error must name the checkpoint T: {err}");
        assert!(err.contains("T=8"), "error must name the requested T: {err}");
        // matching request resumes fine
        let ok =
            init_or_load(Some(&path), &corpus, Hyper::paper_default(16), 1, true).unwrap();
        ok.check_consistency(&corpus).unwrap();
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("garbage.ckpt");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, b"not a checkpoint").unwrap();
        let corpus = preset("tiny").unwrap();
        let err = load(&path, &corpus).unwrap_err();
        assert!(err.contains("magic"));
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn init_or_load_falls_back() {
        let corpus = preset("tiny").unwrap();
        let state =
            init_or_load(None, &corpus, Hyper::paper_default(8), 1, true).unwrap();
        state.check_consistency(&corpus).unwrap();
    }

    #[test]
    fn save_with_retention_keeps_previous_generation() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(21);
        let first = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        let second = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        let path = tmp("retain.ckpt");
        let _ = std::fs::remove_file(prev_path(&path));
        save_with_retention(&first, &path).unwrap();
        save_with_retention(&second, &path).unwrap();
        assert_eq!(load(&path, &corpus).unwrap().z, second.z);
        assert_eq!(load(&prev_path(&path), &corpus).unwrap().z, first.z);
        let _ = std::fs::remove_file(prev_path(&path));
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn init_or_load_recovers_from_truncated_file_via_prev() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(22);
        let first = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        let second = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        let path = tmp("torn.ckpt");
        let _ = std::fs::remove_file(prev_path(&path));
        save_with_retention(&first, &path).unwrap();
        save_with_retention(&second, &path).unwrap();
        // simulate a torn write that escaped the atomic rename
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        let state =
            init_or_load(Some(&path), &corpus, Hyper::paper_default(8), 1, true).unwrap();
        assert_eq!(state.z, first.z, "must recover the previous generation");
        // with no .prev either, a corrupt file degrades to a fresh init
        std::fs::write(&path, b"FNLDA001 and then garbage").unwrap();
        let _ = std::fs::remove_file(prev_path(&path));
        let fresh =
            init_or_load(Some(&path), &corpus, Hyper::paper_default(8), 1, true).unwrap();
        fresh.check_consistency(&corpus).unwrap();
        let _ = std::fs::remove_file(path);
    }
}
