//! AliasLDA (Li, Ahmed, Ravi & Smola, KDD'14) — the paper's §3.3 second
//! baseline.  Decomposition (5):
//!
//! ```text
//!     p_t = α·(n_tw+β)/(n_t+β̄)  +  n_td·(n_tw+β)/(n_t+β̄)
//!           \_ stale, alias-sampled _/ \_ fresh, |T_d|-sparse _/
//! ```
//!
//! The dense first term is sampled from *stale* alias structures built on a
//! snapshot of (n_tw, n_t) and amortized over many draws; the proposal
//! (fresh sparse + stale dense) is corrected toward the true conditional
//! with a short Metropolis–Hastings chain, so the sampler is *not* exact —
//! the slight convergence lag visible in Fig. 4(a,b).
//!
//! The stale dense term is itself split as α·β/(n̂_t+β̄) (word-independent,
//! one shared alias table) + α·n̂_tw/(n̂_t+β̄) (per-word, |T_w|-sparse alias
//! table), so per-word memory is O(|T_w|), not O(T).

use crate::corpus::Corpus;
use crate::sampler::alias::Alias;
use crate::sampler::bsearch::SparseCumSum;
use crate::sampler::DiscreteSampler;
use crate::util::rng::Pcg32;

use super::state::LdaState;
use super::{add_token, remove_token, Sweep};

/// Number of Metropolis–Hastings steps per token (#MH in Table 2).
pub const MH_STEPS: usize = 2;

/// Stale per-word alias structure over α·n̂_tw/(n̂_t+β̄).
struct WordTable {
    /// support snapshot: (topic, stale weight)
    weights: Vec<(u16, f64)>,
    table: Alias,
    sum: f64,
    draws_left: u32,
}

/// AliasLDA sweeper.
pub struct AliasLda {
    /// global stale snapshot of n_t
    nt_snap: Vec<u32>,
    /// shared alias table over α·β/(n̂_t+β̄)
    s_table: Alias,
    s_sum: f64,
    word_tables: Vec<Option<WordTable>>,
    r: SparseCumSum,
}

impl AliasLda {
    pub fn new(state: &LdaState) -> Self {
        let mut s = AliasLda {
            nt_snap: state.nt.clone(),
            s_table: Alias::build(&[1.0]),
            s_sum: 0.0,
            word_tables: Vec::new(),
            r: SparseCumSum::with_capacity(64),
        };
        s.word_tables.resize_with(state.nwt.len(), || None);
        s.snapshot(state);
        s
    }

    /// Refresh the global snapshot + shared smoothing table; invalidate
    /// per-word tables (they reference the old n̂_t).
    fn snapshot(&mut self, state: &LdaState) {
        let h = state.hyper;
        let bb = h.betabar(state.vocab);
        self.nt_snap.copy_from_slice(&state.nt);
        let sp: Vec<f64> = self
            .nt_snap
            .iter()
            .map(|&n| h.alpha * h.beta / (n as f64 + bb))
            .collect();
        self.s_sum = sp.iter().sum();
        self.s_table = Alias::build(&sp);
        for t in self.word_tables.iter_mut() {
            *t = None;
        }
    }

    /// Build (or fetch) the stale table for `word`.
    fn word_table(&mut self, state: &LdaState, word: usize) -> &mut WordTable {
        let rebuild = match &self.word_tables[word] {
            None => true,
            Some(t) => t.draws_left == 0,
        };
        if rebuild {
            let h = state.hyper;
            let bb = h.betabar(state.vocab);
            let weights: Vec<(u16, f64)> = state.nwt[word]
                .iter()
                .map(|(t, c)| {
                    (t, h.alpha * c as f64 / (self.nt_snap[t as usize] as f64 + bb))
                })
                .collect();
            let raw: Vec<f64> = weights.iter().map(|&(_, w)| w).collect();
            let sum: f64 = raw.iter().sum();
            let table = if raw.is_empty() { Alias::build(&[1.0]) } else { Alias::build(&raw) };
            // amortize the Θ(|T_w|) build over T draws (paper §3.3: "the
            // same Alias table can be used to generate T samples")
            let draws = (state.hyper.t as u32).max(16);
            self.word_tables[word] =
                Some(WordTable { weights, table, sum, draws_left: draws });
        }
        self.word_tables[word].as_mut().unwrap()
    }

    /// Stale dense proposal density q̂(t) = s(t) + word-sparse(t).
    fn stale_density(&self, state: &LdaState, word: usize, t: u16) -> f64 {
        let h = state.hyper;
        let bb = h.betabar(state.vocab);
        let mut v = h.alpha * h.beta / (self.nt_snap[t as usize] as f64 + bb);
        if let Some(wt) = &self.word_tables[word] {
            if let Ok(i) = wt.weights.binary_search_by_key(&t, |&(tt, _)| tt) {
                v += wt.weights[i].1;
            }
        }
        v
    }

    /// Fresh target density π(t) for the current (doc, word) with the
    /// token removed.
    #[inline]
    fn target(state: &LdaState, doc: usize, word: usize, t: u16) -> f64 {
        let h = state.hyper;
        let bb = h.betabar(state.vocab);
        (state.ntd[doc].get(t) as f64 + h.alpha)
            * (state.nwt[word].get(t) as f64 + h.beta)
            / (state.nt[t as usize] as f64 + bb)
    }
}

impl Sweep for AliasLda {
    fn sweep(&mut self, state: &mut LdaState, corpus: &Corpus, rng: &mut Pcg32) {
        let h = state.hyper;
        let bb = h.betabar(state.vocab);
        // refresh the global snapshot once per sweep (n_t drifts slowly)
        self.snapshot(state);

        let mut docs = corpus.docs_in(0..corpus.num_docs());
        while let Some((doc, toks)) = docs.next_doc() {
            let base = state.doc_offsets[doc];
            for (pos, &wtok) in toks.iter().enumerate() {
                let word = wtok as usize;
                let old = state.z[base + pos];
                remove_token(state, doc, word, old);

                // fresh sparse term r_t = n_td·(n_tw+β)/(n_t+β̄) over T_d
                self.r.clear();
                for (t, c) in state.ntd[doc].iter() {
                    let w = c as f64 * (state.nwt[word].get(t) as f64 + h.beta)
                        / (state.nt[t as usize] as f64 + bb);
                    self.r.push(t as u32, w);
                }
                let r_sum = self.r.total();
                let (wt_sum, wt_empty) = {
                    let wt = self.word_table(state, word);
                    wt.draws_left = wt.draws_left.saturating_sub(1);
                    (wt.sum, wt.weights.is_empty())
                };
                let stale_sum = self.s_sum + wt_sum;
                let total = r_sum + stale_sum;

                // MH chain starting from the current assignment
                let mut cur = old;
                let mut cur_target = Self::target(state, doc, word, cur);
                let mut cur_prop = {
                    let r_cur = if state.ntd[doc].get(cur) > 0 {
                        state.ntd[doc].get(cur) as f64
                            * (state.nwt[word].get(cur) as f64 + h.beta)
                            / (state.nt[cur as usize] as f64 + bb)
                    } else {
                        0.0
                    };
                    r_cur + self.stale_density(state, word, cur)
                };
                for _ in 0..MH_STEPS {
                    // draw a proposal from the mixture
                    let u = rng.uniform(total);
                    let cand = if u < r_sum && !self.r.is_empty() {
                        self.r.sample(u) as u16
                    } else {
                        let v = rng.uniform(stale_sum);
                        if v < self.s_sum || wt_empty {
                            self.s_table.sample(rng.uniform(self.s_table.total())) as u16
                        } else {
                            let wt = self.word_tables[word].as_ref().unwrap();
                            let k = wt.table.sample(rng.uniform(wt.table.total()));
                            wt.weights[k].0
                        }
                    };
                    if cand == cur {
                        continue;
                    }
                    let cand_target = Self::target(state, doc, word, cand);
                    let r_cand = state.ntd[doc].get(cand) as f64
                        * (state.nwt[word].get(cand) as f64 + h.beta)
                        / (state.nt[cand as usize] as f64 + bb);
                    let cand_prop = r_cand + self.stale_density(state, word, cand);
                    let accept = (cand_target * cur_prop) / (cur_target * cand_prop);
                    if accept >= 1.0 || rng.next_f64() < accept {
                        cur = cand;
                        cur_target = cand_target;
                        cur_prop = cand_prop;
                    }
                }

                add_token(state, doc, word, cur);
                state.z[base + pos] = cur;
            }
        }
    }

    fn name(&self) -> &'static str {
        "alias"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;
    use crate::lda::state::Hyper;

    #[test]
    fn sweep_is_consistent() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(61);
        let mut state = LdaState::init_random(&corpus, Hyper::paper_default(16), &mut rng);
        let mut s = AliasLda::new(&state);
        for _ in 0..3 {
            s.sweep(&mut state, &corpus, &mut rng);
        }
        state.check_consistency(&corpus).unwrap();
    }

    #[test]
    fn stale_density_matches_snapshot_tables() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(62);
        let mut state = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        let mut s = AliasLda::new(&state);
        let word = corpus.doc(0)[0] as usize;
        let _ = s.word_table(&state, word);
        // sum over all topics of the stale density == s_sum + word sum
        let total: f64 = (0..8).map(|t| s.stale_density(&state, word, t as u16)).sum();
        let wt_sum = s.word_tables[word].as_ref().unwrap().sum;
        assert!(
            (total - (s.s_sum + wt_sum)).abs() < 1e-9 * total,
            "stale mass mismatch: {total} vs {}",
            s.s_sum + wt_sum
        );
        let _ = &mut state;
    }

    #[test]
    fn word_tables_amortize() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(63);
        let state = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        let mut s = AliasLda::new(&state);
        let word = corpus.doc(0)[0] as usize;
        let draws0 = {
            let wt = s.word_table(&state, word);
            wt.draws_left
        };
        assert!(draws0 >= 16);
    }
}
