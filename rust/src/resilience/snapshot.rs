//! The on-disk side of the checkpoint service: a directory of FNLDA001
//! snapshots plus a MANIFEST that records `(epoch, file, fingerprint)`
//! for each retained one.
//!
//! Layout of a checkpoint directory:
//!
//! ```text
//! ckpts/
//!   MANIFEST            epoch <TAB> fnv1a-fingerprint <TAB> file, one per line
//!   ckpt-000000.fnlda   FNLDA001 snapshot of epoch 0 (the init baseline)
//!   ckpt-000003.fnlda   ...
//! ```
//!
//! Both the snapshot files and the MANIFEST are written atomically
//! (tmp + fsync + rename, see [`crate::util::fsio`]), and the recovery
//! read path re-fingerprints a file before trusting it — so a torn or
//! corrupted checkpoint is *skipped with a named warning*, never loaded.

use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Duration;

use crate::corpus::Corpus;
use crate::lda::checkpoint;
use crate::lda::LdaState;
use crate::util::fsio::{fnv1a_of_file, AtomicFile};

const MANIFEST: &str = "MANIFEST";

/// One retained checkpoint, as the MANIFEST records it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ManifestEntry {
    pub epoch: usize,
    /// snapshot file name, relative to the store directory
    pub file: String,
    /// FNV-1a fingerprint of the file bytes as committed
    pub fingerprint: u64,
}

/// Keep-last-K checkpoint store over one directory.
///
/// All mutation goes through [`save`](SnapshotStore::save), which the
/// background [`CheckpointWriter`](super::CheckpointWriter) thread calls;
/// the `Mutex` makes the occasional synchronous save (the epoch-0
/// baseline) safe against it.
pub struct SnapshotStore {
    dir: PathBuf,
    keep: usize,
    /// manifest entries, sorted by epoch ascending
    entries: Mutex<Vec<ManifestEntry>>,
    /// test hook: artificial latency added to every save, used to prove
    /// the epoch loop is decoupled from disk speed
    write_delay: Option<Duration>,
}

impl SnapshotStore {
    /// Open (or create) a checkpoint directory, reading back any existing
    /// MANIFEST.  Entries read back this way belong to whichever run wrote
    /// them — a *new* training run over the same directory must call
    /// [`begin_run`](SnapshotStore::begin_run) before its first save.
    pub fn open(dir: &Path, keep: usize) -> Result<SnapshotStore, String> {
        if keep == 0 {
            return Err("checkpoint retention (--keep) must be at least 1".into());
        }
        std::fs::create_dir_all(dir).map_err(|e| format!("create {}: {e}", dir.display()))?;
        let entries = read_manifest(&dir.join(MANIFEST))?;
        Ok(SnapshotStore {
            dir: dir.to_path_buf(),
            keep,
            entries: Mutex::new(entries),
            write_delay: None,
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Mark the start of a fresh training run: every entry inherited from
    /// a previous run's MANIFEST is discarded — snapshot files deleted,
    /// manifest rewritten empty.  Without this, reusing a checkpoint
    /// directory would (a) prune the new run's epoch-0 baseline against
    /// the old run's higher epochs and (b) let recovery reload a stale
    /// snapshot from a different run/seed and silently skip the epochs it
    /// believes already ran.  A no-op on an empty store.
    pub fn begin_run(&self) -> Result<(), String> {
        let mut entries = self.entries.lock().unwrap();
        if entries.is_empty() {
            return Ok(());
        }
        crate::log_event!(
            Info,
            "resilience",
            { count = entries.len() },
            "discarding {} checkpoint(s) left under {} by a previous run",
            entries.len(),
            self.dir.display()
        );
        for e in entries.drain(..) {
            let _ = std::fs::remove_file(self.dir.join(&e.file));
        }
        write_manifest(&self.dir.join(MANIFEST), &entries)
    }

    /// Test hook: make every save sleep first (see the non-blocking-offer
    /// test in `tests/resilience.rs`).
    #[doc(hidden)]
    pub fn set_write_delay(&mut self, delay: Duration) {
        self.write_delay = Some(delay);
    }

    /// Persist `state` as the epoch-`epoch` snapshot: atomic file write,
    /// manifest update, then retention pruning.  Re-saving an epoch
    /// overwrites it (recovery can legitimately re-reach the same epoch).
    pub fn save(&self, epoch: usize, state: &LdaState) -> Result<(), String> {
        let file = format!("ckpt-{epoch:06}.fnlda");
        // the lock covers the file write *and* the manifest update: two
        // concurrent saves of the same epoch must not be able to commit
        // one writer's file under the other writer's fingerprint
        let mut entries = self.entries.lock().unwrap();
        if let Some(d) = self.write_delay {
            std::thread::sleep(d);
        }
        let fingerprint = checkpoint::save_fingerprinted(state, &self.dir.join(&file))?;
        entries.retain(|e| e.epoch != epoch);
        entries.push(ManifestEntry { epoch, file, fingerprint });
        entries.sort_by_key(|e| e.epoch);
        while entries.len() > self.keep {
            let old = entries.remove(0);
            let _ = std::fs::remove_file(self.dir.join(&old.file));
        }
        write_manifest(&self.dir.join(MANIFEST), &entries)
    }

    /// Retained checkpoints, oldest → newest.
    pub fn entries(&self) -> Vec<ManifestEntry> {
        self.entries.lock().unwrap().clone()
    }

    /// The recovery read path: load the newest checkpoint at or below
    /// `max_epoch` that passes both the fingerprint re-check and the full
    /// FNLDA001 count-rebuild consistency load, skipping unusable entries
    /// with a named warning.  Errors only when *no* retained checkpoint is
    /// usable.
    ///
    /// `max_epoch` is the caller's notion of "now" (pass `usize::MAX` for
    /// no bound): a snapshot from beyond it cannot belong to the current
    /// run — loading one would make training skip the epochs in between —
    /// so such entries are rejected, not trusted.
    pub fn load_latest_valid(
        &self,
        corpus: &Corpus,
        max_epoch: usize,
    ) -> Result<(usize, LdaState), String> {
        for e in self.entries().iter().rev() {
            let path = self.dir.join(&e.file);
            if e.epoch > max_epoch {
                crate::log_event!(
                    Warn,
                    "resilience",
                    { epoch = e.epoch, max_epoch = max_epoch },
                    "checkpoint {} is from epoch {} > current epoch {max_epoch} \
                     (stale entry from another run?); skipping it",
                    path.display(),
                    e.epoch
                );
                continue;
            }
            match verify_and_load(&path, e.fingerprint, corpus) {
                Ok(state) => return Ok((e.epoch, state)),
                Err(why) => crate::log_event!(
                    Warn,
                    "resilience",
                    "checkpoint {} unusable ({why}); trying an older one",
                    path.display()
                ),
            }
        }
        Err(format!(
            "no valid checkpoint at or below epoch {max_epoch} under {}",
            self.dir.display()
        ))
    }

    /// Fault injection: truncate the newest retained snapshot file,
    /// simulating corruption that happened after the atomic rename (bad
    /// disk, cosmic ray, hostile test).
    #[doc(hidden)]
    pub fn corrupt_latest(&self) -> Result<(), String> {
        let entries = self.entries();
        let Some(e) = entries.last() else {
            return Err("no checkpoint to corrupt".into());
        };
        let path = self.dir.join(&e.file);
        let len = std::fs::metadata(&path).map_err(|e| e.to_string())?.len();
        let f = std::fs::OpenOptions::new().write(true).open(&path).map_err(|e| e.to_string())?;
        f.set_len(len / 2).map_err(|e| e.to_string())
    }
}

fn verify_and_load(path: &Path, want: u64, corpus: &Corpus) -> Result<LdaState, String> {
    let got = fnv1a_of_file(path)?;
    if got != want {
        return Err(format!(
            "fingerprint mismatch: manifest says {want:016x}, file is {got:016x} — torn write?"
        ));
    }
    checkpoint::load(path, corpus)
}

/// Parse the MANIFEST; a missing file is an empty store, and malformed
/// lines are skipped with a warning (the recovery path must not die on
/// what it is recovering *from*).
fn read_manifest(path: &Path) -> Result<Vec<ManifestEntry>, String> {
    if !path.exists() {
        return Ok(Vec::new());
    }
    let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let mut entries = Vec::new();
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        let mut cols = line.splitn(3, '\t');
        let parsed = (|| {
            let epoch = cols.next()?.parse::<usize>().ok()?;
            let fingerprint = u64::from_str_radix(cols.next()?, 16).ok()?;
            let file = cols.next()?.to_string();
            Some(ManifestEntry { epoch, file, fingerprint })
        })();
        match parsed {
            Some(e) => entries.push(e),
            None => crate::log_event!(
                Warn,
                "resilience",
                "warning: skipping malformed MANIFEST line: {line:?}"
            ),
        }
    }
    entries.sort_by_key(|e| e.epoch);
    Ok(entries)
}

fn write_manifest(path: &Path, entries: &[ManifestEntry]) -> Result<(), String> {
    use std::io::Write;
    let mut w = AtomicFile::create(path)?;
    for e in entries {
        writeln!(w, "{}\t{:016x}\t{}", e.epoch, e.fingerprint, e.file).map_err(|e| e.to_string())?;
    }
    w.commit().map(|_| ())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::preset;
    use crate::lda::Hyper;
    use crate::util::rng::Pcg32;

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("fnomad_snapshot_tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn save_load_roundtrip_and_manifest_survives_reopen() {
        let dir = tmpdir("roundtrip");
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(3);
        let state = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        let store = SnapshotStore::open(&dir, 3).unwrap();
        store.save(7, &state).unwrap();
        // a fresh handle reads the manifest back from disk
        let reopened = SnapshotStore::open(&dir, 3).unwrap();
        let (epoch, loaded) = reopened.load_latest_valid(&corpus, usize::MAX).unwrap();
        assert_eq!(epoch, 7);
        assert_eq!(loaded.z, state.z);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn begin_run_discards_entries_left_by_a_previous_run() {
        let dir = tmpdir("begin-run");
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(6);
        let state = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        let store = SnapshotStore::open(&dir, 2).unwrap();
        store.save(4, &state).unwrap();
        store.save(5, &state).unwrap();

        // a new run over the same directory starts from a clean slate
        let reopened = SnapshotStore::open(&dir, 2).unwrap();
        reopened.begin_run().unwrap();
        assert!(reopened.entries().is_empty(), "stale entries must be discarded");
        assert!(reopened.load_latest_valid(&corpus, usize::MAX).is_err());
        let leftovers = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".fnlda"))
            .count();
        assert_eq!(leftovers, 0, "stale snapshot files must be deleted");

        // the new run's epoch-0 baseline is now the whole retention chain
        // (it used to be pruned immediately against the old run's epochs)
        reopened.save(0, &state).unwrap();
        let epochs: Vec<usize> = reopened.entries().iter().map(|e| e.epoch).collect();
        assert_eq!(epochs, vec![0]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_latest_valid_rejects_epochs_beyond_the_bound() {
        let dir = tmpdir("epoch-bound");
        let corpus = preset("tiny").unwrap();
        let hyper = Hyper::paper_default(8);
        let s1 = LdaState::init_random(&corpus, hyper, &mut Pcg32::seeded(1));
        let s5 = LdaState::init_random(&corpus, hyper, &mut Pcg32::seeded(2));
        let store = SnapshotStore::open(&dir, 3).unwrap();
        store.save(1, &s1).unwrap();
        store.save(5, &s5).unwrap();
        // unbounded: the newest wins
        assert_eq!(store.load_latest_valid(&corpus, usize::MAX).unwrap().0, 5);
        // bounded below the newest: a too-new snapshot must not be trusted
        let (epoch, loaded) = store.load_latest_valid(&corpus, 3).unwrap();
        assert_eq!(epoch, 1);
        assert_eq!(loaded.z, s1.z);
        assert!(store.load_latest_valid(&corpus, 0).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn retention_prunes_files_and_manifest_together() {
        let dir = tmpdir("retention");
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(4);
        let state = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        let store = SnapshotStore::open(&dir, 2).unwrap();
        for epoch in 1..=5 {
            store.save(epoch, &state).unwrap();
        }
        let epochs: Vec<usize> = store.entries().iter().map(|e| e.epoch).collect();
        assert_eq!(epochs, vec![4, 5]);
        let snapshots = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".fnlda"))
            .count();
        assert_eq!(snapshots, 2, "pruned snapshot files must be deleted");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
