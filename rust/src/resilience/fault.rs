//! Deterministic fault injection, so recovery is a tested code path
//! instead of a hope.
//!
//! Two injection surfaces:
//!
//! * the [`Supervisor`](super::Supervisor) consults a [`FaultPlan`] right
//!   before running each epoch — in-process tests script "worker _w_
//!   panics at epoch _e_" or "slot _w_'s socket drops at epoch _e_"
//!   without touching timing;
//! * `serve-worker --fail-after-epochs N` wraps the remote worker's
//!   transport in [`FaultTransport`], which kills the whole process
//!   mid-epoch — from the coordinator's side this is indistinguishable
//!   from `kill -9`, which is the point.

use crate::nomad::token::{Msg, Reply};
use crate::nomad::transport::Transport;

/// Scripted faults for one training run.  Each is one-shot: the
/// supervisor clears a fault once it has fired, so the respawned ring is
/// healthy and the run can complete.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// poison ring slot `.0`'s inbox while running epoch `.1` (1-based),
    /// panicking that worker mid-epoch
    pub panic_worker: Option<(usize, usize)>,
    /// force-close ring slot `.0`'s connection while running epoch `.1`
    /// (meaningful for remote slots; a local slot is poisoned instead)
    pub drop_peer: Option<(usize, usize)>,
    /// truncate the newest retained checkpoint before the first recovery
    /// reload, forcing the fallback-to-an-older-snapshot path
    pub corrupt_latest_checkpoint: bool,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.panic_worker.is_none() && self.drop_peer.is_none() && !self.corrupt_latest_checkpoint
    }
}

/// Transport wrapper behind `serve-worker --fail-after-epochs N`: counts
/// epoch boundaries (each [`Msg::SetS`] broadcast ends one), and once `N`
/// have passed, the next word token kills the process.
///
/// It exits rather than panics: a panic would still unwind through
/// [`run_worker`](crate::nomad::transport::run_worker) and close sockets
/// in an orderly way, but a real `kill -9` does neither — `exit(9)` is
/// the honest simulation, leaving the coordinator to discover the loss
/// through its relay faults and health polling.
pub struct FaultTransport<T> {
    inner: T,
    epochs_left: u32,
}

impl<T> FaultTransport<T> {
    pub fn new(inner: T, fail_after_epochs: u32) -> FaultTransport<T> {
        FaultTransport { inner, epochs_left: fail_after_epochs }
    }
}

impl<T: Transport> Transport for FaultTransport<T> {
    fn recv(&mut self) -> Result<Msg, String> {
        let msg = self.inner.recv()?;
        match &msg {
            Msg::SetS(_) if self.epochs_left > 0 => self.epochs_left -= 1,
            Msg::Word(_) if self.epochs_left == 0 => {
                crate::log_event!(
                    Warn,
                    "serve-worker",
                    "injected fault: dying mid-epoch (--fail-after-epochs)"
                );
                std::process::exit(9);
            }
            _ => {}
        }
        Ok(msg)
    }

    fn send_next(&mut self, msg: Msg) -> Result<(), String> {
        self.inner.send_next(msg)
    }

    fn reply(&mut self, reply: Reply) -> Result<(), String> {
        self.inner.reply(reply)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_empty() {
        assert!(FaultPlan::default().is_empty());
        assert!(!FaultPlan { corrupt_latest_checkpoint: true, ..Default::default() }.is_empty());
        assert!(!FaultPlan { panic_worker: Some((0, 1)), ..Default::default() }.is_empty());
    }
}
