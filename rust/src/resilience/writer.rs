//! The async half of the checkpoint service: a background writer thread
//! fed over a bounded queue, so checkpointing never stalls the epoch
//! loop.
//!
//! The contract the supervisor and tests rely on:
//!
//! * [`SnapshotSink::offer`] **never blocks** — if the writer is still
//!   busy with an earlier snapshot, the new one is skipped (a fresher one
//!   comes at the next cadence point);
//! * [`SnapshotSink::flush`] blocks until every snapshot queued so far is
//!   durably on disk — the recovery path calls it before choosing which
//!   checkpoint to reload — and reports a writer thread that is no longer
//!   there to flush, so recovery knows queued snapshots were lost instead
//!   of silently picking a stale reload point;
//! * [`CheckpointWriter::finish`] drains the queue and joins the thread,
//!   so a clean training exit always persists its final snapshot.
//!
//! The transport is the hand-rolled [`OfferQueue`] rather than
//! `std::sync::mpsc`, for one reason: the offer/flush/finish contract
//! above is load-bearing for recovery correctness, and building it on the
//! [`crate::util::sync`] shim lets `rust/tests/loom_models.rs`
//! model-check it exhaustively (mpsc is opaque to loom).

use std::collections::VecDeque;
use std::thread::JoinHandle;

use crate::coordinator::{EvalPoint, TrainObserver};
use crate::lda::LdaState;
use crate::util::sync::{lock_checked, wait_timeout, Arc, Condvar, Mutex};

use super::snapshot::SnapshotStore;

/// A queued snapshot can sit behind one in-flight write without being
/// dropped; beyond that, freshness wins over completeness.
const QUEUE_DEPTH: usize = 2;

/// How long a flusher sleeps per wait round.  Purely defensive: progress
/// is signaled by notifies; the timeout only bounds the damage of a
/// (hypothetical) missed wakeup.
const FLUSH_POLL: std::time::Duration = std::time::Duration::from_millis(100);

struct OfferState<T> {
    /// `(seq, item)` — seq is 1-based acceptance order
    queue: VecDeque<(u64, T)>,
    /// number of offers accepted so far == seq of the latest accepted
    accepted: u64,
    /// seq of the last item the consumer finished processing
    processed: u64,
    closed: bool,
    consumer_alive: bool,
}

/// A bounded single-consumer queue with *drop-on-full* producers and a
/// *flush barrier*: the checkpoint service's transport, generic so the
/// loom suite can model it with a cheap payload.
///
/// Protocol:
///
/// * [`OfferQueue::offer`] never blocks: full, closed, or consumer-gone
///   means the item is dropped and `false` comes back;
/// * the consumer loops [`OfferQueue::pop`] → work →
///   [`OfferQueue::complete`], and calls [`OfferQueue::consumer_exited`]
///   on the way out (panic included — callers arm a guard);
/// * [`OfferQueue::flush`] blocks until everything accepted *before the
///   call* has been completed, and returns `false` the moment the
///   consumer is found dead instead — unprocessed offers will never
///   complete, and the caller must not assume they landed;
/// * [`OfferQueue::close`] lets the consumer drain what is queued, then
///   its next `pop` returns `None`.
pub struct OfferQueue<T> {
    state: Mutex<OfferState<T>>,
    /// wakes the consumer: something queued, or closed
    not_empty: Condvar,
    /// wakes flushers: progress, or consumer exit
    progressed: Condvar,
    cap: usize,
}

impl<T> OfferQueue<T> {
    pub fn new(cap: usize) -> OfferQueue<T> {
        assert!(cap >= 1, "queue depth must be >= 1");
        OfferQueue {
            state: Mutex::new(OfferState {
                queue: VecDeque::new(),
                accepted: 0,
                processed: 0,
                closed: false,
                consumer_alive: true,
            }),
            not_empty: Condvar::new(),
            progressed: Condvar::new(),
            cap,
        }
    }

    /// Try to enqueue; never blocks.  `false` means dropped (queue full,
    /// closed, consumer gone, or — defensively — lock poisoned).
    pub fn offer(&self, item: T) -> bool {
        let Ok(mut st) = lock_checked(&self.state) else { return false };
        if st.closed || !st.consumer_alive || st.queue.len() >= self.cap {
            return false;
        }
        st.accepted += 1;
        let seq = st.accepted;
        st.queue.push_back((seq, item));
        drop(st);
        self.not_empty.notify_one();
        true
    }

    /// Consumer side: block for the next item; `None` once the queue is
    /// closed and drained (or the lock is poisoned).
    pub fn pop(&self) -> Option<(u64, T)> {
        let mut st = lock_checked(&self.state).ok()?;
        loop {
            if let Some(front) = st.queue.pop_front() {
                return Some(front);
            }
            if st.closed {
                return None;
            }
            st = wait_timeout(&self.not_empty, st, FLUSH_POLL).ok()?;
        }
    }

    /// Consumer side: mark `seq` fully processed, waking flushers.
    pub fn complete(&self, seq: u64) {
        if let Ok(mut st) = lock_checked(&self.state) {
            st.processed = st.processed.max(seq);
        }
        self.progressed.notify_all();
    }

    /// Consumer side: the consumer is gone; pending flushes fail fast.
    pub fn consumer_exited(&self) {
        if let Ok(mut st) = lock_checked(&self.state) {
            st.consumer_alive = false;
        }
        self.progressed.notify_all();
    }

    /// Block until everything accepted before this call is processed.
    /// `false` the moment the consumer is found dead (its unprocessed
    /// backlog will never complete) or the lock is poisoned.
    #[must_use]
    pub fn flush(&self) -> bool {
        let Ok(mut st) = lock_checked(&self.state) else { return false };
        let target = st.accepted;
        loop {
            if !st.consumer_alive {
                return false;
            }
            if st.processed >= target {
                return true;
            }
            let Ok(guard) = wait_timeout(&self.progressed, st, FLUSH_POLL) else {
                return false;
            };
            st = guard;
        }
    }

    /// Stop accepting offers; the consumer drains the backlog, then its
    /// next [`OfferQueue::pop`] returns `None`.
    pub fn close(&self) {
        if let Ok(mut st) = lock_checked(&self.state) {
            st.closed = true;
        }
        self.not_empty.notify_all();
    }

    /// Current queue occupancy (items accepted but not yet popped).
    /// Advisory only — the answer can be stale by the time the caller
    /// acts on it; telemetry gauges are its only consumer.
    pub fn len(&self) -> usize {
        lock_checked(&self.state).map(|st| st.queue.len()).unwrap_or(0)
    }

    /// `len() == 0`, with the same advisory-only caveat.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

type SaveJob = (usize, Box<LdaState>);

/// Cloneable, non-blocking handle feeding the writer thread.
#[derive(Clone)]
pub struct SnapshotSink {
    queue: Arc<OfferQueue<SaveJob>>,
}

impl SnapshotSink {
    /// Queue a snapshot without blocking.  Returns whether it was
    /// accepted; `false` means the bounded queue was full (writer busy)
    /// or the writer is gone, and the snapshot was dropped.
    pub fn offer(&self, epoch: usize, state: LdaState) -> bool {
        let accepted = self.queue.offer((epoch, Box::new(state)));
        let reg = crate::obs::registry::global();
        reg.gauge("ckpt.queue_depth").set(self.queue.len() as u64);
        if !accepted {
            reg.counter("ckpt.skipped").inc();
        }
        accepted
    }

    /// Block until everything queued so far is on disk.  Returns `false`
    /// when the writer thread is gone (already stopped, or dead) — the
    /// queued snapshots it would have flushed are lost, and callers
    /// choosing a recovery reload point must not assume they landed.
    #[must_use]
    pub fn flush(&self) -> bool {
        self.queue.flush()
    }
}

/// Owner of the background writer thread.
pub struct CheckpointWriter {
    queue: Arc<OfferQueue<SaveJob>>,
    handle: Option<JoinHandle<()>>,
}

impl CheckpointWriter {
    /// Spawn the writer over `store`.
    pub fn spawn(store: Arc<SnapshotStore>, quiet: bool) -> CheckpointWriter {
        let queue = Arc::new(OfferQueue::new(QUEUE_DEPTH));
        let q = Arc::clone(&queue);
        let handle = std::thread::Builder::new()
            .name("ckpt-writer".into())
            .spawn(move || writer_loop(&store, &q, quiet))
            .expect("spawn checkpoint writer thread");
        CheckpointWriter { queue, handle: Some(handle) }
    }

    pub fn sink(&self) -> SnapshotSink {
        SnapshotSink { queue: Arc::clone(&self.queue) }
    }

    /// Drain the queue, stop the thread, and join it.
    pub fn finish(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        if let Some(handle) = self.handle.take() {
            self.queue.close();
            let _ = handle.join();
        }
    }
}

impl Drop for CheckpointWriter {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn writer_loop(store: &SnapshotStore, queue: &OfferQueue<SaveJob>, quiet: bool) {
    // exit marker armed against panics too: a dying writer must fail
    // pending flushes by name ("writer gone"), not strand them
    struct ExitGuard<'a>(&'a OfferQueue<SaveJob>);
    impl Drop for ExitGuard<'_> {
        fn drop(&mut self) {
            self.0.consumer_exited();
        }
    }
    let _exit = ExitGuard(queue);
    while let Some((seq, (epoch, state))) = queue.pop() {
        let t_save = crate::obs::trace::start();
        let saved = store.save(epoch, &state);
        crate::obs::trace::complete_tid(
            "checkpoint",
            &format!("checkpoint epoch {epoch}"),
            t_save,
            crate::obs::trace::TID_CHECKPOINT,
        );
        match saved {
            Ok(()) => {
                crate::obs::registry::global().counter("ckpt.saved").inc();
                if !quiet {
                    crate::log_event!(
                        Info,
                        "resilience",
                        { epoch = epoch },
                        "checkpointed epoch {epoch} under {}",
                        store.dir().display()
                    );
                }
            }
            // a failed background save must not kill training; the
            // cost is only an older recovery baseline
            Err(e) => {
                crate::log_event!(
                    Warn,
                    "resilience",
                    { epoch = epoch },
                    "warning: checkpoint of epoch {epoch} failed: {e}"
                );
            }
        }
        // processed even when the save failed: flush waits for the
        // backlog to be *handled*, not for every save to succeed
        queue.complete(seq);
    }
}

/// [`TrainObserver`] that feeds evaluation-point states to the writer.
///
/// With `save_every == 0` every eval point is snapshotted (recovery
/// granularity = eval cadence); otherwise a snapshot is queued every
/// `save_every` epochs, matching the single-file `Checkpointer` policy.
pub struct AsyncCheckpointer {
    sink: SnapshotSink,
    save_every: usize,
    last_queued: Option<usize>,
    quiet: bool,
}

impl AsyncCheckpointer {
    pub fn new(sink: SnapshotSink, save_every: usize, quiet: bool) -> AsyncCheckpointer {
        AsyncCheckpointer { sink, save_every, last_queued: None, quiet }
    }
}

impl TrainObserver for AsyncCheckpointer {
    fn on_eval(&mut self, point: &EvalPoint<'_>) -> Result<(), String> {
        let due = self.save_every == 0
            || point.epoch >= self.last_queued.unwrap_or(0) + self.save_every;
        if !due {
            return Ok(());
        }
        if self.sink.offer(point.epoch, point.state.clone()) {
            self.last_queued = Some(point.epoch);
        } else if !self.quiet {
            crate::log_event!(
                Info,
                "resilience",
                { epoch = point.epoch },
                "writer busy; skipped snapshot of epoch {}",
                point.epoch
            );
        }
        Ok(())
    }
}
