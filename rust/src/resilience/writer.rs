//! The async half of the checkpoint service: a background writer thread
//! fed over a bounded channel, so checkpointing never stalls the epoch
//! loop.
//!
//! The contract the supervisor and tests rely on:
//!
//! * [`SnapshotSink::offer`] **never blocks** — if the writer is still
//!   busy with an earlier snapshot, the new one is skipped (a fresher one
//!   comes at the next cadence point);
//! * [`SnapshotSink::flush`] blocks until every snapshot queued so far is
//!   durably on disk — the recovery path calls it before choosing which
//!   checkpoint to reload — and reports a writer thread that is no longer
//!   there to flush, so recovery knows queued snapshots were lost instead
//!   of silently picking a stale reload point;
//! * [`CheckpointWriter::finish`] drains the queue and joins the thread,
//!   so a clean training exit always persists its final snapshot.

use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::coordinator::{EvalPoint, TrainObserver};
use crate::lda::LdaState;

use super::snapshot::SnapshotStore;

/// A queued snapshot can sit behind one in-flight write without being
/// dropped; beyond that, freshness wins over completeness.
const QUEUE_DEPTH: usize = 2;

enum Job {
    Save { epoch: usize, state: Box<LdaState> },
    /// reply once every job queued before this one has been processed
    Flush(Sender<()>),
    Stop,
}

/// Cloneable, non-blocking handle feeding the writer thread.
#[derive(Clone)]
pub struct SnapshotSink {
    tx: SyncSender<Job>,
}

impl SnapshotSink {
    /// Queue a snapshot without blocking.  Returns whether it was
    /// accepted; `false` means the bounded queue was full (writer busy)
    /// and the snapshot was dropped.
    pub fn offer(&self, epoch: usize, state: LdaState) -> bool {
        !matches!(
            self.tx.try_send(Job::Save { epoch, state: Box::new(state) }),
            Err(TrySendError::Full(_) | TrySendError::Disconnected(_))
        )
    }

    /// Block until everything queued so far is on disk.  Returns `false`
    /// when the writer thread is gone (already stopped, or dead) — the
    /// queued snapshots it would have flushed are lost, and callers
    /// choosing a recovery reload point must not assume they landed.
    #[must_use]
    pub fn flush(&self) -> bool {
        let (done_tx, done_rx) = std::sync::mpsc::channel();
        self.tx.send(Job::Flush(done_tx)).is_ok() && done_rx.recv().is_ok()
    }
}

/// Owner of the background writer thread.
pub struct CheckpointWriter {
    sink: SnapshotSink,
    handle: Option<JoinHandle<()>>,
}

impl CheckpointWriter {
    /// Spawn the writer over `store`.
    pub fn spawn(store: Arc<SnapshotStore>, quiet: bool) -> CheckpointWriter {
        let (tx, rx) = sync_channel::<Job>(QUEUE_DEPTH);
        let handle = std::thread::Builder::new()
            .name("ckpt-writer".into())
            .spawn(move || writer_loop(&store, &rx, quiet))
            .expect("spawn checkpoint writer thread");
        CheckpointWriter { sink: SnapshotSink { tx }, handle: Some(handle) }
    }

    pub fn sink(&self) -> SnapshotSink {
        self.sink.clone()
    }

    /// Drain the queue, stop the thread, and join it.
    pub fn finish(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        if let Some(handle) = self.handle.take() {
            let _ = self.sink.tx.send(Job::Stop);
            let _ = handle.join();
        }
    }
}

impl Drop for CheckpointWriter {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn writer_loop(store: &SnapshotStore, rx: &Receiver<Job>, quiet: bool) {
    while let Ok(job) = rx.recv() {
        match job {
            Job::Save { epoch, state } => match store.save(epoch, &state) {
                Ok(()) => {
                    if !quiet {
                        eprintln!(
                            "[resilience] checkpointed epoch {epoch} under {}",
                            store.dir().display()
                        );
                    }
                }
                // a failed background save must not kill training; the
                // cost is only an older recovery baseline
                Err(e) => {
                    eprintln!("[resilience] warning: checkpoint of epoch {epoch} failed: {e}");
                }
            },
            Job::Flush(done) => {
                let _ = done.send(());
            }
            Job::Stop => return,
        }
    }
}

/// [`TrainObserver`] that feeds evaluation-point states to the writer.
///
/// With `save_every == 0` every eval point is snapshotted (recovery
/// granularity = eval cadence); otherwise a snapshot is queued every
/// `save_every` epochs, matching the single-file `Checkpointer` policy.
pub struct AsyncCheckpointer {
    sink: SnapshotSink,
    save_every: usize,
    last_queued: Option<usize>,
    quiet: bool,
}

impl AsyncCheckpointer {
    pub fn new(sink: SnapshotSink, save_every: usize, quiet: bool) -> AsyncCheckpointer {
        AsyncCheckpointer { sink, save_every, last_queued: None, quiet }
    }
}

impl TrainObserver for AsyncCheckpointer {
    fn on_eval(&mut self, point: &EvalPoint<'_>) -> Result<(), String> {
        let due = self.save_every == 0
            || point.epoch >= self.last_queued.unwrap_or(0) + self.save_every;
        if !due {
            return Ok(());
        }
        if self.sink.offer(point.epoch, point.state.clone()) {
            self.last_queued = Some(point.epoch);
        } else if !self.quiet {
            eprintln!("[resilience] writer busy; skipped snapshot of epoch {}", point.epoch);
        }
        Ok(())
    }
}
