//! Supervised ring recovery: the [`Supervisor`] engine that keeps a
//! distributed training run alive through worker loss.
//!
//! The supervisor sits between the coordinator's driver loop and the
//! Nomad ring, presenting the infallible [`TrainEngine`] surface while
//! driving the ring's fallible `try_run_epoch` / `try_gather_state`
//! twins underneath.  When one of them reports a ring failure it:
//!
//! 1. tears down whatever is left of the broken ring;
//! 2. flushes the async checkpoint writer so queued snapshots land;
//! 3. reloads the latest *valid* snapshot from the [`SnapshotStore`]
//!    (fingerprint-verified — a torn checkpoint is skipped, not loaded);
//! 4. probes the configured remote workers and keeps only the reachable
//!    survivors — `try_from_state` then recomputes the token-balanced
//!    [`Partition`](crate::corpus::Partition) over the remaining slots
//!    and re-ships each its corpus slice via the `Init` machinery;
//! 5. re-runs the lost epochs up to where the driver believes it is.
//!
//! Restarts are bounded (`max_restarts`) with exponential backoff; once
//! the budget is spent the supervisor gives up with the original named
//! ring error.  Construction first discards whatever a previous run left
//! in the checkpoint store and then persists the init state synchronously
//! as the epoch-0 baseline, so recovery always has *something* valid to
//! reload — and only ever from *this* run (reloads are additionally
//! bounded by the epoch the driver has consumed).

use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::{Clock, EpochReport, TrainConfig, TrainEngine};
use crate::corpus::Corpus;
use crate::lda::LdaState;
use crate::nomad::token::Msg;
use crate::nomad::{NomadConfig, NomadRuntime};

use super::fault::FaultPlan;
use super::snapshot::SnapshotStore;
use super::writer::SnapshotSink;

/// First-restart backoff; doubles per consecutive restart.
const BACKOFF_BASE: Duration = Duration::from_millis(50);

/// Backoff ceiling — recovery should retry within human patience.
const BACKOFF_CAP: Duration = Duration::from_secs(2);

/// Connect timeout when probing which remote workers survived.
const PROBE_TIMEOUT: Duration = Duration::from_millis(500);

/// A [`TrainEngine`] wrapping the Nomad ring with checkpoint-based
/// restart.  Built by the driver when `--checkpoint-dir` is set on a
/// nomad run; `--max-restarts` bounds how many ring failures it absorbs.
pub struct Supervisor<'c> {
    corpus: &'c Corpus,
    workers: usize,
    remote: Vec<String>,
    seed: u64,
    max_restarts: usize,
    store: Arc<SnapshotStore>,
    sink: SnapshotSink,
    fault: FaultPlan,
    /// the ring; `None` only transiently inside recovery
    inner: Option<NomadRuntime>,
    /// absolute epochs whose results the driver has consumed
    done: usize,
    /// absolute epoch of the inner ring's current state (trails `done`
    /// while re-running lost epochs after a restart)
    inner_epoch: usize,
    restarts: usize,
}

impl<'c> Supervisor<'c> {
    /// Spawn the supervised ring.  Any checkpoints a previous run left in
    /// the store are discarded first ([`SnapshotStore::begin_run`]) —
    /// recovery must never reload another run's state — and then the init
    /// state is persisted synchronously as the epoch-0 baseline: the ring
    /// may die before the async writer lands any snapshot, and recovery
    /// must never find an empty store.
    pub fn new(
        corpus: &'c Corpus,
        init: &LdaState,
        cfg: &TrainConfig,
        store: Arc<SnapshotStore>,
        sink: SnapshotSink,
    ) -> Result<Supervisor<'c>, String> {
        store.begin_run()?;
        store.save(0, init)?;
        let rt_cfg = NomadConfig {
            workers: cfg.workers,
            seed: cfg.seed,
            remote: cfg.remote.clone(),
        };
        let inner = NomadRuntime::try_from_state(corpus, init, rt_cfg)?;
        Ok(Supervisor {
            corpus,
            workers: cfg.workers,
            remote: cfg.remote.clone(),
            seed: cfg.seed,
            max_restarts: cfg.max_restarts,
            store,
            sink,
            fault: cfg.fault.clone(),
            inner: Some(inner),
            done: 0,
            inner_epoch: 0,
            restarts: 0,
        })
    }

    /// Restarts performed so far (telemetry / tests).
    pub fn restarts(&self) -> usize {
        self.restarts
    }

    /// Fire any scripted fault due at the epoch about to run, consuming
    /// it so the respawned ring is healthy.
    fn inject_faults(&mut self, epoch: usize) {
        let ring = self.inner.as_ref().expect("ring present");
        if let Some((slot, at)) = self.fault.panic_worker {
            if at == epoch {
                self.fault.panic_worker = None;
                // arity-mismatched SetS: the worker's copy_from_slice panics
                ring.inject_raw(slot, Msg::SetS(Vec::new()));
            }
        }
        if let Some((slot, at)) = self.fault.drop_peer {
            if at == epoch {
                self.fault.drop_peer = None;
                ring.kill_slot(slot);
            }
        }
    }

    /// Run epochs until the inner ring has reached absolute epoch
    /// `target`, recovering across failures; the report accumulates
    /// every epoch actually executed (including re-runs) so throughput
    /// numbers stay honest.
    fn advance_to(&mut self, target: usize) -> Result<EpochReport, String> {
        let mut acc = EpochReport::default();
        while self.inner_epoch < target {
            self.inject_faults(self.inner_epoch + 1);
            match self.inner.as_mut().expect("ring present").try_run_epoch() {
                Ok(report) => {
                    self.inner_epoch += 1;
                    acc.processed += report.processed;
                    acc.secs += report.secs;
                    acc.msgs += report.msgs;
                    acc.stale_reads += report.stale_reads;
                    // the breakdown of the last epoch actually executed —
                    // re-runs overwrite, which is the state the driver sees
                    acc.ring = report.ring;
                }
                Err(why) => self.recover(&why)?,
            }
        }
        Ok(acc)
    }

    /// The restart loop: teardown, flush, reload, re-spawn.  `Err` only
    /// when the restart budget is exhausted (carrying the original ring
    /// failure) or no usable checkpoint / worker remains.
    fn recover(&mut self, why: &str) -> Result<(), String> {
        // the recovery timeline is traced end to end: failure handling,
        // then (inside respawn) the checkpoint reload and ring respawn
        let t_fail = crate::obs::trace::start();
        if let Some(mut broken) = self.inner.take() {
            broken.shutdown();
        }
        // land queued snapshots before choosing a reload point; a dead
        // writer cannot flush, so say what recovery is about to lose
        if !self.sink.flush() {
            crate::log_event!(
                Warn,
                "resilience",
                "checkpoint writer thread is gone; snapshots queued since it exited \
                 were lost — recovering from what reached disk"
            );
        }
        if self.fault.corrupt_latest_checkpoint {
            self.fault.corrupt_latest_checkpoint = false;
            let _ = self.store.corrupt_latest();
        }
        crate::obs::trace::complete("recovery", "ring failure", t_fail);
        crate::obs::registry::global().counter("train.ring_failures").inc();
        loop {
            if self.restarts >= self.max_restarts {
                return Err(format!(
                    "giving up after {}/{} restarts: {why}",
                    self.restarts, self.max_restarts
                ));
            }
            self.restarts += 1;
            let backoff = backoff_for(self.restarts);
            // recovery narration is Warn — visible regardless of --quiet
            // (which only silences the Info-level progress chatter): a run
            // that silently lost and re-ran epochs would be a debugging trap
            crate::log_event!(
                Warn,
                "resilience",
                { restart = self.restarts, max = self.max_restarts },
                "ring failure: {why}; restart {}/{} after {backoff:?}",
                self.restarts,
                self.max_restarts
            );
            std::thread::sleep(backoff);
            match self.respawn() {
                Ok(epoch) => {
                    let slots = self.inner.as_ref().expect("ring rebuilt").ring_size();
                    crate::obs::registry::global().counter("train.restarts").inc();
                    crate::log_event!(
                        Warn,
                        "resilience",
                        { epoch = epoch, slots = slots },
                        "recovered: restarted from epoch {epoch} ({slots} ring slots)"
                    );
                    self.inner_epoch = epoch;
                    return Ok(());
                }
                Err(e) => {
                    crate::log_event!(Warn, "resilience", "restart failed: {e}");
                }
            }
        }
    }

    /// One respawn attempt: latest valid checkpoint × surviving workers.
    /// The reload is bounded by `done`: every checkpoint this run wrote
    /// came from a consumed eval point, so anything newer is a stale
    /// entry from another run and must not be resumed from.
    fn respawn(&mut self) -> Result<usize, String> {
        let t_reload = crate::obs::trace::start();
        let (epoch, state) = self.store.load_latest_valid(self.corpus, self.done)?;
        crate::obs::trace::complete("recovery", "reload checkpoint", t_reload);
        let t_respawn = crate::obs::trace::start();
        let surviving: Vec<String> =
            self.remote.iter().filter(|addr| probe(addr)).cloned().collect();
        for lost in self.remote.iter().filter(|a| !surviving.contains(a)) {
            crate::log_event!(Warn, "resilience", "dropping unreachable worker {lost}");
        }
        if self.workers == 0 && surviving.is_empty() {
            return Err("no local threads and no reachable remote workers".into());
        }
        let rt_cfg = NomadConfig {
            workers: self.workers,
            seed: self.seed,
            remote: surviving.clone(),
        };
        // try_from_state repartitions the CSR doc ranges over the new slot
        // count and ships each remote its rebased corpus slice
        self.inner = Some(NomadRuntime::try_from_state(self.corpus, &state, rt_cfg)?);
        crate::obs::trace::complete("recovery", "respawn ring", t_respawn);
        self.remote = surviving;
        Ok(epoch)
    }
}

impl TrainEngine for Supervisor<'_> {
    fn run_epoch(&mut self) -> EpochReport {
        let target = self.done + 1;
        let report = self
            .advance_to(target)
            .unwrap_or_else(|e| panic!("nomad ring failure: {e}"));
        self.done = target;
        report
    }

    fn state_snapshot(&mut self, corpus: &Corpus) -> LdaState {
        loop {
            match self.inner.as_mut().expect("ring present").try_gather_state(corpus) {
                Ok(state) => return state,
                Err(why) => {
                    let caught_up = self
                        .recover(&why)
                        .and_then(|()| self.advance_to(self.done).map(|_| ()));
                    if let Err(e) = caught_up {
                        panic!("nomad ring failure: {e}");
                    }
                }
            }
        }
    }

    fn clock(&self) -> Clock {
        Clock::Wall
    }

    fn shutdown(&mut self) {
        if let Some(ring) = self.inner.as_mut() {
            ring.shutdown();
        }
    }
}

fn backoff_for(attempt: usize) -> Duration {
    let factor = 1u32 << (attempt.saturating_sub(1)).min(16) as u32;
    (BACKOFF_BASE * factor).min(BACKOFF_CAP)
}

/// Is a live `serve-worker` still at `addr`?  The probe connects, sends a
/// [`Ping`](crate::nomad::wire::Frame::Ping) frame, and requires a
/// [`Pong`](crate::nomad::wire::Frame::Pong) back within the deadline —
/// the worker answers it *before* the `Init` handshake, so a probe never
/// spawns a session thread on the worker host (and a random process
/// squatting on the port does not pass for one).
fn probe(addr: &str) -> bool {
    use std::io::{BufReader, BufWriter};
    use std::net::ToSocketAddrs;

    use crate::nomad::net::{read_frame, write_frame};
    use crate::nomad::wire::Frame;

    let Ok(mut resolved) = addr.to_socket_addrs() else {
        return false;
    };
    let Some(sock) = resolved.next() else {
        return false;
    };
    let Ok(stream) = std::net::TcpStream::connect_timeout(&sock, PROBE_TIMEOUT) else {
        return false;
    };
    if stream.set_read_timeout(Some(PROBE_TIMEOUT)).is_err()
        || stream.set_write_timeout(Some(PROBE_TIMEOUT)).is_err()
    {
        return false;
    }
    let Ok(clone) = stream.try_clone() else {
        return false;
    };
    let mut reader = BufReader::new(clone);
    let mut writer = BufWriter::new(stream);
    write_frame(&mut writer, &Frame::Ping).is_ok()
        && matches!(read_frame(&mut reader), Ok(Frame::Pong))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_doubles_and_caps() {
        assert_eq!(backoff_for(1), Duration::from_millis(50));
        assert_eq!(backoff_for(2), Duration::from_millis(100));
        assert_eq!(backoff_for(3), Duration::from_millis(200));
        assert_eq!(backoff_for(100), BACKOFF_CAP);
    }

    #[test]
    fn probe_requires_a_pong_answering_worker() {
        use std::net::ToSocketAddrs;

        use crate::nomad::net::{read_frame, write_frame};
        use crate::nomad::wire::Frame;

        // NXDOMAIN-hijacking resolvers can resolve anything, so only
        // assert the bogus-hostname case when resolution actually fails
        // (.invalid is reserved by RFC 2606 and should never resolve)
        let bogus = "definitely-not-a-host.invalid:1";
        if bogus.to_socket_addrs().is_err() {
            assert!(!probe(bogus));
        }

        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let responder = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
            let mut writer = std::io::BufWriter::new(stream);
            match read_frame(&mut reader) {
                Ok(Frame::Ping) => write_frame(&mut writer, &Frame::Pong).unwrap(),
                other => panic!("probe must open with Ping, sent {other:?}"),
            }
        });
        assert!(probe(&addr), "a Pong-answering worker must probe alive");
        responder.join().unwrap();
        // listener gone: connection refused — and even if another process
        // re-bound the ephemeral port meanwhile, it would not speak Pong
        assert!(!probe(&addr));
    }
}
