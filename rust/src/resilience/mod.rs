//! Resilient training: the layer between the coordinator's epoch loop and
//! the Nomad ring that makes worker loss survivable.
//!
//! The paper's framework assumes workers live for the whole run; at the
//! "millions of documents, billions of tokens" scale it targets, worker
//! loss is the norm.  Since PR 4 a dropped TCP peer or panicked worker
//! thread surfaces as a *named error* — this subsystem is what turns that
//! error back into a running job.  Two halves:
//!
//! * **Async checkpoint service** — [`SnapshotStore`] owns an on-disk
//!   checkpoint directory (FNLDA001 files + a fingerprinting MANIFEST,
//!   keep-last-K retention); [`CheckpointWriter`] drains [`LdaState`]
//!   snapshots from a bounded offer queue (hand-rolled on the
//!   [`crate::util::sync`] shim; its offer/flush/finish contract is
//!   model-checked in `rust/tests/loom_models.rs`) on a background thread
//!   so the epoch loop never blocks on disk; [`AsyncCheckpointer`] is the
//!   [`TrainObserver`] that feeds it at the eval cadence.
//! * **Supervised recovery** — [`Supervisor`] wraps the ring's fallible
//!   `try_run_epoch`/`try_gather_state` twins behind the [`TrainEngine`]
//!   surface: on a ring failure it tears the ring down, reloads the
//!   latest *valid* checkpoint, re-spawns the ring over the surviving
//!   transports (repartitioning doc ranges over the remaining slots), and
//!   resumes — bounded retries with exponential backoff before giving up
//!   with the original named error.
//!
//! [`FaultPlan`] and [`FaultTransport`] make all of this deterministically
//! testable: scripted worker panics, dropped TCP peers, corrupted
//! checkpoints, and a real `serve-worker --fail-after-epochs N` process
//! death.
//!
//! [`LdaState`]: crate::lda::LdaState
//! [`TrainObserver`]: crate::coordinator::TrainObserver
//! [`TrainEngine`]: crate::coordinator::TrainEngine

pub mod fault;
pub mod snapshot;
pub mod supervisor;
pub mod writer;

pub use fault::{FaultPlan, FaultTransport};
pub use snapshot::{ManifestEntry, SnapshotStore};
pub use supervisor::Supervisor;
pub use writer::{AsyncCheckpointer, CheckpointWriter, SnapshotSink};
