//! The training coordinator: wires corpora, samplers, runtimes and the
//! PJRT evaluator into runnable experiments, and records the convergence
//! series every figure is built from.

use std::path::PathBuf;

use crate::adlda::{AdLda, AdLdaConfig};
use crate::corpus::{preset, Corpus};
use crate::lda::{self, Hyper, LdaState};
use crate::nomad::{NomadConfig, NomadRuntime};
use crate::ps::{PsConfig, PsRuntime};
use crate::runtime::{artifacts_available, default_artifact_dir, LlEvaluator};
use crate::simnet::nomad_sim::{NomadSim, NomadSimConfig};
use crate::simnet::ps_sim::{PsSim, PsSimConfig};
use crate::simnet::{ClusterSpec, CostModel};
use crate::util::metrics::{write_csv, Series, Stopwatch};
use crate::util::rng::Pcg32;

/// Training/experiment options (CLI surface).
#[derive(Clone, Debug)]
pub struct TrainOpts {
    pub preset: String,
    pub topics: usize,
    /// serial sampler variant (runtime == "serial")
    pub sampler: String,
    /// serial | nomad | nomad-sim | ps | ps-sim | adlda
    pub runtime: String,
    pub workers: usize,
    /// simulated machines (sim runtimes; workers = machines × 20 when > 1)
    pub machines: usize,
    pub iters: usize,
    pub seed: u64,
    /// auto | xla | rust
    pub eval: String,
    pub eval_every: usize,
    /// PS pull/push cadence (docs)
    pub batch_docs: usize,
    /// PS disk flavor (sim only)
    pub disk: bool,
    pub out: Option<PathBuf>,
    pub quiet: bool,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts {
            preset: "tiny".into(),
            topics: 128,
            sampler: "flda-word".into(),
            runtime: "serial".into(),
            workers: 2,
            machines: 1,
            iters: 10,
            seed: 0,
            eval: "auto".into(),
            eval_every: 1,
            batch_docs: 16,
            disk: false,
            out: None,
            quiet: false,
        }
    }
}

/// Model-quality evaluator: PJRT artifact path or the Rust reference.
pub enum Evaluator {
    Xla(Box<LlEvaluator>),
    Rust,
}

impl Evaluator {
    /// Resolve by policy: `auto` prefers the blocked path when artifacts
    /// exist *and* cover the topic count, and otherwise falls back to the
    /// sparse Rust reference — which is exact and faster than the dense
    /// blocked evaluator, so hermetic default builds (no `artifacts/`)
    /// deliberately train with `Rust`.  The blocked backend (PJRT with
    /// `--features pjrt`, pure Rust otherwise) stays reachable via the
    /// explicit `xla` policy and `fnomad-lda check-artifacts`.
    pub fn resolve(policy: &str, topics: usize) -> Result<Evaluator, String> {
        let dir = default_artifact_dir();
        match policy {
            "rust" => Ok(Evaluator::Rust),
            "xla" => Ok(Evaluator::Xla(Box::new(LlEvaluator::new(&dir, topics)?))),
            "auto" => {
                if artifacts_available(&dir) {
                    match LlEvaluator::new(&dir, topics) {
                        Ok(e) => Ok(Evaluator::Xla(Box::new(e))),
                        Err(_) => Ok(Evaluator::Rust),
                    }
                } else {
                    Ok(Evaluator::Rust)
                }
            }
            other => Err(format!("unknown eval policy '{other}' (auto|xla|rust)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            // "xla" under --features pjrt, "blocked-rust" in default builds
            Evaluator::Xla(_) => LlEvaluator::BACKEND,
            Evaluator::Rust => "rust",
        }
    }

    pub fn log_likelihood(&mut self, state: &LdaState) -> Result<f64, String> {
        match self {
            Evaluator::Xla(e) => e.log_likelihood(state),
            Evaluator::Rust => Ok(lda::log_likelihood(state)),
        }
    }
}

/// Result of one training run: the two series every figure needs.
pub struct TrainResult {
    /// (iteration, LL)
    pub ll_vs_iter: Series,
    /// (seconds — wall or virtual, LL)
    pub ll_vs_time: Series,
    /// tokens/sec aggregate (real or virtual)
    pub tokens_per_sec: f64,
    pub final_state: LdaState,
}

/// Run one experiment per `opts`.
pub fn train(opts: &TrainOpts) -> Result<TrainResult, String> {
    let corpus = preset(&opts.preset)?;
    let hyper = Hyper::paper_default(opts.topics);
    let mut eval = Evaluator::resolve(&opts.eval, opts.topics)?;
    let label = run_label(opts);
    if !opts.quiet {
        eprintln!(
            "[train] {} docs={} vocab={} tokens={} T={} eval={}",
            label,
            corpus.num_docs(),
            corpus.vocab,
            corpus.num_tokens(),
            opts.topics,
            eval.name()
        );
    }
    match opts.runtime.as_str() {
        "serial" => train_serial(opts, &corpus, hyper, &mut eval, &label),
        "nomad" => train_nomad(opts, &corpus, hyper, &mut eval, &label),
        "ps" => train_ps(opts, &corpus, hyper, &mut eval, &label),
        "adlda" => train_adlda(opts, &corpus, hyper, &mut eval, &label),
        "nomad-sim" => train_nomad_sim(opts, &corpus, hyper, &mut eval, &label),
        "ps-sim" => train_ps_sim(opts, &corpus, hyper, &mut eval, &label),
        other => Err(format!(
            "unknown runtime '{other}' (serial|nomad|ps|adlda|nomad-sim|ps-sim)"
        )),
    }
}

pub fn run_label(opts: &TrainOpts) -> String {
    match opts.runtime.as_str() {
        "serial" => format!("{}-{}", opts.sampler, opts.preset),
        "nomad-sim" | "ps-sim" if opts.machines > 1 => format!(
            "{}-{}x20-{}{}",
            opts.runtime,
            opts.machines,
            opts.preset,
            if opts.disk { "-disk" } else { "" }
        ),
        rt => format!(
            "{rt}-p{}-{}{}",
            opts.workers,
            opts.preset,
            if opts.disk { "-disk" } else { "" }
        ),
    }
}

fn sim_cluster(opts: &TrainOpts) -> ClusterSpec {
    if opts.machines > 1 {
        ClusterSpec { machines: opts.machines, ..ClusterSpec::cluster(opts.machines) }
    } else {
        ClusterSpec::multicore(opts.workers)
    }
}

macro_rules! eval_point {
    ($eval:expr, $state:expr, $iters:expr, $x_time:expr, $res:expr, $opts:expr, $label:expr) => {{
        let ll = $eval.log_likelihood(&$state)?;
        $res.ll_vs_iter.push($iters as f64, ll);
        $res.ll_vs_time.push($x_time, ll);
        if !$opts.quiet {
            eprintln!("[{}] iter {:4}  t={:9.3}s  LL={ll:.4e}", $label, $iters, $x_time);
        }
    }};
}

fn new_result(label: &str) -> TrainResult {
    TrainResult {
        ll_vs_iter: Series::new(format!("{label}:ll_vs_iter")),
        ll_vs_time: Series::new(format!("{label}:ll_vs_time")),
        tokens_per_sec: 0.0,
        final_state: LdaState {
            hyper: Hyper::paper_default(2),
            vocab: 0,
            z: vec![],
            ntd: vec![],
            nwt: vec![],
            nt: vec![],
        },
    }
}

fn train_serial(
    opts: &TrainOpts,
    corpus: &Corpus,
    hyper: Hyper,
    eval: &mut Evaluator,
    label: &str,
) -> Result<TrainResult, String> {
    let mut rng = Pcg32::seeded(opts.seed);
    let mut state = LdaState::init_random(corpus, hyper, &mut rng);
    let mut sampler = lda::by_name(&opts.sampler, &state, corpus)?;
    let mut res = new_result(label);
    let mut sample_secs = 0.0;
    eval_point!(eval, state, 0, 0.0, res, opts, label);
    for it in 1..=opts.iters {
        let t0 = Stopwatch::new();
        sampler.sweep(&mut state, corpus, &mut rng);
        sample_secs += t0.secs();
        if it % opts.eval_every == 0 || it == opts.iters {
            eval_point!(eval, state, it, sample_secs, res, opts, label);
        }
    }
    res.tokens_per_sec = (opts.iters * corpus.num_tokens()) as f64 / sample_secs;
    res.final_state = state;
    finish(opts, res)
}

fn train_nomad(
    opts: &TrainOpts,
    corpus: &Corpus,
    hyper: Hyper,
    eval: &mut Evaluator,
    label: &str,
) -> Result<TrainResult, String> {
    let mut rt = NomadRuntime::new(corpus, hyper, NomadConfig {
        workers: opts.workers,
        seed: opts.seed,
    });
    let mut res = new_result(label);
    let mut sample_secs = 0.0;
    let mut processed = 0u64;
    let state0 = rt.gather_state(corpus);
    eval_point!(eval, state0, 0, 0.0, res, opts, label);
    for it in 1..=opts.iters {
        let stats = rt.run_epoch();
        sample_secs += stats.wall_secs;
        processed += stats.processed;
        if it % opts.eval_every == 0 || it == opts.iters {
            let state = rt.gather_state(corpus);
            eval_point!(eval, state, it, sample_secs, res, opts, label);
        }
    }
    res.tokens_per_sec = processed as f64 / sample_secs;
    res.final_state = rt.gather_state(corpus);
    rt.shutdown();
    finish(opts, res)
}

fn train_ps(
    opts: &TrainOpts,
    corpus: &Corpus,
    hyper: Hyper,
    eval: &mut Evaluator,
    label: &str,
) -> Result<TrainResult, String> {
    let mut rt = PsRuntime::new(corpus, hyper, PsConfig {
        workers: opts.workers,
        seed: opts.seed,
        batch_docs: opts.batch_docs,
    });
    let mut res = new_result(label);
    let mut sample_secs = 0.0;
    let mut processed = 0u64;
    let state0 = rt.gather_state(corpus);
    eval_point!(eval, state0, 0, 0.0, res, opts, label);
    for it in 1..=opts.iters {
        let stats = rt.run_epoch();
        sample_secs += stats.wall_secs;
        processed += stats.processed;
        if it % opts.eval_every == 0 || it == opts.iters {
            let state = rt.gather_state(corpus);
            eval_point!(eval, state, it, sample_secs, res, opts, label);
        }
    }
    res.tokens_per_sec = processed as f64 / sample_secs;
    res.final_state = rt.gather_state(corpus);
    rt.shutdown();
    finish(opts, res)
}

fn train_adlda(
    opts: &TrainOpts,
    corpus: &Corpus,
    hyper: Hyper,
    eval: &mut Evaluator,
    label: &str,
) -> Result<TrainResult, String> {
    let mut trainer = AdLda::new(corpus, hyper, AdLdaConfig {
        workers: opts.workers,
        seed: opts.seed,
    });
    let mut res = new_result(label);
    let mut sample_secs = 0.0;
    eval_point!(eval, trainer.state, 0, 0.0, res, opts, label);
    for it in 1..=opts.iters {
        let t0 = Stopwatch::new();
        trainer.iterate(corpus);
        sample_secs += t0.secs();
        if it % opts.eval_every == 0 || it == opts.iters {
            eval_point!(eval, trainer.state, it, sample_secs, res, opts, label);
        }
    }
    res.tokens_per_sec = (opts.iters * corpus.num_tokens()) as f64 / sample_secs;
    res.final_state = trainer.state;
    finish(opts, res)
}

fn train_nomad_sim(
    opts: &TrainOpts,
    corpus: &Corpus,
    hyper: Hyper,
    eval: &mut Evaluator,
    label: &str,
) -> Result<TrainResult, String> {
    let cluster = sim_cluster(opts);
    let mut cfg = NomadSimConfig::new(cluster, opts.topics);
    cfg.seed = opts.seed;
    cfg.cost = CostModel::default_for(opts.topics);
    let mut sim = NomadSim::new(corpus, hyper, cfg);
    let mut res = new_result(label);
    let mut processed = 0u64;
    let state0 = sim.gather_state(corpus);
    eval_point!(eval, state0, 0, 0.0, res, opts, label);
    for it in 1..=opts.iters {
        let stats = sim.run_epoch();
        processed += stats.processed;
        if it % opts.eval_every == 0 || it == opts.iters {
            let state = sim.gather_state(corpus);
            eval_point!(eval, state, it, sim.vtime_secs(), res, opts, label);
        }
    }
    res.tokens_per_sec = processed as f64 / sim.vtime_secs();
    res.final_state = sim.gather_state(corpus);
    finish(opts, res)
}

fn train_ps_sim(
    opts: &TrainOpts,
    corpus: &Corpus,
    hyper: Hyper,
    eval: &mut Evaluator,
    label: &str,
) -> Result<TrainResult, String> {
    let cluster = sim_cluster(opts);
    let mut cfg = PsSimConfig::new(cluster, opts.topics);
    cfg.seed = opts.seed;
    cfg.batch_docs = opts.batch_docs;
    cfg.disk = opts.disk;
    cfg.cost = CostModel::default_for(opts.topics);
    let mut sim = PsSim::new(corpus, hyper, cfg);
    let mut res = new_result(label);
    let mut processed = 0u64;
    let state0 = sim.gather_state(corpus);
    eval_point!(eval, state0, 0, 0.0, res, opts, label);
    for it in 1..=opts.iters {
        let stats = sim.run_epoch();
        processed += stats.processed;
        if it % opts.eval_every == 0 || it == opts.iters {
            let state = sim.gather_state(corpus);
            eval_point!(eval, state, it, sim.vtime_secs(), res, opts, label);
        }
    }
    res.tokens_per_sec = processed as f64 / sim.vtime_secs();
    res.final_state = sim.gather_state(corpus);
    finish(opts, res)
}

fn finish(opts: &TrainOpts, res: TrainResult) -> Result<TrainResult, String> {
    if let Some(path) = &opts.out {
        write_csv(path, &[res.ll_vs_iter.clone(), res.ll_vs_time.clone()])
            .map_err(|e| e.to_string())?;
        if !opts.quiet {
            eprintln!("[train] wrote {}", path.display());
        }
    }
    Ok(res)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet(runtime: &str) -> TrainOpts {
        TrainOpts {
            runtime: runtime.into(),
            iters: 2,
            eval: "rust".into(),
            quiet: true,
            topics: 8,
            ..Default::default()
        }
    }

    #[test]
    fn every_runtime_trains_tiny() {
        for rt in ["serial", "nomad", "ps", "adlda", "nomad-sim", "ps-sim"] {
            let res = train(&quiet(rt)).unwrap_or_else(|e| panic!("{rt}: {e}"));
            assert_eq!(res.ll_vs_iter.points.len(), 3, "{rt}"); // iter 0,1,2
            assert!(res.tokens_per_sec > 0.0, "{rt}");
            let lls: Vec<f64> = res.ll_vs_iter.points.iter().map(|&(_, y)| y).collect();
            assert!(lls.last().unwrap() > lls.first().unwrap(), "{rt}: no improvement");
        }
    }

    #[test]
    fn unknown_runtime_and_eval_error() {
        assert!(train(&TrainOpts { runtime: "bogus".into(), ..quiet("serial") }).is_err());
        assert!(train(&TrainOpts { eval: "bogus".into(), ..quiet("serial") }).is_err());
    }

    #[test]
    fn csv_output_written() {
        let path = std::env::temp_dir().join("fnomad_train_test").join("out.csv");
        let mut opts = quiet("serial");
        opts.out = Some(path.clone());
        train(&opts).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.contains("ll_vs_iter"));
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }
}
