//! The training coordinator: wires corpora, samplers, runtimes and the
//! PJRT evaluator into runnable experiments, and records the convergence
//! series every figure is built from.
//!
//! Architecture (one PR's worth of API): a typed [`TrainConfig`] selects a
//! [`RuntimeKind`]; [`engine::make_engine`] builds the matching
//! [`TrainEngine`]; **one** generic driver loop ([`train_with`]) runs
//! epochs, evaluates at the configured cadence, and fans events out to
//! [`TrainObserver`]s (progress logging, CSV output, checkpointing,
//! hyperparameter estimation — all observers, no special cases).  With
//! `checkpoint_dir` set the driver also stands up the
//! [`crate::resilience`] layer: an async snapshot service fed at the eval
//! cadence, and — for the nomad runtime — the supervised-recovery engine
//! that restarts the ring from the latest valid snapshot on worker loss.

pub mod config;
pub mod engine;
pub mod observer;

pub use config::{EvalPolicy, RuntimeKind, SamplerKind, TrainConfig};
pub use engine::{make_engine, Clock, EpochReport, TrainEngine};
pub use observer::{
    Checkpointer, CsvWriter, EvalPoint, HyperOptimizer, LlRecorder, ProgressLogger,
    TrainObserver,
};

use std::sync::Arc;

use crate::corpus::{preset, Corpus};
use crate::lda::{self, checkpoint, Hyper, LdaState};
use crate::resilience::{AsyncCheckpointer, CheckpointWriter, SnapshotStore, Supervisor};
use crate::runtime::{artifacts_available, default_artifact_dir, LlEvaluator};
use crate::util::metrics::Series;

/// Model-quality evaluator: PJRT artifact path or the Rust reference.
pub enum Evaluator {
    Xla(Box<LlEvaluator>),
    Rust,
}

impl Evaluator {
    /// Resolve by policy: [`EvalPolicy::Auto`] prefers the blocked path
    /// when artifacts exist *and* cover the topic count, and otherwise
    /// falls back to the sparse Rust reference — which is exact and faster
    /// than the dense blocked evaluator, so hermetic default builds (no
    /// `artifacts/`) deliberately train with `Rust`.  The blocked backend
    /// (PJRT with `--features pjrt`, pure Rust otherwise) stays reachable
    /// via the explicit [`EvalPolicy::Xla`] policy and
    /// `fnomad-lda check-artifacts`.
    pub fn resolve(policy: EvalPolicy, topics: usize) -> Result<Evaluator, String> {
        let dir = default_artifact_dir();
        match policy {
            EvalPolicy::Rust => Ok(Evaluator::Rust),
            EvalPolicy::Xla => Ok(Evaluator::Xla(Box::new(LlEvaluator::new(&dir, topics)?))),
            EvalPolicy::Auto => {
                if artifacts_available(&dir) {
                    match LlEvaluator::new(&dir, topics) {
                        Ok(e) => Ok(Evaluator::Xla(Box::new(e))),
                        Err(_) => Ok(Evaluator::Rust),
                    }
                } else {
                    Ok(Evaluator::Rust)
                }
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            // "xla" under --features pjrt, "blocked-rust" in default builds
            Evaluator::Xla(_) => LlEvaluator::BACKEND,
            Evaluator::Rust => "rust",
        }
    }

    pub fn log_likelihood(&mut self, state: &LdaState) -> Result<f64, String> {
        match self {
            Evaluator::Xla(e) => e.log_likelihood(state),
            Evaluator::Rust => Ok(lda::log_likelihood(state)),
        }
    }
}

/// Result of one training run: the two series every figure needs.
pub struct TrainResult {
    /// (iteration, LL)
    pub ll_vs_iter: Series,
    /// (seconds — wall or virtual, LL)
    pub ll_vs_time: Series,
    /// tokens/sec aggregate (real or virtual)
    pub tokens_per_sec: f64,
    pub final_state: LdaState,
}

/// Run one experiment per `cfg` with no extra observers.
pub fn train(cfg: &TrainConfig) -> Result<TrainResult, String> {
    train_with(cfg, &mut [])
}

/// Resolve the training corpus: an `.fncorpus` file when `cfg.corpus` is
/// set (streamed through a bounded read window unless `--in-ram` asks for
/// a full load), the named preset otherwise.
fn resolve_corpus(cfg: &TrainConfig) -> Result<Corpus, String> {
    match &cfg.corpus {
        Some(path) => {
            if cfg.corpus_ram {
                Corpus::load_fncorpus_ram(path)
            } else {
                Corpus::open_fncorpus(path, cfg.corpus_window)
            }
        }
        None => preset(&cfg.preset),
    }
}

/// The single driver loop behind every runtime.
///
/// Builds the engine from a checkpoint-or-random initial state, runs
/// `cfg.iters` epochs, evaluates at epoch 0, every `cfg.eval_every`
/// epochs, and the final epoch, and fans events out to the stock
/// observers the config selects plus any in `extra`.
pub fn train_with(
    cfg: &TrainConfig,
    extra: &mut [&mut dyn TrainObserver],
) -> Result<TrainResult, String> {
    cfg.validate()?;
    if (cfg.resume || cfg.save_every > 0) && cfg.checkpoint.is_none() {
        return Err("--resume/--save-every require --checkpoint PATH".into());
    }
    let corpus = resolve_corpus(cfg)?;
    let hyper = Hyper::paper_default(cfg.topics);
    let resume_from = if cfg.resume { cfg.checkpoint.as_deref() } else { None };
    let resumed = resume_from.is_some_and(|p| p.exists());
    // init_or_load validates the requested hyperparameters against the
    // checkpoint: a T mismatch is an error (no silent override)
    let init = checkpoint::init_or_load(resume_from, &corpus, hyper, cfg.seed, cfg.quiet)?;
    let mut eval = Evaluator::resolve(cfg.eval, init.hyper.t)?;
    let label = cfg.label();
    if cfg.trace.is_some() {
        // enable before the first epoch so span t=0 precedes every span
        crate::obs::trace::enable();
    }
    if !cfg.quiet {
        crate::log_event!(
            Info,
            "train",
            {
                docs = corpus.num_docs(),
                vocab = corpus.vocab(),
                tokens = corpus.num_tokens(),
                t = init.hyper.t
            },
            "{} docs={} vocab={} tokens={} T={} eval={}{}{}",
            label,
            corpus.num_docs(),
            corpus.vocab(),
            corpus.num_tokens(),
            init.hyper.t,
            eval.name(),
            if corpus.is_on_disk() { " corpus=streamed" } else { "" },
            if resumed { " (resumed from checkpoint)" } else { "" }
        );
    }

    // the async checkpoint service: a store + background writer thread,
    // fed from the observer below; with the nomad runtime it also powers
    // supervised recovery (the Supervisor engine)
    let ckpt_service = match &cfg.checkpoint_dir {
        Some(dir) => {
            let store = Arc::new(SnapshotStore::open(dir, cfg.keep)?);
            // this run owns the directory now: checkpoints left by a
            // previous run must not survive into its retention chain
            // (recovery reloading another run's state would silently skip
            // every epoch that run had already passed)
            store.begin_run()?;
            let writer = CheckpointWriter::spawn(Arc::clone(&store), cfg.quiet);
            Some((store, writer))
        }
        None => None,
    };
    let mut engine: Box<dyn TrainEngine + '_> = match &ckpt_service {
        Some((store, writer)) if cfg.runtime == RuntimeKind::Nomad => Box::new(
            Supervisor::new(&corpus, &init, cfg, Arc::clone(store), writer.sink())?,
        ),
        _ => make_engine(&corpus, init, cfg)?,
    };
    let mut recorder = LlRecorder::new(&label);
    let mut stock: Vec<Box<dyn TrainObserver>> = Vec::new();
    if !cfg.quiet {
        stock.push(Box::new(ProgressLogger::new(&label)));
    }
    if let Some((_, writer)) = &ckpt_service {
        stock.push(Box::new(AsyncCheckpointer::new(writer.sink(), cfg.save_every, cfg.quiet)));
    }
    if let Some(path) = &cfg.out {
        stock.push(Box::new(CsvWriter::new(path, cfg.quiet)));
    }
    // hyper-opt before the checkpointer: on_finish runs in push order, so
    // the final checkpoint carries the optimized hyperparameters
    if cfg.hyper_opt_steps > 0 {
        stock.push(Box::new(HyperOptimizer::new(cfg.hyper_opt_steps, cfg.quiet)));
    }
    if let Some(path) = &cfg.checkpoint {
        stock.push(Box::new(Checkpointer::new(path, cfg.save_every, cfg.quiet)));
    }
    if let Some(path) = &cfg.metrics {
        stock.push(Box::new(crate::obs::export::MetricsWriter::create(path)?));
    }

    let eval_every = cfg.eval_every.max(1);
    let mut wall_secs = 0.0f64;
    let mut processed = 0u64;
    let mut last_state = eval_point(
        &mut *engine,
        &mut eval,
        &corpus,
        0,
        0.0,
        &mut recorder,
        &mut stock,
        extra,
    )?;
    let reg = crate::obs::registry::global();
    let epochs_total = reg.counter("train.epochs_total");
    let tokens_total = reg.counter("train.tokens_total");
    for it in 1..=cfg.iters {
        let t_epoch = crate::obs::trace::start();
        let report = engine.run_epoch();
        if let Some(t0) = t_epoch {
            crate::obs::trace::complete("epoch", &format!("epoch {it}"), t_epoch);
            if let Some(ring) = &report.ring {
                // slot lanes: sampling starts once injection is done; each
                // slot's per-epoch sample time renders as one span
                let base_us = crate::obs::trace::us_since_epoch(t0)
                    + (ring.inject_secs * 1e6) as u64;
                for s in &ring.slots {
                    crate::obs::trace::span_at(
                        "slot",
                        &format!("slot {} sample", s.slot),
                        base_us,
                        (s.sample_secs * 1e6) as u64,
                        s.slot as u64 + 1,
                    );
                }
            }
        }
        epochs_total.inc();
        tokens_total.add(report.processed);
        wall_secs += report.secs;
        processed += report.processed;
        for o in stock.iter_mut() {
            o.on_epoch(it, &report)?;
        }
        for o in extra.iter_mut() {
            o.on_epoch(it, &report)?;
        }
        if it % eval_every == 0 || it == cfg.iters {
            last_state = eval_point(
                &mut *engine,
                &mut eval,
                &corpus,
                it,
                wall_secs,
                &mut recorder,
                &mut stock,
                extra,
            )?;
        }
    }
    let elapsed = match engine.clock() {
        Clock::Wall => wall_secs,
        Clock::Virtual(v) => v,
    };
    engine.shutdown();

    let (ll_vs_iter, ll_vs_time) = recorder.into_series();
    let mut result = TrainResult {
        ll_vs_iter,
        ll_vs_time,
        tokens_per_sec: if elapsed > 0.0 { processed as f64 / elapsed } else { 0.0 },
        final_state: last_state,
    };
    for o in stock.iter_mut() {
        o.on_finish(&mut result)?;
    }
    for o in extra.iter_mut() {
        o.on_finish(&mut result)?;
    }
    // drain and join the checkpoint writer so the final snapshot is on
    // disk before the run reports success
    if let Some((_, writer)) = ckpt_service {
        writer.finish();
    }
    // after the writer join, so checkpoint spans from this run are in
    if let Some(path) = &cfg.trace {
        crate::obs::trace::write(path)?;
    }
    Ok(result)
}

/// One evaluation: snapshot the exact state, score it, notify observers.
#[allow(clippy::too_many_arguments)]
fn eval_point(
    engine: &mut dyn TrainEngine,
    eval: &mut Evaluator,
    corpus: &Corpus,
    epoch: usize,
    wall_secs: f64,
    recorder: &mut LlRecorder,
    stock: &mut [Box<dyn TrainObserver>],
    extra: &mut [&mut dyn TrainObserver],
) -> Result<LdaState, String> {
    let state = engine.state_snapshot(corpus);
    let ll = eval.log_likelihood(&state)?;
    let secs = match engine.clock() {
        Clock::Wall => wall_secs,
        Clock::Virtual(v) => v,
    };
    let point = EvalPoint { epoch, secs, ll, state: &state };
    recorder.on_eval(&point)?;
    for o in stock.iter_mut() {
        o.on_eval(&point)?;
    }
    for o in extra.iter_mut() {
        o.on_eval(&point)?;
    }
    Ok(state)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet(runtime: RuntimeKind) -> TrainConfig {
        TrainConfig::preset("tiny")
            .runtime(runtime)
            .iters(2)
            .eval(EvalPolicy::Rust)
            .quiet(true)
            .topics(8)
    }

    #[test]
    fn every_runtime_trains_tiny() {
        for rt in RuntimeKind::ALL {
            let res = train(&quiet(rt)).unwrap_or_else(|e| panic!("{rt}: {e}"));
            assert_eq!(res.ll_vs_iter.points.len(), 3, "{rt}"); // iter 0,1,2
            assert!(res.tokens_per_sec > 0.0, "{rt}");
            let lls: Vec<f64> = res.ll_vs_iter.points.iter().map(|&(_, y)| y).collect();
            assert!(lls.last().unwrap() > lls.first().unwrap(), "{rt}: no improvement");
            res.final_state
                .check_consistency(&preset("tiny").unwrap())
                .unwrap_or_else(|e| panic!("{rt}: {e}"));
        }
    }

    #[test]
    fn unknown_names_error_at_the_parse_layer() {
        assert!("bogus".parse::<RuntimeKind>().is_err());
        assert!("bogus".parse::<SamplerKind>().is_err());
        assert!("bogus".parse::<EvalPolicy>().is_err());
        assert!(train(&TrainConfig::preset("no-such-preset").quiet(true)).is_err());
    }

    #[test]
    fn csv_output_written() {
        let path = std::env::temp_dir().join("fnomad_train_test").join("out.csv");
        let cfg = quiet(RuntimeKind::Serial).out(path.clone());
        train(&cfg).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.contains("ll_vs_iter"));
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn virtual_time_axis_for_sim_runtimes() {
        let res = train(&quiet(RuntimeKind::NomadSim)).unwrap();
        // virtual seconds are strictly increasing across evaluations
        let xs: Vec<f64> = res.ll_vs_time.points.iter().map(|&(x, _)| x).collect();
        assert!(xs.windows(2).all(|w| w[0] < w[1]), "vtime not monotone: {xs:?}");
    }
}
