//! Typed training configuration: the [`TrainConfig`] builder plus the
//! [`RuntimeKind`] / [`SamplerKind`] / [`EvalPolicy`] enums that replace
//! the old stringly-typed option bag.
//!
//! CLI strings survive only at the parse boundary: `main.rs` calls
//! `FromStr` on each flag and everything past that point is typed.
//!
//! ```
//! use fnomad_lda::coordinator::{RuntimeKind, TrainConfig};
//!
//! let cfg = TrainConfig::preset("tiny")
//!     .runtime(RuntimeKind::NomadSim)
//!     .topics(16)
//!     .iters(3)
//!     .quiet(true);
//! assert_eq!(cfg.runtime.to_string(), "nomad-sim");
//! ```

use std::fmt;
use std::path::PathBuf;
use std::str::FromStr;

use crate::resilience::FaultPlan;

/// Which training runtime executes the epochs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RuntimeKind {
    /// single-threaded Gibbs sweeps (any [`SamplerKind`])
    Serial,
    /// threaded Nomad: real workers, nomadic word tokens (§4)
    Nomad,
    /// threaded parameter-server baseline (Yahoo! LDA architecture)
    Ps,
    /// bulk-synchronous AD-LDA baseline
    AdLda,
    /// Nomad under virtual time (discrete-event simulator)
    NomadSim,
    /// parameter server under virtual time
    PsSim,
}

impl RuntimeKind {
    /// Every variant, in CLI order (drives `every_runtime_trains_tiny`).
    pub const ALL: [RuntimeKind; 6] = [
        RuntimeKind::Serial,
        RuntimeKind::Nomad,
        RuntimeKind::Ps,
        RuntimeKind::AdLda,
        RuntimeKind::NomadSim,
        RuntimeKind::PsSim,
    ];

    /// CLI name (also the `Display` form).
    pub fn name(&self) -> &'static str {
        match self {
            RuntimeKind::Serial => "serial",
            RuntimeKind::Nomad => "nomad",
            RuntimeKind::Ps => "ps",
            RuntimeKind::AdLda => "adlda",
            RuntimeKind::NomadSim => "nomad-sim",
            RuntimeKind::PsSim => "ps-sim",
        }
    }

    /// True for the virtual-time runtimes (their clock is not wall time).
    pub fn is_simulated(&self) -> bool {
        matches!(self, RuntimeKind::NomadSim | RuntimeKind::PsSim)
    }

    fn valid_names() -> String {
        RuntimeKind::ALL.map(|r| r.name()).join("|")
    }
}

impl fmt::Display for RuntimeKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for RuntimeKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        RuntimeKind::ALL
            .into_iter()
            .find(|r| r.name() == s)
            .ok_or_else(|| format!("unknown runtime '{s}' ({})", RuntimeKind::valid_names()))
    }
}

/// Which serial Gibbs sweep variant the [`RuntimeKind::Serial`] runtime uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SamplerKind {
    /// dense O(T) collapsed Gibbs
    Plain,
    /// SparseLDA s/r/q decomposition
    Sparse,
    /// AliasLDA (Metropolis-Hastings over a stale alias table)
    Alias,
    /// F+LDA, doc-by-doc order
    FLdaDoc,
    /// F+LDA, word-by-word order (the paper's fastest serial sampler)
    FLdaWord,
}

impl SamplerKind {
    /// Every variant, in CLI order.
    pub const ALL: [SamplerKind; 5] = [
        SamplerKind::Plain,
        SamplerKind::Sparse,
        SamplerKind::Alias,
        SamplerKind::FLdaDoc,
        SamplerKind::FLdaWord,
    ];

    /// CLI name; also the key accepted by [`crate::lda::by_name`].
    pub fn name(&self) -> &'static str {
        match self {
            SamplerKind::Plain => "plain",
            SamplerKind::Sparse => "sparse",
            SamplerKind::Alias => "alias",
            SamplerKind::FLdaDoc => "flda-doc",
            SamplerKind::FLdaWord => "flda-word",
        }
    }

    fn valid_names() -> String {
        SamplerKind::ALL.map(|s| s.name()).join("|")
    }
}

impl fmt::Display for SamplerKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for SamplerKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        SamplerKind::ALL
            .into_iter()
            .find(|k| k.name() == s)
            .ok_or_else(|| format!("unknown sampler '{s}' ({})", SamplerKind::valid_names()))
    }
}

/// How the model-quality evaluator backend is chosen.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum EvalPolicy {
    /// blocked backend when artifacts cover the topic count, Rust otherwise
    #[default]
    Auto,
    /// force the blocked backend (PJRT with `--features pjrt`)
    Xla,
    /// force the exact sparse Rust reference
    Rust,
}

impl EvalPolicy {
    /// Every variant, in CLI order.
    pub const ALL: [EvalPolicy; 3] = [EvalPolicy::Auto, EvalPolicy::Xla, EvalPolicy::Rust];

    /// CLI name (also the `Display` form).
    pub fn name(&self) -> &'static str {
        match self {
            EvalPolicy::Auto => "auto",
            EvalPolicy::Xla => "xla",
            EvalPolicy::Rust => "rust",
        }
    }

    fn valid_names() -> String {
        EvalPolicy::ALL.map(|p| p.name()).join("|")
    }
}

impl fmt::Display for EvalPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for EvalPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        EvalPolicy::ALL
            .into_iter()
            .find(|p| p.name() == s)
            .ok_or_else(|| format!("unknown eval policy '{s}' ({})", EvalPolicy::valid_names()))
    }
}

/// Typed training/experiment configuration.
///
/// Construct with [`TrainConfig::preset`] and chain the builder methods;
/// every field is also public for struct-literal construction at the CLI
/// parse layer.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// corpus preset name (see [`crate::corpus::presets`]); ignored when
    /// [`TrainConfig::corpus`] points at an `.fncorpus` file
    pub preset: String,
    /// train out-of-core from this FNCP0001 file instead of a preset
    pub corpus: Option<PathBuf>,
    /// load the `.fncorpus` file fully into RAM instead of streaming it
    pub corpus_ram: bool,
    /// sliding read-window size in tokens for the streaming backend
    pub corpus_window: usize,
    pub topics: usize,
    /// serial sweep variant (only [`RuntimeKind::Serial`] reads this)
    pub sampler: SamplerKind,
    pub runtime: RuntimeKind,
    pub workers: usize,
    /// `host:port` of `serve-worker` processes to splice into the ring
    /// after the local threads ([`RuntimeKind::Nomad`] only)
    pub remote: Vec<String>,
    /// simulated machines (sim runtimes; workers = machines × 20 when > 1)
    pub machines: usize,
    pub iters: usize,
    pub seed: u64,
    pub eval: EvalPolicy,
    pub eval_every: usize,
    /// PS pull/push cadence (docs)
    pub batch_docs: usize,
    /// PS disk flavor (sim only)
    pub disk: bool,
    /// CSV output path for the convergence series
    pub out: Option<PathBuf>,
    pub quiet: bool,
    /// checkpoint file; written at finish (and every `save_every` epochs)
    pub checkpoint: Option<PathBuf>,
    /// checkpoint cadence in epochs (0 = only at finish); snapshots are
    /// taken at evaluation points, so cadences finer than `eval_every`
    /// round up to the next evaluation
    pub save_every: usize,
    /// start from `checkpoint` if it exists instead of random init
    pub resume: bool,
    /// Minka fixed-point steps applied to the final state (0 = off)
    pub hyper_opt_steps: usize,
    /// directory for the async checkpoint service (retained snapshots +
    /// MANIFEST); with the nomad runtime this also enables supervised
    /// ring recovery
    pub checkpoint_dir: Option<PathBuf>,
    /// snapshots retained under `checkpoint_dir` (keep-last-K)
    pub keep: usize,
    /// ring rebuilds the supervisor may attempt before giving up with the
    /// original failure (0 = fail on the first ring loss)
    pub max_restarts: usize,
    /// scripted fault injection (tests only; never set from the CLI)
    pub fault: FaultPlan,
    /// periodic telemetry export: one JSON object per epoch appended to
    /// this file (`train --metrics FILE.jsonl`)
    pub metrics: Option<PathBuf>,
    /// Chrome-trace-event span recording, written once at the end of the
    /// run (`train --trace FILE.json`; open in <https://ui.perfetto.dev>)
    pub trace: Option<PathBuf>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            preset: "tiny".into(),
            corpus: None,
            corpus_ram: false,
            corpus_window: crate::corpus::DEFAULT_WINDOW_TOKENS,
            topics: 128,
            sampler: SamplerKind::FLdaWord,
            runtime: RuntimeKind::Serial,
            workers: 2,
            remote: Vec::new(),
            machines: 1,
            iters: 10,
            seed: 0,
            eval: EvalPolicy::Auto,
            eval_every: 1,
            batch_docs: 16,
            disk: false,
            out: None,
            quiet: false,
            checkpoint: None,
            save_every: 0,
            resume: false,
            hyper_opt_steps: 0,
            checkpoint_dir: None,
            keep: 3,
            max_restarts: 0,
            fault: FaultPlan::default(),
            metrics: None,
            trace: None,
        }
    }
}

impl TrainConfig {
    /// Start a config for the given corpus preset (builder entry point).
    pub fn preset(name: &str) -> Self {
        TrainConfig { preset: name.into(), ..Default::default() }
    }

    pub fn topics(mut self, t: usize) -> Self {
        self.topics = t;
        self
    }

    pub fn corpus(mut self, path: impl Into<PathBuf>) -> Self {
        self.corpus = Some(path.into());
        self
    }

    pub fn corpus_ram(mut self, in_ram: bool) -> Self {
        self.corpus_ram = in_ram;
        self
    }

    pub fn corpus_window(mut self, tokens: usize) -> Self {
        self.corpus_window = tokens;
        self
    }

    pub fn sampler(mut self, s: SamplerKind) -> Self {
        self.sampler = s;
        self
    }

    pub fn runtime(mut self, r: RuntimeKind) -> Self {
        self.runtime = r;
        self
    }

    pub fn workers(mut self, p: usize) -> Self {
        self.workers = p;
        self
    }

    pub fn remote(mut self, addrs: Vec<String>) -> Self {
        self.remote = addrs;
        self
    }

    pub fn machines(mut self, m: usize) -> Self {
        self.machines = m;
        self
    }

    pub fn iters(mut self, n: usize) -> Self {
        self.iters = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    pub fn eval(mut self, e: EvalPolicy) -> Self {
        self.eval = e;
        self
    }

    pub fn eval_every(mut self, k: usize) -> Self {
        self.eval_every = k;
        self
    }

    pub fn batch_docs(mut self, b: usize) -> Self {
        self.batch_docs = b;
        self
    }

    pub fn disk(mut self, d: bool) -> Self {
        self.disk = d;
        self
    }

    pub fn out(mut self, path: impl Into<PathBuf>) -> Self {
        self.out = Some(path.into());
        self
    }

    pub fn quiet(mut self, q: bool) -> Self {
        self.quiet = q;
        self
    }

    pub fn checkpoint(mut self, path: impl Into<PathBuf>) -> Self {
        self.checkpoint = Some(path.into());
        self
    }

    pub fn save_every(mut self, n: usize) -> Self {
        self.save_every = n;
        self
    }

    pub fn resume(mut self, r: bool) -> Self {
        self.resume = r;
        self
    }

    pub fn hyper_opt_steps(mut self, n: usize) -> Self {
        self.hyper_opt_steps = n;
        self
    }

    pub fn checkpoint_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.checkpoint_dir = Some(dir.into());
        self
    }

    pub fn keep(mut self, k: usize) -> Self {
        self.keep = k;
        self
    }

    pub fn max_restarts(mut self, n: usize) -> Self {
        self.max_restarts = n;
        self
    }

    pub fn fault(mut self, plan: FaultPlan) -> Self {
        self.fault = plan;
        self
    }

    pub fn metrics(mut self, path: impl Into<PathBuf>) -> Self {
        self.metrics = Some(path.into());
        self
    }

    pub fn trace(mut self, path: impl Into<PathBuf>) -> Self {
        self.trace = Some(path.into());
        self
    }

    /// Validate cross-field constraints the type system cannot express.
    /// Called once by the driver, so CLI and library users both get a
    /// proper error (never a worker-runtime assertion) for e.g.
    /// `--workers 0`.
    pub fn validate(&self) -> Result<(), String> {
        if !self.remote.is_empty() && self.runtime != RuntimeKind::Nomad {
            return Err(format!("--remote requires --runtime nomad (got '{}')", self.runtime));
        }
        // serial ignores workers entirely; every other runtime spawns them
        let needs_workers = self.runtime != RuntimeKind::Serial;
        let fully_remote = self.runtime == RuntimeKind::Nomad && !self.remote.is_empty();
        if needs_workers && self.workers == 0 && !fully_remote {
            return Err(format!(
                "--workers must be at least 1 to run '{}' (only a nomad ring with \
                 --remote workers can run with 0 local threads)",
                self.runtime
            ));
        }
        if self.max_restarts > 0 && self.checkpoint_dir.is_none() {
            return Err(
                "--max-restarts requires --checkpoint-dir DIR (recovery restarts from \
                 retained snapshots)"
                    .into(),
            );
        }
        if self.max_restarts > 0 && self.runtime != RuntimeKind::Nomad {
            return Err(format!(
                "--max-restarts requires --runtime nomad (got '{}'); only the ring \
                 supports supervised recovery",
                self.runtime
            ));
        }
        if self.checkpoint_dir.is_some() && self.keep == 0 {
            return Err("--keep must be at least 1 (retention would delete every snapshot)".into());
        }
        if !self.fault.is_empty() && self.runtime != RuntimeKind::Nomad {
            return Err(format!(
                "fault injection requires the nomad runtime (got '{}')",
                self.runtime
            ));
        }
        if self.corpus_ram && self.corpus.is_none() {
            return Err("--in-ram requires --corpus FILE.fncorpus".into());
        }
        if self.corpus.is_some() && self.corpus_window == 0 {
            return Err("--corpus-window must be at least 1 token".into());
        }
        Ok(())
    }

    /// Corpus component of the label: the `.fncorpus` file stem for
    /// `--corpus` runs, the preset name otherwise.
    fn corpus_tag(&self) -> String {
        match &self.corpus {
            Some(p) => p
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| self.preset.clone()),
            None => self.preset.clone(),
        }
    }

    /// Figure/progress label, e.g. `flda-word-tiny`, `nomad-p4-enron-sim`,
    /// or `nomad-p1+r2-tiny` for a mixed local/remote ring.
    pub fn label(&self) -> String {
        let tag = self.corpus_tag();
        match self.runtime {
            RuntimeKind::Serial => format!("{}-{}", self.sampler, tag),
            RuntimeKind::NomadSim | RuntimeKind::PsSim if self.machines > 1 => format!(
                "{}-{}x20-{}{}",
                self.runtime,
                self.machines,
                tag,
                if self.disk { "-disk" } else { "" }
            ),
            rt => format!(
                "{rt}-p{}{}-{}{}",
                self.workers,
                if self.remote.is_empty() {
                    String::new()
                } else {
                    format!("+r{}", self.remote.len())
                },
                tag,
                if self.disk { "-disk" } else { "" }
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_kind_roundtrip_and_error() {
        for kind in RuntimeKind::ALL {
            assert_eq!(kind.to_string().parse::<RuntimeKind>().unwrap(), kind);
        }
        let err = "bogus".parse::<RuntimeKind>().unwrap_err();
        for kind in RuntimeKind::ALL {
            assert!(err.contains(kind.name()), "error must list '{kind}': {err}");
        }
    }

    #[test]
    fn sampler_kind_roundtrip_and_error() {
        for kind in SamplerKind::ALL {
            assert_eq!(kind.to_string().parse::<SamplerKind>().unwrap(), kind);
        }
        let err = "bogus".parse::<SamplerKind>().unwrap_err();
        for kind in SamplerKind::ALL {
            assert!(err.contains(kind.name()), "error must list '{kind}': {err}");
        }
    }

    #[test]
    fn eval_policy_roundtrip_and_error() {
        for p in EvalPolicy::ALL {
            assert_eq!(p.to_string().parse::<EvalPolicy>().unwrap(), p);
        }
        let err = "bogus".parse::<EvalPolicy>().unwrap_err();
        for p in EvalPolicy::ALL {
            assert!(err.contains(p.name()), "error must list '{p}': {err}");
        }
    }

    #[test]
    fn validate_rejects_zero_workers_and_misplaced_remote() {
        // serial never reads workers, so 0 stays legal there
        TrainConfig::preset("tiny").workers(0).validate().unwrap();
        let err = TrainConfig::preset("tiny")
            .runtime(RuntimeKind::Nomad)
            .workers(0)
            .validate()
            .unwrap_err();
        assert!(err.contains("--workers"), "error must name the flag: {err}");
        let err = TrainConfig::preset("tiny")
            .remote(vec!["127.0.0.1:7777".into()])
            .validate()
            .unwrap_err();
        assert!(err.contains("--remote"), "error must name the flag: {err}");
        // a fully-remote nomad ring is the one legitimate workers == 0
        TrainConfig::preset("tiny")
            .runtime(RuntimeKind::Nomad)
            .workers(0)
            .remote(vec!["127.0.0.1:7777".into()])
            .validate()
            .unwrap();
        TrainConfig::preset("tiny").validate().unwrap();
    }

    #[test]
    fn validate_pins_resilience_flag_combinations() {
        let err = TrainConfig::preset("tiny")
            .runtime(RuntimeKind::Nomad)
            .max_restarts(1)
            .validate()
            .unwrap_err();
        assert!(err.contains("--checkpoint-dir"), "error must name the flag: {err}");
        let err = TrainConfig::preset("tiny")
            .checkpoint_dir("ckpts")
            .max_restarts(1)
            .validate()
            .unwrap_err();
        assert!(err.contains("nomad"), "error must name the runtime: {err}");
        let err = TrainConfig::preset("tiny")
            .runtime(RuntimeKind::Nomad)
            .checkpoint_dir("ckpts")
            .keep(0)
            .validate()
            .unwrap_err();
        assert!(err.contains("--keep"), "error must name the flag: {err}");
        TrainConfig::preset("tiny")
            .runtime(RuntimeKind::Nomad)
            .checkpoint_dir("ckpts")
            .max_restarts(2)
            .validate()
            .unwrap();
    }

    #[test]
    fn builder_chains_and_labels() {
        let cfg = TrainConfig::preset("enron-sim")
            .runtime(RuntimeKind::Nomad)
            .workers(4)
            .topics(64);
        assert_eq!(cfg.label(), "nomad-p4-enron-sim");
        let mixed = TrainConfig::preset("tiny")
            .runtime(RuntimeKind::Nomad)
            .workers(1)
            .remote(vec!["a:1".into(), "b:2".into()]);
        assert_eq!(mixed.label(), "nomad-p1+r2-tiny");
        let serial = TrainConfig::preset("tiny").sampler(SamplerKind::Plain);
        assert_eq!(serial.label(), "plain-tiny");
        let sim = TrainConfig::preset("tiny")
            .runtime(RuntimeKind::PsSim)
            .machines(4)
            .disk(true);
        assert_eq!(sim.label(), "ps-sim-4x20-tiny-disk");
    }
}
