//! Training observers: the hook surface of the single driver loop.
//!
//! Everything that used to be an ad-hoc branch in six copy-pasted
//! `train_*` functions — progress logging, CSV output, series recording,
//! checkpointing, hyperparameter estimation — is an implementation of
//! [`TrainObserver`].  The driver installs the stock observers that the
//! [`super::TrainConfig`] asks for and threads any caller-supplied ones
//! through [`super::train_with`].

use std::path::PathBuf;

use crate::lda::{self, LdaState};
use crate::util::metrics::{write_csv, Series};

use super::engine::EpochReport;
use super::TrainResult;

/// One evaluation of model quality at an epoch boundary.
#[derive(Debug)]
pub struct EvalPoint<'a> {
    /// epoch index (0 = before any training)
    pub epoch: usize,
    /// x coordinate on the time axis: wall or virtual seconds, per the
    /// engine's [`super::Clock`]
    pub secs: f64,
    /// joint log-likelihood under the configured evaluator
    pub ll: f64,
    /// the exact global state the likelihood was computed from
    pub state: &'a LdaState,
}

/// Hooks called by the driver loop; all default to no-ops.
///
/// Errors propagate out of [`super::train_with`] and abort the run.
pub trait TrainObserver {
    /// After every epoch, with that epoch's [`EpochReport`].
    fn on_epoch(&mut self, _epoch: usize, _report: &EpochReport) -> Result<(), String> {
        Ok(())
    }

    /// At every evaluation point (epoch 0, every `eval_every` epochs, and
    /// the final epoch).
    fn on_eval(&mut self, _point: &EvalPoint<'_>) -> Result<(), String> {
        Ok(())
    }

    /// Once, after the last epoch, with the assembled result (mutable so
    /// finishers like the hyperparameter optimizer can refine it).
    fn on_finish(&mut self, _result: &mut TrainResult) -> Result<(), String> {
        Ok(())
    }
}

/// Records the two convergence series every figure is built from.  The
/// driver always installs one; it is public so custom harnesses can reuse
/// it.
#[derive(Debug, Default)]
pub struct LlRecorder {
    pub ll_vs_iter: Series,
    pub ll_vs_time: Series,
}

impl LlRecorder {
    pub fn new(label: &str) -> Self {
        LlRecorder {
            ll_vs_iter: Series::new(format!("{label}:ll_vs_iter")),
            ll_vs_time: Series::new(format!("{label}:ll_vs_time")),
        }
    }

    /// Take the recorded series out (driver, at finish).
    pub fn into_series(self) -> (Series, Series) {
        (self.ll_vs_iter, self.ll_vs_time)
    }
}

impl TrainObserver for LlRecorder {
    fn on_eval(&mut self, point: &EvalPoint<'_>) -> Result<(), String> {
        self.ll_vs_iter.push(point.epoch as f64, point.ll);
        self.ll_vs_time.push(point.secs, point.ll);
        Ok(())
    }
}

/// Prints one progress line per evaluation point (the old `eval_point!`
/// logging); installed unless the config is quiet.
pub struct ProgressLogger {
    label: String,
}

impl ProgressLogger {
    pub fn new(label: &str) -> Self {
        ProgressLogger { label: label.into() }
    }
}

impl TrainObserver for ProgressLogger {
    fn on_eval(&mut self, point: &EvalPoint<'_>) -> Result<(), String> {
        crate::log_event!(
            Info,
            "train",
            { iter = point.epoch, ll = format!("{:.4e}", point.ll) },
            "[{}] iter {:4}  t={:9.3}s  LL={:.4e}",
            self.label,
            point.epoch,
            point.secs,
            point.ll
        );
        Ok(())
    }
}

/// Writes the recorded series as long-format CSV at finish; installed when
/// the config has an output path.
pub struct CsvWriter {
    path: PathBuf,
    quiet: bool,
}

impl CsvWriter {
    pub fn new(path: impl Into<PathBuf>, quiet: bool) -> Self {
        CsvWriter { path: path.into(), quiet }
    }
}

impl TrainObserver for CsvWriter {
    fn on_finish(&mut self, result: &mut TrainResult) -> Result<(), String> {
        write_csv(&self.path, &[result.ll_vs_iter.clone(), result.ll_vs_time.clone()])
            .map_err(|e| e.to_string())?;
        if !self.quiet {
            crate::log_event!(Info, "train", "wrote {}", self.path.display());
        }
        Ok(())
    }
}

/// Saves [`crate::lda::checkpoint`] files: every `save_every` epochs (at
/// evaluation points, where the exact state is materialized) and always at
/// finish.  `save_every == 0` means finish-only.
pub struct Checkpointer {
    path: PathBuf,
    save_every: usize,
    /// epoch of the most recent save (None = nothing written yet)
    last_saved: Option<usize>,
    /// last evaluation epoch seen — the final state's epoch at finish
    last_eval: usize,
    quiet: bool,
}

impl Checkpointer {
    pub fn new(path: impl Into<PathBuf>, save_every: usize, quiet: bool) -> Self {
        Checkpointer { path: path.into(), save_every, last_saved: None, last_eval: 0, quiet }
    }

    fn save(&self, state: &LdaState, what: &str) -> Result<(), String> {
        // atomic write + hard-linked `<path>.prev` retention: a crash
        // mid-save (or a later corruption of the live file) still leaves
        // a loadable generation for init_or_load to fall back to
        lda::checkpoint::save_with_retention(state, &self.path)?;
        if !self.quiet {
            crate::log_event!(Info, "ckpt", "saved {} ({what})", self.path.display());
        }
        Ok(())
    }
}

impl TrainObserver for Checkpointer {
    fn on_eval(&mut self, point: &EvalPoint<'_>) -> Result<(), String> {
        self.last_eval = point.epoch;
        let due = self.save_every > 0
            && point.epoch >= self.last_saved.unwrap_or(0) + self.save_every;
        if due {
            self.save(point.state, &format!("epoch {}", point.epoch))?;
            self.last_saved = Some(point.epoch);
        }
        Ok(())
    }

    fn on_finish(&mut self, result: &mut TrainResult) -> Result<(), String> {
        // the final eval may have just written this exact state
        if self.last_saved == Some(self.last_eval) {
            return Ok(());
        }
        self.save(&result.final_state, "final")
    }
}

/// Runs Minka's fixed-point hyperparameter estimation
/// ([`crate::lda::hyper_opt`]) on the final state, so the returned
/// `final_state.hyper` carries the (α, β) maximum-likelihood estimates.
pub struct HyperOptimizer {
    steps: usize,
    quiet: bool,
    /// the (α, β) estimate after finish (None until then)
    pub estimate: Option<(f64, f64)>,
}

impl HyperOptimizer {
    pub fn new(steps: usize, quiet: bool) -> Self {
        HyperOptimizer { steps, quiet, estimate: None }
    }
}

impl TrainObserver for HyperOptimizer {
    fn on_finish(&mut self, result: &mut TrainResult) -> Result<(), String> {
        let (alpha, beta) = lda::hyper_opt::optimize(&mut result.final_state, self.steps);
        self.estimate = Some((alpha, beta));
        if !self.quiet {
            crate::log_event!(
                Info,
                "hyper-opt",
                "{} steps: alpha={alpha:.4} beta={beta:.4}",
                self.steps
            );
        }
        Ok(())
    }
}
