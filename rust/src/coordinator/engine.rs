//! The typed engine API every training runtime plugs into.
//!
//! [`TrainEngine`] is the contract between the single driver loop in
//! [`super::train_with`] and the six execution backends (serial sweeps,
//! threaded Nomad — which also drives mixed local/remote rings over TCP
//! via `TrainConfig::remote` — threaded parameter server, bulk-synchronous
//! AD-LDA, and the two virtual-time simulators).  A new runtime implements
//! this trait and the whole coordinator surface (observers, checkpoints,
//! CSV series, CLI) comes for free.
//!
//! All engines are built from an explicit initial [`LdaState`]
//! ([`make_engine`]), which is how `--resume` works uniformly: the driver
//! loads a checkpoint (or random-inits) once and every runtime starts from
//! those assignments.
//!
//! The nomad engine has a second construction path the driver chooses
//! when `checkpoint_dir` is set: [`crate::resilience::Supervisor`] wraps
//! the same ring behind this trait but drives the fallible
//! `try_run_epoch` / `try_gather_state` twins, restarting from the latest
//! valid snapshot instead of panicking when the ring fails.

use crate::adlda::{AdLda, AdLdaConfig};
use crate::corpus::Corpus;
use crate::lda::{AliasLda, FLdaDoc, FLdaWord, LdaState, PlainLda, SparseLda, Sweep};
use crate::nomad::{NomadConfig, NomadRuntime};
use crate::ps::{PsConfig, PsRuntime};
use crate::simnet::nomad_sim::{NomadSim, NomadSimConfig};
use crate::simnet::ps_sim::{PsSim, PsSimConfig};
use crate::simnet::{ClusterSpec, CostModel};
use crate::util::metrics::Stopwatch;
use crate::util::rng::Pcg32;

use super::{RuntimeKind, SamplerKind, TrainConfig};

/// How an engine measures time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Clock {
    /// real elapsed seconds; the driver accumulates per-epoch `secs`
    Wall,
    /// discrete-event virtual time; carries the current clock reading in
    /// seconds since construction
    Virtual(f64),
}

/// Per-epoch statistics, uniform across every runtime (the union of the
/// four structs it replaced).
#[derive(Clone, Debug, Default)]
pub struct EpochReport {
    /// tokens resampled this epoch
    pub processed: u64,
    /// epoch duration in this engine's clock (wall or virtual seconds)
    pub secs: f64,
    /// reads served from possibly-stale state: PS cache pulls, AD-LDA
    /// tokens sampled against the frozen snapshot; zero for nomad and
    /// serial, whose word counts are always exact
    pub stale_reads: u64,
    /// coordination messages: token transfers (nomad) or server ops
    /// (parameter server); zero for the uncoordinated runtimes
    pub msgs: u64,
    /// where the epoch's wall time went on the ring — `Some` only for the
    /// nomad runtime, whose coordinator/transport boundary is the one
    /// place the breakdown can be measured without putting clocks in
    /// sampler scope
    pub ring: Option<RingTelemetry>,
}

/// One ring slot's share of an epoch: how long its worker spent sampling
/// versus parked in `recv()` waiting for the ring to hand it a token.
///
/// Times are measured by the worker around its own transport boundary
/// (never inside the sampler) and ride back to the coordinator in the
/// epoch-end `SyncS` fold.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SlotTelemetry {
    /// ring slot index
    pub slot: usize,
    /// seconds spent processing word/global tokens this epoch
    pub sample_secs: f64,
    /// seconds spent blocked on the ring link this epoch
    pub wait_secs: f64,
    /// tokens this worker has processed (cumulative over the run)
    pub processed: u64,
}

/// Epoch wall-time breakdown for the nomad ring, assembled by the
/// coordinator from its own phase clocks plus the per-slot reports.
///
/// The paper's throughput argument is exactly this decomposition: the
/// async ring wins iff `sample_secs` dominates `wait_secs` on every slot
/// and the synchronous tail (`fold`/`set`) stays negligible.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RingTelemetry {
    /// seconds injecting this epoch's word/global tokens into the ring
    pub inject_secs: f64,
    /// seconds from last injection until every token came home
    pub circulate_secs: f64,
    /// seconds folding the `SyncS` replies into the global topic counts
    pub fold_secs: f64,
    /// seconds broadcasting the refreshed counts (`SetS`)
    pub set_secs: f64,
    /// per-hop latency estimate, p50 (µs): token round-trip / hops
    pub hop_p50_us: f64,
    /// per-hop latency estimate, p95 (µs)
    pub hop_p95_us: f64,
    /// per-hop latency estimate, max (µs)
    pub hop_max_us: f64,
    /// one entry per ring slot, in slot order
    pub slots: Vec<SlotTelemetry>,
}

/// A training runtime the generic driver loop can drive.
pub trait TrainEngine {
    /// Run one epoch (one pass over every token) and report it.
    fn run_epoch(&mut self) -> EpochReport;

    /// Assemble the exact global count state (valid at epoch boundaries).
    fn state_snapshot(&mut self, corpus: &Corpus) -> LdaState;

    /// Which clock `EpochReport::secs` (and the LL-vs-time x axis) uses.
    fn clock(&self) -> Clock {
        Clock::Wall
    }

    /// Stop workers / release resources.  Idempotent; engines with `Drop`
    /// shutdown also call it there.
    fn shutdown(&mut self) {}
}

/// Serial Gibbs sweeps behind the engine API.
pub struct SerialEngine<'c> {
    corpus: &'c Corpus,
    state: LdaState,
    sampler: Box<dyn Sweep>,
    rng: Pcg32,
}

impl<'c> SerialEngine<'c> {
    pub fn from_state(
        corpus: &'c Corpus,
        state: LdaState,
        sampler: SamplerKind,
        seed: u64,
    ) -> Self {
        // typed construction: the enum already guarantees a valid variant,
        // so no stringly `lda::by_name` round-trip and no error path
        let sampler: Box<dyn Sweep> = match sampler {
            SamplerKind::Plain => Box::new(PlainLda::new(&state)),
            SamplerKind::Sparse => Box::new(SparseLda::new(&state)),
            SamplerKind::Alias => Box::new(AliasLda::new(&state)),
            SamplerKind::FLdaDoc => Box::new(FLdaDoc::new(&state)),
            SamplerKind::FLdaWord => Box::new(FLdaWord::new(&state, corpus)),
        };
        // sampling draws come from their own stream so they never replay
        // the stream-0 draws that produced the random init
        SerialEngine { corpus, state, sampler, rng: Pcg32::new(seed, 0xD1CE) }
    }
}

impl TrainEngine for SerialEngine<'_> {
    fn run_epoch(&mut self) -> EpochReport {
        let t0 = Stopwatch::new();
        self.sampler.sweep(&mut self.state, self.corpus, &mut self.rng);
        EpochReport {
            processed: self.corpus.num_tokens() as u64,
            secs: t0.secs(),
            ..Default::default()
        }
    }

    fn state_snapshot(&mut self, _corpus: &Corpus) -> LdaState {
        self.state.clone()
    }
}

/// Bulk-synchronous AD-LDA behind the engine API.
pub struct AdLdaEngine<'c> {
    corpus: &'c Corpus,
    inner: AdLda,
}

impl TrainEngine for AdLdaEngine<'_> {
    fn run_epoch(&mut self) -> EpochReport {
        let t0 = Stopwatch::new();
        self.inner.iterate(self.corpus);
        let processed = self.corpus.num_tokens() as u64;
        EpochReport {
            processed,
            secs: t0.secs(),
            // every token is sampled against the iteration-start snapshot
            stale_reads: processed,
            msgs: 0,
            ring: None,
        }
    }

    fn state_snapshot(&mut self, _corpus: &Corpus) -> LdaState {
        self.inner.state.clone()
    }
}

impl TrainEngine for NomadRuntime {
    fn run_epoch(&mut self) -> EpochReport {
        NomadRuntime::run_epoch(self)
    }

    fn state_snapshot(&mut self, corpus: &Corpus) -> LdaState {
        self.gather_state(corpus)
    }

    fn shutdown(&mut self) {
        NomadRuntime::shutdown(self);
    }
}

impl TrainEngine for PsRuntime {
    fn run_epoch(&mut self) -> EpochReport {
        PsRuntime::run_epoch(self)
    }

    fn state_snapshot(&mut self, corpus: &Corpus) -> LdaState {
        self.gather_state(corpus)
    }

    fn shutdown(&mut self) {
        PsRuntime::shutdown(self);
    }
}

impl TrainEngine for NomadSim {
    fn run_epoch(&mut self) -> EpochReport {
        NomadSim::run_epoch(self)
    }

    fn state_snapshot(&mut self, corpus: &Corpus) -> LdaState {
        self.gather_state(corpus)
    }

    fn clock(&self) -> Clock {
        Clock::Virtual(self.vtime_secs())
    }
}

impl TrainEngine for PsSim {
    fn run_epoch(&mut self) -> EpochReport {
        PsSim::run_epoch(self)
    }

    fn state_snapshot(&mut self, corpus: &Corpus) -> LdaState {
        self.gather_state(corpus)
    }

    fn clock(&self) -> Clock {
        Clock::Virtual(self.vtime_secs())
    }
}

/// Simulated cluster shape for the sim runtimes.
fn sim_cluster(cfg: &TrainConfig) -> ClusterSpec {
    if cfg.machines > 1 {
        ClusterSpec { machines: cfg.machines, ..ClusterSpec::cluster(cfg.machines) }
    } else {
        ClusterSpec::multicore(cfg.workers)
    }
}

/// Build the engine `cfg` asks for, starting from `init` (loaded from a
/// checkpoint or random-initialized — the engine does not care which).
/// The topic count and hyperparameters come from `init.hyper`.
pub fn make_engine<'c>(
    corpus: &'c Corpus,
    init: LdaState,
    cfg: &TrainConfig,
) -> Result<Box<dyn TrainEngine + 'c>, String> {
    let hyper = init.hyper;
    Ok(match cfg.runtime {
        RuntimeKind::Serial => {
            Box::new(SerialEngine::from_state(corpus, init, cfg.sampler, cfg.seed))
        }
        RuntimeKind::Nomad => {
            let rt_cfg = NomadConfig {
                workers: cfg.workers,
                seed: cfg.seed,
                remote: cfg.remote.clone(),
            };
            // fallible: remote slots dial out over TCP at construction
            Box::new(NomadRuntime::try_from_state(corpus, &init, rt_cfg)?)
        }
        RuntimeKind::Ps => {
            let rt_cfg = PsConfig {
                workers: cfg.workers,
                seed: cfg.seed,
                batch_docs: cfg.batch_docs,
            };
            Box::new(PsRuntime::from_state(corpus, &init, rt_cfg))
        }
        RuntimeKind::AdLda => {
            let rt_cfg = AdLdaConfig { workers: cfg.workers, seed: cfg.seed };
            let inner = AdLda::from_state(corpus, init, rt_cfg);
            Box::new(AdLdaEngine { corpus, inner })
        }
        RuntimeKind::NomadSim => {
            let mut sim_cfg = NomadSimConfig::new(sim_cluster(cfg), hyper.t);
            sim_cfg.seed = cfg.seed;
            sim_cfg.cost = CostModel::default_for(hyper.t);
            Box::new(NomadSim::from_state(corpus, &init, sim_cfg))
        }
        RuntimeKind::PsSim => {
            let mut sim_cfg = PsSimConfig::new(sim_cluster(cfg), hyper.t);
            sim_cfg.seed = cfg.seed;
            sim_cfg.batch_docs = cfg.batch_docs;
            sim_cfg.disk = cfg.disk;
            sim_cfg.cost = CostModel::default_for(hyper.t);
            Box::new(PsSim::from_state(corpus, &init, sim_cfg))
        }
    })
}
