//! Worker-local state and the token-processing kernel (Algorithm 4 body).
//!
//! A worker owns a contiguous document range: the assignments `z` (one
//! flat per-worker buffer in the corpus's CSR layout, rebased to local
//! offsets), the doc-topic counts `n_td` for those docs, a local copy
//! `s_l` of the topic totals, the snapshot `s̄` from the global token's
//! last visit, and an F+tree over `q_t = (n_tw+β)/(s_l+β̄)` for the word
//! currently being processed.  The same struct runs under real threads
//! ([`super::runtime`]) and under virtual time ([`crate::simnet`]).
//!
//! [`WorkerState::process_word_token`] — the Algorithm-4 inner loop — is
//! **allocation-free**: the occurrence slices, the F+tree, the sparse
//! cumsum `r` and the count rows are all preallocated or owned by the
//! token, so at steady state (after the first pass has settled the
//! `SparseCounts`/`SparseCumSum` capacities) no heap allocation happens
//! per word token (`rust/tests/alloc_free.rs` asserts this with a
//! counting allocator).

use crate::corpus::CorpusSlice;
use crate::lda::state::{local_rows, Hyper, SparseCounts};
use crate::sampler::bsearch::SparseCumSum;
use crate::sampler::ftree::FTree;
use crate::sampler::DiscreteSampler;
use crate::util::rng::Pcg32;

use super::token::{GlobalToken, WordToken};

/// Per-worker occurrence index: word -> (local doc, position) pairs.
#[derive(Clone, Debug)]
pub struct LocalWordIndex {
    doc_of: Vec<u32>,
    pos_of: Vec<u32>,
    offsets: Vec<usize>,
}

impl LocalWordIndex {
    /// Build over a worker's corpus slice.
    pub fn build(slice: &CorpusSlice) -> Self {
        let mut counts = vec![0usize; slice.vocab + 1];
        for &w in &slice.tokens {
            counts[w as usize + 1] += 1;
        }
        for j in 1..counts.len() {
            counts[j] += counts[j - 1];
        }
        let offsets = counts.clone();
        let total = *offsets.last().unwrap();
        let mut doc_of = vec![0u32; total];
        let mut pos_of = vec![0u32; total];
        let mut cursor = offsets.clone();
        for local in 0..slice.num_docs() {
            for (p, &w) in slice.doc(local).iter().enumerate() {
                let at = cursor[w as usize];
                doc_of[at] = local as u32;
                pos_of[at] = p as u32;
                cursor[w as usize] += 1;
            }
        }
        LocalWordIndex { doc_of, pos_of, offsets }
    }

    #[inline]
    pub fn occurrences(&self, word: usize) -> (&[u32], &[u32]) {
        let lo = self.offsets[word];
        let hi = self.offsets[word + 1];
        (&self.doc_of[lo..hi], &self.pos_of[lo..hi])
    }

    pub fn count(&self, word: usize) -> usize {
        self.offsets[word + 1] - self.offsets[word]
    }
}

/// All state owned by one nomad worker.
pub struct WorkerState {
    pub id: usize,
    pub num_workers: usize,
    pub hyper: Hyper,
    pub vocab: usize,
    /// global doc id of local doc 0
    pub start_doc: usize,
    /// flat assignments for the local docs (CSR payload)
    pub z: Vec<u16>,
    /// local CSR offsets: local doc d is `z[z_offsets[d]..z_offsets[d+1]]`
    pub z_offsets: Vec<usize>,
    /// n_td for the local docs
    pub ntd: Vec<SparseCounts>,
    /// local topic totals s_l (authoritative for this worker's sampling)
    pub s_local: Vec<i64>,
    /// snapshot s̄ from the global token's last visit
    pub s_snap: Vec<i64>,
    /// F+tree over the current word's q (base = β/(s_l+β̄) elsewhere)
    tree: FTree,
    r: SparseCumSum,
    index: LocalWordIndex,
    pub rng: Pcg32,
    /// tokens resampled since start (throughput metric)
    pub processed: u64,
}

impl WorkerState {
    /// Initialize from a worker's corpus slice with the given initial
    /// assignments (the flat z rows for its docs, in CSR order) and the
    /// *global* initial topic totals.
    pub fn new(
        id: usize,
        num_workers: usize,
        slice: &CorpusSlice,
        hyper: Hyper,
        z: Vec<u16>,
        s_init: Vec<i64>,
        rng: Pcg32,
    ) -> Self {
        let (z_offsets, ntd) = local_rows(slice, &z, hyper.t);
        let t = hyper.t;
        let mut w = WorkerState {
            id,
            num_workers,
            hyper,
            vocab: slice.vocab,
            start_doc: slice.start_doc,
            z,
            z_offsets,
            ntd,
            s_local: s_init.clone(),
            s_snap: s_init,
            tree: FTree::with_capacity(&vec![0.0; t], t),
            r: SparseCumSum::with_capacity(64),
            index: LocalWordIndex::build(slice),
            rng,
            processed: 0,
        };
        w.rebuild_tree();
        w
    }

    /// Rebuild the F+tree to the base value β/(s_l+β̄) for every topic.
    pub fn rebuild_tree(&mut self) {
        let bb = self.hyper.betabar(self.vocab);
        let beta = self.hyper.beta;
        let base: Vec<f64> = self
            .s_local
            .iter()
            .map(|&n| beta / (n.max(0) as f64 + bb))
            .collect();
        self.tree.refill(&base);
    }

    /// Execute subtask `t_j` on this worker: resample every local
    /// occurrence of the token's word.  The token's count row is the
    /// authoritative n_wt and is updated in place.  Returns the number of
    /// occurrences processed.
    ///
    /// Zero-allocation: the borrow is split across `WorkerState` fields so
    /// the occurrence slices are read straight out of the index while the
    /// tree / counts / z are mutated — no `to_vec` copies, no collected
    /// support vectors.
    pub fn process_word_token(&mut self, tok: &mut WordToken) -> usize {
        let word = tok.word as usize;
        let alpha = self.hyper.alpha;
        let beta = self.hyper.beta;
        let bb = self.hyper.betabar(self.vocab);
        let WorkerState { z, z_offsets, ntd, s_local, tree, r, index, rng, .. } = self;
        let (docs, poss) = index.occurrences(word);
        if docs.is_empty() {
            return 0;
        }

        // raise the tree on the word's support
        for (t, c) in tok.counts.iter() {
            let v = (c as f64 + beta) / (s_local[t as usize].max(0) as f64 + bb);
            tree.set(t as usize, v);
        }

        for (&doc, &pos) in docs.iter().zip(poss) {
            let (doc, pos) = (doc as usize, pos as usize);
            let zi = z_offsets[doc] + pos;
            let old = z[zi];
            // remove from the three aggregates (ntd local, row in token,
            // totals in s_l)
            ntd[doc].dec(old);
            tok.counts.dec(old);
            s_local[old as usize] -= 1;
            let v = (tok.counts.get(old) as f64 + beta)
                / (s_local[old as usize].max(0) as f64 + bb);
            tree.set(old as usize, v);

            // sparse r over the doc's support
            r.clear();
            for (t, c) in ntd[doc].iter() {
                r.push(t as u32, c as f64 * tree.leaf(t as usize));
            }
            let r_total = r.total();

            let u = rng.uniform(alpha * tree.total() + r_total);
            let new = if u < r_total {
                r.sample(u) as u16
            } else {
                tree.sample((u - r_total) / alpha) as u16
            };

            ntd[doc].inc(new);
            tok.counts.inc(new);
            s_local[new as usize] += 1;
            let v = (tok.counts.get(new) as f64 + beta)
                / (s_local[new as usize].max(0) as f64 + bb);
            tree.set(new as usize, v);
            z[zi] = new;
        }

        // lower back to base on the final support
        for (t, _) in tok.counts.iter() {
            tree.set(
                t as usize,
                beta / (s_local[t as usize].max(0) as f64 + bb),
            );
        }
        let n = docs.len();
        self.processed += n as u64;
        n
    }

    /// τ_s arrival (Algorithm 4): fold local effort into the token,
    /// refresh both local copies, rebuild the tree base.
    pub fn process_global_token(&mut self, tok: &mut GlobalToken) {
        for t in 0..self.hyper.t {
            tok.s[t] += self.s_local[t] - self.s_snap[t];
        }
        self.s_local.copy_from_slice(&tok.s);
        self.s_snap.copy_from_slice(&tok.s);
        self.rebuild_tree();
    }

    /// Epoch-boundary fold: return `s_l − s̄` and advance the snapshot.
    pub fn take_s_delta(&mut self) -> Vec<i64> {
        let delta: Vec<i64> = self
            .s_local
            .iter()
            .zip(&self.s_snap)
            .map(|(&l, &s)| l - s)
            .collect();
        self.s_snap.copy_from_slice(&self.s_local);
        delta
    }

    /// Epoch-boundary adopt: set both copies to the reduced totals.
    pub fn set_s(&mut self, s: &[i64]) {
        self.s_local.copy_from_slice(s);
        self.s_snap.copy_from_slice(s);
        self.rebuild_tree();
    }

    /// Number of local occurrences of `word` (DES cost model input).
    pub fn occurrence_count(&self, word: usize) -> usize {
        self.index.count(word)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;
    use crate::corpus::Corpus;

    fn setup() -> (Corpus, WorkerState, Vec<WordToken>) {
        let corpus = preset("tiny").unwrap();
        let hyper = Hyper::paper_default(8);
        let mut rng = Pcg32::seeded(1);
        // single worker owning everything
        let slice = corpus.read_range(0, corpus.num_docs());
        let mut z = Vec::with_capacity(corpus.num_tokens());
        let mut nwt = vec![SparseCounts::default(); corpus.vocab()];
        let mut s = vec![0i64; hyper.t];
        for &w in &slice.tokens {
            let topic = rng.below(hyper.t) as u16;
            nwt[w as usize].inc(topic);
            s[topic as usize] += 1;
            z.push(topic);
        }
        let worker = WorkerState::new(0, 1, &slice, hyper, z, s, Pcg32::seeded(2));
        let tokens: Vec<WordToken> = nwt
            .into_iter()
            .enumerate()
            .map(|(w, c)| WordToken::new(w as u32, c))
            .collect();
        (corpus, worker, tokens)
    }

    #[test]
    fn word_token_processing_preserves_mass() {
        let (_corpus, mut w, mut tokens) = setup();
        let total_before: i64 = w.s_local.iter().sum();
        let mut processed = 0;
        for tok in &mut tokens {
            processed += w.process_word_token(tok);
        }
        assert_eq!(processed as i64, total_before);
        let total_after: i64 = w.s_local.iter().sum();
        assert_eq!(total_before, total_after);
        // token rows still sum to the totals
        let mut from_tokens = vec![0i64; 8];
        for tok in &tokens {
            for (t, c) in tok.counts.iter() {
                from_tokens[t as usize] += c as i64;
            }
        }
        assert_eq!(from_tokens, w.s_local);
    }

    #[test]
    fn local_offsets_mirror_corpus_rows() {
        let (corpus, w, _tokens) = setup();
        assert_eq!(w.z_offsets.as_slice(), corpus.offsets());
        assert_eq!(w.z.len(), corpus.num_tokens());
        // ntd rows rebuilt from z rows agree
        for d in 0..corpus.num_docs() {
            let row = &w.z[w.z_offsets[d]..w.z_offsets[d + 1]];
            let mut counts = SparseCounts::default();
            for &t in row {
                counts.inc(t);
            }
            assert_eq!(&counts, &w.ntd[d], "doc {d}");
        }
    }

    #[test]
    fn global_token_folds_and_resets() {
        let (_corpus, mut w, mut tokens) = setup();
        let mut gt = GlobalToken::new(w.s_local.clone());
        // do some work, then fold
        for tok in tokens.iter_mut().take(10) {
            w.process_word_token(tok);
        }
        let mass_before: i64 = gt.s.iter().sum();
        w.process_global_token(&mut gt);
        // totals mass unchanged (moves between topics only)
        assert_eq!(gt.s.iter().sum::<i64>(), mass_before);
        assert_eq!(w.s_local, gt.s);
        assert_eq!(w.s_snap, gt.s);
        // a second fold with no work in between is a no-op
        let snapshot = gt.s.clone();
        w.process_global_token(&mut gt);
        assert_eq!(gt.s, snapshot);
    }

    #[test]
    fn s_delta_epoch_fold() {
        let (_corpus, mut w, mut tokens) = setup();
        for tok in tokens.iter_mut() {
            w.process_word_token(tok);
        }
        let delta = w.take_s_delta();
        assert_eq!(delta.iter().sum::<i64>(), 0, "mass-conserving delta");
        // snapshot advanced → immediate second delta is zero
        assert!(w.take_s_delta().iter().all(|&d| d == 0));
    }

    #[test]
    fn tree_base_tracks_s_local() {
        let (_corpus, mut w, mut tokens) = setup();
        for tok in tokens.iter_mut() {
            w.process_word_token(tok);
        }
        let bb = w.hyper.betabar(w.vocab);
        for t in 0..8 {
            let want = w.hyper.beta / (w.s_local[t].max(0) as f64 + bb);
            let got = w.tree.leaf(t);
            assert!(
                (got - want).abs() < 1e-12,
                "leaf {t}: {got} vs {want}"
            );
        }
    }
}
