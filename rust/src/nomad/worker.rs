//! Worker-local state and the token-processing kernel (Algorithm 4 body).
//!
//! A worker owns a contiguous document range: the assignments `z`, the
//! doc-topic counts `n_td` for those docs, a local copy `s_l` of the topic
//! totals, the snapshot `s̄` from the global token's last visit, and an
//! F+tree over `q_t = (n_tw+β)/(s_l+β̄)` for the word currently being
//! processed.  The same struct runs under real threads
//! ([`super::runtime`]) and under virtual time ([`crate::simnet`]).

use crate::corpus::Corpus;
use crate::lda::state::{Hyper, SparseCounts};
use crate::sampler::bsearch::SparseCumSum;
use crate::sampler::ftree::FTree;
use crate::sampler::DiscreteSampler;
use crate::util::rng::Pcg32;

use super::token::{GlobalToken, WordToken};

/// Per-worker occurrence index: word -> (local doc, position) pairs.
#[derive(Clone, Debug)]
pub struct LocalWordIndex {
    doc_of: Vec<u32>,
    pos_of: Vec<u32>,
    offsets: Vec<usize>,
}

impl LocalWordIndex {
    /// Build over the worker's doc range [start, end).
    pub fn build(corpus: &Corpus, start: usize, end: usize) -> Self {
        let vocab = corpus.vocab;
        let mut counts = vec![0usize; vocab + 1];
        for doc in &corpus.docs[start..end] {
            for &w in doc {
                counts[w as usize + 1] += 1;
            }
        }
        for j in 1..counts.len() {
            counts[j] += counts[j - 1];
        }
        let offsets = counts.clone();
        let total = *offsets.last().unwrap();
        let mut doc_of = vec![0u32; total];
        let mut pos_of = vec![0u32; total];
        let mut cursor = offsets.clone();
        for (local, doc) in corpus.docs[start..end].iter().enumerate() {
            for (p, &w) in doc.iter().enumerate() {
                let at = cursor[w as usize];
                doc_of[at] = local as u32;
                pos_of[at] = p as u32;
                cursor[w as usize] += 1;
            }
        }
        LocalWordIndex { doc_of, pos_of, offsets }
    }

    #[inline]
    pub fn occurrences(&self, word: usize) -> (&[u32], &[u32]) {
        let lo = self.offsets[word];
        let hi = self.offsets[word + 1];
        (&self.doc_of[lo..hi], &self.pos_of[lo..hi])
    }

    pub fn count(&self, word: usize) -> usize {
        self.offsets[word + 1] - self.offsets[word]
    }
}

/// All state owned by one nomad worker.
pub struct WorkerState {
    pub id: usize,
    pub num_workers: usize,
    pub hyper: Hyper,
    pub vocab: usize,
    /// global doc id of local doc 0
    pub start_doc: usize,
    /// z and n_td for the local docs
    pub z: Vec<Vec<u16>>,
    pub ntd: Vec<SparseCounts>,
    /// local topic totals s_l (authoritative for this worker's sampling)
    pub s_local: Vec<i64>,
    /// snapshot s̄ from the global token's last visit
    pub s_snap: Vec<i64>,
    /// F+tree over the current word's q (base = β/(s_l+β̄) elsewhere)
    tree: FTree,
    r: SparseCumSum,
    index: LocalWordIndex,
    pub rng: Pcg32,
    /// tokens resampled since start (throughput metric)
    pub processed: u64,
}

impl WorkerState {
    /// Initialize from a corpus slice with the given initial assignments
    /// (z rows for [start, end)) and the *global* initial topic totals.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: usize,
        num_workers: usize,
        corpus: &Corpus,
        hyper: Hyper,
        start: usize,
        end: usize,
        z: Vec<Vec<u16>>,
        s_init: Vec<i64>,
        rng: Pcg32,
    ) -> Self {
        assert_eq!(z.len(), end - start);
        let mut ntd = Vec::with_capacity(end - start);
        for zs in &z {
            let mut counts = SparseCounts::with_capacity(zs.len().min(hyper.t));
            for &topic in zs {
                counts.inc(topic);
            }
            ntd.push(counts);
        }
        let t = hyper.t;
        let mut w = WorkerState {
            id,
            num_workers,
            hyper,
            vocab: corpus.vocab,
            start_doc: start,
            z,
            ntd,
            s_local: s_init.clone(),
            s_snap: s_init,
            tree: FTree::with_capacity(&vec![0.0; t], t),
            r: SparseCumSum::with_capacity(64),
            index: LocalWordIndex::build(corpus, start, end),
            rng,
            processed: 0,
        };
        w.rebuild_tree();
        w
    }

    /// Rebuild the F+tree to the base value β/(s_l+β̄) for every topic.
    pub fn rebuild_tree(&mut self) {
        let bb = self.hyper.betabar(self.vocab);
        let beta = self.hyper.beta;
        let base: Vec<f64> = self
            .s_local
            .iter()
            .map(|&n| beta / (n.max(0) as f64 + bb))
            .collect();
        self.tree.refill(&base);
    }

    #[inline]
    fn q_value(&self, counts: &SparseCounts, t: u16) -> f64 {
        let bb = self.hyper.betabar(self.vocab);
        (counts.get(t) as f64 + self.hyper.beta)
            / (self.s_local[t as usize].max(0) as f64 + bb)
    }

    /// Execute subtask `t_j` on this worker: resample every local
    /// occurrence of the token's word.  The token's count row is the
    /// authoritative n_wt and is updated in place.  Returns the number of
    /// occurrences processed.
    pub fn process_word_token(&mut self, tok: &mut WordToken) -> usize {
        let word = tok.word as usize;
        let alpha = self.hyper.alpha;
        let (docs, poss) = {
            let (d, p) = self.index.occurrences(word);
            (d.to_vec(), p.to_vec())
        };
        if docs.is_empty() {
            return 0;
        }

        // raise the tree on the word's support
        let support: Vec<u16> = tok.counts.iter().map(|(t, _)| t).collect();
        for &t in &support {
            let v = self.q_value(&tok.counts, t);
            self.tree.set(t as usize, v);
        }

        for (&doc, &pos) in docs.iter().zip(&poss) {
            let (doc, pos) = (doc as usize, pos as usize);
            let old = self.z[doc][pos];
            // remove from the three aggregates (ntd local, row in token,
            // totals in s_l)
            self.ntd[doc].dec(old);
            tok.counts.dec(old);
            self.s_local[old as usize] -= 1;
            let v = self.q_value(&tok.counts, old);
            self.tree.set(old as usize, v);

            // sparse r over the doc's support
            self.r.clear();
            for (t, c) in self.ntd[doc].iter() {
                self.r.push(t as u32, c as f64 * self.tree.leaf(t as usize));
            }
            let r_total = self.r.total();

            let u = self.rng.uniform(alpha * self.tree.total() + r_total);
            let new = if u < r_total {
                self.r.sample(u) as u16
            } else {
                self.tree.sample((u - r_total) / alpha) as u16
            };

            self.ntd[doc].inc(new);
            tok.counts.inc(new);
            self.s_local[new as usize] += 1;
            let v = self.q_value(&tok.counts, new);
            self.tree.set(new as usize, v);
            self.z[doc][pos] = new;
        }

        // lower back to base on the final support
        let bb = self.hyper.betabar(self.vocab);
        let beta = self.hyper.beta;
        let support: Vec<u16> = tok.counts.iter().map(|(t, _)| t).collect();
        for &t in &support {
            self.tree.set(
                t as usize,
                beta / (self.s_local[t as usize].max(0) as f64 + bb),
            );
        }
        self.processed += docs.len() as u64;
        docs.len()
    }

    /// τ_s arrival (Algorithm 4): fold local effort into the token,
    /// refresh both local copies, rebuild the tree base.
    pub fn process_global_token(&mut self, tok: &mut GlobalToken) {
        for t in 0..self.hyper.t {
            tok.s[t] += self.s_local[t] - self.s_snap[t];
        }
        self.s_local.copy_from_slice(&tok.s);
        self.s_snap.copy_from_slice(&tok.s);
        self.rebuild_tree();
    }

    /// Epoch-boundary fold: return `s_l − s̄` and advance the snapshot.
    pub fn take_s_delta(&mut self) -> Vec<i64> {
        let delta: Vec<i64> = self
            .s_local
            .iter()
            .zip(&self.s_snap)
            .map(|(&l, &s)| l - s)
            .collect();
        self.s_snap.copy_from_slice(&self.s_local);
        delta
    }

    /// Epoch-boundary adopt: set both copies to the reduced totals.
    pub fn set_s(&mut self, s: &[i64]) {
        self.s_local.copy_from_slice(s);
        self.s_snap.copy_from_slice(s);
        self.rebuild_tree();
    }

    /// Number of local occurrences of `word` (DES cost model input).
    pub fn occurrence_count(&self, word: usize) -> usize {
        self.index.count(word)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;

    fn setup() -> (Corpus, WorkerState, Vec<WordToken>) {
        let corpus = preset("tiny").unwrap();
        let hyper = Hyper::paper_default(8);
        let mut rng = Pcg32::seeded(1);
        // single worker owning everything
        let mut z = Vec::new();
        let mut nwt = vec![SparseCounts::default(); corpus.vocab];
        let mut s = vec![0i64; hyper.t];
        for doc in &corpus.docs {
            let zs: Vec<u16> = doc
                .iter()
                .map(|&w| {
                    let topic = rng.below(hyper.t) as u16;
                    nwt[w as usize].inc(topic);
                    s[topic as usize] += 1;
                    topic
                })
                .collect();
            z.push(zs);
        }
        let worker = WorkerState::new(
            0,
            1,
            &corpus,
            hyper,
            0,
            corpus.num_docs(),
            z,
            s,
            Pcg32::seeded(2),
        );
        let tokens: Vec<WordToken> = nwt
            .into_iter()
            .enumerate()
            .map(|(w, c)| WordToken::new(w as u32, c))
            .collect();
        (corpus, worker, tokens)
    }

    #[test]
    fn word_token_processing_preserves_mass() {
        let (_corpus, mut w, mut tokens) = setup();
        let total_before: i64 = w.s_local.iter().sum();
        let mut processed = 0;
        for tok in &mut tokens {
            processed += w.process_word_token(tok);
        }
        assert_eq!(processed as i64, total_before);
        let total_after: i64 = w.s_local.iter().sum();
        assert_eq!(total_before, total_after);
        // token rows still sum to the totals
        let mut from_tokens = vec![0i64; 8];
        for tok in &tokens {
            for (t, c) in tok.counts.iter() {
                from_tokens[t as usize] += c as i64;
            }
        }
        assert_eq!(from_tokens, w.s_local);
    }

    #[test]
    fn global_token_folds_and_resets() {
        let (_corpus, mut w, mut tokens) = setup();
        let mut gt = GlobalToken::new(w.s_local.clone());
        // do some work, then fold
        for tok in tokens.iter_mut().take(10) {
            w.process_word_token(tok);
        }
        let mass_before: i64 = gt.s.iter().sum();
        w.process_global_token(&mut gt);
        // totals mass unchanged (moves between topics only)
        assert_eq!(gt.s.iter().sum::<i64>(), mass_before);
        assert_eq!(w.s_local, gt.s);
        assert_eq!(w.s_snap, gt.s);
        // a second fold with no work in between is a no-op
        let snapshot = gt.s.clone();
        w.process_global_token(&mut gt);
        assert_eq!(gt.s, snapshot);
    }

    #[test]
    fn s_delta_epoch_fold() {
        let (_corpus, mut w, mut tokens) = setup();
        for tok in tokens.iter_mut() {
            w.process_word_token(tok);
        }
        let delta = w.take_s_delta();
        assert_eq!(delta.iter().sum::<i64>(), 0, "mass-conserving delta");
        // snapshot advanced → immediate second delta is zero
        assert!(w.take_s_delta().iter().all(|&d| d == 0));
    }

    #[test]
    fn tree_base_tracks_s_local() {
        let (_corpus, mut w, mut tokens) = setup();
        for tok in tokens.iter_mut() {
            w.process_word_token(tok);
        }
        let bb = w.hyper.betabar(w.vocab);
        for t in 0..8 {
            let want = w.hyper.beta / (w.s_local[t].max(0) as f64 + bb);
            let got = w.tree.leaf(t);
            assert!(
                (got - want).abs() < 1e-12,
                "leaf {t}: {got} vs {want}"
            );
        }
    }
}
