//! Threaded + cross-process Nomad runtime: ring slots are either real
//! `std::thread` workers over mpsc channels or remote `serve-worker`
//! processes over TCP ([`super::net`]); worker l forwards to l+1 mod p.
//!
//! Epoch protocol (measurement boundaries only — *within* an epoch the
//! system is fully asynchronous and lock-free, exactly Algorithm 4):
//!
//! 1. coordinator injects all J word tokens (round-robin) plus the global
//!    token `τ_s`;
//! 2. tokens hop the ring; a word token that has visited all p workers
//!    returns home ([`Reply::WordDone`]); `τ_s` circulates
//!    `S_CIRCULATIONS`× then returns;
//! 3. coordinator sends `SyncS`; workers answer with their unfolded effort
//!    `s_l − s̄`; the exact totals are `token.s + Σ deltas` (the fold
//!    identity of §4.1);
//! 4. coordinator broadcasts `SetS(exact)` — workers refresh `s_l`, `s̄`
//!    and rebuild their F+tree base.
//!
//! The epoch boundary gives the *exact* count state the convergence curves
//! evaluate; the paper measures per-iteration likelihood the same way.
//! The protocol (and every per-slot RNG stream) is identical whether a
//! slot is a thread or a TCP peer, so mixed rings satisfy the same
//! exact-fold invariant.
//!
//! # Failure handling
//!
//! A ring is only as alive as its weakest slot: a panicked thread or a
//! dropped TCP peer strands every in-flight token.  The coordinator
//! therefore never blocks indefinitely — [`NomadRuntime::try_run_epoch`]
//! polls ring health while waiting and turns a dead slot into a
//! descriptive error (joining the dead thread to harvest its panic
//! message; surfacing the socket fault for a remote).  The infallible
//! [`NomadRuntime::run_epoch`] wraps that error in a panic for the
//! `TrainEngine` surface, which is still a clean exit rather than the
//! silent deadlock it replaces.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::engine::{RingTelemetry, SlotTelemetry};
use crate::coordinator::EpochReport;
use crate::corpus::{Corpus, Partition};
use crate::util::metrics::{bucket_percentile_us, LATENCY_BUCKETS};
use crate::lda::state::{assemble_state, checked_totals, Hyper, LdaState, SparseCounts};
use crate::util::rng::Pcg32;

use super::net::{self, RemoteHandle, RingPorts};
use super::token::{GlobalToken, Msg, Reply, WordToken};
use super::transport::{run_worker, ChannelTransport};
use super::wire;
use super::worker::WorkerState;

/// How many full ring circulations `τ_s` makes per epoch.
pub const S_CIRCULATIONS: u32 = 4;

/// Reply-wait slice between ring health checks.
const HEALTH_POLL: Duration = Duration::from_millis(50);

/// How long shutdown waits for the remote teardown cascade before
/// force-closing sockets.
const SHUTDOWN_GRACE: Duration = Duration::from_secs(2);

/// Runtime configuration.
#[derive(Clone, Debug)]
pub struct NomadConfig {
    /// local worker threads (ring slots `0..workers`)
    pub workers: usize,
    pub seed: u64,
    /// `host:port` of `serve-worker` processes joining the ring as slots
    /// `workers..workers+remote.len()`
    pub remote: Vec<String>,
}

impl Default for NomadConfig {
    fn default() -> Self {
        NomadConfig { workers: 2, seed: 0, remote: Vec::new() }
    }
}

/// One ring slot as the coordinator tracks it.
enum Slot {
    /// a local worker thread (`None` once joined)
    Local(Option<JoinHandle<()>>),
    /// a connected `serve-worker` and its relay threads
    Remote(RemoteHandle),
}

/// Coordinator handle for the threaded / mixed-ring runtime.
pub struct NomadRuntime {
    /// ring input per slot; a remote slot's sender feeds its writer relay
    senders: Vec<Sender<Msg>>,
    replies: Receiver<Reply>,
    slots: Vec<Slot>,
    /// socket faults recorded by remote relay threads
    faults: Arc<Mutex<Vec<String>>>,
    /// raised during shutdown so routine disconnects are not faults
    stopping: Arc<AtomicBool>,
    /// word tokens parked at the coordinator between epochs
    home: Vec<WordToken>,
    /// exact global totals between epochs
    s: Vec<i64>,
    /// vocabulary size (token count per epoch)
    num_words: usize,
    hyper: Hyper,
    partition: Partition,
    pub epochs_run: usize,
    prev_processed: u64,
    total_processed: u64,
}

impl NomadRuntime {
    /// Build workers from a random initial state (see [`Self::from_state`]).
    pub fn new(corpus: &Corpus, hyper: Hyper, cfg: NomadConfig) -> Self {
        let mut rng = Pcg32::new(cfg.seed, 0x10AD);
        let state = LdaState::init_random(corpus, hyper, &mut rng);
        Self::from_state(corpus, &state, cfg)
    }

    /// Infallible [`Self::try_from_state`] for in-process rings (where
    /// construction cannot fail); panics on an invalid config or a remote
    /// connection error.
    pub fn from_state(corpus: &Corpus, init: &LdaState, cfg: NomadConfig) -> Self {
        Self::try_from_state(corpus, init, cfg)
            .unwrap_or_else(|e| panic!("nomad ring construction failed: {e}"))
    }

    /// Build the ring from explicit initial assignments (the resume
    /// path): distribute documents over `workers + remote.len()` slots,
    /// spawn local threads, connect remote `serve-worker`s, park all word
    /// tokens at home.
    ///
    /// Slot RNG streams are derived in slot order regardless of where a
    /// slot runs, so a mixed ring replays the same per-slot streams as an
    /// all-threads ring of the same size and seed.
    pub fn try_from_state(
        corpus: &Corpus,
        init: &LdaState,
        cfg: NomadConfig,
    ) -> Result<Self, String> {
        let total = cfg.workers + cfg.remote.len();
        if total == 0 {
            return Err("the nomad ring needs at least one slot (workers or remote)".into());
        }
        // offsets equality (not just doc count): under the flat layout a
        // doc-length mismatch would misindex z silently instead of
        // panicking like the old per-doc rows did
        if init.doc_offsets.as_slice() != corpus.offsets() {
            return Err("init state / corpus mismatch".into());
        }
        let hyper = init.hyper;
        let partition = Partition::by_tokens(corpus, total);
        // worker streams derive from a different stream id than the init
        // draws (0x10AD in `new`), so sampling never replays them
        let mut seed_rng = Pcg32::new(cfg.seed, 0xAD10);

        let s: Vec<i64> = init.nt.iter().map(|&v| v as i64).collect();
        let home: Vec<WordToken> = init
            .nwt
            .iter()
            .cloned()
            .enumerate()
            .map(|(w, counts)| WordToken::new(w as u32, counts))
            .collect();

        let (reply_tx, replies) = channel::<Reply>();
        let mut senders = Vec::with_capacity(total);
        let mut receivers = Vec::with_capacity(total);
        for _ in 0..total {
            let (tx, rx) = channel::<Msg>();
            senders.push(tx);
            receivers.push(rx);
        }
        let faults = Arc::new(Mutex::new(Vec::new()));
        let stopping = Arc::new(AtomicBool::new(false));
        let mut slots = Vec::with_capacity(total);
        for (l, rx) in receivers.into_iter().enumerate() {
            // derived in slot order for every slot kind (see above)
            let rng = seed_rng.split(l as u64 + 1);
            let (start, end) = partition.ranges[l];
            let next = senders[(l + 1) % total].clone();
            let reply = reply_tx.clone();
            if l < cfg.workers {
                // one bulk copy of the worker's contiguous CSR rows (the
                // slice read pulls the docs off disk when out-of-core)
                let z_slice: Vec<u16> = init.z_range(start, end).to_vec();
                let slice = corpus.read_range(start, end);
                let state = WorkerState::new(l, total, &slice, hyper, z_slice, s.clone(), rng);
                let link = ChannelTransport { rx, next, reply };
                // a transport Err is the ring breaking elsewhere; the
                // clean exit is cascade and health checks attribute blame
                // to the original failure (panic / socket fault)
                let handle = std::thread::spawn(move || {
                    let _ = run_worker(state, link);
                });
                slots.push(Slot::Local(Some(handle)));
            } else {
                let addr = &cfg.remote[l - cfg.workers];
                let init_frame = remote_init(corpus, init, &partition, l, total, &s, &rng);
                let ports = RingPorts { inbox: rx, next, reply };
                let connected = net::connect_worker(
                    addr,
                    l,
                    init_frame,
                    ports,
                    Arc::clone(&faults),
                    Arc::clone(&stopping),
                );
                match connected {
                    Ok(handle) => slots.push(Slot::Remote(handle)),
                    Err(e) => {
                        // tear down what already exists; threads unwind on
                        // Stop / socket close without being joined
                        stopping.store(true, Ordering::SeqCst);
                        for tx in &senders {
                            let _ = tx.send(Msg::Stop);
                        }
                        for slot in &slots {
                            if let Slot::Remote(r) = slot {
                                r.force_close();
                            }
                        }
                        return Err(e);
                    }
                }
            }
        }

        let num_words = home.len();
        Ok(NomadRuntime {
            senders,
            replies,
            slots,
            faults,
            stopping,
            home,
            s,
            num_words,
            hyper,
            partition,
            epochs_run: 0,
            prev_processed: 0,
            total_processed: 0,
        })
    }

    /// Number of ring slots (local threads + remote workers).
    pub fn ring_size(&self) -> usize {
        self.slots.len()
    }

    /// Run one fully-asynchronous epoch; returns wall time + throughput.
    ///
    /// Panics with the underlying ring failure if a worker dies
    /// mid-epoch — see [`Self::try_run_epoch`] for the recoverable form.
    pub fn run_epoch(&mut self) -> EpochReport {
        self.try_run_epoch().unwrap_or_else(|e| panic!("nomad ring failure: {e}"))
    }

    /// Run one epoch, surfacing ring failures (a panicked worker thread,
    /// a dropped TCP peer) as a descriptive error instead of blocking on
    /// replies that can never arrive.  After an `Err` the ring is broken
    /// and the runtime is only good for [`Self::shutdown`].
    pub fn try_run_epoch(&mut self) -> Result<EpochReport, String> {
        let p = self.slots.len();
        let t0 = Instant::now();

        // inject word tokens round-robin and the global token
        let tokens: Vec<WordToken> = std::mem::take(&mut self.home);
        for (i, mut tok) in tokens.into_iter().enumerate() {
            tok.hops = 0;
            self.send_ring(i % p, Msg::Word(tok))?;
        }
        self.send_ring(0, Msg::Global(GlobalToken::new(self.s.clone())))?;
        let t_injected = Instant::now();

        // collect everything home (every vocab word has a token, including
        // zero-occurrence ones)
        let expected_words = self.num_words;
        let mut got_words = 0usize;
        let mut global: Option<GlobalToken> = None;
        let mut home = Vec::with_capacity(expected_words);
        // per-hop latency estimate: a token's injection→home transit over
        // its p hops, log₂-bucketed at the coordinator boundary (these
        // clocks never touch sampler scope)
        let mut hop_buckets = [0u64; LATENCY_BUCKETS];
        let mut hop_max_ns = 0u64;
        while got_words < expected_words || global.is_none() {
            match self.recv_reply()? {
                Reply::WordDone(tok) => {
                    let hop_ns = t_injected.elapsed().as_nanos() as u64 / p as u64;
                    hop_buckets[crate::util::metrics::latency_bucket(hop_ns)] += 1;
                    hop_max_ns = hop_max_ns.max(hop_ns);
                    home.push(tok);
                    got_words += 1;
                }
                Reply::GlobalDone(tok) => global = Some(tok),
                other => return Err(format!("unexpected mid-epoch reply: {other:?}")),
            }
        }
        home.sort_by_key(|t| t.word);
        self.home = home;
        let t_circulated = Instant::now();

        // exact fold: s = token.s + Σ_l (s_l − s̄_l)
        let mut s = global.unwrap().s;
        for l in 0..p {
            self.send_ring(l, Msg::SyncS)?;
        }
        let mut processed = 0u64;
        let mut slot_stats: Vec<SlotTelemetry> = Vec::with_capacity(p);
        for _ in 0..p {
            match self.recv_reply()? {
                Reply::SDelta { worker, delta, tokens_processed, sample_ns, wait_ns } => {
                    for (acc, d) in s.iter_mut().zip(delta) {
                        *acc += d;
                    }
                    processed += tokens_processed;
                    slot_stats.push(SlotTelemetry {
                        slot: worker,
                        sample_secs: sample_ns as f64 / 1e9,
                        wait_secs: wait_ns as f64 / 1e9,
                        processed: tokens_processed,
                    });
                }
                other => return Err(format!("expected SDelta, got {other:?}")),
            }
        }
        let t_folded = Instant::now();
        for l in 0..p {
            self.send_ring(l, Msg::SetS(s.clone()))?;
        }
        self.s = s;
        self.epochs_run += 1;
        let delta_processed = processed - self.prev_processed;
        self.prev_processed = processed;
        self.total_processed = processed;
        slot_stats.sort_by_key(|s| s.slot);
        let ring = RingTelemetry {
            inject_secs: (t_injected - t0).as_secs_f64(),
            circulate_secs: (t_circulated - t_injected).as_secs_f64(),
            fold_secs: (t_folded - t_circulated).as_secs_f64(),
            set_secs: t_folded.elapsed().as_secs_f64(),
            hop_p50_us: bucket_percentile_us(&hop_buckets, 50.0).max(0.0),
            hop_p95_us: bucket_percentile_us(&hop_buckets, 95.0).max(0.0),
            hop_max_us: hop_max_ns as f64 / 1e3,
            slots: slot_stats,
        };
        Ok(EpochReport {
            processed: delta_processed,
            secs: t0.elapsed().as_secs_f64(),
            // word counts travel with their token — never stale (§4)
            stale_reads: 0,
            // ring transfers: every word token hops p times, τ_s circulates
            msgs: (self.num_words * p) as u64 + (p as u32 * S_CIRCULATIONS) as u64,
            ring: Some(ring),
        })
    }

    /// Run several epochs back to back.
    pub fn run_epochs(&mut self, n: usize) -> Vec<EpochReport> {
        (0..n).map(|_| self.run_epoch()).collect()
    }

    /// Assemble the exact global [`LdaState`] (epoch boundaries only).
    ///
    /// Panics if the ring is broken or the folded global totals contain a
    /// negative entry — that is count-state corruption, not a value to
    /// clamp away.
    pub fn gather_state(&mut self, corpus: &Corpus) -> LdaState {
        self.try_gather_state(corpus).unwrap_or_else(|e| panic!("nomad ring failure: {e}"))
    }

    /// [`Self::gather_state`] with ring failures surfaced as errors.
    pub fn try_gather_state(&mut self, corpus: &Corpus) -> Result<LdaState, String> {
        // doc-side state from every slot, thread or TCP alike
        let p = self.slots.len();
        for l in 0..p {
            self.send_ring(l, Msg::ReportDocs)?;
        }
        let mut parts = Vec::with_capacity(p);
        for _ in 0..p {
            match self.recv_reply()? {
                Reply::Docs { start_doc, ntd, z, .. } => parts.push((start_doc, ntd, z)),
                other => return Err(format!("expected Docs, got {other:?}")),
            }
        }
        // word-side from the home tokens, totals from the exact fold
        let mut nwt = vec![SparseCounts::default(); corpus.vocab()];
        for tok in &self.home {
            nwt[tok.word as usize] = tok.counts.clone();
        }
        Ok(assemble_state(
            corpus,
            self.hyper,
            parts.iter().map(|(s, n, z)| (*s, n.as_slice(), z.as_slice())),
            nwt,
            checked_totals(&self.s),
        ))
    }

    /// Total tokens resampled since construction.
    pub fn throughput_total(&self) -> u64 {
        self.total_processed
    }

    /// Document partition in use (telemetry).
    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    /// Test hook: push a raw message into ring slot `slot`'s inbox,
    /// bypassing the epoch protocol (simulates a worker dying mid-epoch).
    #[doc(hidden)]
    pub fn inject_raw(&self, slot: usize, msg: Msg) {
        let _ = self.senders[slot].send(msg);
    }

    /// Test hook: kill ring slot `slot` mid-run — a remote slot's socket
    /// is force-closed (a dropped TCP peer), a local slot's inbox is
    /// poisoned with an arity-mismatched `SetS` so its thread panics.
    /// Deterministic stand-in for `kill -9`; used by the resilience
    /// fault plans.
    #[doc(hidden)]
    pub fn kill_slot(&self, slot: usize) {
        match &self.slots[slot] {
            Slot::Remote(remote) => remote.force_close(),
            Slot::Local(_) => self.inject_raw(slot, Msg::SetS(Vec::new())),
        }
    }

    /// Send one ring input, converting a closed inbox into the story of
    /// how that slot died.
    fn send_ring(&mut self, slot: usize, msg: Msg) -> Result<(), String> {
        if self.senders[slot].send(msg).is_ok() {
            return Ok(());
        }
        // the slot's receiving end is gone: harvest why
        if let Slot::Local(handle) = &mut self.slots[slot] {
            if let Some(handle) = handle.take() {
                // the thread dropped its receiver, so it is exiting; join
                // completes promptly and yields any panic payload
                let why = match handle.join() {
                    Err(p) => format!("panicked mid-epoch: {}", panic_message(p.as_ref())),
                    Ok(()) => "exited mid-epoch (ring transport closed)".into(),
                };
                return Err(format!("worker {slot} {why}"));
            }
        }
        Err(self.ring_failure(format!("ring slot {slot} is unreachable")))
    }

    /// Wait for the next reply, polling ring health so a dead slot
    /// surfaces as an error instead of an eternal block.
    fn recv_reply(&mut self) -> Result<Reply, String> {
        loop {
            match self.replies.recv_timeout(HEALTH_POLL) {
                Ok(reply) => return Ok(reply),
                Err(RecvTimeoutError::Timeout) => self.check_ring_health()?,
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(self.ring_failure("every ring worker disconnected".into()));
                }
            }
        }
    }

    /// `Err` with the most specific diagnosis available, falling back to
    /// `fallback` if the failure has not become observable yet.
    fn ring_failure(&mut self, fallback: String) -> String {
        // give a just-dying thread a beat to become joinable / report
        for _ in 0..20 {
            if let Err(e) = self.check_ring_health() {
                return e;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        fallback
    }

    /// Scan for dead slots: join finished local threads (harvesting panic
    /// payloads) and collect socket faults from the remote relays.
    /// Primary causes (panics, socket faults) are listed before the
    /// cascade of clean worker exits they trigger.
    fn check_ring_health(&mut self) -> Result<(), String> {
        let mut panics = Vec::new();
        let mut exits = Vec::new();
        for (l, slot) in self.slots.iter_mut().enumerate() {
            let Slot::Local(handle) = slot else { continue };
            if !handle.as_ref().is_some_and(|h| h.is_finished()) {
                continue;
            }
            match handle.take().unwrap().join() {
                Err(p) => {
                    let why = panic_message(p.as_ref());
                    panics.push(format!("worker {l} panicked mid-epoch: {why}"));
                }
                Ok(()) => {
                    exits.push(format!("worker {l} exited mid-epoch (ring transport closed)"));
                }
            }
        }
        let mut problems = panics;
        problems.extend(self.faults.lock().unwrap().iter().cloned());
        problems.extend(exits);
        if problems.is_empty() {
            Ok(())
        } else {
            Err(problems.join("; "))
        }
    }

    /// Stop all workers: local threads are joined; remote teardown
    /// cascades (writer flushes `Stop`, host closes, reader sees EOF)
    /// with a grace window before sockets are force-closed, so a wedged
    /// peer cannot hang shutdown.
    pub fn shutdown(&mut self) {
        self.stopping.store(true, Ordering::SeqCst);
        for tx in &self.senders {
            let _ = tx.send(Msg::Stop);
        }
        // writer relays exit once every sender to their inbox is gone
        self.senders.clear();
        for slot in &mut self.slots {
            if let Slot::Local(handle) = slot {
                if let Some(handle) = handle.take() {
                    let _ = handle.join();
                }
            }
        }
        let deadline = Instant::now() + SHUTDOWN_GRACE;
        while Instant::now() < deadline && self.any_remote_relay_alive() {
            std::thread::sleep(Duration::from_millis(10));
        }
        for slot in &mut self.slots {
            if let Slot::Remote(remote) = slot {
                remote.force_close();
                remote.join_relays();
            }
        }
    }

    /// True while any remote slot's relay threads are still running.
    fn any_remote_relay_alive(&self) -> bool {
        self.slots.iter().any(|s| matches!(s, Slot::Remote(r) if r.relays_alive()))
    }
}

impl Drop for NomadRuntime {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Render a boxed panic payload (the `&str` / `String` cases std panics
/// produce) for the ring-failure diagnostics.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".into()
    }
}

/// Build the [`wire::Init`] that turns a `serve-worker` into ring slot
/// `l`: its rebased corpus slice, initial assignments, totals, and RNG
/// stream.
fn remote_init(
    corpus: &Corpus,
    init: &LdaState,
    partition: &Partition,
    l: usize,
    total: usize,
    s: &[i64],
    rng: &Pcg32,
) -> wire::Init {
    let (start, end) = partition.ranges[l];
    let slice = corpus.read_range(start, end);
    let (rng_state, rng_inc) = rng.to_parts();
    wire::Init {
        worker_id: l as u32,
        num_workers: total as u32,
        start_doc: start as u64,
        t: init.hyper.t as u32,
        alpha: init.hyper.alpha,
        beta: init.hyper.beta,
        vocab: slice.vocab as u64,
        doc_offsets: slice.offsets.iter().map(|&o| o as u64).collect(),
        tokens: slice.tokens,
        z: init.z_range(start, end).to_vec(),
        s: s.to_vec(),
        rng_state,
        rng_inc,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;

    #[test]
    fn epoch_returns_all_tokens_home() {
        let corpus = preset("tiny").unwrap();
        let mut rt = NomadRuntime::new(&corpus, Hyper::paper_default(8), NomadConfig {
            workers: 2,
            seed: 3,
            ..Default::default()
        });
        assert_eq!(rt.home.len(), corpus.vocab());
        let stats = rt.run_epoch();
        assert_eq!(rt.home.len(), corpus.vocab());
        // each occurrence lives in exactly one worker's partition → every
        // token is resampled exactly once per epoch
        assert_eq!(stats.processed as usize, corpus.num_tokens());
        // the ring breakdown is always collected: one entry per slot in
        // slot order, with the per-worker processed counts covering the
        // corpus and phase times summing to at most the epoch
        let ring = stats.ring.expect("nomad epochs carry ring telemetry");
        assert_eq!(ring.slots.len(), 2);
        assert_eq!(ring.slots[0].slot, 0);
        assert_eq!(ring.slots[1].slot, 1);
        let slot_processed: u64 = ring.slots.iter().map(|s| s.processed).sum();
        assert_eq!(slot_processed as usize, corpus.num_tokens());
        let phases = ring.inject_secs + ring.circulate_secs + ring.fold_secs;
        assert!(phases <= stats.secs + 1e-6, "phases {phases} vs epoch {}", stats.secs);
        assert!(ring.hop_p50_us >= 0.0 && ring.hop_p95_us >= ring.hop_p50_us);
        rt.shutdown();
    }

    #[test]
    fn totals_remain_exact_across_epochs() {
        let corpus = preset("tiny").unwrap();
        let mut rt = NomadRuntime::new(&corpus, Hyper::paper_default(8), NomadConfig {
            workers: 3,
            seed: 4,
            ..Default::default()
        });
        for _ in 0..3 {
            rt.run_epoch();
        }
        let total: i64 = rt.s.iter().sum();
        assert_eq!(total as usize, corpus.num_tokens());
        let state = rt.gather_state(&corpus);
        state.check_consistency(&corpus).unwrap();
        rt.shutdown();
    }

    #[test]
    #[should_panic(expected = "state corruption")]
    fn gather_state_panics_on_negative_total() {
        let corpus = preset("tiny").unwrap();
        let mut rt = NomadRuntime::new(&corpus, Hyper::paper_default(8), NomadConfig {
            workers: 2,
            seed: 6,
            ..Default::default()
        });
        rt.run_epoch();
        // inject corruption: a negative global total must surface loudly,
        // not be clamped to zero
        rt.s[0] = -1;
        let _ = rt.gather_state(&corpus);
    }

    #[test]
    fn single_worker_matches_corpus_mass() {
        let corpus = preset("tiny").unwrap();
        let mut rt = NomadRuntime::new(&corpus, Hyper::paper_default(8), NomadConfig {
            workers: 1,
            seed: 5,
            ..Default::default()
        });
        let stats = rt.run_epoch();
        assert_eq!(stats.processed as usize, corpus.num_tokens());
        rt.shutdown();
    }

    #[test]
    fn zero_slot_ring_is_a_config_error() {
        let corpus = preset("tiny").unwrap();
        let mut rng = Pcg32::seeded(1);
        let init = LdaState::init_random(&corpus, Hyper::paper_default(8), &mut rng);
        let cfg = NomadConfig { workers: 0, seed: 1, remote: Vec::new() };
        let err = NomadRuntime::try_from_state(&corpus, &init, cfg).unwrap_err();
        assert!(err.contains("at least one"), "unhelpful error: {err}");
    }

    /// A worker that panics mid-epoch must surface its panic message
    /// through `try_run_epoch` instead of deadlocking the coordinator in
    /// `replies.recv()` (the bug this PR fixes).
    #[test]
    fn killed_worker_thread_surfaces_error_instead_of_hanging() {
        let corpus = preset("tiny").unwrap();
        let mut rt = NomadRuntime::new(&corpus, Hyper::paper_default(8), NomadConfig {
            workers: 2,
            seed: 7,
            ..Default::default()
        });
        rt.run_epoch(); // healthy baseline
        // poison slot 1: SetS with the wrong arity makes set_s panic,
        // which is exactly a worker dying mid-protocol
        rt.inject_raw(1, Msg::SetS(Vec::new()));
        let err = rt.try_run_epoch().unwrap_err();
        assert!(err.contains("worker 1"), "error must name the dead slot: {err}");
        assert!(err.contains("panicked"), "error must say it panicked: {err}");
        rt.shutdown();
    }
}
