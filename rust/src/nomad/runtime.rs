//! Threaded Nomad runtime: real `std::thread` workers, unbounded mpsc
//! channels, ring routing (worker l forwards to l+1 mod p).
//!
//! Epoch protocol (measurement boundaries only — *within* an epoch the
//! system is fully asynchronous and lock-free, exactly Algorithm 4):
//!
//! 1. coordinator injects all J word tokens (round-robin) plus the global
//!    token `τ_s`;
//! 2. tokens hop the ring; a word token that has visited all p workers
//!    returns home ([`Reply::WordDone`]); `τ_s` circulates
//!    `S_CIRCULATIONS`× then returns;
//! 3. coordinator sends `SyncS`; workers answer with their unfolded effort
//!    `s_l − s̄`; the exact totals are `token.s + Σ deltas` (the fold
//!    identity of §4.1);
//! 4. coordinator broadcasts `SetS(exact)` — workers refresh `s_l`, `s̄`
//!    and rebuild their F+tree base.
//!
//! The epoch boundary gives the *exact* count state the convergence curves
//! evaluate; the paper measures per-iteration likelihood the same way.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use crate::coordinator::EpochReport;
use crate::corpus::{Corpus, Partition};
use crate::lda::state::{assemble_state, checked_totals, Hyper, LdaState, SparseCounts};
use crate::util::rng::Pcg32;

use super::token::{GlobalToken, Msg, Reply, WordToken};
use super::worker::WorkerState;

/// How many full ring circulations `τ_s` makes per epoch.
pub const S_CIRCULATIONS: u32 = 4;

/// Runtime configuration.
#[derive(Clone, Debug)]
pub struct NomadConfig {
    pub workers: usize,
    pub seed: u64,
}

impl Default for NomadConfig {
    fn default() -> Self {
        NomadConfig { workers: 2, seed: 0 }
    }
}

/// Coordinator handle for the threaded runtime.
pub struct NomadRuntime {
    senders: Vec<Sender<Msg>>,
    replies: Receiver<Reply>,
    handles: Vec<JoinHandle<()>>,
    /// word tokens parked at the coordinator between epochs
    home: Vec<WordToken>,
    /// exact global totals between epochs
    s: Vec<i64>,
    /// vocabulary size (token count per epoch)
    num_words: usize,
    hyper: Hyper,
    cfg: NomadConfig,
    partition: Partition,
    pub epochs_run: usize,
    prev_processed: u64,
    total_processed: u64,
}

impl NomadRuntime {
    /// Build workers from a random initial state (see [`Self::from_state`]).
    pub fn new(corpus: &Corpus, hyper: Hyper, cfg: NomadConfig) -> Self {
        let mut rng = Pcg32::new(cfg.seed, 0x10AD);
        let state = LdaState::init_random(corpus, hyper, &mut rng);
        Self::from_state(corpus, &state, cfg)
    }

    /// Build workers from explicit initial assignments (the resume path),
    /// distribute documents, park all word tokens at home.
    pub fn from_state(corpus: &Corpus, init: &LdaState, cfg: NomadConfig) -> Self {
        assert!(cfg.workers >= 1);
        // offsets equality (not just doc count): under the flat layout a
        // doc-length mismatch would misindex z silently instead of
        // panicking like the old per-doc rows did
        assert_eq!(init.doc_offsets, corpus.doc_offsets, "init state / corpus mismatch");
        let hyper = init.hyper;
        let partition = Partition::by_tokens(corpus, cfg.workers);
        // worker streams derive from a different stream id than the init
        // draws (0x10AD in `new`), so sampling never replays them
        let mut seed_rng = Pcg32::new(cfg.seed, 0xAD10);

        let s: Vec<i64> = init.nt.iter().map(|&v| v as i64).collect();
        let home: Vec<WordToken> = init
            .nwt
            .iter()
            .cloned()
            .enumerate()
            .map(|(w, counts)| WordToken::new(w as u32, counts))
            .collect();

        // spawn workers
        let (reply_tx, replies) = channel::<Reply>();
        let mut senders = Vec::with_capacity(cfg.workers);
        let mut receivers = Vec::with_capacity(cfg.workers);
        for _ in 0..cfg.workers {
            let (tx, rx) = channel::<Msg>();
            senders.push(tx);
            receivers.push(rx);
        }
        let mut handles = Vec::with_capacity(cfg.workers);
        for (l, rx) in receivers.into_iter().enumerate() {
            let (start, end) = partition.ranges[l];
            // one bulk copy of the worker's contiguous CSR rows
            let z_slice: Vec<u16> =
                init.z_range(start, end).to_vec();
            let state = WorkerState::new(
                l,
                cfg.workers,
                corpus,
                hyper,
                start,
                end,
                z_slice,
                s.clone(),
                seed_rng.split(l as u64 + 1),
            );
            let next = senders[(l + 1) % cfg.workers].clone();
            let reply = reply_tx.clone();
            handles.push(std::thread::spawn(move || {
                worker_loop(state, rx, next, reply);
            }));
        }

        let num_words = home.len();
        NomadRuntime {
            senders,
            replies,
            handles,
            home,
            s,
            num_words,
            hyper,
            cfg,
            partition,
            epochs_run: 0,
            prev_processed: 0,
            total_processed: 0,
        }
    }

    /// Run one fully-asynchronous epoch; returns wall time + throughput.
    pub fn run_epoch(&mut self) -> EpochReport {
        let p = self.cfg.workers;
        let t0 = std::time::Instant::now();

        // inject word tokens round-robin and the global token
        let tokens: Vec<WordToken> = std::mem::take(&mut self.home);
        for (i, mut tok) in tokens.into_iter().enumerate() {
            tok.hops = 0;
            self.senders[i % p].send(Msg::Word(tok)).expect("worker hung up");
        }
        self.senders[0]
            .send(Msg::Global(GlobalToken::new(self.s.clone())))
            .expect("worker hung up");

        // collect everything home (every vocab word has a token, including
        // zero-occurrence ones)
        let expected_words = self.num_words;
        let mut got_words = 0usize;
        let mut global: Option<GlobalToken> = None;
        let mut home = Vec::with_capacity(expected_words);
        while got_words < expected_words || global.is_none() {
            match self.replies.recv().expect("reply channel closed") {
                Reply::WordDone(tok) => {
                    home.push(tok);
                    got_words += 1;
                }
                Reply::GlobalDone(tok) => global = Some(tok),
                other => panic!("unexpected mid-epoch reply: {other:?}"),
            }
        }
        home.sort_by_key(|t| t.word);
        self.home = home;

        // exact fold: s = token.s + Σ_l (s_l − s̄_l)
        let mut s = global.unwrap().s;
        for tx in &self.senders {
            tx.send(Msg::SyncS).expect("worker hung up");
        }
        let mut processed = 0u64;
        for _ in 0..p {
            match self.replies.recv().expect("reply channel closed") {
                Reply::SDelta { delta, tokens_processed, .. } => {
                    for (acc, d) in s.iter_mut().zip(delta) {
                        *acc += d;
                    }
                    processed += tokens_processed;
                }
                other => panic!("expected SDelta, got {other:?}"),
            }
        }
        for tx in &self.senders {
            tx.send(Msg::SetS(s.clone())).expect("worker hung up");
        }
        self.s = s;
        self.epochs_run += 1;
        let delta_processed = processed - self.prev_processed;
        self.prev_processed = processed;
        self.total_processed = processed;
        EpochReport {
            processed: delta_processed,
            secs: t0.elapsed().as_secs_f64(),
            // word counts travel with their token — never stale (§4)
            stale_reads: 0,
            // ring transfers: every word token hops p times, τ_s circulates
            msgs: (self.num_words * p) as u64 + (p as u32 * S_CIRCULATIONS) as u64,
        }
    }

    /// Run several epochs back to back.
    pub fn run_epochs(&mut self, n: usize) -> Vec<EpochReport> {
        (0..n).map(|_| self.run_epoch()).collect()
    }

    /// Assemble the exact global [`LdaState`] (epoch boundaries only).
    ///
    /// Panics if the folded global totals contain a negative entry — that
    /// is count-state corruption, not a value to clamp away.
    pub fn gather_state(&mut self, corpus: &Corpus) -> LdaState {
        // doc-side state from every worker
        for tx in &self.senders {
            tx.send(Msg::ReportDocs).expect("worker hung up");
        }
        let mut parts = Vec::with_capacity(self.cfg.workers);
        for _ in 0..self.cfg.workers {
            match self.replies.recv().expect("reply channel closed") {
                Reply::Docs { start_doc, ntd, z, .. } => parts.push((start_doc, ntd, z)),
                other => panic!("expected Docs, got {other:?}"),
            }
        }
        // word-side from the home tokens, totals from the exact fold
        let mut nwt = vec![SparseCounts::default(); corpus.vocab];
        for tok in &self.home {
            nwt[tok.word as usize] = tok.counts.clone();
        }
        assemble_state(
            corpus,
            self.hyper,
            parts.iter().map(|(s, n, z)| (*s, n.as_slice(), z.as_slice())),
            nwt,
            checked_totals(&self.s),
        )
    }

    /// Total tokens resampled since construction.
    pub fn throughput_total(&self) -> u64 {
        self.total_processed
    }

    /// Document partition in use (telemetry).
    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    /// Stop all workers and join their threads.
    pub fn shutdown(&mut self) {
        for tx in &self.senders {
            let _ = tx.send(Msg::Stop);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for NomadRuntime {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Worker thread body.
fn worker_loop(
    mut state: WorkerState,
    rx: Receiver<Msg>,
    next: Sender<Msg>,
    reply: Sender<Reply>,
) {
    let p = state.num_workers as u32;
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Word(mut tok) => {
                state.process_word_token(&mut tok);
                tok.hops += 1;
                if tok.hops >= p {
                    let _ = reply.send(Reply::WordDone(tok));
                } else {
                    let _ = next.send(Msg::Word(tok));
                }
            }
            Msg::Global(mut tok) => {
                state.process_global_token(&mut tok);
                tok.hops += 1;
                if tok.hops >= p * S_CIRCULATIONS {
                    let _ = reply.send(Reply::GlobalDone(tok));
                } else {
                    let _ = next.send(Msg::Global(tok));
                }
            }
            Msg::SyncS => {
                let delta = state.take_s_delta();
                let _ = reply.send(Reply::SDelta {
                    worker: state.id,
                    delta,
                    tokens_processed: state.processed,
                });
            }
            Msg::SetS(s) => state.set_s(&s),
            Msg::ReportDocs => {
                // z is already flat — one bulk clone, no per-doc Vecs
                let _ = reply.send(Reply::Docs {
                    worker: state.id,
                    start_doc: state.start_doc,
                    ntd: state.ntd.clone(),
                    z: state.z.clone(),
                });
            }
            Msg::Stop => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::presets::preset;

    #[test]
    fn epoch_returns_all_tokens_home() {
        let corpus = preset("tiny").unwrap();
        let mut rt = NomadRuntime::new(&corpus, Hyper::paper_default(8), NomadConfig {
            workers: 2,
            seed: 3,
        });
        assert_eq!(rt.home.len(), corpus.vocab);
        let stats = rt.run_epoch();
        assert_eq!(rt.home.len(), corpus.vocab);
        // each occurrence lives in exactly one worker's partition → every
        // token is resampled exactly once per epoch
        assert_eq!(stats.processed as usize, corpus.num_tokens());
        rt.shutdown();
    }

    #[test]
    fn totals_remain_exact_across_epochs() {
        let corpus = preset("tiny").unwrap();
        let mut rt = NomadRuntime::new(&corpus, Hyper::paper_default(8), NomadConfig {
            workers: 3,
            seed: 4,
        });
        for _ in 0..3 {
            rt.run_epoch();
        }
        let total: i64 = rt.s.iter().sum();
        assert_eq!(total as usize, corpus.num_tokens());
        let state = rt.gather_state(&corpus);
        state.check_consistency(&corpus).unwrap();
        rt.shutdown();
    }

    #[test]
    #[should_panic(expected = "state corruption")]
    fn gather_state_panics_on_negative_total() {
        let corpus = preset("tiny").unwrap();
        let mut rt = NomadRuntime::new(&corpus, Hyper::paper_default(8), NomadConfig {
            workers: 2,
            seed: 6,
        });
        rt.run_epoch();
        // inject corruption: a negative global total must surface loudly,
        // not be clamped to zero
        rt.s[0] = -1;
        let _ = rt.gather_state(&corpus);
    }

    #[test]
    fn single_worker_matches_corpus_mass() {
        let corpus = preset("tiny").unwrap();
        let mut rt = NomadRuntime::new(&corpus, Hyper::paper_default(8), NomadConfig {
            workers: 1,
            seed: 5,
        });
        let stats = rt.run_epoch();
        assert_eq!(stats.processed as usize, corpus.num_tokens());
        rt.shutdown();
    }
}
