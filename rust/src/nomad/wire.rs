//! Compact binary wire format for the cross-process nomad ring.
//!
//! Every object that crosses a transport boundary — [`Msg`], [`Reply`],
//! the [`WordToken`]/[`GlobalToken`] payloads and their [`SparseCounts`]
//! rows, and the session-opening [`Init`] — encodes to a self-describing
//! tagged byte body ([`encode_frame`]) that [`decode_frame`] parses back.
//! The framing layer (`net`) length-prefixes these bodies on the socket.
//!
//! Design rules:
//!
//! * **little-endian, fixed-width** integers, `f64` as IEEE bits — the
//!   same conventions as the FNLDA001 checkpoint format;
//! * **decode never panics**: every length is bounds-checked against the
//!   remaining buffer *before* allocation, sparse rows are validated
//!   (strictly increasing topics, nonzero counts) through
//!   [`SparseCounts::from_sorted_pairs`], and trailing bytes are an
//!   error.  A malformed frame is a `Err(String)`, not UB or an abort;
//! * **exact roundtrip**: `decode(encode(x)) == x` for every frame,
//!   including token `hops` and count totals (property-tested below).

use crate::lda::SparseCounts;
use crate::util::codec::{put_bytes, put_f64, put_i64, put_u16, put_u32, put_u64, Cur};

use super::token::{GlobalToken, Msg, Reply, WordToken};

/// One unit of conversation between the coordinator and a remote worker.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// coordinator → worker: open a session (corpus slice + initial state)
    Init(Box<Init>),
    /// worker → coordinator: session accepted, ring input may flow
    InitOk,
    /// coordinator → worker: ring input (a token or an epoch-boundary op)
    Ring(Msg),
    /// worker → coordinator: pass this message to my successor slot
    Forward(Msg),
    /// worker → coordinator: a [`Reply`] for the epoch protocol
    Reply(Reply),
    /// either direction: the session is broken; human-readable reason
    Err(String),
    /// coordinator → worker: liveness probe (recovery asks "are you
    /// still there?" without opening a session)
    Ping,
    /// worker → coordinator: answer to [`Frame::Ping`], sent before the
    /// handshake so probing is cheap and never spawns a worker
    Pong,
}

/// Everything a remote worker needs to become ring slot `worker_id`: its
/// corpus slice (rebased CSR), initial assignments, global totals, and the
/// exact RNG stream its in-process twin would have used.
#[derive(Clone, Debug, PartialEq)]
pub struct Init {
    pub worker_id: u32,
    pub num_workers: u32,
    /// global doc id of the slice's first document (for `Reply::Docs`)
    pub start_doc: u64,
    pub t: u32,
    pub alpha: f64,
    pub beta: f64,
    pub vocab: u64,
    /// rebased CSR offsets of the slice (first entry 0)
    pub doc_offsets: Vec<u64>,
    /// the slice's token payload
    pub tokens: Vec<u32>,
    /// initial assignments for the slice (mirrors `tokens`)
    pub z: Vec<u16>,
    /// initial global topic totals
    pub s: Vec<i64>,
    /// worker RNG stream, from [`crate::util::rng::Pcg32::to_parts`]
    pub rng_state: u64,
    pub rng_inc: u64,
}

/// Magic at the head of every `Init` body ("FNMD"): distinguishes a
/// version-skewed or foreign peer from random line noise.
const INIT_MAGIC: u32 = 0x464E_4D44;

/// Wire protocol version, checked during the `Init` handshake.  Bump on
/// ANY change to frame layouts or protocol semantics the two sides must
/// agree on (e.g. [`super::runtime::S_CIRCULATIONS`]), so coordinator /
/// `serve-worker` binary skew is a named error, not a confusing decode
/// failure or a silent divergence.  v3: `SDelta` carries the per-slot
/// `sample_ns`/`wait_ns` telemetry split.
pub const WIRE_VERSION: u32 = 3;

const TAG_INIT: u8 = 1;
const TAG_INIT_OK: u8 = 2;
const TAG_RING: u8 = 3;
const TAG_FORWARD: u8 = 4;
const TAG_REPLY: u8 = 5;
const TAG_ERR: u8 = 6;
const TAG_PING: u8 = 7;
const TAG_PONG: u8 = 8;

const MSG_WORD: u8 = 1;
const MSG_GLOBAL: u8 = 2;
const MSG_SYNC_S: u8 = 3;
const MSG_SET_S: u8 = 4;
const MSG_REPORT_DOCS: u8 = 5;
const MSG_STOP: u8 = 6;

const REPLY_WORD_DONE: u8 = 1;
const REPLY_GLOBAL_DONE: u8 = 2;
const REPLY_S_DELTA: u8 = 3;
const REPLY_DOCS: u8 = 4;

// ---------------------------------------------------------------- encode
// (generic put_* writers live in util::codec; only the domain layouts
// are defined here)

fn put_word_token(out: &mut Vec<u8>, tok: &WordToken) {
    put_u32(out, tok.word);
    put_u32(out, tok.hops);
    tok.counts.encode(out);
}

fn put_global_token(out: &mut Vec<u8>, tok: &GlobalToken) {
    put_u32(out, tok.hops);
    put_i64s(out, &tok.s);
}

fn put_i64s(out: &mut Vec<u8>, s: &[i64]) {
    put_u32(out, s.len() as u32);
    for &v in s {
        put_i64(out, v);
    }
}

fn put_msg(out: &mut Vec<u8>, msg: &Msg) {
    match msg {
        Msg::Word(tok) => {
            out.push(MSG_WORD);
            put_word_token(out, tok);
        }
        Msg::Global(tok) => {
            out.push(MSG_GLOBAL);
            put_global_token(out, tok);
        }
        Msg::SyncS => out.push(MSG_SYNC_S),
        Msg::SetS(s) => {
            out.push(MSG_SET_S);
            put_i64s(out, s);
        }
        Msg::ReportDocs => out.push(MSG_REPORT_DOCS),
        Msg::Stop => out.push(MSG_STOP),
    }
}

fn put_reply(out: &mut Vec<u8>, reply: &Reply) {
    match reply {
        Reply::WordDone(tok) => {
            out.push(REPLY_WORD_DONE);
            put_word_token(out, tok);
        }
        Reply::GlobalDone(tok) => {
            out.push(REPLY_GLOBAL_DONE);
            put_global_token(out, tok);
        }
        Reply::SDelta { worker, delta, tokens_processed, sample_ns, wait_ns } => {
            out.push(REPLY_S_DELTA);
            put_u32(out, *worker as u32);
            put_i64s(out, delta);
            put_u64(out, *tokens_processed);
            put_u64(out, *sample_ns);
            put_u64(out, *wait_ns);
        }
        Reply::Docs { worker, start_doc, ntd, z } => {
            out.push(REPLY_DOCS);
            put_u32(out, *worker as u32);
            put_u64(out, *start_doc as u64);
            put_u32(out, ntd.len() as u32);
            for row in ntd {
                row.encode(out);
            }
            put_u32(out, z.len() as u32);
            for &v in z {
                put_u16(out, v);
            }
        }
    }
}

fn put_init(out: &mut Vec<u8>, init: &Init) {
    put_u32(out, INIT_MAGIC);
    put_u32(out, WIRE_VERSION);
    put_u32(out, init.worker_id);
    put_u32(out, init.num_workers);
    put_u64(out, init.start_doc);
    put_u32(out, init.t);
    put_f64(out, init.alpha);
    put_f64(out, init.beta);
    put_u64(out, init.vocab);
    put_u32(out, init.doc_offsets.len() as u32);
    for &o in &init.doc_offsets {
        put_u64(out, o);
    }
    put_u32(out, init.tokens.len() as u32);
    for &w in &init.tokens {
        put_u32(out, w);
    }
    put_u32(out, init.z.len() as u32);
    for &z in &init.z {
        put_u16(out, z);
    }
    put_i64s(out, &init.s);
    put_u64(out, init.rng_state);
    put_u64(out, init.rng_inc);
}

/// Serialize a frame to its tagged byte body (no length prefix — that is
/// the transport's job).
pub fn encode_frame(frame: &Frame) -> Vec<u8> {
    let mut out = Vec::new();
    match frame {
        Frame::Init(init) => {
            out.push(TAG_INIT);
            put_init(&mut out, init);
        }
        Frame::InitOk => out.push(TAG_INIT_OK),
        Frame::Ring(msg) => {
            out.push(TAG_RING);
            put_msg(&mut out, msg);
        }
        Frame::Forward(msg) => {
            out.push(TAG_FORWARD);
            put_msg(&mut out, msg);
        }
        Frame::Reply(reply) => {
            out.push(TAG_REPLY);
            put_reply(&mut out, reply);
        }
        Frame::Err(msg) => {
            out.push(TAG_ERR);
            put_bytes(&mut out, msg.as_bytes());
        }
        Frame::Ping => out.push(TAG_PING),
        Frame::Pong => out.push(TAG_PONG),
    }
    out
}

// ---------------------------------------------------------------- decode
// (the bounds-checked reader lives in util::codec; the functions below
// parse the domain layouts out of it)

fn get_word_token(cur: &mut Cur) -> Result<WordToken, String> {
    let word = cur.u32()?;
    let hops = cur.u32()?;
    let counts = SparseCounts::decode(cur)?;
    Ok(WordToken { word, counts, hops })
}

fn get_global_token(cur: &mut Cur) -> Result<GlobalToken, String> {
    let hops = cur.u32()?;
    let s = get_i64s(cur)?;
    Ok(GlobalToken { s, hops })
}

fn get_i64s(cur: &mut Cur) -> Result<Vec<i64>, String> {
    let n = cur.len(8)?;
    (0..n).map(|_| cur.i64()).collect()
}

fn get_u16s(cur: &mut Cur) -> Result<Vec<u16>, String> {
    let n = cur.len(2)?;
    (0..n).map(|_| cur.u16()).collect()
}

fn get_msg(cur: &mut Cur) -> Result<Msg, String> {
    Ok(match cur.u8()? {
        MSG_WORD => Msg::Word(get_word_token(cur)?),
        MSG_GLOBAL => Msg::Global(get_global_token(cur)?),
        MSG_SYNC_S => Msg::SyncS,
        MSG_SET_S => Msg::SetS(get_i64s(cur)?),
        MSG_REPORT_DOCS => Msg::ReportDocs,
        MSG_STOP => Msg::Stop,
        tag => return Err(format!("unknown msg tag {tag}")),
    })
}

fn get_reply(cur: &mut Cur) -> Result<Reply, String> {
    Ok(match cur.u8()? {
        REPLY_WORD_DONE => Reply::WordDone(get_word_token(cur)?),
        REPLY_GLOBAL_DONE => Reply::GlobalDone(get_global_token(cur)?),
        REPLY_S_DELTA => Reply::SDelta {
            worker: cur.u32()? as usize,
            delta: get_i64s(cur)?,
            tokens_processed: cur.u64()?,
            sample_ns: cur.u64()?,
            wait_ns: cur.u64()?,
        },
        REPLY_DOCS => {
            let worker = cur.u32()? as usize;
            let start_doc = cur.u64()? as usize;
            // ntd rows are variable-width, so the byte pre-check uses
            // the 4-byte-per-row floor (an empty row's length field)
            let rows = cur.len(4)?;
            let mut ntd = Vec::with_capacity(rows);
            for _ in 0..rows {
                ntd.push(SparseCounts::decode(cur)?);
            }
            let z = get_u16s(cur)?;
            Reply::Docs { worker, start_doc, ntd, z }
        }
        tag => return Err(format!("unknown reply tag {tag}")),
    })
}

fn get_init(cur: &mut Cur) -> Result<Init, String> {
    let magic = cur.u32()?;
    if magic != INIT_MAGIC {
        return Err(format!("bad Init magic {magic:#010x}: not an fnomad wire peer"));
    }
    let version = cur.u32()?;
    if version != WIRE_VERSION {
        return Err(format!(
            "protocol version mismatch: peer speaks wire v{version}, this binary \
             speaks v{WIRE_VERSION} — rebuild both sides from the same commit"
        ));
    }
    let worker_id = cur.u32()?;
    let num_workers = cur.u32()?;
    let start_doc = cur.u64()?;
    let t = cur.u32()?;
    let alpha = cur.f64()?;
    let beta = cur.f64()?;
    let vocab = cur.u64()?;
    let n_off = cur.len(8)?;
    let doc_offsets = (0..n_off).map(|_| cur.u64()).collect::<Result<_, _>>()?;
    let n_tok = cur.len(4)?;
    let tokens = (0..n_tok).map(|_| cur.u32()).collect::<Result<_, _>>()?;
    let z = get_u16s(cur)?;
    let s = get_i64s(cur)?;
    let rng_state = cur.u64()?;
    let rng_inc = cur.u64()?;
    Ok(Init {
        worker_id,
        num_workers,
        start_doc,
        t,
        alpha,
        beta,
        vocab,
        doc_offsets,
        tokens,
        z,
        s,
        rng_state,
        rng_inc,
    })
}

/// Parse a frame body produced by [`encode_frame`].  Errors (never
/// panics) on unknown tags, truncation, oversized lengths, invalid
/// sparse rows, and trailing bytes.
pub fn decode_frame(buf: &[u8]) -> Result<Frame, String> {
    let mut cur = Cur::new(buf);
    let frame = match cur.u8().map_err(|_| "empty frame".to_string())? {
        TAG_INIT => Frame::Init(Box::new(get_init(&mut cur)?)),
        TAG_INIT_OK => Frame::InitOk,
        TAG_RING => Frame::Ring(get_msg(&mut cur)?),
        TAG_FORWARD => Frame::Forward(get_msg(&mut cur)?),
        TAG_REPLY => Frame::Reply(get_reply(&mut cur)?),
        TAG_ERR => Frame::Err(cur.string()?),
        TAG_PING => Frame::Ping,
        TAG_PONG => Frame::Pong,
        tag => return Err(format!("unknown frame tag {tag}")),
    };
    cur.finish()?;
    Ok(frame)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::check;
    use crate::util::rng::Pcg32;

    fn roundtrip(frame: &Frame) -> Frame {
        decode_frame(&encode_frame(frame)).expect("roundtrip decode failed")
    }

    /// Random sparse row with the given support size over a 64-topic
    /// space (sorted by construction via inc).
    fn random_counts(rng: &mut Pcg32, support: usize) -> SparseCounts {
        let mut c = SparseCounts::default();
        let mut placed = 0;
        while placed < support {
            let t = rng.below(64) as u16;
            if c.get(t) == 0 {
                placed += 1;
            }
            c.inc(t);
            // sometimes pile extra mass on an existing topic
            if rng.next_f64() < 0.3 {
                c.inc(t);
            }
        }
        c
    }

    #[test]
    fn sparse_rows_roundtrip_all_support_sizes() {
        // empty and single-entry rows are the edge cases the epoch
        // protocol actually produces (zero-occurrence words; fresh docs)
        check("SparseCounts wire roundtrip", 48, |rng| {
            let support = match rng.below(4) {
                0 => 0,
                1 => 1,
                _ => 2 + rng.below(40),
            };
            let counts = random_counts(rng, support);
            let total = counts.total();
            let hops = rng.below(32) as u32;
            let tok = WordToken { word: rng.below(10_000) as u32, counts, hops };
            let back = roundtrip(&Frame::Ring(Msg::Word(tok.clone())));
            match back {
                Frame::Ring(Msg::Word(got)) => {
                    if got != tok {
                        return Err(format!("token changed: {got:?} vs {tok:?}"));
                    }
                    if got.counts.total() != total {
                        return Err("count mass changed".into());
                    }
                    if got.hops != tok.hops {
                        return Err("hops changed".into());
                    }
                    Ok(())
                }
                other => Err(format!("wrong frame back: {other:?}")),
            }
        });
    }

    #[test]
    fn global_token_and_totals_roundtrip() {
        check("global token wire roundtrip", 32, |rng| {
            let t = 1 + rng.below(256);
            let s: Vec<i64> = (0..t).map(|_| rng.below(1 << 20) as i64 - (1 << 10)).collect();
            let tok = GlobalToken { s: s.clone(), hops: rng.below(128) as u32 };
            match roundtrip(&Frame::Forward(Msg::Global(tok.clone()))) {
                Frame::Forward(Msg::Global(got)) => {
                    if got != tok {
                        return Err(format!("global token changed: {got:?}"));
                    }
                    Ok(())
                }
                other => Err(format!("wrong frame back: {other:?}")),
            }
        });
    }

    #[test]
    fn every_plain_variant_roundtrips() {
        for frame in [
            Frame::InitOk,
            Frame::Ping,
            Frame::Pong,
            Frame::Ring(Msg::SyncS),
            Frame::Ring(Msg::ReportDocs),
            Frame::Ring(Msg::Stop),
            Frame::Ring(Msg::SetS(vec![-3, 0, 7, i64::MAX, i64::MIN])),
            Frame::Err("ring on fire".into()),
        ] {
            assert_eq!(roundtrip(&frame), frame);
        }
    }

    #[test]
    fn replies_roundtrip() {
        let mut rng = Pcg32::seeded(5);
        let sdelta = Frame::Reply(Reply::SDelta {
            worker: 3,
            delta: vec![5, -5, 0, 123456789],
            tokens_processed: u64::MAX / 3,
            sample_ns: 987_654_321,
            wait_ns: u64::MAX / 7,
        });
        assert_eq!(roundtrip(&sdelta), sdelta);
        let docs = Frame::Reply(Reply::Docs {
            worker: 7,
            start_doc: 421,
            ntd: (0..9).map(|i| random_counts(&mut rng, i % 4)).collect(),
            z: (0..100).map(|_| rng.below(64) as u16).collect(),
        });
        assert_eq!(roundtrip(&docs), docs);
        // empty doc range (degenerate partitions ship these)
        let empty = Frame::Reply(Reply::Docs { worker: 0, start_doc: 0, ntd: vec![], z: vec![] });
        assert_eq!(roundtrip(&empty), empty);
    }

    #[test]
    fn init_roundtrips() {
        let init = Init {
            worker_id: 2,
            num_workers: 5,
            start_doc: 1000,
            t: 128,
            alpha: 50.0 / 128.0,
            beta: 0.01,
            vocab: 7000,
            doc_offsets: vec![0, 3, 8, 9],
            tokens: vec![5, 5, 9, 0, 1, 2, 3, 4, 6999],
            z: vec![0, 1, 2, 3, 4, 5, 127, 9, 11],
            s: vec![7; 128],
            rng_state: 0xDEADBEEFCAFE,
            rng_inc: 0x1234567 | 1,
        };
        let frame = Frame::Init(Box::new(init));
        assert_eq!(roundtrip(&frame), frame);
    }

    #[test]
    fn init_rejects_magic_and_version_skew() {
        let init = Init {
            worker_id: 0,
            num_workers: 1,
            start_doc: 0,
            t: 8,
            alpha: 1.0,
            beta: 0.01,
            vocab: 4,
            doc_offsets: vec![0, 1],
            tokens: vec![0],
            z: vec![0],
            s: vec![1; 8],
            rng_state: 1,
            rng_inc: 3,
        };
        let good = encode_frame(&Frame::Init(Box::new(init)));
        // bytes 1..5 are the magic, 5..9 the version (after the frame tag)
        let mut bad_magic = good.clone();
        bad_magic[1..5].copy_from_slice(&0u32.to_le_bytes());
        assert!(decode_frame(&bad_magic).unwrap_err().contains("magic"));
        let mut bad_version = good.clone();
        bad_version[5..9].copy_from_slice(&(WIRE_VERSION + 1).to_le_bytes());
        let err = decode_frame(&bad_version).unwrap_err();
        assert!(err.contains("version mismatch"), "unhelpful skew error: {err}");
        // the untampered frame still decodes
        decode_frame(&good).unwrap();
    }

    #[test]
    fn malformed_frames_error_instead_of_panicking() {
        // empty buffer
        assert!(decode_frame(&[]).is_err());
        // unknown frame tag
        assert!(decode_frame(&[99]).unwrap_err().contains("unknown frame tag"));
        // truncated word token
        let row = SparseCounts::from_sorted_pairs(vec![(1, 2), (3, 4)]).unwrap();
        let mut buf = encode_frame(&Frame::Ring(Msg::Word(WordToken::new(7, row))));
        buf.truncate(buf.len() - 3);
        assert!(decode_frame(&buf).is_err());
        // trailing bytes
        let mut buf = encode_frame(&Frame::InitOk);
        buf.push(0);
        assert!(decode_frame(&buf).unwrap_err().contains("trailing"));
        // absurd length field: must error, not try to allocate 4 GiB
        let mut buf = vec![TAG_RING, MSG_SET_S];
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_frame(&buf).unwrap_err().contains("exceeds"));
        // sparse row violating sortedness / nonzero-count invariants
        let mut buf = vec![TAG_RING, MSG_WORD];
        buf.extend_from_slice(&7u32.to_le_bytes()); // word
        buf.extend_from_slice(&0u32.to_le_bytes()); // hops
        buf.extend_from_slice(&2u32.to_le_bytes()); // support
        for (t, c) in [(5u16, 1u32), (2, 1)] {
            buf.extend_from_slice(&t.to_le_bytes());
            buf.extend_from_slice(&c.to_le_bytes());
        }
        assert!(decode_frame(&buf).unwrap_err().contains("increasing"));
        let mut buf = vec![TAG_RING, MSG_WORD];
        buf.extend_from_slice(&7u32.to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes());
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&3u16.to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes()); // zero count
        assert!(decode_frame(&buf).unwrap_err().contains("zero count"));
    }

    #[test]
    fn random_bytes_never_panic_the_decoder() {
        check("decoder is total on garbage", 64, |rng| {
            let n = rng.below(200);
            let buf: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            // any outcome is fine — reaching here without a panic is the test
            let _ = decode_frame(&buf);
            Ok(())
        });
    }
}
